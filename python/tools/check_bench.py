#!/usr/bin/env python3
"""Perf-regression ratchet over the BENCH_*.json artifacts.

Every bench target writes a ``BENCH_<name>.json`` document at the repo
root (``Bencher`` rows under ``results`` plus a bench-specific summary
object). This script compares those documents against the committed
``bench_baselines.json`` and fails the build when a metric regresses:

* **bounds** — machine-independent invariants on summary metrics
  (ratios, booleans): ``{"path": "obs.overhead_p50", "max": 0.5}``.
  A violated bound, or a bound whose path is missing from the document
  (schema drift), is a failure.
* **results** — per-row ``mean_ns`` ratchets with a multiplicative
  tolerance (CI runners are noisy; the default tolerance is generous).
  A ``null`` baseline means "not yet baselined": it is reported but
  never fails — run with ``--update`` to pin the current numbers.

Re-baselining after an intentional perf change::

    MPCNN_BENCH_FAST=1 cargo bench --bench obs   # regenerate the artifact
    python3 python/tools/check_bench.py --update  # pin current numbers
    git add bench_baselines.json                  # commit the new floor

Exit status: 0 when every present artifact passes, 1 on any regression
or bound violation. Artifacts named in the baselines but absent on disk
are skipped (each CI job only generates a subset); pass file names as
positional arguments to restrict the check to those artifacts.
"""

import argparse
import json
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[2]

OK = "ok"
FAIL = "REGRESSED"
UNSET = "unbaselined"


def lookup(doc, dotted):
    """Resolve a dotted path ("obs.overhead_p50") inside a JSON object."""
    node = doc
    for part in dotted.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node


def result_row(doc, name):
    for row in doc.get("results", []):
        if row.get("name") == name:
            return row
    return None


def fmt(v):
    if isinstance(v, bool) or v is None:
        return str(v)
    if isinstance(v, float) and abs(v) >= 1000:
        return f"{v:,.0f}"
    if isinstance(v, float):
        return f"{v:.4g}"
    return f"{v:,}"


def check_bounds(fname, doc, bounds, rows):
    bad = 0
    for b in bounds:
        path = b["path"]
        cur = lookup(doc, path)
        if cur is None:
            rows.append((fname, path, "present", "MISSING", FAIL, b.get("why", "")))
            bad += 1
            continue
        if "equals" in b:
            status = OK if cur == b["equals"] else FAIL
            want = f"== {fmt(b['equals'])}"
        elif "max" in b:
            status = OK if cur <= b["max"] else FAIL
            want = f"<= {fmt(b['max'])}"
        else:
            status = OK if cur >= b["min"] else FAIL
            want = f">= {fmt(b['min'])}"
        rows.append((fname, path, want, fmt(cur), status, b.get("why", "")))
        bad += status == FAIL
    return bad


def check_results(fname, doc, results, default_tol, rows):
    bad = 0
    for name, spec in sorted(results.items()):
        row = result_row(doc, name)
        base = spec.get("mean_ns")
        if row is None:
            rows.append((fname, name, fmt(base), "MISSING", FAIL, "bench row gone"))
            bad += 1
            continue
        cur = row.get("mean_ns")
        if base is None:
            rows.append((fname, name, "(none)", fmt(cur), UNSET, "run --update to pin"))
            continue
        tol = spec.get("tolerance", default_tol)
        status = OK if cur <= base * tol else FAIL
        delta = 100.0 * (cur / base - 1.0) if base else 0.0
        rows.append((fname, name, fmt(base), fmt(cur), status, f"{delta:+.1f}% (tol x{tol})"))
        bad += status == FAIL
    return bad


def render(rows):
    headers = ("artifact", "metric", "baseline", "current", "status", "note")
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    line = "  ".join(h.ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(c.ljust(w) for c, w in zip(r, widths)))


def update_baselines(baselines, path):
    """Pin current mean_ns values for every artifact present on disk."""
    pinned = 0
    for fname, entry in baselines.get("files", {}).items():
        fpath = REPO_ROOT / fname
        if not fpath.exists():
            continue
        doc = json.loads(fpath.read_text())
        for name, spec in entry.get("results", {}).items():
            row = result_row(doc, name)
            if row is not None:
                spec["mean_ns"] = row.get("mean_ns")
                pinned += 1
    path.write_text(json.dumps(baselines, indent=2, sort_keys=False) + "\n")
    print(f"pinned {pinned} baseline(s) into {path}")
    print("commit the updated file to accept the new perf floor")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifacts", nargs="*",
                    help="restrict to these BENCH_*.json files (default: all in baselines)")
    ap.add_argument("--baselines", default=str(REPO_ROOT / "bench_baselines.json"))
    ap.add_argument("--update", action="store_true",
                    help="pin current numbers as the new baseline instead of checking")
    args = ap.parse_args()

    bpath = Path(args.baselines)
    baselines = json.loads(bpath.read_text())
    if args.update:
        update_baselines(baselines, bpath)
        return 0

    default_tol = baselines.get("default_tolerance", 1.35)
    only = {Path(a).name for a in args.artifacts}
    rows, bad, checked = [], 0, 0
    for fname, entry in baselines.get("files", {}).items():
        if only and fname not in only:
            continue
        fpath = REPO_ROOT / fname
        if not fpath.exists():
            if only:  # explicitly requested but absent: that is a failure
                rows.append((fname, "-", "-", "MISSING", FAIL, "artifact not generated"))
                bad += 1
            else:
                rows.append((fname, "-", "-", "-", "skipped", "artifact not on disk"))
            continue
        doc = json.loads(fpath.read_text())
        checked += 1
        bad += check_bounds(fname, doc, entry.get("bounds", []), rows)
        bad += check_results(fname, doc, entry.get("results", {}), default_tol, rows)
    render(rows)
    if bad:
        print(f"\n{bad} regression(s). If intentional, re-baseline:")
        print("  MPCNN_BENCH_FAST=1 cargo bench --bench <name>")
        print("  python3 python/tools/check_bench.py --update  # then commit bench_baselines.json")
        return 1
    print(f"\nall checks passed across {checked} artifact(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
