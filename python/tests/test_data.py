"""Synthetic shapes dataset tests + binary format parity with the rust
TestSet reader."""

import struct

import numpy as np

from compile import data


def test_deterministic_given_seed():
    a_img, a_lab = data.make_dataset(3, seed=42)
    b_img, b_lab = data.make_dataset(3, seed=42)
    np.testing.assert_array_equal(a_img, b_img)
    np.testing.assert_array_equal(a_lab, b_lab)


def test_seeds_differ():
    a_img, _ = data.make_dataset(2, seed=1)
    b_img, _ = data.make_dataset(2, seed=2)
    assert not np.array_equal(a_img, b_img)


def test_class_balance_and_ranges():
    img, lab = data.make_dataset(5, seed=0)
    assert img.shape == (50, 32, 32, 3)
    assert img.dtype == np.float32
    assert img.min() >= 0.0 and img.max() <= 1.0
    counts = np.bincount(lab, minlength=10)
    assert np.all(counts == 5)


def test_train_test_disjoint_generation():
    (tr_x, _), (te_x, _) = data.train_test_split(2, 2, seed=0)
    # Different seeds -> different samples (probability of collision ~ 0).
    assert not np.array_equal(tr_x[:20], te_x[:20])


def test_classes_are_distinguishable():
    """Mean inter-class L2 distance must exceed intra-class distance —
    otherwise QAT accuracy ordering is meaningless."""
    img, lab = data.make_dataset(8, seed=3)
    means = np.stack([img[lab == c].mean(axis=0).ravel() for c in range(10)])
    inter = np.mean(
        [
            np.linalg.norm(means[i] - means[j])
            for i in range(10)
            for j in range(i + 1, 10)
        ]
    )
    intra = np.mean(
        [
            np.linalg.norm(x.ravel() - means[lab[i]])
            for i, x in enumerate(img)
        ]
    )
    assert inter > 0.5 * intra, f"inter={inter} intra={intra}"


def test_testset_bin_format(tmp_path):
    img, lab = data.make_dataset(2, seed=9)
    path = tmp_path / "testset.bin"
    data.write_testset_bin(str(path), img, lab)
    raw = path.read_bytes()
    assert raw[:4] == b"MPTS"
    n, h, w, c = struct.unpack("<IIII", raw[4:20])
    assert (n, h, w, c) == (20, 32, 32, 3)
    assert len(raw) == 20 + n * h * w * c * 4 + n
    # images round-trip
    back = np.frombuffer(raw[20 : 20 + n * h * w * c * 4], dtype="<f4").reshape(
        n, h, w, c
    )
    np.testing.assert_array_equal(back, img)
    labels_back = np.frombuffer(raw[20 + n * h * w * c * 4 :], dtype=np.uint8)
    np.testing.assert_array_equal(labels_back, lab)
