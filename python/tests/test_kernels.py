"""L1 Pallas kernels vs pure-jnp oracles — the CORE correctness signal.

The bit-sliced matmul must equal the plain quantized matmul *bit-exactly*
on int32 inputs for every (shape, word-length, slice) combination
(hypothesis sweep), mirroring rust/src/pe/golden.rs on the python side.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.bitslice import (
    bitslice_matmul,
    lsq_quantize_kernel,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import bitslice_matmul_ref, lsq_quantize_ref, matmul_ref
from compile.quantize import qbounds, slice_signed_int


def random_operands(rng, m, kk, n, wq, dtype=np.int32):
    qn, qp = qbounds(wq, True)
    a = rng.integers(0, 256, size=(m, kk)).astype(dtype)  # 8-bit act codes
    w = rng.integers(qn, qp + 1, size=(kk, n)).astype(dtype)
    return a, w


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    kk=st.integers(1, 64),
    n=st.integers(1, 70),
    wq=st.sampled_from([1, 2, 4, 8]),
    k=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_bitslice_matmul_exact_int32(m, kk, n, wq, k, seed):
    rng = np.random.default_rng(seed)
    a, w = random_operands(rng, m, kk, n, wq)
    planes = np.asarray(
        slice_signed_int(jnp.asarray(w, jnp.float32), wq, k), np.int32
    )
    out = bitslice_matmul(jnp.asarray(a), jnp.asarray(planes), k)
    want = a.astype(np.int64) @ w.astype(np.int64)
    np.testing.assert_array_equal(np.asarray(out, np.int64), want)


@settings(max_examples=20, deadline=None)
@given(
    wq=st.sampled_from([2, 4, 8]),
    k=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_kernel_matches_ref_decomposition(wq, k, seed):
    """Kernel == the explicit per-slice oracle (not just the end result)."""
    rng = np.random.default_rng(seed)
    a, w = random_operands(rng, 17, 23, 9, wq)
    planes = np.asarray(
        slice_signed_int(jnp.asarray(w, jnp.float32), wq, k), np.int32
    )
    ours = bitslice_matmul(jnp.asarray(a), jnp.asarray(planes), k)
    ref = bitslice_matmul_ref(jnp.asarray(a), jnp.asarray(planes), k)
    np.testing.assert_array_equal(np.asarray(ours), np.asarray(ref))


def test_float32_path_close_to_ref():
    rng = np.random.default_rng(3)
    a, w = random_operands(rng, 64, 144, 32, 4, dtype=np.float32)
    planes = np.asarray(slice_signed_int(jnp.asarray(w), 4, 2), np.float32)
    out = bitslice_matmul(jnp.asarray(a), jnp.asarray(planes), 2)
    want = matmul_ref(jnp.asarray(a), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def test_blocking_independence():
    """Result must not depend on the tile sizes (padding correctness)."""
    rng = np.random.default_rng(5)
    a, w = random_operands(rng, 50, 30, 26, 8)
    planes = np.asarray(
        slice_signed_int(jnp.asarray(w, jnp.float32), 8, 2), np.int32
    )
    outs = [
        np.asarray(bitslice_matmul(jnp.asarray(a), jnp.asarray(planes), 2, bm, bn))
        for bm, bn in [(8, 8), (16, 64), (64, 16), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_array_equal(o, outs[0])


def test_k_independence():
    """The same dot product through different slicings must agree exactly."""
    rng = np.random.default_rng(7)
    a, w = random_operands(rng, 33, 41, 13, 8)
    results = []
    for k in [1, 2, 4]:
        planes = np.asarray(
            slice_signed_int(jnp.asarray(w, jnp.float32), 8, k), np.int32
        )
        results.append(np.asarray(bitslice_matmul(jnp.asarray(a), jnp.asarray(planes), k)))
    for r in results[1:]:
        np.testing.assert_array_equal(r, results[0])


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3000),
    gamma=st.floats(0.01, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_lsq_kernel_matches_ref(n, gamma, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, 2, size=n).astype(np.float32))
    g = jnp.asarray(gamma, jnp.float32)
    ours = lsq_quantize_kernel(x, g, 0.0, 255.0)
    want = lsq_quantize_ref(x, g, 0.0, 255.0)
    np.testing.assert_allclose(np.asarray(ours), np.asarray(want), rtol=1e-6)


def test_lsq_kernel_multidim_shape_preserved():
    x = jnp.ones((2, 5, 5, 3))
    out = lsq_quantize_kernel(x, jnp.asarray(0.1), 0.0, 255.0)
    assert out.shape == x.shape


def test_perf_estimators():
    # VMEM footprint of the default tile on a ResNet-8 stage-3 conv:
    # (64 x 576) acts + 2 planes (576 x 64) + (64 x 64) out, f32.
    b = vmem_footprint_bytes(64, 64, 576, 2)
    assert b == 4 * (64 * 576 + 2 * 576 * 64 + 64 * 64)
    assert b < 16 * 2**20, "tile must fit VMEM (16 MiB)"
    u = mxu_utilization_estimate(64, 64, 576)
    assert 0.0 < u <= 1.0
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
