"""LSQ quantizer + bit-slicing properties (mirror of rust/src/quant tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.quantize import (
    lsq_init_gamma,
    lsq_quantize,
    qbounds,
    quantize_int,
    reconstruct_slices,
    slice_signed_int,
)


def test_qbounds_match_paper():
    assert qbounds(8, False) == (0, 255)
    assert qbounds(8, True) == (-128, 127)
    assert qbounds(1, True) == (-1, 0)
    assert qbounds(2, True) == (-2, 1)


def test_quantize_grid_identity():
    gamma = 0.25
    for code in range(-8, 8):
        v = code * gamma
        q = lsq_quantize(jnp.asarray(v), jnp.asarray(gamma), 4, True)
        assert abs(float(q) - v) < 1e-7


def test_quantize_clamps():
    q = lsq_quantize(jnp.asarray(100.0), jnp.asarray(1.0), 2, True)
    assert float(q) == 1.0
    q = lsq_quantize(jnp.asarray(-100.0), jnp.asarray(1.0), 2, True)
    assert float(q) == -2.0
    q = lsq_quantize(jnp.asarray(-5.0), jnp.asarray(0.5), 8, False)
    assert float(q) == 0.0


@settings(max_examples=60, deadline=None)
@given(
    bits=st.sampled_from([2, 4, 8]),
    gamma=st.floats(0.01, 2.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_error_bounded_by_half_step(bits, gamma, seed):
    rng = np.random.default_rng(seed)
    qn, qp = qbounds(bits, True)
    v = rng.uniform(qn * gamma, qp * gamma, size=32).astype(np.float32)
    q = lsq_quantize(jnp.asarray(v), jnp.asarray(gamma, jnp.float32), bits, True)
    err = np.max(np.abs(np.asarray(q) - v))
    assert err <= gamma / 2 + 1e-5


def test_ste_gradient_passes_inside_clamp():
    def f(x):
        return jnp.sum(lsq_quantize(x, jnp.asarray(0.5), 8, True))

    g = jax.grad(f)(jnp.asarray([0.3, -0.7, 100.0]))
    assert float(g[0]) == 1.0
    assert float(g[1]) == 1.0
    assert float(g[2]) == 0.0  # clamped -> no gradient to x


def test_gamma_gradient_finite_and_nonzero():
    def f(gamma):
        x = jnp.linspace(-1.0, 1.0, 64)
        return jnp.sum(lsq_quantize(x, gamma, 4, True) ** 2)

    g = jax.grad(f)(jnp.asarray(0.3))
    assert np.isfinite(float(g))


def test_init_gamma_one_bit_finite():
    w = jnp.asarray(np.random.default_rng(0).normal(size=100), jnp.float32)
    g = lsq_init_gamma(w, 1, True)
    assert np.isfinite(float(g)) and float(g) > 0


@settings(max_examples=100, deadline=None)
@given(
    wq=st.sampled_from([1, 2, 3, 4, 8]),
    k=st.sampled_from([1, 2, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_prop_slice_roundtrip_exact(wq, k, seed):
    rng = np.random.default_rng(seed)
    qn, qp = qbounds(wq, True)
    w = rng.integers(qn, qp + 1, size=(5, 7)).astype(np.float32)
    digits = slice_signed_int(jnp.asarray(w), wq, k)
    rec = reconstruct_slices(digits, k)
    np.testing.assert_array_equal(np.asarray(rec), w)
    # digit count and ranges
    assert digits.shape[0] == -(-wq // k)
    d = np.asarray(digits)
    for s in range(d.shape[0] - 1):
        assert d[s].min() >= 0 and d[s].max() < 2 ** min(k, wq - s * k)


def test_quantize_int_codes_in_range():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=200).astype(np.float32))
    for bits, signed in [(1, True), (2, True), (8, False)]:
        qn, qp = qbounds(bits, signed)
        codes = np.asarray(quantize_int(x, jnp.asarray(0.1), bits, signed))
        assert codes.min() >= qn and codes.max() <= qp
        assert np.all(codes == np.round(codes))
