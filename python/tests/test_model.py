"""L2 model tests: shapes, train/infer path equivalence, k-independence,
serialization round-trip."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data
from compile.model import (
    flatten_params,
    forward_infer,
    forward_train,
    init_params,
    load_params,
    save_params,
    unflatten_params,
    update_bn,
)


@pytest.fixture(scope="module")
def batch():
    x, y = data.make_dataset(1, seed=11)
    return jnp.asarray(x[:4]), jnp.asarray(y[:4].astype(np.int32))


def test_forward_train_shapes(batch):
    x, _ = batch
    params = init_params(jax.random.PRNGKey(0), 4)
    logits, stats = forward_train(params, x, 4, train=True)
    assert logits.shape == (4, 10)
    assert len(stats) == 9  # 9 BN layers in ResNet-8
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("wq", [1, 2, 4, 8])
def test_infer_matches_train_eval_mode(batch, wq):
    """The bit-sliced Pallas datapath equals the lax.conv oracle."""
    x, _ = batch
    params = init_params(jax.random.PRNGKey(1), wq)
    want, _ = forward_train(params, x, wq, train=False)
    for k in [1, 2, 4]:
        got = forward_infer(params, x, wq, k)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


def test_infer_k_independent(batch):
    x, _ = batch
    params = init_params(jax.random.PRNGKey(2), 4)
    outs = [np.asarray(forward_infer(params, x, 4, k)) for k in [1, 2, 4]]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=1e-5)


def test_fp_baseline_path(batch):
    x, _ = batch
    params = init_params(jax.random.PRNGKey(3), 0)
    logits, _ = forward_train(params, x, 0, train=False)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_update_bn_moves_running_stats(batch):
    x, _ = batch
    params = init_params(jax.random.PRNGKey(4), 4)
    _, stats = forward_train(params, x, 4, train=True)
    new = update_bn(params, stats, momentum=0.0)  # jump straight to batch stats
    assert not np.allclose(
        np.asarray(new["conv1"]["bn_mean"]), np.asarray(params["conv1"]["bn_mean"])
    )
    # original untouched (functional update)
    assert float(jnp.sum(jnp.abs(params["conv1"]["bn_mean"]))) == 0.0


def test_params_roundtrip(tmp_path, batch):
    x, _ = batch
    params = init_params(jax.random.PRNGKey(5), 2)
    path = tmp_path / "p.npz"
    save_params(path, params)
    loaded = load_params(path)
    a, _ = forward_train(params, x, 2, train=False)
    b, _ = forward_train(loaded, x, 2, train=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_flatten_unflatten_inverse():
    params = init_params(jax.random.PRNGKey(6), 4)
    flat = flatten_params(params)
    rec = unflatten_params(flat)
    flat2 = flatten_params(rec)
    assert set(flat) == set(flat2)
    for k in flat:
        np.testing.assert_array_equal(np.asarray(flat[k]), np.asarray(flat2[k]))


def test_gradients_flow_to_weights_and_gammas(batch):
    x, y = batch
    params = init_params(jax.random.PRNGKey(7), 2)

    def loss(p):
        logits, _ = forward_train(p, x, 2, train=True)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(logp[jnp.arange(y.shape[0]), y])

    g = jax.grad(loss)(params)
    assert float(jnp.sum(jnp.abs(g["conv1"]["w"]))) > 0
    assert float(jnp.abs(g["block1"]["conv1"]["gamma_w"])) > 0
    assert float(jnp.abs(g["conv1"]["gamma_a"])) > 0
    # BN running stats receive no gradient (updated via update_bn instead)
    assert float(jnp.sum(jnp.abs(g["conv1"]["bn_mean"]))) == 0.0
