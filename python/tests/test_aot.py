"""AOT export tests: HLO text emission, manifest schema, and numeric parity
between the exported computation and forward_infer (via jax round-trip)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, data
from compile.model import forward_infer, init_params


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(7), 4)


def test_export_writes_parseable_hlo(tmp_path, params):
    out = tmp_path / "m.hlo.txt"
    nbytes = aot.export_model(params, 4, 1, str(out))
    text = out.read_text()
    assert nbytes == len(text)
    assert "HloModule" in text
    # The exported graph must be pure HLO (interpret-mode pallas lowers to
    # standard ops) — a Mosaic custom-call would break the CPU PJRT client.
    assert "custom-call" not in text or "mosaic" not in text.lower()
    # Large constants (the baked weights!) must not be elided — the rust
    # parser accepts `constant({...})` and silently zeroes the model.
    assert "{...}" not in text


def test_exported_hlo_has_right_signature(tmp_path, params):
    out = tmp_path / "m.hlo.txt"
    aot.export_model(params, 2, 8, str(out))
    text = out.read_text()
    assert "f32[8,32,32,3]" in text, "batch-8 input parameter"
    assert "f32[8,10]" in text, "batch-8 logits"


def test_manifest_end_to_end(tmp_path, params, monkeypatch):
    """Run aot.main with random params and validate the manifest bundle."""
    import sys

    monkeypatch.setattr(
        sys,
        "argv",
        [
            "aot",
            "--out-dir",
            str(tmp_path),
            "--wq",
            "4",
            "--batches",
            "1",
            "--random-params",
            "--n-test-per-class",
            "2",
        ],
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert len(manifest["models"]) == 1
    entry = manifest["models"][0]
    assert entry["input"] == [1, 32, 32, 3]
    assert entry["classes"] == 10
    assert (tmp_path / entry["path"]).exists()
    assert (tmp_path / manifest["testset"]).exists()


def test_lowered_computation_matches_eager(params):
    """jit(fn) must equal eager forward_infer (the AOT contract, checked
    on the jax side; the rust integration test re-checks through PJRT)."""
    x = jnp.asarray(data.make_dataset(1, seed=5)[0][:2])

    def fn(xx):
        return forward_infer(params, xx, 4, aot.EXPORT_K)

    eager = fn(x)
    jitted = jax.jit(fn)(x)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager), rtol=1e-5)


def test_missing_params_errors(tmp_path, monkeypatch):
    import sys

    monkeypatch.setattr(
        sys, "argv", ["aot", "--out-dir", str(tmp_path), "--wq", "2", "--batches", "1"]
    )
    with pytest.raises(SystemExit):
        aot.main()
