"""QAT training smoke tests (short runs; the full runs happen in
`make artifacts` and are recorded in EXPERIMENTS.md)."""

import numpy as np
import pytest

from compile.train_qat import train


@pytest.mark.slow
def test_short_training_reduces_loss_and_beats_chance():
    params, acc, loss_log = train(
        4, steps=40, batch=32, n_train_per_class=60, n_test_per_class=10, log_every=0
    )
    assert np.mean(loss_log[:5]) > np.mean(loss_log[-5:]), "loss must decrease"
    assert acc > 0.3, f"accuracy {acc} should beat 10% chance handily"


@pytest.mark.slow
def test_one_bit_trains_without_nan():
    _, acc, loss_log = train(
        1, steps=25, batch=32, n_train_per_class=40, n_test_per_class=10, log_every=0
    )
    assert np.all(np.isfinite(loss_log))
    assert 0.0 <= acc <= 1.0
