"""L1 Pallas kernels: the paper's BP-ST-1D bit-sliced MAC datapath.

``bitslice_matmul`` is the compute hot-spot of the whole stack: a tiled
matmul where the weight matrix arrives decomposed into ``S = ceil(wq/k)``
k-bit digit planes (PPG operands). Inside one tile the kernel computes one
partial product per digit plane (the PPG array), shift-aligns each by
``2^(k*s)`` (the barrel shifters) and sums them (the Sum-Together adder
tree) — exactly the Fig 1b / Fig 6b datapath, expressed for a TPU-shaped
machine (see DESIGN.md §6 Hardware-Adaptation):

- PPG array        -> one MXU contraction per digit plane
- shift + ST tree  -> scalar-weighted accumulation over the plane axis
- BRAM broadcast   -> BlockSpec: the (block_m, K) activation tile and all S
                      (K, block_n) digit tiles are resident in VMEM while
                      the grid walks output tiles (activations stream, the
                      weight tile is reused — the H×W×D spatial reuse).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what
``aot.py`` exports and the rust runtime executes.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def bitslice_matmul(a, w_slices, k: int, block_m: int = 1024, block_n: int = 128):
    """Bit-sliced matmul: ``sum_s (a @ w_slices[s]) * 2^(k*s)``.

    a:        [M, K] integer-valued (activation codes), int32 or float32
    w_slices: [S, K, N] integer-valued digit planes (top plane signed)
    returns:  [M, N] in a.dtype — equal to ``a @ reconstruct(w_slices)``

    The decomposition is exact in int32, and exact in float32 while every
    partial dot stays below 2^24 (true for all trained models here; the
    int32 path is what the property tests drive).

    Tile defaults (1024, 128) are the §Perf result: grid-iteration
    overhead dominates interpret/CPU wallclock AND the HBM↔VMEM
    round-trips on real hardware, so tiles are sized to the largest block
    that keeps the activation tile + all digit planes + the output tile
    within VMEM (~3.5 MiB at K = 576, S = 2 — 21 % of a 16 MiB VMEM);
    measured ~9x faster than the initial 64x64 tiles end-to-end
    (EXPERIMENTS.md §Perf).
    """
    assert a.ndim == 2 and w_slices.ndim == 3
    m, kk = a.shape
    s, kk2, n = w_slices.shape
    assert kk == kk2, f"contraction mismatch: {kk} vs {kk2}"
    dtype = a.dtype
    assert w_slices.dtype == dtype, "operand dtypes must match"

    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, 0)))
    w_p = jnp.pad(w_slices, ((0, 0), (0, 0), (0, np_ - n)))

    shift = [dtype.type(2 ** (k * i)) for i in range(s)]

    def kernel(a_ref, w_ref, o_ref):
        # PPG array: one contraction per digit plane; ST adder tree: the
        # shift-weighted sum. Unrolled statically over the plane axis.
        a_tile = a_ref[...]
        acc = jnp.zeros(o_ref.shape, dtype)
        for i in range(s):
            pp = jax.lax.dot_general(
                a_tile,
                w_ref[i],
                (((1,), (0,)), ((), ())),
                preferred_element_type=dtype,
            )
            acc = acc + pp * shift[i]
        o_ref[...] = acc

    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, kk), lambda i, j: (i, 0)),
            pl.BlockSpec((s, kk, bn), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), dtype),
        interpret=True,
    )(a_p, w_p)
    return out[:m, :n]


def lsq_quantize_kernel(x, gamma, qn: float, qp: float, block: int = 32768):
    """Elementwise LSQ quantizer (Eq 5) as a Pallas kernel:
    ``round(clamp(x/gamma, qn, qp)) * gamma``.

    x: any shape, float32. gamma: scalar array.
    """
    orig_shape = x.shape
    flat = x.reshape(-1)
    nelem = flat.shape[0]
    b = min(block, _ceil_to(nelem, 8))
    npad = _ceil_to(nelem, b)
    flat_p = jnp.pad(flat, (0, npad - nelem))
    gamma_arr = jnp.asarray(gamma, jnp.float32).reshape(1)

    def kernel(x_ref, g_ref, o_ref):
        g = g_ref[0]
        v = jnp.clip(x_ref[...] / g, qn, qp)
        o_ref[...] = jnp.round(v) * g

    out = pl.pallas_call(
        kernel,
        grid=(npad // b,),
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=True,
    )(flat_p, gamma_arr)
    return out[:nelem].reshape(orig_shape)


@partial(jax.jit, static_argnums=(2,))
def bitslice_matmul_jit(a, w_slices, k: int):
    """Jitted wrapper (tests + benchmarking)."""
    return bitslice_matmul(a, w_slices, k)


def vmem_footprint_bytes(block_m: int, block_n: int, kk: int, s: int, itemsize: int = 4):
    """Estimated VMEM residency of one grid step (activation tile + all
    digit planes + output tile) — the L1 'profile' quantity recorded in
    EXPERIMENTS.md §Perf (interpret mode has no real TPU timing)."""
    return itemsize * (block_m * kk + s * kk * block_n + block_m * block_n)


def mxu_utilization_estimate(block_m: int, block_n: int, kk: int):
    """Fraction of a 128x128 MXU a (block_m x kk x block_n) contraction
    keeps busy per pass — structural estimate for DESIGN.md §Perf."""
    eff_m = min(block_m, 128) / 128.0
    eff_n = min(block_n, 128) / 128.0
    eff_k = min(kk, 128) / 128.0
    return eff_m * eff_n * eff_k
