"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Everything here is the "obvious" implementation; the Pallas kernels in
``bitslice.py`` must match these bit-exactly on integer inputs (pytest +
hypothesis sweep shapes and word-lengths).
"""

import jax.numpy as jnp


def matmul_ref(a, w):
    """Plain dot product: the full-precision MAC array."""
    return a @ w


def bitslice_matmul_ref(a, w_slices, k: int):
    """What the BP-ST-1D datapath computes: per-slice partial products,
    shift-aligned and summed. On exact inputs this equals
    ``a @ reconstruct(w_slices)``."""
    s = w_slices.shape[0]
    acc = None
    for i in range(s):
        pp = a @ w_slices[i]
        term = pp * (2 ** (k * i))
        acc = term if acc is None else acc + term
    return acc


def lsq_quantize_ref(x, gamma, qn: float, qp: float):
    """Eq 5 without STE."""
    return jnp.round(jnp.clip(x / gamma, qn, qp)) * gamma


def conv2d_nhwc_ref(x, w, stride: int = 1):
    """Reference conv via jax.lax (float path), with *symmetric* half
    padding ``((K-1)//2, K-1-(K-1)//2)`` so the output grid matches the
    im2col extraction in ``model._im2col`` for every stride (lax's 'SAME'
    uses asymmetric low/high padding at stride 2, which would misalign the
    two datapaths by one pixel).

    x: [B, H, W, C], w: [KH, KW, C, O]. Output spatial = ceil(H/stride).
    """
    import jax.lax as lax

    kh, kw = w.shape[0], w.shape[1]
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((ph, kh - 1 - ph), (pw, kw - 1 - pw)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
