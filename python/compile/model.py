"""L2: the quantized ResNet (JAX), in two mathematically-identical forms.

- ``forward_train``: LSQ fake-quantization (STE) + ``lax.conv`` — the QAT
  path used by ``train_qat.py``. With ``train=False`` it uses BN running
  stats and is the float oracle for the export path.
- ``forward_infer``: the **bit-sliced datapath** — integer activation codes
  and k-bit weight digit planes flowing through the L1 Pallas kernels
  (``bitslice_matmul`` + ``lsq_quantize_kernel``), exactly what the paper's
  BP-ST-1D array executes. ``aot.py`` lowers this form to HLO for the rust
  runtime.

The topology mirrors ``rust/src/cnn/resnet.rs::resnet_small`` exactly
(ResNet-8: conv1 + three basic blocks at 16/32/64 channels + FC), so the
rust simulator's shape model corresponds 1:1 to the executable artifact.

Quantization scheme (paper §IV-C): activations 8-bit unsigned everywhere;
first (conv1) and last (fc) layer weights at 8 bit; inner weights at
``wq_inner`` ∈ {1, 2, 4, 8}. ``wq_inner = 0`` disables quantization (the
FP32 baseline of Table III).
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels.bitslice import bitslice_matmul, lsq_quantize_kernel
from .kernels.ref import conv2d_nhwc_ref
from .quantize import (
    lsq_init_gamma,
    lsq_quantize,
    quantize_int,
    slice_signed_int,
)

BN_EPS = 1e-5
ACT_BITS = 8
N_CLASSES = 10

# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _he_init(key, shape):
    fan_in = 1
    for d in shape[:-1]:
        fan_in *= d
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def _conv_params(key, kh, kw, cin, cout, wq):
    w = _he_init(key, (kh, kw, cin, cout))
    return {
        "w": w,
        "gamma_w": lsq_init_gamma(w, max(wq, 1), signed=True),
        "bn_scale": jnp.ones(cout),
        "bn_bias": jnp.zeros(cout),
        "bn_mean": jnp.zeros(cout),
        "bn_var": jnp.ones(cout),
        "gamma_a": jnp.asarray(0.04),  # refined by LSQ during QAT
    }


def init_params(key, wq_inner: int, width: int = 16):
    """Initialize the ResNet-8 parameter pytree."""
    keys = jax.random.split(key, 16)
    w1, w2, w3 = width, width * 2, width * 4
    params = {
        "gamma_in": jnp.asarray(1.0 / 255.0),
        "conv1": _conv_params(keys[0], 3, 3, 3, w1, 8),
        "block1": {
            "conv1": _conv_params(keys[1], 3, 3, w1, w1, wq_inner),
            "conv2": _conv_params(keys[2], 3, 3, w1, w1, wq_inner),
        },
        "block2": {
            "conv1": _conv_params(keys[3], 3, 3, w1, w2, wq_inner),
            "conv2": _conv_params(keys[4], 3, 3, w2, w2, wq_inner),
            "ds": _conv_params(keys[5], 1, 1, w1, w2, wq_inner),
        },
        "block3": {
            "conv1": _conv_params(keys[6], 3, 3, w2, w3, wq_inner),
            "conv2": _conv_params(keys[7], 3, 3, w3, w3, wq_inner),
            "ds": _conv_params(keys[8], 1, 1, w2, w3, wq_inner),
        },
        "fc": {
            "w": _he_init(keys[9], (w3, N_CLASSES)),
            "b": jnp.zeros(N_CLASSES),
            "gamma_w": jnp.asarray(0.01),
            "gamma_a": jnp.asarray(0.04),
        },
    }
    params["fc"]["gamma_w"] = lsq_init_gamma(params["fc"]["w"], 8, signed=True)
    return params


# ---------------------------------------------------------------------------
# Training / oracle path (fake quantization, lax.conv)
# ---------------------------------------------------------------------------


def _bn(y, p, train: bool):
    """BatchNorm. Returns (out, (batch_mean, batch_var)) — the caller folds
    the batch stats into the running averages."""
    if train:
        mean = jnp.mean(y, axis=(0, 1, 2))
        var = jnp.var(y, axis=(0, 1, 2))
    else:
        mean, var = p["bn_mean"], p["bn_var"]
    out = (y - mean) / jnp.sqrt(var + BN_EPS) * p["bn_scale"] + p["bn_bias"]
    return out, (mean, var)


def _act_q(y, gamma_a, quantize: bool):
    """Unsigned 8-bit activation quantization. The clamp at Qn=0 doubles as
    the ReLU (negative pre-activations map to code 0)."""
    if not quantize:
        return jax.nn.relu(y)
    return lsq_quantize(y, gamma_a, ACT_BITS, False)


def _qconv_train(x, p, wq: int, stride: int, train: bool, stats: list):
    if wq == 0:
        w_q = p["w"]
    else:
        w_q = lsq_quantize(p["w"], p["gamma_w"], wq, True)
    y = conv2d_nhwc_ref(x, w_q, stride)
    out, bn_stats = _bn(y, p, train)
    stats.append(bn_stats)
    return out


def forward_train(params, x, wq_inner: int, train: bool = True):
    """QAT/oracle forward. Returns (logits, bn_batch_stats list in layer
    order) — pass the stats to :func:`update_bn` after a training step."""
    q = wq_inner > 0
    stats: list = []
    xq = _act_q(x, params["gamma_in"], q)

    h = _qconv_train(xq, params["conv1"], 8 if q else 0, 1, train, stats)
    h = _act_q(h, params["conv1"]["gamma_a"], q)

    # block1 (16 -> 16, stride 1, identity shortcut)
    b = params["block1"]
    y = _qconv_train(h, b["conv1"], wq_inner, 1, train, stats)
    y = _act_q(y, b["conv1"]["gamma_a"], q)
    y = _qconv_train(y, b["conv2"], wq_inner, 1, train, stats)
    h = _act_q(y + h, b["conv2"]["gamma_a"], q)

    # blocks 2, 3 (stride 2, 1x1 downsample shortcut)
    for name in ("block2", "block3"):
        b = params[name]
        y = _qconv_train(h, b["conv1"], wq_inner, 2, train, stats)
        y = _act_q(y, b["conv1"]["gamma_a"], q)
        y = _qconv_train(y, b["conv2"], wq_inner, 1, train, stats)
        sc = _qconv_train(h, b["ds"], wq_inner, 2, train, stats)
        h = _act_q(y + sc, b["conv2"]["gamma_a"], q)

    # global average pool + quantized FC
    pooled = jnp.mean(h, axis=(1, 2))
    fc = params["fc"]
    pq = _act_q(pooled, fc["gamma_a"], q)
    if q:
        w_q = lsq_quantize(fc["w"], fc["gamma_w"], 8, True)
    else:
        w_q = fc["w"]
    logits = pq @ w_q + fc["b"]
    return logits, stats


_BN_LAYER_ORDER = [
    ("conv1",),
    ("block1", "conv1"),
    ("block1", "conv2"),
    ("block2", "conv1"),
    ("block2", "conv2"),
    ("block2", "ds"),
    ("block3", "conv1"),
    ("block3", "conv2"),
    ("block3", "ds"),
]


def update_bn(params, stats, momentum: float = 0.9):
    """Fold a step's batch statistics into the running BN averages."""
    new = jax.tree_util.tree_map(lambda v: v, params)  # shallow-ish copy
    for path, (mean, var) in zip(_BN_LAYER_ORDER, stats):
        node = new
        for k in path:
            node = node[k]
        node["bn_mean"] = momentum * node["bn_mean"] + (1 - momentum) * mean
        node["bn_var"] = momentum * node["bn_var"] + (1 - momentum) * var
    return new


# ---------------------------------------------------------------------------
# Inference / export path (bit-sliced Pallas datapath)
# ---------------------------------------------------------------------------


def _im2col(codes, kh: int, kw: int, stride: int):
    """SAME-padded patch extraction. codes: [B, H, W, C] ->
    [B*OH*OW, kh*kw*C], ordering (dy, dx, c) to match the HWIO weight
    reshape. Zero padding is exact: activation code 0 is real value 0."""
    b, h, w, c = codes.shape
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    x = jnp.pad(codes, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    oh = -(-h // stride)
    ow = -(-w // stride)
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            patch = x[:, dy : dy + h : stride, dx : dx + w : stride, :]
            cols.append(patch[:, :oh, :ow, :])
    stacked = jnp.concatenate(cols, axis=-1)  # [B, OH, OW, kh*kw*C]
    return stacked.reshape(b * oh * ow, kh * kw * c), (b, oh, ow)


def _qconv_infer(codes, gamma_prev, p, wq: int, stride: int, k: int):
    """One conv on the bit-sliced datapath.

    codes: integer-valued activation codes [B,H,W,C] (f32).
    Returns the *real-valued*, BN-folded output [B,OH,OW,O].
    """
    kh, kw, cin, cout = p["w"].shape
    w_int = quantize_int(p["w"], p["gamma_w"], wq, True)  # [kh,kw,cin,cout]
    planes = slice_signed_int(w_int, wq, k)  # [S, kh,kw,cin,cout]
    s = planes.shape[0]
    planes2d = planes.reshape(s, kh * kw * cin, cout)
    patches, (b, oh, ow) = _im2col(codes, kh, kw, stride)
    y_int = bitslice_matmul(patches, planes2d, k)  # [B*OH*OW, cout]
    y = y_int.reshape(b, oh, ow, cout) * (gamma_prev * p["gamma_w"])
    out = (y - p["bn_mean"]) / jnp.sqrt(p["bn_var"] + BN_EPS) * p["bn_scale"] + p[
        "bn_bias"
    ]
    return out


def _act_codes(y, gamma_a):
    """Real values -> integer activation codes via the Pallas LSQ kernel
    (divide the quantized value back by gamma; exact because the kernel
    rounds to an integer multiple of gamma)."""
    q = lsq_quantize_kernel(y, gamma_a, 0.0, float(2**ACT_BITS - 1))
    return q / gamma_a


def forward_infer(params, x, wq_inner: int, k: int):
    """Bit-sliced inference forward: logits [B, 10].

    Must match ``forward_train(..., train=False)`` to float tolerance —
    property-tested in python/tests/test_model.py.
    """
    wq_inner = int(wq_inner)
    gamma_in = params["gamma_in"]
    codes = quantize_int(x, gamma_in, ACT_BITS, False)

    h_real = _qconv_infer(codes, gamma_in, params["conv1"], 8, 1, k)
    g = params["conv1"]["gamma_a"]
    h = _act_codes(h_real, g)

    b = params["block1"]
    y = _qconv_infer(h, g, b["conv1"], wq_inner, 1, k)
    y_codes = _act_codes(y, b["conv1"]["gamma_a"])
    y2 = _qconv_infer(y_codes, b["conv1"]["gamma_a"], b["conv2"], wq_inner, 1, k)
    h_real = y2 + h * g  # shortcut adds the real value of the block input
    g = b["conv2"]["gamma_a"]
    h = _act_codes(h_real, g)

    for name in ("block2", "block3"):
        b = params[name]
        y = _qconv_infer(h, g, b["conv1"], wq_inner, 2, k)
        y_codes = _act_codes(y, b["conv1"]["gamma_a"])
        y2 = _qconv_infer(y_codes, b["conv1"]["gamma_a"], b["conv2"], wq_inner, 1, k)
        sc = _qconv_infer(h, g, b["ds"], wq_inner, 2, k)
        h_real = y2 + sc
        g = b["conv2"]["gamma_a"]
        h = _act_codes(h_real, g)

    pooled = jnp.mean(h * g, axis=(1, 2))
    fc = params["fc"]
    p_codes = _act_codes(pooled, fc["gamma_a"])
    w_int = quantize_int(fc["w"], fc["gamma_w"], 8, True)
    planes = slice_signed_int(w_int, 8, k)
    logits_int = bitslice_matmul(p_codes, planes, k)
    logits = logits_int * (fc["gamma_a"] * fc["gamma_w"]) + fc["b"]
    return logits


@partial(jax.jit, static_argnums=(2, 3))
def forward_infer_jit(params, x, wq_inner: int, k: int):
    return forward_infer(params, x, wq_inner, k)


# ---------------------------------------------------------------------------
# (De)serialization — npz with '/'-joined keys
# ---------------------------------------------------------------------------


def flatten_params(params, prefix=""):
    out = {}
    for key, val in params.items():
        name = f"{prefix}{key}"
        if isinstance(val, dict):
            out.update(flatten_params(val, prefix=name + "/"))
        else:
            out[name] = val
    return out


def unflatten_params(flat):
    params: dict = {}
    for name, val in flat.items():
        node = params
        parts = name.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(val)
    return params


def save_params(path, params):
    import numpy as np

    np.savez(path, **{k: np.asarray(v) for k, v in flatten_params(params).items()})


def load_params(path):
    import numpy as np

    with np.load(path) as data:
        return unflatten_params({k: data[k] for k in data.files})
