"""Quantization-aware training on the synthetic shapes dataset.

Substitutes the paper's 30-epoch ImageNet LSQ QAT (DESIGN.md §4): same
quantization code path (Eq 5, STE, per-layer w_Q, 8-bit first/last layer),
scaled to a workload that trains in ~a minute on CPU. The accuracy
*ordering* across word-lengths (FP ≈ 4 > 2 >> 1) is the reproduction
target, recorded in EXPERIMENTS.md.

Usage:
  python -m compile.train_qat --wq 4 --steps 400 --out ../artifacts/params_w4.npz
  (wq 0 = FP32 baseline)
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import forward_train, init_params, save_params, update_bn


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(logp[jnp.arange(labels.shape[0]), labels])


def make_train_step(wq_inner: int, lr: float, momentum: float = 0.9):
    def loss_fn(params, x, y):
        logits, stats = forward_train(params, x, wq_inner, train=True)
        return cross_entropy(logits, y), stats

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    @jax.jit
    def step(params, velocity, x, y, lr_now):
        (loss, stats), grads = grad_fn(params, x, y)
        new_velocity = jax.tree_util.tree_map(
            lambda v, g: momentum * v - lr_now * g, velocity, grads
        )
        new_params = jax.tree_util.tree_map(
            lambda p, v: p + v, params, new_velocity
        )
        return new_params, new_velocity, loss, stats

    _ = lr
    return step


def evaluate(params, wq_inner: int, images, labels, batch: int = 200):
    """Top-1 accuracy with BN running stats (eval mode)."""
    correct = 0
    eval_fn = jax.jit(
        lambda p, x: forward_train(p, x, wq_inner, train=False)[0]
    )
    for i in range(0, len(images), batch):
        logits = eval_fn(params, images[i : i + batch])
        pred = jnp.argmax(logits, axis=-1)
        correct += int(jnp.sum(pred == labels[i : i + batch]))
    return correct / len(images)


def train(
    wq_inner: int,
    steps: int = 400,
    batch: int = 64,
    lr: float = 0.05,
    seed: int = 0,
    n_train_per_class: int = 300,
    n_test_per_class: int = 50,
    log_every: int = 50,
):
    """Run QAT; returns (params, test_accuracy, loss_log)."""
    (train_x, train_y), (test_x, test_y) = data.train_test_split(
        n_train_per_class, n_test_per_class, seed=seed
    )
    key = jax.random.PRNGKey(seed)
    params = init_params(key, wq_inner)
    velocity = jax.tree_util.tree_map(jnp.zeros_like, params)
    step_fn = make_train_step(wq_inner, lr)
    rng = np.random.default_rng(seed + 1)
    loss_log = []
    t0 = time.time()
    for i in range(steps):
        idx = rng.integers(0, len(train_x), size=batch)
        x = jnp.asarray(train_x[idx])
        y = jnp.asarray(train_y[idx].astype(np.int32))
        # cosine-ish two-phase schedule
        lr_now = lr if i < int(steps * 0.7) else lr * 0.1
        params, velocity, loss, stats = step_fn(params, velocity, x, y, lr_now)
        params = update_bn(params, stats)
        loss_log.append(float(loss))
        if log_every and (i + 1) % log_every == 0:
            print(
                f"  step {i + 1:4d}/{steps}  loss {float(loss):.4f}  "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )
    acc = evaluate(params, wq_inner, jnp.asarray(test_x), jnp.asarray(test_y))
    return params, acc, loss_log


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--wq", type=int, default=4, help="inner weight bits (0 = FP32)")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default=None, help="save params npz here")
    ap.add_argument("--loss-log", type=str, default=None, help="save loss curve (csv)")
    args = ap.parse_args()

    tag = "FP" if args.wq == 0 else f"w{args.wq}"
    print(f"QAT {tag}: {args.steps} steps, batch {args.batch}")
    params, acc, loss_log = train(
        args.wq, steps=args.steps, batch=args.batch, lr=args.lr, seed=args.seed
    )
    print(f"QAT {tag}: test top-1 accuracy = {acc * 100:.2f}%")
    if args.out:
        save_params(args.out, params)
        print(f"saved params to {args.out}")
    if args.loss_log:
        with open(args.loss_log, "w") as f:
            f.write("step,loss\n")
            for i, l in enumerate(loss_log):
                f.write(f"{i},{l}\n")
        print(f"saved loss curve to {args.loss_log}")


if __name__ == "__main__":
    main()
