"""AOT export: lower the bit-sliced inference model to HLO **text** and
write the artifact bundle the rust runtime consumes.

HLO text — not ``.serialize()`` — is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` crate) rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Outputs under --out-dir (default ../artifacts):
  resnet8_w{wq}_b{batch}.hlo.txt   per (wq, batch) variant
  params_w{wq}.npz                 trained parameters (inputs, kept for repro)
  testset.bin                      held-out eval set (rust TestSet format)
  manifest.json                    index of all of the above

Usage: cd python && python -m compile.aot [--wq 1,2,4,8] [--batches 1,8]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import data
from .model import forward_infer, init_params, load_params

# Canonical operand slice for the exported datapath. The numerical result
# is k-independent (property-tested); k=2 matches the paper's headline
# design (Table IV/V use the k=2 image for the flagship results).
EXPORT_K = 2

HW = data.HW
CHANNELS = data.CHANNELS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big literals as
    # `constant({...})`, which silently zeroes the baked weights after the
    # text round-trip (the rust parser accepts the placeholder!).
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def export_model(params, wq: int, batch: int, out_path: str) -> int:
    """Lower forward_infer closed over ``params`` at a fixed batch size.
    Returns the HLO text size in bytes."""

    def fn(x):
        return (forward_infer(params, x, wq, EXPORT_K),)

    spec = jax.ShapeDtypeStruct((batch, HW, HW, CHANNELS), jnp.float32)
    lowered = jax.jit(fn).lower(spec)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", type=str, default="../artifacts")
    ap.add_argument("--wq", type=str, default="1,2,4,8")
    ap.add_argument("--batches", type=str, default="1,8")
    ap.add_argument(
        "--random-params",
        action="store_true",
        help="export with fixed-seed random params when no trained npz exists",
    )
    ap.add_argument("--n-test-per-class", type=int, default=40)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    wqs = [int(w) for w in args.wq.split(",")]
    batches = [int(b) for b in args.batches.split(",")]

    models = []
    for wq in wqs:
        params_path = os.path.join(args.out_dir, f"params_w{wq}.npz")
        if os.path.exists(params_path):
            params = load_params(params_path)
            print(f"w{wq}: loaded trained params from {params_path}")
        elif args.random_params:
            params = init_params(jax.random.PRNGKey(7), wq)
            print(f"w{wq}: WARNING — using random params (no {params_path})")
        else:
            raise SystemExit(
                f"missing {params_path}; run train_qat first or pass --random-params"
            )
        for batch in batches:
            name = f"resnet8_w{wq}_b{batch}"
            path = f"{name}.hlo.txt"
            nbytes = export_model(params, wq, batch, os.path.join(args.out_dir, path))
            print(f"  exported {name}: {nbytes} bytes of HLO text")
            models.append(
                {
                    "name": name,
                    "path": path,
                    "wq": wq,
                    "batch": batch,
                    "input": [batch, HW, HW, CHANNELS],
                    "classes": data.N_CLASSES,
                }
            )

    # Held-out evaluation set (same generator family, disjoint seed).
    test_x, test_y = data.make_dataset(args.n_test_per_class, seed=10_000)
    ts_path = os.path.join(args.out_dir, "testset.bin")
    data.write_testset_bin(ts_path, test_x, test_y)
    print(f"wrote testset: {test_x.shape[0]} images -> {ts_path}")

    manifest = {
        "models": models,
        "testset": "testset.bin",
        "export_k": EXPORT_K,
        "generator": "python/compile/aot.py",
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(models)} models")


if __name__ == "__main__":
    main()
