"""Synthetic "shapes" dataset — the ImageNet substitution (DESIGN.md §4).

Ten procedurally generated 32x32x3 pattern classes with per-sample random
phase, orientation jitter, color and noise. Classifiable to high accuracy
by a small CNN but not linearly separable, which is what the QAT accuracy
ordering check (FP ≈ 4 bit > 2 bit >> 1 bit) needs.

Deterministic given the seed; the held-out split is exported to
``artifacts/testset.bin`` so the rust serving path measures real accuracy.
"""

import numpy as np

N_CLASSES = 10
HW = 32
CHANNELS = 3


def _grid():
    y, x = np.meshgrid(np.arange(HW), np.arange(HW), indexing="ij")
    return y.astype(np.float32), x.astype(np.float32)


def _pattern(cls: int, rng: np.random.Generator) -> np.ndarray:
    """One [HW, HW] grayscale pattern in [0, 1] for class ``cls``."""
    y, x = _grid()
    phase = rng.uniform(0, 2 * np.pi)
    freq = rng.uniform(0.55, 0.85)
    cy, cx = rng.uniform(10, 22, size=2)
    if cls == 0:  # horizontal stripes
        return 0.5 + 0.5 * np.sin(freq * y + phase)
    if cls == 1:  # vertical stripes
        return 0.5 + 0.5 * np.sin(freq * x + phase)
    if cls == 2:  # diagonal stripes
        return 0.5 + 0.5 * np.sin(freq * (x + y) / np.sqrt(2) + phase)
    if cls == 3:  # checkerboard
        return 0.5 + 0.5 * np.sin(freq * x + phase) * np.sin(freq * y + phase)
    if cls == 4:  # filled disk
        r = np.sqrt((y - cy) ** 2 + (x - cx) ** 2)
        return (r < rng.uniform(6, 10)).astype(np.float32)
    if cls == 5:  # square frame
        half = rng.uniform(7, 12)
        dy, dx = np.abs(y - cy), np.abs(x - cx)
        outer = np.maximum(dy, dx) < half
        inner = np.maximum(dy, dx) < half - 3
        return (outer & ~inner).astype(np.float32)
    if cls == 6:  # radial gradient
        r = np.sqrt((y - cy) ** 2 + (x - cx) ** 2)
        return np.clip(1.0 - r / 24.0, 0.0, 1.0)
    if cls == 7:  # linear gradient (random direction)
        ang = rng.uniform(0, 2 * np.pi)
        proj = (x * np.cos(ang) + y * np.sin(ang)) / HW
        return (proj - proj.min()) / max(float(np.ptp(proj)), 1e-6)
    if cls == 8:  # three gaussian blobs
        img = np.zeros((HW, HW), np.float32)
        for _ in range(3):
            by, bx = rng.uniform(4, 28, size=2)
            img += np.exp(-((y - by) ** 2 + (x - bx) ** 2) / (2 * 3.0**2))
        return np.clip(img, 0, 1)
    if cls == 9:  # cross
        wid = rng.uniform(1.5, 3.5)
        return ((np.abs(y - cy) < wid) | (np.abs(x - cx) < wid)).astype(np.float32)
    raise ValueError(f"class {cls} out of range")


def make_dataset(n_per_class: int, seed: int = 0, noise: float = 0.08):
    """Generate (images [N, 32, 32, 3] f32 in [0,1], labels [N] u8),
    shuffled deterministically."""
    rng = np.random.default_rng(seed)
    n = n_per_class * N_CLASSES
    images = np.zeros((n, HW, HW, CHANNELS), np.float32)
    labels = np.zeros(n, np.uint8)
    i = 0
    for cls in range(N_CLASSES):
        for _ in range(n_per_class):
            base = _pattern(cls, rng)
            color = rng.uniform(0.4, 1.0, size=CHANNELS).astype(np.float32)
            img = base[..., None] * color[None, None, :]
            img += rng.normal(0, noise, img.shape).astype(np.float32)
            images[i] = np.clip(img, 0.0, 1.0)
            labels[i] = cls
            i += 1
    perm = rng.permutation(n)
    return images[perm], labels[perm]


def train_test_split(n_train_per_class: int, n_test_per_class: int, seed: int = 0):
    """Disjoint train/test sets (different seeds => different samples)."""
    train = make_dataset(n_train_per_class, seed=seed)
    test = make_dataset(n_test_per_class, seed=seed + 10_000)
    return train, test


def write_testset_bin(path: str, images: np.ndarray, labels: np.ndarray):
    """Serialize in the rust ``TestSet`` format (see runtime/testset.rs):
    magic 'MPTS', u32 n/h/w/c, f32 images, u8 labels (little-endian)."""
    n, h, w, c = images.shape
    with open(path, "wb") as f:
        f.write(b"MPTS")
        for v in (n, h, w, c):
            f.write(np.uint32(v).tobytes())
        f.write(images.astype("<f4").tobytes())
        f.write(labels.astype(np.uint8).tobytes())
