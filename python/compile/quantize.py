"""LSQ quantization (paper Eq 5; Esser et al. [10]) and two's-complement
bit-slicing — the L2-side mirror of ``rust/src/quant/``.

Quantizer convention (paper §IV-C):
  activations: unsigned, Qn = 0,        Qp = 2^b - 1
  weights:     signed,   Qn = -2^{b-1}, Qp = 2^{b-1} - 1
  v_int   = round(clamp(v / gamma, Qn, Qp))
  v_quant = v_int * gamma

The straight-through estimator passes gradients through the round() and
clamp() per the LSQ paper (gradient w.r.t. gamma as in Esser et al. §3).
"""

from functools import partial

import jax
import jax.numpy as jnp


def qbounds(bits: int, signed: bool):
    """(Qn, Qp) clamp bounds for a ``bits``-wide quantizer."""
    if signed:
        return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    return 0, 2**bits - 1


def lsq_init_gamma(x, bits: int, signed: bool):
    """LSQ step-size initialization: gamma = 2 E[|x|] / sqrt(Qp).

    Qp is floored at 1 so the 1-bit signed case (Qp = 0, levels {-1, 0})
    still yields a finite positive step."""
    _, qp = qbounds(bits, signed)
    return jnp.maximum(2.0 * jnp.mean(jnp.abs(x)) / jnp.sqrt(float(max(qp, 1))), 1e-6)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def lsq_quantize(x, gamma, bits: int, signed: bool):
    """Fake-quantize ``x`` with trained step size ``gamma`` (Eq 5)."""
    qn, qp = qbounds(bits, signed)
    v = jnp.clip(x / gamma, qn, qp)
    return jnp.round(v) * gamma


def _lsq_fwd(x, gamma, bits, signed):
    return lsq_quantize(x, gamma, bits, signed), (x, gamma)


def _lsq_bwd(bits, signed, res, g):
    x, gamma = res
    qn, qp = qbounds(bits, signed)
    v = x / gamma
    inside = (v >= qn) & (v <= qp)
    # dL/dx: straight-through inside the clamp range.
    gx = g * inside.astype(g.dtype)
    # dL/dgamma (LSQ): -v + round(v) inside; Qn/Qp at the clamp rails.
    dgamma_elem = jnp.where(
        inside,
        jnp.round(v) - v,
        jnp.clip(v, qn, qp),
    )
    # LSQ gradient scale: 1/sqrt(numel * Qp) stabilizes training.
    scale = 1.0 / jnp.sqrt(float(x.size) * float(max(qp, 1)))
    ggamma = jnp.sum(g * dgamma_elem) * scale
    return gx, ggamma


lsq_quantize.defvjp(_lsq_fwd, _lsq_bwd)


def quantize_int(x, gamma, bits: int, signed: bool):
    """Integer codes (no STE; inference/export path)."""
    qn, qp = qbounds(bits, signed)
    return jnp.round(jnp.clip(x / gamma, qn, qp))


def slice_signed_int(w_int, wq: int, k: int):
    """Decompose integer-valued signed codes into ``ceil(wq/k)`` k-bit
    digits, least-significant first; the top digit is signed. Mirrors
    ``rust/src/quant/slicing.rs`` exactly.

    Works on float arrays carrying integers (export path) and on integer
    arrays (test path). Returns an array stacked on a new leading axis:
    ``[S, ...w_int.shape]`` with ``sum_s digits[s] * 2^(k s) == w_int``.
    """
    assert wq >= 1 and k >= 1
    n = -(-wq // k)  # ceil
    # Two's complement image in [0, 2^wq).
    u = jnp.where(w_int < 0, w_int + float(2**wq), w_int)
    digits = []
    for s in range(n):
        remaining = wq - s * k
        dbits = min(k, remaining)
        d = jnp.mod(jnp.floor(u / float(2 ** (s * k))), float(2**dbits))
        if s == n - 1:
            half = float(2 ** (dbits - 1))
            d = jnp.where(d >= half, d - float(2**dbits), d)
        digits.append(d)
    return jnp.stack(digits, axis=0)


def reconstruct_slices(digits, k: int):
    """Inverse of :func:`slice_signed_int`."""
    s = digits.shape[0]
    weights = jnp.array([2.0 ** (k * i) for i in range(s)], dtype=digits.dtype)
    return jnp.tensordot(weights, digits, axes=1)
