//! Held-out evaluation set exported by `python/compile/aot.py` as a flat
//! binary (`testset.bin`) so the rust serving path can measure real
//! classification accuracy without any python at runtime.
//!
//! Layout (little-endian):
//! `magic "MPTS"` · `u32 n` · `u32 h` · `u32 w` · `u32 c` ·
//! `n·h·w·c × f32` images · `n × u8` labels.

use crate::bail;
use crate::util::error::{Context, Result};
use std::path::Path;

#[derive(Clone, Debug)]
pub struct TestSet {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// All images, row-major `[n, h, w, c]`.
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
}

pub const MAGIC: &[u8; 4] = b"MPTS";

impl TestSet {
    pub fn load(path: impl AsRef<Path>) -> Result<TestSet> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_bytes(&bytes)
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<TestSet> {
        if bytes.len() < 20 || &bytes[0..4] != MAGIC {
            bail!("testset: bad magic");
        }
        let rd_u32 = |off: usize| -> u32 {
            u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap())
        };
        let n = rd_u32(4) as usize;
        let h = rd_u32(8) as usize;
        let w = rd_u32(12) as usize;
        let c = rd_u32(16) as usize;
        let img_len = n * h * w * c;
        let expect = 20 + img_len * 4 + n;
        if bytes.len() != expect {
            bail!(
                "testset: size mismatch (got {} bytes, want {expect} for n={n} {h}x{w}x{c})",
                bytes.len()
            );
        }
        let mut images = Vec::with_capacity(img_len);
        let mut off = 20;
        for _ in 0..img_len {
            images.push(f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
            off += 4;
        }
        let labels = bytes[off..off + n].to_vec();
        Ok(TestSet {
            n,
            h,
            w,
            c,
            images,
            labels,
        })
    }

    /// Serialize (used by tests and by rust-side dataset generation).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(20 + self.images.len() * 4 + self.n);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.n as u32).to_le_bytes());
        out.extend_from_slice(&(self.h as u32).to_le_bytes());
        out.extend_from_slice(&(self.w as u32).to_le_bytes());
        out.extend_from_slice(&(self.c as u32).to_le_bytes());
        for v in &self.images {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&self.labels);
        out
    }

    /// Image `i` as a slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let len = self.h * self.w * self.c;
        &self.images[i * len..(i + 1) * len]
    }

    pub fn image_len(&self) -> usize {
        self.h * self.w * self.c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TestSet {
        TestSet {
            n: 3,
            h: 2,
            w: 2,
            c: 1,
            images: (0..12).map(|i| i as f32 * 0.5).collect(),
            labels: vec![0, 1, 2],
        }
    }

    #[test]
    fn roundtrip() {
        let t = sample();
        let bytes = t.to_bytes();
        let u = TestSet::from_bytes(&bytes).unwrap();
        assert_eq!(u.n, 3);
        assert_eq!(u.images, t.images);
        assert_eq!(u.labels, t.labels);
        assert_eq!(u.image(1), &[2.0, 2.5, 3.0, 3.5]);
    }

    #[test]
    fn rejects_corruption() {
        let t = sample();
        let mut bytes = t.to_bytes();
        bytes[0] = b'X';
        assert!(TestSet::from_bytes(&bytes).is_err());
        let mut truncated = t.to_bytes();
        truncated.pop();
        assert!(TestSet::from_bytes(&truncated).is_err());
    }
}
