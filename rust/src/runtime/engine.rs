//! PJRT execution engine: one CPU client, one compiled executable per model
//! variant. Python never runs here — the HLO text under `artifacts/` is the
//! entire contract with L1/L2.
//!
//! The real engine needs the `xla` crate, which only exists in vendored
//! build environments; it is gated behind the `pjrt` cargo feature so the
//! default build stays dependency-free. Without the feature the same API is
//! exported but every constructor returns a descriptive error, and the
//! serving stack falls back to [`crate::serving::MockBackend`].

use super::manifest::{Manifest, ModelEntry};
use crate::util::error::Result;
use std::collections::BTreeMap;

/// Argmax over each row of a flattened `[rows, cols]` matrix.
///
/// NaN-safe total-order fold: a NaN logit never wins (any non-NaN value
/// displaces a NaN incumbent), ties keep the first index, and an all-NaN
/// row yields 0. The previous `partial_cmp(..).unwrap()` panicked on the
/// first NaN — inside the variant worker, that took the whole serving
/// pipeline down with it.
pub fn argmax_rows(flat: &[f32], cols: usize) -> Vec<usize> {
    flat.chunks(cols)
        .map(|row| {
            let mut best = 0usize;
            for (i, v) in row.iter().enumerate().skip(1) {
                if *v > row[best] || (row[best].is_nan() && !v.is_nan()) {
                    best = i;
                }
            }
            best
        })
        .collect()
}

#[cfg(feature = "pjrt")]
mod imp {
    use super::{argmax_rows, BTreeMap, Manifest, ModelEntry, Result};
    use crate::util::error::Context;
    use crate::{anyhow, bail};

    /// A compiled model ready to execute.
    pub struct LoadedModel {
        pub entry: ModelEntry,
        exe: xla::PjRtLoadedExecutable,
    }

    impl LoadedModel {
        /// Run one batch. `input` must have exactly `entry.input_len()`
        /// elements (shape `[batch, h, w, c]`, NHWC, f32). Returns flattened
        /// logits `[batch, classes]`.
        pub fn infer(&self, input: &[f32]) -> Result<Vec<f32>> {
            if input.len() != self.entry.input_len() {
                bail!(
                    "model {}: input has {} elements, expected {} ({:?})",
                    self.entry.name,
                    input.len(),
                    self.entry.input_len(),
                    self.entry.input_shape
                );
            }
            let dims: Vec<i64> = self.entry.input_shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(input)
                .reshape(&dims)
                .context("reshaping input literal")?;
            let result = self
                .exe
                .execute::<xla::Literal>(&[lit])
                .context("PJRT execute")?;
            let out = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            // aot.py lowers with return_tuple=True -> unwrap the 1-tuple.
            let out = out.to_tuple1().context("unwrapping result tuple")?;
            let logits = out.to_vec::<f32>().context("reading logits")?;
            let expect = self.entry.batch * self.entry.classes;
            if logits.len() != expect {
                bail!(
                    "model {}: got {} logits, expected {}",
                    self.entry.name,
                    logits.len(),
                    expect
                );
            }
            Ok(logits)
        }

        /// Argmax class per batch element.
        pub fn classify(&self, input: &[f32]) -> Result<Vec<usize>> {
            let logits = self.infer(input)?;
            Ok(argmax_rows(&logits, self.entry.classes))
        }
    }

    /// The engine: a PJRT CPU client plus the set of loaded model variants.
    pub struct Engine {
        client: xla::PjRtClient,
        pub manifest: Manifest,
        models: BTreeMap<String, LoadedModel>,
    }

    impl Engine {
        /// Create a client and load every model in the manifest directory.
        pub fn load_all(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
            let manifest = Manifest::load(&artifacts_dir)?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            let mut engine = Engine {
                client,
                manifest: manifest.clone(),
                models: BTreeMap::new(),
            };
            for entry in &manifest.models {
                engine.load(entry.clone())?;
            }
            Ok(engine)
        }

        /// Create a client without loading any models (lazy use).
        pub fn with_manifest(manifest: Manifest) -> Result<Engine> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Engine {
                client,
                manifest,
                models: BTreeMap::new(),
            })
        }

        /// Create a client and load only one word-length's model variants —
        /// what a per-variant serving worker needs, without compiling the
        /// whole family into every worker thread.
        pub fn load_wq(artifacts_dir: impl AsRef<std::path::Path>, wq: u32) -> Result<Engine> {
            let manifest = Manifest::load(&artifacts_dir)?;
            let entries: Vec<ModelEntry> =
                manifest.entries_for_wq(wq).into_iter().cloned().collect();
            if entries.is_empty() {
                bail!("no exported models for wq={wq}");
            }
            let mut engine = Engine::with_manifest(manifest)?;
            for entry in entries {
                engine.load(entry)?;
            }
            Ok(engine)
        }

        /// Compile one model variant from its HLO text.
        pub fn load(&mut self, entry: ModelEntry) -> Result<&LoadedModel> {
            let path = self.manifest.resolve(&entry.path);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", entry.name))?;
            let name = entry.name.clone();
            self.models.insert(name.clone(), LoadedModel { entry, exe });
            Ok(&self.models[&name])
        }

        pub fn get(&self, name: &str) -> Option<&LoadedModel> {
            self.models.get(name)
        }

        /// Model for (wq, batch), if exported.
        pub fn model_for(&self, wq: u32, batch: usize) -> Option<&LoadedModel> {
            self.manifest
                .find(wq, batch)
                .and_then(|e| self.models.get(&e.name))
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn loaded_names(&self) -> Vec<String> {
            self.models.keys().cloned().collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::{argmax_rows, BTreeMap, Manifest, ModelEntry, Result};
    use crate::bail;

    const NO_PJRT: &str = "mpcnn was built without the `pjrt` feature (the `xla` crate \
         is only available in vendored build environments); the PJRT engine \
         is unavailable — the serving stack falls back to the xmp sliced-digit \
         engine (`--backend xmp`, real integer arithmetic on synthetic \
         weights) or MockBackend (`--backend mock`), or rebuild with \
         --features pjrt";

    /// Stub of the compiled model; the API matches the `pjrt` build.
    pub struct LoadedModel {
        pub entry: ModelEntry,
    }

    impl LoadedModel {
        pub fn infer(&self, _input: &[f32]) -> Result<Vec<f32>> {
            bail!("{NO_PJRT}");
        }

        pub fn classify(&self, input: &[f32]) -> Result<Vec<usize>> {
            let logits = self.infer(input)?;
            Ok(argmax_rows(&logits, self.entry.classes))
        }
    }

    /// Stub engine: constructors fail with a descriptive error so callers
    /// (CLI `serve`/`classify`, benches) degrade gracefully.
    pub struct Engine {
        pub manifest: Manifest,
        models: BTreeMap<String, LoadedModel>,
    }

    impl Engine {
        pub fn load_all(_artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
            bail!("{NO_PJRT}");
        }

        pub fn with_manifest(_manifest: Manifest) -> Result<Engine> {
            bail!("{NO_PJRT}");
        }

        pub fn load_wq(_artifacts_dir: impl AsRef<std::path::Path>, _wq: u32) -> Result<Engine> {
            bail!("{NO_PJRT}");
        }

        pub fn load(&mut self, _entry: ModelEntry) -> Result<&LoadedModel> {
            bail!("{NO_PJRT}");
        }

        pub fn get(&self, name: &str) -> Option<&LoadedModel> {
            self.models.get(name)
        }

        pub fn model_for(&self, wq: u32, batch: usize) -> Option<&LoadedModel> {
            self.manifest
                .find(wq, batch)
                .and_then(|e| self.models.get(&e.name))
        }

        pub fn platform(&self) -> String {
            "none (pjrt feature disabled)".to_string()
        }

        pub fn loaded_names(&self) -> Vec<String> {
            self.models.keys().cloned().collect()
        }
    }
}

pub use imp::{Engine, LoadedModel};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_rows_basic() {
        let flat = vec![0.1, 0.9, 0.0, 0.5, 0.2, 0.3];
        assert_eq!(argmax_rows(&flat, 3), vec![1, 0]);
    }

    #[test]
    fn argmax_single_row() {
        assert_eq!(argmax_rows(&[1.0, 2.0, 3.0, 2.5], 4), vec![2]);
    }

    #[test]
    fn argmax_nan_never_panics_or_wins() {
        // Regression: these rows panicked the old partial_cmp unwrap.
        assert_eq!(argmax_rows(&[f32::NAN, 1.0, 2.0], 3), vec![2]);
        assert_eq!(argmax_rows(&[1.0, f32::NAN, 0.5], 3), vec![0]);
        // All-NaN row degrades to index 0 instead of crashing the worker.
        assert_eq!(argmax_rows(&[f32::NAN, f32::NAN], 2), vec![0]);
        // Mixed rows: each row independent.
        assert_eq!(
            argmax_rows(&[f32::NAN, 3.0, 0.0, 1.0, 9.0, f32::NAN], 3),
            vec![1, 1]
        );
        // Infinities still order normally.
        assert_eq!(
            argmax_rows(&[f32::NEG_INFINITY, f32::INFINITY, 0.0], 3),
            vec![1]
        );
    }

    #[test]
    fn argmax_ties_keep_first_index() {
        assert_eq!(argmax_rows(&[2.0, 2.0, 1.0], 3), vec![0]);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_reports_missing_feature() {
        let err = Engine::load_all("/nonexistent").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    // Engine tests that require a PJRT client + artifacts live in
    // rust/tests/integration_runtime.rs (they need `make artifacts`).
}
