//! Artifact manifest: `artifacts/manifest.json`, written by
//! `python/compile/aot.py`, read here. Lists every exported HLO module with
//! its geometry so the engine can validate inputs before touching PJRT.

use crate::util::json::{parse, Json};
use crate::util::error::{Context, Result};
use crate::{anyhow, bail};
use std::path::{Path, PathBuf};

/// One exported model variant.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelEntry {
    pub name: String,
    /// Path to the HLO text, relative to the manifest's directory.
    pub path: String,
    /// Inner-layer weight word-length this variant was trained/exported at.
    pub wq: u32,
    pub batch: usize,
    /// Input shape [batch, h, w, c].
    pub input_shape: Vec<usize>,
    pub classes: usize,
}

impl ModelEntry {
    pub fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub models: Vec<ModelEntry>,
    pub testset: Option<String>,
    /// Directory the manifest was loaded from (for resolving paths).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut m = Self::from_json_str(&text)?;
        m.dir = dir;
        Ok(m)
    }

    pub fn from_json_str(text: &str) -> Result<Manifest> {
        let j = parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let models_j = j
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing 'models' array"))?;
        let mut models = Vec::new();
        for mj in models_j {
            let get_str = |k: &str| -> Result<String> {
                mj.get(k)
                    .and_then(Json::as_str)
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("manifest model: missing '{k}'"))
            };
            let get_num = |k: &str| -> Result<u64> {
                mj.get(k)
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("manifest model: missing '{k}'"))
            };
            let input_shape: Vec<usize> = mj
                .get("input")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("manifest model: missing 'input'"))?
                .iter()
                .filter_map(|v| v.as_u64().map(|n| n as usize))
                .collect();
            if input_shape.len() != 4 {
                bail!("manifest model: 'input' must be [b,h,w,c]");
            }
            models.push(ModelEntry {
                name: get_str("name")?,
                path: get_str("path")?,
                wq: get_num("wq")? as u32,
                batch: get_num("batch")? as usize,
                input_shape,
                classes: get_num("classes")? as usize,
            });
        }
        let testset = j
            .get("testset")
            .and_then(Json::as_str)
            .map(str::to_string);
        Ok(Manifest {
            models,
            testset,
            dir: PathBuf::new(),
        })
    }

    /// Find a model by inner word-length and batch size.
    pub fn find(&self, wq: u32, batch: usize) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.wq == wq && m.batch == batch)
    }

    /// Every exported model for one word-length (all batch sizes), in
    /// manifest order.
    pub fn entries_for_wq(&self, wq: u32) -> Vec<&ModelEntry> {
        self.models.iter().filter(|m| m.wq == wq).collect()
    }

    /// All word-lengths available.
    pub fn wqs(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self.models.iter().map(|m| m.wq).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    pub fn resolve(&self, rel: &str) -> PathBuf {
        self.dir.join(rel)
    }
}

/// Default artifacts directory: `$MPCNN_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("MPCNN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "models": [
        {"name": "resnet8_w4_b1", "path": "resnet8_w4_b1.hlo.txt", "wq": 4,
         "batch": 1, "input": [1, 32, 32, 3], "classes": 10},
        {"name": "resnet8_w4_b8", "path": "resnet8_w4_b8.hlo.txt", "wq": 4,
         "batch": 8, "input": [8, 32, 32, 3], "classes": 10}
      ],
      "testset": "testset.bin"
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json_str(SAMPLE).unwrap();
        assert_eq!(m.models.len(), 2);
        assert_eq!(m.models[0].input_len(), 32 * 32 * 3);
        assert_eq!(m.testset.as_deref(), Some("testset.bin"));
        assert_eq!(m.find(4, 8).unwrap().name, "resnet8_w4_b8");
        assert!(m.find(2, 1).is_none());
        assert_eq!(m.wqs(), vec![4]);
        assert_eq!(m.entries_for_wq(4).len(), 2);
        assert!(m.entries_for_wq(2).is_empty());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::from_json_str("{}").is_err());
        assert!(Manifest::from_json_str(r#"{"models": [{"name": "x"}]}"#).is_err());
        let bad_shape = r#"{"models": [{"name":"x","path":"p","wq":4,"batch":1,
            "input":[32,32,3],"classes":10}]}"#;
        assert!(Manifest::from_json_str(bad_shape).is_err());
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(Manifest::load("/nonexistent-dir-xyz").is_err());
    }
}
