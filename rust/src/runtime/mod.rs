//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the CPU PJRT client from the rust hot path.
//!
//! Interchange is HLO **text** (not serialized `HloModuleProto`): jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly (see
//! /opt/xla-example/README.md and DESIGN.md §8).

pub mod engine;
pub mod manifest;
pub mod testset;

pub use engine::{argmax_rows, Engine, LoadedModel};
pub use manifest::{artifacts_dir, Manifest, ModelEntry};
pub use testset::TestSet;
