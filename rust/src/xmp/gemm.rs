//! Integer GEMM kernels over 2D-sliced operands — the engine's MAC
//! datapath.
//!
//! Operands: an im2col patch matrix at activation word-length `aq` (u8
//! activations widened to `i16` once at extraction, and — for the fast
//! path — lowered to `ceil(aq/k)` unsigned digit planes by
//! [`crate::xmp::pack::pack_activations`]) and one channel group's
//! weights at word-length `wq`. Output: exact `i64` accumulators,
//! `M × od` row-major, which the caller requantizes per channel. Three
//! kernels compute the same function:
//!
//! - [`gemm_codes_i64`] — ground truth: direct `Σ a·w`, no slicing.
//! - [`gemm_sliced_reference`] — the scalar reference: digits of *both*
//!   operands extracted on the fly with
//!   [`crate::quant::slicing::slice_digit`] /
//!   [`crate::quant::slicing::slice_digit_unsigned`] and shift-add
//!   recombined per MAC over the `S_a × S_w` slice cross-product at
//!   weight-shift + activation-shift — transparently the Fig 1b PPG +
//!   shifted adder tree generalized to the paper's 2D operand slicing,
//!   and the baseline `cargo bench --bench xmp` measures against.
//! - [`gemm_sliced_fast`] — the serving hot path: digit-plane-major
//!   packed operands on both sides, one tight `i32` dot product per
//!   `(s_a, s_w)` slice pair, scoped-thread fan-out over im2col rows.
//!
//! All three are property-tested bit-identical across every `(wq, aq, k)`
//! triple including partial top digits on both operands; the fast path's
//! `i32` partials are exact because [`crate::xmp::pack::max_kdim`] bounds
//! the reduction depth as a function of the actual digit magnitudes.

use super::pack::{max_kdim, PackedGroup, SlicedActs};
use crate::quant::slicing::{n_slices, slice_digit, slice_digit_unsigned};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Plain `i64` ground truth: direct `Σ a·w` per output element.
pub fn gemm_codes_i64(cols: &[i16], m: usize, kdim: usize, codes: &[i32], od: usize) -> Vec<i64> {
    assert_eq!(cols.len(), m * kdim);
    assert_eq!(codes.len(), od * kdim);
    let mut out = vec![0i64; m * od];
    for (row_out, a) in out.chunks_mut(od).zip(cols.chunks_exact(kdim)) {
        for (o, w) in row_out.iter_mut().zip(codes.chunks_exact(kdim)) {
            let mut acc = 0i64;
            for (&x, &c) in a.iter().zip(w) {
                acc += x as i64 * c as i64;
            }
            *o = acc;
        }
    }
    out
}

/// Scalar 2D-sliced reference kernel: for every MAC, decompose the
/// activation into `ceil(aq/k)` unsigned digits and the weight into
/// `ceil(wq/k)` signed digits on the fly, and accumulate each digit
/// pair's partial product at shift `k·(s_a + s_w)`. Single-threaded,
/// unpacked, allocation-free — slow, but the algebra is the module's
/// correctness anchor stated in code.
#[allow(clippy::too_many_arguments)]
pub fn gemm_sliced_reference(
    cols: &[i16],
    m: usize,
    kdim: usize,
    codes: &[i32],
    od: usize,
    wq: u32,
    aq: u32,
    k: u32,
) -> Vec<i64> {
    assert_eq!(cols.len(), m * kdim);
    assert_eq!(codes.len(), od * kdim);
    let sw = n_slices(wq, k);
    let sa = n_slices(aq, k);
    let mut out = vec![0i64; m * od];
    for (row_out, a) in out.chunks_mut(od).zip(cols.chunks_exact(kdim)) {
        for (o, w) in row_out.iter_mut().zip(codes.chunks_exact(kdim)) {
            let mut acc = 0i64;
            for (&x, &c) in a.iter().zip(w) {
                for ai in 0..sa {
                    let ad = slice_digit_unsigned(x as u64, aq, k, ai);
                    for si in 0..sw {
                        acc += (ad * slice_digit(c as i64, wq, k, si)) << (k * (ai + si));
                    }
                }
            }
            *o = acc;
        }
    }
    out
}

/// Number of xmp GEMMs currently fanning out threads: concurrent kernels
/// (one serving worker per hosted variant may be inside a GEMM at once)
/// split the machine instead of each grabbing `available_parallelism()` —
/// the same discipline as `array::search`.
static ACTIVE_GEMMS: AtomicUsize = AtomicUsize::new(0);

struct GemmSlot;

impl GemmSlot {
    fn acquire() -> (GemmSlot, usize) {
        let active = ACTIVE_GEMMS.fetch_add(1, Ordering::Relaxed) + 1;
        let avail = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        (GemmSlot, (avail / active).max(1))
    }
}

impl Drop for GemmSlot {
    fn drop(&mut self) {
        ACTIVE_GEMMS.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Inner loop of the fast path for one im2col row: per `(s_w, s_a)` slice
/// pair, a tight `i32` dot product between the weight plane's channel row
/// and the activation plane's row, recombined by shift-add at
/// `k·(s_w + s_a)`. Exact: the plane digits are `slice_signed`'s /
/// `slice_unsigned`'s, and the `i32` partials cannot overflow within
/// [`crate::xmp::pack::max_kdim`]`(wq, aq, k)`.
#[inline]
fn fast_row(a: &SlicedActs, row: usize, g: &PackedGroup, row_out: &mut [i64]) {
    let kdim = g.kdim;
    for (n, o) in row_out.iter_mut().enumerate() {
        let mut acc = 0i64;
        for (sw, wplane) in g.planes.iter().enumerate() {
            let wrow = &wplane[n * kdim..(n + 1) * kdim];
            for (sa, aplane) in a.planes.iter().enumerate() {
                let arow = &aplane[row * kdim..(row + 1) * kdim];
                let mut p = 0i32;
                for (&x, &d) in arow.iter().zip(wrow) {
                    p += x as i32 * d as i32;
                }
                acc += (p as i64) << (g.k as usize * (sw + sa));
            }
        }
        *o = acc;
    }
}

/// Fast path: digit-plane-major layout on both operands, `i32` partials
/// per slice pair, scoped-thread fan-out over im2col rows. Bit-identical
/// to [`gemm_sliced_reference`] — same digits, same exact integer
/// algebra; only the evaluation order and layout differ.
pub fn gemm_sliced_fast(a: &SlicedActs, g: &PackedGroup) -> Vec<i64> {
    assert_eq!(a.kdim, g.kdim, "operand reduction depths must agree");
    assert_eq!(
        a.k, g.k,
        "activation and weight planes must slice at the same digit width"
    );
    // The re-derived i32 partial-sum bound: a function of the actual
    // digit magnitudes (wq, aq, k), not the 8-bit worst case.
    assert!(
        g.kdim <= max_kdim(g.wq, a.aq, g.k),
        "reduction depth {} exceeds the i32 bound {} for (w{}, a{}, k{})",
        g.kdim,
        max_kdim(g.wq, a.aq, g.k),
        g.wq,
        a.aq,
        g.k
    );
    let m = a.m;
    let mut out = vec![0i64; m * g.od];
    if m == 0 || g.od == 0 {
        return out;
    }
    // Below this many digit-MACs, thread spawn/teardown rivals the kernel
    // itself (serving runs one GEMM per channel group per layer per image;
    // small-CNN groups are ~1M MACs and sub-millisecond) — stay inline.
    const MIN_WORK_TO_FAN_OUT: usize = 4_000_000;
    let work = m * g.kdim * g.od * g.planes.len() * a.planes.len();
    let (_slot, budget) = GemmSlot::acquire();
    let n_threads = budget.min(m).max(1);
    if n_threads == 1 || work < MIN_WORK_TO_FAN_OUT {
        for (row, row_out) in out.chunks_mut(g.od).enumerate() {
            fast_row(a, row, g, row_out);
        }
        return out;
    }
    let rows_per_chunk = m.div_ceil(n_threads);
    std::thread::scope(|sc| {
        for (ci, chunk) in out.chunks_mut(rows_per_chunk * g.od).enumerate() {
            sc.spawn(move || {
                let m0 = ci * rows_per_chunk;
                for (j, row_out) in chunk.chunks_mut(g.od).enumerate() {
                    fast_row(a, m0 + j, g, row_out);
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_eq, forall};
    use crate::util::rng::Rng;
    use crate::xmp::pack::{pack_activations, pack_group};
    use crate::xmp::Requant;

    #[allow(clippy::type_complexity)]
    fn random_case(rng: &mut Rng) -> (Vec<i16>, usize, usize, Vec<i32>, usize, u32, u32, u32) {
        let wq = 1 + rng.range(0, 8) as u32;
        let aq = 1 + rng.range(0, 8) as u32;
        let k = *rng.choose(&[1u32, 2, 3, 4, 5, 8]);
        let (m, kdim, od) = (1 + rng.range(0, 6), 1 + rng.range(0, 14), 1 + rng.range(0, 6));
        let amax = (1i64 << aq) - 1;
        let cols: Vec<i16> = (0..m * kdim).map(|_| rng.range_i64(0, amax) as i16).collect();
        let (lo, hi) = (-(1i64 << (wq - 1)), (1i64 << (wq - 1)) - 1);
        let codes: Vec<i32> = (0..od * kdim).map(|_| rng.range_i64(lo, hi) as i32).collect();
        (cols, m, kdim, codes, od, wq, aq, k)
    }

    #[test]
    fn prop_all_three_kernels_bit_identical() {
        // The module's anchor: plain i64 == on-the-fly 2D-sliced reference
        // == packed fast path, across every (wq, aq, k) incl. partial top
        // digits on BOTH operands.
        forall(800, |rng| {
            let (cols, m, kdim, codes, od, wq, aq, k) = random_case(rng);
            let plain = gemm_codes_i64(&cols, m, kdim, &codes, od);
            let refr = gemm_sliced_reference(&cols, m, kdim, &codes, od, wq, aq, k);
            check_eq(refr.clone(), plain.clone(), "reference vs plain i64")?;
            let g = pack_group(
                &codes,
                od,
                kdim,
                wq,
                k,
                vec![Requant::from_scale(0.5); od],
                vec![1.0; od],
            );
            let a = pack_activations(&cols, m, kdim, aq, k);
            let fast = gemm_sliced_fast(&a, &g);
            check_eq(fast, plain, "fast vs plain i64")
        });
    }

    #[test]
    fn aq8_reproduces_the_weight_only_datapath() {
        // With aq = 8 the 2D engine must be the same function as the old
        // weight-only-sliced engine was: the plain i64 truth is unchanged,
        // so bit-identity to it IS reproduction of every old result.
        let mut rng = Rng::new(0xA88);
        for _ in 0..50 {
            let (m, kdim, od) = (1 + rng.range(0, 5), 1 + rng.range(0, 12), 1 + rng.range(0, 5));
            let wq = 1 + rng.range(0, 8) as u32;
            let k = *rng.choose(&[1u32, 2, 3, 4, 8]);
            let cols: Vec<i16> =
                (0..m * kdim).map(|_| rng.range_i64(0, 255) as i16).collect();
            let (lo, hi) = (-(1i64 << (wq - 1)), (1i64 << (wq - 1)) - 1);
            let codes: Vec<i32> =
                (0..od * kdim).map(|_| rng.range_i64(lo, hi) as i32).collect();
            let plain = gemm_codes_i64(&cols, m, kdim, &codes, od);
            assert_eq!(gemm_sliced_reference(&cols, m, kdim, &codes, od, wq, 8, k), plain);
            let g = pack_group(&codes, od, kdim, wq, k,
                vec![Requant::from_scale(0.5); od], vec![1.0; od]);
            let a = pack_activations(&cols, m, kdim, 8, k);
            assert_eq!(gemm_sliced_fast(&a, &g), plain);
        }
    }

    #[test]
    fn fast_path_threads_agree_with_single_thread() {
        // Work above MIN_WORK_TO_FAN_OUT (512·128·32·3·4 ≈ 25M digit-MACs)
        // so the scoped fan-out engages on multi-core machines;
        // thread-count must not affect the bits.
        let mut rng = Rng::new(99);
        let (m, kdim, od, wq, aq, k) = (512usize, 128usize, 32usize, 5u32, 7u32, 2u32);
        let cols: Vec<i16> = (0..m * kdim).map(|_| rng.range_i64(0, 127) as i16).collect();
        let codes: Vec<i32> = (0..od * kdim).map(|_| rng.range_i64(-16, 15) as i32).collect();
        let g = pack_group(
            &codes,
            od,
            kdim,
            wq,
            k,
            vec![Requant::from_scale(0.5); od],
            vec![1.0; od],
        );
        let a = pack_activations(&cols, m, kdim, aq, k);
        let fast = gemm_sliced_fast(&a, &g);
        assert_eq!(fast, gemm_codes_i64(&cols, m, kdim, &codes, od));
    }

    #[test]
    fn known_tiny_gemm() {
        // 1x2 · 2x1: a = [3, 5], w = [-2, 1] -> -6 + 5 = -1, across 2D
        // slicings of both operands.
        let cols = vec![3i16, 5];
        let codes = vec![-2i32, 1];
        assert_eq!(gemm_codes_i64(&cols, 1, 2, &codes, 1), vec![-1]);
        for k in [1u32, 2, 3] {
            for aq in [3u32, 4, 8] {
                assert_eq!(
                    gemm_sliced_reference(&cols, 1, 2, &codes, 1, 3, aq, k),
                    vec![-1],
                    "aq={aq} k={k}"
                );
            }
        }
    }

    #[test]
    fn empty_dimensions_are_safe() {
        let g = pack_group(&[], 0, 4, 2, 2, vec![], vec![]);
        let a = pack_activations(&[], 0, 4, 8, 2);
        assert!(gemm_sliced_fast(&a, &g).is_empty());
    }
}
