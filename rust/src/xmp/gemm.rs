//! Integer GEMM kernels over 2D-sliced operands — the engine's MAC
//! datapath.
//!
//! Operands: an im2col patch matrix at activation word-length `aq` (u8
//! activations widened to `i16` once at extraction, and — for the fast
//! path — lowered to `ceil(aq/k)` unsigned digit planes by
//! [`crate::xmp::pack::pack_activations`]) and one channel group's
//! weights at word-length `wq`. Output: exact `i64` accumulators,
//! `M × od` row-major, which the caller requantizes per channel. Three
//! kernels compute the same function:
//!
//! - [`gemm_codes_i64`] — ground truth: direct `Σ a·w`, no slicing.
//! - [`gemm_sliced_reference`] — the scalar reference: digits of *both*
//!   operands extracted on the fly with
//!   [`crate::quant::slicing::slice_digit`] /
//!   [`crate::quant::slicing::slice_digit_unsigned`] and shift-add
//!   recombined per MAC over the `S_a × S_w` slice cross-product at
//!   weight-shift + activation-shift — transparently the Fig 1b PPG +
//!   shifted adder tree generalized to the paper's 2D operand slicing,
//!   and the baseline `cargo bench --bench xmp` measures against.
//! - [`gemm_sliced_fast`] — the serving hot path: digit-plane-major
//!   packed operands, lane-fused, register/cache-tiled and (optionally)
//!   SIMD. See below.
//!
//! ## The fast path
//!
//! Three independent mechanisms, each bit-exact:
//!
//! 1. **Lane fusion.** Adjacent digit planes fuse pairwise into planes of
//!    twice the digit width ([`crate::xmp::pack::fuse_plane_pairs`]:
//!    provably identical to re-slicing at `2k`), and the ladder keeps
//!    doubling the effective width `k_eff` while
//!    [`crate::xmp::pack::max_kdim`]`(wq, aq, 2·k_eff)` still admits the
//!    reduction depth — each rung quarters the `S_a × S_w` slice
//!    cross-product. ResNet-family depths (`kdim ≤ 4608`) sit far below
//!    every bound, so serving workloads typically fuse all the way to a
//!    single plane pair; Table-IV-style wide-digit/deep-reduction cells
//!    stay bound-limited and keep their slice cost (`benches/
//!    table4_operand_slices.rs` measures exactly this grid).
//! 2. **Register/cache tiling.** [`MR`]`×`[`NR`] output tiles accumulate
//!    in `i32` registers over the whole reduction (exact within the
//!    re-checked `max_kdim(wq, aq, k_eff)` bound), with the reduction cut
//!    into [`KC`]-lane blocks so a tile's working set stays L1-resident
//!    at any depth; row tiles are swept outermost so the activation rows
//!    stay hot across the whole channel sweep.
//! 3. **SIMD dot products.** The innermost dot is scalar by default, and
//!    AVX2 (`madd_epi16`) or NEON (`vmlal_s16`) when the crate is built
//!    with `--features simd` and [`crate::util::simd::level`] detects the
//!    hardware. Per-lane partials are bounded by `kdim/lanes · a_max ·
//!    w_max` — stricter than the scalar bound — so vector accumulation is
//!    exact wherever scalar accumulation is.
//!
//! All paths (fusion on/off × SIMD on/off × thread fan-out) are
//! property-tested bit-identical to the two oracle kernels across every
//! `(wq, aq, k)` triple including partial top digits on both operands and
//! tile-remainder shapes; [`gemm_sliced_fast_opts`] exposes the switches
//! so the differential net and the benches can pin each datapath.

use super::pack::{fuse_plane_pairs, max_kdim, PackedGroup, SlicedActs};
use crate::quant::slicing::{n_slices, slice_digit, slice_digit_unsigned};
use crate::util::simd::{self, SimdLevel};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Plain `i64` ground truth: direct `Σ a·w` per output element.
pub fn gemm_codes_i64(cols: &[i16], m: usize, kdim: usize, codes: &[i32], od: usize) -> Vec<i64> {
    assert_eq!(cols.len(), m * kdim);
    assert_eq!(codes.len(), od * kdim);
    let mut out = vec![0i64; m * od];
    for (row_out, a) in out.chunks_mut(od).zip(cols.chunks_exact(kdim)) {
        for (o, w) in row_out.iter_mut().zip(codes.chunks_exact(kdim)) {
            let mut acc = 0i64;
            for (&x, &c) in a.iter().zip(w) {
                acc += x as i64 * c as i64;
            }
            *o = acc;
        }
    }
    out
}

/// Scalar 2D-sliced reference kernel: for every MAC, decompose the
/// activation into `ceil(aq/k)` unsigned digits and the weight into
/// `ceil(wq/k)` signed digits on the fly, and accumulate each digit
/// pair's partial product at shift `k·(s_a + s_w)`. Single-threaded,
/// unpacked, allocation-free — slow, but the algebra is the module's
/// correctness anchor stated in code.
#[allow(clippy::too_many_arguments)]
pub fn gemm_sliced_reference(
    cols: &[i16],
    m: usize,
    kdim: usize,
    codes: &[i32],
    od: usize,
    wq: u32,
    aq: u32,
    k: u32,
) -> Vec<i64> {
    assert_eq!(cols.len(), m * kdim);
    assert_eq!(codes.len(), od * kdim);
    let sw = n_slices(wq, k);
    let sa = n_slices(aq, k);
    let mut out = vec![0i64; m * od];
    for (row_out, a) in out.chunks_mut(od).zip(cols.chunks_exact(kdim)) {
        for (o, w) in row_out.iter_mut().zip(codes.chunks_exact(kdim)) {
            let mut acc = 0i64;
            for (&x, &c) in a.iter().zip(w) {
                for ai in 0..sa {
                    let ad = slice_digit_unsigned(x as u64, aq, k, ai);
                    for si in 0..sw {
                        acc += (ad * slice_digit(c as i64, wq, k, si)) << (k * (ai + si));
                    }
                }
            }
            *o = acc;
        }
    }
    out
}

/// Number of xmp GEMMs currently fanning out threads: concurrent kernels
/// (one serving worker per hosted variant may be inside a GEMM at once)
/// split the machine instead of each grabbing `available_parallelism()` —
/// the same discipline as `array::search`.
static ACTIVE_GEMMS: AtomicUsize = AtomicUsize::new(0);

struct GemmSlot;

impl GemmSlot {
    fn acquire() -> (GemmSlot, usize) {
        let active = ACTIVE_GEMMS.fetch_add(1, Ordering::Relaxed) + 1;
        let avail = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        (GemmSlot, (avail / active).max(1))
    }
}

impl Drop for GemmSlot {
    fn drop(&mut self) {
        ACTIVE_GEMMS.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Register-tile rows (im2col rows per output tile) of the fast kernel.
pub const MR: usize = 4;
/// Register-tile columns (output channels per output tile).
pub const NR: usize = 4;
/// Cache block along the reduction dimension, in `i16` lanes: one tile's
/// live operands are `(MR + NR) · KC · 2` bytes = 8 KiB — L1-resident
/// however deep the layer's reduction is.
pub const KC: usize = 512;

/// Switches for [`gemm_sliced_fast_opts`]: the differential tests and the
/// benches pin each datapath (lane fusion on/off × SIMD on/off) and
/// assert bit-identity; serving uses [`FastOpts::default`] (both on).
#[derive(Clone, Copy, Debug)]
pub struct FastOpts {
    /// Fuse low-width slice pairs into wider digit lanes while
    /// [`max_kdim`] at the doubled width admits the reduction depth.
    pub fuse: bool,
    /// Use the runtime-detected SIMD level ([`crate::util::simd::level`]);
    /// `false` pins the scalar tiled dot product.
    pub simd: bool,
}

impl Default for FastOpts {
    fn default() -> FastOpts {
        FastOpts {
            fuse: true,
            simd: true,
        }
    }
}

/// The effective digit width the lane-fusion ladder reaches: starting
/// from the packed width `k`, keep doubling while either operand still
/// has more than one plane and the `i32` bound at the doubled width still
/// admits the reduction depth. Terminates because once `k_eff` covers
/// both word-lengths each operand is a single plane.
fn fused_width(wq: u32, aq: u32, k: u32, kdim: usize) -> u32 {
    let mut k_eff = k;
    while (n_slices(wq, k_eff) > 1 || n_slices(aq, k_eff) > 1)
        && kdim <= max_kdim(wq, aq, k_eff * 2)
    {
        k_eff *= 2;
    }
    k_eff
}

/// Run the fusion ladder from width `k` up to `target` (a power-of-two
/// multiple of `k` chosen by [`fused_width`]), one pairwise rung at a
/// time — each rung is exactly a re-slicing at the doubled width.
fn fuse_to(planes: &[Vec<i16>], k: u32, target: u32) -> Vec<Vec<i16>> {
    let mut out = fuse_plane_pairs(planes, k);
    let mut k_cur = k * 2;
    while k_cur < target {
        out = fuse_plane_pairs(&out, k_cur);
        k_cur *= 2;
    }
    out
}

/// Everything a worker needs to run the tiled kernel over its row range:
/// the (possibly fused) digit planes of both operands plus the shared
/// shape/dispatch parameters.
struct TileJob<'p> {
    aplanes: &'p [Vec<i16>],
    wplanes: &'p [Vec<i16>],
    k_eff: u32,
    kdim: usize,
    od: usize,
    level: SimdLevel,
}

/// Run every `(s_w, s_a)` plane pair's tiled GEMM over the rows of `out`
/// (a `rows × od` slab whose first row is global im2col row `r0`),
/// shift-adding each pair's `i32` tile accumulators into the `i64`
/// output at `k_eff·(s_w + s_a)`.
fn fast_block(job: &TileJob, r0: usize, out: &mut [i64]) {
    for (sw, wplane) in job.wplanes.iter().enumerate() {
        for (sa, aplane) in job.aplanes.iter().enumerate() {
            let sh = job.k_eff as usize * (sw + sa);
            pair_block(job, aplane, wplane, r0, sh, out);
        }
    }
}

/// One plane pair's register/cache-tiled GEMM: MR×NR output tiles
/// accumulated in `i32` over the whole reduction (exact within
/// [`max_kdim`]`(wq, aq, k_eff)`), the reduction cut into KC-lane cache
/// blocks. Row tiles are outermost so the MR activation rows stay hot
/// across the whole channel sweep.
fn pair_block(
    job: &TileJob,
    aplane: &[i16],
    wplane: &[i16],
    r0: usize,
    sh: usize,
    out: &mut [i64],
) {
    let (kdim, od) = (job.kdim, job.od);
    let rows = out.len() / od;
    let mut acc = [[0i32; NR]; MR];
    for rt in (0..rows).step_by(MR) {
        let mr = MR.min(rows - rt);
        for ct in (0..od).step_by(NR) {
            let nr = NR.min(od - ct);
            for row in acc.iter_mut() {
                *row = [0i32; NR];
            }
            for kb in (0..kdim).step_by(KC) {
                let kc = KC.min(kdim - kb);
                for (i, arow) in acc.iter_mut().take(mr).enumerate() {
                    let a = &aplane[(r0 + rt + i) * kdim + kb..][..kc];
                    for (j, cell) in arow.iter_mut().take(nr).enumerate() {
                        let w = &wplane[(ct + j) * kdim + kb..][..kc];
                        *cell += dot_i16(a, w, job.level);
                    }
                }
            }
            for (i, arow) in acc.iter().take(mr).enumerate() {
                let orow = &mut out[(rt + i) * od + ct..][..nr];
                for (o, &p) in orow.iter_mut().zip(arow.iter()) {
                    *o += (p as i64) << sh;
                }
            }
        }
    }
}

/// Innermost dot product between one activation row block and one weight
/// channel block — dispatched on the detected SIMD level. Every level is
/// bit-identical: the products are exact in `i32` and integer addition is
/// associative, so lane order cannot change the sum.
#[inline]
fn dot_i16(a: &[i16], w: &[i16], level: SimdLevel) -> i32 {
    match level {
        SimdLevel::Scalar => dot_scalar(a, w),
        SimdLevel::Avx2 => dot_avx2_or_scalar(a, w),
        SimdLevel::Neon => dot_neon_or_scalar(a, w),
    }
}

#[inline]
fn dot_scalar(a: &[i16], w: &[i16]) -> i32 {
    let mut p = 0i32;
    for (&x, &d) in a.iter().zip(w) {
        p += x as i32 * d as i32;
    }
    p
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn dot_avx2_or_scalar(a: &[i16], w: &[i16]) -> i32 {
    // Safety: `SimdLevel::Avx2` is only ever produced by
    // `util::simd::level()` after `is_x86_feature_detected!("avx2")`.
    unsafe { dot_avx2(a, w) }
}

#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
#[inline]
fn dot_avx2_or_scalar(a: &[i16], w: &[i16]) -> i32 {
    dot_scalar(a, w)
}

/// AVX2 dot product: 16 `i16` lanes per step. `madd_epi16` sums adjacent
/// `i16·i16` products into 8 `i32` lanes (exact: each pairwise sum is
/// `< 2·2^15·2^15 = 2^31`); each lane then accumulates `≤ kdim/8`
/// partials of magnitude `≤ a_max·w_max`, within the scalar bound that
/// [`max_kdim`] already enforces for the full `kdim`.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn dot_avx2(a: &[i16], w: &[i16]) -> i32 {
    use std::arch::x86_64::*;
    let mut acc = _mm256_setzero_si256();
    let mut ia = a.chunks_exact(16);
    let mut iw = w.chunks_exact(16);
    for (ca, cw) in (&mut ia).zip(&mut iw) {
        let av = _mm256_loadu_si256(ca.as_ptr() as *const __m256i);
        let wv = _mm256_loadu_si256(cw.as_ptr() as *const __m256i);
        acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, wv));
    }
    // Horizontal sum of the 8 i32 lanes.
    let s = _mm_add_epi32(_mm256_extracti128_si256(acc, 1), _mm256_castsi256_si128(acc));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b0100_1110));
    let s = _mm_add_epi32(s, _mm_shuffle_epi32(s, 0b1011_0001));
    let mut p = _mm_cvtsi128_si32(s);
    for (&x, &d) in ia.remainder().iter().zip(iw.remainder()) {
        p += x as i32 * d as i32;
    }
    p
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
#[inline]
fn dot_neon_or_scalar(a: &[i16], w: &[i16]) -> i32 {
    // Safety: NEON is baseline on aarch64 (std itself assumes it).
    unsafe { dot_neon(a, w) }
}

#[cfg(not(all(feature = "simd", target_arch = "aarch64")))]
#[inline]
fn dot_neon_or_scalar(a: &[i16], w: &[i16]) -> i32 {
    dot_scalar(a, w)
}

/// NEON dot product: 8 `i16` lanes per step via widening multiply-add
/// (`vmlal_s16`) on the low/high halves. Each of the 4 `i32` lanes
/// accumulates `≤ kdim/4` exact products, within the scalar bound.
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
#[target_feature(enable = "neon")]
unsafe fn dot_neon(a: &[i16], w: &[i16]) -> i32 {
    use std::arch::aarch64::*;
    let mut acc = vdupq_n_s32(0);
    let mut ia = a.chunks_exact(8);
    let mut iw = w.chunks_exact(8);
    for (ca, cw) in (&mut ia).zip(&mut iw) {
        let av = vld1q_s16(ca.as_ptr());
        let wv = vld1q_s16(cw.as_ptr());
        acc = vmlal_s16(acc, vget_low_s16(av), vget_low_s16(wv));
        acc = vmlal_high_s16(acc, av, wv);
    }
    let mut p = vaddvq_s32(acc);
    for (&x, &d) in ia.remainder().iter().zip(iw.remainder()) {
        p += x as i32 * d as i32;
    }
    p
}

/// Fast path with the default switches (lane fusion + SIMD on) — the
/// kernel [`crate::xmp::XmpBackend`] serves from.
pub fn gemm_sliced_fast(a: &SlicedActs, g: &PackedGroup) -> Vec<i64> {
    gemm_sliced_fast_opts(a, g, FastOpts::default())
}

/// Fast path with explicit datapath switches: digit-plane-major layout on
/// both operands, lane fusion to the widest bound-admitted digit width,
/// MR×NR/KC-tiled `i32` partials, SIMD inner dots, scoped-thread fan-out
/// over im2col rows. Bit-identical to [`gemm_sliced_reference`] under
/// every switch combination — same digits, same exact integer algebra;
/// only evaluation order and layout differ.
pub fn gemm_sliced_fast_opts(a: &SlicedActs, g: &PackedGroup, opts: FastOpts) -> Vec<i64> {
    assert_eq!(a.kdim, g.kdim, "operand reduction depths must agree");
    assert_eq!(
        a.k, g.k,
        "activation and weight planes must slice at the same digit width"
    );
    // The re-derived i32 partial-sum bound: a function of the actual
    // digit magnitudes (wq, aq, k), not the 8-bit worst case.
    assert!(
        g.kdim <= max_kdim(g.wq, a.aq, g.k),
        "reduction depth {} exceeds the i32 bound {} for (w{}, a{}, k{})",
        g.kdim,
        max_kdim(g.wq, a.aq, g.k),
        g.wq,
        a.aq,
        g.k
    );
    let m = a.m;
    let mut out = vec![0i64; m * g.od];
    if m == 0 || g.od == 0 {
        return out;
    }
    let level = if opts.simd {
        simd::level()
    } else {
        SimdLevel::Scalar
    };
    // Lane-fusion ladder: rebuild both operands' planes at the widest
    // bound-admitted digit width (skipped when that width is k itself).
    let target = if opts.fuse {
        fused_width(g.wq, a.aq, g.k, g.kdim)
    } else {
        g.k
    };
    let fused = if target > g.k {
        let w = fuse_to(&g.planes, g.k, target);
        let a2 = fuse_to(&a.planes, g.k, target);
        Some((w, a2))
    } else {
        None
    };
    let (wplanes, aplanes): (&[Vec<i16>], &[Vec<i16>]) = match &fused {
        Some((w, a2)) => (w, a2),
        None => (&g.planes, &a.planes),
    };
    let job = TileJob {
        aplanes,
        wplanes,
        k_eff: target,
        kdim: g.kdim,
        od: g.od,
        level,
    };
    // Below this many digit-MACs, thread spawn/teardown rivals the kernel
    // itself (serving runs one GEMM per channel group per layer per image;
    // small-CNN groups are ~1M MACs and sub-millisecond) — stay inline.
    const MIN_WORK_TO_FAN_OUT: usize = 4_000_000;
    let work = m * g.kdim * g.od * job.wplanes.len() * job.aplanes.len();
    let (_slot, budget) = GemmSlot::acquire();
    let n_threads = budget.min(m).max(1);
    if n_threads == 1 || work < MIN_WORK_TO_FAN_OUT {
        fast_block(&job, 0, &mut out);
        return out;
    }
    let rows_per_chunk = m.div_ceil(n_threads);
    let job = &job;
    std::thread::scope(|sc| {
        for (ci, chunk) in out.chunks_mut(rows_per_chunk * g.od).enumerate() {
            sc.spawn(move || {
                fast_block(job, ci * rows_per_chunk, chunk);
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_eq, forall};
    use crate::util::rng::Rng;
    use crate::xmp::pack::{pack_activations, pack_group};
    use crate::xmp::Requant;

    #[allow(clippy::type_complexity)]
    fn random_case(rng: &mut Rng) -> (Vec<i16>, usize, usize, Vec<i32>, usize, u32, u32, u32) {
        let wq = 1 + rng.range(0, 8) as u32;
        let aq = 1 + rng.range(0, 8) as u32;
        let k = *rng.choose(&[1u32, 2, 3, 4, 5, 8]);
        let (m, kdim, od) = (1 + rng.range(0, 6), 1 + rng.range(0, 14), 1 + rng.range(0, 6));
        let amax = (1i64 << aq) - 1;
        let cols: Vec<i16> = (0..m * kdim).map(|_| rng.range_i64(0, amax) as i16).collect();
        let (lo, hi) = (-(1i64 << (wq - 1)), (1i64 << (wq - 1)) - 1);
        let codes: Vec<i32> = (0..od * kdim).map(|_| rng.range_i64(lo, hi) as i32).collect();
        (cols, m, kdim, codes, od, wq, aq, k)
    }

    fn packed(codes: &[i32], od: usize, kdim: usize, wq: u32, k: u32) -> PackedGroup {
        pack_group(
            codes,
            od,
            kdim,
            wq,
            k,
            vec![Requant::from_scale(0.5); od],
            vec![1.0; od],
        )
    }

    /// Every switch combination of the fast path.
    fn opts_grid() -> [FastOpts; 4] {
        let mut grid = [FastOpts::default(); 4];
        let mut i = 0;
        for fuse in [true, false] {
            for simd in [true, false] {
                grid[i] = FastOpts { fuse, simd };
                i += 1;
            }
        }
        grid
    }

    #[test]
    fn prop_all_three_kernels_bit_identical() {
        // The module's anchor: plain i64 == on-the-fly 2D-sliced reference
        // == packed fast path, across every (wq, aq, k) incl. partial top
        // digits on BOTH operands.
        forall(800, |rng| {
            let (cols, m, kdim, codes, od, wq, aq, k) = random_case(rng);
            let plain = gemm_codes_i64(&cols, m, kdim, &codes, od);
            let refr = gemm_sliced_reference(&cols, m, kdim, &codes, od, wq, aq, k);
            check_eq(refr.clone(), plain.clone(), "reference vs plain i64")?;
            let g = packed(&codes, od, kdim, wq, k);
            let a = pack_activations(&cols, m, kdim, aq, k);
            let fast = gemm_sliced_fast(&a, &g);
            check_eq(fast, plain, "fast vs plain i64")
        });
    }

    #[test]
    fn prop_fusion_and_simd_switches_agree() {
        // The lane-fusion on/off × SIMD on/off agreement loop: all four
        // datapaths of the fast kernel are the same function as the plain
        // i64 oracle on random shapes.
        forall(300, |rng| {
            let (cols, m, kdim, codes, od, wq, aq, k) = random_case(rng);
            let plain = gemm_codes_i64(&cols, m, kdim, &codes, od);
            let g = packed(&codes, od, kdim, wq, k);
            let a = pack_activations(&cols, m, kdim, aq, k);
            for opts in opts_grid() {
                check_eq(
                    gemm_sliced_fast_opts(&a, &g, opts),
                    plain.clone(),
                    "fast datapath vs plain i64",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn adversarial_tile_remainder_shapes_are_bit_identical() {
        // M, od and kdim at tile boundaries ±1 (register tiles MR/NR, the
        // KC cache block, and the 8/16-lane SIMD widths), against word
        // lengths with partial top digits on both operands. Every fast
        // datapath must agree with the plain i64 oracle at every shape.
        let mut rng = Rng::new(0x7117);
        for (wq, aq, k) in [(8u32, 8u32, 8u32), (3, 5, 2), (5, 7, 2), (7, 3, 3)] {
            for m in [1usize, MR - 1, MR, MR + 1, 2 * MR + 1] {
                for od in [1usize, NR - 1, NR, NR + 1] {
                    for kdim in [1usize, 7, 8, 9, 15, 16, 17, KC - 1, KC, KC + 1] {
                        let amax = (1i64 << aq) - 1;
                        let cols: Vec<i16> =
                            (0..m * kdim).map(|_| rng.range_i64(0, amax) as i16).collect();
                        let (lo, hi) = (-(1i64 << (wq - 1)), (1i64 << (wq - 1)) - 1);
                        let codes: Vec<i32> =
                            (0..od * kdim).map(|_| rng.range_i64(lo, hi) as i32).collect();
                        let plain = gemm_codes_i64(&cols, m, kdim, &codes, od);
                        let g = packed(&codes, od, kdim, wq, k);
                        let a = pack_activations(&cols, m, kdim, aq, k);
                        for opts in opts_grid() {
                            assert_eq!(
                                gemm_sliced_fast_opts(&a, &g, opts),
                                plain,
                                "(w{wq} a{aq} k{k}) m={m} od={od} kdim={kdim} {opts:?}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn prop_tile_decomposition_matches_whole_matrix() {
        // Stitching row-strip × channel-group sub-GEMMs back together is
        // the whole GEMM: the tiled kernel may partition work any way it
        // likes without changing a bit.
        forall(150, |rng| {
            let (cols, m, kdim, codes, od, wq, aq, k) = random_case(rng);
            let a = pack_activations(&cols, m, kdim, aq, k);
            let g = packed(&codes, od, kdim, wq, k);
            let whole = gemm_sliced_fast(&a, &g);
            let rsplit = 1 + rng.range(0, m);
            let csplit = 1 + rng.range(0, od);
            let mut stitched = vec![0i64; m * od];
            for (r0, r1) in [(0, rsplit.min(m)), (rsplit.min(m), m)] {
                if r0 == r1 {
                    continue;
                }
                let mut sub_planes = Vec::with_capacity(a.planes.len());
                for p in &a.planes {
                    sub_planes.push(p[r0 * kdim..r1 * kdim].to_vec());
                }
                let sub_a = SlicedActs {
                    aq: a.aq,
                    k: a.k,
                    m: r1 - r0,
                    kdim,
                    planes: sub_planes,
                };
                for (c0, c1) in [(0, csplit.min(od)), (csplit.min(od), od)] {
                    if c0 == c1 {
                        continue;
                    }
                    let sub_g = packed(&codes[c0 * kdim..c1 * kdim], c1 - c0, kdim, wq, k);
                    let part = gemm_sliced_fast(&sub_a, &sub_g);
                    for r in r0..r1 {
                        for c in c0..c1 {
                            stitched[r * od + c] = part[(r - r0) * (c1 - c0) + (c - c0)];
                        }
                    }
                }
            }
            check_eq(stitched, whole, "stitched tiles vs whole-matrix GEMM")
        });
    }

    #[test]
    fn aq8_reproduces_the_weight_only_datapath() {
        // With aq = 8 the 2D engine must be the same function as the old
        // weight-only-sliced engine was: the plain i64 truth is unchanged,
        // so bit-identity to it IS reproduction of every old result.
        let mut rng = Rng::new(0xA88);
        for _ in 0..50 {
            let (m, kdim, od) = (1 + rng.range(0, 5), 1 + rng.range(0, 12), 1 + rng.range(0, 5));
            let wq = 1 + rng.range(0, 8) as u32;
            let k = *rng.choose(&[1u32, 2, 3, 4, 8]);
            let cols: Vec<i16> =
                (0..m * kdim).map(|_| rng.range_i64(0, 255) as i16).collect();
            let (lo, hi) = (-(1i64 << (wq - 1)), (1i64 << (wq - 1)) - 1);
            let codes: Vec<i32> =
                (0..od * kdim).map(|_| rng.range_i64(lo, hi) as i32).collect();
            let plain = gemm_codes_i64(&cols, m, kdim, &codes, od);
            assert_eq!(gemm_sliced_reference(&cols, m, kdim, &codes, od, wq, 8, k), plain);
            let g = packed(&codes, od, kdim, wq, k);
            let a = pack_activations(&cols, m, kdim, 8, k);
            assert_eq!(gemm_sliced_fast(&a, &g), plain);
        }
    }

    #[test]
    fn fast_path_threads_agree_with_single_thread() {
        // Enough post-fusion work (2048·128·64 ≈ 16.8M digit-MACs even
        // after the ladder collapses (w5, a7, k2) to one plane pair) that
        // the scoped fan-out engages on multi-core machines; thread count
        // must not affect the bits.
        let mut rng = Rng::new(99);
        let (m, kdim, od, wq, aq, k) = (2048usize, 128usize, 64usize, 5u32, 7u32, 2u32);
        let cols: Vec<i16> = (0..m * kdim).map(|_| rng.range_i64(0, 127) as i16).collect();
        let codes: Vec<i32> = (0..od * kdim).map(|_| rng.range_i64(-16, 15) as i32).collect();
        let g = packed(&codes, od, kdim, wq, k);
        let a = pack_activations(&cols, m, kdim, aq, k);
        let fast = gemm_sliced_fast(&a, &g);
        assert_eq!(fast, gemm_codes_i64(&cols, m, kdim, &codes, od));
    }

    #[test]
    fn fused_width_respects_the_bound_and_the_operands() {
        // ResNet depths fuse all the way to single planes; wide digits at
        // deep reductions stay bound-limited; single-plane operands never
        // widen at all.
        assert_eq!(fused_width(4, 8, 2, 576), 8); // resnet18 layer-1: full fuse
        assert_eq!(fused_width(8, 8, 8, 576), 8); // already single planes
        assert_eq!(fused_width(2, 2, 2, 576), 2); // nothing to fuse
        // Depth beyond max_kdim(2, 3, 2) forbids even the first rung...
        let deep = max_kdim(2, 3, 2) + 1;
        assert_eq!(fused_width(2, 3, 1, deep), 1);
        // ...while a shallow reduction takes it.
        assert_eq!(fused_width(2, 3, 1, 16), 4);
        // The reached width always admits the depth.
        let cases = [(4u32, 8u32, 2u32, 576usize), (8, 8, 1, 33_000), (5, 7, 2, 128)];
        for (wq, aq, k, kdim) in cases {
            let k_eff = fused_width(wq, aq, k, kdim);
            assert!(kdim <= max_kdim(wq, aq, k_eff), "(w{wq} a{aq} k{k}→{k_eff})");
        }
    }

    #[test]
    fn known_tiny_gemm() {
        // 1x2 · 2x1: a = [3, 5], w = [-2, 1] -> -6 + 5 = -1, across 2D
        // slicings of both operands.
        let cols = vec![3i16, 5];
        let codes = vec![-2i32, 1];
        assert_eq!(gemm_codes_i64(&cols, 1, 2, &codes, 1), vec![-1]);
        for k in [1u32, 2, 3] {
            for aq in [3u32, 4, 8] {
                assert_eq!(
                    gemm_sliced_reference(&cols, 1, 2, &codes, 1, 3, aq, k),
                    vec![-1],
                    "aq={aq} k={k}"
                );
                let g = packed(&codes, 1, 2, 3, k);
                let a = pack_activations(&cols, 1, 2, aq, k);
                for opts in OPTS_GRID {
                    assert_eq!(gemm_sliced_fast_opts(&a, &g, opts), vec![-1], "aq={aq} k={k}");
                }
            }
        }
    }

    #[test]
    fn empty_dimensions_are_safe() {
        let g = pack_group(&[], 0, 4, 2, 2, vec![], vec![]);
        let a = pack_activations(&[], 0, 4, 8, 2);
        assert!(gemm_sliced_fast(&a, &g).is_empty());
    }
}
