//! Integer GEMM kernels over sliced-digit operands — the engine's MAC
//! datapath.
//!
//! Operands: an im2col patch matrix `cols` (`M × kdim`, u8 activations
//! widened to `i16` once at extraction) and one channel group's weights.
//! Output: exact `i64` accumulators, `M × od` row-major, which the caller
//! requantizes per channel. Three kernels compute the same function:
//!
//! - [`gemm_codes_i64`] — ground truth: direct `Σ a·w`, no slicing.
//! - [`gemm_sliced_reference`] — the scalar reference: digits extracted
//!   on the fly with [`crate::quant::slicing::slice_digit`] and shift-add
//!   recombined per MAC; transparently the Fig 1b PPG + shifted adder
//!   tree, and the baseline `cargo bench --bench xmp` measures against.
//! - [`gemm_sliced_fast`] — the serving hot path: digit-plane-major
//!   packed weights, `i32` per-slice partial accumulators, scoped-thread
//!   fan-out over im2col rows.
//!
//! All three are property-tested bit-identical across every
//! `(wq, k)` pair including partial top digits; the fast path's `i32`
//! partials are exact because [`crate::xmp::pack::MAX_KDIM`] bounds the
//! reduction depth.

use super::pack::PackedGroup;
use crate::quant::slicing::{n_slices, slice_digit};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Plain `i64` ground truth: direct `Σ a·w` per output element.
pub fn gemm_codes_i64(cols: &[i16], m: usize, kdim: usize, codes: &[i32], od: usize) -> Vec<i64> {
    assert_eq!(cols.len(), m * kdim);
    assert_eq!(codes.len(), od * kdim);
    let mut out = vec![0i64; m * od];
    for (row_out, a) in out.chunks_mut(od).zip(cols.chunks_exact(kdim)) {
        for (o, w) in row_out.iter_mut().zip(codes.chunks_exact(kdim)) {
            let mut acc = 0i64;
            for (&x, &c) in a.iter().zip(w) {
                acc += x as i64 * c as i64;
            }
            *o = acc;
        }
    }
    out
}

/// Scalar sliced reference kernel: for every MAC, decompose the weight
/// into `ceil(wq/k)` digits on the fly and accumulate each digit's
/// partial product at its shift weight. Single-threaded, unpacked,
/// allocation-free — slow, but the algebra is the module's correctness
/// anchor stated in code.
pub fn gemm_sliced_reference(
    cols: &[i16],
    m: usize,
    kdim: usize,
    codes: &[i32],
    od: usize,
    wq: u32,
    k: u32,
) -> Vec<i64> {
    assert_eq!(cols.len(), m * kdim);
    assert_eq!(codes.len(), od * kdim);
    let s = n_slices(wq, k);
    let mut out = vec![0i64; m * od];
    for (row_out, a) in out.chunks_mut(od).zip(cols.chunks_exact(kdim)) {
        for (o, w) in row_out.iter_mut().zip(codes.chunks_exact(kdim)) {
            let mut acc = 0i64;
            for (&x, &c) in a.iter().zip(w) {
                for si in 0..s {
                    acc += (x as i64 * slice_digit(c as i64, wq, k, si)) << (k * si);
                }
            }
            *o = acc;
        }
    }
    out
}

/// Number of xmp GEMMs currently fanning out threads: concurrent kernels
/// (one serving worker per hosted variant may be inside a GEMM at once)
/// split the machine instead of each grabbing `available_parallelism()` —
/// the same discipline as `array::search`.
static ACTIVE_GEMMS: AtomicUsize = AtomicUsize::new(0);

struct GemmSlot;

impl GemmSlot {
    fn acquire() -> (GemmSlot, usize) {
        let active = ACTIVE_GEMMS.fetch_add(1, Ordering::Relaxed) + 1;
        let avail = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        (GemmSlot, (avail / active).max(1))
    }
}

impl Drop for GemmSlot {
    fn drop(&mut self) {
        ACTIVE_GEMMS.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Inner loop of the fast path for one im2col row: per slice, a tight
/// `i32` dot product over the digit plane's channel row, recombined by
/// shift-add. Exact: the plane digits are `slice_signed`'s and the `i32`
/// partials cannot overflow within [`crate::xmp::pack::MAX_KDIM`].
#[inline]
fn fast_row(a: &[i16], g: &PackedGroup, row_out: &mut [i64]) {
    let kdim = g.kdim;
    for (n, o) in row_out.iter_mut().enumerate() {
        let mut acc = 0i64;
        for (si, plane) in g.planes.iter().enumerate() {
            let wrow = &plane[n * kdim..(n + 1) * kdim];
            let mut p = 0i32;
            for (&x, &d) in a.iter().zip(wrow) {
                p += x as i32 * d as i32;
            }
            acc += (p as i64) << (g.k as usize * si);
        }
        *o = acc;
    }
}

/// Fast path: digit-plane-major layout, `i32` per-slice partials,
/// scoped-thread fan-out over im2col rows. Bit-identical to
/// [`gemm_sliced_reference`] — same digits, same exact integer algebra;
/// only the evaluation order and layout differ.
pub fn gemm_sliced_fast(cols: &[i16], m: usize, g: &PackedGroup) -> Vec<i64> {
    assert_eq!(cols.len(), m * g.kdim);
    debug_assert!(g.kdim <= super::pack::MAX_KDIM);
    let mut out = vec![0i64; m * g.od];
    if m == 0 || g.od == 0 {
        return out;
    }
    // Below this many digit-MACs, thread spawn/teardown rivals the kernel
    // itself (serving runs one GEMM per channel group per layer per image;
    // small-CNN groups are ~1M MACs and sub-millisecond) — stay inline.
    const MIN_WORK_TO_FAN_OUT: usize = 4_000_000;
    let work = m * g.kdim * g.od * g.planes.len();
    let (_slot, budget) = GemmSlot::acquire();
    let n_threads = budget.min(m).max(1);
    if n_threads == 1 || work < MIN_WORK_TO_FAN_OUT {
        for (row_out, a) in out.chunks_mut(g.od).zip(cols.chunks_exact(g.kdim)) {
            fast_row(a, g, row_out);
        }
        return out;
    }
    let rows_per_chunk = m.div_ceil(n_threads);
    std::thread::scope(|sc| {
        for (ci, chunk) in out.chunks_mut(rows_per_chunk * g.od).enumerate() {
            sc.spawn(move || {
                let m0 = ci * rows_per_chunk;
                for (j, row_out) in chunk.chunks_mut(g.od).enumerate() {
                    let a = &cols[(m0 + j) * g.kdim..(m0 + j + 1) * g.kdim];
                    fast_row(a, g, row_out);
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_eq, forall};
    use crate::util::rng::Rng;
    use crate::xmp::pack::pack_group;
    use crate::xmp::Requant;

    fn random_case(rng: &mut Rng) -> (Vec<i16>, usize, usize, Vec<i32>, usize, u32, u32) {
        let wq = *rng.choose(&[1u32, 2, 3, 4, 5, 6, 7, 8]);
        let k = *rng.choose(&[1u32, 2, 3, 4, 5, 8]);
        let (m, kdim, od) = (1 + rng.range(0, 6), 1 + rng.range(0, 14), 1 + rng.range(0, 6));
        let cols: Vec<i16> = (0..m * kdim).map(|_| rng.range_i64(0, 255) as i16).collect();
        let (lo, hi) = (-(1i64 << (wq - 1)), (1i64 << (wq - 1)) - 1);
        let codes: Vec<i32> = (0..od * kdim).map(|_| rng.range_i64(lo, hi) as i32).collect();
        (cols, m, kdim, codes, od, wq, k)
    }

    #[test]
    fn prop_all_three_kernels_bit_identical() {
        // The module's anchor: plain i64 == on-the-fly sliced reference ==
        // packed fast path, across every (wq, k) incl. partial top digits.
        forall(800, |rng| {
            let (cols, m, kdim, codes, od, wq, k) = random_case(rng);
            let plain = gemm_codes_i64(&cols, m, kdim, &codes, od);
            let refr = gemm_sliced_reference(&cols, m, kdim, &codes, od, wq, k);
            check_eq(refr.clone(), plain.clone(), "reference vs plain i64")?;
            let g = pack_group(
                &codes,
                od,
                kdim,
                wq,
                k,
                vec![Requant::from_scale(0.5); od],
                vec![1.0; od],
            );
            let fast = gemm_sliced_fast(&cols, m, &g);
            check_eq(fast, plain, "fast vs plain i64")
        });
    }

    #[test]
    fn fast_path_threads_agree_with_single_thread() {
        // Work above MIN_WORK_TO_FAN_OUT (512·128·32·3 ≈ 6.3M digit-MACs)
        // so the scoped fan-out engages on multi-core machines;
        // thread-count must not affect the bits.
        let mut rng = Rng::new(99);
        let (m, kdim, od, wq, k) = (512usize, 128usize, 32usize, 5u32, 2u32);
        let cols: Vec<i16> = (0..m * kdim).map(|_| rng.range_i64(0, 255) as i16).collect();
        let codes: Vec<i32> = (0..od * kdim).map(|_| rng.range_i64(-16, 15) as i32).collect();
        let g = pack_group(
            &codes,
            od,
            kdim,
            wq,
            k,
            vec![Requant::from_scale(0.5); od],
            vec![1.0; od],
        );
        let fast = gemm_sliced_fast(&cols, m, &g);
        assert_eq!(fast, gemm_codes_i64(&cols, m, kdim, &codes, od));
    }

    #[test]
    fn known_tiny_gemm() {
        // 1x2 · 2x1: a = [3, 5], w = [-2, 1] -> -6 + 5 = -1, across slicings.
        let cols = vec![3i16, 5];
        let codes = vec![-2i32, 1];
        assert_eq!(gemm_codes_i64(&cols, 1, 2, &codes, 1), vec![-1]);
        for k in [1u32, 2, 3] {
            assert_eq!(
                gemm_sliced_reference(&cols, 1, 2, &codes, 1, 3, k),
                vec![-1],
                "k={k}"
            );
        }
    }

    #[test]
    fn empty_dimensions_are_safe() {
        let g = pack_group(&[], 0, 4, 2, 2, vec![], vec![]);
        assert!(gemm_sliced_fast(&[], 0, &g).is_empty());
    }
}
