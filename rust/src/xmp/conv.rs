//! im2col + channel-grouped convolution forward.
//!
//! Layers execute as `im2col` (SAME padding, NHWC, one extraction shared
//! by every channel group) followed by one 2D-sliced GEMM per group and a
//! per-channel integer requantize into the next activation map. The
//! groups are where the mixed precision is *truly* mixed: each runs at
//! its own weight word-length `wq` with its own `ceil(wq/k)` digit
//! planes, while the layer's input activations — at the *producer's*
//! activation word-length `a_in` — are sliced once into `ceil(a_in/k)`
//! unsigned digit planes shared across all groups. Group outputs
//! interleave back into one NHWC map at the layer's channel offsets — no
//! per-group sub-layer dispatch, no reconfiguration, exactly the
//! on-the-fly word-length switching the paper's PE performs, now on both
//! MAC operands.

use super::gemm::{gemm_codes_i64, gemm_sliced_fast, gemm_sliced_reference};
use super::pack::{pack_activations, PackedLayer, SlicedActs};
use super::XmpLayer;
use crate::obs::StageTimes;
use std::time::Instant;

/// SAME-padding geometry: `(output size, leading pad)` for a square
/// `ih`-pixel map under a `k`-wide kernel at stride `s`. Matches
/// [`crate::cnn::Layer::oh`] (`ceil(ih/s)`) and the TF/JAX "SAME" rule
/// the exported models use (`pad_total = (oh-1)·s + k - ih`, split
/// low-first).
pub fn same_pad(ih: u32, k: u32, s: u32) -> (u32, u32) {
    let oh = ih.div_ceil(s);
    let pad_total = ((oh - 1) * s + k).saturating_sub(ih);
    (oh, pad_total / 2)
}

/// im2col over an NHWC u8 activation map: returns the `(M = oh², kdim =
/// k²·iw)` patch matrix in `i16` (widened once here so the GEMM inner
/// loops multiply `i16` lanes directly), plus `(m, kdim)`. Out-of-map
/// taps are zero (the pre-zeroed buffer is simply skipped over).
pub fn im2col(input: &[u8], ih: u32, iw: u32, k: u32, s: u32) -> (Vec<i16>, usize, usize) {
    assert_eq!(input.len(), (ih * ih * iw) as usize, "input must be ih²·iw");
    let (oh, pad) = same_pad(ih, k, s);
    let kdim = (k * k * iw) as usize;
    let m = (oh * oh) as usize;
    let mut cols = vec![0i16; m * kdim];
    let ih_i = ih as i64;
    let cs = iw as usize;
    let mut pos = 0usize;
    for oy in 0..oh {
        for ox in 0..oh {
            for ky in 0..k {
                let iy = (oy * s + ky) as i64 - pad as i64;
                for kx in 0..k {
                    let ix = (ox * s + kx) as i64 - pad as i64;
                    if (0..ih_i).contains(&iy) && (0..ih_i).contains(&ix) {
                        let base = (iy as usize * ih as usize + ix as usize) * cs;
                        for &v in &input[base..base + cs] {
                            cols[pos] = v as i16;
                            pos += 1;
                        }
                    } else {
                        pos += cs; // zero padding
                    }
                }
            }
        }
    }
    debug_assert_eq!(pos, m * kdim);
    (cols, m, kdim)
}

/// Activation digit planes per digit width, built lazily so one im2col
/// extraction feeds every channel group: groups share planes when they
/// slice at the same `k` (the common case — `k` is an engine-wide knob).
struct ActPlaneCache<'a> {
    cols: &'a [i16],
    m: usize,
    kdim: usize,
    a_in: u32,
    built: Vec<SlicedActs>,
}

impl<'a> ActPlaneCache<'a> {
    fn new(cols: &'a [i16], m: usize, kdim: usize, a_in: u32) -> ActPlaneCache<'a> {
        ActPlaneCache { cols, m, kdim, a_in, built: Vec::new() }
    }

    fn for_k(&mut self, k: u32) -> &SlicedActs {
        if let Some(i) = self.built.iter().position(|a| a.k == k) {
            return &self.built[i];
        }
        self.built
            .push(pack_activations(self.cols, self.m, self.kdim, self.a_in, k));
        self.built.last().unwrap()
    }
}

/// im2col over a whole batch of NHWC maps: each image's patch matrix,
/// stacked row-major into one `(batch·oh², kdim)` operand so the batch
/// shares one activation slicing and ONE GEMM per channel group. Row
/// `b·oh² + r` is row `r` of image `b`'s own im2col; GEMM output rows
/// depend only on their own input row, so batching cannot change any
/// image's bits.
pub fn im2col_batch(
    inputs: &[u8],
    batch: usize,
    ih: u32,
    iw: u32,
    k: u32,
    s: u32,
) -> (Vec<i16>, usize, usize) {
    let img = (ih * ih * iw) as usize;
    assert_eq!(inputs.len(), batch * img, "inputs must be batch·ih²·iw");
    let (oh, _) = same_pad(ih, k, s);
    let kdim = (k * k * iw) as usize;
    let m1 = (oh * oh) as usize;
    let mut cols = Vec::with_capacity(batch * m1 * kdim);
    for image in inputs.chunks_exact(img) {
        let (c, m, kd) = im2col(image, ih, iw, k, s);
        debug_assert_eq!((m, kd), (m1, kdim));
        cols.extend_from_slice(&c);
    }
    (cols, batch * m1, kdim)
}

/// One conv layer forward: im2col once, slice the activations once per
/// digit width, then one 2D-sliced GEMM per channel group (`fast` picks
/// the digit-plane fast path or the scalar reference kernel), per-channel
/// requantization into the NHWC u8 output. `a_in` is the word-length of
/// the *input* activations (every value `< 2^a_in` — the producer layer's
/// requantizer guarantees it); the output is clamped to the layer's own
/// `2^aq − 1` by the requantizers.
pub fn conv_forward(
    input: &[u8],
    a_in: u32,
    l: &XmpLayer,
    pl: &PackedLayer,
    fast: bool,
) -> Vec<u8> {
    conv_forward_batch_profiled(input, 1, a_in, l, pl, fast, None)
}

/// [`conv_forward`] over a batch of images in one pass: one batched
/// im2col, one activation digit-plane slicing per digit width, and one
/// GEMM per channel group for the whole batch — the batch-level operand
/// reuse the serving path runs on. Output is the per-image outputs
/// concatenated, bit-identical to calling [`conv_forward`] per image.
pub fn conv_forward_batch(
    inputs: &[u8],
    batch: usize,
    a_in: u32,
    l: &XmpLayer,
    pl: &PackedLayer,
    fast: bool,
) -> Vec<u8> {
    conv_forward_batch_profiled(inputs, batch, a_in, l, pl, fast, None)
}

/// Advance the stage clock: charge the time since the last lap to one
/// [`StageTimes`] field. A `None` sink keeps the hot path clock-free.
fn lap(
    prof: &mut Option<&mut StageTimes>,
    mark: &mut Option<Instant>,
    add: impl FnOnce(&mut StageTimes, f64),
) {
    if let (Some(p), Some(m)) = (prof.as_deref_mut(), mark.as_mut()) {
        let now = Instant::now();
        add(p, now.duration_since(*m).as_secs_f64() * 1e6);
        *m = now;
    }
}

/// [`conv_forward`] with a per-stage timing sink: im2col, activation
/// digit-plane packing (fast path only — the reference kernel extracts
/// digits on the fly, so its slicing time is charged to the GEMM), the
/// sliced GEMM itself, and requantize. The computed output is bit-for-bit
/// the unprofiled one; a `None` sink takes no clock readings at all.
pub fn conv_forward_profiled(
    input: &[u8],
    a_in: u32,
    l: &XmpLayer,
    pl: &PackedLayer,
    fast: bool,
    prof: Option<&mut StageTimes>,
) -> Vec<u8> {
    conv_forward_batch_profiled(input, 1, a_in, l, pl, fast, prof)
}

/// The one conv implementation everything above delegates to:
/// [`conv_forward_batch`] with an optional per-stage timing sink.
pub fn conv_forward_batch_profiled(
    inputs: &[u8],
    batch: usize,
    a_in: u32,
    l: &XmpLayer,
    pl: &PackedLayer,
    fast: bool,
    mut prof: Option<&mut StageTimes>,
) -> Vec<u8> {
    let mut mark = prof.as_ref().map(|_| Instant::now());
    let (cols, m, kdim) = im2col_batch(inputs, batch, l.ih, l.iw, l.k, l.s);
    lap(&mut prof, &mut mark, |p, us| p.im2col_us += us);
    debug_assert_eq!(kdim, l.kdim());
    let od = l.od as usize;
    let mut out = vec![0u8; m * od];
    let mut acts = ActPlaneCache::new(&cols, m, kdim, a_in);
    let mut base = 0usize;
    for (g, pg) in l.groups.iter().zip(&pl.groups) {
        let accs = if fast {
            let sliced = acts.for_k(pg.k);
            lap(&mut prof, &mut mark, |p, us| p.pack_us += us);
            gemm_sliced_fast(sliced, pg)
        } else {
            gemm_sliced_reference(&cols, m, kdim, &g.codes, pg.od, pg.wq, a_in, pg.k)
        };
        lap(&mut prof, &mut mark, |p, us| p.gemm_us += us);
        for (row_out, row_acc) in out.chunks_mut(od).zip(accs.chunks_exact(pg.od)) {
            let slots = row_out[base..base + pg.od].iter_mut();
            for ((o, r), &acc) in slots.zip(&pg.requant).zip(row_acc) {
                *o = r.apply(acc);
            }
        }
        lap(&mut prof, &mut mark, |p, us| p.requant_us += us);
        base += pg.od;
    }
    out
}

/// Ground-truth conv for the property tests: plain `i64` MACs straight
/// from the integer codes (no slicing on either operand) plus the same
/// per-channel requantize. The 2D-sliced kernels must reproduce this
/// bit-for-bit at every `(wq, aq, k)`.
pub fn conv_forward_i64(input: &[u8], l: &XmpLayer) -> Vec<u8> {
    let (cols, m, kdim) = im2col(input, l.ih, l.iw, l.k, l.s);
    let od = l.od as usize;
    let mut out = vec![0u8; m * od];
    let mut base = 0usize;
    for g in &l.groups {
        let god = g.od as usize;
        let accs = gemm_codes_i64(&cols, m, kdim, &g.codes, god);
        for (row_out, row_acc) in out.chunks_mut(od).zip(accs.chunks_exact(god)) {
            let slots = row_out[base..base + god].iter_mut();
            for ((o, r), &acc) in slots.zip(&g.requant).zip(row_acc) {
                *o = r.apply(acc);
            }
        }
        base += god;
    }
    out
}

/// Batched plain-i64 oracle: image-by-image [`conv_forward_i64`], outputs
/// concatenated. Deliberately does NO cross-image reuse — it is the
/// definition the batched sliced paths must reproduce bit-for-bit.
pub fn conv_forward_i64_batch(inputs: &[u8], batch: usize, l: &XmpLayer) -> Vec<u8> {
    let img = (l.ih * l.ih * l.iw) as usize;
    assert_eq!(inputs.len(), batch * img, "inputs must be batch·ih²·iw");
    let mut out = Vec::with_capacity(batch * img);
    for image in inputs.chunks_exact(img) {
        out.extend_from_slice(&conv_forward_i64(image, l));
    }
    out
}

/// The FC head through the same 2D-sliced kernels (`M = 1`): pooled u8
/// features (at word-length `a_in`) in, `f32` logits out via the
/// per-class dequant scale.
pub fn fc_logits(pooled: &[u8], a_in: u32, l: &XmpLayer, pl: &PackedLayer, fast: bool) -> Vec<f32> {
    fc_logits_batch(pooled, 1, a_in, l, pl, fast)
}

/// Batched FC head (`M = batch`): pooled feature rows in, `batch × od`
/// logit rows out — one sliced GEMM per channel group for the whole
/// batch, each group's classes written at their offsets exactly like the
/// conv channel interleave. Bit-identical to per-image [`fc_logits`].
pub fn fc_logits_batch(
    pooled: &[u8],
    batch: usize,
    a_in: u32,
    l: &XmpLayer,
    pl: &PackedLayer,
    fast: bool,
) -> Vec<f32> {
    assert!(
        batch > 0 && pooled.len() % batch == 0,
        "pooled features must be whole batch rows"
    );
    let kdim = pooled.len() / batch;
    let cols: Vec<i16> = pooled.iter().map(|&v| v as i16).collect();
    let od = l.od as usize;
    let mut logits = vec![0f32; batch * od];
    let mut acts = ActPlaneCache::new(&cols, batch, kdim, a_in);
    let mut base = 0usize;
    for (g, pg) in l.groups.iter().zip(&pl.groups) {
        let accs = if fast {
            gemm_sliced_fast(acts.for_k(pg.k), pg)
        } else {
            gemm_sliced_reference(&cols, batch, kdim, &g.codes, pg.od, pg.wq, a_in, pg.k)
        };
        for (row_out, row_acc) in logits.chunks_mut(od).zip(accs.chunks_exact(pg.od)) {
            let slots = row_out[base..base + pg.od].iter_mut();
            for ((o, &acc), &scale) in slots.zip(row_acc).zip(&pg.scales) {
                *o = acc as f32 * scale;
            }
        }
        base += pg.od;
    }
    logits
}

/// Plain-i64 FC head (ground truth): direct MACs from the codes, same
/// per-class dequantization.
pub fn fc_logits_i64(pooled: &[u8], l: &XmpLayer) -> Vec<f32> {
    let cols: Vec<i16> = pooled.iter().map(|&v| v as i16).collect();
    let kdim = pooled.len();
    let mut logits = Vec::with_capacity(l.od as usize);
    for g in &l.groups {
        let accs = gemm_codes_i64(&cols, 1, kdim, &g.codes, g.od as usize);
        for (&acc, &scale) in accs.iter().zip(&g.scales) {
            logits.push(acc as f32 * scale);
        }
    }
    logits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_pad_geometry() {
        // 3x3/1 on 32: out 32, pad 1. 3x3/2 on 32: out 16, pad 0 (SAME
        // puts the single pad pixel at the end). 1x1/1: no pad.
        assert_eq!(same_pad(32, 3, 1), (32, 1));
        assert_eq!(same_pad(32, 3, 2), (16, 0));
        assert_eq!(same_pad(32, 1, 1), (32, 0));
        assert_eq!(same_pad(7, 3, 2), (4, 1));
        // 7x7/2 on 224 (ResNet conv1): out 112, pad_total 5, leading 2.
        assert_eq!(same_pad(224, 7, 2), (112, 2));
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1/1 im2col is the identity layout (pixels-major, channels
        // inner — exactly the NHWC input).
        let input: Vec<u8> = (0u8..12).collect(); // 2x2 map, 3 channels
        let (cols, m, kdim) = im2col(&input, 2, 3, 1, 1);
        assert_eq!((m, kdim), (4, 3));
        assert_eq!(cols, input.iter().map(|&v| v as i16).collect::<Vec<i16>>());
    }

    #[test]
    fn im2col_pads_with_zeros() {
        // 3x3 kernel on a 1x1 single-channel map: only the center tap is
        // real; the 8 surrounding taps are padding.
        let (cols, m, kdim) = im2col(&[7u8], 1, 1, 3, 1);
        assert_eq!((m, kdim), (1, 9));
        assert_eq!(cols.iter().filter(|&&v| v != 0).count(), 1);
        assert_eq!(cols[4], 7); // center of the 3x3 patch
    }

    #[test]
    fn conv_identity_weights_pass_through() {
        // 1x1 conv, single channel, weight code 1, requant scale 1 (mult
        // 2^shift / 2^shift): output == input, at every input precision
        // wide enough for the values.
        let requant = crate::xmp::Requant { mult: 256, shift: 8, qmax: 255 };
        let l = XmpLayer {
            name: "id".into(),
            kind: crate::cnn::LayerKind::Conv,
            ih: 3,
            iw: 1,
            od: 1,
            k: 1,
            s: 1,
            aq: 8,
            groups: vec![crate::xmp::GroupWeights {
                wq: 2,
                od: 1,
                codes: vec![1],
                requant: vec![requant],
                scales: vec![1.0],
            }],
        };
        let pl = PackedLayer {
            groups: vec![crate::xmp::pack::pack_group(
                &[1],
                1,
                1,
                2,
                2,
                vec![requant],
                vec![1.0],
            )],
        };
        let input: Vec<u8> = vec![0, 50, 100, 150, 200, 250, 3, 9, 27];
        assert_eq!(conv_forward(&input, 8, &l, &pl, true), input);
        assert_eq!(conv_forward(&input, 8, &l, &pl, false), input);
        assert_eq!(conv_forward_i64(&input, &l), input);
        // A narrower input precision must still pass narrow values through.
        let narrow: Vec<u8> = vec![0, 1, 2, 3, 4, 5, 6, 7, 8];
        assert_eq!(conv_forward(&narrow, 4, &l, &pl, true), narrow);
        assert_eq!(conv_forward(&narrow, 4, &l, &pl, false), narrow);
    }

    #[test]
    fn profiled_conv_matches_unprofiled_and_fills_stages() {
        let requant = crate::xmp::Requant { mult: 256, shift: 8, qmax: 255 };
        let l = XmpLayer {
            name: "id".into(),
            kind: crate::cnn::LayerKind::Conv,
            ih: 3,
            iw: 1,
            od: 1,
            k: 1,
            s: 1,
            aq: 8,
            groups: vec![crate::xmp::GroupWeights {
                wq: 2,
                od: 1,
                codes: vec![1],
                requant: vec![requant],
                scales: vec![1.0],
            }],
        };
        let pl = PackedLayer {
            groups: vec![crate::xmp::pack::pack_group(
                &[1],
                1,
                1,
                2,
                2,
                vec![requant],
                vec![1.0],
            )],
        };
        let input: Vec<u8> = vec![0, 50, 100, 150, 200, 250, 3, 9, 27];
        let mut st = StageTimes::default();
        let out = conv_forward_profiled(&input, 8, &l, &pl, true, Some(&mut st));
        assert_eq!(out, conv_forward(&input, 8, &l, &pl, true), "profiling changed the math");
        assert!(st.total_us() > 0.0, "stages must accumulate wall time");
        // The reference kernel slices on the fly: no packing stage.
        let mut st_ref = StageTimes::default();
        let out_ref = conv_forward_profiled(&input, 8, &l, &pl, false, Some(&mut st_ref));
        assert_eq!(out_ref, out);
        assert_eq!(st_ref.pack_us, 0.0, "reference path has no pack stage");
    }

    #[test]
    fn batched_conv_matches_per_image_loops() {
        // The batched forward is one big GEMM over stacked im2col rows:
        // its output must be the per-image outputs concatenated, on every
        // kernel path.
        let requant = crate::xmp::Requant { mult: 256, shift: 8, qmax: 255 };
        let l = XmpLayer {
            name: "id".into(),
            kind: crate::cnn::LayerKind::Conv,
            ih: 3,
            iw: 1,
            od: 1,
            k: 3,
            s: 1,
            aq: 8,
            groups: vec![crate::xmp::GroupWeights {
                wq: 4,
                od: 1,
                codes: vec![0, 1, 0, -2, 3, 1, 0, -1, 0],
                requant: vec![requant],
                scales: vec![1.0],
            }],
        };
        let pl = PackedLayer {
            groups: vec![crate::xmp::pack::pack_group(
                &l.groups[0].codes,
                1,
                9,
                4,
                2,
                vec![requant],
                vec![1.0],
            )],
        };
        let inputs: Vec<u8> = (0u8..27).map(|i| i.wrapping_mul(9)).collect();
        for fast in [true, false] {
            let mut per_image = Vec::new();
            for image in inputs.chunks_exact(9) {
                per_image.extend_from_slice(&conv_forward(image, 8, &l, &pl, fast));
            }
            let batched = conv_forward_batch(&inputs, 3, 8, &l, &pl, fast);
            assert_eq!(batched, per_image, "fast={fast}");
        }
        assert_eq!(
            conv_forward_i64_batch(&inputs, 3, &l),
            conv_forward_batch(&inputs, 3, 8, &l, &pl, true)
        );
    }

    #[test]
    fn batched_fc_matches_per_row_loops() {
        let l = XmpLayer {
            name: "fc".into(),
            kind: crate::cnn::LayerKind::Fc,
            ih: 1,
            iw: 4,
            od: 2,
            k: 1,
            s: 1,
            aq: 8,
            groups: vec![crate::xmp::GroupWeights {
                wq: 4,
                od: 2,
                codes: vec![1, -2, 3, -4, 5, -6, 7, 7],
                requant: vec![crate::xmp::Requant { mult: 256, shift: 8, qmax: 255 }; 2],
                scales: vec![0.5, -0.25],
            }],
        };
        let pl = PackedLayer {
            groups: vec![crate::xmp::pack::pack_group(
                &l.groups[0].codes,
                2,
                4,
                4,
                2,
                l.groups[0].requant.clone(),
                l.groups[0].scales.clone(),
            )],
        };
        let pooled: Vec<u8> = vec![3, 0, 255, 17, 9, 8, 7, 6, 1, 2, 3, 4];
        for fast in [true, false] {
            let mut per_row = Vec::new();
            for row in pooled.chunks_exact(4) {
                per_row.extend_from_slice(&fc_logits(row, 8, &l, &pl, fast));
            }
            let batched = fc_logits_batch(&pooled, 3, 8, &l, &pl, fast);
            assert_eq!(batched, per_row, "fast={fast}");
        }
    }

    #[test]
    fn requant_clamps_to_the_layer_aq() {
        // aq = 4: outputs clamp to 2^4 - 1 = 15, not 255.
        let requant = crate::xmp::Requant { mult: 256, shift: 8, qmax: 15 };
        let l = XmpLayer {
            name: "clamp".into(),
            kind: crate::cnn::LayerKind::Conv,
            ih: 2,
            iw: 1,
            od: 1,
            k: 1,
            s: 1,
            aq: 4,
            groups: vec![crate::xmp::GroupWeights {
                wq: 2,
                od: 1,
                codes: vec![1],
                requant: vec![requant],
                scales: vec![1.0],
            }],
        };
        let pl = PackedLayer {
            groups: vec![crate::xmp::pack::pack_group(
                &[1],
                1,
                1,
                2,
                2,
                vec![requant],
                vec![1.0],
            )],
        };
        let input: Vec<u8> = vec![0, 9, 15, 200];
        let want: Vec<u8> = vec![0, 9, 15, 15];
        assert_eq!(conv_forward(&input, 8, &l, &pl, true), want);
        assert_eq!(conv_forward(&input, 8, &l, &pl, false), want);
        assert_eq!(conv_forward_i64(&input, &l), want);
    }
}
