//! [`XmpBackend`] — the xmp engine behind the serving gateway's
//! [`InferenceBackend`] trait: real sliced-digit arithmetic where the
//! gateway previously fell back to mock logits.
//!
//! The backend owns one [`XmpModel`] (typically synthetic LSQ weights via
//! [`XmpBackend::from_spec`] when no trained artifacts exist); `warmup`
//! pre-packs the digit planes and verifies the fast path against the
//! scalar reference on a probe image before the variant is announced
//! ready. Any batch size executes unpadded and unsplit
//! (`supports_batch(n) == true` for all `n ≥ 1`) — the engine is
//! size-flexible, unlike compiled PJRT executables.

use super::pack::{pack_model, PackedModel};
use super::{KernelPath, XmpConfig, XmpModel};
use crate::cnn::Cnn;
use crate::obs::ModelProfile;
use crate::runtime::argmax_rows;
use crate::serving::{BackendHealth, InferenceBackend, VariantSpec};
use crate::util::error::Result;
use std::cell::OnceCell;
use std::sync::atomic::{AtomicU32, Ordering};

/// Consecutive `infer_batch` errors after which [`XmpBackend::health`]
/// self-reports `Unavailable` (mirrors the worker's own error threshold);
/// a single error already reports `Degraded`. Any success resets the
/// streak back to `Healthy`.
const ERRORS_TO_UNAVAILABLE: u32 = 3;

/// A truly-mixed-precision execution backend for one served variant.
pub struct XmpBackend {
    model: XmpModel,
    packed: OnceCell<PackedModel>,
    fast: bool,
    /// Error streak feeding `health()`; fresh backends start `Healthy`.
    consecutive_errors: AtomicU32,
}

impl XmpBackend {
    /// Wrap an existing model (weights already quantized).
    pub fn new(model: XmpModel) -> XmpBackend {
        XmpBackend {
            model,
            packed: OnceCell::new(),
            fast: true,
            consecutive_errors: AtomicU32::new(0),
        }
    }

    /// Build a synthetic-weight backend serving `spec`'s quantization of
    /// `base` — what `--backend xmp` and the planner's family server use
    /// when no trained artifacts exist. Honors the spec's joint `(wq, aq)`
    /// plan: weights at the per-layer channel groups, activations at the
    /// per-layer word-lengths. Deterministic in `(base, spec, cfg)`: two
    /// independently built copies (e.g. a worker backend and a local
    /// ground-truth probe) agree bit-for-bit.
    pub fn from_spec(base: &Cnn, spec: &VariantSpec, cfg: XmpConfig) -> Result<XmpBackend> {
        let plan = spec.per_layer_plan(base);
        let aq = spec.per_layer_aq(base);
        Ok(XmpBackend::new(XmpModel::synthetic_joint(base, &plan, &aq, cfg)?))
    }

    /// Route every layer through the scalar sliced reference kernel
    /// instead of the fast path (cross-checks, tests).
    pub fn reference_kernels(mut self) -> XmpBackend {
        self.fast = false;
        self
    }

    pub fn model(&self) -> &XmpModel {
        &self.model
    }

    fn packed(&self) -> &PackedModel {
        self.packed.get_or_init(|| pack_model(&self.model))
    }

    /// Argmax class of one image — the local ground-truth probe
    /// `mpcnn serve --backend xmp` checks routed responses against.
    pub fn classify_one(&self, image: &[f32]) -> Result<usize> {
        let logits = self.model.forward(self.packed(), image, self.fast)?;
        let cols = logits.len().max(1);
        Ok(argmax_rows(&logits, cols).first().copied().unwrap_or(0))
    }

    /// Run one image with per-layer profiling: measured host time and
    /// kernel stage split for every layer, logits bit-identical to the
    /// unprofiled forward. Join the modeled FPGA cycles afterwards with
    /// [`ModelProfile::attach_sim`] for the measured-vs-virtual report.
    pub fn profile_forward(&self, image: &[f32]) -> Result<(Vec<f32>, ModelProfile)> {
        let mut prof = ModelProfile::default();
        let path = if self.fast { KernelPath::Fast } else { KernelPath::Reference };
        let logits = self
            .model
            .forward_profiled(self.packed(), image, path, Some(&mut prof))?;
        Ok((logits, prof))
    }

    fn infer_batch_inner(&self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        if images.len() != batch * self.image_len() {
            crate::bail!(
                "xmp: bad input length {} for batch {batch} (image_len {})",
                images.len(),
                self.image_len()
            );
        }
        // One batched forward: every layer's im2col and digit-plane
        // packing runs once for the whole batch, and each GEMM sees
        // `batch` times the rows. Bit-identical to a per-image loop
        // (pinned by `infer_batch_layout_and_determinism` and the
        // forward_batch property test).
        let path = if self.fast { KernelPath::Fast } else { KernelPath::Reference };
        let logits = self.model.forward_batch(self.packed(), images, batch, path)?;
        if logits.len() != batch * self.classes() {
            crate::bail!(
                "xmp: model '{}' produced {} logits, expected {} x {}",
                self.model.name,
                logits.len(),
                batch,
                self.classes()
            );
        }
        Ok(logits)
    }
}

impl InferenceBackend for XmpBackend {
    fn batch_sizes(&self) -> Vec<usize> {
        vec![1]
    }

    /// The engine runs any batch unpadded: the batcher never splits or
    /// zero-fills for this backend.
    fn supports_batch(&self, n: usize) -> bool {
        n >= 1
    }

    fn image_len(&self) -> usize {
        self.model.image_len()
    }

    fn classes(&self) -> usize {
        self.model.classes as usize
    }

    fn infer_batch(&self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        let r = self.infer_batch_inner(images, batch);
        match &r {
            Ok(_) => self.consecutive_errors.store(0, Ordering::Relaxed),
            Err(_) => {
                self.consecutive_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        r
    }

    /// Pre-pack the digit planes, then run one probe image through BOTH
    /// kernels: the fast path must match the scalar reference bit-for-bit
    /// before the variant serves traffic.
    fn warmup(&self) -> Result<()> {
        let packed = self.packed();
        let probe = vec![0.5f32; self.image_len()];
        let fast = self.model.forward(packed, &probe, true)?;
        let refr = self.model.forward(packed, &probe, false)?;
        if fast
            .iter()
            .zip(&refr)
            .any(|(a, b)| a.to_bits() != b.to_bits())
        {
            crate::bail!(
                "xmp: fast path diverged from the scalar reference on the warm-up probe"
            );
        }
        Ok(())
    }

    /// Self-reported health from the live error streak: fresh and
    /// recently-successful backends are `Healthy`, any error degrades, a
    /// streak of [`ERRORS_TO_UNAVAILABLE`] reports `Unavailable` until a
    /// success resets it. The worker polls this between batches and merges
    /// it with its own observations.
    fn health(&self) -> BackendHealth {
        match self.consecutive_errors.load(Ordering::Relaxed) {
            0 => BackendHealth::Healthy,
            n if n < ERRORS_TO_UNAVAILABLE => BackendHealth::Degraded,
            _ => BackendHealth::Unavailable,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;
    use crate::cnn::ChannelGroup;

    fn backend(wq: u32) -> XmpBackend {
        let base = resnet::resnet_small(1, 10);
        XmpBackend::from_spec(&base, &VariantSpec::uniform(wq), XmpConfig::default()).unwrap()
    }

    #[test]
    fn capabilities() {
        let b = backend(2);
        assert_eq!(b.image_len(), 3072);
        assert_eq!(b.classes(), 10);
        assert!(b.supports_batch(1) && b.supports_batch(17));
        assert!(!b.supports_batch(0));
        assert_eq!(b.health(), BackendHealth::Healthy);
    }

    #[test]
    fn warmup_verifies_kernels() {
        backend(4).warmup().unwrap();
    }

    #[test]
    fn infer_batch_layout_and_determinism() {
        let b = backend(2);
        let img0 = vec![0.2f32; 3072];
        let img1 = vec![5.0f32; 3072];
        let mut batch = img0.clone();
        batch.extend_from_slice(&img1);
        let logits = b.infer_batch(&batch, 2).unwrap();
        assert_eq!(logits.len(), 20);
        // Batch rows are independent per-image forwards.
        assert_eq!(&logits[..10], &b.infer_batch(&img0, 1).unwrap()[..]);
        assert_eq!(&logits[10..], &b.infer_batch(&img1, 1).unwrap()[..]);
        // classify_one agrees with argmax over infer_batch.
        let want = argmax_rows(&logits[..10], 10)[0];
        assert_eq!(b.classify_one(&img0).unwrap(), want);
        // The scalar-reference backend batches identically, and the two
        // kernel paths agree on the whole batched result.
        let r = backend(2).reference_kernels();
        let lr = r.infer_batch(&batch, 2).unwrap();
        assert_eq!(&lr[..10], &r.infer_batch(&img0, 1).unwrap()[..]);
        assert_eq!(logits, lr, "fast and reference disagree on the batch");
    }

    #[test]
    fn two_copies_agree_bitwise() {
        // The worker's backend and a local probe copy must be the same
        // function — this is what serve's reference agreement relies on.
        let a = backend(4);
        let b = backend(4);
        let img = vec![1.5f32; 3072];
        assert_eq!(
            a.infer_batch(&img, 1).unwrap(),
            b.infer_batch(&img, 1).unwrap()
        );
    }

    #[test]
    fn reference_kernels_match_fast() {
        let base = resnet::resnet_small(1, 10);
        let spec = VariantSpec::channelwise(
            "mix18",
            vec![
                ChannelGroup { wq: 1, fraction: 0.75 },
                ChannelGroup { wq: 8, fraction: 0.25 },
            ],
        );
        let fast = XmpBackend::from_spec(&base, &spec, XmpConfig::default()).unwrap();
        let refr = XmpBackend::from_spec(&base, &spec, XmpConfig::default())
            .unwrap()
            .reference_kernels();
        let img = vec![0.7f32; 3072];
        let lf = fast.infer_batch(&img, 1).unwrap();
        let lr = refr.infer_batch(&img, 1).unwrap();
        for (a, b) in lf.iter().zip(&lr) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn joint_wq_aq_spec_serves_and_self_verifies() {
        // A uniform (w4, a5) spec: warm-up's fast==reference probe must
        // pass with activations sliced at 5 bits between the layers, and
        // two copies must still be the same function.
        let base = resnet::resnet_small(1, 10);
        let spec = VariantSpec::uniform_joint(4, 5);
        let a = XmpBackend::from_spec(&base, &spec, XmpConfig::default()).unwrap();
        a.warmup().unwrap();
        let b = XmpBackend::from_spec(&base, &spec, XmpConfig::default()).unwrap();
        let img = vec![1.1f32; 3072];
        assert_eq!(a.infer_batch(&img, 1).unwrap(), b.infer_batch(&img, 1).unwrap());
        // Inner layers carry the narrowed activation word-length.
        assert_eq!(a.model().layers[1].aq, 5);
        assert_eq!(a.model().layers[0].aq, 8, "edge activations stay 8-bit");
        // And it differs from the (w4, a8) function.
        let w4a8 = XmpBackend::from_spec(&base, &VariantSpec::uniform(4), XmpConfig::default())
            .unwrap();
        assert_ne!(a.infer_batch(&img, 1).unwrap(), w4a8.infer_batch(&img, 1).unwrap());
    }

    #[test]
    fn profile_forward_attributes_host_and_modeled_sides() {
        use crate::array::Dims;
        use crate::config::RunConfig;
        use crate::pe::PeDesign;
        use crate::sim::{simulate, AcceleratorDesign};
        let base = resnet::resnet_small(1, 10);
        let b =
            XmpBackend::from_spec(&base, &VariantSpec::uniform_joint(4, 8), XmpConfig::default())
                .unwrap();
        let img = vec![0.6f32; 3072];
        let (logits, mut prof) = b.profile_forward(&img).unwrap();
        assert_eq!(logits, b.infer_batch(&img, 1).unwrap(), "profiling changed the math");
        assert_eq!(prof.layers.len(), b.model().layers.len());
        // Join the simulator's modeled schedule for the same net: every
        // conv layer must end up with both host time and modeled cycles.
        let planned = base.with_uniform_wq(4);
        let cfg = RunConfig::default();
        let design =
            AcceleratorDesign::new(PeDesign::bp_st_1d(2), Dims::new(7, 5, 37), &planned, &cfg);
        let sim = simulate(&planned, &design);
        assert!(prof.attach_sim(&sim) > 0, "no layer matched the schedule");
        assert!(
            prof.conv_layers_attributed(),
            "conv layers missing a side:\n{}",
            prof.table().render()
        );
        assert!(prof.total_host_us() > 0.0 && prof.total_fpga_us() > 0.0);
    }

    #[test]
    fn health_tracks_error_streak_and_recovers() {
        let b = backend(2);
        assert_eq!(b.health(), BackendHealth::Healthy);
        assert!(b.infer_batch(&[0.0; 3], 1).is_err());
        assert_eq!(b.health(), BackendHealth::Degraded, "one error degrades");
        for _ in 1..ERRORS_TO_UNAVAILABLE {
            assert!(b.infer_batch(&[0.0; 3], 1).is_err());
        }
        assert_eq!(b.health(), BackendHealth::Unavailable);
        // A success clears the streak entirely.
        assert!(b.infer_batch(&vec![0.1; 3072], 1).is_ok());
        assert_eq!(b.health(), BackendHealth::Healthy);
    }

    #[test]
    fn rejects_bad_lengths() {
        let b = backend(8);
        assert!(b.infer_batch(&[0.0; 10], 1).is_err());
        let m = b.model().clone();
        assert!(XmpBackend::new(m).classify_one(&[0.0; 3]).is_err());
    }

    #[test]
    fn rejects_malformed_plan() {
        let base = resnet::resnet_small(1, 10);
        let r = XmpModel::synthetic(&base, &[], XmpConfig::default());
        assert!(r.is_err());
    }
}
