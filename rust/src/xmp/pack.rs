//! Digit-plane packing: lower LSQ-quantized weight codes **and** unsigned
//! activations into the layouts the 2D-sliced kernels execute from.
//!
//! A group of `od` channels at word-length `wq` becomes `S_w = ceil(wq/k)`
//! **weight digit planes**: plane `s` holds digit `s` of every
//! `(channel, k)` weight, row-major per output channel. The digits are
//! exactly [`crate::quant::slicing::slice_signed`]'s — low planes unsigned
//! in `[0, 2^k)`, the top plane signed over the (possibly partial)
//! remaining bits — so `Σ_s plane_s[i] · 2^{k·s}` reconstructs every code.
//!
//! Activations are the second operand of the paper's 2D-sliced MAC
//! (Table IV's operand-slice axis applies to *both* sides): an im2col
//! patch matrix at activation word-length `aq` becomes `S_a = ceil(aq/k)`
//! **activation digit planes** via
//! [`crate::quant::slicing::slice_unsigned`] — every plane unsigned, the
//! top plane included ([`pack_activations`]). The fast GEMM accumulates
//! over the `S_a × S_w` slice cross-product and recombines by shift-add at
//! weight-shift + activation-shift, which is the two's-complement identity
//! itself.
//!
//! Digits are stored in `i16` lanes (digit-granular, not sub-byte: the MAC
//! loop reads one lane per operand); [`PackedGroup::packed_bits`] reports
//! the equivalent at-rest bit-packed footprint, which is what the Table
//! III models count.
//!
//! ## The i32 partial-sum bound
//!
//! The fast path accumulates each `(s_a, s_w)` pair's dot product in
//! `i32`. A running partial is bounded by `kdim · a_max · w_max` where
//! `a_max = 2^min(k,aq) − 1` (the widest unsigned activation digit) and
//! `w_max = 2^min(k,wq) − 1` (the widest weight digit — the signed top
//! digit's magnitude `2^{b−1}` never exceeds this), so the safe reduction
//! depth is `max_kdim(wq, aq, k) = floor((2^31 − 1) / (a_max · w_max))`.
//! The bound **shrinks as the digits widen**: the worst case
//! `(wq, aq, k) = (8, 8, 8)` gives `255 · 255` and `kdim ≤ 33 025`
//! (matching the old activation-unsliced constant), while e.g. `k = 2`
//! digits (`3 · 3`) allow reductions ~7000× deeper. [`pack_group`] gates
//! at the conservative `aq = 8` bound (activations never exceed 8 bit);
//! the fast GEMM re-checks the exact `(wq, aq, k)` bound per call.

use super::Requant;
use crate::quant::slicing::{n_slices, slice_digit_unsigned, slice_signed};

/// Largest reduction depth (`K²·I_W`) the `i32` per-slice accumulators
/// tolerate in the worst digit-width case `(wq, aq, k) = (8, 8, 8)` —
/// see [`max_kdim`] for the exact per-shape bound. Every CNN in the repo
/// is far below this (ResNet-152 peaks at 4608).
pub const MAX_KDIM: usize = 33_000;

/// Exact safe reduction depth for the `i32` per-slice-pair partials of
/// the fast GEMM: `floor((2^31 − 1) / (a_max · w_max))` with
/// `a_max = 2^min(k,aq) − 1`, `w_max = 2^min(k,wq) − 1`.
pub fn max_kdim(wq: u32, aq: u32, k: u32) -> usize {
    assert!(wq >= 1 && aq >= 1 && k >= 1);
    let a_max = (1u64 << k.min(aq).min(8)) - 1;
    let w_max = (1u64 << k.min(wq).min(8)) - 1;
    ((i32::MAX as u64) / (a_max * w_max).max(1)) as usize
}

/// One channel group's weights in digit-plane-major layout.
#[derive(Clone, Debug)]
pub struct PackedGroup {
    /// Weight word-length (bits).
    pub wq: u32,
    /// Digit width (bits) — [`super::XmpConfig::k`].
    pub k: u32,
    /// Number of digit planes, `ceil(wq / k)`.
    pub n_slices: u32,
    /// Output channels in this group.
    pub od: usize,
    /// Reduction depth per output element.
    pub kdim: usize,
    /// `n_slices` planes of `od * kdim` digits, row-major per channel.
    pub planes: Vec<Vec<i16>>,
    /// Per-channel requantization back to the layer's output activation
    /// range (len `od`).
    pub requant: Vec<Requant>,
    /// Per-channel dequantization scale for logits (len `od`).
    pub scales: Vec<f32>,
}

impl PackedGroup {
    /// At-rest footprint if the planes were stored bit-packed: `k` bits
    /// per low-plane digit, `wq - k·(S-1)` bits per top-plane digit —
    /// i.e. exactly `wq` bits per weight, however it is sliced.
    pub fn packed_bits(&self) -> u64 {
        let weights = (self.od * self.kdim) as u64;
        let mut bits = 0u64;
        for s in 0..self.n_slices {
            let digit_bits = if s + 1 == self.n_slices {
                self.wq - self.k * (self.n_slices - 1)
            } else {
                self.k
            };
            bits += weights * digit_bits as u64;
        }
        bits
    }
}

/// Pack one channel group's codes into digit planes. `codes` is
/// `od * kdim`, row-major per output channel, every code within the
/// signed `wq`-bit range (enforced by [`slice_signed`]).
pub fn pack_group(
    codes: &[i32],
    od: usize,
    kdim: usize,
    wq: u32,
    k: u32,
    requant: Vec<Requant>,
    scales: Vec<f32>,
) -> PackedGroup {
    assert_eq!(codes.len(), od * kdim, "codes must be od*kdim");
    assert_eq!(requant.len(), od, "one requantizer per channel");
    // Conservative gate at the 8-bit-activation bound; the GEMM re-checks
    // the exact (wq, aq, k) bound once the activation word-length is known.
    assert!(
        kdim <= max_kdim(wq, 8, k),
        "reduction depth {kdim} exceeds the i32 accumulator bound {} for (w{wq}, a8, k{k})",
        max_kdim(wq, 8, k)
    );
    // The i16 digit lanes (and the bound arithmetic) assume digits of at
    // most 8 bits; the widest digit is min(k, wq) bits.
    assert!(
        wq.min(k) <= 8,
        "digit width {} bits exceeds the 8-bit bound the i32 partials assume",
        wq.min(k)
    );
    let s = n_slices(wq, k);
    let mut planes = vec![vec![0i16; od * kdim]; s as usize];
    for (idx, &c) in codes.iter().enumerate() {
        for (si, d) in slice_signed(c as i64, wq, k).into_iter().enumerate() {
            planes[si][idx] = d as i16;
        }
    }
    PackedGroup {
        wq,
        k,
        n_slices: s,
        od,
        kdim,
        planes,
        requant,
        scales,
    }
}

/// An im2col patch matrix lowered to unsigned activation digit planes —
/// the activation operand of the 2D-sliced GEMM. Built once per layer and
/// shared by every channel group slicing at the same digit width.
#[derive(Clone, Debug)]
pub struct SlicedActs {
    /// Activation word-length (bits) the values were sliced at.
    pub aq: u32,
    /// Digit width (bits) — must match the weight planes' `k`.
    pub k: u32,
    /// im2col rows.
    pub m: usize,
    /// Reduction depth per row.
    pub kdim: usize,
    /// `ceil(aq/k)` planes of `m * kdim` unsigned digits, row-major.
    pub planes: Vec<Vec<i16>>,
}

/// Slice an im2col patch matrix (`m × kdim`, unsigned values `< 2^aq`
/// widened to `i16`) into `ceil(aq/k)` unsigned digit planes — exactly
/// [`slice_digit_unsigned`]'s digits, the possibly-partial top plane
/// unsigned too.
pub fn pack_activations(cols: &[i16], m: usize, kdim: usize, aq: u32, k: u32) -> SlicedActs {
    assert_eq!(cols.len(), m * kdim, "cols must be m*kdim");
    assert!((1..=8).contains(&aq), "activation word-lengths are 1..=8 bit");
    assert!(k >= 1, "digit width must be >= 1");
    let s = n_slices(aq, k);
    let mut planes = vec![vec![0i16; m * kdim]; s as usize];
    for (idx, &x) in cols.iter().enumerate() {
        debug_assert!(
            x >= 0 && (x as u64) < (1u64 << aq),
            "activation {x} out of unsigned {aq}-bit range"
        );
        if x == 0 {
            continue; // padding taps stay zero in every plane
        }
        for si in 0..s {
            planes[si as usize][idx] = slice_digit_unsigned(x as u64, aq, k, si) as i16;
        }
    }
    SlicedActs {
        aq,
        k,
        m,
        kdim,
        planes,
    }
}

/// Fuse adjacent digit planes into planes of twice the digit width: the
/// pair `(2j, 2j+1)` becomes `plane_{2j} + (plane_{2j+1} << k)`; a
/// trailing unpaired plane passes through unchanged. Because digit planes
/// are positional, the result is EXACTLY the digit planes of re-slicing
/// the original values at width `2k` — for signed weight planes (two's
/// complement top digit included, even and odd plane counts alike) and
/// for unsigned activation planes (property-tested below). The fast GEMM
/// uses this ladder to fuse low-(wq, aq) slice pairs into wider lanes
/// wherever [`max_kdim`] at the doubled width still admits the reduction
/// depth, quartering the slice cross-product per rung.
///
/// Fused digits stay well inside `i16`: a pair only exists when
/// `k < word-length ≤ 8`, so the fused width is at most 14 bits.
pub fn fuse_plane_pairs(planes: &[Vec<i16>], k: u32) -> Vec<Vec<i16>> {
    let mut out = Vec::with_capacity(planes.len().div_ceil(2));
    for pair in planes.chunks(2) {
        if let [lo, hi] = pair {
            debug_assert!(k <= 7, "fusable planes imply k < word-length <= 8");
            let mut fused = Vec::with_capacity(lo.len());
            for (&l, &h) in lo.iter().zip(hi.iter()) {
                fused.push((l as i32 + ((h as i32) << k)) as i16);
            }
            out.push(fused);
        } else {
            out.push(pair[0].clone());
        }
    }
    out
}

/// One layer's packed groups, in the same order as
/// [`super::XmpLayer::groups`].
#[derive(Clone, Debug)]
pub struct PackedLayer {
    pub groups: Vec<PackedGroup>,
}

/// A whole model lowered to digit planes.
#[derive(Clone, Debug)]
pub struct PackedModel {
    pub layers: Vec<PackedLayer>,
}

impl PackedModel {
    /// Total at-rest weight footprint in bits (bit-packed equivalent).
    pub fn packed_bits(&self) -> u64 {
        self.layers
            .iter()
            .flat_map(|l| l.groups.iter().map(PackedGroup::packed_bits))
            .sum()
    }
}

/// Lower every layer of `m` to digit planes at the model's digit width.
pub fn pack_model(m: &super::XmpModel) -> PackedModel {
    let layers = m
        .layers
        .iter()
        .map(|l| PackedLayer {
            groups: l
                .groups
                .iter()
                .map(|g| {
                    pack_group(
                        &g.codes,
                        g.od as usize,
                        l.kdim(),
                        g.wq,
                        m.cfg.k,
                        g.requant.clone(),
                        g.scales.clone(),
                    )
                })
                .collect(),
        })
        .collect();
    PackedModel { layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::slicing::slice_unsigned;
    use crate::util::prop::{check, check_eq, forall};

    #[test]
    fn prop_planes_reconstruct_codes() {
        // Σ_s plane_s[i] << k·s == code[i] for every weight — the packed
        // form carries the exact two's-complement decomposition.
        forall(500, |rng| {
            let wq = *rng.choose(&[1u32, 2, 3, 4, 5, 6, 7, 8]);
            let k = *rng.choose(&[1u32, 2, 3, 4, 8]);
            let (od, kdim) = (1 + rng.range(0, 4), 1 + rng.range(0, 9));
            let (lo, hi) = (-(1i64 << (wq - 1)), (1i64 << (wq - 1)) - 1);
            let codes: Vec<i32> = (0..od * kdim)
                .map(|_| rng.range_i64(lo, hi) as i32)
                .collect();
            let requant = vec![Requant::from_scale(0.01); od];
            let g = pack_group(&codes, od, kdim, wq, k, requant, vec![1.0; od]);
            check_eq(g.planes.len() as u32, g.n_slices, "plane count")?;
            for (idx, &c) in codes.iter().enumerate() {
                let recon: i64 = g
                    .planes
                    .iter()
                    .enumerate()
                    .map(|(s, p)| (p[idx] as i64) << (k as usize * s))
                    .sum();
                check_eq(recon, c as i64, "plane reconstruction")?;
            }
            // Low planes unsigned < 2^k, top plane within its signed range.
            for (s, p) in g.planes.iter().enumerate() {
                for &d in p {
                    if s + 1 < g.planes.len() {
                        check(
                            (0..(1i16 << k)).contains(&d),
                            "low digits must be unsigned",
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_activation_planes_reconstruct_values() {
        // Σ_s plane_s[i] << k·s == cols[i] for every activation, every
        // plane unsigned — the partial top digit included.
        forall(500, |rng| {
            let aq = 1 + rng.range(0, 8) as u32;
            let k = *rng.choose(&[1u32, 2, 3, 4, 5, 8]);
            let (m, kdim) = (1 + rng.range(0, 5), 1 + rng.range(0, 9));
            let cols: Vec<i16> = (0..m * kdim)
                .map(|_| rng.below(1u64 << aq) as i16)
                .collect();
            let a = pack_activations(&cols, m, kdim, aq, k);
            check_eq(a.planes.len() as u32, n_slices(aq, k), "plane count")?;
            for (idx, &x) in cols.iter().enumerate() {
                let recon: i64 = a
                    .planes
                    .iter()
                    .enumerate()
                    .map(|(s, p)| (p[idx] as i64) << (k as usize * s))
                    .sum();
                check_eq(recon, x as i64, "activation plane reconstruction")?;
                let digits = slice_unsigned(x as u64, aq, k);
                for (s, &d) in digits.iter().enumerate() {
                    check_eq(a.planes[s][idx] as i64, d, "digits are slice_unsigned's")?;
                }
            }
            for p in &a.planes {
                check(
                    p.iter().all(|&d| (0..(1i16 << k.min(aq))).contains(&d)),
                    "every activation digit must be unsigned",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn packed_bits_counts_wq_bits_per_weight() {
        // However a wq is sliced, the at-rest footprint is wq bits/weight.
        for (wq, k) in [(8u32, 2u32), (3, 2), (5, 3), (1, 4), (8, 8)] {
            let (od, kdim) = (3usize, 7usize);
            let codes = vec![0i32; od * kdim];
            let g = pack_group(
                &codes,
                od,
                kdim,
                wq,
                k,
                vec![Requant::from_scale(0.5); od],
                vec![1.0; od],
            );
            assert_eq!(g.packed_bits(), (od * kdim) as u64 * wq as u64, "w{wq}/k{k}");
        }
    }

    #[test]
    fn max_kdim_shrinks_with_digit_magnitude() {
        // Worst case (8,8,8): the old 255·255 constant's neighborhood.
        assert_eq!(max_kdim(8, 8, 8), (i32::MAX as usize) / (255 * 255));
        assert!(max_kdim(8, 8, 8) >= MAX_KDIM);
        // Narrower digits (smaller k, or narrower operands) allow deeper
        // reductions: the bound is monotone non-increasing in each width.
        assert!(max_kdim(8, 8, 2) > 1_000_000);
        assert!(max_kdim(2, 2, 8) > max_kdim(8, 8, 8));
        assert!(max_kdim(8, 4, 8) > max_kdim(8, 8, 8));
        for k in 1..=8u32 {
            for wq in 1..=8u32 {
                for aq in 1..=8u32 {
                    let b = max_kdim(wq, aq, k);
                    let a_max = (1u64 << k.min(aq)) - 1;
                    let w_max = (1u64 << k.min(wq)) - 1;
                    // The defining inequality, tight to within one unit.
                    assert!(b as u64 * a_max * w_max <= i32::MAX as u64);
                    assert!((b as u64 + 1) * a_max * w_max > i32::MAX as u64);
                }
            }
        }
    }

    #[test]
    fn prop_fused_planes_equal_reslicing_at_double_width() {
        // The lane-fusion identity the fast GEMM rests on: fusing adjacent
        // plane pairs at width k yields bit-for-bit the planes of slicing
        // the original values at width 2k — signed weight planes (partial
        // top digits, even and odd plane counts) and unsigned activation
        // planes alike. So "fused" and "unfused per slice pair" recombine
        // to the same accumulator by construction.
        forall(400, |rng| {
            let wq = 1 + rng.range(0, 8) as u32;
            let k = *rng.choose(&[1u32, 2, 3, 4, 5]);
            let (od, kdim) = (1 + rng.range(0, 4), 1 + rng.range(0, 9));
            let (lo, hi) = (-(1i64 << (wq - 1)), (1i64 << (wq - 1)) - 1);
            let codes: Vec<i32> = (0..od * kdim)
                .map(|_| rng.range_i64(lo, hi) as i32)
                .collect();
            let requant = vec![Requant::from_scale(0.01); od];
            let g = pack_group(&codes, od, kdim, wq, k, requant.clone(), vec![1.0; od]);
            let g2 = pack_group(&codes, od, kdim, wq, 2 * k, requant, vec![1.0; od]);
            check_eq(
                fuse_plane_pairs(&g.planes, k),
                g2.planes,
                "fused weight planes == planes sliced at 2k",
            )?;

            let aq = 1 + rng.range(0, 8) as u32;
            let m = 1 + rng.range(0, 5);
            let cols: Vec<i16> = (0..m * kdim)
                .map(|_| rng.below(1u64 << aq) as i16)
                .collect();
            let a = pack_activations(&cols, m, kdim, aq, k);
            let a2 = pack_activations(&cols, m, kdim, aq, 2 * k);
            check_eq(
                fuse_plane_pairs(&a.planes, k),
                a2.planes,
                "fused activation planes == planes sliced at 2k",
            )?;

            // The ladder composes: two fusion rungs == slicing at 4k.
            if k <= 3 {
                let a4 = pack_activations(&cols, m, kdim, aq, 4 * k);
                check_eq(
                    fuse_plane_pairs(&fuse_plane_pairs(&a.planes, k), 2 * k),
                    a4.planes,
                    "two fusion rungs == planes sliced at 4k",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn fuse_plane_pairs_passes_single_planes_through() {
        let planes = vec![vec![3i16, -2, 0, 7]];
        assert_eq!(fuse_plane_pairs(&planes, 4), planes);
        assert!(fuse_plane_pairs(&[], 2).is_empty());
    }

    #[test]
    fn max_kdim_stays_tight_at_fused_widths() {
        // The fusion ladder evaluates the bound at doubled digit widths
        // k·2^t (capped only by the operands themselves, so up to 16 for
        // 8-bit words): the defining inequality must stay tight at every
        // width the ladder can reach, exhaustively over (wq, aq, k).
        for wq in 1..=8u32 {
            for aq in 1..=8u32 {
                for k in 1..=8u32 {
                    let mut k_eff = k;
                    while k_eff <= 16 {
                        let b = max_kdim(wq, aq, k_eff) as u64;
                        let a_max = (1u64 << k_eff.min(aq)) - 1;
                        let w_max = (1u64 << k_eff.min(wq)) - 1;
                        assert!(
                            b * a_max * w_max <= i32::MAX as u64,
                            "(w{wq}, a{aq}, k{k_eff}) bound unsafe"
                        );
                        assert!(
                            (b + 1) * a_max * w_max > i32::MAX as u64,
                            "(w{wq}, a{aq}, k{k_eff}) bound not tight"
                        );
                        // Doubling the width never widens the safe depth.
                        assert!(max_kdim(wq, aq, k_eff * 2) <= max_kdim(wq, aq, k_eff));
                        k_eff *= 2;
                    }
                }
            }
        }
    }

    #[test]
    #[should_panic(expected = "i32 accumulator bound")]
    fn rejects_overflowing_reduction_depth() {
        // Must exceed the aq = 8 worst-case bound for (w8, k8): 33 025.
        let kdim = max_kdim(8, 8, 8) + 1;
        let codes = vec![0i32; kdim];
        pack_group(
            &codes,
            1,
            kdim,
            8,
            8,
            vec![Requant::from_scale(0.5)],
            vec![1.0],
        );
    }
}
