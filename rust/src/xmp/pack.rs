//! Digit-plane packing: lower LSQ-quantized weight codes into the layout
//! the sliced kernels execute from.
//!
//! A group of `od` channels at word-length `wq` becomes `S = ceil(wq/k)`
//! **digit planes**: plane `s` holds digit `s` of every `(channel, k)`
//! weight, row-major per output channel. The digits are exactly
//! [`crate::quant::slicing::slice_signed`]'s — low planes unsigned in
//! `[0, 2^k)`, the top plane signed over the (possibly partial) remaining
//! bits — so `Σ_s plane_s[i] · 2^{k·s}` reconstructs every code, and the
//! fast GEMM's shift-add recombination is the two's-complement identity
//! itself. Digits are stored in `i16` lanes (digit-granular, not sub-byte:
//! the MAC loop reads one lane per operand); [`PackedGroup::packed_bits`]
//! reports the equivalent at-rest bit-packed footprint, which is what the
//! Table III models count.

use super::Requant;
use crate::quant::slicing::{n_slices, slice_signed};

/// Largest reduction depth (`K²·I_W`) the `i32` per-slice accumulators
/// tolerate: `kdim · 255 · 255 < 2^31` with headroom. Every CNN in the
/// repo is far below this (ResNet-152 peaks at 4608).
pub const MAX_KDIM: usize = 33_000;

/// One channel group's weights in digit-plane-major layout.
#[derive(Clone, Debug)]
pub struct PackedGroup {
    /// Weight word-length (bits).
    pub wq: u32,
    /// Digit width (bits) — [`super::XmpConfig::k`].
    pub k: u32,
    /// Number of digit planes, `ceil(wq / k)`.
    pub n_slices: u32,
    /// Output channels in this group.
    pub od: usize,
    /// Reduction depth per output element.
    pub kdim: usize,
    /// `n_slices` planes of `od * kdim` digits, row-major per channel.
    pub planes: Vec<Vec<i16>>,
    /// Per-channel requantization (len `od`).
    pub requant: Vec<Requant>,
    /// Per-channel dequantization scale for logits (len `od`).
    pub scales: Vec<f32>,
}

impl PackedGroup {
    /// At-rest footprint if the planes were stored bit-packed: `k` bits
    /// per low-plane digit, `wq - k·(S-1)` bits per top-plane digit —
    /// i.e. exactly `wq` bits per weight, however it is sliced.
    pub fn packed_bits(&self) -> u64 {
        let weights = (self.od * self.kdim) as u64;
        let mut bits = 0u64;
        for s in 0..self.n_slices {
            let digit_bits = if s + 1 == self.n_slices {
                self.wq - self.k * (self.n_slices - 1)
            } else {
                self.k
            };
            bits += weights * digit_bits as u64;
        }
        bits
    }
}

/// Pack one channel group's codes into digit planes. `codes` is
/// `od * kdim`, row-major per output channel, every code within the
/// signed `wq`-bit range (enforced by [`slice_signed`]).
pub fn pack_group(
    codes: &[i32],
    od: usize,
    kdim: usize,
    wq: u32,
    k: u32,
    requant: Vec<Requant>,
    scales: Vec<f32>,
) -> PackedGroup {
    assert_eq!(codes.len(), od * kdim, "codes must be od*kdim");
    assert_eq!(requant.len(), od, "one requantizer per channel");
    assert!(
        kdim <= MAX_KDIM,
        "reduction depth {kdim} exceeds the i32 accumulator bound {MAX_KDIM}"
    );
    // MAX_KDIM's overflow analysis assumes digits of at most 8 bits
    // (kdim · 255 · 255 < 2^31); the widest digit is min(k, wq) bits.
    assert!(
        wq.min(k) <= 8,
        "digit width {} bits exceeds the 8-bit bound the i32 partials assume",
        wq.min(k)
    );
    let s = n_slices(wq, k);
    let mut planes = vec![vec![0i16; od * kdim]; s as usize];
    for (idx, &c) in codes.iter().enumerate() {
        for (si, d) in slice_signed(c as i64, wq, k).into_iter().enumerate() {
            planes[si][idx] = d as i16;
        }
    }
    PackedGroup {
        wq,
        k,
        n_slices: s,
        od,
        kdim,
        planes,
        requant,
        scales,
    }
}

/// One layer's packed groups, in the same order as
/// [`super::XmpLayer::groups`].
#[derive(Clone, Debug)]
pub struct PackedLayer {
    pub groups: Vec<PackedGroup>,
}

/// A whole model lowered to digit planes.
#[derive(Clone, Debug)]
pub struct PackedModel {
    pub layers: Vec<PackedLayer>,
}

impl PackedModel {
    /// Total at-rest weight footprint in bits (bit-packed equivalent).
    pub fn packed_bits(&self) -> u64 {
        self.layers
            .iter()
            .flat_map(|l| l.groups.iter().map(PackedGroup::packed_bits))
            .sum()
    }
}

/// Lower every layer of `m` to digit planes at the model's digit width.
pub fn pack_model(m: &super::XmpModel) -> PackedModel {
    let layers = m
        .layers
        .iter()
        .map(|l| PackedLayer {
            groups: l
                .groups
                .iter()
                .map(|g| {
                    pack_group(
                        &g.codes,
                        g.od as usize,
                        l.kdim(),
                        g.wq,
                        m.cfg.k,
                        g.requant.clone(),
                        g.scales.clone(),
                    )
                })
                .collect(),
        })
        .collect();
    PackedModel { layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, check_eq, forall};

    #[test]
    fn prop_planes_reconstruct_codes() {
        // Σ_s plane_s[i] << k·s == code[i] for every weight — the packed
        // form carries the exact two's-complement decomposition.
        forall(500, |rng| {
            let wq = *rng.choose(&[1u32, 2, 3, 4, 5, 6, 7, 8]);
            let k = *rng.choose(&[1u32, 2, 3, 4, 8]);
            let (od, kdim) = (1 + rng.range(0, 4), 1 + rng.range(0, 9));
            let (lo, hi) = (-(1i64 << (wq - 1)), (1i64 << (wq - 1)) - 1);
            let codes: Vec<i32> = (0..od * kdim)
                .map(|_| rng.range_i64(lo, hi) as i32)
                .collect();
            let requant = vec![Requant::from_scale(0.01); od];
            let g = pack_group(&codes, od, kdim, wq, k, requant, vec![1.0; od]);
            check_eq(g.planes.len() as u32, g.n_slices, "plane count")?;
            for (idx, &c) in codes.iter().enumerate() {
                let recon: i64 = g
                    .planes
                    .iter()
                    .enumerate()
                    .map(|(s, p)| (p[idx] as i64) << (k as usize * s))
                    .sum();
                check_eq(recon, c as i64, "plane reconstruction")?;
            }
            // Low planes unsigned < 2^k, top plane within its signed range.
            for (s, p) in g.planes.iter().enumerate() {
                for &d in p {
                    if s + 1 < g.planes.len() {
                        check(
                            (0..(1i16 << k)).contains(&d),
                            "low digits must be unsigned",
                        )?;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn packed_bits_counts_wq_bits_per_weight() {
        // However a wq is sliced, the at-rest footprint is wq bits/weight.
        for (wq, k) in [(8u32, 2u32), (3, 2), (5, 3), (1, 4), (8, 8)] {
            let (od, kdim) = (3usize, 7usize);
            let codes = vec![0i32; od * kdim];
            let g = pack_group(
                &codes,
                od,
                kdim,
                wq,
                k,
                vec![Requant::from_scale(0.5); od],
                vec![1.0; od],
            );
            assert_eq!(g.packed_bits(), (od * kdim) as u64 * wq as u64, "w{wq}/k{k}");
        }
    }

    #[test]
    #[should_panic(expected = "i32 accumulator bound")]
    fn rejects_overflowing_reduction_depth() {
        let codes = vec![0i32; MAX_KDIM + 1];
        pack_group(
            &codes,
            1,
            MAX_KDIM + 1,
            8,
            2,
            vec![Requant::from_scale(0.5)],
            vec![1.0],
        );
    }
}
