//! xmp — a native **truly mixed-precision** CNN execution engine.
//!
//! Everything below the serving gateway used to be a *model* of compute
//! (DSE cost models, virtual clocks, mock logits). This module is the
//! compute: a dependency-free, multithreaded integer inference engine
//! whose inner MAC **is** the paper's 2D-sliced datapath (Fig 1b +
//! Table IV's operand-slice axis, applied to *both* operands). LSQ-
//! quantized weights are decomposed into `ceil(wq/k)` signed `k`-bit
//! digit planes (exactly [`crate::quant::slicing::slice_signed`]: low
//! digits unsigned, top digit signed, possibly partial) and activations
//! into `ceil(aq/k)` **unsigned** digit planes (exactly
//! [`crate::quant::slicing::slice_unsigned`]); every convolution
//! accumulates the `S_a × S_w` slice cross-product and recombines by
//! shift-add at weight-shift + activation-shift — so the two's-complement
//! digit identity the property tests anchor is what the serving path
//! actually executes, on both axes of the paper's "weight and/or
//! activation word-length reduction".
//!
//! Pipeline, one layer at a time ([`conv`]):
//! `u8 activations (a_in bits) → im2col → per-channel-group 2D-sliced
//! GEMM ([`gemm`]) → per-channel integer requantize ([`Requant`], clamp
//! to the layer's `2^aq − 1`) → u8 activations (aq bits)`,
//! with the FC head running through the same kernels (M = 1) and
//! dequantizing to `f32` logits. Channel groups at different weight
//! word-lengths coexist *within* one layer — the "truly mixed" part —
//! honoring layerwise and channelwise [`crate::serving::VariantSpec`]
//! plans (now `(wq, aq)` pairs) from the [`crate::planner`].
//!
//! Three kernels compute every layer ([`XmpModel::forward_kernel`]):
//! - the **plain-i64 ground truth** ([`gemm::gemm_codes_i64`]): direct
//!   `Σ a·w`, no slicing on either operand;
//! - the **scalar reference** ([`gemm::gemm_sliced_reference`]): digit
//!   extraction on the fly for both operands via the allocation-free
//!   `slice_digit` / `slice_digit_unsigned`, transparently the PPG +
//!   shifted-adder-tree algebra;
//! - the **fast path** ([`gemm::gemm_sliced_fast`]): digit-plane-major
//!   packed operands ([`pack`]), `i32` per-slice-pair accumulators
//!   bounded by [`pack::max_kdim`]`(wq, aq, k)`, scoped-thread row
//!   fan-out (same concurrency discipline as [`crate::array::search`]).
//!
//! All three are property-tested bit-identical (the differential harness
//! in `rust/tests/integration_xmp.rs` + module props), and
//! [`backend::XmpBackend`] re-verifies fast == reference on a probe
//! image at warm-up before a variant is announced ready. `cargo bench
//! --bench xmp` tracks the fast-path-vs-reference baseline
//! (`BENCH_xmp.json`), `cargo bench --bench table4_operand_slices` the
//! 2D operand-slice grid; reproduction notes live in EXPERIMENTS.md
//! §Execution.

pub mod backend;
pub mod conv;
pub mod gemm;
pub mod pack;

pub use backend::XmpBackend;
pub use pack::{pack_model, PackedModel};

use crate::cnn::channelwise::group_channel_counts;
use crate::cnn::{ChannelGroup, Cnn, LayerKind};
use crate::obs::{LayerProfile, ModelProfile, StageTimes};
use crate::quant::lsq::{QuantParams, Quantizer};
use crate::util::error::Result;
use crate::util::rng::Rng;
use std::time::Instant;

/// Engine-wide knobs.
#[derive(Clone, Copy, Debug)]
pub struct XmpConfig {
    /// Digit (operand-slice) width `k` in bits — the PPG operand width of
    /// the simulated BP-ST design. Every group's weights decompose into
    /// `ceil(w_Q / k)` digit planes, every layer's activations into
    /// `ceil(a_Q / k)`.
    pub k: u32,
    /// Base seed for synthetic weight generation; the effective seed also
    /// mixes in the planned CNN's fingerprint, so two independently built
    /// copies of the same (base, plan) agree bit-for-bit.
    pub seed: u64,
}

impl Default for XmpConfig {
    fn default() -> Self {
        XmpConfig { k: 2, seed: 0xA11CE }
    }
}

/// Which kernel computes the layers of a forward pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelPath {
    /// Plain `i64` MACs straight from the codes — the ground truth the
    /// sliced kernels are differentially tested against.
    PlainI64,
    /// Scalar 2D-sliced reference (on-the-fly digit extraction per MAC).
    Reference,
    /// Digit-plane-major fast path.
    Fast,
}

/// Integer requantization of an accumulator back to an unsigned
/// activation of the layer's word-length:
/// `clamp((acc·mult + 2^{shift-1}) >> shift, 0, qmax)` — round-half-up
/// fixed-point scaling with `qmax = 2^{aq} − 1`, the clamp at 0 doubling
/// as the ReLU and the clamp at `qmax` pinning the output to its
/// activation range (255 for the legacy 8-bit case). Pure function of
/// `acc`, so every kernel path requantizes identically by construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Requant {
    pub mult: i64,
    pub shift: u32,
    /// Upper clamp bound, `2^{aq} − 1`.
    pub qmax: i64,
}

impl Requant {
    /// Fixed-point `(mult, shift)` approximating the real factor `r`
    /// (`0 < r < 128`): `mult = round(r·2^shift)` with `shift` chosen so
    /// `mult` lands in `[128, 255]` — 8-bit multiplier precision, ~0.4%
    /// worst-case scale error. Output clamps to the 8-bit range.
    pub fn from_scale(r: f64) -> Requant {
        Requant::from_scale_aq(r, 8)
    }

    /// [`from_scale`](Self::from_scale) with the output clamped to the
    /// unsigned `aq`-bit activation range `[0, 2^aq − 1]`.
    pub fn from_scale_aq(r: f64, aq: u32) -> Requant {
        assert!(
            r.is_finite() && r > 0.0 && r < 128.0,
            "requantize scale must be in (0, 128), got {r}"
        );
        assert!((1..=8).contains(&aq), "activation word-lengths are 1..=8 bit");
        let mut shift = 0u32;
        let mut m = r;
        while m < 128.0 && shift < 62 {
            m *= 2.0;
            shift += 1;
        }
        Requant {
            mult: (m.round() as i64).clamp(1, 255),
            shift: shift.max(1),
            qmax: (1i64 << aq) - 1,
        }
    }

    /// Apply to an exact integer accumulator.
    #[inline]
    pub fn apply(&self, acc: i64) -> u8 {
        let q = (acc * self.mult + (1i64 << (self.shift - 1))) >> self.shift;
        q.clamp(0, self.qmax) as u8
    }
}

/// One channel group's weights within a layer: every channel in the group
/// shares the weight word-length `wq`.
#[derive(Clone, Debug)]
pub struct GroupWeights {
    /// Weight word-length of this group (bits).
    pub wq: u32,
    /// Output channels in this group.
    pub od: u32,
    /// Integer weight codes, `od * kdim` row-major per output channel,
    /// each in `[-2^{wq-1}, 2^{wq-1} - 1]`.
    pub codes: Vec<i32>,
    /// Per-channel requantization back to `aq`-bit activations (len `od`).
    pub requant: Vec<Requant>,
    /// Per-channel dequantization scale (the LSQ step γ), used for the
    /// `f32` logits of the FC head (len `od`).
    pub scales: Vec<f32>,
}

/// One executable layer: geometry (the [`crate::cnn::Layer`] vocabulary)
/// plus channel-group weights and the layer's **output activation
/// word-length** `aq`. `k` is the *spatial* kernel size; the digit width
/// lives in [`XmpConfig::k`].
#[derive(Clone, Debug)]
pub struct XmpLayer {
    pub name: String,
    pub kind: LayerKind,
    /// Input feature-map height/width (square).
    pub ih: u32,
    /// Input channels.
    pub iw: u32,
    /// Output channels (sum of the group `od`s).
    pub od: u32,
    /// Spatial kernel size (square); 1 for FC.
    pub k: u32,
    /// Stride.
    pub s: u32,
    /// Output activation word-length (bits): the requantizers clamp to
    /// `2^aq − 1`, and the consumer layer slices its input at this width.
    pub aq: u32,
    pub groups: Vec<GroupWeights>,
}

impl XmpLayer {
    /// Reduction depth of one output element (`K²·I_W`).
    pub fn kdim(&self) -> usize {
        (self.k * self.k * self.iw) as usize
    }

    /// Output spatial size (SAME padding, `ceil(I_H / S)` as in
    /// [`crate::cnn::Layer::oh`]).
    pub fn oh(&self) -> u32 {
        self.ih.div_ceil(self.s)
    }
}

/// An executable mixed-precision CNN: geometry plus LSQ-quantized integer
/// weights, in raw (unpacked) form. [`pack::pack_model`] lowers it to
/// digit planes for the kernels.
#[derive(Clone, Debug)]
pub struct XmpModel {
    pub name: String,
    pub input_hw: u32,
    pub input_channels: u32,
    pub classes: u32,
    pub cfg: XmpConfig,
    /// Input quantization step: `a = round(clamp(v / in_scale, 0, 255))`.
    pub in_scale: f32,
    pub layers: Vec<XmpLayer>,
}

/// Estimated |activation| scale feeding the requantize heuristic: inputs
/// are u8 with std ≈ 74 when uniform, and we map ~2.5σ of the accumulator
/// distribution onto the output activation range.
const REQUANT_SIGMA_TIMES_ASTD: f64 = 185.0;

impl XmpModel {
    /// Generate a synthetic LSQ-quantized model for `base` under a
    /// per-layer weight precision plan, with every activation at 8 bit —
    /// see [`synthetic_joint`](Self::synthetic_joint) for the general
    /// `(wq, aq)` form this delegates to. Bit-for-bit identical to the
    /// models this constructor produced before activations became
    /// plannable.
    pub fn synthetic(base: &Cnn, plan: &[Vec<ChannelGroup>], cfg: XmpConfig) -> Result<XmpModel> {
        XmpModel::synthetic_joint(base, plan, &vec![8; plan.len()], cfg)
    }

    /// Generate a synthetic LSQ-quantized model for `base` under a joint
    /// per-layer precision plan: one [`ChannelGroup`] list (weights) and
    /// one activation word-length `aq` per base layer, as produced by
    /// [`crate::serving::VariantSpec::per_layer_plan`] /
    /// [`crate::serving::VariantSpec::per_layer_aq`] or a planner
    /// [`crate::planner::Assignment`]. Per channel, weights are drawn
    /// `N(0, 1/√kdim)` and quantized with an LSQ-initialized quantizer at
    /// the group's word-length; requantization maps the accumulator's
    /// L2-norm-estimated spread onto the layer's `[0, 2^aq − 1]` output
    /// range. Deterministic in `(base, plan, aq, cfg.seed)`, and the
    /// weight draw depends on the *weight* plan only — two variants
    /// differing solely in activation word-lengths share their codes.
    pub fn synthetic_joint(
        base: &Cnn,
        plan: &[Vec<ChannelGroup>],
        aq: &[u32],
        cfg: XmpConfig,
    ) -> Result<XmpModel> {
        if plan.len() != base.layers.len() {
            crate::bail!(
                "plan has {} layer entries for a {}-layer CNN",
                plan.len(),
                base.layers.len()
            );
        }
        if aq.len() != base.layers.len() {
            crate::bail!(
                "activation plan has {} entries for a {}-layer CNN",
                aq.len(),
                base.layers.len()
            );
        }
        if let Some(bad) = aq.iter().find(|a| !(1..=8).contains(*a)) {
            crate::bail!("activation word-length {bad} outside the supported 1..=8 bit range");
        }
        // `apply_plan` validates the plan (fractions, FC splits) and its
        // fingerprint pins the synthetic weights to the planned topology.
        // Deliberately the weights-only lowering: the draw must not move
        // when only activation word-lengths change.
        let planned = crate::cnn::channelwise::apply_plan(base, plan);
        let seed = cfg.seed ^ planned.fingerprint();
        let mut layers = Vec::with_capacity(base.layers.len());
        for (li, (l, groups)) in base.layers.iter().zip(plan).enumerate() {
            let mut rng = Rng::new(seed ^ (li as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let counts = group_channel_counts(l.od, groups);
            let kdim = (l.k * l.k * l.iw) as usize;
            let wstd = 1.0 / (kdim.max(1) as f64).sqrt();
            let qmax = (1u32 << aq[li]) - 1;
            let mut gws = Vec::new();
            for (g, &od) in groups.iter().zip(&counts) {
                if od == 0 {
                    continue;
                }
                let mut codes = Vec::with_capacity(od as usize * kdim);
                let mut requant = Vec::with_capacity(od as usize);
                let mut scales = Vec::with_capacity(od as usize);
                for _ in 0..od {
                    let vals: Vec<f64> = (0..kdim).map(|_| rng.normal() * wstd).collect();
                    let q = Quantizer::init_from_data(QuantParams::weights(g.wq), &vals);
                    let ints = q.to_ints(&vals);
                    let l2 = ints
                        .iter()
                        .map(|&c| (c as f64) * (c as f64))
                        .sum::<f64>()
                        .sqrt();
                    requant.push(Requant::from_scale_aq(
                        qmax as f64 / (REQUANT_SIGMA_TIMES_ASTD * l2.max(1.0)),
                        aq[li],
                    ));
                    scales.push(q.gamma as f32);
                    codes.extend(ints.iter().map(|&c| c as i32));
                }
                gws.push(GroupWeights {
                    wq: g.wq,
                    od,
                    codes,
                    requant,
                    scales,
                });
            }
            layers.push(XmpLayer {
                name: l.name.clone(),
                kind: l.kind,
                ih: l.ih,
                iw: l.iw,
                od: l.od,
                k: l.k,
                s: l.s,
                aq: aq[li],
                groups: gws,
            });
        }
        Ok(XmpModel {
            name: format!("{} [xmp synthetic]", planned.name),
            input_hw: base.input_hw,
            input_channels: base.input_channels,
            classes: base.classes,
            cfg,
            in_scale: 0.04,
            layers,
        })
    }

    /// Flattened input image length (NHWC).
    pub fn image_len(&self) -> usize {
        (self.input_hw * self.input_hw * self.input_channels) as usize
    }

    /// Quantize a flat NHWC f32 image to u8 activation codes (8 bit).
    pub fn quantize_input(&self, image: &[f32]) -> Vec<u8> {
        image
            .iter()
            .map(|&v| (v / self.in_scale).round().clamp(0.0, 255.0) as u8)
            .collect()
    }

    /// Run one image to `f32` logits through the packed kernels.
    /// `fast = false` routes every layer through the scalar 2D-sliced
    /// reference kernel instead of the digit-plane fast path; see
    /// [`forward_kernel`](Self::forward_kernel) for the plain-i64 ground
    /// truth path the golden tests drive.
    pub fn forward(&self, packed: &PackedModel, image: &[f32], fast: bool) -> Result<Vec<f32>> {
        self.forward_kernel(
            packed,
            image,
            if fast { KernelPath::Fast } else { KernelPath::Reference },
        )
    }

    /// Run one image to `f32` logits through the chosen kernel path. All
    /// three paths are bit-identical (differentially tested, and probed
    /// at backend warm-up).
    ///
    /// The layer list is executed sequentially, tracking the activation
    /// word-length of every live buffer: each layer slices its input at
    /// the *producer's* `aq` and clamps its output to its own. Two
    /// ResNet-IR idioms the shape chain doesn't encode are reconstructed
    /// structurally: an elided stride-2 max-pool is inserted when the
    /// next layer expects a halved map at unchanged depth, and a branch
    /// layer whose input matches an *earlier* activation (the
    /// `downsample` projections) is run from that saved activation and
    /// merged into the running one by saturating add — clamped at the
    /// merged buffers' wider activation bound, so the precision invariant
    /// survives the join. Identity skips carry no IR at all and are not
    /// modeled — the kernels, not the topology, are the contract here.
    pub fn forward_kernel(
        &self,
        packed: &PackedModel,
        image: &[f32],
        path: KernelPath,
    ) -> Result<Vec<f32>> {
        self.forward_profiled(packed, image, path, None)
    }

    /// [`forward_kernel`](Self::forward_kernel) with a per-layer profiling
    /// sink: each layer's measured wall time (kernel stages plus glue —
    /// pooling, branch merges) lands in a [`LayerProfile`], with the
    /// im2col/pack/GEMM/requant stage split from the sliced conv kernels
    /// (the plain-i64 ground truth and the FC head report wall time only).
    /// `None` is the zero-cost off switch: no clock reads, no allocation,
    /// bit-identical logits either way.
    pub fn forward_profiled(
        &self,
        packed: &PackedModel,
        image: &[f32],
        path: KernelPath,
        prof: Option<&mut ModelProfile>,
    ) -> Result<Vec<f32>> {
        self.forward_batch_profiled(packed, image, 1, path, prof)
    }

    /// Run a whole batch of images to `batch × classes` logit rows in one
    /// pass: every layer executes **once** for the batch, so the im2col
    /// patch matrices and digit-plane packing are built once per layer
    /// per batch instead of once per image, and each GEMM sees `batch`
    /// times the rows (deeper thread fan-out, better plane reuse). Rows
    /// of a GEMM are independent, so the result is bit-identical to
    /// looping [`forward_kernel`](Self::forward_kernel) per image — the
    /// batching property test and the backend's `infer_batch` regression
    /// both pin that.
    pub fn forward_batch(
        &self,
        packed: &PackedModel,
        images: &[f32],
        batch: usize,
        path: KernelPath,
    ) -> Result<Vec<f32>> {
        self.forward_batch_profiled(packed, images, batch, path, None)
    }

    /// [`forward_batch`](Self::forward_batch) with the profiling sink —
    /// the single implementation behind every forward entry point
    /// (single-image calls are `batch = 1`), so the batched and
    /// per-image paths cannot drift apart.
    pub fn forward_batch_profiled(
        &self,
        packed: &PackedModel,
        images: &[f32],
        batch: usize,
        path: KernelPath,
        mut prof: Option<&mut ModelProfile>,
    ) -> Result<Vec<f32>> {
        if batch == 0 {
            return Ok(Vec::new());
        }
        if images.len() != batch * self.image_len() {
            crate::bail!(
                "batch of {} images has {} elements, model expects {}",
                batch,
                images.len(),
                batch * self.image_len()
            );
        }
        if let Some(p) = prof.as_deref_mut() {
            p.model = self.name.clone();
            p.path = match path {
                KernelPath::PlainI64 => "plain-i64",
                KernelPath::Reference => "reference",
                KernelPath::Fast => "fast",
            }
            .to_string();
            p.simd = crate::util::simd::level().name().to_string();
        }
        let conv_with = |input: &[u8],
                         a_in: u32,
                         l: &XmpLayer,
                         pl: &pack::PackedLayer,
                         st: Option<&mut StageTimes>| match path {
            KernelPath::PlainI64 => conv::conv_forward_i64_batch(input, batch, l),
            KernelPath::Reference => {
                conv::conv_forward_batch_profiled(input, batch, a_in, l, pl, false, st)
            }
            KernelPath::Fast => {
                conv::conv_forward_batch_profiled(input, batch, a_in, l, pl, true, st)
            }
        };
        // The quantizer is elementwise, so the batch quantizes in one go.
        let mut cur = self.quantize_input(images);
        let mut cur_shape = (self.input_hw, self.input_channels);
        // The image quantizer emits the full 8-bit range.
        let mut cur_aq = 8u32;
        // Activation history for branch layers: (shape, aq, data).
        let mut history: Vec<((u32, u32), u32, Vec<u8>)> = Vec::new();
        let mut logits: Option<Vec<f32>> = None;
        for (l, pl) in self.layers.iter().zip(&packed.layers) {
            let t_layer = prof.as_ref().map(|_| Instant::now());
            let mut stages = StageTimes::default();
            if logits.is_some() {
                crate::bail!("layer '{}' follows the FC head; unsupported", l.name);
            }
            if l.kind == LayerKind::Fc {
                // Global average pool, then the FC head runs through the
                // same sliced kernels (M = 1) and dequantizes to logits.
                // Pooling never exceeds the per-channel max, so the pooled
                // features keep the running activation word-length.
                let pooled = avg_pool_batch(&cur, batch, cur_shape.0, cur_shape.1);
                if pooled.len() != batch * l.iw as usize {
                    crate::bail!(
                        "FC '{}' expects {} features, pooled map has {}",
                        l.name,
                        l.iw,
                        pooled.len() / batch
                    );
                }
                logits = Some(match path {
                    KernelPath::PlainI64 => {
                        // The ground-truth path stays deliberately
                        // per-image: it is the definition batching must
                        // reproduce, so it gets no batched shortcuts.
                        let mut all = Vec::with_capacity(batch * l.od as usize);
                        for row in pooled.chunks_exact(l.iw as usize) {
                            all.extend_from_slice(&conv::fc_logits_i64(row, l));
                        }
                        all
                    }
                    KernelPath::Reference => {
                        conv::fc_logits_batch(&pooled, batch, cur_aq, l, pl, false)
                    }
                    KernelPath::Fast => conv::fc_logits_batch(&pooled, batch, cur_aq, l, pl, true),
                });
                record_layer(&mut prof, l, t_layer, stages);
                continue;
            }
            let need = (l.ih, l.iw);
            if need != cur_shape && cur_shape.1 == l.iw && cur_shape.0.div_ceil(2) == l.ih {
                // The IR elides conv1's 2x stride max-pool (shapes only).
                cur = max_pool2_batch(&cur, batch, cur_shape.0, cur_shape.1);
                cur_shape = (cur_shape.0.div_ceil(2), cur_shape.1);
            }
            let (out, branch) = if need == cur_shape {
                let st = prof.is_some().then_some(&mut stages);
                (conv_with(&cur, cur_aq, l, pl, st), false)
            } else {
                let src = history
                    .iter()
                    .rev()
                    .find(|(s, _, _)| *s == need)
                    .ok_or_else(|| {
                        crate::anyhow!(
                            "layer '{}' wants a {}x{}-channel input; no live activation matches",
                            l.name,
                            l.ih,
                            l.iw
                        )
                    })?;
                let st = prof.is_some().then_some(&mut stages);
                (conv_with(&src.2, src.1, l, pl, st), true)
            };
            let out_shape = (l.oh(), l.od);
            if branch && out_shape == cur_shape {
                // Projection shortcut: merge by saturating add at the
                // wider of the two branches' activation bounds (for the
                // all-8-bit case this is exactly u8 saturating_add).
                let merged_aq = cur_aq.max(l.aq);
                let bound = ((1u32 << merged_aq) - 1) as u16;
                for (c, o) in cur.iter_mut().zip(&out) {
                    *c = (*c as u16 + *o as u16).min(bound) as u8;
                }
                cur_aq = merged_aq;
            } else {
                history.push((cur_shape, cur_aq, std::mem::take(&mut cur)));
                cur = out;
                cur_shape = out_shape;
                cur_aq = l.aq;
            }
            record_layer(&mut prof, l, t_layer, stages);
        }
        match logits {
            Some(l) => Ok(l),
            // Conv-only nets: per-channel pooled activations as logits.
            None => Ok(avg_pool_batch(&cur, batch, cur_shape.0, cur_shape.1)
                .into_iter()
                .map(|v| v as f32)
                .collect()),
        }
    }
}

/// Append one layer's measured profile entry; no-op when profiling is off.
/// The reported `wq` is the widest-population channel group's word-length
/// (truly-mixed layers carry several).
fn record_layer(
    prof: &mut Option<&mut ModelProfile>,
    l: &XmpLayer,
    started: Option<Instant>,
    stages: StageTimes,
) {
    let (Some(p), Some(t0)) = (prof.as_deref_mut(), started) else {
        return;
    };
    let kind = match l.kind {
        LayerKind::Fc => "fc".to_string(),
        LayerKind::Conv => format!("conv{}x{}", l.k, l.k),
    };
    p.layers.push(LayerProfile {
        name: l.name.clone(),
        kind,
        wq: l.groups.iter().max_by_key(|g| g.od).map(|g| g.wq).unwrap_or(0),
        aq: l.aq,
        host_us: t0.elapsed().as_secs_f64() * 1e6,
        stages,
        ..Default::default()
    });
}

/// [`avg_pool`] applied per image over a batch-concatenated NHWC map.
fn avg_pool_batch(act: &[u8], batch: usize, h: u32, c: u32) -> Vec<u8> {
    let img = (h * h * c) as usize;
    debug_assert_eq!(act.len(), batch * img, "batched map must be whole images");
    let mut out = Vec::with_capacity(batch * c as usize);
    for image in act.chunks_exact(img) {
        out.extend_from_slice(&avg_pool(image, h, c));
    }
    out
}

/// [`max_pool2`] applied per image over a batch-concatenated NHWC map.
fn max_pool2_batch(act: &[u8], batch: usize, h: u32, c: u32) -> Vec<u8> {
    let img = (h * h * c) as usize;
    debug_assert_eq!(act.len(), batch * img, "batched map must be whole images");
    let oh = h.div_ceil(2);
    let mut out = Vec::with_capacity(batch * (oh * oh * c) as usize);
    for image in act.chunks_exact(img) {
        out.extend_from_slice(&max_pool2(image, h, c));
    }
    out
}

/// Global average pool over an NHWC u8 map: rounded per-channel mean.
fn avg_pool(act: &[u8], h: u32, c: u32) -> Vec<u8> {
    let cs = c as usize;
    let mut sums = vec![0u64; cs];
    for px in act.chunks_exact(cs) {
        for (s, &v) in sums.iter_mut().zip(px) {
            *s += v as u64;
        }
    }
    let n = (h as u64) * (h as u64);
    sums.into_iter().map(|s| ((s + n / 2) / n) as u8).collect()
}

/// 2x2 stride-2 max pool (SAME: edge windows clamp) over an NHWC u8 map.
fn max_pool2(act: &[u8], h: u32, c: u32) -> Vec<u8> {
    let oh = h.div_ceil(2);
    let (hs, cs) = (h as usize, c as usize);
    let mut out = vec![0u8; (oh * oh) as usize * cs];
    for oy in 0..oh as usize {
        for ox in 0..oh as usize {
            let dst = (oy * oh as usize + ox) * cs;
            for dy in 0..2usize {
                for dx in 0..2usize {
                    let (iy, ix) = (2 * oy + dy, 2 * ox + dx);
                    if iy >= hs || ix >= hs {
                        continue;
                    }
                    let src = (iy * hs + ix) * cs;
                    for ch in 0..cs {
                        out[dst + ch] = out[dst + ch].max(act[src + ch]);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{resnet, Layer};
    use crate::util::prop::{check, check_eq, forall};

    fn uniform_plan(base: &Cnn, wq: u32) -> Vec<Vec<ChannelGroup>> {
        crate::serving::VariantSpec::uniform(wq).per_layer_plan(base)
    }

    #[test]
    fn requant_rounds_clamps_and_is_monotone() {
        let r = Requant::from_scale(0.01);
        assert!(r.mult >= 128 && r.mult <= 255, "{r:?}");
        assert_eq!(r.qmax, 255);
        assert_eq!(r.apply(-1_000_000), 0, "negative accs clamp to 0 (ReLU)");
        assert_eq!(r.apply(1 << 40), 255);
        forall(2000, |rng| {
            let aq = 1 + rng.range(0, 8) as u32;
            let r = Requant::from_scale_aq(rng.uniform(1e-4, 1.0), aq);
            check_eq(r.qmax, (1i64 << aq) - 1, "qmax is 2^aq - 1")?;
            let a = rng.range_i64(-(1 << 30), 1 << 30);
            let d = rng.range_i64(0, 1 << 20);
            check(r.apply(a + d) >= r.apply(a), "requantize must be monotone")?;
            check(
                (r.apply(a) as i64) <= r.qmax,
                "outputs never exceed the aq range",
            )
        });
    }

    #[test]
    fn requant_aq8_matches_legacy_255_clamp() {
        // from_scale is from_scale_aq(_, 8): identical (mult, shift, qmax)
        // — the aq = 8 path reproduces the pre-aq engine bit-for-bit.
        forall(500, |rng| {
            let s = rng.uniform(1e-4, 1.0);
            check_eq(Requant::from_scale(s), Requant::from_scale_aq(s, 8), "aq=8 identity")
        });
    }

    #[test]
    fn requant_matches_real_scale() {
        forall(500, |rng| {
            let scale = rng.uniform(1e-4, 1.0);
            let r = Requant::from_scale(scale);
            let eff = r.mult as f64 / (1u64 << r.shift) as f64;
            check(
                (eff - scale).abs() / scale < 0.005,
                &format!("{eff} vs {scale}"),
            )
        });
    }

    #[test]
    fn synthetic_model_shapes_and_ranges() {
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 2);
        let m = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        assert_eq!(m.layers.len(), base.layers.len());
        assert_eq!(m.image_len(), 3072);
        for (l, b) in m.layers.iter().zip(&base.layers) {
            assert_eq!(l.od, b.od);
            assert_eq!(l.aq, 8, "synthetic() pins every activation at 8 bit");
            let mut total = 0u32;
            for g in &l.groups {
                total += g.od;
                assert_eq!(g.codes.len(), g.od as usize * l.kdim());
                let (lo, hi) = (-(1i64 << (g.wq - 1)), (1i64 << (g.wq - 1)) - 1);
                assert!(g.codes.iter().all(|&c| (lo..=hi).contains(&(c as i64))));
            }
            assert_eq!(total, l.od);
        }
        // Inner layers at w2, edges pinned to 8.
        assert_eq!(m.layers[0].groups[0].wq, 8);
        assert_eq!(m.layers[1].groups[0].wq, 2);
        assert_eq!(m.layers.last().unwrap().groups[0].wq, 8);
    }

    #[test]
    fn synthetic_is_deterministic_across_builds() {
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 4);
        let a = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        let b = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            for (ga, gb) in la.groups.iter().zip(&lb.groups) {
                assert_eq!(ga.codes, gb.codes);
                assert_eq!(ga.requant, gb.requant);
            }
        }
        // A different seed moves the weights.
        let c = XmpModel::synthetic(&base, &plan, XmpConfig { seed: 7, ..XmpConfig::default() })
            .unwrap();
        assert_ne!(a.layers[0].groups[0].codes, c.layers[0].groups[0].codes);
    }

    #[test]
    fn joint_plan_shares_codes_and_scales_requant() {
        // Two variants differing only in activation word-lengths must
        // share their weight codes (the draw depends on the weight plan
        // alone) while their requantizers clamp to their own 2^aq - 1.
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 4);
        let n = plan.len();
        let mut aq = vec![8u32; n];
        for (i, a) in aq.iter_mut().enumerate() {
            if i != 0 && i + 1 != n && base.layers[i].kind != LayerKind::Fc {
                *a = 5;
            }
        }
        let a8 = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        let a5 = XmpModel::synthetic_joint(&base, &plan, &aq, XmpConfig::default()).unwrap();
        for ((la, lb), &want_aq) in a8.layers.iter().zip(&a5.layers).zip(&aq) {
            assert_eq!(lb.aq, want_aq);
            for (ga, gb) in la.groups.iter().zip(&lb.groups) {
                assert_eq!(ga.codes, gb.codes, "weight draw must not move with aq");
                for r in &gb.requant {
                    assert_eq!(r.qmax, (1i64 << want_aq) - 1);
                }
            }
        }
        // And the narrow-activation model is a genuinely different function.
        let pa = pack::pack_model(&a8);
        let pb = pack::pack_model(&a5);
        let img = vec![0.9f32; a8.image_len()];
        assert_ne!(
            a8.forward(&pa, &img, true).unwrap(),
            a5.forward(&pb, &img, true).unwrap()
        );
    }

    #[test]
    fn synthetic_joint_rejects_bad_aq() {
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 4);
        let bad = vec![9u32; plan.len()];
        assert!(XmpModel::synthetic_joint(&base, &plan, &bad, XmpConfig::default()).is_err());
        let short = vec![8u32; plan.len() - 1];
        assert!(XmpModel::synthetic_joint(&base, &plan, &short, XmpConfig::default()).is_err());
    }

    #[test]
    fn forward_runs_resnet8_and_kernels_agree() {
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 2);
        let m = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        let packed = pack::pack_model(&m);
        let img = vec![0.5f32; m.image_len()];
        let fast = m.forward(&packed, &img, true).unwrap();
        let refr = m.forward(&packed, &img, false).unwrap();
        let plain = m.forward_kernel(&packed, &img, KernelPath::PlainI64).unwrap();
        assert_eq!(fast.len(), 10);
        for ((a, b), c) in fast.iter().zip(&refr).zip(&plain) {
            assert_eq!(a.to_bits(), b.to_bits(), "fast/reference logits diverged");
            assert_eq!(a.to_bits(), c.to_bits(), "fast/plain-i64 logits diverged");
        }
        // Deterministic across calls.
        let again = m.forward(&packed, &img, true).unwrap();
        assert_eq!(fast, again);
    }

    #[test]
    fn forward_tracks_activation_precision_on_joint_models() {
        // A joint (w, a) resnet-8: all three kernel paths stay
        // bit-identical with narrowed activations flowing between layers
        // (incl. the branch merges and the elided pool).
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 3);
        let n = plan.len();
        let aq: Vec<u32> = (0..n)
            .map(|i| {
                if i == 0 || i + 1 == n || base.layers[i].kind == LayerKind::Fc {
                    8
                } else {
                    [3u32, 4, 6][i % 3]
                }
            })
            .collect();
        let m = XmpModel::synthetic_joint(&base, &plan, &aq, XmpConfig::default()).unwrap();
        let packed = pack::pack_model(&m);
        for img_val in [0.1f32, 0.5, 2.0] {
            let img = vec![img_val; m.image_len()];
            let fast = m.forward(&packed, &img, true).unwrap();
            let refr = m.forward(&packed, &img, false).unwrap();
            let plain = m.forward_kernel(&packed, &img, KernelPath::PlainI64).unwrap();
            for ((a, b), c) in fast.iter().zip(&refr).zip(&plain) {
                assert_eq!(a.to_bits(), b.to_bits());
                assert_eq!(a.to_bits(), c.to_bits());
            }
        }
    }

    #[test]
    fn batched_forward_matches_per_image_forward() {
        // A joint (w, a) resnet-8: forward_batch over 3 images is
        // bit-identical to looping forward_kernel per image on all three
        // kernel paths — GEMM rows are independent, so batch-level
        // im2col/digit-plane reuse must not move a single logit bit.
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 3);
        let n = plan.len();
        let aq: Vec<u32> = (0..n)
            .map(|i| {
                if i == 0 || i + 1 == n || base.layers[i].kind == LayerKind::Fc {
                    8
                } else {
                    [4u32, 6, 8][i % 3]
                }
            })
            .collect();
        let m = XmpModel::synthetic_joint(&base, &plan, &aq, XmpConfig::default()).unwrap();
        let packed = pack::pack_model(&m);
        let batch = 3usize;
        let mut rng = Rng::new(0xBA7C);
        let images: Vec<f32> = (0..batch * m.image_len())
            .map(|_| rng.uniform(0.0, 8.0) as f32)
            .collect();
        let paths = [KernelPath::PlainI64, KernelPath::Reference, KernelPath::Fast];
        for path in paths {
            let batched = m.forward_batch(&packed, &images, batch, path).unwrap();
            assert_eq!(batched.len(), batch * 10);
            for (b, img) in images.chunks_exact(m.image_len()).enumerate() {
                let single = m.forward_kernel(&packed, img, path).unwrap();
                let row = &batched[b * 10..(b + 1) * 10];
                for (x, y) in row.iter().zip(&single) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{path:?} batch row {b} diverged");
                }
            }
        }
        // Degenerate batches: empty is fine, a ragged batch is an error.
        let empty = m.forward_batch(&packed, &[], 0, KernelPath::Fast).unwrap();
        assert!(empty.is_empty());
        assert!(m.forward_batch(&packed, &images, 2, KernelPath::Fast).is_err());
    }

    #[test]
    fn profiled_forward_is_bit_identical_and_covers_every_layer() {
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 4);
        let m = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        let packed = pack::pack_model(&m);
        let img = vec![0.8f32; m.image_len()];
        let mut prof = ModelProfile::default();
        let logits = m
            .forward_profiled(&packed, &img, KernelPath::Fast, Some(&mut prof))
            .unwrap();
        assert_eq!(logits, m.forward(&packed, &img, true).unwrap(), "profiling changed logits");
        assert_eq!(prof.layers.len(), m.layers.len(), "one profile entry per layer");
        assert_eq!(prof.path, "fast");
        assert!(!prof.simd.is_empty(), "profile must record the SIMD level");
        for (pl, l) in prof.layers.iter().zip(&m.layers) {
            assert_eq!(pl.name, l.name);
            assert_eq!(pl.aq, l.aq);
            assert!(pl.host_us > 0.0, "{} has no measured time", pl.name);
            assert!(
                pl.stages.total_us() <= pl.host_us + 1.0,
                "{}: stages {} exceed wall {}",
                pl.name,
                pl.stages.total_us(),
                pl.host_us
            );
        }
        // Every conv layer gets a stage split; the FC head is wall-only.
        for c in prof.layers.iter().filter(|l| l.is_conv()) {
            assert!(c.stages.gemm_us > 0.0, "{} gemm stage untimed", c.name);
        }
        assert_eq!(prof.layers.last().unwrap().kind, "fc");
    }

    #[test]
    fn forward_inserts_elided_max_pool() {
        // conv(8px) -> conv expecting 4px at unchanged depth: the IR elides
        // the 2x pool; forward must insert it rather than error.
        let base = Cnn {
            name: "pooltest".into(),
            input_hw: 8,
            input_channels: 2,
            classes: 3,
            layers: vec![
                Layer::conv("a", 8, 2, 4, 3, 1),
                Layer::conv("b", 4, 4, 6, 3, 1),
                Layer::fc("fc", 6, 3),
            ],
        };
        let plan = uniform_plan(&base, 4);
        let m = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        let packed = pack::pack_model(&m);
        let img = vec![1.0; m.image_len()];
        let logits = m.forward(&packed, &img, true).unwrap();
        assert_eq!(logits.len(), 3);
    }

    #[test]
    fn forward_rejects_bad_image_len() {
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 8);
        let m = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        let packed = pack::pack_model(&m);
        assert!(m.forward(&packed, &[0.0; 7], true).is_err());
    }

    #[test]
    fn pools_behave() {
        // avg: channel means rounded; max: stride-2 windows with edge clamp.
        let act = vec![0u8, 10, 2, 10, 4, 10, 6, 10]; // 2x2 map, 2 channels
        assert_eq!(avg_pool(&act, 2, 2), vec![3, 10]);
        let m = max_pool2(&act, 2, 2);
        assert_eq!(m, vec![6, 10]);
        // 3x3 single-channel map: SAME pooling -> 2x2 output.
        let act3: Vec<u8> = (1..=9).collect();
        assert_eq!(max_pool2(&act3, 3, 1), vec![5, 6, 8, 9]);
    }

    #[test]
    fn prop_avg_pool_bounds() {
        forall(300, |rng| {
            let h = 1 + rng.range(0, 6) as u32;
            let c = 1 + rng.range(0, 4) as u32;
            let act: Vec<u8> = (0..(h * h * c) as usize)
                .map(|_| rng.range(0, 256) as u8)
                .collect();
            let p = avg_pool(&act, h, c);
            check_eq(p.len(), c as usize, "one value per channel")?;
            for (ch, &v) in p.iter().enumerate() {
                let vals: Vec<u8> = act
                    .chunks_exact(c as usize)
                    .map(|px| px[ch])
                    .collect();
                let (lo, hi) = (
                    *vals.iter().min().unwrap(),
                    *vals.iter().max().unwrap(),
                );
                check(v >= lo && v <= hi, "mean within [min, max]")?;
            }
            Ok(())
        });
    }
}
