//! xmp — a native **truly mixed-precision** CNN execution engine.
//!
//! Everything below the serving gateway used to be a *model* of compute
//! (DSE cost models, virtual clocks, mock logits). This module is the
//! compute: a dependency-free, multithreaded integer inference engine
//! whose inner MAC **is** the paper's sliced-digit datapath (Fig 1b).
//! LSQ-quantized weights are decomposed into `k`-bit digit planes
//! (exactly [`crate::quant::slicing::slice_signed`]: low digits unsigned,
//! top digit signed, possibly partial), and every convolution accumulates
//! per-slice partial products that are recombined by shift-add — so the
//! two's-complement digit identity the property tests anchor is what the
//! serving path actually executes.
//!
//! Pipeline, one layer at a time ([`conv`]):
//! `u8 activations → im2col → per-channel-group sliced GEMM ([`gemm`]) →
//! per-channel integer requantize ([`Requant`]) → u8 activations`,
//! with the FC head running through the same kernels (M = 1) and
//! dequantizing to `f32` logits. Channel groups at different word-lengths
//! coexist *within* one layer — the "truly mixed" part — honoring
//! layerwise and channelwise [`crate::serving::VariantSpec`] plans from
//! the [`crate::planner`].
//!
//! Two kernels compute every layer:
//! - the **scalar reference** ([`gemm::gemm_sliced_reference`]): digit
//!   extraction on the fly via [`crate::quant::slicing::slice_digit`],
//!   transparently the PPG + shifted-adder-tree algebra;
//! - the **fast path** ([`gemm::gemm_sliced_fast`]): digit-plane-major
//!   packed weights ([`pack`]), `i32` per-slice accumulators, scoped-thread
//!   row fan-out (same concurrency discipline as [`crate::array::search`]).
//!
//! Both are property-tested bit-identical to a plain `i64` convolution,
//! and [`backend::XmpBackend`] re-verifies fast == reference on a probe
//! image at warm-up before a variant is announced ready. `cargo bench
//! --bench xmp` tracks the fast-path-vs-reference baseline
//! (`BENCH_xmp.json`); reproduction notes live in EXPERIMENTS.md
//! §Execution.

pub mod backend;
pub mod conv;
pub mod gemm;
pub mod pack;

pub use backend::XmpBackend;
pub use pack::{pack_model, PackedModel};

use crate::cnn::channelwise::group_channel_counts;
use crate::cnn::{ChannelGroup, Cnn, LayerKind};
use crate::quant::lsq::{QuantParams, Quantizer};
use crate::util::error::Result;
use crate::util::rng::Rng;

/// Engine-wide knobs.
#[derive(Clone, Copy, Debug)]
pub struct XmpConfig {
    /// Digit (operand-slice) width `k` in bits — the PPG operand width of
    /// the simulated BP-ST design. Every group's weights decompose into
    /// `ceil(w_Q / k)` digit planes.
    pub k: u32,
    /// Base seed for synthetic weight generation; the effective seed also
    /// mixes in the planned CNN's fingerprint, so two independently built
    /// copies of the same (base, plan) agree bit-for-bit.
    pub seed: u64,
}

impl Default for XmpConfig {
    fn default() -> Self {
        XmpConfig { k: 2, seed: 0xA11CE }
    }
}

/// Integer requantization of an accumulator back to an unsigned 8-bit
/// activation: `clamp((acc·mult + 2^{shift-1}) >> shift, 0, 255)` —
/// round-half-up fixed-point scaling, with the clamp at 0 doubling as the
/// ReLU. Pure function of `acc`, so the scalar reference and the fast
/// path requantize identically by construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Requant {
    pub mult: i64,
    pub shift: u32,
}

impl Requant {
    /// Fixed-point `(mult, shift)` approximating the real factor `r`
    /// (`0 < r < 128`): `mult = round(r·2^shift)` with `shift` chosen so
    /// `mult` lands in `[128, 255]` — 8-bit multiplier precision, ~0.4%
    /// worst-case scale error.
    pub fn from_scale(r: f64) -> Requant {
        assert!(
            r.is_finite() && r > 0.0 && r < 128.0,
            "requantize scale must be in (0, 128), got {r}"
        );
        let mut shift = 0u32;
        let mut m = r;
        while m < 128.0 && shift < 62 {
            m *= 2.0;
            shift += 1;
        }
        Requant {
            mult: (m.round() as i64).clamp(1, 255),
            shift: shift.max(1),
        }
    }

    /// Apply to an exact integer accumulator.
    #[inline]
    pub fn apply(&self, acc: i64) -> u8 {
        let q = (acc * self.mult + (1i64 << (self.shift - 1))) >> self.shift;
        q.clamp(0, 255) as u8
    }
}

/// One channel group's weights within a layer: every channel in the group
/// shares the word-length `wq`.
#[derive(Clone, Debug)]
pub struct GroupWeights {
    /// Weight word-length of this group (bits).
    pub wq: u32,
    /// Output channels in this group.
    pub od: u32,
    /// Integer weight codes, `od * kdim` row-major per output channel,
    /// each in `[-2^{wq-1}, 2^{wq-1} - 1]`.
    pub codes: Vec<i32>,
    /// Per-channel requantization back to u8 activations (len `od`).
    pub requant: Vec<Requant>,
    /// Per-channel dequantization scale (the LSQ step γ), used for the
    /// `f32` logits of the FC head (len `od`).
    pub scales: Vec<f32>,
}

/// One executable layer: geometry (the [`crate::cnn::Layer`] vocabulary)
/// plus channel-group weights. `k` is the *spatial* kernel size; the digit
/// width lives in [`XmpConfig::k`].
#[derive(Clone, Debug)]
pub struct XmpLayer {
    pub name: String,
    pub kind: LayerKind,
    /// Input feature-map height/width (square).
    pub ih: u32,
    /// Input channels.
    pub iw: u32,
    /// Output channels (sum of the group `od`s).
    pub od: u32,
    /// Spatial kernel size (square); 1 for FC.
    pub k: u32,
    /// Stride.
    pub s: u32,
    pub groups: Vec<GroupWeights>,
}

impl XmpLayer {
    /// Reduction depth of one output element (`K²·I_W`).
    pub fn kdim(&self) -> usize {
        (self.k * self.k * self.iw) as usize
    }

    /// Output spatial size (SAME padding, `ceil(I_H / S)` as in
    /// [`crate::cnn::Layer::oh`]).
    pub fn oh(&self) -> u32 {
        self.ih.div_ceil(self.s)
    }
}

/// An executable mixed-precision CNN: geometry plus LSQ-quantized integer
/// weights, in raw (unpacked) form. [`pack::pack_model`] lowers it to
/// digit planes for the kernels.
#[derive(Clone, Debug)]
pub struct XmpModel {
    pub name: String,
    pub input_hw: u32,
    pub input_channels: u32,
    pub classes: u32,
    pub cfg: XmpConfig,
    /// Input quantization step: `a = round(clamp(v / in_scale, 0, 255))`.
    pub in_scale: f32,
    pub layers: Vec<XmpLayer>,
}

/// Estimated |activation| scale feeding the requantize heuristic: inputs
/// are u8 with std ≈ 74 when uniform, and we map ~2.5σ of the accumulator
/// distribution onto the 8-bit output range.
const REQUANT_SIGMA_TIMES_ASTD: f64 = 185.0;

impl XmpModel {
    /// Generate a synthetic LSQ-quantized model for `base` under a
    /// per-layer precision plan (one [`ChannelGroup`] list per base layer,
    /// as produced by [`crate::serving::VariantSpec::per_layer_plan`] or a
    /// planner [`crate::planner::Assignment`]). Per channel, weights are
    /// drawn `N(0, 1/√kdim)` and quantized with an LSQ-initialized
    /// quantizer at the group's word-length; requantization maps the
    /// accumulator's L2-norm-estimated spread back onto u8. Deterministic
    /// in `(base, plan, cfg.seed)`.
    pub fn synthetic(base: &Cnn, plan: &[Vec<ChannelGroup>], cfg: XmpConfig) -> Result<XmpModel> {
        if plan.len() != base.layers.len() {
            crate::bail!(
                "plan has {} layer entries for a {}-layer CNN",
                plan.len(),
                base.layers.len()
            );
        }
        // `apply_plan` validates the plan (fractions, FC splits) and its
        // fingerprint pins the synthetic weights to the planned topology.
        let planned = crate::cnn::channelwise::apply_plan(base, plan);
        let seed = cfg.seed ^ planned.fingerprint();
        let mut layers = Vec::with_capacity(base.layers.len());
        for (li, (l, groups)) in base.layers.iter().zip(plan).enumerate() {
            let mut rng = Rng::new(seed ^ (li as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let counts = group_channel_counts(l.od, groups);
            let kdim = (l.k * l.k * l.iw) as usize;
            let wstd = 1.0 / (kdim.max(1) as f64).sqrt();
            let mut gws = Vec::new();
            for (g, &od) in groups.iter().zip(&counts) {
                if od == 0 {
                    continue;
                }
                let mut codes = Vec::with_capacity(od as usize * kdim);
                let mut requant = Vec::with_capacity(od as usize);
                let mut scales = Vec::with_capacity(od as usize);
                for _ in 0..od {
                    let vals: Vec<f64> = (0..kdim).map(|_| rng.normal() * wstd).collect();
                    let q = Quantizer::init_from_data(QuantParams::weights(g.wq), &vals);
                    let ints = q.to_ints(&vals);
                    let l2 = ints
                        .iter()
                        .map(|&c| (c as f64) * (c as f64))
                        .sum::<f64>()
                        .sqrt();
                    requant.push(Requant::from_scale(
                        255.0 / (REQUANT_SIGMA_TIMES_ASTD * l2.max(1.0)),
                    ));
                    scales.push(q.gamma as f32);
                    codes.extend(ints.iter().map(|&c| c as i32));
                }
                gws.push(GroupWeights {
                    wq: g.wq,
                    od,
                    codes,
                    requant,
                    scales,
                });
            }
            layers.push(XmpLayer {
                name: l.name.clone(),
                kind: l.kind,
                ih: l.ih,
                iw: l.iw,
                od: l.od,
                k: l.k,
                s: l.s,
                groups: gws,
            });
        }
        Ok(XmpModel {
            name: format!("{} [xmp synthetic]", planned.name),
            input_hw: base.input_hw,
            input_channels: base.input_channels,
            classes: base.classes,
            cfg,
            in_scale: 0.04,
            layers,
        })
    }

    /// Flattened input image length (NHWC).
    pub fn image_len(&self) -> usize {
        (self.input_hw * self.input_hw * self.input_channels) as usize
    }

    /// Quantize a flat NHWC f32 image to u8 activation codes.
    pub fn quantize_input(&self, image: &[f32]) -> Vec<u8> {
        image
            .iter()
            .map(|&v| (v / self.in_scale).round().clamp(0.0, 255.0) as u8)
            .collect()
    }

    /// Run one image to `f32` logits through the packed kernels.
    /// `fast = false` routes every layer through the scalar sliced
    /// reference kernel instead of the digit-plane fast path; the two are
    /// bit-identical (property-tested, and probed at backend warm-up).
    ///
    /// The layer list is executed sequentially. Two ResNet-IR idioms the
    /// shape chain doesn't encode are reconstructed structurally: an
    /// elided stride-2 max-pool is inserted when the next layer expects a
    /// halved map at unchanged depth, and a branch layer whose input
    /// matches an *earlier* activation (the `downsample` projections) is
    /// run from that saved activation and merged into the running one by
    /// saturating add. Identity skips carry no IR at all and are not
    /// modeled — the kernels, not the topology, are the contract here.
    pub fn forward(&self, packed: &PackedModel, image: &[f32], fast: bool) -> Result<Vec<f32>> {
        if image.len() != self.image_len() {
            crate::bail!(
                "image has {} elements, model expects {}",
                image.len(),
                self.image_len()
            );
        }
        let mut cur = self.quantize_input(image);
        let mut cur_shape = (self.input_hw, self.input_channels);
        // Activation history for branch layers.
        let mut history: Vec<((u32, u32), Vec<u8>)> = Vec::new();
        let mut logits: Option<Vec<f32>> = None;
        for (l, pl) in self.layers.iter().zip(&packed.layers) {
            if logits.is_some() {
                crate::bail!("layer '{}' follows the FC head; unsupported", l.name);
            }
            if l.kind == LayerKind::Fc {
                // Global average pool, then the FC head runs through the
                // same sliced kernels (M = 1) and dequantizes to logits.
                let pooled = avg_pool(&cur, cur_shape.0, cur_shape.1);
                if pooled.len() != l.iw as usize {
                    crate::bail!(
                        "FC '{}' expects {} features, pooled map has {}",
                        l.name,
                        l.iw,
                        pooled.len()
                    );
                }
                logits = Some(conv::fc_logits(&pooled, l, pl, fast));
                continue;
            }
            let need = (l.ih, l.iw);
            if need != cur_shape && cur_shape.1 == l.iw && cur_shape.0.div_ceil(2) == l.ih {
                // The IR elides conv1's 2x stride max-pool (shapes only).
                cur = max_pool2(&cur, cur_shape.0, cur_shape.1);
                cur_shape = (cur_shape.0.div_ceil(2), cur_shape.1);
            }
            let (out, branch) = if need == cur_shape {
                (conv::conv_forward(&cur, l, pl, fast), false)
            } else {
                let src = history
                    .iter()
                    .rev()
                    .find(|(s, _)| *s == need)
                    .ok_or_else(|| {
                        crate::anyhow!(
                            "layer '{}' wants a {}x{}-channel input; no live activation matches",
                            l.name,
                            l.ih,
                            l.iw
                        )
                    })?;
                (conv::conv_forward(&src.1, l, pl, fast), true)
            };
            let out_shape = (l.oh(), l.od);
            if branch && out_shape == cur_shape {
                // Projection shortcut: merge by saturating u8 add.
                for (c, o) in cur.iter_mut().zip(&out) {
                    *c = (*c).saturating_add(*o);
                }
            } else {
                history.push((cur_shape, std::mem::take(&mut cur)));
                cur = out;
                cur_shape = out_shape;
            }
        }
        match logits {
            Some(l) => Ok(l),
            // Conv-only nets: per-channel pooled activations as logits.
            None => Ok(avg_pool(&cur, cur_shape.0, cur_shape.1)
                .into_iter()
                .map(|v| v as f32)
                .collect()),
        }
    }
}

/// Global average pool over an NHWC u8 map: rounded per-channel mean.
fn avg_pool(act: &[u8], h: u32, c: u32) -> Vec<u8> {
    let cs = c as usize;
    let mut sums = vec![0u64; cs];
    for px in act.chunks_exact(cs) {
        for (s, &v) in sums.iter_mut().zip(px) {
            *s += v as u64;
        }
    }
    let n = (h as u64) * (h as u64);
    sums.into_iter().map(|s| ((s + n / 2) / n) as u8).collect()
}

/// 2x2 stride-2 max pool (SAME: edge windows clamp) over an NHWC u8 map.
fn max_pool2(act: &[u8], h: u32, c: u32) -> Vec<u8> {
    let oh = h.div_ceil(2);
    let (hs, cs) = (h as usize, c as usize);
    let mut out = vec![0u8; (oh * oh) as usize * cs];
    for oy in 0..oh as usize {
        for ox in 0..oh as usize {
            let dst = (oy * oh as usize + ox) * cs;
            for dy in 0..2usize {
                for dx in 0..2usize {
                    let (iy, ix) = (2 * oy + dy, 2 * ox + dx);
                    if iy >= hs || ix >= hs {
                        continue;
                    }
                    let src = (iy * hs + ix) * cs;
                    for ch in 0..cs {
                        out[dst + ch] = out[dst + ch].max(act[src + ch]);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::{resnet, Layer};
    use crate::util::prop::{check, check_eq, forall};

    fn uniform_plan(base: &Cnn, wq: u32) -> Vec<Vec<ChannelGroup>> {
        crate::serving::VariantSpec::uniform(wq).per_layer_plan(base)
    }

    #[test]
    fn requant_rounds_clamps_and_is_monotone() {
        let r = Requant::from_scale(0.01);
        assert!(r.mult >= 128 && r.mult <= 255, "{r:?}");
        assert_eq!(r.apply(-1_000_000), 0, "negative accs clamp to 0 (ReLU)");
        assert_eq!(r.apply(1 << 40), 255);
        forall(2000, |rng| {
            let r = Requant::from_scale(rng.uniform(1e-4, 1.0));
            let a = rng.range_i64(-(1 << 30), 1 << 30);
            let d = rng.range_i64(0, 1 << 20);
            check(r.apply(a + d) >= r.apply(a), "requantize must be monotone")
        });
    }

    #[test]
    fn requant_matches_real_scale() {
        forall(500, |rng| {
            let scale = rng.uniform(1e-4, 1.0);
            let r = Requant::from_scale(scale);
            let eff = r.mult as f64 / (1u64 << r.shift) as f64;
            check(
                (eff - scale).abs() / scale < 0.005,
                &format!("{eff} vs {scale}"),
            )
        });
    }

    #[test]
    fn synthetic_model_shapes_and_ranges() {
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 2);
        let m = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        assert_eq!(m.layers.len(), base.layers.len());
        assert_eq!(m.image_len(), 3072);
        for (l, b) in m.layers.iter().zip(&base.layers) {
            assert_eq!(l.od, b.od);
            let mut total = 0u32;
            for g in &l.groups {
                total += g.od;
                assert_eq!(g.codes.len(), g.od as usize * l.kdim());
                let (lo, hi) = (-(1i64 << (g.wq - 1)), (1i64 << (g.wq - 1)) - 1);
                assert!(g.codes.iter().all(|&c| (lo..=hi).contains(&(c as i64))));
            }
            assert_eq!(total, l.od);
        }
        // Inner layers at w2, edges pinned to 8.
        assert_eq!(m.layers[0].groups[0].wq, 8);
        assert_eq!(m.layers[1].groups[0].wq, 2);
        assert_eq!(m.layers.last().unwrap().groups[0].wq, 8);
    }

    #[test]
    fn synthetic_is_deterministic_across_builds() {
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 4);
        let a = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        let b = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            for (ga, gb) in la.groups.iter().zip(&lb.groups) {
                assert_eq!(ga.codes, gb.codes);
                assert_eq!(ga.requant, gb.requant);
            }
        }
        // A different seed moves the weights.
        let c = XmpModel::synthetic(&base, &plan, XmpConfig { seed: 7, ..XmpConfig::default() })
            .unwrap();
        assert_ne!(a.layers[0].groups[0].codes, c.layers[0].groups[0].codes);
    }

    #[test]
    fn forward_runs_resnet8_and_kernels_agree() {
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 2);
        let m = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        let packed = pack::pack_model(&m);
        let img = vec![0.5f32; m.image_len()];
        let fast = m.forward(&packed, &img, true).unwrap();
        let refr = m.forward(&packed, &img, false).unwrap();
        assert_eq!(fast.len(), 10);
        for (a, b) in fast.iter().zip(&refr) {
            assert_eq!(a.to_bits(), b.to_bits(), "fast/reference logits diverged");
        }
        // Deterministic across calls.
        let again = m.forward(&packed, &img, true).unwrap();
        assert_eq!(fast, again);
    }

    #[test]
    fn forward_inserts_elided_max_pool() {
        // conv(8px) -> conv expecting 4px at unchanged depth: the IR elides
        // the 2x pool; forward must insert it rather than error.
        let base = Cnn {
            name: "pooltest".into(),
            input_hw: 8,
            input_channels: 2,
            classes: 3,
            layers: vec![
                Layer::conv("a", 8, 2, 4, 3, 1),
                Layer::conv("b", 4, 4, 6, 3, 1),
                Layer::fc("fc", 6, 3),
            ],
        };
        let plan = uniform_plan(&base, 4);
        let m = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        let packed = pack::pack_model(&m);
        let img = vec![1.0; m.image_len()];
        let logits = m.forward(&packed, &img, true).unwrap();
        assert_eq!(logits.len(), 3);
    }

    #[test]
    fn forward_rejects_bad_image_len() {
        let base = resnet::resnet_small(1, 10);
        let plan = uniform_plan(&base, 8);
        let m = XmpModel::synthetic(&base, &plan, XmpConfig::default()).unwrap();
        let packed = pack::pack_model(&m);
        assert!(m.forward(&packed, &[0.0; 7], true).is_err());
    }

    #[test]
    fn pools_behave() {
        // avg: channel means rounded; max: stride-2 windows with edge clamp.
        let act = vec![0u8, 10, 2, 10, 4, 10, 6, 10]; // 2x2 map, 2 channels
        assert_eq!(avg_pool(&act, 2, 2), vec![3, 10]);
        let m = max_pool2(&act, 2, 2);
        assert_eq!(m, vec![6, 10]);
        // 3x3 single-channel map: SAME pooling -> 2x2 output.
        let act3: Vec<u8> = (1..=9).collect();
        assert_eq!(max_pool2(&act3, 3, 1), vec![5, 6, 8, 9]);
    }

    #[test]
    fn prop_avg_pool_bounds() {
        forall(300, |rng| {
            let h = 1 + rng.range(0, 6) as u32;
            let c = 1 + rng.range(0, 4) as u32;
            let act: Vec<u8> = (0..(h * h * c) as usize)
                .map(|_| rng.range(0, 256) as u8)
                .collect();
            let p = avg_pool(&act, h, c);
            check_eq(p.len(), c as usize, "one value per channel")?;
            for (ch, &v) in p.iter().enumerate() {
                let vals: Vec<u8> = act
                    .chunks_exact(c as usize)
                    .map(|px| px[ch])
                    .collect();
                let (lo, hi) = (
                    *vals.iter().min().unwrap(),
                    *vals.iter().max().unwrap(),
                );
                check(v >= lo && v <= hi, "mean within [min, max]")?;
            }
            Ok(())
        });
    }
}
