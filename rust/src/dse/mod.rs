//! The holistic DSE driver — the paper's Fig 2 flowchart end to end.
//!
//! Phases:
//! 1. **PE DSE** (blue box): rank the PE design space at the CNN's
//!    MAC-weighted average word-length; keep the best family per slice `k`
//!    and derive the max-feasible-PE threshold from the LUT budget.
//! 2. **PE-array DSE** (red box): exhaustive (H, W, D) search per `k` under
//!    the hardware constraints, maximizing frames/s (Ops/resources with the
//!    Eq-3 utilization in the loop).
//! 3. **Dataflow / system evaluation** (green box): full simulation with
//!    roofline bandwidth feedback; pick the best `k` for the CNN.
//!
//! "To reach highest throughput for each uniquely quantized CNN, the DSE …
//! has to be repeated … As a result, a new FPGA accelerator design is
//! created" — [`explore`] is exactly that per-CNN repetition.

use crate::array::search::{search_dims, ArrayChoice, SearchParams};
use crate::cnn::{workload, Cnn};
use crate::config::RunConfig;
use crate::pe::dse::{best_for, evaluate, PeEval};
use crate::pe::PeDesign;
use crate::sim::{simulate, AcceleratorDesign, SimResult};

/// Result of the holistic DSE for one (CNN, k) pair.
#[derive(Clone, Debug)]
pub struct DseOutcome {
    pub k: u32,
    pub pe_eval: PeEval,
    /// Max feasible PE count from the LUT budget alone (the §IV-B
    /// "threshold of PEs bound for the design space").
    pub max_pe_threshold: u64,
    pub array: ArrayChoice,
    pub sim: SimResult,
}

/// Result of the DSE across all candidate slices.
#[derive(Clone, Debug)]
pub struct DseReport {
    pub cnn_name: String,
    pub avg_wq: f64,
    pub per_k: Vec<DseOutcome>,
    /// Index into `per_k` of the frames/s winner.
    pub best: usize,
}

impl DseReport {
    pub fn best_outcome(&self) -> &DseOutcome {
        &self.per_k[self.best]
    }
}

/// Run the full DSE for one quantized CNN at a fixed operand slice `k`.
pub fn explore_k(cnn: &Cnn, cfg: &RunConfig, k: u32) -> DseOutcome {
    let pe = PeDesign::bp_st_1d(k);
    let pe_eval = evaluate(&pe, workload::mac_weighted_avg_wq(cnn).round() as u32);
    let max_pe_threshold =
        (cfg.lut_budget() as f64 / crate::pe::cost::lut_cost(&pe)).floor() as u64;
    let params = SearchParams::from_config(cfg);
    let array = search_dims(cnn, &pe, &params);
    let design = AcceleratorDesign::new(pe, array.dims, cnn, cfg);
    let sim = simulate(cnn, &design);
    DseOutcome {
        k,
        pe_eval,
        max_pe_threshold,
        array,
        sim,
    }
}

/// Run the full DSE over every candidate slice and pick the fps winner.
pub fn explore(cnn: &Cnn, cfg: &RunConfig) -> DseReport {
    assert!(!cfg.slices.is_empty());
    let per_k: Vec<DseOutcome> = cfg.slices.iter().map(|&k| explore_k(cnn, cfg, k)).collect();
    let best = per_k
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.sim.fps.partial_cmp(&b.sim.fps).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    DseReport {
        cnn_name: cnn.name.clone(),
        avg_wq: workload::mac_weighted_avg_wq(cnn),
        per_k,
        best,
    }
}

/// Sanity gate used by `main` and tests: does the PE-level DSE still pick
/// BP-ST-1D for this CNN's average word-length? (It must, per Fig 6.)
pub fn pe_winner_for(cnn: &Cnn, cfg: &RunConfig) -> PeEval {
    let avg = workload::mac_weighted_avg_wq(cnn).round().max(1.0) as u32;
    best_for(&cfg.slices, avg.min(8))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;
    use crate::pe::{Consolidation, InputMode, Scaling};

    #[test]
    fn full_dse_resnet18_wq2() {
        let cnn = resnet::resnet18().with_uniform_wq(2);
        let cfg = RunConfig::default();
        let report = explore(&cnn, &cfg);
        assert_eq!(report.per_k.len(), 3);
        let best = report.best_outcome();
        // Paper Fig 9 / Table IV: for a w_Q=2 CNN the k=1 or k=2 design wins
        // throughput (k=4 wastes slices).
        assert!(best.k <= 2, "best k={} for a 2-bit CNN", best.k);
        assert!(best.sim.fps > 100.0, "fps={}", best.sim.fps);
    }

    #[test]
    fn pe_winner_is_bp_st_1d() {
        let cnn = resnet::resnet18().with_uniform_wq(2);
        let w = pe_winner_for(&cnn, &RunConfig::default());
        assert_eq!(w.design.mode, InputMode::BitParallel);
        assert_eq!(w.design.consolidation, Consolidation::SumTogether);
        assert_eq!(w.design.scaling, Scaling::OneD);
    }

    #[test]
    fn threshold_bounds_array() {
        let cnn = resnet::resnet18().with_uniform_wq(8);
        let cfg = RunConfig::default();
        for out in explore(&cnn, &cfg).per_k {
            assert!(
                out.array.n_pe <= out.max_pe_threshold,
                "k={}: array {} exceeds threshold {}",
                out.k,
                out.array.n_pe,
                out.max_pe_threshold
            );
        }
    }

    #[test]
    fn deeper_cnn_same_methodology() {
        // The DSE must run unchanged on ResNet-50 (bottleneck blocks).
        let cnn = resnet::resnet50().with_uniform_wq(2);
        let cfg = RunConfig {
            slices: vec![2],
            ..RunConfig::default()
        };
        let report = explore(&cnn, &cfg);
        let best = report.best_outcome();
        assert!(best.sim.fps > 10.0);
        assert!(best.sim.gops > 100.0);
    }
}
