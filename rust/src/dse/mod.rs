//! The holistic DSE driver — the paper's Fig 2 flowchart end to end.
//!
//! Phases:
//! 1. **PE DSE** (blue box): rank the PE design space at the CNN's
//!    MAC-weighted average word-length; keep the best family per slice `k`
//!    and derive the max-feasible-PE threshold from the LUT budget.
//! 2. **PE-array DSE** (red box): exhaustive (H, W, D) search per `k` under
//!    the hardware constraints, maximizing frames/s (Ops/resources with the
//!    Eq-3 utilization in the loop).
//! 3. **Dataflow / system evaluation** (green box): full simulation with
//!    roofline bandwidth feedback; pick the best `k` for the CNN.
//!
//! "To reach highest throughput for each uniquely quantized CNN, the DSE …
//! has to be repeated … As a result, a new FPGA accelerator design is
//! created" — [`explore`] is exactly that per-CNN repetition.

use crate::array::search::{search_dims, ArrayChoice, SearchParams};
use crate::cnn::{workload, Cnn};
use crate::config::RunConfig;
use crate::pe::dse::{best_for, evaluate, PeEval};
use crate::pe::PeDesign;
use crate::sim::{simulate, AcceleratorDesign, SimResult};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Result of the holistic DSE for one (CNN, k) pair.
#[derive(Clone, Debug)]
pub struct DseOutcome {
    pub k: u32,
    pub pe_eval: PeEval,
    /// Max feasible PE count from the LUT budget alone (the §IV-B
    /// "threshold of PEs bound for the design space").
    pub max_pe_threshold: u64,
    pub array: ArrayChoice,
    pub sim: SimResult,
}

/// Result of the DSE across all candidate slices.
#[derive(Clone, Debug)]
pub struct DseReport {
    pub cnn_name: String,
    pub avg_wq: f64,
    pub per_k: Vec<DseOutcome>,
    /// Index into `per_k` of the frames/s winner.
    pub best: usize,
}

impl DseReport {
    pub fn best_outcome(&self) -> &DseOutcome {
        &self.per_k[self.best]
    }
}

/// Run the full DSE for one quantized CNN at a fixed operand slice `k`.
pub fn explore_k(cnn: &Cnn, cfg: &RunConfig, k: u32) -> DseOutcome {
    let pe = PeDesign::bp_st_1d(k);
    let pe_eval = evaluate(&pe, workload::mac_weighted_avg_wq(cnn).round() as u32);
    let max_pe_threshold =
        (cfg.lut_budget() as f64 / crate::pe::cost::lut_cost(&pe)).floor() as u64;
    let params = SearchParams::from_config(cfg);
    let array = search_dims(cnn, &pe, &params);
    let design = AcceleratorDesign::new(pe, array.dims, cnn, cfg);
    let sim = simulate(cnn, &design);
    DseOutcome {
        k,
        pe_eval,
        max_pe_threshold,
        array,
        sim,
    }
}

/// Shared driver for [`explore`]/[`explore_cached`]: fan the per-slice DSE
/// out over scoped threads (each slice's array search additionally
/// parallelizes its own H scan, splitting the machine via the active-search
/// budget) and pick the fps winner. Slice order in `per_k`, and therefore
/// tie-breaking, is identical to a sequential scan.
fn explore_with(
    cnn: &Cnn,
    cfg: &RunConfig,
    per_slice: impl Fn(u32) -> DseOutcome + Sync,
) -> DseReport {
    assert!(!cfg.slices.is_empty());
    let mut slots: Vec<Option<DseOutcome>> = (0..cfg.slices.len()).map(|_| None).collect();
    let per_slice = &per_slice;
    std::thread::scope(|s| {
        for (slot, &k) in slots.iter_mut().zip(cfg.slices.iter()) {
            s.spawn(move || *slot = Some(per_slice(k)));
        }
    });
    let per_k: Vec<DseOutcome> = slots.into_iter().map(|o| o.unwrap()).collect();
    let best = per_k
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.sim.fps.partial_cmp(&b.sim.fps).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    DseReport {
        cnn_name: cnn.name.clone(),
        avg_wq: workload::mac_weighted_avg_wq(cnn),
        per_k,
        best,
    }
}

/// Run the full DSE over every candidate slice concurrently and pick the
/// fps winner.
pub fn explore(cnn: &Cnn, cfg: &RunConfig) -> DseReport {
    explore_with(cnn, cfg, |k| explore_k(cnn, cfg, k))
}

/// Memoizes [`explore_k`] results so the serving path and the report
/// generators stop recomputing identical searches. Keyed by the CNN's
/// structural [`Cnn::fingerprint`], the operand slice, and every
/// [`RunConfig`] field the outcome depends on (budgets, BRAM geometry, DDR
/// bandwidth, activation word-length). Bounded: the map is cleared when it
/// exceeds [`DseCache::CAPACITY`] entries, which is far beyond any one
/// process's distinct-workload count.
/// Structural cache key: (CNN fingerprint, k, LUT budget, BRAM budget,
/// BRAM bits, DDR bandwidth bits, activation bits). A tuple with `Eq`
/// rather than a pre-collapsed hash, so only a full `Cnn::fingerprint`
/// collision — not a key-hash collision — could ever alias two entries.
type CacheKey = (u64, u32, u64, u64, u64, u64, u32);

pub struct DseCache {
    map: Mutex<HashMap<CacheKey, DseOutcome>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl Default for DseCache {
    fn default() -> Self {
        Self::new()
    }
}

impl DseCache {
    pub const CAPACITY: usize = 64;

    pub fn new() -> DseCache {
        DseCache {
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Process-wide shared cache (the serving path, CLI, and report
    /// generators all funnel through this one).
    pub fn global() -> &'static DseCache {
        static GLOBAL: OnceLock<DseCache> = OnceLock::new();
        GLOBAL.get_or_init(DseCache::new)
    }

    /// (hits, misses) so far.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
        )
    }

    /// Every input the DSE outcome depends on, as a structurally comparable
    /// key (the CNN contributes via its FNV-1a [`Cnn::fingerprint`]).
    fn key(cnn: &Cnn, cfg: &RunConfig, k: u32) -> CacheKey {
        (
            cnn.fingerprint(),
            k,
            cfg.lut_budget(),
            cfg.bram_budget(),
            cfg.fpga.bram_bits,
            cfg.fpga.ddr_bw_bytes_per_s.to_bits(),
            cfg.act_bits,
        )
    }
}

/// [`explore_k`], memoized through `cache`. The first call per distinct
/// (CNN, config, k) runs the real search; subsequent calls are a hash-map
/// lookup plus a clone of the outcome.
pub fn explore_k_cached(cnn: &Cnn, cfg: &RunConfig, k: u32, cache: &DseCache) -> DseOutcome {
    let key = DseCache::key(cnn, cfg, k);
    if let Some(hit) = cache.map.lock().unwrap().get(&key) {
        cache.hits.fetch_add(1, Ordering::Relaxed);
        return hit.clone();
    }
    cache.misses.fetch_add(1, Ordering::Relaxed);
    let out = explore_k(cnn, cfg, k);
    let mut map = cache.map.lock().unwrap();
    if map.len() >= DseCache::CAPACITY {
        map.clear();
    }
    map.insert(key, out.clone());
    out
}

/// [`explore`], memoized per slice through `cache`.
pub fn explore_cached(cnn: &Cnn, cfg: &RunConfig, cache: &DseCache) -> DseReport {
    explore_with(cnn, cfg, |k| explore_k_cached(cnn, cfg, k, cache))
}

/// Sanity gate used by `main` and tests: does the PE-level DSE still pick
/// BP-ST-1D for this CNN's average word-length? (It must, per Fig 6.)
pub fn pe_winner_for(cnn: &Cnn, cfg: &RunConfig) -> PeEval {
    let avg = workload::mac_weighted_avg_wq(cnn).round().max(1.0) as u32;
    best_for(&cfg.slices, avg.min(8))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;
    use crate::pe::{Consolidation, InputMode, Scaling};

    #[test]
    fn full_dse_resnet18_wq2() {
        let cnn = resnet::resnet18().with_uniform_wq(2);
        let cfg = RunConfig::default();
        let report = explore(&cnn, &cfg);
        assert_eq!(report.per_k.len(), 3);
        let best = report.best_outcome();
        // Paper Fig 9 / Table IV: for a w_Q=2 CNN the k=1 or k=2 design wins
        // throughput (k=4 wastes slices).
        assert!(best.k <= 2, "best k={} for a 2-bit CNN", best.k);
        assert!(best.sim.fps > 100.0, "fps={}", best.sim.fps);
    }

    #[test]
    fn pe_winner_is_bp_st_1d() {
        let cnn = resnet::resnet18().with_uniform_wq(2);
        let w = pe_winner_for(&cnn, &RunConfig::default());
        assert_eq!(w.design.mode, InputMode::BitParallel);
        assert_eq!(w.design.consolidation, Consolidation::SumTogether);
        assert_eq!(w.design.scaling, Scaling::OneD);
    }

    #[test]
    fn threshold_bounds_array() {
        let cnn = resnet::resnet18().with_uniform_wq(8);
        let cfg = RunConfig::default();
        for out in explore(&cnn, &cfg).per_k {
            assert!(
                out.array.n_pe <= out.max_pe_threshold,
                "k={}: array {} exceeds threshold {}",
                out.k,
                out.array.n_pe,
                out.max_pe_threshold
            );
        }
    }

    #[test]
    fn cache_hits_and_returns_identical_outcome() {
        let cnn = resnet::resnet18().with_uniform_wq(2);
        let cfg = RunConfig::default();
        let cache = DseCache::new();
        let a = explore_k_cached(&cnn, &cfg, 2, &cache);
        let b = explore_k_cached(&cnn, &cfg, 2, &cache);
        assert_eq!(cache.stats(), (1, 1), "second call must hit");
        assert_eq!(a.array.dims, b.array.dims);
        assert_eq!(a.sim.fps.to_bits(), b.sim.fps.to_bits());
        // Uncached path agrees with what the cache stored.
        let c = explore_k(&cnn, &cfg, 2);
        assert_eq!(a.array.dims, c.array.dims);
        assert_eq!(a.sim.fps.to_bits(), c.sim.fps.to_bits());
    }

    #[test]
    fn cache_key_separates_configs_and_cnns() {
        let cfg = RunConfig::default();
        let cache = DseCache::new();
        let cnn2 = resnet::resnet18().with_uniform_wq(2);
        let cnn8 = resnet::resnet18().with_uniform_wq(8);
        let r2 = explore_k_cached(&cnn2, &cfg, 2, &cache);
        let r8 = explore_k_cached(&cnn8, &cfg, 2, &cache);
        assert_eq!(cache.stats(), (0, 2), "different wq must miss");
        assert!(r2.sim.fps > r8.sim.fps);

        let mut starved = cfg.clone();
        starved.fpga.ddr_bw_bytes_per_s = 0.2e9;
        let rs = explore_k_cached(&cnn8, &starved, 2, &cache);
        assert_eq!(cache.stats(), (0, 3), "different DDR bandwidth must miss");
        assert!(rs.sim.fps < r8.sim.fps);
    }

    #[test]
    fn explore_cached_matches_explore() {
        let cnn = resnet::resnet18().with_uniform_wq(4);
        let cfg = RunConfig::default();
        let cache = DseCache::new();
        let plain = explore(&cnn, &cfg);
        let cached = explore_cached(&cnn, &cfg, &cache);
        assert_eq!(plain.best, cached.best);
        for (a, b) in plain.per_k.iter().zip(&cached.per_k) {
            assert_eq!(a.k, b.k);
            assert_eq!(a.array.dims, b.array.dims);
            assert_eq!(a.sim.fps.to_bits(), b.sim.fps.to_bits());
        }
    }

    #[test]
    fn deeper_cnn_same_methodology() {
        // The DSE must run unchanged on ResNet-50 (bottleneck blocks).
        let cnn = resnet::resnet50().with_uniform_wq(2);
        let cfg = RunConfig {
            slices: vec![2],
            ..RunConfig::default()
        };
        let report = explore(&cnn, &cfg);
        let best = report.best_outcome();
        assert!(best.sim.fps > 10.0);
        assert!(best.sim.gops > 100.0);
    }
}
