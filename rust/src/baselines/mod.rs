//! Baseline accelerator designs the paper compares against, implemented on
//! the same simulator so comparisons are apples-to-apples:
//!
//! - **DSP-only** array: the conventional approach of mapping MACs to the
//!   FPGA's 256 DSP hardmacros (what [15] does on the same Stratix V).
//! - **Fixed 8-bit LUT** array: LUT-fabric MACs without precision slicing
//!   (a conventional PE, Fig 1a) — word-length reduction buys nothing.
//! - **BitFusion-style 2D** array: k=2 two-dimensional scaling [28][29] —
//!   flexibility on both operands, paid in area.
//!
//! Plus the published reference rows of Table V ([26] FINN-R, [34] Maki,
//! [15] Ma, [27] Nguyen) as constants for the comparison table.

use crate::array::Dims;
use crate::cnn::Cnn;
use crate::config::RunConfig;
use crate::pe::{Consolidation, InputMode, PeDesign, Scaling};
use crate::sim::{simulate, AcceleratorDesign, SimResult};

/// A DSP-hardmacro MAC array: 256 PEs (one per DSP), 8×8 fixed, clocked at
/// the hardmacro's comfortable 200 MHz on this node.
pub fn dsp_only_design(cnn: &Cnn, cfg: &RunConfig) -> AcceleratorDesign {
    // Arrange the 256 DSPs as 4x2x32 (H,W,D) — the best square-ish split
    // for ResNet shapes found by a mini-search over divisors of 256.
    let pe = PeDesign::conventional();
    let mut d = AcceleratorDesign::new(pe, Dims::new(4, 2, 32), cnn, cfg);
    d.fmax_mhz = 200.0;
    d.luts = 30_000; // control + buffers only
    d
}

/// Fixed 8-bit LUT-fabric array (no slicing): conventional PEs fill the
/// logic budget.
pub fn fixed8_lut_design(cnn: &Cnn, cfg: &RunConfig) -> AcceleratorDesign {
    let pe = PeDesign::conventional();
    let params = crate::array::search::SearchParams::from_config(cfg);
    let choice = crate::array::search::search_dims(cnn, &pe, &params);
    AcceleratorDesign::new(pe, choice.dims, cnn, cfg)
}

/// BitFusion-style design: BP-ST-**2D** with k=2 [28][29].
pub fn bitfusion_style_design(cnn: &Cnn, cfg: &RunConfig) -> AcceleratorDesign {
    let pe = PeDesign::new(
        InputMode::BitParallel,
        Consolidation::SumTogether,
        Scaling::TwoD,
        2,
    );
    let params = crate::array::search::SearchParams::from_config(cfg);
    let choice = crate::array::search::search_dims(cnn, &pe, &params);
    AcceleratorDesign::new(pe, choice.dims, cnn, cfg)
}

/// Simulate a named baseline. Returns (design description, result).
pub fn run_baseline(which: &str, cnn: &Cnn, cfg: &RunConfig) -> Option<(String, SimResult)> {
    let d = match which {
        "dsp" => dsp_only_design(cnn, cfg),
        "fixed8" => fixed8_lut_design(cnn, cfg),
        "bitfusion" => bitfusion_style_design(cnn, cfg),
        _ => return None,
    };
    let r = simulate(cnn, &d);
    Some((d.pe.tag(), r))
}

/// A published reference row of Table V.
#[derive(Clone, Debug)]
pub struct ReferenceRow {
    pub cite: &'static str,
    pub cnn: &'static str,
    pub fpga: &'static str,
    pub wq: &'static str,
    pub f_mhz: f64,
    pub gops: f64,
    pub fps: Option<f64>,
    pub top5: Option<f64>,
    pub dsps: u64,
    pub kluts: f64,
    pub channel_wise: bool,
}

/// Table V reference rows, verbatim from the paper.
pub fn table5_references() -> Vec<ReferenceRow> {
    vec![
        ReferenceRow {
            cite: "[26] FINN-R",
            cnn: "DoReFaNet",
            fpga: "PYNQ-Z1",
            wq: "1 (acts 2)",
            f_mhz: 100.0,
            gops: 258.0,
            fps: None,
            top5: Some(74.0),
            dsps: 0,
            kluts: 35.7,
            channel_wise: false,
        },
        ReferenceRow {
            cite: "[34] Maki",
            cnn: "ResNet-50",
            fpga: "ZCU102",
            wq: "1-16",
            f_mhz: 100.0,
            gops: 95.4,
            fps: None,
            top5: Some(91.9),
            dsps: 0,
            kluts: 57.0,
            channel_wise: true,
        },
        ReferenceRow {
            cite: "[15] Ma",
            cnn: "ResNet-152",
            fpga: "Stratix V",
            wq: "16",
            f_mhz: 150.0,
            gops: 276.6,
            fps: Some(12.23),
            top5: None,
            dsps: 256,
            kluts: 370.0,
            channel_wise: false,
        },
        ReferenceRow {
            cite: "[27] Nguyen",
            cnn: "ResNet-152",
            fpga: "Virtex 7",
            wq: "8",
            f_mhz: 200.0,
            gops: 726.0,
            fps: Some(32.1),
            top5: None,
            dsps: 2515,
            kluts: 280.4,
            channel_wise: true,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;

    #[test]
    fn sliced_design_beats_dsp_only() {
        // The motivating claim: LUT-fabric sliced PEs out-throughput the 256
        // DSPs by a wide margin (paper: 4.09x vs Ma [15] on ResNet-152).
        let cnn = resnet::resnet152().with_uniform_wq(2);
        let cfg = RunConfig::default();
        let dsp = simulate(&cnn, &dsp_only_design(&cnn, &cfg));
        let ours = crate::dse::explore_k(&cnn, &cfg, 2).sim;
        assert!(
            ours.gops > 2.0 * dsp.gops,
            "ours {:.0} GOps/s vs dsp {:.0}",
            ours.gops,
            dsp.gops
        );
    }

    #[test]
    fn sliced_beats_fixed8_on_quantized_cnn() {
        // On a w_Q=2 CNN the sliced design must beat the fixed-8bit LUT
        // design; on w_Q=8 they should be comparable.
        let cfg = RunConfig::default();
        let cnn2 = resnet::resnet18().with_uniform_wq(2);
        let fixed = simulate(&cnn2, &fixed8_lut_design(&cnn2, &cfg));
        let ours = crate::dse::explore_k(&cnn2, &cfg, 2).sim;
        assert!(
            ours.fps > 1.5 * fixed.fps,
            "sliced {:.0} fps vs fixed {:.0} fps",
            ours.fps,
            fixed.fps
        );
    }

    #[test]
    fn one_d_beats_bitfusion_2d_at_fixed_acts() {
        // Fig 6's architecture conclusion at the system level.
        let cfg = RunConfig::default();
        let cnn = resnet::resnet18().with_uniform_wq(2);
        let bf = simulate(&cnn, &bitfusion_style_design(&cnn, &cfg));
        let ours = crate::dse::explore_k(&cnn, &cfg, 2).sim;
        assert!(
            ours.fps > bf.fps,
            "1D {:.0} fps vs 2D {:.0} fps",
            ours.fps,
            bf.fps
        );
    }

    #[test]
    fn reference_rows_complete() {
        let refs = table5_references();
        assert_eq!(refs.len(), 4);
        assert!(refs.iter().any(|r| r.cite.contains("[27]")));
        // Paper's speedup claims recomputable from rows:
        let ma = refs.iter().find(|r| r.cite.contains("[15]")).unwrap();
        assert!((1131.38 / ma.gops - 4.09).abs() < 0.01);
        let ng = refs.iter().find(|r| r.cite.contains("[27]")).unwrap();
        assert!((1131.38 / ng.gops - 1.56).abs() < 0.01);
    }

    #[test]
    fn run_baseline_dispatch() {
        let cnn = resnet::resnet_small(1, 10).with_uniform_wq(4);
        let cfg = RunConfig::default();
        assert!(run_baseline("dsp", &cnn, &cfg).is_some());
        assert!(run_baseline("nope", &cnn, &cfg).is_none());
    }
}
