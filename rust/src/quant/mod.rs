//! Quantization: the paper's LSQ-style quantizer (Eq 5) and the
//! two's-complement bit-slicing that feeds the PPG datapath.
//!
//! Activations are quantized unsigned (`Q_n = 0, Q_p = 2^b - 1`), weights
//! signed (`Q_n = -2^{b-1}, Q_p = 2^{b-1} - 1`), both with a trained step
//! size γ (Eq 5). The same math lives in `python/compile/quantize.py`; the
//! python tests cross-check the two implementations through exported vectors.

pub mod lsq;
pub mod slicing;

pub use lsq::{QuantParams, Quantizer};
pub use slicing::{reconstruct_slices, slice_digit, slice_signed, slice_unsigned};
