//! Two's-complement bit-slicing of integer operands into `k`-bit digits —
//! the operand preparation for the PPG datapath (Fig 1b), on **both** MAC
//! operands of the 2D-scaled designs (Table IV's operand-slice axis).
//!
//! A `w`-bit **signed** integer (weights) is decomposed into `ceil(w/k)`
//! digits of `k` bits each: the low digits are unsigned in `[0, 2^k)`, the
//! top digit is signed (two's-complement weight `-2^{b-1}..2^{b-1}-1` over
//! its `b = w - k·(S-1)` remaining bits, scaled by its position) so that
//!
//! `value = Σ_{s<S-1} d_s · 2^{k·s}  +  d_{S-1} · 2^{k·(S-1)}`  (d_{S-1} signed)
//!
//! holds *exactly*. A `w`-bit **unsigned** integer (activations) decomposes
//! the same way except that *every* digit — the possibly-partial top digit
//! included — is unsigned: when `w` is an exact multiple of `k` (the
//! `w == aq` top-digit case of an activation sliced at its own word-length)
//! the top digit spans the full `[0, 2^k)`, never the signed reading.
//! The Pallas kernel (`python/compile/kernels/bitslice.py`) performs the
//! same decomposition; the identity is property-tested on both sides and is
//! the correctness anchor of the whole mixed-precision datapath.

/// Number of `k`-bit slices needed for a `w`-bit operand.
pub fn n_slices(w: u32, k: u32) -> u32 {
    w.div_ceil(k)
}

/// Slice a **signed** `w`-bit integer (`-2^{w-1} <= v < 2^{w-1}`) into
/// `ceil(w/k)` digits, least-significant first. The last digit is signed;
/// all earlier digits are in `[0, 2^k)`.
pub fn slice_signed(v: i64, w: u32, k: u32) -> Vec<i64> {
    assert!(w >= 1 && k >= 1);
    let lo = -(1i64 << (w - 1));
    let hi = (1i64 << (w - 1)) - 1;
    assert!(
        (lo..=hi).contains(&v),
        "value {v} out of signed {w}-bit range"
    );
    let s = n_slices(w, k);
    let mut out = Vec::with_capacity(s as usize);
    // Work on the unsigned two's-complement image confined to w bits
    // (`w` <= 32 in practice; the branch avoids shift overflow at w = 64).
    let mut u = (v as u64) & if w >= 64 { u64::MAX } else { (1u64 << w) - 1 };
    for i in 0..s {
        let remaining = w - i * k;
        let digit_bits = remaining.min(k);
        let digit = (u & ((1u64 << digit_bits) - 1)) as i64;
        if i == s - 1 {
            // Top digit: reinterpret as signed over `digit_bits`, i.e. the
            // two's-complement weight of the MSB is negative.
            let sign_bit = 1i64 << (digit_bits - 1);
            let signed_digit = if digit & sign_bit != 0 {
                digit - (1i64 << digit_bits)
            } else {
                digit
            };
            out.push(signed_digit);
        } else {
            out.push(digit);
        }
        u >>= digit_bits;
    }
    out
}

/// Slice an **unsigned** `w`-bit integer into `ceil(w/k)` unsigned digits,
/// least-significant first — the activation side of the 2D-sliced MAC.
///
/// Every digit is unsigned: low digits in `[0, 2^k)`, the top digit in
/// `[0, 2^b)` over its `b = w - k·(S-1)` remaining bits. In particular for
/// the `w == aq` top-digit case (`w` an exact multiple of `k`) the top
/// digit covers the full `[0, 2^k)` — it is **not** reinterpreted as
/// signed the way [`slice_signed`]'s top digit is. (The doc used to leave
/// this open while the module header described only the signed reading;
/// the behavior — plain unsigned masking — was always the intended one
/// for activations and is now the documented contract.)
///
/// Supports the full `u64` range it claims: any `w <= 64` with `k <= 63`
/// (a digit wider than 63 bits would overflow both the mask and the `i64`
/// digit type, so `k >= 64` — previously accepted and overflowing — is now
/// rejected up front). Round-trips exactly through
/// [`reconstruct_slices_unsigned`]; the `i64`-summing
/// [`reconstruct_slices`] is exact only for values below `2^63`.
pub fn slice_unsigned(v: u64, w: u32, k: u32) -> Vec<i64> {
    assert!(w >= 1 && w <= 64, "need 1 <= w <= 64, got w={w}");
    assert!(k >= 1 && k <= 63, "digit width k must be in 1..=63, got {k}");
    assert!(
        w >= 64 || v < (1u64 << w),
        "value {v} out of unsigned {w}-bit range"
    );
    let s = n_slices(w, k);
    let mut out = Vec::with_capacity(s as usize);
    let mut u = v;
    for i in 0..s {
        let remaining = w - i * k;
        let digit_bits = remaining.min(k);
        out.push((u & ((1u64 << digit_bits) - 1)) as i64);
        u >>= digit_bits;
    }
    out
}

/// Extract digit `idx` of the unsigned `ceil(w/k)`-digit decomposition of
/// `v` without materializing the whole digit vector — the allocation-free
/// form the xmp scalar reference kernel extracts activation digits with
/// inside its MAC loop. Property-tested identical to
/// `slice_unsigned(v, w, k)[idx]`.
#[inline]
pub fn slice_digit_unsigned(v: u64, w: u32, k: u32, idx: u32) -> i64 {
    debug_assert!(w >= 1 && w <= 64 && k >= 1 && k <= 63);
    let s = n_slices(w, k);
    debug_assert!(idx < s, "slice {idx} out of range for {s} slices");
    debug_assert!(w >= 64 || v < (1u64 << w), "value out of unsigned range");
    let lo_bit = k * idx;
    let digit_bits = (w - lo_bit).min(k);
    ((v >> lo_bit) & ((1u64 << digit_bits) - 1)) as i64
}

/// Reconstruct an unsigned value from its unsigned digits in `u64`
/// arithmetic: `Σ d_s · 2^{k·s}` — exact over the full `u64` range
/// [`slice_unsigned`] supports (unlike the `i64`-summing
/// [`reconstruct_slices`], which overflows above `2^63`).
pub fn reconstruct_slices_unsigned(digits: &[i64], k: u32) -> u64 {
    digits
        .iter()
        .enumerate()
        .fold(0u64, |acc, (i, &d)| {
            debug_assert!(d >= 0, "unsigned digits must be non-negative");
            acc.wrapping_add((d as u64).wrapping_shl(k * i as u32))
        })
}

/// Extract digit `idx` of the `ceil(w/k)`-digit decomposition of `v`
/// without materializing the whole digit vector — the allocation-free form
/// the xmp scalar reference kernel computes with inside its MAC loop.
/// Property-tested identical to `slice_signed(v, w, k)[idx]`.
#[inline]
pub fn slice_digit(v: i64, w: u32, k: u32, idx: u32) -> i64 {
    debug_assert!(w >= 1 && k >= 1);
    let s = n_slices(w, k);
    debug_assert!(idx < s, "slice {idx} out of range for {s} slices");
    let u = (v as u64) & if w >= 64 { u64::MAX } else { (1u64 << w) - 1 };
    let lo_bit = k * idx;
    let digit_bits = (w - lo_bit).min(k);
    let digit = ((u >> lo_bit) & ((1u64 << digit_bits) - 1)) as i64;
    if idx == s - 1 {
        // Top digit: two's-complement weight of its MSB is negative.
        let sign_bit = 1i64 << (digit_bits - 1);
        if digit & sign_bit != 0 {
            return digit - (1i64 << digit_bits);
        }
    }
    digit
}

/// Reconstruct the integer from its digits: `Σ d_s · 2^{k·s}`.
pub fn reconstruct_slices(digits: &[i64], k: u32) -> i64 {
    digits
        .iter()
        .enumerate()
        .map(|(i, d)| d << (k as usize * i))
        .sum()
}

/// Shift weight (power of two) each slice contributes — what the BP-ST
/// adder tree applies before summation.
pub fn slice_weight(slice_idx: u32, k: u32) -> i64 {
    1i64 << (k * slice_idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_eq, forall};
    use crate::util::rng::Rng;

    #[test]
    fn known_decompositions() {
        // -1 in 8-bit, k=2: 0b11111111 -> digits [3, 3, 3, -1]
        assert_eq!(slice_signed(-1, 8, 2), vec![3, 3, 3, -1]);
        // 0b0110_1010 = 106, k=4 -> [0xA, 0x6]
        assert_eq!(slice_signed(106, 8, 4), vec![0xA, 0x6]);
        // -128, k=4 -> [0, -8]
        assert_eq!(slice_signed(-128, 8, 4), vec![0, -8]);
        // w=1 (binary weights): values -1, 0
        assert_eq!(slice_signed(-1, 1, 1), vec![-1]);
        assert_eq!(slice_signed(0, 1, 1), vec![0]);
    }

    #[test]
    fn n_slices_rounding() {
        assert_eq!(n_slices(8, 2), 4);
        assert_eq!(n_slices(8, 3), 3);
        assert_eq!(n_slices(1, 2), 1);
        assert_eq!(n_slices(4, 4), 1);
    }

    #[test]
    fn single_slice_is_identity() {
        for v in -8i64..=7 {
            assert_eq!(slice_signed(v, 4, 4), vec![v]);
            assert_eq!(reconstruct_slices(&[v], 4), v);
        }
    }

    #[test]
    fn prop_signed_roundtrip_exact() {
        // The correctness anchor: slicing then reconstructing is the identity
        // for every (w, k) pair used anywhere in the stack.
        forall(5000, |rng: &mut Rng| {
            let w = *rng.choose(&[1u32, 2, 3, 4, 5, 8, 16]);
            let k = *rng.choose(&[1u32, 2, 3, 4, 8]);
            let lo = -(1i64 << (w - 1));
            let hi = (1i64 << (w - 1)) - 1;
            let v = rng.range_i64(lo, hi);
            let digits = slice_signed(v, w, k);
            check_eq(reconstruct_slices(&digits, k), v, "signed roundtrip")?;
            check_eq(digits.len() as u32, n_slices(w, k), "digit count")
        });
    }

    #[test]
    fn prop_unsigned_roundtrip_exact() {
        forall(5000, |rng: &mut Rng| {
            let w = *rng.choose(&[1u32, 2, 4, 8, 16]);
            let k = *rng.choose(&[1u32, 2, 4]);
            let v = rng.below(1u64 << w);
            let digits = slice_unsigned(v, w, k);
            check_eq(reconstruct_slices(&digits, k), v as i64, "unsigned roundtrip")
        });
    }

    #[test]
    fn prop_unsigned_roundtrip_full_u64_range() {
        // The satellite contract: reconstruct ∘ slice is the identity over
        // the FULL range slice_unsigned claims to support — w up to 64,
        // values up to u64::MAX, partial and exact-multiple top digits.
        forall(5000, |rng: &mut Rng| {
            let w = *rng.choose(&[1u32, 7, 8, 31, 32, 33, 63, 64]);
            let k = *rng.choose(&[1u32, 2, 3, 5, 8, 16, 63]);
            let v = if w >= 64 {
                rng.next_u64()
            } else {
                rng.below(1u64 << w)
            };
            let digits = slice_unsigned(v, w, k);
            check_eq(digits.len() as u32, n_slices(w, k), "digit count")?;
            check_eq(
                reconstruct_slices_unsigned(&digits, k),
                v,
                "full-range unsigned roundtrip",
            )
        });
        // Edge values explicitly: the extremes of the claimed range.
        for v in [0u64, 1, u64::MAX - 1, u64::MAX] {
            for k in [1u32, 8, 63] {
                let digits = slice_unsigned(v, 64, k);
                assert_eq!(reconstruct_slices_unsigned(&digits, k), v, "v={v} k={k}");
            }
        }
    }

    #[test]
    fn unsigned_top_digit_is_unsigned_at_exact_multiple() {
        // The w == aq top-digit case the doc now pins down: when w is an
        // exact multiple of k, the top digit spans the full [0, 2^k) —
        // e.g. 255 at (w=8, k=4) is [15, 15], NOT [15, -1].
        assert_eq!(slice_unsigned(255, 8, 4), vec![0xF, 0xF]);
        assert_eq!(slice_unsigned(255, 8, 2), vec![3, 3, 3, 3]);
        assert_eq!(slice_unsigned(7, 3, 3), vec![7]);
        // Contrast with the signed reading of the same bit patterns.
        assert_eq!(slice_signed(-1, 8, 4), vec![0xF, -1]);
    }

    #[test]
    fn prop_slice_digit_unsigned_matches_slice_unsigned() {
        // The allocation-free single-digit form must agree with the vector
        // decomposition on every digit, for every (w, k) — including the
        // partial-top-digit cases (w not a multiple of k) and w = aq.
        forall(5000, |rng: &mut Rng| {
            let w = *rng.choose(&[1u32, 2, 3, 4, 5, 6, 7, 8, 16, 64]);
            let k = *rng.choose(&[1u32, 2, 3, 4, 5, 8, 63]);
            let v = if w >= 64 {
                rng.next_u64()
            } else {
                rng.below(1u64 << w)
            };
            let digits = slice_unsigned(v, w, k);
            for (i, d) in digits.iter().enumerate() {
                check_eq(
                    slice_digit_unsigned(v, w, k, i as u32),
                    *d,
                    "unsigned digit extraction",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "1..=63")]
    fn unsigned_rejects_overflowing_digit_width() {
        // k = 64 used to shift-overflow the digit mask; now rejected.
        slice_unsigned(5, 64, 64);
    }

    #[test]
    #[should_panic(expected = "out of unsigned")]
    fn unsigned_rejects_out_of_range() {
        slice_unsigned(256, 8, 2);
    }

    #[test]
    fn prop_low_digits_unsigned_range() {
        forall(2000, |rng: &mut Rng| {
            let w = *rng.choose(&[4u32, 8]);
            let k = *rng.choose(&[1u32, 2]);
            let v = rng.range_i64(-(1 << (w - 1)), (1 << (w - 1)) - 1);
            let digits = slice_signed(v, w, k);
            for (i, d) in digits.iter().enumerate() {
                if i + 1 < digits.len() {
                    if !(0..(1i64 << k)).contains(d) {
                        return Err(format!("low digit {d} outside [0, 2^{k})"));
                    }
                } else {
                    let half = 1i64 << (k - 1);
                    if !(-half..half).contains(d) {
                        return Err(format!("top digit {d} outside signed {k}-bit"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_mac_linearity_over_slices() {
        // a * w == Σ_s a * d_s * 2^{ks}: the PPG + shifted adder-tree identity
        // for a full MAC (this is exactly what BP-ST computes).
        forall(3000, |rng: &mut Rng| {
            let wbits = *rng.choose(&[1u32, 2, 4, 8]);
            let k = *rng.choose(&[1u32, 2, 4]);
            let a = rng.range_i64(0, 255); // 8-bit unsigned activation
            let w = rng.range_i64(-(1 << (wbits - 1)), (1 << (wbits - 1)) - 1);
            let digits = slice_signed(w, wbits, k);
            let via_ppgs: i64 = digits
                .iter()
                .enumerate()
                .map(|(s, d)| a * d * slice_weight(s as u32, k))
                .sum();
            check_eq(via_ppgs, a * w, "PPG decomposition of MAC")
        });
    }

    #[test]
    fn prop_mac_linearity_over_2d_slices() {
        // The 2D-sliced MAC identity: a · w == Σ_{sa,sw} a_sa · w_sw ·
        // 2^{k(sa+sw)} with the activation sliced unsigned at aq and the
        // weight sliced signed at wq — what the xmp engine's slice
        // cross-product accumulation computes, including partial top
        // digits on BOTH operands.
        forall(3000, |rng: &mut Rng| {
            let wq = 1 + rng.range(0, 8) as u32;
            let aq = 1 + rng.range(0, 8) as u32;
            let k = *rng.choose(&[1u32, 2, 3, 4, 5, 8]);
            let a = rng.below(1u64 << aq);
            let w = rng.range_i64(-(1i64 << (wq - 1)), (1i64 << (wq - 1)) - 1);
            let adigits = slice_unsigned(a, aq, k);
            let wdigits = slice_signed(w, wq, k);
            let mut acc = 0i64;
            for (sa, &ad) in adigits.iter().enumerate() {
                for (sw, &wd) in wdigits.iter().enumerate() {
                    acc += (ad * wd) << (k as usize * (sa + sw));
                }
            }
            check_eq(acc, a as i64 * w, "2D PPG decomposition of MAC")
        });
    }

    #[test]
    fn prop_slice_digit_matches_slice_signed() {
        // The allocation-free single-digit form must agree with the vector
        // decomposition on every digit, for every (w, k) — including the
        // partial-top-digit cases (w not a multiple of k).
        forall(5000, |rng: &mut Rng| {
            let w = *rng.choose(&[1u32, 2, 3, 4, 5, 6, 7, 8, 16]);
            let k = *rng.choose(&[1u32, 2, 3, 4, 5, 8]);
            let v = rng.range_i64(-(1i64 << (w - 1)), (1i64 << (w - 1)) - 1);
            let digits = slice_signed(v, w, k);
            for (i, d) in digits.iter().enumerate() {
                check_eq(slice_digit(v, w, k, i as u32), *d, "digit extraction")?;
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "out of signed")]
    fn rejects_out_of_range() {
        slice_signed(200, 8, 2);
    }
}
