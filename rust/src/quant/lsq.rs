//! LSQ quantizer (paper Eq 5; Esser et al. [10]).
//!
//! `v_int = round(clamp(v_FP / γ, Q_n, Q_p))`, `v_quant = v_int · γ`.
//! Round-to-nearest with ties away from zero matches `jnp.round`'s behaviour
//! closely enough for our integer ranges (ties occur only at .5 boundaries,
//! which QAT never lands on exactly after division by a learned γ; the python
//! test suite cross-checks on a shared vector set avoiding exact ties).

/// Static description of a quantizer: bit-width and signedness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantParams {
    pub bits: u32,
    pub signed: bool,
}

impl QuantParams {
    pub fn weights(bits: u32) -> QuantParams {
        QuantParams { bits, signed: true }
    }

    pub fn activations(bits: u32) -> QuantParams {
        QuantParams {
            bits,
            signed: false,
        }
    }

    /// Lower clamp bound `Q_n`.
    pub fn qn(&self) -> i64 {
        if self.signed {
            -(1i64 << (self.bits - 1))
        } else {
            0
        }
    }

    /// Upper clamp bound `Q_p`.
    pub fn qp(&self) -> i64 {
        if self.signed {
            (1i64 << (self.bits - 1)) - 1
        } else {
            (1i64 << self.bits) - 1
        }
    }

    /// Number of representable levels.
    pub fn levels(&self) -> u64 {
        (self.qp() - self.qn() + 1) as u64
    }
}

/// A quantizer with a concrete step size γ.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quantizer {
    pub params: QuantParams,
    pub gamma: f64,
}

impl Quantizer {
    pub fn new(params: QuantParams, gamma: f64) -> Quantizer {
        assert!(gamma > 0.0, "step size must be positive");
        Quantizer { params, gamma }
    }

    /// LSQ initialization: γ = 2·E[|v|] / sqrt(max(Q_p, 1)) (Esser et al.
    /// §3). The clamp covers 1-bit signed weights, whose code set {-1, 0}
    /// has Q_p = 0 — the unclamped formula degenerates to γ = ∞ and every
    /// downstream statistic (noise power, planner proxy) to NaN.
    pub fn init_from_data(params: QuantParams, data: &[f64]) -> Quantizer {
        let mean_abs = if data.is_empty() {
            1.0
        } else {
            data.iter().map(|v| v.abs()).sum::<f64>() / data.len() as f64
        };
        let gamma = (2.0 * mean_abs / (params.qp() as f64).max(1.0).sqrt()).max(1e-9);
        Quantizer::new(params, gamma)
    }

    /// Integer code for `v` (Eq 5 inner part).
    pub fn to_int(&self, v: f64) -> i64 {
        let scaled = v / self.gamma;
        let clamped = scaled.clamp(self.params.qn() as f64, self.params.qp() as f64);
        // round half away from zero
        let r = if clamped >= 0.0 {
            (clamped + 0.5).floor()
        } else {
            (clamped - 0.5).ceil()
        };
        r as i64
    }

    /// Quantized (dequantized-back) value `v_quant = v_int · γ`.
    pub fn quantize(&self, v: f64) -> f64 {
        self.to_int(v) as f64 * self.gamma
    }

    /// Dequantize an integer code.
    pub fn from_int(&self, code: i64) -> f64 {
        code as f64 * self.gamma
    }

    /// Quantize a slice to integer codes.
    pub fn to_ints(&self, vs: &[f64]) -> Vec<i64> {
        vs.iter().map(|v| self.to_int(*v)).collect()
    }

    /// Mean-squared quantization error over `vs`.
    pub fn mse(&self, vs: &[f64]) -> f64 {
        if vs.is_empty() {
            return 0.0;
        }
        vs.iter()
            .map(|v| (v - self.quantize(*v)).powi(2))
            .sum::<f64>()
            / vs.len() as f64
    }
}

/// Quantization-noise power of an LSQ-initialized `bits`-wide signed weight
/// quantizer over a fixed standard-normal reference sample (deterministic:
/// seeded through [`crate::util::rng`]). This is the per-weight noise term
/// the planner's sensitivity model aggregates — the *relative* MSE across
/// word-lengths is what matters; the absolute scale cancels against the
/// Table III calibration anchors (see `planner::sensitivity`).
pub fn reference_noise_power(bits: u32) -> f64 {
    assert!((1..=8).contains(&bits), "weight word-lengths are 1..=8 bit");
    let mut rng = crate::util::rng::Rng::new(0x5EED_11);
    let sample: Vec<f64> = (0..4096).map(|_| rng.normal()).collect();
    let q = Quantizer::init_from_data(QuantParams::weights(bits), &sample);
    q.mse(&sample)
}

/// The per-bit-width **activation** counterpart of
/// [`reference_noise_power`]: quantization-noise power of an
/// LSQ-initialized unsigned `bits`-wide activation quantizer
/// ([`QuantParams::activations`], `Q_n = 0`) over a fixed half-normal
/// reference sample — post-ReLU activations are non-negative, so `|N(0,1)|`
/// is the natural reference distribution. Deterministic (seeded through
/// [`crate::util::rng`]) and strictly decreasing in `bits`; the planner's
/// sensitivity model aggregates it as the activation word-length's noise
/// term (see `planner::sensitivity`), where — as with the weight term —
/// only the *relative* value across word-lengths matters.
pub fn reference_activation_noise_power(bits: u32) -> f64 {
    assert!(
        (1..=8).contains(&bits),
        "activation word-lengths are 1..=8 bit"
    );
    let mut rng = crate::util::rng::Rng::new(0x5EED_AC);
    let sample: Vec<f64> = (0..4096).map(|_| rng.normal().abs()).collect();
    let q = Quantizer::init_from_data(QuantParams::activations(bits), &sample);
    q.mse(&sample)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, check_close, check_eq, forall};
    use crate::util::rng::Rng;

    #[test]
    fn bounds_match_paper() {
        // Activations: Qn = 0, Qp = 2^b - 1; weights: Qn = -2^{b-1}, Qp = 2^{b-1}-1.
        let a8 = QuantParams::activations(8);
        assert_eq!((a8.qn(), a8.qp()), (0, 255));
        let w8 = QuantParams::weights(8);
        assert_eq!((w8.qn(), w8.qp()), (-128, 127));
        let w1 = QuantParams::weights(1);
        assert_eq!((w1.qn(), w1.qp()), (-1, 0));
        let w2 = QuantParams::weights(2);
        assert_eq!((w2.qn(), w2.qp()), (-2, 1));
    }

    #[test]
    fn quantize_identity_on_grid() {
        let q = Quantizer::new(QuantParams::weights(4), 0.25);
        for code in q.params.qn()..=q.params.qp() {
            let v = code as f64 * 0.25;
            assert_eq!(q.to_int(v), code);
            assert_eq!(q.quantize(v), v);
        }
    }

    #[test]
    fn clamps_to_range() {
        let q = Quantizer::new(QuantParams::weights(2), 1.0);
        assert_eq!(q.to_int(100.0), 1);
        assert_eq!(q.to_int(-100.0), -2);
        let a = Quantizer::new(QuantParams::activations(8), 0.5);
        assert_eq!(a.to_int(-3.0), 0);
        assert_eq!(a.to_int(1000.0), 255);
    }

    #[test]
    fn init_scales_with_data() {
        let small: Vec<f64> = vec![0.01; 100];
        let large: Vec<f64> = vec![10.0; 100];
        let qs = Quantizer::init_from_data(QuantParams::weights(4), &small);
        let ql = Quantizer::init_from_data(QuantParams::weights(4), &large);
        assert!(ql.gamma > qs.gamma);
    }

    #[test]
    fn prop_quantization_error_bounded_by_half_step() {
        forall(2000, |rng: &mut Rng| {
            let bits = *rng.choose(&[2u32, 4, 8]);
            let gamma = rng.uniform(0.01, 2.0);
            let q = Quantizer::new(QuantParams::weights(bits), gamma);
            // value inside the representable range
            let v = rng.uniform(
                q.params.qn() as f64 * gamma,
                q.params.qp() as f64 * gamma,
            );
            let err = (v - q.quantize(v)).abs();
            check(
                err <= gamma / 2.0 + 1e-12,
                &format!("err {err} > gamma/2 {}", gamma / 2.0),
            )
        });
    }

    #[test]
    fn prop_idempotent() {
        forall(1000, |rng: &mut Rng| {
            let bits = *rng.choose(&[1u32, 2, 4, 8]);
            let q = Quantizer::new(QuantParams::weights(bits), rng.uniform(0.01, 1.0));
            let v = rng.normal();
            let once = q.quantize(v);
            check_close(q.quantize(once), once, 1e-12, "quantize idempotent")
        });
    }

    #[test]
    fn prop_codes_in_range() {
        forall(1000, |rng: &mut Rng| {
            let signed = rng.chance(0.5);
            let bits = *rng.choose(&[1u32, 2, 4, 8]);
            let p = QuantParams {
                bits,
                signed,
            };
            let q = Quantizer::new(p, rng.uniform(0.001, 10.0));
            let v = rng.normal() * 100.0;
            let code = q.to_int(v);
            check(
                code >= p.qn() && code <= p.qp(),
                &format!("code {code} outside [{}, {}]", p.qn(), p.qp()),
            )
        });
    }

    #[test]
    fn prop_monotone() {
        forall(1000, |rng: &mut Rng| {
            let q = Quantizer::new(QuantParams::weights(4), rng.uniform(0.05, 1.0));
            let a = rng.normal();
            let b = a + rng.uniform(0.0, 2.0);
            check(
                q.to_int(a) <= q.to_int(b),
                "quantization must be monotone",
            )
        });
    }

    #[test]
    fn reference_noise_power_monotone_and_deterministic() {
        // More bits -> strictly less quantization noise, and the sample is
        // fixed so repeated calls agree bit-for-bit (the planner's DP relies
        // on both).
        let powers: Vec<f64> = [1u32, 2, 3, 4, 8]
            .iter()
            .map(|&b| reference_noise_power(b))
            .collect();
        for w in powers.windows(2) {
            assert!(w[0] > w[1], "noise must fall with bits: {powers:?}");
        }
        assert!(powers.iter().all(|p| *p > 0.0));
        assert_eq!(
            reference_noise_power(2).to_bits(),
            reference_noise_power(2).to_bits()
        );
    }

    #[test]
    fn activation_noise_power_monotone_deterministic_and_unsigned() {
        // The activation menu mirrors the weight menu's guarantees: strict
        // monotone decrease with bits, determinism, positivity — and at 8
        // bit the noise is tiny relative to the 1-bit end.
        let powers: Vec<f64> = (1u32..=8).map(reference_activation_noise_power).collect();
        for w in powers.windows(2) {
            assert!(w[0] > w[1], "activation noise must fall with bits: {powers:?}");
        }
        assert!(powers.iter().all(|p| *p > 0.0));
        assert!(powers[0] / powers[7] > 100.0, "{powers:?}");
        assert_eq!(
            reference_activation_noise_power(4).to_bits(),
            reference_activation_noise_power(4).to_bits()
        );
        // Distinct from the signed weight menu (different Q-range + sample).
        assert_ne!(
            reference_activation_noise_power(4).to_bits(),
            reference_noise_power(4).to_bits()
        );
    }

    #[test]
    fn levels_count() {
        forall(100, |rng: &mut Rng| {
            let bits = *rng.choose(&[1u32, 2, 4, 8]);
            let p = QuantParams::weights(bits);
            check_eq(p.levels(), 1u64 << bits, "levels = 2^bits")
        });
    }
}
