//! Per-variant serving pipeline: bounded admission queue with backpressure,
//! a dynamic batcher, and a worker thread that owns one [`InferenceBackend`]
//! (the PJRT engine in production, mocks in tests).
//!
//! No tokio offline — plain threads + `std::sync::mpsc`, which is entirely
//! adequate for a single-device inference queue: one batcher thread owns
//! the backend, clients block on per-request channels. The multi-variant
//! [`Server`](crate::serving::Server) runs one of these pipelines per
//! registered variant and routes requests between them.
//!
//! Fault tolerance (PR 6): `infer_batch` runs under `catch_unwind`, so a
//! panicking backend fails its chunk's requests like any backend error
//! instead of killing the thread; the in-thread supervisor then rebuilds
//! the backend from the variant's factory with exponential backoff (see
//! [`SupervisorConfig`]). Requests carry an optional deadline that is
//! enforced at admission (queue-wait EWMA already exceeds it) and at
//! dequeue (already expired before batching), and a per-variant
//! [`CircuitBreaker`] records chunk outcomes for the server's status
//! reporting.

use super::backend::{BackendHealth, InferenceBackend};
use super::metrics::{Metrics, EWMA_ALPHA};
use super::retry::{BreakerConfig, CircuitBreaker};
use super::router::RouteError;
use super::supervisor::{Supervisor, SupervisorConfig};
use crate::obs::TraceHandle;
use crate::util::error::Result;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Lock a metrics mutex, tolerating poison: a worker that panicked while
/// holding the lock must not cascade panics into healthy workers, routing,
/// or `summary_table` — the counters are plain data and stay usable.
pub(crate) fn lock_metrics(m: &Mutex<Metrics>) -> MutexGuard<'_, Metrics> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Batching policy for one variant's pipeline.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Assemble at most this many requests per batch. May exceed the
    /// largest backend batch size: the worker splits the assembled batch
    /// into supported executions (see [`plan_executions`]).
    pub max_batch: usize,
    /// Wait at most this long for the batch to fill.
    pub max_wait: Duration,
    /// Admission queue depth; beyond this, `try_submit` sheds load.
    pub queue_capacity: usize,
    /// Frames/s of the simulated FPGA design (drives the virtual clock);
    /// 0 disables the virtual clock.
    pub fpga_fps_sim: f64,
    /// Restart pacing when the backend crashes (panics) or wedges.
    pub supervisor: SupervisorConfig,
    /// Per-variant circuit-breaker thresholds.
    pub breaker: BreakerConfig,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_capacity: 128,
            fpga_fps_sim: 0.0,
            supervisor: SupervisorConfig::default(),
            breaker: BreakerConfig::default(),
        }
    }
}

/// One queued inference request.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    /// Answer-by time; expired requests are shed at dequeue instead of
    /// being batched (a late answer is worth less than a fast failure).
    deadline: Option<Instant>,
    reply: SyncSender<Result<Response, String>>,
    /// Shared trace: the worker appends `queue.wait` / `batch.assemble` /
    /// `infer` spans into the same trace the edge handler holds. The
    /// disabled handle is a no-op.
    trace: TraceHandle,
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Logits for this request's image.
    pub logits: Vec<f32>,
    /// Predicted class (argmax).
    pub class: usize,
    /// End-to-end latency.
    pub latency: Duration,
    /// Size of the executed batch this request rode in (before padding).
    pub batch_size: usize,
    /// Name of the variant that served the request.
    pub variant: String,
}

/// Submission error.
#[derive(Debug)]
pub enum SubmitError {
    Backpressure,
    Closed,
    BadInput { expected: usize, got: usize },
    /// Admission-time load shedding: the queue's EWMA wait already exceeds
    /// the request's deadline, so enqueueing could only produce a late
    /// answer.
    DeadlineUnattainable { queue_wait_us: u64 },
    /// The request's [`VariantSelector`](crate::serving::VariantSelector)
    /// could not be resolved to a variant.
    Route(RouteError),
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Backpressure => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "server is shut down"),
            SubmitError::BadInput { expected, got } => {
                write!(f, "bad input: expected {expected} elements, got {got}")
            }
            SubmitError::DeadlineUnattainable { queue_wait_us } => write!(
                f,
                "deadline unattainable: queue wait ~{queue_wait_us}us already exceeds it (shed)"
            ),
            SubmitError::Route(e) => write!(f, "routing failed: {e}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Live per-variant state shared between the worker thread, the clients,
/// and the router: an EWMA latency estimate, a queue-wait estimate, a
/// health snapshot, the circuit breaker, and the number of in-flight
/// requests. All lock-free so routing never contends with the serving hot
/// path.
#[derive(Debug)]
pub(crate) struct VariantShared {
    ewma_us_bits: AtomicU64,
    queue_wait_ewma_us_bits: AtomicU64,
    health: AtomicU8,
    inflight: AtomicU64,
    shed_admission: AtomicU64,
    pub(crate) breaker: CircuitBreaker,
}

impl VariantShared {
    pub(crate) fn new(breaker: BreakerConfig) -> VariantShared {
        VariantShared {
            ewma_us_bits: AtomicU64::new(0f64.to_bits()),
            queue_wait_ewma_us_bits: AtomicU64::new(0f64.to_bits()),
            health: AtomicU8::new(BackendHealth::Healthy.as_u8()),
            inflight: AtomicU64::new(0),
            shed_admission: AtomicU64::new(0),
            breaker: CircuitBreaker::new(breaker),
        }
    }

    pub(crate) fn ewma_us(&self) -> f64 {
        f64::from_bits(self.ewma_us_bits.load(Ordering::Relaxed))
    }

    pub(crate) fn set_ewma_us(&self, us: f64) {
        self.ewma_us_bits.store(us.to_bits(), Ordering::Relaxed);
    }

    /// EWMA of time requests spent queued before batch assembly — the
    /// admission controller's estimate of what a new request will wait.
    pub(crate) fn queue_wait_ewma_us(&self) -> f64 {
        f64::from_bits(self.queue_wait_ewma_us_bits.load(Ordering::Relaxed))
    }

    pub(crate) fn set_queue_wait_ewma_us(&self, us: f64) {
        self.queue_wait_ewma_us_bits
            .store(us.to_bits(), Ordering::Relaxed);
    }

    pub(crate) fn health(&self) -> BackendHealth {
        BackendHealth::from_u8(self.health.load(Ordering::Relaxed))
    }

    pub(crate) fn set_health(&self, h: BackendHealth) {
        self.health.store(h.as_u8(), Ordering::Relaxed);
    }

    pub(crate) fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Requests shed at admission (deadline unattainable), folded into the
    /// variant's [`Metrics`] snapshot by the server.
    pub(crate) fn shed_admission(&self) -> u64 {
        self.shed_admission.load(Ordering::Relaxed)
    }
}

/// Handle for submitting requests to one variant's pipeline; cheap to clone
/// across client threads.
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Request>,
    image_len: usize,
    shared: Arc<VariantShared>,
}

impl Client {
    fn make_request(
        &self,
        image: Vec<f32>,
        deadline: Option<Instant>,
        trace: TraceHandle,
    ) -> (Request, PendingResponse) {
        let (reply_tx, reply_rx) = sync_channel(1);
        (
            Request {
                image,
                enqueued: Instant::now(),
                deadline,
                reply: reply_tx,
                trace,
            },
            PendingResponse { rx: reply_rx },
        )
    }

    fn check_len(&self, image: &[f32]) -> Result<(), SubmitError> {
        if image.len() != self.image_len {
            return Err(SubmitError::BadInput {
                expected: self.image_len,
                got: image.len(),
            });
        }
        Ok(())
    }

    /// Admission control: refuse a deadline the queue alone already makes
    /// unattainable — shedding here costs nothing, shedding at dequeue
    /// costs a queue slot and a wasted wait.
    fn check_deadline(&self, deadline: Option<Instant>) -> Result<(), SubmitError> {
        let Some(d) = deadline else { return Ok(()) };
        let wait_us = self.shared.queue_wait_ewma_us();
        let remaining = d.saturating_duration_since(Instant::now());
        if wait_us > remaining.as_micros() as f64 {
            self.shared.shed_admission.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::DeadlineUnattainable {
                queue_wait_us: wait_us as u64,
            });
        }
        Ok(())
    }

    /// Non-blocking submit; sheds load when the queue is full.
    pub fn try_submit(&self, image: Vec<f32>) -> Result<PendingResponse, SubmitError> {
        self.try_submit_with_deadline(image, None)
    }

    /// Non-blocking submit with a deadline the pipeline enforces: shed at
    /// admission if the queue's EWMA wait already exceeds it, shed at
    /// dequeue if it expires before batching.
    pub fn try_submit_with_deadline(
        &self,
        image: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<PendingResponse, SubmitError> {
        self.try_submit_traced(image, deadline, TraceHandle::off())
    }

    /// [`Client::try_submit_with_deadline`] carrying a request trace the
    /// worker appends its spans into.
    pub fn try_submit_traced(
        &self,
        image: Vec<f32>,
        deadline: Option<Instant>,
        trace: TraceHandle,
    ) -> Result<PendingResponse, SubmitError> {
        self.check_len(&image)?;
        self.check_deadline(deadline)?;
        let (req, pending) = self.make_request(image, deadline, trace);
        // Count in-flight BEFORE the send: a zero-latency worker can serve
        // and decrement in the window after `try_send` returns, and a late
        // increment would wrap the counter below zero.
        self.shared.inflight.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(req) {
            Ok(()) => Ok(pending),
            Err(TrySendError::Full(_)) => {
                self.shared.inflight.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Backpressure)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.shared.inflight.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Closed)
            }
        }
    }

    /// Blocking submit (applies backpressure to the caller).
    pub fn submit(&self, image: Vec<f32>) -> Result<PendingResponse, SubmitError> {
        self.submit_with_deadline(image, None)
    }

    /// Blocking submit with a pipeline-enforced deadline (see
    /// [`Client::try_submit_with_deadline`]).
    pub fn submit_with_deadline(
        &self,
        image: Vec<f32>,
        deadline: Option<Instant>,
    ) -> Result<PendingResponse, SubmitError> {
        self.submit_traced(image, deadline, TraceHandle::off())
    }

    /// [`Client::submit_with_deadline`] carrying a request trace.
    pub fn submit_traced(
        &self,
        image: Vec<f32>,
        deadline: Option<Instant>,
        trace: TraceHandle,
    ) -> Result<PendingResponse, SubmitError> {
        self.check_len(&image)?;
        self.check_deadline(deadline)?;
        let (req, pending) = self.make_request(image, deadline, trace);
        self.shared.inflight.fetch_add(1, Ordering::Relaxed);
        if self.tx.send(req).is_err() {
            self.shared.inflight.fetch_sub(1, Ordering::Relaxed);
            return Err(SubmitError::Closed);
        }
        Ok(pending)
    }

    /// Convenience: submit and wait.
    pub fn classify(&self, image: Vec<f32>) -> Result<Response, String> {
        self.submit(image).map_err(|e| e.to_string())?.wait()
    }
}

/// Future-like handle for an in-flight request.
#[derive(Debug)]
pub struct PendingResponse {
    rx: Receiver<Result<Response, String>>,
}

impl PendingResponse {
    pub fn wait(self) -> Result<Response, String> {
        self.rx
            .recv()
            .map_err(|_| "server dropped request".to_string())?
    }

    pub fn wait_timeout(self, d: Duration) -> Result<Response, String> {
        match self.rx.recv_timeout(d) {
            Ok(r) => r,
            Err(_) => Err("timeout".to_string()),
        }
    }

    /// Non-consuming wait: `Some(outcome)` if the response (or failure)
    /// arrived within `d`, `None` on timeout — the handle stays usable, so
    /// a hedging caller can keep polling the original while racing a
    /// duplicate.
    pub fn poll_timeout(&self, d: Duration) -> Option<Result<Response, String>> {
        match self.rx.recv_timeout(d) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                Some(Err("server dropped request".to_string()))
            }
        }
    }
}

/// One variant's running pipeline: the client side of the queue plus the
/// worker thread that owns the backend.
pub(crate) struct VariantWorker {
    pub(crate) client: Client,
    pub(crate) metrics: Arc<Mutex<Metrics>>,
    pub(crate) shared: Arc<VariantShared>,
    handle: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

impl VariantWorker {
    pub(crate) fn stop_and_join(&mut self) {
        if let Some(h) = self.handle.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Also drop our own sender so an idle worker wakes immediately
            // when no other Client clones exist.
            let dummy = Client {
                tx: sync_channel(1).0,
                image_len: 0,
                shared: Arc::new(VariantShared::new(BreakerConfig::default())),
            };
            let old = std::mem::replace(&mut self.client, dummy);
            drop(old);
            let _ = h.join();
        }
    }
}

impl Drop for VariantWorker {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Spawn one variant's worker thread. `factory` runs *inside* the worker
/// thread and builds the backend there — required because the PJRT client
/// types are not `Send`. It is a `Fn` (not `FnOnce`) because the
/// supervisor re-invokes it to rebuild a crashed backend; it never leaves
/// the worker thread. The backend is [`warmup`]-ed before the variant is
/// announced ready; factory or warm-up failure fails the spawn.
///
/// [`warmup`]: InferenceBackend::warmup
pub(crate) fn spawn_variant<F>(name: &str, factory: F, cfg: BatcherConfig) -> Result<VariantWorker>
where
    F: Fn() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
{
    assert!(cfg.max_batch >= 1);
    let (tx, rx) = sync_channel::<Request>(cfg.queue_capacity);
    let metrics = Arc::new(Mutex::new(Metrics::default()));
    let shared = Arc::new(VariantShared::new(cfg.breaker));
    let m2 = metrics.clone();
    let s2 = shared.clone();
    // The worker reports readiness (and the image length) or the factory's
    // error back over a rendezvous channel.
    let (ready_tx, ready_rx) = sync_channel::<Result<usize, String>>(1);
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    let label = name.to_string();
    let worker = std::thread::Builder::new()
        .name(format!("mpcnn-batcher-{name}"))
        .spawn(move || {
            let backend = match factory().and_then(|b| b.warmup().map(|()| b)) {
                Ok(b) => {
                    let _ = ready_tx.send(Ok(b.image_len()));
                    b
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(format!("{e:#}")));
                    return;
                }
            };
            supervised_loop(factory, backend, rx, cfg, label, m2, s2, stop2)
        })
        .expect("spawn batcher");
    let image_len = ready_rx
        .recv()
        .map_err(|_| crate::anyhow!("batcher thread for '{name}' died during startup"))?
        .map_err(|e| crate::anyhow!("backend factory for '{name}' failed: {e}"))?;
    Ok(VariantWorker {
        client: Client {
            tx,
            image_len,
            shared: shared.clone(),
        },
        metrics,
        shared,
        handle: Some(worker),
        stop,
    })
}

/// Split an assembled batch of `n` requests into backend executions.
/// Returns `(take, exec_size)` pairs: execute `exec_size` (a supported
/// size), of which `take` are real requests and the rest padding. `n` may
/// exceed the largest supported size — the previous implementation padded
/// *down* in that case, truncating trailing images and fanning logits out
/// past the backend's output; now the batch is split instead.
pub(crate) fn plan_executions(n: usize, supported_sorted: &[usize]) -> Vec<(usize, usize)> {
    assert!(!supported_sorted.is_empty());
    let largest = *supported_sorted.last().unwrap();
    let mut plan = Vec::new();
    let mut remaining = n;
    while remaining > 0 {
        let exec = supported_sorted
            .iter()
            .copied()
            .find(|&s| s >= remaining)
            .unwrap_or(largest);
        let take = remaining.min(exec);
        plan.push((take, exec));
        remaining -= take;
    }
    plan
}

/// Idle decay applied to the EWMA latency estimate once per 25 ms idle
/// tick (halves in ~0.9 s). Without it a variant that was degraded, then
/// starved of traffic by the router, would keep its stale high estimate
/// forever and never be probed again after recovering. The queue-wait
/// EWMA decays on the same tick so admission control unblocks once the
/// queue drains.
const IDLE_EWMA_DECAY: f64 = 0.98;

/// After this many consecutive backend errors the worker reports the
/// variant [`BackendHealth::Unavailable`] (policy routing then avoids it)
/// even if the backend itself still claims to be healthy.
const ERRORS_TO_UNAVAILABLE: u32 = 3;

fn worse(a: BackendHealth, b: BackendHealth) -> BackendHealth {
    if a.as_u8() >= b.as_u8() {
        a
    } else {
        b
    }
}

/// Why [`batcher_loop`] returned.
enum LoopExit {
    /// Stop flag set or every client dropped — the worker is done.
    Shutdown,
    /// The backend panicked inside `infer_batch`: its state is suspect, so
    /// the supervisor must rebuild it before serving more traffic.
    Crashed,
}

/// Human-readable description of a caught panic payload.
fn describe_panic(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(p) = payload.downcast_ref::<super::fault::InjectedPanic>() {
        p.0.clone()
    } else if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// The supervision shell around [`batcher_loop`]: serve until the backend
/// crashes, then back off (failing queued requests fast instead of letting
/// them rot), rebuild from the factory, and serve again. Within the
/// restart budget crashes rebuild eagerly at exponential pacing; past it
/// the worker parks at the maximum backoff and keeps probing — removing
/// the fault always lets the variant return to service without a server
/// restart. A successful batch resets the budget.
#[allow(clippy::too_many_arguments)]
fn supervised_loop<F>(
    factory: F,
    first_backend: Box<dyn InferenceBackend>,
    rx: Receiver<Request>,
    cfg: BatcherConfig,
    label: String,
    metrics: Arc<Mutex<Metrics>>,
    shared: Arc<VariantShared>,
    stop: Arc<AtomicBool>,
) where
    F: Fn() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
{
    let mut supervisor = Supervisor::new(cfg.supervisor);
    let mut backend = Some(first_backend);
    loop {
        if let Some(b) = backend.take() {
            match batcher_loop(
                b.as_ref(),
                &rx,
                &cfg,
                &label,
                &metrics,
                &shared,
                &stop,
                &mut supervisor,
            ) {
                LoopExit::Shutdown => return,
                LoopExit::Crashed => {}
            }
            // `b` (the crashed backend) drops here.
        }
        let backoff = supervisor.on_crash();
        shared.set_health(BackendHealth::Unavailable);
        lock_metrics(&metrics).worker_restarts += 1;
        // Fail queued requests fast during the backoff window: their
        // backend is gone and making them wait out the rebuild helps no
        // one (retry policies can re-route them *now*).
        let until = Instant::now() + backoff;
        loop {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let now = Instant::now();
            if now >= until {
                break;
            }
            let step = (until - now).min(Duration::from_millis(25));
            match rx.recv_timeout(step) {
                Ok(r) => {
                    lock_metrics(&metrics).errors += 1;
                    shared.inflight.fetch_sub(1, Ordering::Relaxed);
                    shared.breaker.record_failure();
                    let _ = r
                        .reply
                        .send(Err("variant restarting after crash (supervisor backoff)"
                            .to_string()));
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => return,
            }
        }
        // Rebuild under catch_unwind too: a factory or warm-up that
        // panics/fails is just another crash, paced by the same backoff.
        match catch_unwind(AssertUnwindSafe(|| {
            factory().and_then(|b| b.warmup().map(|()| b))
        })) {
            Ok(Ok(b)) => {
                // Probation until the first successful batch promotes it.
                shared.set_health(worse(b.health(), BackendHealth::Degraded));
                backend = Some(b);
            }
            Ok(Err(_)) | Err(_) => {}
        }
    }
}

/// The batcher loop: collect up to `max_batch` requests within `max_wait`
/// of the first, shed the expired ones, split into supported backend
/// executions (padding the last one), execute under `catch_unwind`, fan
/// out.
#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    backend: &dyn InferenceBackend,
    rx: &Receiver<Request>,
    cfg: &BatcherConfig,
    label: &str,
    metrics: &Arc<Mutex<Metrics>>,
    shared: &Arc<VariantShared>,
    stop: &Arc<AtomicBool>,
    supervisor: &mut Supervisor,
) -> LoopExit {
    let supported = {
        let mut s: Vec<usize> = backend
            .batch_sizes()
            .into_iter()
            .filter(|&s| backend.supports_batch(s))
            .collect();
        s.sort_unstable();
        s.dedup();
        if s.is_empty() {
            s.push(1);
        }
        s
    };
    let image_len = backend.image_len();
    let classes = backend.classes();
    let mut consecutive_errors = 0u32;
    loop {
        // Block for the first request of the batch, polling the stop flag
        // so shutdown works even while stray Client clones are alive.
        let first = loop {
            if stop.load(Ordering::SeqCst) {
                // Drain whatever is already queued, then exit.
                match rx.try_recv() {
                    Ok(r) => break r,
                    Err(_) => return LoopExit::Shutdown,
                }
            }
            match rx.recv_timeout(Duration::from_millis(25)) {
                Ok(r) => break r,
                Err(RecvTimeoutError::Timeout) => {
                    // Idle tick: decay the latency estimate so excluded
                    // variants eventually re-qualify and get probed, and
                    // the queue-wait estimate so admission control opens
                    // back up once the queue has drained.
                    let mut m = lock_metrics(metrics);
                    m.ewma_latency_us *= IDLE_EWMA_DECAY;
                    shared.set_ewma_us(m.ewma_latency_us);
                    shared
                        .set_queue_wait_ewma_us(shared.queue_wait_ewma_us() * IDLE_EWMA_DECAY);
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => return LoopExit::Shutdown,
            }
        };
        let assemble_until = Instant::now() + cfg.max_wait;
        let mut batch = vec![first];
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= assemble_until {
                break;
            }
            match rx.recv_timeout(assemble_until - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Deadline enforcement at dequeue: a request that expired while
        // queued can only yield a late answer — shed it before it costs
        // backend time that punctual requests need.
        let now = Instant::now();
        let mut live = Vec::with_capacity(batch.len());
        let mut shed = 0u64;
        for r in batch {
            if r.deadline.is_some_and(|d| now >= d) {
                shed += 1;
                shared.inflight.fetch_sub(1, Ordering::Relaxed);
                let _ = r
                    .reply
                    .send(Err("deadline expired before execution (shed)".to_string()));
            } else {
                live.push(r);
            }
        }

        let n = live.len();
        {
            let mut m = lock_metrics(metrics);
            m.requests += n as u64 + shed;
            m.shed_expired += shed;
            let mut qw = shared.queue_wait_ewma_us();
            for r in &live {
                let wait_us = r.enqueued.elapsed().as_micros() as f64;
                r.trace.add_span("queue.wait", r.enqueued, now, vec![]);
                m.queue_wait.record_us(wait_us);
                qw = if qw <= 0.0 {
                    wait_us
                } else {
                    EWMA_ALPHA * wait_us + (1.0 - EWMA_ALPHA) * qw
                };
            }
            shared.set_queue_wait_ewma_us(qw);
        }
        if n == 0 {
            continue;
        }

        // Execute in supported-size chunks; each chunk pads up to its
        // execution size, never truncates. Capability introspection first:
        // a backend that supports the assembled size exactly (beyond its
        // compiled list) runs it unpadded and unsplit.
        let plan = if backend.supports_batch(n) {
            vec![(n, n)]
        } else {
            plan_executions(n, &supported)
        };
        let mut queue: std::collections::VecDeque<Request> = live.into();
        let mut crashed = false;
        for (take, exec_size) in plan {
            let assemble_start = Instant::now();
            let chunk: Vec<Request> = queue.drain(..take).collect();
            let mut flat = Vec::with_capacity(exec_size * image_len);
            for r in &chunk {
                flat.extend_from_slice(&r.image);
            }
            flat.resize(exec_size * image_len, 0.0); // zero padding

            {
                let mut m = lock_metrics(metrics);
                m.batches += 1;
                m.batched_items += take as u64;
                m.padded_items += (exec_size - take) as u64;
                m.batch_sizes.record_us(take as f64);
            }
            let infer_start = Instant::now();
            for r in &chunk {
                r.trace
                    .add_span("batch.assemble", assemble_start, infer_start, vec![]);
            }

            // Panic isolation: a backend panic fails this chunk like any
            // backend error (feeding the same health machinery), then
            // surrenders the backend to the supervisor for a rebuild.
            let result = match catch_unwind(AssertUnwindSafe(|| {
                backend.infer_batch(&flat, exec_size)
            })) {
                Ok(r) => r,
                Err(payload) => {
                    crashed = true;
                    lock_metrics(metrics).panics += 1;
                    Err(crate::anyhow!(
                        "backend panicked: {}",
                        describe_panic(payload.as_ref())
                    ))
                }
            };
            let infer_end = Instant::now();
            for r in &chunk {
                if r.trace.enabled() {
                    r.trace.add_span(
                        "infer",
                        infer_start,
                        infer_end,
                        vec![
                            ("variant", label.to_string()),
                            ("batch", take.to_string()),
                            ("exec", exec_size.to_string()),
                            ("ok", result.is_ok().to_string()),
                        ],
                    );
                }
            }
            consecutive_errors = if result.is_ok() {
                supervisor.on_success();
                shared.breaker.record_success();
                0
            } else {
                shared.breaker.record_failure();
                consecutive_errors.saturating_add(1)
            };
            let observed = if crashed || consecutive_errors >= ERRORS_TO_UNAVAILABLE {
                BackendHealth::Unavailable
            } else if consecutive_errors > 0 {
                BackendHealth::Degraded
            } else {
                BackendHealth::Healthy
            };
            // The worse of the backend's self-report and what the worker
            // observes: a backend that errors every call must stop
            // attracting policy-routed traffic even if it claims health.
            // Skip the self-report after a panic — the backend is suspect.
            let self_report = if crashed {
                BackendHealth::Unavailable
            } else {
                backend.health()
            };
            shared.set_health(worse(self_report, observed));
            let mut m = lock_metrics(metrics);
            if cfg.fpga_fps_sim > 0.0 {
                m.fpga_virtual_us += take as f64 / cfg.fpga_fps_sim * 1e6;
            }
            match result {
                Ok(logits) => {
                    for (i, r) in chunk.into_iter().enumerate() {
                        let row = logits[i * classes..(i + 1) * classes].to_vec();
                        let class = crate::runtime::argmax_rows(&row, classes)[0];
                        let latency = r.enqueued.elapsed();
                        m.observe_latency_us(latency.as_micros() as f64);
                        m.responses += 1;
                        shared.set_ewma_us(m.ewma_latency_us);
                        shared.inflight.fetch_sub(1, Ordering::Relaxed);
                        let _ = r.reply.send(Ok(Response {
                            logits: row,
                            class,
                            latency,
                            batch_size: take,
                            variant: label.to_string(),
                        }));
                    }
                }
                Err(e) => {
                    let msg = format!("backend error: {e}");
                    for r in chunk {
                        m.errors += 1;
                        shared.inflight.fetch_sub(1, Ordering::Relaxed);
                        let _ = r.reply.send(Err(msg.clone()));
                    }
                }
            }
            if crashed {
                // Fail the rest of the assembled batch too: the backend is
                // gone and the supervisor owns what happens next.
                let mut m = lock_metrics(metrics);
                for r in queue.drain(..) {
                    m.errors += 1;
                    shared.inflight.fetch_sub(1, Ordering::Relaxed);
                    shared.breaker.record_failure();
                    let _ = r
                        .reply
                        .send(Err("backend crashed; variant restarting".to_string()));
                }
                break;
            }
        }
        if crashed {
            return LoopExit::Crashed;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::fault::InjectedPanic;
    use super::*;
    use crate::serving::backend::MockBackend;
    use crate::serving::retry::BreakerState;

    fn mock_worker(
        batch_sizes: Vec<usize>,
        latency_us: u64,
        cfg: BatcherConfig,
    ) -> VariantWorker {
        spawn_variant(
            "test",
            move || {
                Ok(Box::new(MockBackend::new(12, 4, batch_sizes.clone(), latency_us))
                    as Box<dyn InferenceBackend>)
            },
            cfg,
        )
        .unwrap()
    }

    #[test]
    fn plan_pads_within_supported_sizes() {
        // 6 requests, supported up to 8: one padded execution (the old
        // behaviour, preserved).
        assert_eq!(plan_executions(6, &[1, 4, 8]), vec![(6, 8)]);
        assert_eq!(plan_executions(1, &[1, 4, 8]), vec![(1, 1)]);
        assert_eq!(plan_executions(8, &[1, 4, 8]), vec![(8, 8)]);
    }

    #[test]
    fn plan_splits_oversized_batches() {
        // 11 requests but the largest supported execution is 4: split into
        // 4+4+3, padding only the last chunk.
        assert_eq!(plan_executions(11, &[1, 4]), vec![(4, 4), (4, 4), (3, 4)]);
        // 9 with [1, 4, 8]: one full 8 plus a batch-1 execution.
        assert_eq!(plan_executions(9, &[1, 4, 8]), vec![(8, 8), (1, 1)]);
        // Degenerate: only batch-1 compiled.
        assert_eq!(plan_executions(3, &[1]), vec![(1, 1), (1, 1), (1, 1)]);
    }

    #[test]
    fn plan_covers_all_requests() {
        crate::util::prop::forall(500, |rng| {
            let mut supported: Vec<usize> =
                (0..rng.range(1, 4)).map(|_| rng.range(1, 16)).collect();
            supported.sort_unstable();
            supported.dedup();
            let n = rng.range(1, 64);
            let plan = plan_executions(n, &supported);
            let total: usize = plan.iter().map(|(take, _)| take).sum();
            crate::util::prop::check_eq(total, n, "plan must cover every request")?;
            for &(take, exec) in &plan {
                if take > exec || !supported.contains(&exec) {
                    return Err(format!(
                        "bad chunk ({take}, {exec}) for supported {supported:?}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn oversized_batch_is_split_not_truncated() {
        // Regression: 11 requests assemble into one batch (max_batch 16,
        // generous max_wait) but the backend only supports up to batch 4.
        // The old code padded *down* to 4 — truncating 7 images and
        // indexing past the logits — so correctness here proves the split.
        let cfg = BatcherConfig {
            max_batch: 16,
            max_wait: Duration::from_millis(500),
            queue_capacity: 32,
            ..Default::default()
        };
        let w = mock_worker(vec![1, 4], 1_000, cfg);
        let client = w.client.clone();
        let reference = MockBackend::new(12, 4, vec![1], 0);
        let mut pending = Vec::new();
        for i in 0..11 {
            let img = vec![i as f32; 12];
            let want = reference.expected_class(&img);
            pending.push((client.submit(img).unwrap(), want));
        }
        for (p, want) in pending {
            let r = p.wait().unwrap();
            assert_eq!(r.class, want, "split batch must preserve every image");
            assert!(r.batch_size <= 4, "chunks can't exceed the backend max");
        }
        let m = lock_metrics(&w.metrics).clone();
        assert_eq!(m.responses, 11);
        assert_eq!(m.errors, 0);
        assert_eq!(m.requests, 11);
        // 11 = 4 + 4 + 3(+1 pad) once assembled into a single wave; the
        // first request may also ride alone if the worker grabs it before
        // the rest arrive, so only bound the shape loosely.
        assert!(m.batches >= 3, "must split: {} batches", m.batches);
        assert_eq!(m.batched_items, 11);
    }

    #[test]
    fn inflight_tracks_queue_depth() {
        let cfg = BatcherConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(0),
            queue_capacity: 64,
            ..Default::default()
        };
        let w = mock_worker(vec![1], 20_000, cfg);
        let client = w.client.clone();
        let pending: Vec<_> = (0..5).map(|_| client.submit(vec![0.0; 12]).unwrap()).collect();
        assert!(w.shared.inflight() >= 1, "submissions must register in-flight");
        for p in pending {
            p.wait().unwrap();
        }
        // Workers decrement before replying, so after the last reply the
        // counter is drained.
        assert_eq!(w.shared.inflight(), 0);
    }

    #[test]
    fn ewma_visible_to_shared_state() {
        let w = mock_worker(vec![1], 2_000, BatcherConfig::default());
        let client = w.client.clone();
        for _ in 0..5 {
            client.classify(vec![0.0; 12]).unwrap();
        }
        assert!(
            w.shared.ewma_us() >= 1_000.0,
            "ewma must reflect the 2ms mock latency: {}",
            w.shared.ewma_us()
        );
    }

    #[test]
    fn ewma_decays_while_idle() {
        let w = mock_worker(vec![1], 5_000, BatcherConfig::default());
        let client = w.client.clone();
        for _ in 0..3 {
            client.classify(vec![0.0; 12]).unwrap();
        }
        let busy = w.shared.ewma_us();
        assert!(busy >= 4_000.0, "{busy}");
        // ~16 idle ticks at 2% decay each: the estimate must shrink, so a
        // variant the router starved can re-qualify and get probed.
        std::thread::sleep(Duration::from_millis(400));
        let idle = w.shared.ewma_us();
        assert!(
            idle < busy * 0.9,
            "idle decay must shrink the estimate: {busy} -> {idle}"
        );
    }

    /// Errors every call but self-reports Healthy — the worker's own error
    /// observation must mark it Unavailable anyway.
    struct LyingBackend;

    impl InferenceBackend for LyingBackend {
        fn batch_sizes(&self) -> Vec<usize> {
            vec![1]
        }
        fn image_len(&self) -> usize {
            12
        }
        fn classes(&self) -> usize {
            4
        }
        fn infer_batch(&self, _images: &[f32], _batch: usize) -> Result<Vec<f32>> {
            Err(crate::anyhow!("boom"))
        }
    }

    #[test]
    fn consecutive_errors_mark_variant_unavailable() {
        let w = spawn_variant(
            "lying",
            || Ok(Box::new(LyingBackend) as Box<dyn InferenceBackend>),
            BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(0),
                ..Default::default()
            },
        )
        .unwrap();
        let client = w.client.clone();
        for _ in 0..4 {
            assert!(client.classify(vec![0.0; 12]).is_err());
        }
        assert_eq!(w.shared.health(), BackendHealth::Unavailable);
        let m = lock_metrics(&w.metrics).clone();
        assert!(m.errors >= 4);
    }

    #[test]
    fn breaker_opens_on_consecutive_failures() {
        let w = spawn_variant(
            "breaking",
            || Ok(Box::new(LyingBackend) as Box<dyn InferenceBackend>),
            BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(0),
                breaker: BreakerConfig {
                    failure_threshold: 3,
                    open_for: Duration::from_secs(60),
                },
                ..Default::default()
            },
        )
        .unwrap();
        let client = w.client.clone();
        for _ in 0..4 {
            assert!(client.classify(vec![0.0; 12]).is_err());
        }
        assert_eq!(w.shared.breaker.state(), BreakerState::Open);
    }

    /// Panics on every `infer_batch` until `calm` flips; tracks factory
    /// rebuilds through the shared `builds` counter.
    struct PanickyBackend {
        calm: Arc<AtomicBool>,
    }

    impl InferenceBackend for PanickyBackend {
        fn batch_sizes(&self) -> Vec<usize> {
            vec![1]
        }
        fn image_len(&self) -> usize {
            12
        }
        fn classes(&self) -> usize {
            4
        }
        fn infer_batch(&self, _images: &[f32], batch: usize) -> Result<Vec<f32>> {
            if !self.calm.load(Ordering::SeqCst) {
                std::panic::panic_any(InjectedPanic("test panic".to_string()));
            }
            Ok(vec![0.25; batch * 4])
        }
    }

    #[test]
    fn panic_is_isolated_and_supervisor_rebuilds() {
        super::super::fault::silence_injected_panics();
        let calm = Arc::new(AtomicBool::new(false));
        let builds = Arc::new(AtomicU64::new(0));
        let (calm2, builds2) = (calm.clone(), builds.clone());
        let w = spawn_variant(
            "panicky",
            move || {
                builds2.fetch_add(1, Ordering::SeqCst);
                Ok(Box::new(PanickyBackend { calm: calm2.clone() })
                    as Box<dyn InferenceBackend>)
            },
            BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(0),
                supervisor: SupervisorConfig {
                    restart_budget: 2,
                    backoff_initial: Duration::from_millis(5),
                    backoff_max: Duration::from_millis(40),
                },
                ..Default::default()
            },
        )
        .unwrap();
        let client = w.client.clone();
        // The panic must surface as an error reply, not a hung request.
        let err = client.classify(vec![0.0; 12]).unwrap_err();
        assert!(err.contains("panic"), "{err}");
        assert_eq!(w.shared.health(), BackendHealth::Unavailable);
        // Lift the fault: the supervisor's rebuild must bring the variant
        // back without respawning the worker.
        calm.store(true, Ordering::SeqCst);
        let recovered = (0..200).find_map(|_| {
            std::thread::sleep(Duration::from_millis(10));
            client.classify(vec![0.0; 12]).ok()
        });
        assert!(recovered.is_some(), "variant must recover after the fault lifts");
        assert_eq!(w.shared.health(), BackendHealth::Healthy);
        assert!(builds.load(Ordering::SeqCst) >= 2, "factory must have rebuilt");
        let m = lock_metrics(&w.metrics).clone();
        assert!(m.panics >= 1, "panic counter: {}", m.panics);
        assert!(m.worker_restarts >= 1, "restarts: {}", m.worker_restarts);
    }

    #[test]
    fn expired_requests_are_shed_at_dequeue() {
        let cfg = BatcherConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(0),
            ..Default::default()
        };
        let w = mock_worker(vec![1], 30_000, cfg);
        let client = w.client.clone();
        // Occupy the backend (30 ms mock latency), then queue a request
        // whose deadline expires while it waits.
        let blocker = client.submit(vec![0.0; 12]).unwrap();
        let doomed = client
            .submit_with_deadline(
                vec![0.0; 12],
                Some(Instant::now() + Duration::from_millis(5)),
            )
            .unwrap();
        blocker.wait().unwrap();
        let err = doomed.wait().unwrap_err();
        assert!(err.contains("shed"), "{err}");
        let m = lock_metrics(&w.metrics).clone();
        assert_eq!(m.shed_expired, 1);
        assert_eq!(w.shared.inflight(), 0, "shed requests release in-flight");
    }

    #[test]
    fn unattainable_deadline_is_shed_at_admission() {
        let w = mock_worker(vec![1], 0, BatcherConfig::default());
        let client = w.client.clone();
        // Pretend the queue is already backed up by a second.
        w.shared.set_queue_wait_ewma_us(1_000_000.0);
        let r = client.submit_with_deadline(
            vec![0.0; 12],
            Some(Instant::now() + Duration::from_millis(10)),
        );
        match r {
            Err(SubmitError::DeadlineUnattainable { queue_wait_us }) => {
                assert!(queue_wait_us >= 900_000, "{queue_wait_us}");
            }
            other => panic!("expected admission shed, got {other:?}"),
        }
        assert_eq!(w.shared.shed_admission(), 1);
        // A deadline-free request is untouched by admission control.
        assert!(client.classify(vec![0.0; 12]).is_ok());
    }

    #[test]
    fn traced_request_collects_worker_spans() {
        let w = mock_worker(vec![1, 8], 2_000, BatcherConfig::default());
        let client = w.client.clone();
        let trace = TraceHandle::start();
        let p = client
            .try_submit_traced(vec![0.0; 12], None, trace.clone())
            .unwrap();
        p.wait().unwrap();
        let done = trace.finish(Instant::now()).unwrap();
        let names: Vec<&str> = done.spans.iter().map(|s| s.name).collect();
        for want in ["queue.wait", "batch.assemble", "infer"] {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
        let infer = done.spans.iter().find(|s| s.name == "infer").unwrap();
        assert!(infer.dur_us >= 1_000.0, "mock latency must show: {}", infer.dur_us);
        assert!(infer.tags.iter().any(|(k, v)| *k == "variant" && v == "test"));
        assert!(infer.tags.iter().any(|(k, v)| *k == "batch" && v == "1"));
        // The untraced path is unchanged and allocation-free.
        assert!(client.classify(vec![0.0; 12]).is_ok());
        let m = lock_metrics(&w.metrics).clone();
        assert_eq!(m.batch_sizes.count(), m.batches, "one size sample per batch");
    }

    #[test]
    fn poll_timeout_is_non_consuming() {
        let w = mock_worker(vec![1], 20_000, BatcherConfig::default());
        let client = w.client.clone();
        let p = client.submit(vec![0.0; 12]).unwrap();
        assert!(p.poll_timeout(Duration::from_millis(1)).is_none(), "not ready yet");
        let r = p
            .poll_timeout(Duration::from_secs(5))
            .expect("must complete")
            .unwrap();
        assert_eq!(r.variant, "test");
    }
}
