//! Deterministic fault injection for the serving gateway.
//!
//! [`FaultyBackend`] wraps any [`InferenceBackend`] and injects latency
//! spikes, transient/persistent errors, panics, and corrupt logits
//! according to a [`FaultPlan`] — a schedule of call-window rules drawn
//! from a seeded RNG, so a scenario replays identically run after run.
//! The wrapper shares its call counter and live override switch through an
//! [`Arc<FaultControls>`]: the counter survives supervisor-driven backend
//! rebuilds (a window-based scenario keeps progressing across restarts),
//! and tests flip the override to force a persistent fault and later lift
//! it to watch the variant recover without a server restart.
//!
//! Panics are raised with a typed [`InjectedPanic`] payload so test
//! binaries can install a panic hook that silences exactly these panics
//! and no others.

use crate::anyhow;
use crate::serving::backend::{BackendHealth, InferenceBackend};
use crate::util::error::Result;
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What a matching [`FaultRule`] does to the call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Return a backend error (feeds `ERRORS_TO_UNAVAILABLE`).
    Error,
    /// Panic with an [`InjectedPanic`] payload (exercises `catch_unwind`
    /// isolation and the supervisor).
    Panic,
    /// Sleep before delegating (latency spike; the call still succeeds).
    Latency(Duration),
    /// Delegate, then rotate each logit row by one so the argmax lands on
    /// the wrong class (silent corruption — caught only by end-to-end
    /// agreement checks, never by the health machinery).
    Corrupt,
}

/// One scheduled fault: applies to calls in `[from, to)` with probability
/// `prob` (per call, drawn deterministically from the plan seed).
#[derive(Clone, Copy, Debug)]
pub struct FaultRule {
    pub from: u64,
    /// Exclusive upper call index; `u64::MAX` means "forever".
    pub to: u64,
    pub kind: FaultKind,
    pub prob: f64,
}

impl FaultRule {
    /// A rule active from call 0 forever.
    pub fn always(kind: FaultKind, prob: f64) -> FaultRule {
        FaultRule { from: 0, to: u64::MAX, kind, prob }
    }

    /// A rule active for calls in `[from, to)`.
    pub fn window(from: u64, to: u64, kind: FaultKind, prob: f64) -> FaultRule {
        FaultRule { from, to, kind, prob }
    }
}

/// A seeded schedule of fault rules. The first rule that is active for the
/// call index *and* wins its probability draw fires; at most one fault is
/// injected per call.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    pub rules: Vec<FaultRule>,
    pub seed: u64,
}

impl FaultPlan {
    pub fn new(rules: Vec<FaultRule>, seed: u64) -> FaultPlan {
        FaultPlan { rules, seed }
    }

    /// Named scenarios the CLI exposes (`--fault <name>`):
    ///
    /// - `flaky`: 15% transient errors + 10% 2 ms latency spikes, forever.
    /// - `crashy`: 8% panics, forever.
    /// - `storm`: a burst — calls 8..40 panic at 60% and error at 30%,
    ///   then the backend is clean again (recovery is observable).
    /// - `dead`: every call errors (persistent outage).
    /// - `latency`: 30% 5 ms spikes.
    /// - `corrupt`: 25% silently-wrong logits.
    pub fn scenario(name: &str) -> Option<FaultPlan> {
        let rules = match name {
            "flaky" => vec![
                FaultRule::always(FaultKind::Error, 0.15),
                FaultRule::always(FaultKind::Latency(Duration::from_millis(2)), 0.10),
            ],
            "crashy" => vec![FaultRule::always(FaultKind::Panic, 0.08)],
            "storm" => vec![
                FaultRule::window(8, 40, FaultKind::Panic, 0.60),
                FaultRule::window(8, 40, FaultKind::Error, 0.30),
            ],
            "dead" => vec![FaultRule::always(FaultKind::Error, 1.0)],
            "latency" => vec![FaultRule::always(
                FaultKind::Latency(Duration::from_millis(5)),
                0.30,
            )],
            "corrupt" => vec![FaultRule::always(FaultKind::Corrupt, 0.25)],
            _ => return None,
        };
        Some(FaultPlan::new(rules, 0xFA17))
    }

    /// Parse `name` or `name:seed` (e.g. `flaky:42`). Unknown names list
    /// the available scenarios in the error.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let (name, seed) = match spec.split_once(':') {
            Some((n, s)) => {
                let seed = s
                    .parse::<u64>()
                    .map_err(|_| anyhow!("fault scenario seed must be an integer: {s:?}"))?;
                (n, Some(seed))
            }
            None => (spec, None),
        };
        let mut plan = FaultPlan::scenario(name).ok_or_else(|| {
            anyhow!(
                "unknown fault scenario {name:?} \
                 (available: flaky, crashy, storm, dead, latency, corrupt)"
            )
        })?;
        if let Some(seed) = seed {
            plan.seed = seed;
        }
        Ok(plan)
    }
}

/// Live override a test (or operator) can flip while the backend serves.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Forced {
    /// No override: the plan's schedule applies.
    None,
    /// Every call panics.
    Panic,
    /// Every call errors.
    Error,
    /// Every call corrupts its logits.
    Corrupt,
}

impl Forced {
    fn as_u8(self) -> u8 {
        match self {
            Forced::None => 0,
            Forced::Panic => 1,
            Forced::Error => 2,
            Forced::Corrupt => 3,
        }
    }

    fn from_u8(v: u8) -> Forced {
        match v {
            1 => Forced::Panic,
            2 => Forced::Error,
            3 => Forced::Corrupt,
            _ => Forced::None,
        }
    }
}

/// Shared state of one injected variant: survives backend rebuilds (the
/// factory re-wraps a fresh inner backend around the *same* controls) and
/// doubles as the test's remote control + injection ledger.
#[derive(Debug, Default)]
pub struct FaultControls {
    calls: AtomicU64,
    forced: AtomicU8,
    errors: AtomicU64,
    panics: AtomicU64,
    latency_spikes: AtomicU64,
    corruptions: AtomicU64,
}

impl FaultControls {
    pub fn new() -> Arc<FaultControls> {
        Arc::new(FaultControls::default())
    }

    /// Force (or lift, with [`Forced::None`]) a persistent fault.
    pub fn force(&self, f: Forced) {
        self.forced.store(f.as_u8(), Ordering::SeqCst);
    }

    pub fn forced(&self) -> Forced {
        Forced::from_u8(self.forced.load(Ordering::SeqCst))
    }

    /// Total `infer_batch` calls seen across all backend incarnations.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::SeqCst)
    }

    pub fn injected_errors(&self) -> u64 {
        self.errors.load(Ordering::SeqCst)
    }

    pub fn injected_panics(&self) -> u64 {
        self.panics.load(Ordering::SeqCst)
    }

    pub fn injected_latency_spikes(&self) -> u64 {
        self.latency_spikes.load(Ordering::SeqCst)
    }

    pub fn injected_corruptions(&self) -> u64 {
        self.corruptions.load(Ordering::SeqCst)
    }

    /// Total faults injected, any kind.
    pub fn injected_total(&self) -> u64 {
        self.injected_errors()
            + self.injected_panics()
            + self.injected_latency_spikes()
            + self.injected_corruptions()
    }
}

/// Typed panic payload for injected panics, so a test binary's panic hook
/// can silence exactly these (`payload.downcast_ref::<InjectedPanic>()`)
/// without hiding real failures.
#[derive(Debug)]
pub struct InjectedPanic(pub String);

/// Install a process-wide panic hook that silences the default "thread
/// panicked" stderr report for [`InjectedPanic`] payloads only — real
/// panics still print. Idempotent; used by chaos tests and
/// `mpcnn serve --fault` so injected crashes don't spam the console
/// (they are fully accounted for in the metrics).
pub fn silence_injected_panics() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<InjectedPanic>().is_none() {
                default(info);
            }
        }));
    });
}

/// A fault-injecting wrapper around any inference backend.
///
/// Capability calls (`batch_sizes`, `image_len`, `classes`, `warmup`,
/// `health`) delegate untouched — faults apply only to `infer_batch`, the
/// path the batcher exercises per batch.
pub struct FaultyBackend {
    inner: Box<dyn InferenceBackend>,
    plan: FaultPlan,
    controls: Arc<FaultControls>,
}

impl FaultyBackend {
    pub fn new(
        inner: Box<dyn InferenceBackend>,
        plan: FaultPlan,
        controls: Arc<FaultControls>,
    ) -> FaultyBackend {
        FaultyBackend { inner, plan, controls }
    }

    pub fn controls(&self) -> Arc<FaultControls> {
        self.controls.clone()
    }

    /// The fault (if any) call number `call` injects: the forced override
    /// first, else the first schedule rule that is active and wins its
    /// deterministic per-call draw.
    fn decide(&self, call: u64) -> Option<FaultKind> {
        match self.controls.forced() {
            Forced::Panic => return Some(FaultKind::Panic),
            Forced::Error => return Some(FaultKind::Error),
            Forced::Corrupt => return Some(FaultKind::Corrupt),
            Forced::None => {}
        }
        // One RNG per (seed, call): replays identically regardless of how
        // calls interleave with rebuilds, and rules draw in a fixed order.
        let mut rng = Rng::new(self.plan.seed ^ call.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        for rule in &self.plan.rules {
            if call >= rule.from && call < rule.to && rng.chance(rule.prob) {
                return Some(rule.kind);
            }
        }
        None
    }

    /// Rotate each `classes`-wide logit row left by one: the argmax moves
    /// to a different class, deterministically, without NaN/Inf games.
    fn corrupt_rows(&self, logits: &mut [f32]) {
        let classes = self.inner.classes().max(1);
        for row in logits.chunks_exact_mut(classes) {
            row.rotate_left(1);
        }
    }
}

impl InferenceBackend for FaultyBackend {
    fn batch_sizes(&self) -> Vec<usize> {
        self.inner.batch_sizes()
    }

    fn supports_batch(&self, n: usize) -> bool {
        self.inner.supports_batch(n)
    }

    fn image_len(&self) -> usize {
        self.inner.image_len()
    }

    fn classes(&self) -> usize {
        self.inner.classes()
    }

    fn infer_batch(&self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        let call = self.controls.calls.fetch_add(1, Ordering::SeqCst);
        match self.decide(call) {
            Some(FaultKind::Error) => {
                self.controls.errors.fetch_add(1, Ordering::SeqCst);
                Err(anyhow!("injected fault: error on call {call}"))
            }
            Some(FaultKind::Panic) => {
                self.controls.panics.fetch_add(1, Ordering::SeqCst);
                std::panic::panic_any(InjectedPanic(format!(
                    "injected fault: panic on call {call}"
                )))
            }
            Some(FaultKind::Latency(d)) => {
                self.controls.latency_spikes.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(d);
                self.inner.infer_batch(images, batch)
            }
            Some(FaultKind::Corrupt) => {
                self.controls.corruptions.fetch_add(1, Ordering::SeqCst);
                let mut logits = self.inner.infer_batch(images, batch)?;
                self.corrupt_rows(&mut logits);
                Ok(logits)
            }
            None => self.inner.infer_batch(images, batch),
        }
    }

    /// Warm-up is never injected: a scenario describes serving-time faults,
    /// and startup must succeed so the variant can begin taking traffic.
    fn warmup(&self) -> Result<()> {
        self.inner.warmup()
    }

    fn health(&self) -> BackendHealth {
        self.inner.health()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::backend::MockBackend;

    fn wrapped(plan: FaultPlan) -> (FaultyBackend, Arc<FaultControls>) {
        let controls = FaultControls::new();
        let inner = Box::new(MockBackend::new(4, 3, vec![1, 2, 4], 0));
        let b = FaultyBackend::new(inner, plan, controls.clone());
        (b, controls)
    }

    #[test]
    fn clean_plan_is_transparent() {
        let (b, c) = wrapped(FaultPlan::default());
        assert_eq!(b.image_len(), 4);
        assert_eq!(b.classes(), 3);
        assert!(b.supports_batch(2));
        b.warmup().unwrap();
        let img = vec![2.0f32; 4];
        let logits = b.infer_batch(&img, 1).unwrap();
        assert_eq!(logits, vec![0.0, 0.0, 1.0]);
        assert_eq!(c.calls(), 1);
        assert_eq!(c.injected_total(), 0);
    }

    #[test]
    fn dead_scenario_errors_every_call() {
        let (b, c) = wrapped(FaultPlan::scenario("dead").unwrap());
        let img = vec![0.0f32; 4];
        for _ in 0..5 {
            assert!(b.infer_batch(&img, 1).is_err());
        }
        assert_eq!(c.injected_errors(), 5);
        assert_eq!(b.health(), BackendHealth::Healthy, "inner is fine");
    }

    #[test]
    fn scenarios_are_deterministic_in_seed() {
        let run = |seed: u64| {
            let mut plan = FaultPlan::scenario("flaky").unwrap();
            plan.seed = seed;
            let (b, c) = wrapped(plan);
            let img = vec![0.0f32; 4];
            let outcomes: Vec<bool> =
                (0..64).map(|_| b.infer_batch(&img, 1).is_ok()).collect();
            (outcomes, c.injected_total())
        };
        let (a1, n1) = run(7);
        let (a2, n2) = run(7);
        assert_eq!(a1, a2, "same seed, same fault schedule");
        assert_eq!(n1, n2);
        assert!(n1 > 0, "flaky over 64 calls must inject something");
        let (a3, _) = run(8);
        assert_ne!(a1, a3, "different seed, different schedule");
    }

    #[test]
    fn window_rules_expire() {
        let plan = FaultPlan::new(
            vec![FaultRule::window(2, 4, FaultKind::Error, 1.0)],
            0,
        );
        let (b, c) = wrapped(plan);
        let img = vec![0.0f32; 4];
        let ok: Vec<bool> = (0..6).map(|_| b.infer_batch(&img, 1).is_ok()).collect();
        assert_eq!(ok, vec![true, true, false, false, true, true]);
        assert_eq!(c.injected_errors(), 2);
    }

    #[test]
    fn call_counter_survives_rebuild() {
        // The supervisor re-creates the backend from the factory; a shared
        // FaultControls keeps window scenarios progressing.
        let plan = FaultPlan::new(
            vec![FaultRule::window(0, 3, FaultKind::Error, 1.0)],
            0,
        );
        let controls = FaultControls::new();
        let img = vec![0.0f32; 4];
        for round in 0..2 {
            let inner = Box::new(MockBackend::new(4, 3, vec![1], 0));
            let b = FaultyBackend::new(inner, plan.clone(), controls.clone());
            let r = b.infer_batch(&img, 1);
            let s = b.infer_batch(&img, 1);
            if round == 0 {
                assert!(r.is_err() && s.is_err());
            } else {
                assert!(r.is_err(), "call 2 still inside the window");
                assert!(s.is_ok(), "call 3 is past the window");
            }
        }
        assert_eq!(controls.calls(), 4);
    }

    #[test]
    fn forced_override_and_recovery() {
        let (b, c) = wrapped(FaultPlan::default());
        let img = vec![0.0f32; 4];
        assert!(b.infer_batch(&img, 1).is_ok());
        c.force(Forced::Error);
        assert!(b.infer_batch(&img, 1).is_err());
        c.force(Forced::None);
        assert!(b.infer_batch(&img, 1).is_ok(), "lifting the fault recovers");
        assert_eq!(c.injected_errors(), 1);
    }

    #[test]
    fn injected_panic_carries_typed_payload() {
        silence_injected_panics();
        let (b, c) = wrapped(FaultPlan::default());
        c.force(Forced::Panic);
        let img = vec![0.0f32; 4];
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = b.infer_batch(&img, 1);
        }));
        let payload = r.unwrap_err();
        let p = payload.downcast_ref::<InjectedPanic>().expect("typed payload");
        assert!(p.0.contains("injected fault"), "{}", p.0);
        assert_eq!(c.injected_panics(), 1);
    }

    #[test]
    fn corruption_moves_the_argmax() {
        let (b, c) = wrapped(FaultPlan::default());
        c.force(Forced::Corrupt);
        let img = vec![2.0f32; 4]; // honest class 2
        let logits = b.infer_batch(&img, 1).unwrap();
        assert_eq!(logits, vec![0.0, 1.0, 0.0], "row rotated: argmax now 1");
        assert_eq!(c.injected_corruptions(), 1);
    }

    #[test]
    fn parse_accepts_name_and_seed() {
        assert_eq!(FaultPlan::parse("flaky").unwrap().seed, 0xFA17);
        assert_eq!(FaultPlan::parse("storm:99").unwrap().seed, 99);
        assert!(FaultPlan::parse("nope").is_err());
        assert!(FaultPlan::parse("flaky:x").is_err());
        for name in ["flaky", "crashy", "storm", "dead", "latency", "corrupt"] {
            assert!(FaultPlan::scenario(name).is_some(), "{name}");
        }
    }
}
