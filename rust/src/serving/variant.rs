//! Model variants: the quantization spec a served backend was exported at
//! (uniform `wq` or channel-wise groups) and its routing profile — the
//! point it occupies on the paper's accuracy–throughput curve, with the
//! throughput side pulled from the cached holistic DSE.

use crate::cnn::{
    apply_channelwise,
    channelwise::{apply_joint_plan, apply_plan},
    ChannelGroup, Cnn, LayerKind,
};
use crate::config::RunConfig;
use crate::dse;

/// Which quantization a variant serves: a joint `(wq, aq)` specification —
/// weight word-lengths per layer/channel-group plus activation
/// word-lengths per layer (the paper's "weight and/or activation
/// word-length reduction").
#[derive(Clone, Debug, PartialEq)]
pub struct VariantSpec {
    /// Registry name, unique per server (e.g. `w4`, `w4a5`).
    pub name: String,
    /// Uniform inner-layer weight word-length, if uniform.
    pub wq: Option<u32>,
    /// Uniform inner-layer **activation** word-length; `None` means the
    /// paper's fixed 8 bit. Edge layers (first, last, FC) stay at 8 bit,
    /// exactly as their weights do.
    pub aq: Option<u32>,
    /// Channel-wise word-length groups (empty for uniform variants),
    /// applied to every inner layer.
    pub channelwise: Vec<ChannelGroup>,
    /// Planner-emitted per-layer plan: one group list per layer of the base
    /// CNN (empty unless the variant came from `planner::emit`). Takes
    /// precedence over `wq`/`channelwise` when non-empty.
    pub layerwise: Vec<Vec<ChannelGroup>>,
    /// Planner-emitted per-layer activation word-lengths, parallel to
    /// `layerwise` (empty = derive from `aq`). Takes precedence over `aq`
    /// when non-empty.
    pub layerwise_aq: Vec<u32>,
}

impl VariantSpec {
    /// Uniform word-length variant, named `w<wq>` (activations at the
    /// paper's fixed 8 bit).
    pub fn uniform(wq: u32) -> VariantSpec {
        VariantSpec {
            name: format!("w{wq}"),
            wq: Some(wq),
            aq: None,
            channelwise: Vec::new(),
            layerwise: Vec::new(),
            layerwise_aq: Vec::new(),
        }
    }

    /// Uniform **joint** `(wq, aq)` variant, named `w<wq>a<aq>` (plain
    /// `w<wq>` when `aq` is the paper's fixed 8 bit — identical to
    /// [`uniform`](Self::uniform) then).
    pub fn uniform_joint(wq: u32, aq: u32) -> VariantSpec {
        if aq == 8 {
            return VariantSpec::uniform(wq);
        }
        VariantSpec {
            name: format!("w{wq}a{aq}"),
            wq: Some(wq),
            aq: Some(aq),
            channelwise: Vec::new(),
            layerwise: Vec::new(),
            layerwise_aq: Vec::new(),
        }
    }

    /// Channel-wise mixed-precision variant.
    pub fn channelwise(name: impl Into<String>, groups: Vec<ChannelGroup>) -> VariantSpec {
        VariantSpec {
            name: name.into(),
            wq: None,
            aq: None,
            channelwise: groups,
            layerwise: Vec::new(),
            layerwise_aq: Vec::new(),
        }
    }

    /// Planner-emitted variant with an explicit per-layer plan (see
    /// [`crate::planner`]); `per_layer` must have one entry per base-CNN
    /// layer. Activations default to 8 bit; attach per-layer activation
    /// word-lengths with [`with_layerwise_aq`](Self::with_layerwise_aq).
    pub fn planned(name: impl Into<String>, per_layer: Vec<Vec<ChannelGroup>>) -> VariantSpec {
        VariantSpec {
            name: name.into(),
            wq: None,
            aq: None,
            channelwise: Vec::new(),
            layerwise: per_layer,
            layerwise_aq: Vec::new(),
        }
    }

    /// Rename (builder-style).
    pub fn named(mut self, name: impl Into<String>) -> VariantSpec {
        self.name = name.into();
        self
    }

    /// Set the uniform inner-layer activation word-length (builder-style).
    pub fn with_aq(mut self, aq: u32) -> VariantSpec {
        self.aq = Some(aq);
        self
    }

    /// Attach planner-emitted per-layer activation word-lengths, one per
    /// base-CNN layer (builder-style).
    pub fn with_layerwise_aq(mut self, aq: Vec<u32>) -> VariantSpec {
        self.layerwise_aq = aq;
        self
    }

    /// Quantize `base` according to this spec (the CNN the DSE and the
    /// virtual-clock simulation run on). Joint specs also lower their
    /// activation word-lengths into the layers' `act_bits`, so footprint
    /// and activation-traffic models cost them.
    pub fn apply(&self, base: &Cnn) -> Cnn {
        let aqs = self.per_layer_aq(base);
        if aqs.iter().any(|&a| a != 8) {
            return apply_joint_plan(base, &self.per_layer_plan(base), &aqs);
        }
        if !self.layerwise.is_empty() {
            apply_plan(base, &self.layerwise)
        } else if self.channelwise.is_empty() {
            base.clone().with_uniform_wq(self.wq.unwrap_or(8))
        } else {
            apply_channelwise(base, &self.channelwise)
        }
    }

    /// The explicit per-base-layer **activation** word-lengths this spec
    /// denotes, parallel to [`per_layer_plan`](Self::per_layer_plan):
    /// edge layers (first, last, FC) pinned to 8 bit, inner layers at the
    /// planner's `layerwise_aq` or the uniform `aq` (default 8). This is
    /// the form the xmp engine slices activations from.
    pub fn per_layer_aq(&self, base: &Cnn) -> Vec<u32> {
        if !self.layerwise_aq.is_empty() {
            assert_eq!(
                self.layerwise_aq.len(),
                base.layers.len(),
                "layerwise aq plan must have one entry per base layer"
            );
            return self.layerwise_aq.clone();
        }
        let n = base.layers.len();
        (0..n)
            .map(|i| {
                let edge = i == 0 || i + 1 == n || base.layers[i].kind == LayerKind::Fc;
                if edge {
                    8
                } else {
                    self.aq.unwrap_or(8)
                }
            })
            .collect()
    }

    /// The explicit per-base-layer plan this spec denotes: one
    /// [`ChannelGroup`] list per layer of `base`, with edge layers (first,
    /// last, FC) pinned to 8 bit exactly as [`apply`](Self::apply)'s
    /// lowering pins them. This is the form the xmp execution engine
    /// ([`crate::xmp`]) packs weights from — one layer with word-length
    /// groups *inside* it, rather than the split sub-layer view the
    /// DSE/simulator schedule uses; both derive their channel counts from
    /// [`crate::cnn::channelwise::group_channel_counts`].
    pub fn per_layer_plan(&self, base: &Cnn) -> Vec<Vec<ChannelGroup>> {
        if !self.layerwise.is_empty() {
            return self.layerwise.clone();
        }
        let n = base.layers.len();
        (0..n)
            .map(|i| {
                let edge = i == 0 || i + 1 == n || base.layers[i].kind == LayerKind::Fc;
                if edge || self.channelwise.is_empty() {
                    let wq = if edge { 8 } else { self.wq.unwrap_or(8) };
                    vec![ChannelGroup { wq, fraction: 1.0 }]
                } else {
                    self.channelwise.clone()
                }
            })
            .collect()
    }

    /// Estimated Top-5 accuracy in percent from the paper's tables for
    /// `family` (e.g. `"ResNet-18"`); channel-wise groups use the anchor
    /// interpolation of [`crate::report::paper::top5_interpolated`]
    /// (fraction-weighted), so non-anchor word-lengths like `w_Q = 3`
    /// resolve too. `None` when the paper has no rows for the family, or
    /// for planner-emitted layerwise specs (their profiles carry the
    /// planner's calibrated proxy instead). The estimate is weight-lineage
    /// only — the paper publishes no reduced-`a_Q` accuracy rows, so a
    /// joint `w4a4` variant reports the `w4` table value; the planner's
    /// calibrated proxy (which does model the activation term) is the
    /// profile to prefer for joint plans.
    pub fn estimated_top5(&self, family: &str) -> Option<f64> {
        if !self.layerwise.is_empty() {
            return None;
        }
        if self.channelwise.is_empty() {
            return paper_top5(family, self.wq?);
        }
        let mut acc = 0.0;
        for g in &self.channelwise {
            acc += g.fraction * crate::report::paper::top5_interpolated(family, g.wq as f64)?;
        }
        Some(acc)
    }
}

/// Paper Top-5 lookup (Tables III + IV — the single source of truth lives
/// in [`crate::report::paper`]).
pub fn paper_top5(family: &str, wq: u32) -> Option<f64> {
    crate::report::paper::top5_accuracy(family, wq)
}

/// A variant's routing profile: where it sits on the accuracy–throughput
/// trade-off curve.
#[derive(Clone, Copy, Debug, Default)]
pub struct VariantProfile {
    /// Estimated Top-5 accuracy in percent (paper lineage), if known.
    pub top5_accuracy: Option<f64>,
    /// Frames/s of the DSE-chosen simulated accelerator design; also used
    /// as the variant's virtual-clock rate when the batcher config doesn't
    /// override it.
    pub fpga_fps: f64,
    /// Energy per frame of that design, mJ.
    pub fpga_mj_per_frame: f64,
}

impl VariantProfile {
    /// Derive the profile by running (or re-using, via the process-global
    /// [`dse::DseCache`]) the holistic DSE for this spec's quantization of
    /// `base`, and looking the accuracy up in the paper's `family` tables.
    /// Joint specs with reduced activation word-lengths get the table
    /// value *penalized* by the planner's calibrated activation-noise
    /// proxy ([`joint_top5_estimate`]) — otherwise `MinAccuracy` routing
    /// would treat e.g. `w4a2` as the full `w4` accuracy and place
    /// traffic on a variant that cannot meet the requested floor.
    pub fn from_dse(spec: &VariantSpec, base: &Cnn, cfg: &RunConfig, family: &str)
        -> VariantProfile {
        let cnn = spec.apply(base);
        let k = spec.wq.unwrap_or(2).clamp(1, 4);
        let out = dse::explore_k_cached(&cnn, cfg, k, dse::DseCache::global());
        let top5 = if spec.per_layer_aq(base).iter().any(|&a| a != 8) {
            joint_top5_estimate(spec, base, family).or_else(|| spec.estimated_top5(family))
        } else {
            spec.estimated_top5(family)
        };
        VariantProfile {
            top5_accuracy: top5,
            fpga_fps: out.sim.fps,
            fpga_mj_per_frame: out.sim.e_total_mj(),
        }
    }
}

/// Activation-noise-penalized Top-5 estimate for a uniform joint spec:
/// the paper table's weight-lineage value minus the calibrated
/// [`crate::planner::SensitivityModel`] proxy gap between the weight-only
/// and joint assignments on `base` (exactly zero at `a_Q = 8` by the
/// proxy's delta calibration). `None` when the spec has no uniform `wq`,
/// no table row, or the family has no anchors — callers fall back to the
/// weight-only estimate.
pub fn joint_top5_estimate(spec: &VariantSpec, base: &Cnn, family: &str) -> Option<f64> {
    let weight_only = spec.estimated_top5(family)?;
    let wq = spec.wq?;
    if !(1..=8).contains(&wq) {
        return None;
    }
    let aqs = spec.per_layer_aq(base);
    let model =
        crate::planner::SensitivityModel::build(base, family, 1.0, &[wq], &aqs).ok()?;
    let flat = crate::planner::Assignment::uniform(base, wq);
    let mut joint = flat.clone();
    joint.aq = aqs;
    let penalty = model.proxy_top5(&flat) - model.proxy_top5(&joint);
    Some((weight_only - penalty).max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;

    #[test]
    fn uniform_spec_naming_and_accuracy() {
        let s = VariantSpec::uniform(2);
        assert_eq!(s.name, "w2");
        assert_eq!(s.wq, Some(2));
        assert_eq!(s.estimated_top5("ResNet-18"), Some(87.48));
        assert_eq!(VariantSpec::uniform(8).estimated_top5("ResNet-18"), Some(89.62));
        assert_eq!(VariantSpec::uniform(3).estimated_top5("ResNet-18"), None);
    }

    #[test]
    fn uniform_joint_spec_names_plans_and_lowers() {
        let base = resnet::resnet_small(1, 10);
        let s = VariantSpec::uniform_joint(4, 5);
        assert_eq!(s.name, "w4a5");
        assert_eq!((s.wq, s.aq), (Some(4), Some(5)));
        // aq = 8 collapses to the plain uniform spec — same name, same
        // equality, so registries and Exact routing are unchanged.
        assert_eq!(VariantSpec::uniform_joint(4, 8), VariantSpec::uniform(4));
        // Per-layer aq pins edges to 8 and inner layers to aq.
        let aqs = s.per_layer_aq(&base);
        assert_eq!(aqs[0], 8);
        assert_eq!(aqs[1], 5);
        assert_eq!(*aqs.last().unwrap(), 8);
        // apply() lowers act_bits so footprint/fingerprint see the plan.
        let cnn = s.apply(&base);
        assert_eq!(cnn.layers[0].act_bits, 8);
        assert_eq!(cnn.layers[1].act_bits, 5);
        assert_ne!(
            cnn.fingerprint(),
            VariantSpec::uniform(4).apply(&base).fingerprint(),
            "joint quantization is a distinct DSE-cache entry"
        );
        assert!(
            cnn.total_activation_bits()
                < VariantSpec::uniform(4).apply(&base).total_activation_bits()
        );
        // The weight side is untouched by aq.
        assert_eq!(s.per_layer_plan(&base), VariantSpec::uniform(4).per_layer_plan(&base));
    }

    #[test]
    fn planned_spec_carries_layerwise_aq() {
        let base = resnet::resnet_small(1, 10);
        let n = base.layers.len();
        let plan = VariantSpec::uniform(2).per_layer_plan(&base);
        let aq: Vec<u32> = (0..n).map(|i| if i == 2 { 3 } else { 8 }).collect();
        let spec = VariantSpec::planned("mp0", plan).with_layerwise_aq(aq.clone());
        assert_eq!(spec.per_layer_aq(&base), aq);
        let cnn = spec.apply(&base);
        assert_eq!(cnn.layers[2].act_bits, 3);
    }

    #[test]
    fn channelwise_accuracy_interpolates() {
        let s = VariantSpec::channelwise(
            "mix24",
            vec![
                ChannelGroup { wq: 2, fraction: 0.5 },
                ChannelGroup { wq: 4, fraction: 0.5 },
            ],
        );
        let acc = s.estimated_top5("ResNet-18").unwrap();
        assert!((acc - (87.48 + 89.10) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn channelwise_non_anchor_wq_interpolates() {
        // A 3-bit group previously had no accuracy estimate (nearest-anchor
        // lookup returned None); it now interpolates between w2 and w4.
        let s = VariantSpec::channelwise(
            "mix38",
            vec![
                ChannelGroup { wq: 3, fraction: 0.5 },
                ChannelGroup { wq: 8, fraction: 0.5 },
            ],
        );
        let acc = s.estimated_top5("ResNet-18").unwrap();
        let t3 = crate::report::paper::top5_interpolated("ResNet-18", 3.0).unwrap();
        assert!((acc - (t3 + 89.62) / 2.0).abs() < 1e-9, "{acc}");
        assert!(acc > 87.48 && acc < 89.62);
    }

    #[test]
    fn planned_spec_applies_per_layer() {
        let base = resnet::resnet_small(1, 10);
        let n = base.layers.len();
        let per_layer: Vec<Vec<ChannelGroup>> = (0..n)
            .map(|i| {
                let wq = if i == 0 || i == n - 1 { 8 } else { 2 };
                vec![ChannelGroup { wq, fraction: 1.0 }]
            })
            .collect();
        let spec = VariantSpec::planned("mp0", per_layer);
        assert_eq!(spec.name, "mp0");
        let cnn = spec.apply(&base);
        assert_eq!(
            cnn.fingerprint(),
            base.clone().with_uniform_wq(2).fingerprint(),
            "an all-uniform plan must lower to the same CNN as with_uniform_wq"
        );
        // Layerwise specs carry no table-lineage estimate of their own.
        assert_eq!(spec.estimated_top5("ResNet-18"), None);
    }

    #[test]
    fn per_layer_plan_matches_apply_lowering() {
        use crate::cnn::channelwise::apply_plan;
        let base = resnet::resnet_small(1, 10);
        // Uniform: lowering the plan must produce the same CNN as apply().
        let u = VariantSpec::uniform(2);
        assert_eq!(
            apply_plan(&base, &u.per_layer_plan(&base)).fingerprint(),
            u.apply(&base).fingerprint()
        );
        // Channel-wise: same sub-layer structure as apply_channelwise.
        let cw = VariantSpec::channelwise(
            "mix",
            vec![
                ChannelGroup { wq: 2, fraction: 0.5 },
                ChannelGroup { wq: 8, fraction: 0.5 },
            ],
        );
        let plan = cw.per_layer_plan(&base);
        assert_eq!(plan.len(), base.layers.len());
        assert_eq!(plan[0], vec![ChannelGroup { wq: 8, fraction: 1.0 }]);
        assert_eq!(plan[1].len(), 2);
        let lowered = apply_plan(&base, &plan);
        assert_eq!(
            lowered.layers.len(),
            cw.apply(&base).layers.len(),
            "same split structure as apply_channelwise"
        );
        // Planned specs return their layerwise plan verbatim.
        let p = VariantSpec::planned("mp0", plan.clone());
        assert_eq!(p.per_layer_plan(&base), plan);
    }

    #[test]
    fn apply_quantizes_base() {
        let base = resnet::resnet_small(1, 10);
        let s = VariantSpec::uniform(2);
        let cnn = s.apply(&base);
        // Quantization changes the structural fingerprint.
        assert_ne!(cnn.fingerprint(), base.clone().with_uniform_wq(8).fingerprint());
    }

    #[test]
    fn joint_profile_penalizes_reduced_activations() {
        let base = resnet::resnet_small(1, 10);
        let cfg = RunConfig::default();
        let w4 = VariantProfile::from_dse(&VariantSpec::uniform(4), &base, &cfg, "ResNet-18");
        assert_eq!(w4.top5_accuracy, Some(89.10));
        // Reduced activations must NOT inherit the full weight-lineage
        // accuracy — MinAccuracy routing reads this field.
        let w4a2 =
            VariantProfile::from_dse(&VariantSpec::uniform_joint(4, 2), &base, &cfg, "ResNet-18");
        let t = w4a2.top5_accuracy.unwrap();
        assert!(t < 89.10 && t > 0.0, "{t}");
        // A mild reduction costs less than a harsh one.
        let w4a6 =
            VariantProfile::from_dse(&VariantSpec::uniform_joint(4, 6), &base, &cfg, "ResNet-18");
        assert!(w4a6.top5_accuracy.unwrap() > t);
        // aq = 8 is the identity: same estimate as the plain uniform.
        let w4a8 =
            VariantProfile::from_dse(&VariantSpec::uniform_joint(4, 8), &base, &cfg, "ResNet-18");
        assert_eq!(w4a8.top5_accuracy, w4.top5_accuracy);
    }

    #[test]
    fn profile_from_dse_pulls_cached_outcome() {
        let base = resnet::resnet_small(1, 10);
        let cfg = RunConfig::default();
        let spec = VariantSpec::uniform(2);
        let p1 = VariantProfile::from_dse(&spec, &base, &cfg, "ResNet-18");
        assert!(p1.fpga_fps > 0.0);
        assert!(p1.fpga_mj_per_frame > 0.0);
        assert_eq!(p1.top5_accuracy, Some(87.48));
        // Second derivation must be a cache hit (identical outcome).
        let p2 = VariantProfile::from_dse(&spec, &base, &cfg, "ResNet-18");
        assert_eq!(p1.fpga_fps.to_bits(), p2.fpga_fps.to_bits());
    }
}
