//! Model variants: the quantization spec a served backend was exported at
//! (uniform `wq` or channel-wise groups) and its routing profile — the
//! point it occupies on the paper's accuracy–throughput curve, with the
//! throughput side pulled from the cached holistic DSE.

use crate::cnn::{apply_channelwise, channelwise::apply_plan, ChannelGroup, Cnn, LayerKind};
use crate::config::RunConfig;
use crate::dse;

/// Which quantization a variant serves.
#[derive(Clone, Debug, PartialEq)]
pub struct VariantSpec {
    /// Registry name, unique per server (e.g. `w4`).
    pub name: String,
    /// Uniform inner-layer weight word-length, if uniform.
    pub wq: Option<u32>,
    /// Channel-wise word-length groups (empty for uniform variants),
    /// applied to every inner layer.
    pub channelwise: Vec<ChannelGroup>,
    /// Planner-emitted per-layer plan: one group list per layer of the base
    /// CNN (empty unless the variant came from `planner::emit`). Takes
    /// precedence over `wq`/`channelwise` when non-empty.
    pub layerwise: Vec<Vec<ChannelGroup>>,
}

impl VariantSpec {
    /// Uniform word-length variant, named `w<wq>`.
    pub fn uniform(wq: u32) -> VariantSpec {
        VariantSpec {
            name: format!("w{wq}"),
            wq: Some(wq),
            channelwise: Vec::new(),
            layerwise: Vec::new(),
        }
    }

    /// Channel-wise mixed-precision variant.
    pub fn channelwise(name: impl Into<String>, groups: Vec<ChannelGroup>) -> VariantSpec {
        VariantSpec {
            name: name.into(),
            wq: None,
            channelwise: groups,
            layerwise: Vec::new(),
        }
    }

    /// Planner-emitted variant with an explicit per-layer plan (see
    /// [`crate::planner`]); `per_layer` must have one entry per base-CNN
    /// layer.
    pub fn planned(name: impl Into<String>, per_layer: Vec<Vec<ChannelGroup>>) -> VariantSpec {
        VariantSpec {
            name: name.into(),
            wq: None,
            channelwise: Vec::new(),
            layerwise: per_layer,
        }
    }

    /// Rename (builder-style).
    pub fn named(mut self, name: impl Into<String>) -> VariantSpec {
        self.name = name.into();
        self
    }

    /// Quantize `base` according to this spec (the CNN the DSE and the
    /// virtual-clock simulation run on).
    pub fn apply(&self, base: &Cnn) -> Cnn {
        if !self.layerwise.is_empty() {
            apply_plan(base, &self.layerwise)
        } else if self.channelwise.is_empty() {
            base.clone().with_uniform_wq(self.wq.unwrap_or(8))
        } else {
            apply_channelwise(base, &self.channelwise)
        }
    }

    /// The explicit per-base-layer plan this spec denotes: one
    /// [`ChannelGroup`] list per layer of `base`, with edge layers (first,
    /// last, FC) pinned to 8 bit exactly as [`apply`](Self::apply)'s
    /// lowering pins them. This is the form the xmp execution engine
    /// ([`crate::xmp`]) packs weights from — one layer with word-length
    /// groups *inside* it, rather than the split sub-layer view the
    /// DSE/simulator schedule uses; both derive their channel counts from
    /// [`crate::cnn::channelwise::group_channel_counts`].
    pub fn per_layer_plan(&self, base: &Cnn) -> Vec<Vec<ChannelGroup>> {
        if !self.layerwise.is_empty() {
            return self.layerwise.clone();
        }
        let n = base.layers.len();
        (0..n)
            .map(|i| {
                let edge = i == 0 || i + 1 == n || base.layers[i].kind == LayerKind::Fc;
                if edge || self.channelwise.is_empty() {
                    let wq = if edge { 8 } else { self.wq.unwrap_or(8) };
                    vec![ChannelGroup { wq, fraction: 1.0 }]
                } else {
                    self.channelwise.clone()
                }
            })
            .collect()
    }

    /// Estimated Top-5 accuracy in percent from the paper's tables for
    /// `family` (e.g. `"ResNet-18"`); channel-wise groups use the anchor
    /// interpolation of [`crate::report::paper::top5_interpolated`]
    /// (fraction-weighted), so non-anchor word-lengths like `w_Q = 3`
    /// resolve too. `None` when the paper has no rows for the family, or
    /// for planner-emitted layerwise specs (their profiles carry the
    /// planner's calibrated proxy instead).
    pub fn estimated_top5(&self, family: &str) -> Option<f64> {
        if !self.layerwise.is_empty() {
            return None;
        }
        if self.channelwise.is_empty() {
            return paper_top5(family, self.wq?);
        }
        let mut acc = 0.0;
        for g in &self.channelwise {
            acc += g.fraction * crate::report::paper::top5_interpolated(family, g.wq as f64)?;
        }
        Some(acc)
    }
}

/// Paper Top-5 lookup (Tables III + IV — the single source of truth lives
/// in [`crate::report::paper`]).
pub fn paper_top5(family: &str, wq: u32) -> Option<f64> {
    crate::report::paper::top5_accuracy(family, wq)
}

/// A variant's routing profile: where it sits on the accuracy–throughput
/// trade-off curve.
#[derive(Clone, Copy, Debug, Default)]
pub struct VariantProfile {
    /// Estimated Top-5 accuracy in percent (paper lineage), if known.
    pub top5_accuracy: Option<f64>,
    /// Frames/s of the DSE-chosen simulated accelerator design; also used
    /// as the variant's virtual-clock rate when the batcher config doesn't
    /// override it.
    pub fpga_fps: f64,
    /// Energy per frame of that design, mJ.
    pub fpga_mj_per_frame: f64,
}

impl VariantProfile {
    /// Derive the profile by running (or re-using, via the process-global
    /// [`dse::DseCache`]) the holistic DSE for this spec's quantization of
    /// `base`, and looking the accuracy up in the paper's `family` tables.
    pub fn from_dse(spec: &VariantSpec, base: &Cnn, cfg: &RunConfig, family: &str)
        -> VariantProfile {
        let cnn = spec.apply(base);
        let k = spec.wq.unwrap_or(2).clamp(1, 4);
        let out = dse::explore_k_cached(&cnn, cfg, k, dse::DseCache::global());
        VariantProfile {
            top5_accuracy: spec.estimated_top5(family),
            fpga_fps: out.sim.fps,
            fpga_mj_per_frame: out.sim.e_total_mj(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;

    #[test]
    fn uniform_spec_naming_and_accuracy() {
        let s = VariantSpec::uniform(2);
        assert_eq!(s.name, "w2");
        assert_eq!(s.wq, Some(2));
        assert_eq!(s.estimated_top5("ResNet-18"), Some(87.48));
        assert_eq!(VariantSpec::uniform(8).estimated_top5("ResNet-18"), Some(89.62));
        assert_eq!(VariantSpec::uniform(3).estimated_top5("ResNet-18"), None);
    }

    #[test]
    fn channelwise_accuracy_interpolates() {
        let s = VariantSpec::channelwise(
            "mix24",
            vec![
                ChannelGroup { wq: 2, fraction: 0.5 },
                ChannelGroup { wq: 4, fraction: 0.5 },
            ],
        );
        let acc = s.estimated_top5("ResNet-18").unwrap();
        assert!((acc - (87.48 + 89.10) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn channelwise_non_anchor_wq_interpolates() {
        // A 3-bit group previously had no accuracy estimate (nearest-anchor
        // lookup returned None); it now interpolates between w2 and w4.
        let s = VariantSpec::channelwise(
            "mix38",
            vec![
                ChannelGroup { wq: 3, fraction: 0.5 },
                ChannelGroup { wq: 8, fraction: 0.5 },
            ],
        );
        let acc = s.estimated_top5("ResNet-18").unwrap();
        let t3 = crate::report::paper::top5_interpolated("ResNet-18", 3.0).unwrap();
        assert!((acc - (t3 + 89.62) / 2.0).abs() < 1e-9, "{acc}");
        assert!(acc > 87.48 && acc < 89.62);
    }

    #[test]
    fn planned_spec_applies_per_layer() {
        let base = resnet::resnet_small(1, 10);
        let n = base.layers.len();
        let per_layer: Vec<Vec<ChannelGroup>> = (0..n)
            .map(|i| {
                let wq = if i == 0 || i == n - 1 { 8 } else { 2 };
                vec![ChannelGroup { wq, fraction: 1.0 }]
            })
            .collect();
        let spec = VariantSpec::planned("mp0", per_layer);
        assert_eq!(spec.name, "mp0");
        let cnn = spec.apply(&base);
        assert_eq!(
            cnn.fingerprint(),
            base.clone().with_uniform_wq(2).fingerprint(),
            "an all-uniform plan must lower to the same CNN as with_uniform_wq"
        );
        // Layerwise specs carry no table-lineage estimate of their own.
        assert_eq!(spec.estimated_top5("ResNet-18"), None);
    }

    #[test]
    fn per_layer_plan_matches_apply_lowering() {
        use crate::cnn::channelwise::apply_plan;
        let base = resnet::resnet_small(1, 10);
        // Uniform: lowering the plan must produce the same CNN as apply().
        let u = VariantSpec::uniform(2);
        assert_eq!(
            apply_plan(&base, &u.per_layer_plan(&base)).fingerprint(),
            u.apply(&base).fingerprint()
        );
        // Channel-wise: same sub-layer structure as apply_channelwise.
        let cw = VariantSpec::channelwise(
            "mix",
            vec![
                ChannelGroup { wq: 2, fraction: 0.5 },
                ChannelGroup { wq: 8, fraction: 0.5 },
            ],
        );
        let plan = cw.per_layer_plan(&base);
        assert_eq!(plan.len(), base.layers.len());
        assert_eq!(plan[0], vec![ChannelGroup { wq: 8, fraction: 1.0 }]);
        assert_eq!(plan[1].len(), 2);
        let lowered = apply_plan(&base, &plan);
        assert_eq!(
            lowered.layers.len(),
            cw.apply(&base).layers.len(),
            "same split structure as apply_channelwise"
        );
        // Planned specs return their layerwise plan verbatim.
        let p = VariantSpec::planned("mp0", plan.clone());
        assert_eq!(p.per_layer_plan(&base), plan);
    }

    #[test]
    fn apply_quantizes_base() {
        let base = resnet::resnet_small(1, 10);
        let s = VariantSpec::uniform(2);
        let cnn = s.apply(&base);
        // Quantization changes the structural fingerprint.
        assert_ne!(cnn.fingerprint(), base.clone().with_uniform_wq(8).fingerprint());
    }

    #[test]
    fn profile_from_dse_pulls_cached_outcome() {
        let base = resnet::resnet_small(1, 10);
        let cfg = RunConfig::default();
        let spec = VariantSpec::uniform(2);
        let p1 = VariantProfile::from_dse(&spec, &base, &cfg, "ResNet-18");
        assert!(p1.fpga_fps > 0.0);
        assert!(p1.fpga_mj_per_frame > 0.0);
        assert_eq!(p1.top5_accuracy, Some(87.48));
        // Second derivation must be a cache hit (identical outcome).
        let p2 = VariantProfile::from_dse(&spec, &base, &cfg, "ResNet-18");
        assert_eq!(p1.fpga_fps.to_bits(), p2.fpga_fps.to_bits());
    }
}
