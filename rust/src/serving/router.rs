//! Variant routing: resolve a [`VariantSelector`] to one of the server's
//! registered model variants using their static profiles (paper accuracy,
//! DSE-simulated fps) and live signals (EWMA latency, in-flight depth,
//! backend health).
//!
//! This operationalizes the paper's accuracy–throughput trade-off curve
//! (Fig 9 / Table IV): a request that asks for "at least 87% Top-5" or
//! "under 5 ms" is placed on the cheapest precision variant that satisfies
//! the constraint, and placement shifts as observed latencies move.

use super::backend::BackendHealth;
use super::VariantSelector;
use std::fmt;
use std::sync::Arc;

/// Routing failure. Deliberately *not* silently recovered: `Exact`/`Named`
/// misses and unsatisfiable policies surface to the caller.
#[derive(Clone, Debug, PartialEq)]
pub enum RouteError {
    /// The server has no variants at all (builder misuse).
    NoVariants,
    /// `Exact(wq)` / `Named(name)` matched nothing. Never falls back.
    NoSuchVariant(String),
    /// A policy selector matched no healthy variant.
    Unsatisfiable(String),
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::NoVariants => write!(f, "server has no variants"),
            RouteError::NoSuchVariant(what) => write!(f, "no such variant: {what}"),
            RouteError::Unsatisfiable(why) => write!(f, "no variant satisfies policy: {why}"),
        }
    }
}

impl std::error::Error for RouteError {}

/// Snapshot of one variant as seen by the router: static profile plus the
/// live signals the worker publishes lock-free.
#[derive(Clone, Debug)]
pub struct VariantStatus {
    /// Shared with the server so per-request snapshots clone a pointer,
    /// not a `String`.
    pub name: Arc<str>,
    /// Uniform weight word-length, if the variant is uniform.
    pub wq: Option<u32>,
    /// Estimated Top-5 accuracy in percent (paper Table III lineage), if
    /// known.
    pub top5_accuracy: Option<f64>,
    /// Frames/s of the DSE-chosen simulated design (the throughput side of
    /// the trade-off curve); 0 if unknown.
    pub fpga_fps: f64,
    /// Live EWMA of end-to-end latency in microseconds; 0 until the first
    /// response.
    pub ewma_latency_us: f64,
    /// Requests currently queued or executing.
    pub inflight: u64,
    /// Effective health as seen by routing. `Server::statuses` folds the
    /// variant's circuit breaker into the worker-observed health before
    /// building this snapshot (open breaker → `Unavailable`, half-open →
    /// `Degraded`), so routing logic here stays breaker-agnostic.
    pub health: BackendHealth,
    /// Is this the server's default variant?
    pub default: bool,
}

impl VariantStatus {
    /// The router's latency estimate in microseconds: live EWMA once
    /// traffic has flowed, else the DSE fps estimate as a prior, else a
    /// pessimistic 1 s. Queue depth inflates the estimate so a backed-up
    /// variant looks slow before its EWMA catches up.
    pub fn latency_estimate_us(&self) -> f64 {
        let base = if self.ewma_latency_us > 0.0 {
            self.ewma_latency_us
        } else if self.fpga_fps > 0.0 {
            1e6 / self.fpga_fps
        } else {
            1e6
        };
        base * (1.0 + self.inflight as f64 / 8.0)
    }
}

/// Pluggable routing policy. Implementations must be pure functions of the
/// statuses (no interior blocking): the server calls this on every submit.
pub trait Router: Send + Sync + 'static {
    /// Resolve `sel` to an index into `variants`, or explain why not.
    fn route(&self, sel: &VariantSelector, variants: &[VariantStatus])
        -> Result<usize, RouteError>;
}

/// The default policy router.
///
/// - `Default` → the registered default variant.
/// - `Exact(wq)` / `Named(name)` → that variant or `NoSuchVariant`; never a
///   fallback, regardless of health (errors should surface, not be masked
///   by silently serving a different precision).
/// - `MinAccuracy(pct)` → among variants with `top5_accuracy >= pct` (and
///   not `Unavailable`), the lowest current latency estimate.
/// - `MaxLatency(d)` → among variants with latency estimate `<= d` (and
///   not `Unavailable`), the highest accuracy; latency breaks ties.
///
/// Exclusion is never permanent: a starved variant's EWMA decays on the
/// worker's idle ticks (see the worker's `IDLE_EWMA_DECAY`), so a variant
/// knocked out by a transient degradation re-qualifies and gets probed.
#[derive(Clone, Copy, Debug, Default)]
pub struct PolicyRouter;

impl PolicyRouter {
    fn usable(v: &VariantStatus) -> bool {
        v.health != BackendHealth::Unavailable
    }
}

impl Router for PolicyRouter {
    fn route(
        &self,
        sel: &VariantSelector,
        variants: &[VariantStatus],
    ) -> Result<usize, RouteError> {
        if variants.is_empty() {
            return Err(RouteError::NoVariants);
        }
        match sel {
            VariantSelector::Default => Ok(variants
                .iter()
                .position(|v| v.default)
                .unwrap_or(0)),
            VariantSelector::Exact(wq) => variants
                .iter()
                .position(|v| v.wq == Some(*wq))
                .ok_or_else(|| RouteError::NoSuchVariant(format!("wq={wq}"))),
            VariantSelector::Named(name) => variants
                .iter()
                .position(|v| v.name.as_ref() == name.as_str())
                .ok_or_else(|| RouteError::NoSuchVariant(format!("name='{name}'"))),
            VariantSelector::MinAccuracy(pct) => {
                let best = variants
                    .iter()
                    .enumerate()
                    .filter(|(_, v)| Self::usable(v))
                    .filter(|(_, v)| v.top5_accuracy.map(|a| a >= *pct).unwrap_or(false))
                    .min_by(|(_, a), (_, b)| {
                        a.latency_estimate_us()
                            .partial_cmp(&b.latency_estimate_us())
                            .unwrap()
                    });
                best.map(|(i, _)| i).ok_or_else(|| {
                    RouteError::Unsatisfiable(format!("min-accuracy {pct:.2}%"))
                })
            }
            VariantSelector::MaxLatency(limit) => {
                let limit_us = limit.as_secs_f64() * 1e6;
                let best = variants
                    .iter()
                    .enumerate()
                    .filter(|(_, v)| Self::usable(v))
                    .filter(|(_, v)| v.latency_estimate_us() <= limit_us)
                    .max_by(|(_, a), (_, b)| {
                        let acc_a = a.top5_accuracy.unwrap_or(-1.0);
                        let acc_b = b.top5_accuracy.unwrap_or(-1.0);
                        acc_a.partial_cmp(&acc_b).unwrap().then(
                            // tie on accuracy: prefer the *faster* one, i.e.
                            // the max of the reversed latency ordering
                            b.latency_estimate_us()
                                .partial_cmp(&a.latency_estimate_us())
                                .unwrap(),
                        )
                    });
                best.map(|(i, _)| i).ok_or_else(|| {
                    RouteError::Unsatisfiable(format!(
                        "max-latency {:.1}ms",
                        limit.as_secs_f64() * 1e3
                    ))
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check_eq, forall};
    use std::time::Duration;

    fn status(name: &str, wq: u32, acc: f64, fps: f64) -> VariantStatus {
        VariantStatus {
            name: Arc::from(name),
            wq: Some(wq),
            top5_accuracy: Some(acc),
            fpga_fps: fps,
            ewma_latency_us: 0.0,
            inflight: 0,
            health: BackendHealth::Healthy,
            default: false,
        }
    }

    #[test]
    fn default_prefers_marked_variant() {
        let mut vs = vec![status("w2", 2, 87.48, 245.0), status("w8", 8, 89.62, 47.0)];
        vs[1].default = true;
        assert_eq!(PolicyRouter.route(&VariantSelector::Default, &vs), Ok(1));
    }

    #[test]
    fn exact_hits_or_errors() {
        let vs = vec![status("w2", 2, 87.48, 245.0), status("w8", 8, 89.62, 47.0)];
        assert_eq!(PolicyRouter.route(&VariantSelector::Exact(8), &vs), Ok(1));
        assert!(matches!(
            PolicyRouter.route(&VariantSelector::Exact(4), &vs),
            Err(RouteError::NoSuchVariant(_))
        ));
        assert_eq!(
            PolicyRouter.route(&VariantSelector::Named("w2".into()), &vs),
            Ok(0)
        );
    }

    #[test]
    fn min_accuracy_picks_fastest_qualifying() {
        // w2 and w4 both qualify at 87%; w2's DSE fps prior is higher, so
        // with no live data it wins. w1 is excluded on accuracy.
        let vs = vec![
            status("w1", 1, 65.29, 271.0),
            status("w2", 2, 87.48, 245.0),
            status("w4", 4, 89.10, 165.0),
        ];
        assert_eq!(
            PolicyRouter.route(&VariantSelector::MinAccuracy(87.0), &vs),
            Ok(1)
        );
        // Live latency overrides the prior: w2 degraded, w4 takes over.
        let mut vs2 = vs.clone();
        vs2[1].ewma_latency_us = 50_000.0;
        vs2[2].ewma_latency_us = 4_000.0;
        assert_eq!(
            PolicyRouter.route(&VariantSelector::MinAccuracy(87.0), &vs2),
            Ok(2)
        );
        // Nothing reaches 95%.
        assert!(matches!(
            PolicyRouter.route(&VariantSelector::MinAccuracy(95.0), &vs),
            Err(RouteError::Unsatisfiable(_))
        ));
    }

    #[test]
    fn max_latency_prefers_accuracy_within_budget() {
        let mut vs = vec![status("w2", 2, 87.48, 245.0), status("w8", 8, 89.62, 47.0)];
        vs[0].ewma_latency_us = 1_000.0;
        vs[1].ewma_latency_us = 3_000.0;
        // Both fit in 10ms: the more accurate w8 wins.
        assert_eq!(
            PolicyRouter.route(&VariantSelector::MaxLatency(Duration::from_millis(10)), &vs),
            Ok(1)
        );
        // w8 degrades past the budget: traffic shifts to w2.
        vs[1].ewma_latency_us = 50_000.0;
        assert_eq!(
            PolicyRouter.route(&VariantSelector::MaxLatency(Duration::from_millis(10)), &vs),
            Ok(0)
        );
        // Nothing fits 0.1ms.
        vs[0].ewma_latency_us = 1_000.0;
        assert!(matches!(
            PolicyRouter.route(
                &VariantSelector::MaxLatency(Duration::from_micros(100)),
                &vs
            ),
            Err(RouteError::Unsatisfiable(_))
        ));
    }

    #[test]
    fn unavailable_variants_are_skipped_by_policies() {
        let mut vs = vec![status("w2", 2, 87.48, 245.0), status("w4", 4, 89.10, 165.0)];
        vs[0].health = BackendHealth::Unavailable;
        assert_eq!(
            PolicyRouter.route(&VariantSelector::MinAccuracy(87.0), &vs),
            Ok(1)
        );
        // Exact still reaches the unavailable variant (errors must surface,
        // not be masked by a silent precision change).
        assert_eq!(PolicyRouter.route(&VariantSelector::Exact(2), &vs), Ok(0));
    }

    #[test]
    fn queue_pressure_inflates_latency_estimate() {
        let mut v = status("w2", 2, 87.48, 245.0);
        v.ewma_latency_us = 1_000.0;
        let idle = v.latency_estimate_us();
        v.inflight = 16;
        assert!(v.latency_estimate_us() > 2.0 * idle);
    }

    /// Property: `Exact(wq)` NEVER falls back — it returns the index of a
    /// variant with exactly that wq, or an error; health, latency, and
    /// accuracy must not influence it.
    #[test]
    fn exact_never_falls_back() {
        forall(2000, |rng| {
            let n = rng.range(1, 6);
            let variants: Vec<VariantStatus> = (0..n)
                .map(|i| {
                    let mut v = status(
                        &format!("v{i}"),
                        *rng.choose(&[1u32, 2, 4, 8]),
                        rng.uniform(50.0, 99.0),
                        rng.uniform(1.0, 300.0),
                    );
                    v.ewma_latency_us = rng.uniform(0.0, 1e5);
                    v.inflight = rng.below(32);
                    v.health = *rng.choose(&[
                        BackendHealth::Healthy,
                        BackendHealth::Degraded,
                        BackendHealth::Unavailable,
                    ]);
                    v.default = rng.chance(0.3);
                    v
                })
                .collect();
            let want_wq = *rng.choose(&[1u32, 2, 4, 8, 16]);
            match PolicyRouter.route(&VariantSelector::Exact(want_wq), &variants) {
                Ok(i) => check_eq(variants[i].wq, Some(want_wq), "Exact must match wq")?,
                Err(RouteError::NoSuchVariant(_)) => {
                    if variants.iter().any(|v| v.wq == Some(want_wq)) {
                        return Err(format!(
                            "router reported NoSuchVariant but wq={want_wq} exists"
                        ));
                    }
                }
                Err(e) => return Err(format!("unexpected error kind: {e}")),
            }
            Ok(())
        });
    }
}
