//! Retry, hedging, and circuit-breaking policy for `Server::infer`.
//!
//! [`RetryPolicy`] bounds how hard the gateway works to answer one request:
//! at most `max_attempts` submissions with exponential backoff between
//! them, plus an optional *hedge* — a duplicate submission raced against a
//! slow first attempt. Retries and hedges re-route **policy-routed**
//! selectors (`Default`, `MinAccuracy`, `MaxLatency`) to the next-best
//! healthy variant; `Exact`/`Named` selectors never fall back (the PR-2
//! invariant) and therefore fail fast after exhausting attempts on their
//! one variant.
//!
//! [`CircuitBreaker`] is the per-variant failure gate layered over
//! [`BackendHealth`]: consecutive chunk failures open it, an open breaker
//! reports the variant `Unavailable` to policy routing, and after
//! `open_for` it half-opens — one probe request is let through (the
//! variant shows as `Degraded`), closing on success or re-opening on
//! failure.
//!
//! [`BackendHealth`]: crate::serving::BackendHealth

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::time::{Duration, Instant};

/// When to launch a hedge (duplicate) request against a slow attempt.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum HedgeTrigger {
    /// Hedge once the attempt has been pending longer than the routed
    /// variant's observed p99 latency (falls back to its EWMA, then to a
    /// fixed floor, while the histogram is empty).
    P99,
    /// Hedge after a fixed delay.
    Fixed(Duration),
}

/// Bounded retry policy for one logical inference request.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total submissions allowed (1 = no retry, the default).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles per attempt.
    pub backoff: Duration,
    /// Optional hedging trigger; `None` disables hedging.
    pub hedge_after: Option<HedgeTrigger>,
}

impl Default for RetryPolicy {
    /// Single attempt, no backoff, no hedge — exactly the pre-retry
    /// `Server::infer` behavior.
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            backoff: Duration::ZERO,
            hedge_after: None,
        }
    }
}

impl RetryPolicy {
    /// `n` total attempts with a 1 ms initial backoff.
    pub fn attempts(n: u32) -> RetryPolicy {
        RetryPolicy {
            max_attempts: n.max(1),
            backoff: Duration::from_millis(1),
            hedge_after: None,
        }
    }

    pub fn with_backoff(mut self, backoff: Duration) -> RetryPolicy {
        self.backoff = backoff;
        self
    }

    pub fn with_hedge(mut self, trigger: HedgeTrigger) -> RetryPolicy {
        self.hedge_after = Some(trigger);
        self
    }

    /// Backoff before retry number `retry` (1-based): exponential from
    /// `self.backoff`, saturating.
    pub fn backoff_before(&self, retry: u32) -> Duration {
        let doublings = retry.saturating_sub(1).min(16);
        self.backoff.saturating_mul(1u32 << doublings)
    }
}

/// Circuit-breaker thresholds. `Default`: open after 5 consecutive
/// failures, probe after 250 ms.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Consecutive request failures that open the breaker.
    pub failure_threshold: u32,
    /// How long the breaker stays open before half-opening for a probe.
    pub open_for: Duration,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig {
            failure_threshold: 5,
            open_for: Duration::from_millis(250),
        }
    }
}

/// Breaker state, in routing-impact order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Normal service.
    Closed,
    /// Tripped: the variant reports `Unavailable` to policy routing until
    /// `open_for` elapses.
    Open,
    /// Probation: one probe is welcome (variant reports `Degraded`);
    /// success closes, failure re-opens.
    HalfOpen,
}

const STATE_CLOSED: u8 = 0;
const STATE_OPEN: u8 = 1;
const STATE_HALF_OPEN: u8 = 2;

/// Lock-free per-variant circuit breaker. Workers record per-chunk
/// outcomes; `Server::statuses` folds [`CircuitBreaker::state`] into the
/// health the router sees. Time is measured against a private epoch so the
/// open deadline fits an atomic.
#[derive(Debug)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: AtomicU8,
    consecutive_failures: AtomicU32,
    open_until_us: AtomicU64,
    epoch: Instant,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            cfg,
            state: AtomicU8::new(STATE_CLOSED),
            consecutive_failures: AtomicU32::new(0),
            open_until_us: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// A request chunk succeeded: close the breaker and clear the failure
    /// streak (also how a half-open probe closes it).
    pub fn record_success(&self) {
        self.consecutive_failures.store(0, Ordering::SeqCst);
        self.state.store(STATE_CLOSED, Ordering::SeqCst);
    }

    /// A request chunk failed. Opens the breaker once the streak reaches
    /// the threshold; a failure during half-open re-opens immediately.
    pub fn record_failure(&self) {
        let streak = self.consecutive_failures.fetch_add(1, Ordering::SeqCst) + 1;
        let half_open = self.state.load(Ordering::SeqCst) == STATE_HALF_OPEN;
        if half_open || streak >= self.cfg.failure_threshold {
            self.open_until_us.store(
                self.now_us() + self.cfg.open_for.as_micros() as u64,
                Ordering::SeqCst,
            );
            self.state.store(STATE_OPEN, Ordering::SeqCst);
        }
    }

    /// Current state; lazily transitions Open → HalfOpen once `open_for`
    /// has elapsed (the caller reading the state *is* the probe admission).
    pub fn state(&self) -> BreakerState {
        match self.state.load(Ordering::SeqCst) {
            STATE_OPEN => {
                if self.now_us() >= self.open_until_us.load(Ordering::SeqCst) {
                    // Racing readers may both CAS; either way the state is
                    // HalfOpen afterwards, which is what both report.
                    let _ = self.state.compare_exchange(
                        STATE_OPEN,
                        STATE_HALF_OPEN,
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    );
                    BreakerState::HalfOpen
                } else {
                    BreakerState::Open
                }
            }
            STATE_HALF_OPEN => BreakerState::HalfOpen,
            _ => BreakerState::Closed,
        }
    }

    pub fn consecutive_failures(&self) -> u32 {
        self.consecutive_failures.load(Ordering::SeqCst)
    }
}

/// Server-level robustness counters (atomic: bumped from `infer` calls on
/// any thread), reported by `mpcnn serve` next to the throughput table.
#[derive(Debug, Default)]
pub struct RobustCounters {
    retried: AtomicU64,
    hedged: AtomicU64,
    hedge_wins: AtomicU64,
    fallbacks: AtomicU64,
}

/// Point-in-time copy of [`RobustCounters`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RobustSnapshot {
    /// Re-submissions after a failed attempt.
    pub retried: u64,
    /// Hedge (duplicate) submissions launched.
    pub hedged: u64,
    /// Hedges that answered before the original attempt.
    pub hedge_wins: u64,
    /// Retries/hedges that landed on a *different* variant than the
    /// original attempt (policy-routed degradation).
    pub fallbacks: u64,
}

impl RobustCounters {
    pub fn note_retry(&self) {
        self.retried.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_hedge(&self) {
        self.hedged.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_hedge_win(&self) {
        self.hedge_wins.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_fallback(&self) {
        self.fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> RobustSnapshot {
        RobustSnapshot {
            retried: self.retried.load(Ordering::Relaxed),
            hedged: self.hedged.load(Ordering::Relaxed),
            hedge_wins: self.hedge_wins.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_is_single_attempt() {
        let p = RetryPolicy::default();
        assert_eq!(p.max_attempts, 1);
        assert_eq!(p.backoff, Duration::ZERO);
        assert!(p.hedge_after.is_none());
    }

    #[test]
    fn backoff_grows_exponentially() {
        let p = RetryPolicy::attempts(4).with_backoff(Duration::from_millis(2));
        assert_eq!(p.backoff_before(1), Duration::from_millis(2));
        assert_eq!(p.backoff_before(2), Duration::from_millis(4));
        assert_eq!(p.backoff_before(3), Duration::from_millis(8));
        assert_eq!(RetryPolicy::attempts(0).max_attempts, 1);
    }

    #[test]
    fn breaker_opens_after_threshold_and_half_opens() {
        let b = CircuitBreaker::new(BreakerConfig {
            failure_threshold: 3,
            open_for: Duration::from_millis(20),
        });
        assert_eq!(b.state(), BreakerState::Closed);
        b.record_failure();
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Closed, "below threshold");
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(b.state(), BreakerState::HalfOpen, "open_for elapsed");
    }

    #[test]
    fn half_open_probe_closes_or_reopens() {
        let cfg = BreakerConfig {
            failure_threshold: 2,
            open_for: Duration::from_millis(10),
        };
        let b = CircuitBreaker::new(cfg);
        b.record_failure();
        b.record_failure();
        std::thread::sleep(Duration::from_millis(15));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open, "failed probe re-opens");
        std::thread::sleep(Duration::from_millis(15));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed, "successful probe closes");
        assert_eq!(b.consecutive_failures(), 0);
    }

    #[test]
    fn success_interrupts_the_streak() {
        let b = CircuitBreaker::new(BreakerConfig {
            failure_threshold: 3,
            open_for: Duration::from_secs(1),
        });
        for _ in 0..5 {
            b.record_failure();
            b.record_failure();
            b.record_success();
        }
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn counters_snapshot() {
        let c = RobustCounters::default();
        c.note_retry();
        c.note_retry();
        c.note_hedge();
        c.note_hedge_win();
        c.note_fallback();
        assert_eq!(
            c.snapshot(),
            RobustSnapshot { retried: 2, hedged: 1, hedge_wins: 1, fallbacks: 1 }
        );
    }
}
