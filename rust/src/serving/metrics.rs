//! Serving metrics: latency histogram, throughput counters, batch-size
//! distribution, the virtual-FPGA clock that reports what the same stream
//! would cost on the simulated accelerator design, and the EWMA latency the
//! router reads to shift traffic between variants.

use crate::util::stats::LatencyHistogram;

/// EWMA smoothing factor for the router-facing latency estimate: heavy
/// enough that one slow batch moves the estimate, light enough that a
/// single outlier doesn't own it.
pub const EWMA_ALPHA: f64 = 0.2;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub latency: LatencyHistogram,
    /// Time requests spent queued before batch assembly.
    pub queue_wait: LatencyHistogram,
    /// Distribution of executed batch sizes (one sample per chunk, before
    /// padding) — same log-bucketed histogram type, value is a count not
    /// microseconds.
    pub batch_sizes: LatencyHistogram,
    pub requests: u64,
    pub responses: u64,
    pub errors: u64,
    pub batches: u64,
    /// Sum of batch sizes (for mean batch size).
    pub batched_items: u64,
    /// Items that were padding (submitted batch < compiled batch).
    pub padded_items: u64,
    /// Exponentially-weighted moving average of end-to-end latency in
    /// microseconds (0 until the first response); what policy routing sees.
    /// Decays while the variant sits idle so a degraded-then-starved
    /// variant eventually re-qualifies and gets probed.
    pub ewma_latency_us: f64,
    /// Simulated FPGA busy time for the same stream, in microseconds.
    pub fpga_virtual_us: f64,
    /// Wall-clock span of the measurement window, in microseconds.
    pub wall_us: f64,
    /// Requests shed at dequeue because their deadline had already expired
    /// before batch assembly (the client gets an error, not silence).
    pub shed_expired: u64,
    /// Requests shed at admission because the queue's EWMA wait already
    /// exceeded the request deadline.
    pub shed_admission: u64,
    /// Panics caught inside `infer_batch` and converted to backend errors.
    pub panics: u64,
    /// Supervisor-driven backend rebuilds after a crash or wedged worker.
    pub worker_restarts: u64,
}

impl Metrics {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }

    /// Achieved throughput in requests/s over the wall-clock window.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_us <= 0.0 {
            0.0
        } else {
            self.responses as f64 / (self.wall_us * 1e-6)
        }
    }

    /// Frames/s the simulated FPGA design would have achieved on this
    /// stream (virtual clock).
    pub fn fpga_fps(&self) -> f64 {
        if self.fpga_virtual_us <= 0.0 {
            0.0
        } else {
            self.responses as f64 / (self.fpga_virtual_us * 1e-6)
        }
    }

    /// Fold one observed end-to-end latency into the EWMA estimate.
    pub fn observe_latency_us(&mut self, us: f64) {
        self.latency.record_us(us);
        self.ewma_latency_us = if self.ewma_latency_us <= 0.0 {
            us
        } else {
            EWMA_ALPHA * us + (1.0 - EWMA_ALPHA) * self.ewma_latency_us
        };
    }

    /// Total requests shed without reaching a backend (admission + dequeue).
    pub fn shed(&self) -> u64 {
        self.shed_expired + self.shed_admission
    }

    /// The stable, machine-consumable projection of this variant's
    /// counters. Every consumer that used to pick fields out of `Metrics`
    /// ad hoc (the CLI serve report, the edge `/metrics` exposition, the
    /// one-line [`summary`](Metrics::summary)) reads this one struct, so
    /// the set of exported signals can only be widened deliberately.
    pub fn summarize(&self) -> MetricsSummary {
        MetricsSummary {
            requests: self.requests,
            responses: self.responses,
            errors: self.errors,
            shed_admission: self.shed_admission,
            shed_expired: self.shed_expired,
            shed: self.shed(),
            panics: self.panics,
            worker_restarts: self.worker_restarts,
            batches: self.batches,
            mean_batch: self.mean_batch(),
            p50_us: self.latency.percentile_us(50.0),
            p99_us: self.latency.percentile_us(99.0),
            max_us: self.latency.max_us(),
            ewma_us: self.ewma_latency_us,
            throughput_rps: self.throughput_rps(),
            fpga_fps: self.fpga_fps(),
        }
    }

    pub fn summary(&self) -> String {
        let s = self.summarize();
        format!(
            "requests={} responses={} errors={} shed={} panics={} restarts={} \
             batches={} mean_batch={:.2} \
             p50={:.0}us p99={:.0}us max={:.0}us ewma={:.0}us throughput={:.1} rps \
             fpga_sim={:.1} fps",
            s.requests,
            s.responses,
            s.errors,
            s.shed,
            s.panics,
            s.worker_restarts,
            s.batches,
            s.mean_batch,
            s.p50_us,
            s.p99_us,
            s.max_us,
            s.ewma_us,
            s.throughput_rps,
            s.fpga_fps,
        )
    }
}

/// One exported per-variant series: Prometheus family name, help text, and
/// the projection out of [`MetricsSummary`]. Families ending in `_total`
/// render as counters, everything else as gauges.
pub type SummaryField = (&'static str, &'static str, fn(&MetricsSummary) -> f64);

/// The single source of truth for which [`MetricsSummary`] counters are
/// exported. The edge `/metrics` exposition renders exactly this table and
/// the exposition tests assert against it, so a new counter added here
/// ships on every surface at once — it cannot silently appear in only one.
pub const SUMMARY_FIELDS: &[SummaryField] = &[
    (
        "mpcnn_variant_requests_total",
        "requests submitted to the variant",
        |s| s.requests as f64,
    ),
    (
        "mpcnn_variant_responses_total",
        "successful responses",
        |s| s.responses as f64,
    ),
    (
        "mpcnn_variant_errors_total",
        "backend errors surfaced to clients",
        |s| s.errors as f64,
    ),
    (
        "mpcnn_variant_shed_admission_total",
        "requests shed at admission (queue-wait EWMA past deadline)",
        |s| s.shed_admission as f64,
    ),
    (
        "mpcnn_variant_shed_expired_total",
        "requests shed at dequeue (deadline already expired)",
        |s| s.shed_expired as f64,
    ),
    (
        "mpcnn_variant_panics_total",
        "backend panics caught and converted to errors",
        |s| s.panics as f64,
    ),
    (
        "mpcnn_variant_worker_restarts_total",
        "supervisor-driven backend rebuilds",
        |s| s.worker_restarts as f64,
    ),
    (
        "mpcnn_variant_batches_total",
        "batches executed by the worker",
        |s| s.batches as f64,
    ),
    (
        "mpcnn_variant_throughput_rps",
        "achieved responses/s over the server's lifetime",
        |s| s.throughput_rps,
    ),
];

/// Point-in-time snapshot of one variant's [`Metrics`], flattened to plain
/// numbers (histograms already reduced to their percentiles). This is the
/// single export surface shared by the CLI report and the edge
/// `/metrics` endpoint.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricsSummary {
    pub requests: u64,
    pub responses: u64,
    pub errors: u64,
    /// Shed at admission: the queue-wait EWMA already exceeded the deadline.
    pub shed_admission: u64,
    /// Shed at dequeue: the deadline had expired before batch assembly.
    pub shed_expired: u64,
    /// `shed_admission + shed_expired`.
    pub shed: u64,
    pub panics: u64,
    pub worker_restarts: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub max_us: f64,
    pub ewma_us: f64,
    pub throughput_rps: f64,
    pub fpga_fps: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_batch_and_throughput() {
        let mut m = Metrics::default();
        m.batches = 4;
        m.batched_items = 14;
        m.responses = 14;
        m.wall_us = 2_000_000.0;
        assert!((m.mean_batch() - 3.5).abs() < 1e-12);
        assert!((m.throughput_rps() - 7.0).abs() < 1e-12);
    }

    #[test]
    fn zero_safe() {
        let m = Metrics::default();
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.throughput_rps(), 0.0);
        assert_eq!(m.fpga_fps(), 0.0);
        assert!(m.summary().contains("requests=0"));
    }

    #[test]
    fn shed_totals_and_summary_counters() {
        let m = Metrics {
            shed_expired: 3,
            shed_admission: 2,
            panics: 1,
            worker_restarts: 4,
            ..Metrics::default()
        };
        assert_eq!(m.shed(), 5);
        let s = m.summary();
        assert!(s.contains("shed=5"), "{s}");
        assert!(s.contains("panics=1"), "{s}");
        assert!(s.contains("restarts=4"), "{s}");
    }

    #[test]
    fn summarize_is_the_single_export_surface() {
        let mut m = Metrics {
            requests: 10,
            responses: 7,
            errors: 1,
            shed_expired: 1,
            shed_admission: 1,
            panics: 2,
            worker_restarts: 1,
            batches: 7,
            batched_items: 7,
            ..Metrics::default()
        };
        m.observe_latency_us(500.0);
        let s = m.summarize();
        assert_eq!(s.shed, 2);
        assert_eq!(s.shed_admission, 1);
        assert_eq!(s.shed_expired, 1);
        assert_eq!(s.panics, 2);
        assert_eq!(s.worker_restarts, 1);
        assert!((s.ewma_us - 500.0).abs() < 1e-9);
        // Log2-bucketed histogram: one 500 us sample reports its bucket's
        // upper bound (512 us).
        assert!(s.p50_us >= 256.0 && s.p50_us <= 1024.0, "{}", s.p50_us);
        // The one-line summary is a rendering of the same struct.
        assert!(m.summary().contains("shed=2"));
    }

    #[test]
    fn summary_field_table_is_coherent() {
        // Unique family names, valid Prometheus identifiers, and live
        // projections — the exposition and its tests both trust this table.
        let mut names: Vec<&str> = SUMMARY_FIELDS.iter().map(|(n, _, _)| *n).collect();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate family name in SUMMARY_FIELDS");
        for (name, help, project) in SUMMARY_FIELDS {
            assert!(name.starts_with("mpcnn_variant_"), "{name}");
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_'),
                "{name}"
            );
            assert!(!help.is_empty());
            assert!(project(&MetricsSummary::default()) == 0.0, "{name} must zero-init");
        }
        // The counters the drive-by is about are all present.
        for required in [
            "mpcnn_variant_requests_total",
            "mpcnn_variant_responses_total",
            "mpcnn_variant_errors_total",
            "mpcnn_variant_panics_total",
            "mpcnn_variant_batches_total",
        ] {
            assert!(names.contains(&required), "{required} missing");
        }
    }

    #[test]
    fn ewma_tracks_latency_shifts() {
        let mut m = Metrics::default();
        m.observe_latency_us(100.0);
        assert!((m.ewma_latency_us - 100.0).abs() < 1e-9, "first sample seeds");
        for _ in 0..50 {
            m.observe_latency_us(1000.0);
        }
        assert!(
            m.ewma_latency_us > 900.0,
            "ewma must converge to the new level: {}",
            m.ewma_latency_us
        );
        assert_eq!(m.latency.count(), 51);
    }
}
