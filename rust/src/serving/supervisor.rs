//! Worker supervision policy: when a variant's backend crashes (panic
//! inside `infer_batch`) or wedges (warm-up/rebuild failure), the batcher's
//! supervised loop asks this state machine what to do next.
//!
//! The worker *thread* never dies — panics are caught at the `infer_batch`
//! boundary — so "restart" means rebuilding the backend from the variant's
//! registered factory, inside the same thread. The supervisor spaces those
//! rebuilds with exponential backoff and a restart budget: within budget,
//! crashes restart eagerly (short backoff); past it, the variant parks at
//! the maximum backoff and keeps probing slowly — deliberately never giving
//! up for good, so removing the fault lets the variant return to service
//! without a server restart. A healthy batch resets both budget and
//! backoff.

use std::time::Duration;

/// Restart pacing for one variant worker. `Default` restarts eagerly three
/// times (50 ms, 100 ms, 200 ms), then probes every two seconds.
#[derive(Clone, Copy, Debug)]
pub struct SupervisorConfig {
    /// Crashes allowed at exponential pacing before parking at
    /// `backoff_max`.
    pub restart_budget: u32,
    /// Backoff before the first in-budget rebuild; doubles per consecutive
    /// crash.
    pub backoff_initial: Duration,
    /// Backoff ceiling, and the probe interval once the budget is spent.
    pub backoff_max: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            restart_budget: 3,
            backoff_initial: Duration::from_millis(50),
            backoff_max: Duration::from_secs(2),
        }
    }
}

/// Per-worker supervisor state: consecutive-crash count and the backoff it
/// implies. Owned by the batcher thread; no locking.
#[derive(Clone, Copy, Debug)]
pub struct Supervisor {
    cfg: SupervisorConfig,
    consecutive_crashes: u32,
    restarts: u64,
}

impl Supervisor {
    pub fn new(cfg: SupervisorConfig) -> Supervisor {
        Supervisor { cfg, consecutive_crashes: 0, restarts: 0 }
    }

    /// A batch completed without crashing: the variant is live again, so
    /// future crashes start from a fresh budget and the initial backoff.
    pub fn on_success(&mut self) {
        self.consecutive_crashes = 0;
    }

    /// A crash (caught panic) or failed rebuild: returns how long to wait
    /// before the next rebuild attempt. Exponential while within budget,
    /// parked at `backoff_max` after.
    pub fn on_crash(&mut self) -> Duration {
        self.consecutive_crashes = self.consecutive_crashes.saturating_add(1);
        self.restarts += 1;
        if self.consecutive_crashes > self.cfg.restart_budget {
            return self.cfg.backoff_max;
        }
        let doublings = self.consecutive_crashes.saturating_sub(1).min(20);
        let backoff = self
            .cfg
            .backoff_initial
            .saturating_mul(1u32 << doublings);
        backoff.min(self.cfg.backoff_max)
    }

    /// Crashes since the last successful batch.
    pub fn consecutive_crashes(&self) -> u32 {
        self.consecutive_crashes
    }

    /// Whether the eager restart budget is spent (the worker is in slow
    /// probe mode until a batch succeeds).
    pub fn parked(&self) -> bool {
        self.consecutive_crashes > self.cfg.restart_budget
    }

    /// Total rebuild attempts over the worker's lifetime.
    pub fn restarts(&self) -> u64 {
        self.restarts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_then_parks() {
        let cfg = SupervisorConfig {
            restart_budget: 3,
            backoff_initial: Duration::from_millis(50),
            backoff_max: Duration::from_secs(2),
        };
        let mut s = Supervisor::new(cfg);
        assert_eq!(s.on_crash(), Duration::from_millis(50));
        assert_eq!(s.on_crash(), Duration::from_millis(100));
        assert_eq!(s.on_crash(), Duration::from_millis(200));
        assert!(!s.parked());
        // Budget spent: every further crash parks at the ceiling.
        assert_eq!(s.on_crash(), Duration::from_secs(2));
        assert!(s.parked());
        assert_eq!(s.on_crash(), Duration::from_secs(2));
        assert_eq!(s.restarts(), 5);
    }

    #[test]
    fn success_resets_budget_and_backoff() {
        let mut s = Supervisor::new(SupervisorConfig::default());
        for _ in 0..10 {
            s.on_crash();
        }
        assert!(s.parked());
        s.on_success();
        assert!(!s.parked());
        assert_eq!(s.consecutive_crashes(), 0);
        assert_eq!(
            s.on_crash(),
            SupervisorConfig::default().backoff_initial,
            "backoff restarts from the initial value"
        );
    }

    #[test]
    fn backoff_never_exceeds_max_within_budget() {
        let cfg = SupervisorConfig {
            restart_budget: 30,
            backoff_initial: Duration::from_millis(500),
            backoff_max: Duration::from_secs(1),
        };
        let mut s = Supervisor::new(cfg);
        let mut prev = Duration::ZERO;
        for _ in 0..32 {
            let b = s.on_crash();
            assert!(b <= cfg.backoff_max);
            assert!(b >= prev, "backoff is monotone");
            prev = b;
        }
    }
}
