//! Inference backends for the serving gateway: the production PJRT engine
//! and a deterministic mock for tests/benches.

use crate::anyhow;
use crate::runtime::Engine;
use crate::util::error::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Coarse backend health, refreshed by the variant worker after every batch
/// and exported to the router through
/// [`crate::serving::router::VariantStatus`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendHealth {
    /// Serving normally.
    Healthy,
    /// Serving, but impaired (e.g. recent errors); policy routing
    /// deprioritizes but does not exclude it.
    Degraded,
    /// Not serving; policy routing excludes it ([`Exact`] routing does not
    /// fall back and will still reach it).
    ///
    /// [`Exact`]: crate::serving::VariantSelector::Exact
    Unavailable,
}

impl BackendHealth {
    pub(crate) fn as_u8(self) -> u8 {
        match self {
            BackendHealth::Healthy => 0,
            BackendHealth::Degraded => 1,
            BackendHealth::Unavailable => 2,
        }
    }

    pub(crate) fn from_u8(v: u8) -> BackendHealth {
        match v {
            0 => BackendHealth::Healthy,
            1 => BackendHealth::Degraded,
            _ => BackendHealth::Unavailable,
        }
    }
}

/// Anything that can run a batch of images to logits.
///
/// Not `Send`: the PJRT client types are thread-affine, so the gateway
/// constructs the backend *inside* the variant's worker thread via a factory
/// closure (see [`crate::serving::ServerBuilder::variant`]).
pub trait InferenceBackend {
    /// Batch sizes the backend has compiled executables for (sorted not
    /// required).
    fn batch_sizes(&self) -> Vec<usize>;
    /// Flattened image length (h*w*c).
    fn image_len(&self) -> usize;
    fn classes(&self) -> usize;
    /// Run `batch` images (flattened, padded by the caller) and return
    /// `batch * classes` logits.
    fn infer_batch(&self, images: &[f32], batch: usize) -> Result<Vec<f32>>;

    /// Can the backend execute a batch of exactly `n` images? The gateway
    /// uses this to decide between padding up to a supported size and
    /// splitting an oversized batch — introspection instead of guessing.
    fn supports_batch(&self, n: usize) -> bool {
        self.batch_sizes().contains(&n)
    }

    /// One-time warm-up before the variant is announced ready (e.g. first
    /// PJRT execution to trigger lazy initialization). Failure aborts the
    /// variant's startup.
    fn warmup(&self) -> Result<()> {
        Ok(())
    }

    /// Current health, polled by the worker between batches.
    fn health(&self) -> BackendHealth {
        BackendHealth::Healthy
    }
}

/// PJRT-backed production backend for one word-length variant.
pub struct EngineBackend {
    engine: Engine,
    wq: u32,
    batch_sizes: Vec<usize>,
    image_len: usize,
    classes: usize,
}

impl EngineBackend {
    /// Wrap an engine, serving the `wq` variant.
    pub fn new(engine: Engine, wq: u32) -> Result<EngineBackend> {
        let entries = engine.manifest.entries_for_wq(wq);
        if entries.is_empty() {
            return Err(anyhow!("no exported models for wq={wq}"));
        }
        let image_len = entries[0].input_len() / entries[0].batch;
        let classes = entries[0].classes;
        let batch_sizes = entries.iter().map(|e| e.batch).collect();
        Ok(EngineBackend {
            engine,
            wq,
            batch_sizes,
            image_len,
            classes,
        })
    }

    /// Load a per-wq engine from `dir` and wrap it — the one-call constructor
    /// variant workers use (only this word-length's models are compiled).
    pub fn load(dir: impl AsRef<std::path::Path>, wq: u32) -> Result<EngineBackend> {
        EngineBackend::new(Engine::load_wq(dir, wq)?, wq)
    }
}

impl InferenceBackend for EngineBackend {
    fn batch_sizes(&self) -> Vec<usize> {
        self.batch_sizes.clone()
    }

    fn image_len(&self) -> usize {
        self.image_len
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn infer_batch(&self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        let model = self
            .engine
            .model_for(self.wq, batch)
            .ok_or_else(|| anyhow!("no compiled model for wq={} batch={batch}", self.wq))?;
        model.infer(images)
    }

    fn warmup(&self) -> Result<()> {
        // First PJRT execution initializes thread-local runtime state; do it
        // on a zero batch so the first real request doesn't pay for it.
        let batch = *self.batch_sizes.iter().min().unwrap_or(&1);
        let zeros = vec![0.0f32; batch * self.image_len];
        self.infer_batch(&zeros, batch).map(|_| ())
    }
}

/// Deterministic mock backend: logits are a fixed function of the input so
/// tests can assert classification results; optional artificial latency and
/// failure injection.
pub struct MockBackend {
    image_len: usize,
    classes: usize,
    batch_sizes: Vec<usize>,
    /// Artificial per-call latency in microseconds; shared so tests can
    /// degrade a live backend and watch the router shift traffic.
    latency_us: Arc<AtomicU64>,
    /// Fail every call after the Nth (failure injection).
    pub fail_after: Option<u64>,
    calls: AtomicU64,
}

impl MockBackend {
    pub fn new(image_len: usize, classes: usize, batch_sizes: Vec<usize>, latency_us: u64) -> Self {
        MockBackend {
            image_len,
            classes,
            batch_sizes,
            latency_us: Arc::new(AtomicU64::new(latency_us)),
            fail_after: None,
            calls: AtomicU64::new(0),
        }
    }

    /// Replace the latency source with a shared handle; callers keep a clone
    /// and can change the backend's latency while it serves.
    pub fn with_latency_source(mut self, source: Arc<AtomicU64>) -> Self {
        self.latency_us = source;
        self
    }

    /// Handle to the live latency knob.
    pub fn latency_handle(&self) -> Arc<AtomicU64> {
        self.latency_us.clone()
    }

    /// The mock's ground-truth rule: class = floor(mean(image)) mod classes.
    pub fn expected_class(&self, image: &[f32]) -> usize {
        let mean = image.iter().sum::<f32>() / image.len() as f32;
        (mean.max(0.0) as usize) % self.classes
    }
}

impl InferenceBackend for MockBackend {
    fn batch_sizes(&self) -> Vec<usize> {
        self.batch_sizes.clone()
    }

    fn image_len(&self) -> usize {
        self.image_len
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn infer_batch(&self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        let n = self.calls.fetch_add(1, Ordering::Relaxed);
        if let Some(limit) = self.fail_after {
            if n >= limit {
                return Err(anyhow!("injected failure on call {n}"));
            }
        }
        let latency = self.latency_us.load(Ordering::Relaxed);
        if latency > 0 {
            std::thread::sleep(std::time::Duration::from_micros(latency));
        }
        if images.len() != batch * self.image_len {
            return Err(anyhow!(
                "mock: bad input length {} for batch {batch}",
                images.len()
            ));
        }
        let mut logits = vec![0.0f32; batch * self.classes];
        for b in 0..batch {
            let img = &images[b * self.image_len..(b + 1) * self.image_len];
            let class = self.expected_class(img);
            logits[b * self.classes + class] = 1.0;
        }
        Ok(logits)
    }

    fn health(&self) -> BackendHealth {
        match self.fail_after {
            Some(limit) if self.calls.load(Ordering::Relaxed) >= limit => {
                BackendHealth::Unavailable
            }
            _ => BackendHealth::Healthy,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let m = MockBackend::new(4, 3, vec![1], 0);
        let img = vec![2.0, 2.0, 2.0, 2.0]; // mean 2 -> class 2
        let logits = m.infer_batch(&img, 1).unwrap();
        assert_eq!(logits, vec![0.0, 0.0, 1.0]);
        assert_eq!(m.expected_class(&img), 2);
    }

    #[test]
    fn mock_batch_layout() {
        let m = MockBackend::new(2, 2, vec![2], 0);
        let imgs = vec![0.0, 0.0, 1.0, 1.0]; // classes 0 and 1
        let logits = m.infer_batch(&imgs, 2).unwrap();
        assert_eq!(logits, vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn mock_failure_injection() {
        let mut m = MockBackend::new(2, 2, vec![1], 0);
        m.fail_after = Some(1);
        assert_eq!(m.health(), BackendHealth::Healthy);
        assert!(m.infer_batch(&[0.0, 0.0], 1).is_ok());
        assert!(m.infer_batch(&[0.0, 0.0], 1).is_err());
        assert_eq!(m.health(), BackendHealth::Unavailable);
    }

    #[test]
    fn mock_validates_length() {
        let m = MockBackend::new(3, 2, vec![1], 0);
        assert!(m.infer_batch(&[0.0; 2], 1).is_err());
    }

    #[test]
    fn default_capabilities() {
        let m = MockBackend::new(2, 2, vec![1, 4, 8], 0);
        assert!(m.supports_batch(4));
        assert!(!m.supports_batch(3));
        assert!(m.warmup().is_ok());
        assert_eq!(m.health(), BackendHealth::Healthy);
    }

    #[test]
    fn shared_latency_source_is_live() {
        let knob = Arc::new(AtomicU64::new(0));
        let m = MockBackend::new(2, 2, vec![1], 0).with_latency_source(knob.clone());
        knob.store(1, Ordering::Relaxed);
        assert_eq!(m.latency_handle().load(Ordering::Relaxed), 1);
    }

    #[test]
    fn health_round_trips_through_u8() {
        for h in [
            BackendHealth::Healthy,
            BackendHealth::Degraded,
            BackendHealth::Unavailable,
        ] {
            assert_eq!(BackendHealth::from_u8(h.as_u8()), h);
        }
    }
}
