//! L3 serving gateway: one [`Server`] process hosts a *family* of
//! mixed-precision model variants (the paper's accuracy–throughput
//! trade-off curve, deployed) and routes typed [`InferRequest`]s across
//! them.
//!
//! Each registered variant gets its own bounded admission queue, dynamic
//! batcher, and worker thread owning an [`InferenceBackend`] (the PJRT
//! engine in production, mocks in tests) — see [`worker`]. A pluggable
//! [`Router`] resolves each request's [`VariantSelector`] against static
//! profiles (paper Top-5, DSE-simulated fps) and live signals (EWMA
//! latency, queue depth, backend health):
//!
//! ```no_run
//! use mpcnn::serving::{BatcherConfig, InferenceBackend, InferRequest, MockBackend,
//!                      Server, VariantSelector, VariantSpec};
//! # fn main() -> mpcnn::util::error::Result<()> {
//! let server = Server::builder()
//!     .variant(VariantSpec::uniform(2), BatcherConfig::default(), || {
//!         Ok(Box::new(MockBackend::new(48, 10, vec![1, 8], 0)) as Box<dyn InferenceBackend>)
//!     })
//!     .variant(VariantSpec::uniform(8), BatcherConfig::default(), || {
//!         Ok(Box::new(MockBackend::new(48, 10, vec![1, 8], 0)) as Box<dyn InferenceBackend>)
//!     })
//!     .build()?;
//! let resp = server
//!     .infer(InferRequest::new(vec![0.5; 48]).with_variant(VariantSelector::MinAccuracy(87.0)))
//!     .map_err(|e| mpcnn::anyhow!("{e}"))?;
//! println!("class {} served by {}", resp.class, resp.variant);
//! # Ok(()) }
//! ```
//!
//! Backends: [`EngineBackend`] (compiled PJRT artifacts),
//! [`crate::xmp::XmpBackend`] (the native sliced-digit execution engine),
//! and [`MockBackend`] (deterministic test stub). The pre-gateway
//! single-variant `coordinator` shim is gone; its pass-through behaviour
//! lives on as the single-variant tests in
//! `rust/tests/integration_serving.rs`.

pub mod backend;
pub mod metrics;
pub mod router;
pub mod variant;
mod worker;

pub use backend::{BackendHealth, EngineBackend, InferenceBackend, MockBackend};
pub use metrics::Metrics;
pub use router::{PolicyRouter, RouteError, Router, VariantStatus};
pub use variant::{VariantProfile, VariantSpec};
pub use worker::{BatcherConfig, Client, PendingResponse, Response, SubmitError};

use crate::util::error::Result;
use crate::util::table::{fnum, Table};
use std::fmt;
use std::sync::Arc;
use std::time::{Duration, Instant};
use worker::{spawn_variant, VariantWorker};

/// How a request picks its model variant.
#[derive(Clone, Debug, PartialEq)]
pub enum VariantSelector {
    /// The server's default variant.
    Default,
    /// Exactly the uniform-`wq` variant; never falls back.
    Exact(u32),
    /// Exactly the named variant; never falls back.
    Named(String),
    /// Cheapest variant whose estimated Top-5 accuracy (percent) is at
    /// least this.
    MinAccuracy(f64),
    /// Most accurate variant whose current latency estimate fits.
    MaxLatency(Duration),
}

impl VariantSelector {
    /// Parse a CLI route spec: `default`, `exact:4`, `name:w4`,
    /// `min-accuracy:0.85` (fraction or percent), `max-latency:20ms`.
    pub fn parse(s: &str) -> Result<VariantSelector, String> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("default") {
            return Ok(VariantSelector::Default);
        }
        let (kind, val) = s
            .split_once(':')
            .ok_or_else(|| format!("bad route '{s}' (want KIND:VALUE or 'default')"))?;
        match kind {
            "exact" => val
                .parse::<u32>()
                .map(VariantSelector::Exact)
                .map_err(|_| format!("bad wq in '{s}'")),
            "name" | "named" => Ok(VariantSelector::Named(val.to_string())),
            "min-accuracy" => {
                let a: f64 = val.parse().map_err(|_| format!("bad accuracy in '{s}'"))?;
                // Accept both 0.85 (fraction) and 85 (percent).
                Ok(VariantSelector::MinAccuracy(if a <= 1.0 { a * 100.0 } else { a }))
            }
            "max-latency" => {
                let ms: f64 = val
                    .trim_end_matches("ms")
                    .parse()
                    .map_err(|_| format!("bad latency in '{s}' (want e.g. 20ms)"))?;
                // from_secs_f64 panics on negative/NaN; reject instead.
                if !ms.is_finite() || ms < 0.0 {
                    return Err(format!("bad latency in '{s}' (want non-negative ms)"));
                }
                Ok(VariantSelector::MaxLatency(Duration::from_secs_f64(
                    ms / 1e3,
                )))
            }
            _ => Err(format!(
                "unknown route kind '{kind}' \
                 (default | exact:WQ | name:NAME | min-accuracy:PCT | max-latency:MS)"
            )),
        }
    }
}

impl fmt::Display for VariantSelector {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VariantSelector::Default => write!(f, "default"),
            VariantSelector::Exact(wq) => write!(f, "exact:{wq}"),
            VariantSelector::Named(n) => write!(f, "name:{n}"),
            VariantSelector::MinAccuracy(a) => write!(f, "min-accuracy:{a:.2}"),
            VariantSelector::MaxLatency(d) => {
                write!(f, "max-latency:{:.1}ms", d.as_secs_f64() * 1e3)
            }
        }
    }
}

/// One typed inference request.
#[derive(Clone, Debug)]
pub struct InferRequest {
    /// Flattened image (must match the routed variant's `image_len`).
    pub image: Vec<f32>,
    pub variant: VariantSelector,
    /// Client-side wait budget for [`Server::infer`]; `None` waits
    /// indefinitely.
    pub deadline: Option<Duration>,
}

impl InferRequest {
    pub fn new(image: Vec<f32>) -> InferRequest {
        InferRequest {
            image,
            variant: VariantSelector::Default,
            deadline: None,
        }
    }

    pub fn with_variant(mut self, v: VariantSelector) -> InferRequest {
        self.variant = v;
        self
    }

    pub fn with_deadline(mut self, d: Duration) -> InferRequest {
        self.deadline = Some(d);
        self
    }
}

type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn InferenceBackend>> + Send>;

struct VariantDef {
    spec: VariantSpec,
    profile: VariantProfile,
    cfg: BatcherConfig,
    factory: BackendFactory,
}

/// Builder for [`Server`]: register named variants, pick a router and a
/// default, then `build()` to spawn one batcher worker per variant.
pub struct ServerBuilder {
    defs: Vec<VariantDef>,
    router: Box<dyn Router>,
    default_name: Option<String>,
}

impl Default for ServerBuilder {
    fn default() -> Self {
        ServerBuilder::new()
    }
}

impl ServerBuilder {
    pub fn new() -> ServerBuilder {
        ServerBuilder {
            defs: Vec::new(),
            router: Box::new(PolicyRouter),
            default_name: None,
        }
    }

    /// Register a variant. `factory` runs *inside* the variant's worker
    /// thread (PJRT backends are not `Send`). The routing profile is
    /// derived from the spec alone (paper ResNet-18 accuracy, no fps
    /// prior); use [`variant_with_profile`](Self::variant_with_profile) to
    /// attach a DSE-derived one.
    pub fn variant<F>(self, spec: VariantSpec, cfg: BatcherConfig, factory: F) -> ServerBuilder
    where
        F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        let profile = VariantProfile {
            top5_accuracy: spec.estimated_top5("ResNet-18"),
            ..VariantProfile::default()
        };
        self.variant_with_profile(spec, profile, cfg, factory)
    }

    /// Register a variant with an explicit routing profile (see
    /// [`VariantProfile::from_dse`]). If `cfg.fpga_fps_sim` is 0 the
    /// profile's DSE fps is attached as the variant's virtual clock.
    pub fn variant_with_profile<F>(
        mut self,
        spec: VariantSpec,
        profile: VariantProfile,
        mut cfg: BatcherConfig,
        factory: F,
    ) -> ServerBuilder
    where
        F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        if cfg.fpga_fps_sim <= 0.0 && profile.fpga_fps > 0.0 {
            cfg.fpga_fps_sim = profile.fpga_fps;
        }
        self.defs.push(VariantDef {
            spec,
            profile,
            cfg,
            factory: Box::new(factory),
        });
        self
    }

    /// Replace the default [`PolicyRouter`].
    pub fn router<R: Router>(mut self, r: R) -> ServerBuilder {
        self.router = Box::new(r);
        self
    }

    /// Name the variant `VariantSelector::Default` resolves to (first
    /// registered wins otherwise).
    pub fn default_variant(mut self, name: impl Into<String>) -> ServerBuilder {
        self.default_name = Some(name.into());
        self
    }

    /// Spawn every variant's worker (factories run in their threads, then
    /// warm up) and return the running server. Any factory/warm-up failure
    /// fails the build; already-spawned workers are joined.
    pub fn build(self) -> Result<Server> {
        if self.defs.is_empty() {
            return Err(crate::anyhow!("server needs at least one variant"));
        }
        for (i, d) in self.defs.iter().enumerate() {
            if self.defs[..i].iter().any(|p| p.spec.name == d.spec.name) {
                return Err(crate::anyhow!("duplicate variant name '{}'", d.spec.name));
            }
        }
        let default_idx = match &self.default_name {
            None => 0,
            Some(n) => self
                .defs
                .iter()
                .position(|d| &d.spec.name == n)
                .ok_or_else(|| crate::anyhow!("default variant '{n}' is not registered"))?,
        };
        let mut variants = Vec::with_capacity(self.defs.len());
        for def in self.defs {
            let worker = spawn_variant(&def.spec.name, def.factory, def.cfg)?;
            variants.push(Variant {
                name: Arc::from(def.spec.name.as_str()),
                spec: def.spec,
                profile: def.profile,
                worker,
            });
        }
        Ok(Server {
            variants,
            router: self.router,
            default_idx,
            started: Instant::now(),
        })
    }
}

struct Variant {
    spec: VariantSpec,
    profile: VariantProfile,
    worker: VariantWorker,
    /// `spec.name` as a shared str: per-request routing snapshots clone a
    /// pointer instead of a `String`.
    name: Arc<str>,
}

/// The running multi-variant serving gateway. Dropping it joins every
/// variant worker.
pub struct Server {
    variants: Vec<Variant>,
    router: Box<dyn Router>,
    default_idx: usize,
    started: Instant,
}

impl Server {
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    pub fn n_variants(&self) -> usize {
        self.variants.len()
    }

    pub fn variant_names(&self) -> Vec<String> {
        self.variants.iter().map(|v| v.spec.name.clone()).collect()
    }

    /// Routing snapshot of every variant (static profile + live signals).
    pub fn statuses(&self) -> Vec<VariantStatus> {
        self.variants
            .iter()
            .enumerate()
            .map(|(i, v)| VariantStatus {
                name: v.name.clone(),
                wq: v.spec.wq,
                top5_accuracy: v.profile.top5_accuracy,
                fpga_fps: v.profile.fpga_fps,
                ewma_latency_us: v.worker.shared.ewma_us(),
                inflight: v.worker.shared.inflight(),
                health: v.worker.shared.health(),
                default: i == self.default_idx,
            })
            .collect()
    }

    /// Resolve a selector to the variant name it would route to right now
    /// (introspection; the actual submit re-routes).
    pub fn route(&self, sel: &VariantSelector) -> Result<String, RouteError> {
        let idx = self.router.route(sel, &self.statuses())?;
        Ok(self.variants[idx].spec.name.clone())
    }

    /// Direct per-variant client (bypasses routing), e.g. for
    /// single-variant benchmark drivers.
    pub fn client(&self, name: &str) -> Option<Client> {
        self.variants
            .iter()
            .find(|v| v.spec.name == name)
            .map(|v| v.worker.client.clone())
    }

    fn resolve(&self, sel: &VariantSelector) -> Result<usize, SubmitError> {
        self.router
            .route(sel, &self.statuses())
            .map_err(SubmitError::Route)
    }

    /// Route and submit without blocking; sheds load when the routed
    /// variant's queue is full.
    pub fn try_submit(&self, req: InferRequest) -> Result<PendingResponse, SubmitError> {
        let idx = self.resolve(&req.variant)?;
        self.variants[idx].worker.client.try_submit(req.image)
    }

    /// Route and submit, blocking on the routed variant's queue.
    pub fn submit(&self, req: InferRequest) -> Result<PendingResponse, SubmitError> {
        let idx = self.resolve(&req.variant)?;
        self.variants[idx].worker.client.submit(req.image)
    }

    /// Submit and wait, honouring the request's deadline if set.
    pub fn infer(&self, req: InferRequest) -> Result<Response, String> {
        let deadline = req.deadline;
        let pending = self.submit(req).map_err(|e| e.to_string())?;
        match deadline {
            Some(d) => pending.wait_timeout(d),
            None => pending.wait(),
        }
    }

    /// Snapshot of one variant's metrics (wall window = since server
    /// start).
    pub fn metrics(&self, name: &str) -> Option<Metrics> {
        let v = self.variants.iter().find(|v| v.spec.name == name)?;
        let mut m = v.worker.metrics.lock().unwrap().clone();
        m.wall_us = self.started.elapsed().as_micros() as f64;
        Some(m)
    }

    /// Snapshots of every variant's metrics, in registration order.
    pub fn metrics_all(&self) -> Vec<(String, Metrics)> {
        self.variants
            .iter()
            .map(|v| {
                let mut m = v.worker.metrics.lock().unwrap().clone();
                m.wall_us = self.started.elapsed().as_micros() as f64;
                (v.spec.name.clone(), m)
            })
            .collect()
    }

    /// Per-variant metrics table for end-of-run summaries.
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new("per-variant serving metrics").headers(&[
            "variant", "wq", "top5 %*", "reqs", "resps", "errs", "mean batch", "p50 ms",
            "p99 ms", "ewma ms", "rps", "fpga-sim fps",
        ]);
        for (name, m) in self.metrics_all() {
            let v = self
                .variants
                .iter()
                .find(|v| v.spec.name == name)
                .expect("metrics_all names are registered");
            t.row(vec![
                name.clone(),
                v.spec
                    .wq
                    .map(|w| w.to_string())
                    .unwrap_or_else(|| "mix".into()),
                v.profile
                    .top5_accuracy
                    .map(|a| fnum(a, 2))
                    .unwrap_or_else(|| "-".into()),
                m.requests.to_string(),
                m.responses.to_string(),
                m.errors.to_string(),
                fnum(m.mean_batch(), 2),
                fnum(m.latency.percentile_us(50.0) / 1e3, 2),
                fnum(m.latency.percentile_us(99.0) / 1e3, 2),
                fnum(m.ewma_latency_us / 1e3, 2),
                fnum(m.throughput_rps(), 1),
                fnum(m.fpga_fps(), 1),
            ]);
        }
        t.note("* estimated (paper Table III/IV lineage); virtual-clock fps from the cached DSE");
        t
    }

    /// Graceful shutdown: join every worker, return final per-variant
    /// metrics. In-flight requests complete; queued-but-unbatched requests
    /// are drained before exit.
    pub fn shutdown(mut self) -> Vec<(String, Metrics)> {
        let wall_us = self.started.elapsed().as_micros() as f64;
        for v in &mut self.variants {
            v.worker.stop_and_join();
        }
        self.variants
            .iter()
            .map(|v| {
                let mut m = v.worker.metrics.lock().unwrap().clone();
                m.wall_us = wall_us;
                (v.spec.name.clone(), m)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock_variant(
        wq: u32,
        latency_us: u64,
        acc: f64,
        fps: f64,
    ) -> (VariantSpec, VariantProfile, BatcherConfig, BackendFactory) {
        (
            VariantSpec::uniform(wq),
            VariantProfile {
                top5_accuracy: Some(acc),
                fpga_fps: fps,
                fpga_mj_per_frame: 1.0,
            },
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_capacity: 64,
                fpga_fps_sim: 0.0,
            },
            Box::new(move || {
                Ok(Box::new(MockBackend::new(12, 4, vec![1, 4], latency_us))
                    as Box<dyn InferenceBackend>)
            }),
        )
    }

    fn three_variant_server() -> Server {
        let (s2, p2, c2, f2) = mock_variant(2, 100, 87.48, 245.0);
        let (s4, p4, c4, f4) = mock_variant(4, 200, 89.10, 165.0);
        let (s8, p8, c8, f8) = mock_variant(8, 400, 89.62, 47.0);
        Server::builder()
            .variant_with_profile(s2, p2, c2, f2)
            .variant_with_profile(s4, p4, c4, f4)
            .variant_with_profile(s8, p8, c8, f8)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_requires_variants() {
        assert!(Server::builder().build().is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let (s, p, c, f) = mock_variant(2, 0, 87.0, 1.0);
        let (_, p2, c2, f2) = mock_variant(4, 0, 89.0, 1.0);
        let err = Server::builder()
            .variant_with_profile(s.clone(), p, c, f)
            .variant_with_profile(s, p2, c2, f2)
            .build();
        assert!(err.is_err());
    }

    #[test]
    fn unknown_default_rejected() {
        let (s, p, c, f) = mock_variant(2, 0, 87.0, 1.0);
        assert!(Server::builder()
            .variant_with_profile(s, p, c, f)
            .default_variant("w999")
            .build()
            .is_err());
    }

    #[test]
    fn factory_failure_fails_build() {
        let r = Server::builder()
            .variant(
                VariantSpec::uniform(2),
                BatcherConfig::default(),
                || Err(crate::anyhow!("no backend here")),
            )
            .build();
        assert!(r.is_err());
        assert!(r.err().unwrap().to_string().contains("no backend here"));
    }

    #[test]
    fn one_process_hosts_three_precisions_and_routes_exactly() {
        let server = three_variant_server();
        assert_eq!(server.n_variants(), 3);
        for (wq, expect_name) in [(2u32, "w2"), (4, "w4"), (8, "w8")] {
            let resp = server
                .infer(
                    InferRequest::new(vec![1.0; 12]).with_variant(VariantSelector::Exact(wq)),
                )
                .unwrap();
            assert_eq!(resp.variant, expect_name);
        }
        // Exact never falls back: wq=16 is not hosted.
        match server.submit(
            InferRequest::new(vec![1.0; 12]).with_variant(VariantSelector::Exact(16)),
        ) {
            Err(SubmitError::Route(RouteError::NoSuchVariant(_))) => {}
            other => panic!("expected NoSuchVariant, got {other:?}"),
        }
        // Per-variant metrics saw exactly one request each.
        for (name, m) in server.shutdown() {
            assert_eq!(m.responses, 1, "variant {name}");
            assert_eq!(m.errors, 0, "variant {name}");
        }
    }

    #[test]
    fn default_variant_is_configurable() {
        let (s2, p2, c2, f2) = mock_variant(2, 0, 87.48, 245.0);
        let (s8, p8, c8, f8) = mock_variant(8, 0, 89.62, 47.0);
        let server = Server::builder()
            .variant_with_profile(s2, p2, c2, f2)
            .variant_with_profile(s8, p8, c8, f8)
            .default_variant("w8")
            .build()
            .unwrap();
        let resp = server.infer(InferRequest::new(vec![0.0; 12])).unwrap();
        assert_eq!(resp.variant, "w8");
        assert_eq!(server.route(&VariantSelector::Default).unwrap(), "w8");
    }

    #[test]
    fn min_accuracy_routes_to_fastest_qualifying() {
        let server = three_variant_server();
        // 87% excludes nothing here except... all qualify; w2 has the best
        // fps prior and lowest mock latency, so it should take the traffic.
        let resp = server
            .infer(
                InferRequest::new(vec![2.0; 12])
                    .with_variant(VariantSelector::MinAccuracy(87.0)),
            )
            .unwrap();
        assert_eq!(resp.variant, "w2");
        // 89.5% only w8 qualifies.
        let resp = server
            .infer(
                InferRequest::new(vec![2.0; 12])
                    .with_variant(VariantSelector::MinAccuracy(89.5)),
            )
            .unwrap();
        assert_eq!(resp.variant, "w8");
    }

    #[test]
    fn deadline_surfaces_as_timeout() {
        let (s, p, c, f) = mock_variant(2, 200_000, 87.0, 1.0);
        let server = Server::builder().variant_with_profile(s, p, c, f).build().unwrap();
        let r = server.infer(
            InferRequest::new(vec![0.0; 12])
                .with_deadline(Duration::from_millis(1)),
        );
        assert_eq!(r.unwrap_err(), "timeout");
    }

    #[test]
    fn selector_parse_round_trip() {
        assert_eq!(VariantSelector::parse("default").unwrap(), VariantSelector::Default);
        assert_eq!(VariantSelector::parse("exact:4").unwrap(), VariantSelector::Exact(4));
        assert_eq!(
            VariantSelector::parse("name:w2").unwrap(),
            VariantSelector::Named("w2".into())
        );
        match VariantSelector::parse("min-accuracy:0.85").unwrap() {
            VariantSelector::MinAccuracy(a) => assert!((a - 85.0).abs() < 1e-9),
            other => panic!("{other:?}"),
        }
        match VariantSelector::parse("min-accuracy:87.5").unwrap() {
            VariantSelector::MinAccuracy(a) => assert!((a - 87.5).abs() < 1e-9),
            other => panic!("{other:?}"),
        }
        assert_eq!(
            VariantSelector::parse("max-latency:20ms").unwrap(),
            VariantSelector::MaxLatency(Duration::from_millis(20))
        );
        assert!(VariantSelector::parse("nonsense").is_err());
        assert!(VariantSelector::parse("exact:notanumber").is_err());
        // from_secs_f64 would panic on these; parse must reject them.
        assert!(VariantSelector::parse("max-latency:-1ms").is_err());
        assert!(VariantSelector::parse("max-latency:nanms").is_err());
        assert!(VariantSelector::parse("max-latency:infms").is_err());
    }

    #[test]
    fn virtual_clock_attaches_from_profile() {
        let (s, p, c, f) = mock_variant(2, 0, 87.48, 100.0);
        // fpga_fps_sim left at 0 in cfg: builder attaches the profile fps.
        let server = Server::builder().variant_with_profile(s, p, c, f).build().unwrap();
        for _ in 0..10 {
            server
                .infer(InferRequest::new(vec![0.0; 12]))
                .unwrap();
        }
        let m = server.metrics("w2").unwrap();
        // 10 frames at 100 fps = 0.1 s of virtual time.
        assert!((m.fpga_virtual_us - 100_000.0).abs() < 1.0, "{}", m.fpga_virtual_us);
    }

    #[test]
    fn summary_table_renders_all_variants() {
        let server = three_variant_server();
        server
            .infer(InferRequest::new(vec![0.0; 12]))
            .unwrap();
        let rendered = server.summary_table().render();
        for name in ["w2", "w4", "w8"] {
            assert!(rendered.contains(name), "missing {name} in:\n{rendered}");
        }
    }
}
