//! L3 serving gateway: one [`Server`] process hosts a *family* of
//! mixed-precision model variants (the paper's accuracy–throughput
//! trade-off curve, deployed) and routes typed [`InferRequest`]s across
//! them.
//!
//! Each registered variant gets its own bounded admission queue, dynamic
//! batcher, and worker thread owning an [`InferenceBackend`] (the PJRT
//! engine in production, mocks in tests) — see [`worker`]. A pluggable
//! [`Router`] resolves each request's [`VariantSelector`] against static
//! profiles (paper Top-5, DSE-simulated fps) and live signals (EWMA
//! latency, queue depth, backend health):
//!
//! ```no_run
//! use mpcnn::serving::{BatcherConfig, InferenceBackend, InferRequest, MockBackend,
//!                      Server, VariantSelector, VariantSpec};
//! # fn main() -> mpcnn::util::error::Result<()> {
//! let server = Server::builder()
//!     .variant(VariantSpec::uniform(2), BatcherConfig::default(), || {
//!         Ok(Box::new(MockBackend::new(48, 10, vec![1, 8], 0)) as Box<dyn InferenceBackend>)
//!     })
//!     .variant(VariantSpec::uniform(8), BatcherConfig::default(), || {
//!         Ok(Box::new(MockBackend::new(48, 10, vec![1, 8], 0)) as Box<dyn InferenceBackend>)
//!     })
//!     .build()?;
//! let resp = server
//!     .infer(InferRequest::new(vec![0.5; 48]).with_variant(VariantSelector::MinAccuracy(87.0)))
//!     .map_err(|e| mpcnn::anyhow!("{e}"))?;
//! println!("class {} served by {}", resp.class, resp.variant);
//! # Ok(()) }
//! ```
//!
//! Backends: [`EngineBackend`] (compiled PJRT artifacts),
//! [`crate::xmp::XmpBackend`] (the native sliced-digit execution engine),
//! and [`MockBackend`] (deterministic test stub). The pre-gateway
//! single-variant `coordinator` shim is gone; its pass-through behaviour
//! lives on as the single-variant tests in
//! `rust/tests/integration_serving.rs`.
//!
//! Fault tolerance (PR 6): backend panics are caught and supervised (see
//! [`worker`] and [`supervisor`]), request deadlines are enforced at
//! admission and dequeue with shed counters in [`Metrics`], a per-variant
//! circuit breaker ([`retry`]) folds into the health policy routing sees,
//! and [`Server::infer`] honours a [`RetryPolicy`] — bounded retries and
//! optional hedging that re-route *policy* selectors to the next-best
//! healthy variant while `Exact`/`Named` keep the never-fall-back
//! invariant and fail fast. [`fault::FaultyBackend`] injects all of these
//! failure modes deterministically for tests and `mpcnn serve --fault`.

pub mod backend;
pub mod fault;
pub mod metrics;
pub mod retry;
pub mod router;
pub mod supervisor;
pub mod variant;
mod worker;

pub use backend::{BackendHealth, EngineBackend, InferenceBackend, MockBackend};
pub use fault::{
    silence_injected_panics, FaultControls, FaultKind, FaultPlan, FaultRule, FaultyBackend,
    Forced, InjectedPanic,
};
pub use metrics::{Metrics, MetricsSummary, SummaryField, SUMMARY_FIELDS};
pub use retry::{
    BreakerConfig, BreakerState, HedgeTrigger, RetryPolicy, RobustCounters, RobustSnapshot,
};
pub use router::{PolicyRouter, RouteError, Router, VariantStatus};
pub use supervisor::SupervisorConfig;
pub use variant::{VariantProfile, VariantSpec};
pub use worker::{BatcherConfig, Client, PendingResponse, Response, SubmitError};

use crate::obs::TraceHandle;
use crate::util::error::Result;
use crate::util::table::{fnum, Table};
use std::fmt;
use std::sync::Arc;
use std::time::{Duration, Instant};
use worker::{lock_metrics, spawn_variant, VariantWorker};

/// How a request picks its model variant.
#[derive(Clone, Debug, PartialEq)]
pub enum VariantSelector {
    /// The server's default variant.
    Default,
    /// Exactly the uniform-`wq` variant; never falls back.
    Exact(u32),
    /// Exactly the named variant; never falls back.
    Named(String),
    /// Cheapest variant whose estimated Top-5 accuracy (percent) is at
    /// least this.
    MinAccuracy(f64),
    /// Most accurate variant whose current latency estimate fits.
    MaxLatency(Duration),
}

impl VariantSelector {
    /// Parse a CLI route spec: `default`, `exact:4`, `name:w4`,
    /// `min-accuracy:0.85` (fraction or percent), `max-latency:20ms`.
    pub fn parse(s: &str) -> Result<VariantSelector, String> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("default") {
            return Ok(VariantSelector::Default);
        }
        let (kind, val) = s
            .split_once(':')
            .ok_or_else(|| format!("bad route '{s}' (want KIND:VALUE or 'default')"))?;
        match kind {
            "exact" => val
                .parse::<u32>()
                .map(VariantSelector::Exact)
                .map_err(|_| format!("bad wq in '{s}'")),
            "name" | "named" => Ok(VariantSelector::Named(val.to_string())),
            "min-accuracy" => {
                let a: f64 = val.parse().map_err(|_| format!("bad accuracy in '{s}'"))?;
                // Accept both 0.85 (fraction) and 85 (percent).
                Ok(VariantSelector::MinAccuracy(if a <= 1.0 { a * 100.0 } else { a }))
            }
            "max-latency" => {
                let ms: f64 = val
                    .trim_end_matches("ms")
                    .parse()
                    .map_err(|_| format!("bad latency in '{s}' (want e.g. 20ms)"))?;
                // from_secs_f64 panics on negative/NaN; reject instead.
                if !ms.is_finite() || ms < 0.0 {
                    return Err(format!("bad latency in '{s}' (want non-negative ms)"));
                }
                Ok(VariantSelector::MaxLatency(Duration::from_secs_f64(
                    ms / 1e3,
                )))
            }
            _ => Err(format!(
                "unknown route kind '{kind}' \
                 (default | exact:WQ | name:NAME | min-accuracy:PCT | max-latency:MS)"
            )),
        }
    }
}

impl fmt::Display for VariantSelector {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VariantSelector::Default => write!(f, "default"),
            VariantSelector::Exact(wq) => write!(f, "exact:{wq}"),
            VariantSelector::Named(n) => write!(f, "name:{n}"),
            VariantSelector::MinAccuracy(a) => write!(f, "min-accuracy:{a:.2}"),
            VariantSelector::MaxLatency(d) => {
                write!(f, "max-latency:{:.1}ms", d.as_secs_f64() * 1e3)
            }
        }
    }
}

/// One typed inference request.
#[derive(Clone, Debug)]
pub struct InferRequest {
    /// Flattened image (must match the routed variant's `image_len`).
    pub image: Vec<f32>,
    pub variant: VariantSelector,
    /// End-to-end answer-by budget; `None` waits indefinitely. Enforced
    /// three times: at admission (shed if the routed queue's EWMA wait
    /// already exceeds it), at dequeue (shed if it expired while queued),
    /// and client-side in [`Server::infer`] (wait at most this long).
    pub deadline: Option<Duration>,
    /// Tracing handle carried through the gateway into the batcher worker.
    /// Off by default — untraced requests pay one `Option` check per
    /// instrumentation point.
    pub trace: TraceHandle,
}

impl InferRequest {
    pub fn new(image: Vec<f32>) -> InferRequest {
        InferRequest {
            image,
            variant: VariantSelector::Default,
            deadline: None,
            trace: TraceHandle::off(),
        }
    }

    pub fn with_variant(mut self, v: VariantSelector) -> InferRequest {
        self.variant = v;
        self
    }

    pub fn with_deadline(mut self, d: Duration) -> InferRequest {
        self.deadline = Some(d);
        self
    }

    pub fn with_trace(mut self, t: TraceHandle) -> InferRequest {
        self.trace = t;
        self
    }
}

type BackendFactory = Box<dyn Fn() -> Result<Box<dyn InferenceBackend>> + Send>;

struct VariantDef {
    spec: VariantSpec,
    profile: VariantProfile,
    cfg: BatcherConfig,
    factory: BackendFactory,
}

/// Builder for [`Server`]: register named variants, pick a router and a
/// default, then `build()` to spawn one batcher worker per variant.
pub struct ServerBuilder {
    defs: Vec<VariantDef>,
    router: Box<dyn Router>,
    default_name: Option<String>,
    retry: RetryPolicy,
}

impl Default for ServerBuilder {
    fn default() -> Self {
        ServerBuilder::new()
    }
}

impl ServerBuilder {
    pub fn new() -> ServerBuilder {
        ServerBuilder {
            defs: Vec::new(),
            router: Box::new(PolicyRouter),
            default_name: None,
            retry: RetryPolicy::default(),
        }
    }

    /// Register a variant. `factory` runs *inside* the variant's worker
    /// thread (PJRT backends are not `Send`) and is re-invoked there by
    /// the supervisor to rebuild a crashed backend. The routing profile is
    /// derived from the spec alone (paper ResNet-18 accuracy, no fps
    /// prior); use [`variant_with_profile`](Self::variant_with_profile) to
    /// attach a DSE-derived one.
    pub fn variant<F>(self, spec: VariantSpec, cfg: BatcherConfig, factory: F) -> ServerBuilder
    where
        F: Fn() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        let profile = VariantProfile {
            top5_accuracy: spec.estimated_top5("ResNet-18"),
            ..VariantProfile::default()
        };
        self.variant_with_profile(spec, profile, cfg, factory)
    }

    /// Register a variant with an explicit routing profile (see
    /// [`VariantProfile::from_dse`]). If `cfg.fpga_fps_sim` is 0 the
    /// profile's DSE fps is attached as the variant's virtual clock.
    pub fn variant_with_profile<F>(
        mut self,
        spec: VariantSpec,
        profile: VariantProfile,
        mut cfg: BatcherConfig,
        factory: F,
    ) -> ServerBuilder
    where
        F: Fn() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        if cfg.fpga_fps_sim <= 0.0 && profile.fpga_fps > 0.0 {
            cfg.fpga_fps_sim = profile.fpga_fps;
        }
        self.defs.push(VariantDef {
            spec,
            profile,
            cfg,
            factory: Box::new(factory),
        });
        self
    }

    /// Replace the default [`PolicyRouter`].
    pub fn router<R: Router>(mut self, r: R) -> ServerBuilder {
        self.router = Box::new(r);
        self
    }

    /// Name the variant `VariantSelector::Default` resolves to (first
    /// registered wins otherwise).
    pub fn default_variant(mut self, name: impl Into<String>) -> ServerBuilder {
        self.default_name = Some(name.into());
        self
    }

    /// Retry/hedge policy applied by [`Server::infer`]. The default is a
    /// single attempt — exactly the pre-policy behavior.
    pub fn retry_policy(mut self, policy: RetryPolicy) -> ServerBuilder {
        self.retry = policy;
        self
    }

    /// Spawn every variant's worker (factories run in their threads, then
    /// warm up) and return the running server. Any factory/warm-up failure
    /// fails the build; already-spawned workers are joined.
    pub fn build(self) -> Result<Server> {
        if self.defs.is_empty() {
            return Err(crate::anyhow!("server needs at least one variant"));
        }
        for (i, d) in self.defs.iter().enumerate() {
            if self.defs[..i].iter().any(|p| p.spec.name == d.spec.name) {
                return Err(crate::anyhow!("duplicate variant name '{}'", d.spec.name));
            }
        }
        let default_idx = match &self.default_name {
            None => 0,
            Some(n) => self
                .defs
                .iter()
                .position(|d| &d.spec.name == n)
                .ok_or_else(|| crate::anyhow!("default variant '{n}' is not registered"))?,
        };
        let mut variants = Vec::with_capacity(self.defs.len());
        for def in self.defs {
            let worker = spawn_variant(&def.spec.name, def.factory, def.cfg)?;
            variants.push(Variant {
                name: Arc::from(def.spec.name.as_str()),
                spec: def.spec,
                profile: def.profile,
                worker,
            });
        }
        Ok(Server {
            variants,
            router: self.router,
            default_idx,
            started: Instant::now(),
            retry: self.retry,
            robust: RobustCounters::default(),
        })
    }
}

struct Variant {
    spec: VariantSpec,
    profile: VariantProfile,
    worker: VariantWorker,
    /// `spec.name` as a shared str: per-request routing snapshots clone a
    /// pointer instead of a `String`.
    name: Arc<str>,
}

/// The running multi-variant serving gateway. Dropping it joins every
/// variant worker.
pub struct Server {
    variants: Vec<Variant>,
    router: Box<dyn Router>,
    default_idx: usize,
    started: Instant,
    retry: RetryPolicy,
    robust: RobustCounters,
}

impl Server {
    pub fn builder() -> ServerBuilder {
        ServerBuilder::new()
    }

    pub fn n_variants(&self) -> usize {
        self.variants.len()
    }

    pub fn variant_names(&self) -> Vec<String> {
        self.variants.iter().map(|v| v.spec.name.clone()).collect()
    }

    /// Routing snapshot of every variant (static profile + live signals).
    /// The circuit breaker folds into the health the router sees: an open
    /// breaker reports `Unavailable` (policy routing excludes it), a
    /// half-open one `Degraded` (eligible again — the next policy-routed
    /// request is the probe that closes or re-opens it). `Exact`/`Named`
    /// ignore health entirely, so pinned traffic still reaches the variant
    /// either way.
    pub fn statuses(&self) -> Vec<VariantStatus> {
        self.variants
            .iter()
            .enumerate()
            .map(|(i, v)| {
                let base = v.worker.shared.health();
                let health = match v.worker.shared.breaker.state() {
                    BreakerState::Open => BackendHealth::Unavailable,
                    BreakerState::HalfOpen if base != BackendHealth::Unavailable => {
                        BackendHealth::Degraded
                    }
                    _ => base,
                };
                VariantStatus {
                    name: v.name.clone(),
                    wq: v.spec.wq,
                    top5_accuracy: v.profile.top5_accuracy,
                    fpga_fps: v.profile.fpga_fps,
                    ewma_latency_us: v.worker.shared.ewma_us(),
                    inflight: v.worker.shared.inflight(),
                    health,
                    default: i == self.default_idx,
                }
            })
            .collect()
    }

    /// Raw per-variant circuit-breaker states, in registration order. The
    /// obs sampler records these alongside [`Server::statuses`] (which
    /// folds the breaker into routing health): the tsdb keeps both so an
    /// operator can tell "breaker open" apart from "backend unhealthy".
    pub fn breaker_states(&self) -> Vec<(String, BreakerState)> {
        self.variants
            .iter()
            .map(|v| (v.spec.name.clone(), v.worker.shared.breaker.state()))
            .collect()
    }

    /// Resolve a selector to the variant name it would route to right now
    /// (introspection; the actual submit re-routes).
    pub fn route(&self, sel: &VariantSelector) -> Result<String, RouteError> {
        let idx = self.router.route(sel, &self.statuses())?;
        Ok(self.variants[idx].spec.name.clone())
    }

    /// Direct per-variant client (bypasses routing), e.g. for
    /// single-variant benchmark drivers.
    pub fn client(&self, name: &str) -> Option<Client> {
        self.variants
            .iter()
            .find(|v| v.spec.name == name)
            .map(|v| v.worker.client.clone())
    }

    fn resolve(&self, sel: &VariantSelector) -> Result<usize, SubmitError> {
        self.router
            .route(sel, &self.statuses())
            .map_err(SubmitError::Route)
    }

    /// Degraded-mode re-route for retries/hedges: route with the already-
    /// failed indices masked `Unavailable`; if the router still lands on a
    /// failed variant (`Default` ignores health) or errors out, degrade to
    /// the cheapest-latency healthy variant not yet tried. `None` means no
    /// healthy variant is left.
    fn reroute(&self, sel: &VariantSelector, failed: &[usize]) -> Option<usize> {
        let mut sts = self.statuses();
        for &i in failed {
            if let Some(s) = sts.get_mut(i) {
                s.health = BackendHealth::Unavailable;
            }
        }
        match self.router.route(sel, &sts) {
            Ok(idx) if !failed.contains(&idx) => Some(idx),
            _ => sts
                .iter()
                .enumerate()
                .filter(|(i, s)| {
                    !failed.contains(i) && s.health != BackendHealth::Unavailable
                })
                .min_by(|a, b| {
                    a.1.latency_estimate_us().total_cmp(&b.1.latency_estimate_us())
                })
                .map(|(i, _)| i),
        }
    }

    /// Route and submit without blocking; sheds load when the routed
    /// variant's queue is full or the deadline is already unattainable.
    pub fn try_submit(&self, req: InferRequest) -> Result<PendingResponse, SubmitError> {
        let idx = self.resolve(&req.variant)?;
        let deadline = req.deadline.map(|d| Instant::now() + d);
        self.variants[idx]
            .worker
            .client
            .try_submit_traced(req.image, deadline, req.trace)
    }

    /// Route and submit, blocking on the routed variant's queue. The
    /// request's deadline travels with it: the pipeline sheds it at
    /// admission or dequeue once the deadline is hopeless.
    pub fn submit(&self, req: InferRequest) -> Result<PendingResponse, SubmitError> {
        let idx = self.resolve(&req.variant)?;
        let deadline = req.deadline.map(|d| Instant::now() + d);
        self.variants[idx]
            .worker
            .client
            .submit_traced(req.image, deadline, req.trace)
    }

    /// Submit and wait, honouring the request's deadline and the server's
    /// [`RetryPolicy`]. Policy-routed selectors (`Default`, `MinAccuracy`,
    /// `MaxLatency`) retry/hedge onto the next-best healthy variant after
    /// a failure — graceful degradation prefers an answer from a healthy
    /// variant over an error from the preferred one. `Exact`/`Named`
    /// selectors never fall back and fail fast: one attempt, no hedge.
    pub fn infer(&self, req: InferRequest) -> Result<Response, String> {
        let pinned = matches!(
            req.variant,
            VariantSelector::Exact(_) | VariantSelector::Named(_)
        );
        let abs_deadline = req.deadline.map(|d| Instant::now() + d);
        let single_shot =
            pinned || (self.retry.max_attempts <= 1 && self.retry.hedge_after.is_none());
        if single_shot {
            // Fast path, identical to the pre-policy gateway: no image
            // clone, one submission, one wait.
            let idx = self.resolve(&req.variant).map_err(|e| e.to_string())?;
            let pending = self.variants[idx]
                .worker
                .client
                .submit_traced(req.image, abs_deadline, req.trace)
                .map_err(|e| e.to_string())?;
            return Self::wait_until(pending, abs_deadline);
        }
        let mut failed: Vec<usize> = Vec::new();
        let mut first_routed: Option<usize> = None;
        let mut last_err = String::new();
        for attempt in 0..self.retry.max_attempts.max(1) {
            let idx = if attempt == 0 {
                match self.resolve(&req.variant) {
                    Ok(i) => i,
                    Err(e) => return Err(e.to_string()),
                }
            } else {
                self.robust.note_retry();
                let backoff = self.retry.backoff_before(attempt);
                if !backoff.is_zero() {
                    std::thread::sleep(backoff);
                }
                match self.reroute(&req.variant, &failed) {
                    Some(i) => i,
                    None => break, // no healthy variant left to try
                }
            };
            if attempt > 0 {
                req.trace.add_event(
                    "retry",
                    Instant::now(),
                    vec![
                        ("attempt", attempt.to_string()),
                        ("variant", self.variants[idx].spec.name.clone()),
                    ],
                );
            }
            match first_routed {
                None => first_routed = Some(idx),
                Some(f) if f != idx => self.robust.note_fallback(),
                _ => {}
            }
            let pending = match self.variants[idx]
                .worker
                .client
                .submit_traced(req.image.clone(), abs_deadline, req.trace.clone())
            {
                Ok(p) => p,
                Err(e) => {
                    last_err = e.to_string();
                    failed.push(idx);
                    continue;
                }
            };
            match self.await_hedged(&req, idx, pending, abs_deadline, &failed) {
                Ok(r) => return Ok(r),
                Err(e) => {
                    last_err = e;
                    if !failed.contains(&idx) {
                        failed.push(idx);
                    }
                }
            }
        }
        Err(if last_err.is_empty() {
            "no healthy variant available".to_string()
        } else {
            last_err
        })
    }

    fn wait_until(
        pending: PendingResponse,
        abs_deadline: Option<Instant>,
    ) -> Result<Response, String> {
        match abs_deadline {
            Some(d) => pending.wait_timeout(d.saturating_duration_since(Instant::now())),
            None => pending.wait(),
        }
    }

    /// The hedge delay for a variant: the policy's fixed delay, or its
    /// observed p99 (EWMA fallback while the histogram is empty, 50 ms
    /// floor so a cold variant isn't hedged instantly).
    fn hedge_delay(&self, idx: usize, trigger: HedgeTrigger) -> Duration {
        match trigger {
            HedgeTrigger::Fixed(d) => d,
            HedgeTrigger::P99 => {
                let m = lock_metrics(&self.variants[idx].worker.metrics);
                let mut us = m.latency.percentile_us(99.0);
                if us <= 0.0 {
                    us = m.ewma_latency_us;
                }
                drop(m);
                Duration::from_micros(us.max(0.0) as u64).max(Duration::from_millis(50))
            }
        }
    }

    /// Wait for `pending`, optionally racing a hedge submission to the
    /// next-best variant once the hedge delay elapses. Returns the first
    /// success, the first error once no submission is left pending, or
    /// `timeout` at the absolute deadline.
    fn await_hedged(
        &self,
        req: &InferRequest,
        idx: usize,
        pending: PendingResponse,
        abs_deadline: Option<Instant>,
        failed: &[usize],
    ) -> Result<Response, String> {
        let Some(trigger) = self.retry.hedge_after else {
            return Self::wait_until(pending, abs_deadline);
        };
        let mut delay = self.hedge_delay(idx, trigger);
        if let Some(d) = abs_deadline {
            delay = delay.min(d.saturating_duration_since(Instant::now()));
        }
        if let Some(r) = pending.poll_timeout(delay) {
            return r; // answered (or failed) before the hedge fired
        }
        // Hedge: duplicate the request onto the next-best healthy variant.
        let mut mask = failed.to_vec();
        mask.push(idx);
        let hedge = self.reroute(&req.variant, &mask).and_then(|hi| {
            self.variants[hi]
                .worker
                .client
                .try_submit_traced(req.image.clone(), abs_deadline, req.trace.clone())
                .ok()
        });
        let mut original = Some(pending);
        let mut hedged = match hedge {
            Some(p) => {
                self.robust.note_hedge();
                req.trace.add_event("hedge", Instant::now(), vec![]);
                Some(p)
            }
            None => None, // nowhere to hedge: keep waiting on the original
        };
        let mut first_err: Option<String> = None;
        let slice = Duration::from_millis(1);
        loop {
            if let Some(p) = &original {
                if let Some(r) = p.poll_timeout(slice) {
                    match r {
                        Ok(resp) => return Ok(resp),
                        Err(e) => {
                            first_err.get_or_insert(e);
                            original = None;
                        }
                    }
                }
            }
            if let Some(p) = &hedged {
                if let Some(r) = p.poll_timeout(slice) {
                    match r {
                        Ok(resp) => {
                            if original.is_some() {
                                self.robust.note_hedge_win();
                            }
                            self.robust.note_fallback();
                            return Ok(resp);
                        }
                        Err(e) => {
                            first_err.get_or_insert(e);
                            hedged = None;
                        }
                    }
                }
            }
            if original.is_none() && hedged.is_none() {
                return Err(first_err.unwrap_or_else(|| "request failed".to_string()));
            }
            if let Some(d) = abs_deadline {
                if Instant::now() >= d {
                    return Err("timeout".to_string());
                }
            }
        }
    }

    /// Server-level robustness counters (retries, hedges, fallbacks).
    pub fn robust_counters(&self) -> RobustSnapshot {
        self.robust.snapshot()
    }

    /// Gateway-wide robustness ledger: the worker-side counters
    /// ([`MetricsSummary`]'s shed/panic/restart fields) summed over every
    /// variant, plus the server-level retry/hedge counters. The CLI's
    /// end-of-run "robustness:" line and the edge `/metrics` endpoint both
    /// consume this one struct instead of folding `metrics_all` ad hoc.
    pub fn robustness_report(&self) -> RobustnessReport {
        let mut r = RobustnessReport::default();
        for (_, m) in self.metrics_all() {
            let s = m.summarize();
            r.shed += s.shed;
            r.shed_admission += s.shed_admission;
            r.shed_expired += s.shed_expired;
            r.panics += s.panics;
            r.worker_restarts += s.worker_restarts;
        }
        let rc = self.robust.snapshot();
        r.retried = rc.retried;
        r.hedged = rc.hedged;
        r.hedge_wins = rc.hedge_wins;
        r.fallbacks = rc.fallbacks;
        r
    }

    /// Clone a variant's metrics, folding in the signals that live outside
    /// the mutex (admission sheds are counted lock-free on the client
    /// path).
    fn snapshot_metrics(v: &Variant, wall_us: f64) -> Metrics {
        let mut m = lock_metrics(&v.worker.metrics).clone();
        m.shed_admission = v.worker.shared.shed_admission();
        m.wall_us = wall_us;
        m
    }

    /// Snapshot of one variant's metrics (wall window = since server
    /// start).
    pub fn metrics(&self, name: &str) -> Option<Metrics> {
        let v = self.variants.iter().find(|v| v.spec.name == name)?;
        Some(Self::snapshot_metrics(
            v,
            self.started.elapsed().as_micros() as f64,
        ))
    }

    /// Snapshots of every variant's metrics, in registration order.
    pub fn metrics_all(&self) -> Vec<(String, Metrics)> {
        let wall_us = self.started.elapsed().as_micros() as f64;
        self.variants
            .iter()
            .map(|v| (v.spec.name.clone(), Self::snapshot_metrics(v, wall_us)))
            .collect()
    }

    /// Per-variant metrics table for end-of-run summaries.
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new("per-variant serving metrics").headers(&[
            "variant", "wq", "top5 %*", "reqs", "resps", "errs", "shed", "rst", "mean batch",
            "p50 ms", "p99 ms", "ewma ms", "rps", "fpga-sim fps",
        ]);
        for (name, m) in self.metrics_all() {
            let v = self
                .variants
                .iter()
                .find(|v| v.spec.name == name)
                .expect("metrics_all names are registered");
            t.row(vec![
                name.clone(),
                v.spec
                    .wq
                    .map(|w| w.to_string())
                    .unwrap_or_else(|| "mix".into()),
                v.profile
                    .top5_accuracy
                    .map(|a| fnum(a, 2))
                    .unwrap_or_else(|| "-".into()),
                m.requests.to_string(),
                m.responses.to_string(),
                m.errors.to_string(),
                m.shed().to_string(),
                m.worker_restarts.to_string(),
                fnum(m.mean_batch(), 2),
                fnum(m.latency.percentile_us(50.0) / 1e3, 2),
                fnum(m.latency.percentile_us(99.0) / 1e3, 2),
                fnum(m.ewma_latency_us / 1e3, 2),
                fnum(m.throughput_rps(), 1),
                fnum(m.fpga_fps(), 1),
            ]);
        }
        t.note("* estimated (paper Table III/IV lineage); virtual-clock fps from the cached DSE");
        t
    }

    /// Graceful shutdown: join every worker, return final per-variant
    /// metrics. In-flight requests complete; queued-but-unbatched requests
    /// are drained before exit.
    pub fn shutdown(mut self) -> Vec<(String, Metrics)> {
        let wall_us = self.started.elapsed().as_micros() as f64;
        for v in &mut self.variants {
            v.worker.stop_and_join();
        }
        self.variants
            .iter()
            .map(|v| (v.spec.name.clone(), Self::snapshot_metrics(v, wall_us)))
            .collect()
    }
}

/// Gateway-wide robustness ledger (see [`Server::robustness_report`]):
/// worker-side shed/panic/restart counters summed over every variant plus
/// the server-level retry/hedge counters from [`RobustSnapshot`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RobustnessReport {
    /// `shed_admission + shed_expired`, summed over variants.
    pub shed: u64,
    pub shed_admission: u64,
    pub shed_expired: u64,
    pub panics: u64,
    pub worker_restarts: u64,
    pub retried: u64,
    pub hedged: u64,
    pub hedge_wins: u64,
    pub fallbacks: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock_variant(
        wq: u32,
        latency_us: u64,
        acc: f64,
        fps: f64,
    ) -> (VariantSpec, VariantProfile, BatcherConfig, BackendFactory) {
        (
            VariantSpec::uniform(wq),
            VariantProfile {
                top5_accuracy: Some(acc),
                fpga_fps: fps,
                fpga_mj_per_frame: 1.0,
            },
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_capacity: 64,
                fpga_fps_sim: 0.0,
                ..Default::default()
            },
            Box::new(move || {
                Ok(Box::new(MockBackend::new(12, 4, vec![1, 4], latency_us))
                    as Box<dyn InferenceBackend>)
            }),
        )
    }

    fn three_variant_server() -> Server {
        let (s2, p2, c2, f2) = mock_variant(2, 100, 87.48, 245.0);
        let (s4, p4, c4, f4) = mock_variant(4, 200, 89.10, 165.0);
        let (s8, p8, c8, f8) = mock_variant(8, 400, 89.62, 47.0);
        Server::builder()
            .variant_with_profile(s2, p2, c2, f2)
            .variant_with_profile(s4, p4, c4, f4)
            .variant_with_profile(s8, p8, c8, f8)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_requires_variants() {
        assert!(Server::builder().build().is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let (s, p, c, f) = mock_variant(2, 0, 87.0, 1.0);
        let (_, p2, c2, f2) = mock_variant(4, 0, 89.0, 1.0);
        let err = Server::builder()
            .variant_with_profile(s.clone(), p, c, f)
            .variant_with_profile(s, p2, c2, f2)
            .build();
        assert!(err.is_err());
    }

    #[test]
    fn unknown_default_rejected() {
        let (s, p, c, f) = mock_variant(2, 0, 87.0, 1.0);
        assert!(Server::builder()
            .variant_with_profile(s, p, c, f)
            .default_variant("w999")
            .build()
            .is_err());
    }

    #[test]
    fn factory_failure_fails_build() {
        let r = Server::builder()
            .variant(
                VariantSpec::uniform(2),
                BatcherConfig::default(),
                || Err(crate::anyhow!("no backend here")),
            )
            .build();
        assert!(r.is_err());
        assert!(r.err().unwrap().to_string().contains("no backend here"));
    }

    #[test]
    fn one_process_hosts_three_precisions_and_routes_exactly() {
        let server = three_variant_server();
        assert_eq!(server.n_variants(), 3);
        for (wq, expect_name) in [(2u32, "w2"), (4, "w4"), (8, "w8")] {
            let resp = server
                .infer(
                    InferRequest::new(vec![1.0; 12]).with_variant(VariantSelector::Exact(wq)),
                )
                .unwrap();
            assert_eq!(resp.variant, expect_name);
        }
        // Exact never falls back: wq=16 is not hosted.
        match server.submit(
            InferRequest::new(vec![1.0; 12]).with_variant(VariantSelector::Exact(16)),
        ) {
            Err(SubmitError::Route(RouteError::NoSuchVariant(_))) => {}
            other => panic!("expected NoSuchVariant, got {other:?}"),
        }
        // Per-variant metrics saw exactly one request each.
        for (name, m) in server.shutdown() {
            assert_eq!(m.responses, 1, "variant {name}");
            assert_eq!(m.errors, 0, "variant {name}");
        }
    }

    #[test]
    fn default_variant_is_configurable() {
        let (s2, p2, c2, f2) = mock_variant(2, 0, 87.48, 245.0);
        let (s8, p8, c8, f8) = mock_variant(8, 0, 89.62, 47.0);
        let server = Server::builder()
            .variant_with_profile(s2, p2, c2, f2)
            .variant_with_profile(s8, p8, c8, f8)
            .default_variant("w8")
            .build()
            .unwrap();
        let resp = server.infer(InferRequest::new(vec![0.0; 12])).unwrap();
        assert_eq!(resp.variant, "w8");
        assert_eq!(server.route(&VariantSelector::Default).unwrap(), "w8");
    }

    #[test]
    fn min_accuracy_routes_to_fastest_qualifying() {
        let server = three_variant_server();
        // 87% excludes nothing here except... all qualify; w2 has the best
        // fps prior and lowest mock latency, so it should take the traffic.
        let resp = server
            .infer(
                InferRequest::new(vec![2.0; 12])
                    .with_variant(VariantSelector::MinAccuracy(87.0)),
            )
            .unwrap();
        assert_eq!(resp.variant, "w2");
        // 89.5% only w8 qualifies.
        let resp = server
            .infer(
                InferRequest::new(vec![2.0; 12])
                    .with_variant(VariantSelector::MinAccuracy(89.5)),
            )
            .unwrap();
        assert_eq!(resp.variant, "w8");
    }

    #[test]
    fn deadline_surfaces_as_timeout() {
        let (s, p, c, f) = mock_variant(2, 200_000, 87.0, 1.0);
        let server = Server::builder().variant_with_profile(s, p, c, f).build().unwrap();
        let r = server.infer(
            InferRequest::new(vec![0.0; 12])
                .with_deadline(Duration::from_millis(1)),
        );
        // With deadline enforcement the server may shed the request at
        // dequeue before the client's own wait expires; either surface is a
        // correct "missed deadline" outcome.
        let e = r.unwrap_err();
        assert!(e == "timeout" || e.contains("shed"), "{e}");
    }

    #[test]
    fn selector_parse_round_trip() {
        assert_eq!(VariantSelector::parse("default").unwrap(), VariantSelector::Default);
        assert_eq!(VariantSelector::parse("exact:4").unwrap(), VariantSelector::Exact(4));
        assert_eq!(
            VariantSelector::parse("name:w2").unwrap(),
            VariantSelector::Named("w2".into())
        );
        match VariantSelector::parse("min-accuracy:0.85").unwrap() {
            VariantSelector::MinAccuracy(a) => assert!((a - 85.0).abs() < 1e-9),
            other => panic!("{other:?}"),
        }
        match VariantSelector::parse("min-accuracy:87.5").unwrap() {
            VariantSelector::MinAccuracy(a) => assert!((a - 87.5).abs() < 1e-9),
            other => panic!("{other:?}"),
        }
        assert_eq!(
            VariantSelector::parse("max-latency:20ms").unwrap(),
            VariantSelector::MaxLatency(Duration::from_millis(20))
        );
        assert!(VariantSelector::parse("nonsense").is_err());
        assert!(VariantSelector::parse("exact:notanumber").is_err());
        // from_secs_f64 would panic on these; parse must reject them.
        assert!(VariantSelector::parse("max-latency:-1ms").is_err());
        assert!(VariantSelector::parse("max-latency:nanms").is_err());
        assert!(VariantSelector::parse("max-latency:infms").is_err());
    }

    #[test]
    fn virtual_clock_attaches_from_profile() {
        let (s, p, c, f) = mock_variant(2, 0, 87.48, 100.0);
        // fpga_fps_sim left at 0 in cfg: builder attaches the profile fps.
        let server = Server::builder().variant_with_profile(s, p, c, f).build().unwrap();
        for _ in 0..10 {
            server
                .infer(InferRequest::new(vec![0.0; 12]))
                .unwrap();
        }
        let m = server.metrics("w2").unwrap();
        // 10 frames at 100 fps = 0.1 s of virtual time.
        assert!((m.fpga_virtual_us - 100_000.0).abs() < 1.0, "{}", m.fpga_virtual_us);
    }

    #[test]
    fn summary_table_renders_all_variants() {
        let server = three_variant_server();
        server
            .infer(InferRequest::new(vec![0.0; 12]))
            .unwrap();
        let rendered = server.summary_table().render();
        for name in ["w2", "w4", "w8"] {
            assert!(rendered.contains(name), "missing {name} in:\n{rendered}");
        }
    }

    /// A variant that fails every request (`fail_after = Some(0)`): its
    /// registered factory still builds fine, so routing tries it first.
    fn failing_variant(
        wq: u32,
        acc: f64,
        fps: f64,
    ) -> (VariantSpec, VariantProfile, BatcherConfig, BackendFactory) {
        let (s, p, c, _) = mock_variant(wq, 0, acc, fps);
        (
            s,
            p,
            c,
            Box::new(|| {
                let mut b = MockBackend::new(12, 4, vec![1, 4], 0);
                b.fail_after = Some(0);
                Ok(Box::new(b) as Box<dyn InferenceBackend>)
            }),
        )
    }

    #[test]
    fn retry_reroutes_policy_traffic_away_from_failing_variant() {
        // w2 looks cheapest (best fps prior) so MinAccuracy routes there
        // first — but every call fails. The retry must fall back to w8.
        let (s2, p2, c2, f2) = failing_variant(2, 87.48, 245.0);
        let (s8, p8, c8, f8) = mock_variant(8, 0, 89.62, 47.0);
        let server = Server::builder()
            .variant_with_profile(s2, p2, c2, f2)
            .variant_with_profile(s8, p8, c8, f8)
            .retry_policy(RetryPolicy::attempts(3))
            .build()
            .unwrap();
        let resp = server
            .infer(
                InferRequest::new(vec![1.0; 12])
                    .with_variant(VariantSelector::MinAccuracy(87.0)),
            )
            .expect("retry should land on the healthy variant");
        assert_eq!(resp.variant, "w8");
        let rc = server.robust_counters();
        assert!(rc.retried >= 1, "{rc:?}");
        assert!(rc.fallbacks >= 1, "{rc:?}");
    }

    #[test]
    fn exact_selector_fails_fast_without_retry() {
        let (s2, p2, c2, f2) = failing_variant(2, 87.48, 245.0);
        let (s8, p8, c8, f8) = mock_variant(8, 0, 89.62, 47.0);
        let server = Server::builder()
            .variant_with_profile(s2, p2, c2, f2)
            .variant_with_profile(s8, p8, c8, f8)
            .retry_policy(RetryPolicy::attempts(3))
            .build()
            .unwrap();
        let err = server
            .infer(
                InferRequest::new(vec![1.0; 12]).with_variant(VariantSelector::Exact(2)),
            )
            .unwrap_err();
        assert!(err.contains("injected failure"), "{err}");
        // Pinned selectors never burn retry attempts or fall back.
        assert_eq!(server.robust_counters(), RobustSnapshot::default());
    }

    #[test]
    fn hedge_races_slow_variant_and_faster_one_wins() {
        // w2 is the default variant but takes 50 ms per call; w8 answers in
        // ~0. A 5 ms fixed hedge should duplicate onto w8 and win.
        let (s2, p2, c2, f2) = mock_variant(2, 50_000, 87.48, 245.0);
        let (s8, p8, c8, f8) = mock_variant(8, 0, 89.62, 47.0);
        let server = Server::builder()
            .variant_with_profile(s2, p2, c2, f2)
            .variant_with_profile(s8, p8, c8, f8)
            .retry_policy(
                RetryPolicy::default().with_hedge(HedgeTrigger::Fixed(Duration::from_millis(5))),
            )
            .build()
            .unwrap();
        let resp = server
            .infer(InferRequest::new(vec![0.0; 12]))
            .expect("hedged request should succeed");
        assert_eq!(resp.variant, "w8", "hedge to the fast variant should win");
        let rc = server.robust_counters();
        assert!(rc.hedged >= 1, "{rc:?}");
        assert!(rc.hedge_wins >= 1, "{rc:?}");
    }
}
