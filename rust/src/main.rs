//! `mpcnn` CLI — leader entrypoint for the DSE, the simulator, the table
//! reproduction harness, and the PJRT serving path.

use mpcnn::cnn::resnet;
use mpcnn::util::error::Result;
use mpcnn::{anyhow, bail};
use mpcnn::config::RunConfig;
use mpcnn::coordinator::{BatcherConfig, Coordinator, EngineBackend};
use mpcnn::report::{render_checks, tables};
use mpcnn::runtime::{artifacts_dir, Engine, TestSet};
use mpcnn::util::cli::Args;
use mpcnn::util::rng::Rng;
use mpcnn::{baselines, dse, sim};
use std::time::Duration;

const USAGE: &str = "\
mpcnn — mixed-precision CNN accelerator DSE + simulator + PJRT serving (FPL'22 reproduction)

USAGE: mpcnn <subcommand> [options]

SUBCOMMANDS
  dse        --cnn resnet18 [--wq 2 | --channelwise 1:0.8,8:0.2]
             [--k 1,2,4] [--config file]
             run the holistic DSE and print the chosen design per slice
  simulate   --cnn resnet18 --wq 2 --k 2 [--dims 7x5x37] [--layers]
             simulate one accelerator design (Table IV style column)
  tables     [--which fig3|fig6|fig7|fig8|fig9|table2|table3|table4|table5|all]
             regenerate the paper's tables/figures with shape checks
  baseline   --which dsp|fixed8|bitfusion --cnn resnet18 --wq 2
             simulate a comparison design
  pe         [--wq 1,2,4,8] rank the PE design space (Fig 6 data)
  serve      [--wq 4] [--batch 8] [--requests 256] [--artifacts DIR]
             run the batched PJRT serving demo over the exported testset
  classify   [--wq 4] [--index 0] classify one testset image via PJRT
  info       print workload statistics for the built-in CNNs
";

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            RunConfig::from_kv(&text).map_err(|e| anyhow!("{e}"))?
        }
        None => RunConfig::default(),
    };
    if args.get("k").is_some() {
        cfg.slices = args.get_list_u32("k", &[1, 2, 4]);
    }
    Ok(cfg)
}

fn cnn_for(args: &Args, cfg: &RunConfig) -> Result<mpcnn::cnn::Cnn> {
    let name = args.get_or("cnn", "resnet18");
    let base = resnet::by_name(&name).ok_or_else(|| anyhow!("unknown CNN '{name}'"))?;
    // `--channelwise 1:0.8,8:0.2` — per-channel word-length groups
    if let Some(spec) = args.get("channelwise") {
        let mut groups = Vec::new();
        for part in spec.split(',') {
            let (w, f) = part
                .split_once(':')
                .ok_or_else(|| anyhow!("--channelwise expects wq:frac,... (got '{part}')"))?;
            groups.push(mpcnn::cnn::ChannelGroup {
                wq: w.trim().parse()?,
                fraction: f.trim().parse()?,
            });
        }
        return Ok(mpcnn::cnn::apply_channelwise(&base, &groups));
    }
    let wq = args.get_u64("wq", 8) as u32;
    if !cfg.weight_bits.contains(&wq) && wq != 8 {
        bail!("wq={wq} not in configured weight_bits {:?}", cfg.weight_bits);
    }
    Ok(base.with_uniform_wq(wq))
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "dse" => cmd_dse(args),
        "simulate" => cmd_simulate(args),
        "tables" => cmd_tables(args),
        "baseline" => cmd_baseline(args),
        "pe" => cmd_pe(args),
        "serve" => cmd_serve(args),
        "classify" => cmd_classify(args),
        "info" => cmd_info(),
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn cmd_dse(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let cnn = cnn_for(args, &cfg)?;
    println!(
        "holistic DSE for {} (avg w_Q = {:.2}) on {}\n",
        cnn.name,
        mpcnn::cnn::workload::mac_weighted_avg_wq(&cnn),
        cfg.fpga.name
    );
    let report = dse::explore(&cnn, &cfg);
    let mut t = mpcnn::util::table::Table::new("DSE outcomes per operand slice").headers(&[
        "k", "array HxWxD", "N_PE", "max-PE thr", "kLUT", "BRAM", "U avg", "fps", "GOps/s",
        "mJ/frame", "GOps/s/W",
    ]);
    for o in &report.per_k {
        t.row(vec![
            o.k.to_string(),
            o.array.dims.to_string(),
            o.array.n_pe.to_string(),
            o.max_pe_threshold.to_string(),
            format!("{:.1}", o.sim.kluts),
            o.sim.brams.to_string(),
            format!("{:.3}", o.array.avg_utilization),
            format!("{:.1}", o.sim.fps),
            format!("{:.1}", o.sim.gops),
            format!("{:.2}", o.sim.e_total_mj()),
            format!("{:.1}", o.sim.gops_per_w()),
        ]);
    }
    print!("{}", t.render());
    let best = report.best_outcome();
    println!(
        "\nchosen design: BP-ST-1D k={} @ {} ({} PEs), {:.1} frames/s",
        best.k, best.array.dims, best.array.n_pe, best.sim.fps
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let cnn = cnn_for(args, &cfg)?;
    let k = args.get_u64("k", 2) as u32;
    let design = match args.get("dims") {
        Some(d) => {
            let parts: Vec<u32> = d.split('x').filter_map(|p| p.parse().ok()).collect();
            if parts.len() != 3 {
                bail!("--dims must be HxWxD");
            }
            sim::AcceleratorDesign::new(
                mpcnn::pe::PeDesign::bp_st_1d(k),
                mpcnn::array::Dims::new(parts[0], parts[1], parts[2]),
                &cnn,
                &cfg,
            )
        }
        None => {
            let out = dse::explore_k(&cnn, &cfg, k);
            sim::AcceleratorDesign::new(
                mpcnn::pe::PeDesign::bp_st_1d(k),
                out.array.dims,
                &cnn,
                &cfg,
            )
        }
    };
    let r = sim::simulate(&cnn, &design);
    if args.has_flag("layers") {
        print!("{}", sim::trace::layer_table(&r).render());
    }
    println!("{}", sim::trace::summary_json(&r).to_string_pretty());
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let which = args.get_or("which", "all");
    let mut all_checks = Vec::new();
    let mut emit = |name: &str, result: (mpcnn::util::table::Table, Vec<mpcnn::report::ShapeCheck>)| {
        let (t, checks) = result;
        println!("{}", t.render());
        print!("{}", render_checks(&checks));
        println!();
        all_checks.extend(checks);
        let _ = name;
    };
    let want = |n: &str| which == "all" || which == n;
    if want("fig3") {
        emit("fig3", tables::fig3());
    }
    if want("fig6") {
        emit("fig6", tables::fig6(&cfg));
    }
    if want("fig7") {
        emit("fig7", tables::fig7(&cfg));
    }
    if want("fig8") {
        emit("fig8", tables::fig8());
    }
    if want("table2") {
        emit("table2", tables::table2(&cfg));
    }
    if want("table3") {
        emit("table3", tables::table3());
    }
    if want("table4") {
        emit("table4", tables::table4(&cfg));
    }
    if want("table5") {
        emit("table5", tables::table5(&cfg));
    }
    if want("fig9") {
        emit("fig9", tables::fig9(&cfg));
    }
    let failed = all_checks.iter().filter(|c| !c.pass).count();
    println!(
        "== overall: {}/{} shape checks passed ==",
        all_checks.len() - failed,
        all_checks.len()
    );
    if failed > 0 {
        bail!("{failed} shape checks failed");
    }
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let cnn = cnn_for(args, &cfg)?;
    let which = args.get_or("which", "dsp");
    let (tag, r) = baselines::run_baseline(&which, &cnn, &cfg)
        .ok_or_else(|| anyhow!("unknown baseline '{which}' (dsp|fixed8|bitfusion)"))?;
    println!("baseline '{which}' = {tag}");
    println!("{}", sim::trace::summary_json(&r).to_string_pretty());
    Ok(())
}

fn cmd_pe(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let (t, checks) = tables::fig6(&cfg);
    println!("{}", t.render());
    print!("{}", render_checks(&checks));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    let wq = args.get_u64("wq", 4) as u32;
    let n_requests = args.get_usize("requests", 256);
    let manifest = mpcnn::runtime::Manifest::load(&dir)?;
    let ts_path = manifest
        .testset
        .clone()
        .ok_or_else(|| anyhow!("manifest has no testset"))?;
    let testset = TestSet::load(dir.join(ts_path))?;

    // Attach the simulated-FPGA clock: what would this stream cost on the
    // DSE-chosen ResNet-8-class design? Memoized in-process, so repeated
    // searches in this run (e.g. serving several word-lengths, or the
    // report tables) reuse the outcome instead of re-searching.
    let cfg = RunConfig::default();
    let small = resnet::resnet_small(1, 10).with_uniform_wq(wq);
    let fpga_fps = dse::explore_k_cached(&small, &cfg, wq.clamp(1, 4), dse::DseCache::global())
        .sim
        .fps;

    let dir2 = dir.clone();
    let coordinator = Coordinator::start(
        move || {
            let engine = Engine::load_all(&dir2)?;
            println!(
                "engine up on {} with models: {:?}",
                engine.platform(),
                engine.loaded_names()
            );
            Ok(Box::new(EngineBackend::new(engine, wq)?) as Box<dyn mpcnn::coordinator::InferenceBackend>)
        },
        BatcherConfig {
            max_batch: args.get_usize("batch", 8),
            max_wait: Duration::from_millis(2),
            queue_capacity: 256,
            fpga_fps_sim: fpga_fps,
        },
    )?;

    let client = coordinator.client();
    let mut rng = Rng::new(42);
    let mut correct = 0usize;
    let mut done = 0usize;
    let mut pending = Vec::new();
    let mut truth = Vec::new();
    for i in 0..n_requests {
        let idx = rng.range(0, testset.n);
        let img = testset.image(idx).to_vec();
        truth.push(testset.labels[idx] as usize);
        pending.push(client.submit(img).map_err(|e| anyhow!("{e}"))?);
        // drain in waves of 32 to keep the queue busy but bounded
        if pending.len() >= 32 || i + 1 == n_requests {
            for (p, t) in pending.drain(..).zip(truth.drain(..)) {
                let r = p.wait().map_err(|e| anyhow!("{e}"))?;
                if r.class == t {
                    correct += 1;
                }
                done += 1;
            }
        }
    }
    let m = coordinator.metrics();
    println!("{}", m.summary());
    println!(
        "accuracy: {}/{} = {:.2}% (wq={wq})",
        correct,
        done,
        100.0 * correct as f64 / done as f64
    );
    println!(
        "simulated FPGA design for this model: {:.1} fps (virtual clock above)",
        fpga_fps
    );
    Ok(())
}

fn cmd_classify(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    let wq = args.get_u64("wq", 4) as u32;
    let index = args.get_usize("index", 0);
    let engine = Engine::load_all(&dir)?;
    let ts_path = engine
        .manifest
        .testset
        .clone()
        .ok_or_else(|| anyhow!("manifest has no testset"))?;
    let testset = TestSet::load(dir.join(ts_path))?;
    if index >= testset.n {
        bail!("index {index} out of range (testset has {} images)", testset.n);
    }
    let model = engine
        .model_for(wq, 1)
        .ok_or_else(|| anyhow!("no batch-1 model for wq={wq}"))?;
    let classes = model.classify(testset.image(index))?;
    println!(
        "image {index}: predicted class {} (label {})",
        classes[0], testset.labels[index]
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    let mut t = mpcnn::util::table::Table::new("built-in CNNs").headers(&[
        "name", "input", "layers", "GMACs (conv)", "params (M)", "peak act Mb",
    ]);
    for name in ["resnet8", "resnet20", "resnet18", "resnet34", "resnet50", "resnet101", "resnet152"] {
        let c = resnet::by_name(name).unwrap();
        t.row(vec![
            c.name.clone(),
            format!("{0}x{0}x{1}", c.input_hw, c.input_channels),
            c.layers.len().to_string(),
            format!("{:.2}", c.conv_macs() as f64 / 1e9),
            format!("{:.2}", c.total_params() as f64 / 1e6),
            format!("{:.2}", c.peak_activation_bits() as f64 / 1e6),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
