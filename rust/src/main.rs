//! `mpcnn` CLI — leader entrypoint for the DSE, the simulator, the table
//! reproduction harness, and the PJRT serving path.

use mpcnn::cnn::resnet;
use mpcnn::edge::{EdgeConfig, EdgeServer, RemoteClient, ResponseCheck};
use mpcnn::util::error::Result;
use mpcnn::{anyhow, bail};
use mpcnn::config::RunConfig;
use mpcnn::report::{render_checks, tables};
use mpcnn::runtime::{artifacts_dir, Engine, TestSet};
use mpcnn::serving::{
    silence_injected_panics, BatcherConfig, EngineBackend, FaultControls, FaultPlan,
    FaultyBackend, InferRequest, InferenceBackend, MockBackend, PendingResponse, RetryPolicy,
    Server, VariantProfile, VariantSelector, VariantSpec,
};
use mpcnn::util::cli::Args;
use mpcnn::util::rng::Rng;
use mpcnn::xmp::{XmpBackend, XmpConfig};
use mpcnn::{baselines, dse, sim};
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Duration;

const USAGE: &str = "\
mpcnn — mixed-precision CNN accelerator DSE + simulator + PJRT serving (FPL'22 reproduction)

USAGE: mpcnn <subcommand> [options]

SUBCOMMANDS
  dse        --cnn resnet18 [--wq 2 | --channelwise 1:0.8,8:0.2]
             [--k 1,2,4] [--config file]
             run the holistic DSE and print the chosen design per slice
  plan       --cnn resnet18 [--family ResNet-18] [--bits 1,2,4,8]
             [--aq 4,6,8] [--beam 48] [--max-evals 16] [--alpha 1.0]
             [--splits 0.5] [--min-top5 PCT] [--budget-mb MB]
             [--no-serve-check]
             search joint layer/channel-wise (weight, activation)
             word-length plans under the FPGA budgets, print the
             (proxy-accuracy, fps, footprint) Pareto frontier vs the
             uniform variants, and boot the emitted family in the serving
             gateway (mock backends); --aq opens the activation axis
             (default 8 = the paper's fixed point)
  simulate   --cnn resnet18 --wq 2 --k 2 [--dims 7x5x37] [--layers]
             simulate one accelerator design (Table IV style column)
  tables     [--which fig3|fig6|fig7|fig8|fig9|table2|table3|table4|table5|all]
             regenerate the paper's tables/figures with shape checks
  baseline   --which dsp|fixed8|bitfusion --cnn resnet18 --wq 2
             simulate a comparison design
  pe         [--wq 1,2,4,8] rank the PE design space (Fig 6 data)
  serve      [--variants 2,4,8] [--aq 8] [--route mixed|default|exact:WQ|
             name:NAME|min-accuracy:0.85|max-latency:20ms] [--batch 8]
             [--requests 256] [--window 64] [--artifacts DIR]
             [--backend auto|pjrt|xmp|mock] [--planned]
             [--fault SCENARIO[:seed][@VARIANT]] [--retry N] [--deadline MS]
             host every listed precision variant in ONE gateway process and
             route a request stream across them; backend fallback order is
             PJRT (compiled artifacts) -> xmp (the native sliced-digit
             mixed-precision engine, synthetic LSQ weights) -> mock (only
             when asked for); reports per-variant metrics, client-side
             achieved throughput, and — on xmp — per-variant agreement with
             an independently built reference model; `--planned` hosts the
             precision planner's emitted Pareto family (layerwise plans
             included) on xmp backends instead of the uniform list; --aq N
             hosts every variant at activation word-length N (xmp engine
             2D-slices both operands; requires --backend xmp/auto-xmp);
             --fault wraps one variant (default: the first) in a seeded
             fault-injecting backend — scenarios flaky|crashy|storm|dead|
             latency|corrupt — and the supervisor/circuit-breaker keep the
             gateway serving through it; --retry N allows up to N attempts
             per request, re-routing policy-routed selectors to the
             next-best healthy variant (exact:/name: never fall back);
             --deadline MS attaches a per-request deadline — hopeless
             requests are shed at admission or dequeue instead of wasting
             backend time; robustness counters (shed, expired, panics,
             worker restarts, retried, hedged, fallbacks) print after the
             per-variant table;
             --listen ADDR hosts the gateway behind the network edge
             instead of driving a synthetic load loop: an HTTP/1.1
             front-end with POST /v1/classify, GET /healthz and a
             Prometheus GET /metrics, per-client token-bucket rate
             limiting (--rate RPS, --burst N; 429 + Retry-After), a
             global in-flight ceiling (--max-inflight N; 503),
             identical-request coalescing, and a sha256
             content-addressed response cache (--cache ENTRIES);
             --for SECS drains gracefully after SECS (default: serve
             until killed);
             --trace arms the flight recorder: every classify carries an
             end-to-end trace (spans from edge parse through batcher
             infer; id returned in the X-Trace-Id response header),
             browsable at GET /v1/trace (recent ids + slow exemplars),
             GET /v1/trace/<id> (span JSON), GET /v1/trace/export
             (Chrome trace-event JSON, Perfetto-loadable);
             --trace-capacity N sizes the ring (default 256),
             --slow-trace-us US pins slower-than-US traces until read;
             --slo FILE|default arms the SLO engine: a background sampler
             (--sample-ms MS, default 1000) snapshots every counter into a
             fixed-memory time-series ring and evaluates each objective as
             a multi-window burn-rate alert (fast 5m x14.4 + slow 1h x6,
             pending -> firing -> resolved); alerts at GET /v1/alerts,
             the operational event journal (alert transitions, worker
             restarts, breaker flips, fault overrides) as JSONL at
             GET /v1/events, windowed rates at GET /v1/stats?window=30s,
             and mpcnn_slo_* series join /metrics; with --fault armed,
             POST /v1/fault {\"force\":\"none|error|panic|corrupt\"}
             overrides the injector live (the CI smoke test lifts a fault
             this way and watches the alert resolve)
  classify   [--wq 4] [--aq 8] [--index 0] [--route exact:4] [--variants 4]
             [--backend auto|pjrt|xmp|mock] [--trace]
             classify one testset image through the gateway; with
             `--backend xmp` the class is computed by the 2D-sliced
             kernels on synthetic weights (no artifacts needed), at the
             requested (wq, aq) precision pair; --trace prints the
             request's span timing table (same taxonomy as the edge's
             flight recorder);
             --remote http://ADDR classifies over HTTP against a
             `serve --listen` edge instead of booting a local gateway
             (--image-len N synthesizes the request image, --deadline MS
             attaches a deadline, --client ID names the rate-limit
             bucket, --retry N retries connection errors with backoff)
  top        --remote http://ADDR [--window 30s] [--interval MS] [--once]
             live operational console for a `serve --listen --slo` edge:
             polls GET /v1/stats?window=W and GET /v1/alerts and redraws a
             per-variant table (rps, p50/p99, queue wait, EWMA, shed,
             restarts, breaker, health) plus the burn-rate alert board
             every --interval MS (default 2000); --window accepts
             ms/s/m/h suffixes (default 30s, the rate denominator);
             --once prints a single frame and exits (CI-friendly)
  trace      --remote http://ADDR [--id N] [--out FILE]
             inspect a `serve --listen --trace` edge's flight recorder:
             list recent trace ids (default), print one trace's spans
             (--id N), or export every retained trace as Chrome
             trace-event JSON (--out trace.json; load in Perfetto or
             chrome://tracing)
  profile    [--cnn resnet18] [--wq 4] [--aq 8] [--k 2] [--json]
             run one image through the xmp sliced-digit kernels with
             per-layer stage timing (im2col/pack/gemm/requant) and join
             the accelerator simulator's modeled cycles for the same
             layers — measured-host vs virtual-FPGA attribution in one
             table (resnet8 is the quick topology; resnet18 runs the
             full ImageNet stem and takes a while on scalar kernels)
  info       print workload statistics for the built-in CNNs

BUILD FEATURES
  --features simd   compile the xmp fast GEMM's vector inner kernels
             (AVX2, runtime-detected; NEON on aarch64). The default
             build is pure scalar; results are bit-identical either
             way, and MPCNN_SIMD=0 forces the scalar tile at runtime
";

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            RunConfig::from_kv(&text).map_err(|e| anyhow!("{e}"))?
        }
        None => RunConfig::default(),
    };
    if args.get("k").is_some() {
        cfg.slices = args.get_list_u32("k", &[1, 2, 4]);
    }
    Ok(cfg)
}

fn cnn_for(args: &Args, cfg: &RunConfig) -> Result<mpcnn::cnn::Cnn> {
    let name = args.get_or("cnn", "resnet18");
    let base = resnet::by_name(&name).ok_or_else(|| anyhow!("unknown CNN '{name}'"))?;
    // `--channelwise 1:0.8,8:0.2` — per-channel word-length groups
    if let Some(spec) = args.get("channelwise") {
        let mut groups = Vec::new();
        for part in spec.split(',') {
            let (w, f) = part
                .split_once(':')
                .ok_or_else(|| anyhow!("--channelwise expects wq:frac,... (got '{part}')"))?;
            groups.push(mpcnn::cnn::ChannelGroup {
                wq: w.trim().parse()?,
                fraction: f.trim().parse()?,
            });
        }
        return Ok(mpcnn::cnn::apply_channelwise(&base, &groups));
    }
    let wq = args.get_u64("wq", 8) as u32;
    if !cfg.weight_bits.contains(&wq) && wq != 8 {
        bail!("wq={wq} not in configured weight_bits {:?}", cfg.weight_bits);
    }
    Ok(base.with_uniform_wq(wq))
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "dse" => cmd_dse(args),
        "plan" => cmd_plan(args),
        "simulate" => cmd_simulate(args),
        "tables" => cmd_tables(args),
        "baseline" => cmd_baseline(args),
        "pe" => cmd_pe(args),
        "serve" => cmd_serve(args),
        "classify" => cmd_classify(args),
        "trace" => cmd_trace(args),
        "top" => cmd_top(args),
        "profile" => cmd_profile(args),
        "info" => cmd_info(),
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

fn cmd_dse(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let cnn = cnn_for(args, &cfg)?;
    println!(
        "holistic DSE for {} (avg w_Q = {:.2}) on {}\n",
        cnn.name,
        mpcnn::cnn::workload::mac_weighted_avg_wq(&cnn),
        cfg.fpga.name
    );
    let report = dse::explore(&cnn, &cfg);
    let mut t = mpcnn::util::table::Table::new("DSE outcomes per operand slice").headers(&[
        "k", "array HxWxD", "N_PE", "max-PE thr", "kLUT", "BRAM", "U avg", "fps", "GOps/s",
        "mJ/frame", "GOps/s/W",
    ]);
    for o in &report.per_k {
        t.row(vec![
            o.k.to_string(),
            o.array.dims.to_string(),
            o.array.n_pe.to_string(),
            o.max_pe_threshold.to_string(),
            format!("{:.1}", o.sim.kluts),
            o.sim.brams.to_string(),
            format!("{:.3}", o.array.avg_utilization),
            format!("{:.1}", o.sim.fps),
            format!("{:.1}", o.sim.gops),
            format!("{:.2}", o.sim.e_total_mj()),
            format!("{:.1}", o.sim.gops_per_w()),
        ]);
    }
    print!("{}", t.render());
    let best = report.best_outcome();
    println!(
        "\nchosen design: BP-ST-1D k={} @ {} ({} PEs), {:.1} frames/s",
        best.k, best.array.dims, best.array.n_pe, best.sim.fps
    );
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let name = args.get_or("cnn", "resnet18");
    let base = resnet::by_name(&name).ok_or_else(|| anyhow!("unknown CNN '{name}'"))?;
    // The accuracy family defaults to the paper table matching the CNN
    // (small 32x32 variants calibrate against ResNet-18, see EXPERIMENTS.md).
    let default_family = match base.name.as_str() {
        "ResNet-50" | "ResNet-101" => "ResNet-50",
        "ResNet-152" => "ResNet-152",
        _ => "ResNet-18",
    };
    let mut pcfg = mpcnn::planner::PlannerConfig::for_config(&cfg);
    pcfg.family = args.get_or("family", default_family);
    pcfg.wq_choices = args.get_list_u32("bits", &pcfg.wq_choices);
    pcfg.aq_choices = args.get_list_u32("aq", &pcfg.aq_choices);
    pcfg.beam_width = args.get_usize("beam", pcfg.beam_width);
    pcfg.max_evals = args.get_usize("max-evals", pcfg.max_evals);
    pcfg.alpha = args.get_f64("alpha", pcfg.alpha);
    if let Some(s) = args.get("splits") {
        pcfg.split_fractions = s.split(',').filter_map(|p| p.trim().parse().ok()).collect();
    }
    // Constraints must parse or error — silently dropping a mistyped
    // accuracy floor / footprint ceiling would plan an unconstrained family.
    if let Some(v) = args.get("min-top5") {
        pcfg.min_top5 =
            Some(v.parse().map_err(|_| anyhow!("bad --min-top5 '{v}' (want e.g. 87.5)"))?);
    }
    if let Some(v) = args.get("budget-mb") {
        pcfg.max_footprint_mb =
            Some(v.parse().map_err(|_| anyhow!("bad --budget-mb '{v}' (want e.g. 6.0)"))?);
    }

    println!(
        "precision planner: {} on {} ({} anchors, bits {:?}, aq {:?}, beam {}, <= {} DSE evals)\n",
        base.name,
        cfg.fpga.name,
        pcfg.family,
        pcfg.wq_choices,
        pcfg.aq_choices,
        pcfg.beam_width,
        pcfg.max_evals
    );
    let started = std::time::Instant::now();
    let report = mpcnn::planner::plan(&base, &cfg, &pcfg)?;
    print!("{}", report.table(&base).render());
    println!(
        "\n{} candidates enumerated, {} evaluated through the DSE in {:.2}s",
        report.enumerated,
        report.evaluated,
        started.elapsed().as_secs_f64()
    );
    let dominating = report.dominating_points();
    if dominating.is_empty() {
        println!("no mixed plan dominates a uniform variant under this budget");
    } else {
        for p in &dominating {
            let doms: Vec<String> =
                p.dominates.iter().map(|w| format!("w{w}")).collect();
            println!(
                "{} [{}] Pareto-dominates {} on (Top-5*, fps, footprint)",
                p.name,
                p.assignment.describe(&base),
                doms.join(", ")
            );
        }
    }

    if !args.has_flag("no-serve-check") {
        // Boot the emitted family end to end on mock backends and route one
        // request to the most accurate planned variant.
        let server = mpcnn::planner::mock_family_server(&report, 3072, 10)?;
        let names = server.variant_names();
        let target = report
            .frontier
            .iter()
            .find(|p| p.uniform_wq.is_none())
            .map(|p| p.name.clone())
            .unwrap_or_else(|| names[0].clone());
        let resp = server
            .infer(
                InferRequest::new(vec![0.5; 3072]).with_variant(VariantSelector::Named(target)),
            )
            .map_err(|e| anyhow!("{e}"))?;
        println!(
            "\nserve check: emitted family {:?} boots in the gateway; '{}' answered class {}",
            names, resp.variant, resp.class
        );
        server.shutdown();
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let cnn = cnn_for(args, &cfg)?;
    let k = args.get_u64("k", 2) as u32;
    let design = match args.get("dims") {
        Some(d) => {
            let parts: Vec<u32> = d.split('x').filter_map(|p| p.parse().ok()).collect();
            if parts.len() != 3 {
                bail!("--dims must be HxWxD");
            }
            sim::AcceleratorDesign::new(
                mpcnn::pe::PeDesign::bp_st_1d(k),
                mpcnn::array::Dims::new(parts[0], parts[1], parts[2]),
                &cnn,
                &cfg,
            )
        }
        None => {
            let out = dse::explore_k(&cnn, &cfg, k);
            sim::AcceleratorDesign::new(
                mpcnn::pe::PeDesign::bp_st_1d(k),
                out.array.dims,
                &cnn,
                &cfg,
            )
        }
    };
    let r = sim::simulate(&cnn, &design);
    if args.has_flag("layers") {
        print!("{}", sim::trace::layer_table(&r).render());
    }
    println!("{}", sim::trace::summary_json(&r).to_string_pretty());
    Ok(())
}

fn cmd_tables(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let which = args.get_or("which", "all");
    let mut all_checks = Vec::new();
    let mut emit = |name: &str, result: (mpcnn::util::table::Table, Vec<mpcnn::report::ShapeCheck>)| {
        let (t, checks) = result;
        println!("{}", t.render());
        print!("{}", render_checks(&checks));
        println!();
        all_checks.extend(checks);
        let _ = name;
    };
    let want = |n: &str| which == "all" || which == n;
    if want("fig3") {
        emit("fig3", tables::fig3());
    }
    if want("fig6") {
        emit("fig6", tables::fig6(&cfg));
    }
    if want("fig7") {
        emit("fig7", tables::fig7(&cfg));
    }
    if want("fig8") {
        emit("fig8", tables::fig8());
    }
    if want("table2") {
        emit("table2", tables::table2(&cfg));
    }
    if want("table3") {
        emit("table3", tables::table3());
    }
    if want("table4") {
        emit("table4", tables::table4(&cfg));
    }
    if want("table5") {
        emit("table5", tables::table5(&cfg));
    }
    if want("fig9") {
        emit("fig9", tables::fig9(&cfg));
    }
    let failed = all_checks.iter().filter(|c| !c.pass).count();
    println!(
        "== overall: {}/{} shape checks passed ==",
        all_checks.len() - failed,
        all_checks.len()
    );
    if failed > 0 {
        bail!("{failed} shape checks failed");
    }
    Ok(())
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let cnn = cnn_for(args, &cfg)?;
    let which = args.get_or("which", "dsp");
    let (tag, r) = baselines::run_baseline(&which, &cnn, &cfg)
        .ok_or_else(|| anyhow!("unknown baseline '{which}' (dsp|fixed8|bitfusion)"))?;
    println!("baseline '{which}' = {tag}");
    println!("{}", sim::trace::summary_json(&r).to_string_pretty());
    Ok(())
}

fn cmd_pe(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let (t, checks) = tables::fig6(&cfg);
    println!("{}", t.render());
    print!("{}", render_checks(&checks));
    Ok(())
}

/// Which execution engine the gateway's variant workers run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BackendKind {
    /// Resolve to PJRT when compiled artifacts are loadable, else xmp —
    /// the fallback order is real compute first, mocks only on request.
    Auto,
    Pjrt,
    /// The native truly-mixed-precision sliced-digit engine (synthetic
    /// LSQ weights when no trained artifacts exist).
    Xmp,
    Mock,
}

impl BackendKind {
    fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "pjrt" => Ok(BackendKind::Pjrt),
            "xmp" => Ok(BackendKind::Xmp),
            "mock" => Ok(BackendKind::Mock),
            other => bail!("unknown --backend '{other}' (auto|pjrt|xmp|mock)"),
        }
    }

    fn label(self) -> &'static str {
        match self {
            BackendKind::Auto => "auto",
            BackendKind::Pjrt => "PJRT",
            BackendKind::Xmp => "xmp",
            BackendKind::Mock => "mock",
        }
    }
}

/// A variant backend factory as the gateway builders pass it around (the
/// supervisor re-invokes it to rebuild a crashed backend).
type Factory = Box<dyn Fn() -> Result<Box<dyn InferenceBackend>> + Send>;

/// Parsed `--fault SCENARIO[:seed][@VARIANT]`: which variant (default: the
/// first registered) gets its factory wrapped in a [`FaultyBackend`], and
/// the shared controls/ledger that survive supervisor rebuilds.
struct FaultArg {
    plan: FaultPlan,
    scenario: String,
    variant: Option<String>,
    controls: Arc<FaultControls>,
}

impl FaultArg {
    fn parse(spec: &str) -> Result<FaultArg> {
        let (plan_spec, variant) = match spec.split_once('@') {
            Some((p, v)) => (p, Some(v.to_string())),
            None => (spec, None),
        };
        Ok(FaultArg {
            plan: FaultPlan::parse(plan_spec)?,
            scenario: plan_spec.to_string(),
            variant,
            controls: FaultControls::new(),
        })
    }

    /// Does the `index`-th registered variant named `name` get the fault?
    fn targets(&self, name: &str, index: usize) -> bool {
        match &self.variant {
            Some(v) => v == name,
            None => index == 0,
        }
    }

    /// Wrap `inner` so every (re)built backend injects this plan through
    /// the same shared controls — window scenarios keep progressing and
    /// injection counts accumulate across supervisor restarts.
    fn wrap(&self, inner: Factory) -> Factory {
        let plan = self.plan.clone();
        let controls = self.controls.clone();
        Box::new(move || {
            Ok(Box::new(FaultyBackend::new(inner()?, plan.clone(), controls.clone()))
                as Box<dyn InferenceBackend>)
        })
    }

    /// Fail loudly when `@VARIANT` named nobody: a chaos run that silently
    /// injects nothing would report misleadingly clean numbers.
    fn check_bound(&self, registered: &[String]) -> Result<()> {
        if let Some(v) = &self.variant {
            if !registered.iter().any(|n| n == v) {
                bail!("--fault targets unknown variant '{v}' (hosted: {registered:?})");
            }
        }
        Ok(())
    }
}

/// What `serve`/`classify` built: the multi-variant gateway plus how to
/// drive it.
struct Gateway {
    server: Server,
    testset: Option<TestSet>,
    /// Resolved engine (never `Auto`).
    backend: BackendKind,
    image_len: usize,
    classes: usize,
    /// On xmp: an independently built reference copy of every variant's
    /// deterministic model, keyed by variant name — responses are checked
    /// against `classify_one` of the copy that served them.
    xmp_refs: BTreeMap<String, XmpBackend>,
}

/// Build a [`Server`] hosting one variant per requested word-length. Each
/// variant's routing profile (paper accuracy, simulated fps) comes from the
/// cached holistic DSE on the exported ResNet-8-class topology, and that fps
/// also drives the variant's virtual-FPGA clock. Engine fallback order:
/// PJRT when compiled artifacts are loadable, otherwise the xmp
/// sliced-digit engine on synthetic LSQ weights — real integer arithmetic
/// either way. Mock backends (service times scaled to each design's
/// simulated frame time) remain available via `--backend mock`.
/// `--planned`: host the precision planner's emitted Pareto family (a
/// quick small-budget `planner::plan` run on the ResNet-8 topology)
/// instead of the uniform `--variants` list — every frontier point,
/// layerwise/channelwise plans included, executes on its own xmp backend.
fn build_planned_gateway(retry: RetryPolicy, fault: Option<&FaultArg>) -> Result<Gateway> {
    let base = resnet::resnet_small(1, 10);
    let cfg = RunConfig {
        slices: vec![2],
        ..RunConfig::default()
    };
    let pcfg = mpcnn::planner::PlannerConfig {
        wq_choices: vec![2, 8],
        beam_width: 8,
        max_evals: 4,
        ..mpcnn::planner::PlannerConfig::default()
    };
    let report = mpcnn::planner::plan(&base, &cfg, &pcfg)?;
    let xcfg = XmpConfig::default();
    let variants = mpcnn::planner::emit_variants(&report);
    if variants.is_empty() {
        bail!("plan frontier is empty — nothing to serve");
    }
    let mut xmp_refs = BTreeMap::new();
    let mut names = Vec::new();
    // Registered by hand (rather than through planner::xmp_family_server)
    // so one planned variant's factory can carry the fault wrapper and the
    // builder the retry policy.
    let mut builder = Server::builder().retry_policy(retry);
    for (i, v) in variants.into_iter().enumerate() {
        xmp_refs.insert(v.spec.name.clone(), XmpBackend::from_spec(&base, &v.spec, xcfg)?);
        names.push(v.spec.name.clone());
        let base2 = base.clone();
        let spec2 = v.spec.clone();
        let inner: Factory = Box::new(move || {
            Ok(Box::new(XmpBackend::from_spec(&base2, &spec2, xcfg)?)
                as Box<dyn InferenceBackend>)
        });
        let factory = match fault {
            Some(f) if f.targets(&v.spec.name, i) => f.wrap(inner),
            _ => inner,
        };
        builder = builder.variant_with_profile(v.spec, v.profile, v.batcher, factory);
    }
    if let Some(f) = fault {
        f.check_bound(&names)?;
    }
    Ok(Gateway {
        server: builder.build()?,
        testset: None,
        backend: BackendKind::Xmp,
        image_len: (base.input_hw * base.input_hw * base.input_channels) as usize,
        classes: base.classes as usize,
        xmp_refs,
    })
}

fn build_gateway(
    dir: &std::path::Path,
    wqs: &[u32],
    aq: u32,
    max_batch: usize,
    kind: BackendKind,
    retry: RetryPolicy,
    fault: Option<&FaultArg>,
) -> Result<Gateway> {
    if wqs.is_empty() {
        bail!("--variants must name at least one word-length");
    }
    if !(1..=8).contains(&aq) {
        bail!("--aq must be in 1..=8, got {aq}");
    }
    let manifest = mpcnn::runtime::Manifest::load(dir).ok();
    let testset = manifest.as_ref().and_then(|m| {
        let p = m.testset.clone()?;
        TestSet::load(dir.join(p)).ok()
    });
    let pjrt_ok = manifest
        .as_ref()
        .map(|m| Engine::with_manifest(m.clone()).is_ok())
        .unwrap_or(false);
    let backend = match kind {
        BackendKind::Auto => {
            // PJRT artifacts are compiled at 8-bit activations, so a
            // reduced --aq auto-resolves past them to the xmp engine —
            // the documented PJRT -> xmp fallback order, not an error.
            if pjrt_ok && aq == 8 {
                BackendKind::Pjrt
            } else {
                BackendKind::Xmp
            }
        }
        BackendKind::Pjrt if !pjrt_ok => {
            bail!(
                "--backend pjrt: no loadable artifacts in {} (missing manifest, or built \
                 without --features pjrt)",
                dir.display()
            )
        }
        k => k,
    };
    if aq != 8 && backend == BackendKind::Pjrt {
        // Only reachable with an explicit --backend pjrt.
        bail!(
            "--aq {aq}: compiled PJRT artifacts are exported at 8-bit activations; \
             activation word-length reduction needs --backend xmp (or mock)"
        );
    }
    let cfg = RunConfig::default();
    let base = resnet::resnet_small(1, 10);
    let (image_len, classes) = match backend {
        // The xmp engine executes the ResNet-8 topology itself; its input
        // geometry is authoritative.
        BackendKind::Xmp => ((base.input_hw * base.input_hw * base.input_channels) as usize, 10),
        _ => match (&manifest, &testset) {
            (Some(m), _) if !m.models.is_empty() => {
                let e = &m.models[0];
                (e.input_len() / e.batch, e.classes)
            }
            (_, Some(ts)) => (ts.h * ts.w * ts.c, 10),
            _ => (3072, 10),
        },
    };
    if backend == BackendKind::Pjrt {
        for &wq in wqs {
            if manifest.as_ref().unwrap().entries_for_wq(wq).is_empty() {
                bail!("wq={wq} is not exported in {}", dir.display());
            }
        }
    }
    // Drop the testset when its geometry doesn't match what the engine
    // executes (synthetic xmp weights have no use for mismatched images).
    let testset = testset.filter(|ts| ts.h * ts.w * ts.c == image_len);
    let mut xmp_refs = BTreeMap::new();
    let mut names = Vec::new();
    let mut builder = Server::builder().retry_policy(retry);
    for (i, &wq) in wqs.iter().enumerate() {
        let spec = VariantSpec::uniform_joint(wq, aq);
        let profile = VariantProfile::from_dse(&spec, &base, &cfg, "ResNet-18");
        let bc = BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(2),
            queue_capacity: 256,
            fpga_fps_sim: profile.fpga_fps,
            ..Default::default()
        };
        let inner: Factory = match backend {
            BackendKind::Pjrt => {
                let dir2 = dir.to_path_buf();
                Box::new(move || {
                    Ok(Box::new(EngineBackend::load(&dir2, wq)?) as Box<dyn InferenceBackend>)
                })
            }
            BackendKind::Xmp => {
                let xcfg = XmpConfig::default();
                xmp_refs.insert(
                    spec.name.clone(),
                    XmpBackend::from_spec(&base, &spec, xcfg)?,
                );
                let base2 = base.clone();
                let spec2 = spec.clone();
                Box::new(move || {
                    Ok(Box::new(XmpBackend::from_spec(&base2, &spec2, xcfg)?)
                        as Box<dyn InferenceBackend>)
                })
            }
            _ => {
                let latency_us = (1e6 / profile.fpga_fps.max(1.0)).clamp(100.0, 20_000.0) as u64;
                Box::new(move || {
                    Ok(Box::new(MockBackend::new(
                        image_len,
                        classes,
                        vec![1, max_batch.max(1)],
                        latency_us,
                    )) as Box<dyn InferenceBackend>)
                })
            }
        };
        names.push(spec.name.clone());
        let factory = match fault {
            Some(f) if f.targets(&spec.name, i) => f.wrap(inner),
            _ => inner,
        };
        builder = builder.variant_with_profile(spec, profile, bc, factory);
    }
    if let Some(f) = fault {
        f.check_bound(&names)?;
    }
    Ok(Gateway {
        server: builder.build()?,
        testset,
        backend,
        image_len,
        classes,
        xmp_refs,
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    let n_requests = args.get_usize("requests", 256);
    let max_batch = args.get_usize("batch", 8);
    let window = args.get_usize("window", 64).max(1);
    let default_wqs = match args.get("wq") {
        Some(_) => vec![args.get_u64("wq", 4) as u32],
        None => vec![2, 4, 8],
    };
    let wqs = args.get_list_u32("variants", &default_wqs);
    let aq = args.get_u64("aq", 8) as u32;
    let route_spec = args.get_or("route", "mixed");
    let kind = BackendKind::parse(&args.get_or("backend", "auto"))?;
    let planned = args.has_flag("planned");
    let retry = RetryPolicy::attempts(args.get_u64("retry", 1).min(16) as u32);
    let deadline_ms = args.get_u64("deadline", 0);
    let fault = match args.get("fault") {
        Some(spec) => {
            // Injected crashes are expected and fully accounted for in the
            // metrics; keep the console for the actual report.
            silence_injected_panics();
            Some(FaultArg::parse(&spec)?)
        }
        None => None,
    };

    let gw = if planned {
        if !matches!(kind, BackendKind::Auto | BackendKind::Xmp) {
            bail!("--planned hosts the planner family on xmp backends; use --backend xmp");
        }
        // The planner emits the family (and its batcher configs) itself.
        if args.get("variants").is_some() || args.get("batch").is_some()
            || args.get("artifacts").is_some() || args.get("aq").is_some()
        {
            eprintln!(
                "(--planned hosts the planner-emitted family with its own batcher \
                 configs; ignoring --variants/--aq/--batch/--artifacts)"
            );
        }
        build_planned_gateway(retry, fault.as_ref())?
    } else {
        build_gateway(&dir, &wqs, aq, max_batch, kind, retry, fault.as_ref())?
    };
    println!(
        "gateway up: {} variants {:?} on {} backends\n",
        gw.server.n_variants(),
        gw.server.variant_names(),
        gw.backend.label(),
    );
    if let Some(f) = &fault {
        let target = f
            .variant
            .clone()
            .unwrap_or_else(|| gw.server.variant_names()[0].clone());
        println!(
            "fault injection armed: scenario '{}' on variant '{target}' \
             (supervisor + circuit breaker keep the gateway serving)\n",
            f.scenario
        );
    }
    if gw.backend == BackendKind::Xmp {
        println!(
            "xmp: every variant verified fast path == scalar reference on its warm-up \
             probe; responses are checked against an independent model copy\n"
        );
    }

    if let Some(listen) = args.get("listen") {
        return serve_listen(args, gw, listen, fault.as_ref());
    }

    // Selector schedule, one per request in round-robin. `mixed` exercises
    // the whole routing surface; any explicit --route applies to every
    // request.
    let schedule: Vec<VariantSelector> = if route_spec == "mixed" && planned {
        // Planned family: round-robin every emitted frontier variant by
        // name (layerwise plans have no uniform wq to route Exact on).
        let mut s = vec![VariantSelector::Default];
        s.extend(
            gw.server
                .variant_names()
                .into_iter()
                .map(VariantSelector::Named),
        );
        s
    } else if route_spec == "mixed" {
        let mut s = vec![VariantSelector::Default];
        s.extend(wqs.iter().map(|&w| VariantSelector::Exact(w)));
        s.push(VariantSelector::MinAccuracy(87.0));
        s.push(VariantSelector::MaxLatency(Duration::from_millis(100)));
        s
    } else {
        vec![VariantSelector::parse(&route_spec).map_err(|e| anyhow!("{e}"))?]
    };

    // Per-request ground truth. Labels (testset index or the mock's
    // mean-class rule) are known at submit time; on xmp the expected class
    // depends on which variant answers, so the image rides along and is
    // re-classified by that variant's reference model copy at drain time.
    enum Truth {
        Label(usize),
        Image(Vec<f32>),
    }

    type Pending = (PendingResponse, Truth, VariantSelector, Vec<f32>);

    // Drain only *waits* on the oldest response inside the timed window;
    // correctness verification (which on xmp re-runs a full reference
    // forward per response) happens after the clock stops, so the printed
    // throughput measures the gateway, not the self-check. With --retry,
    // a failed response is re-driven through `Server::infer`, the
    // policy-aware path that re-routes onto the next-best healthy variant.
    let retry_on_fail = retry.max_attempts > 1;
    let mut retried_ok = 0usize;
    let mut drain = |inflight: &mut VecDeque<Pending>,
                     completed: &mut Vec<(mpcnn::serving::Response, Truth)>,
                     failed: &mut usize| {
        if let Some((p, truth, sel, img)) = inflight.pop_front() {
            match p.wait() {
                Ok(r) => completed.push((r, truth)),
                Err(_) if retry_on_fail => {
                    let mut req = InferRequest::new(img).with_variant(sel);
                    if deadline_ms > 0 {
                        req = req.with_deadline(Duration::from_millis(deadline_ms));
                    }
                    match gw.server.infer(req) {
                        Ok(r) => {
                            retried_ok += 1;
                            completed.push((r, truth));
                        }
                        Err(_) => *failed += 1,
                    }
                }
                Err(_) => *failed += 1,
            }
        }
    };

    let xmp = gw.backend == BackendKind::Xmp;
    let mut rng = Rng::new(42);
    let (mut failed, mut route_errors) = (0usize, 0usize);
    let mut inflight: VecDeque<Pending> = VecDeque::new();
    let mut completed: Vec<(mpcnn::serving::Response, Truth)> = Vec::with_capacity(n_requests);
    let started = std::time::Instant::now();
    for i in 0..n_requests {
        // Overlap submission with completion: only ever block on the oldest
        // pending response, and only when the window is full — no rigid
        // head-of-line drain waves.
        while inflight.len() >= window {
            drain(&mut inflight, &mut completed, &mut failed);
        }
        let (img, label) = match &gw.testset {
            Some(ts) => {
                let idx = rng.range(0, ts.n);
                (ts.image(idx).to_vec(), ts.labels[idx] as usize)
            }
            None => {
                let base = rng.range(0, gw.classes);
                (vec![base as f32; gw.image_len], base)
            }
        };
        let truth = if xmp {
            Truth::Image(img.clone())
        } else {
            Truth::Label(label)
        };
        let sel = schedule[i % schedule.len()].clone();
        let mut req = InferRequest::new(img.clone()).with_variant(sel.clone());
        if deadline_ms > 0 {
            req = req.with_deadline(Duration::from_millis(deadline_ms));
        }
        match gw.server.submit(req) {
            Ok(p) => inflight.push_back((p, truth, sel, img)),
            Err(e) => {
                route_errors += 1;
                if route_errors <= 3 {
                    eprintln!("(submit failed: {e})");
                }
            }
        }
    }
    while !inflight.is_empty() {
        drain(&mut inflight, &mut completed, &mut failed);
    }
    let wall = started.elapsed();

    // Post-window ledger: variant -> (correct, total).
    let mut per_variant: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    let mut correct = 0usize;
    let done = completed.len();
    for (r, truth) in &completed {
        let want = match truth {
            Truth::Label(l) => Some(*l),
            Truth::Image(img) => gw
                .xmp_refs
                .get(&r.variant)
                .and_then(|b| b.classify_one(img).ok()),
        };
        let e = per_variant.entry(r.variant.clone()).or_insert((0, 0));
        e.1 += 1;
        if want == Some(r.class) {
            e.0 += 1;
            correct += 1;
        }
    }

    let metric = if xmp { "reference-agreeing" } else { "correct" };
    print!("{}", gw.server.summary_table().render());
    println!();
    for (name, (c, n)) in &per_variant {
        println!(
            "  {name}: {c}/{n} = {:.2}% of its routed stream {metric}",
            100.0 * *c as f64 / (*n).max(1) as f64
        );
    }
    println!(
        "\ntotal: {done}/{n_requests} answered ({route_errors} unroutable, {failed} failed), \
         {} {:.2}%",
        if xmp { "reference agreement" } else { "accuracy" },
        100.0 * correct as f64 / done.max(1) as f64
    );
    println!(
        "client-side achieved throughput: {:.1} req/s over {:.2}s wall (route={route_spec})",
        done as f64 / wall.as_secs_f64().max(1e-9),
        wall.as_secs_f64()
    );

    // Robustness ledger: the same RobustnessReport the /metrics endpoint
    // renders, plus (if armed) the injector's own account of what it did.
    let r = gw.server.robustness_report();
    println!(
        "robustness: shed={} (expired-at-dequeue {}) panics={} \
         worker-restarts={} retried={} hedged={} hedge-wins={} fallbacks={} \
         client-retries-recovered={retried_ok}",
        r.shed, r.shed_expired, r.panics, r.worker_restarts, r.retried, r.hedged,
        r.hedge_wins, r.fallbacks
    );
    if let Some(f) = &fault {
        let c = &f.controls;
        println!(
            "fault '{}': {} backend calls seen, {} faults injected \
             (errors {}, panics {}, latency spikes {}, corruptions {})",
            f.scenario,
            c.calls(),
            c.injected_total(),
            c.injected_errors(),
            c.injected_panics(),
            c.injected_latency_spikes(),
            c.injected_corruptions(),
        );
    }
    Ok(())
}

/// `serve --listen ADDR`: host the gateway behind the network edge. The
/// edge owns the socket; the gateway keeps owning batching, routing,
/// retries, and supervision. On xmp the cacheability check re-classifies
/// each response against the independent reference model copy, so a
/// corrupt answer (e.g. from `--fault corrupt`) is served once, flagged
/// uncacheable, and never pinned into the response cache.
fn serve_listen(args: &Args, gw: Gateway, listen: &str, fault: Option<&FaultArg>) -> Result<()> {
    let run_for = args.get_u64("for", 0);
    let trace = args.has_flag("trace");
    // `--slo default` arms the built-in objective set; `--slo FILE` loads
    // a JSON spec (see SloSpec::from_json for the schema).
    let slo = match args.get("slo") {
        Some(spec) if spec == "default" => Some(mpcnn::obs::SloSpec::default_spec()),
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow!("--slo {path}: {e}"))?;
            Some(
                mpcnn::obs::SloSpec::from_json(&text)
                    .map_err(|e| anyhow!("--slo {path}: {e}"))?,
            )
        }
        None => None,
    };
    let slo_armed = slo.is_some();
    let cfg = EdgeConfig {
        handler_threads: args.get_usize("threads", 8).max(1),
        max_inflight: args.get_u64("max-inflight", 256),
        rate_per_sec: args.get_f64("rate", 1000.0),
        burst: args.get_f64("burst", 256.0),
        cache_capacity: args.get_usize("cache", 1024),
        trace,
        trace_capacity: args.get_usize("trace-capacity", 256),
        slow_trace_us: args.get_f64("slow-trace-us", 50_000.0),
        slo,
        sample_interval: Duration::from_millis(args.get_u64("sample-ms", 1000).max(10)),
        ..EdgeConfig::default()
    };
    let Gateway {
        server,
        image_len,
        xmp_refs,
        ..
    } = gw;

    // XmpBackend holds per-instance scratch (not Sync); a mutex per
    // reference copy lets the Send+Sync check closure share them across
    // handler threads.
    let check: Option<ResponseCheck> = if xmp_refs.is_empty() {
        None
    } else {
        let refs: Arc<BTreeMap<String, Mutex<XmpBackend>>> = Arc::new(
            xmp_refs
                .into_iter()
                .map(|(name, b)| (name, Mutex::new(b)))
                .collect(),
        );
        Some(Arc::new(move |image: &[f32], a: &mpcnn::edge::Answer| {
            match refs.get(&a.variant) {
                Some(b) => {
                    let b = b.lock().unwrap_or_else(|e| e.into_inner());
                    b.classify_one(image).map(|c| c == a.class).unwrap_or(false)
                }
                // No reference copy for this variant (pjrt/mock): trust it.
                None => true,
            }
        }))
    };

    let server = Arc::new(server);
    let edge = EdgeServer::bind(server.clone(), listen, cfg, check)?;
    if let Some(f) = fault {
        // Hand the injector's live controls to the edge so POST /v1/fault
        // can flip the forced override while the gateway keeps serving.
        edge.state().set_fault_controls(f.controls.clone());
    }
    println!("edge listening on http://{}", edge.local_addr());
    println!("  POST /v1/classify   {{\"image\":[f32; {image_len}], \"route\"?, \"deadline_ms\"?, \"client\"?}}");
    println!("  GET  /healthz       gateway + per-variant health");
    println!("  GET  /metrics       Prometheus text exposition");
    if trace {
        println!("  GET  /v1/trace      flight recorder index (recent + slow exemplars)");
        println!("  GET  /v1/trace/<id> one trace's spans as JSON (X-Trace-Id names it)");
        println!("  GET  /v1/trace/export  Chrome trace-event JSON (Perfetto-loadable)");
    }
    if slo_armed {
        println!("  GET  /v1/alerts     burn-rate alert board (pending/firing/resolved)");
        println!("  GET  /v1/events     operational event journal (JSONL)");
        println!("  GET  /v1/stats      windowed rates for `mpcnn top` (?window=30s)");
        if fault.is_some() {
            println!("  POST /v1/fault      {{\"force\":\"none|error|panic|corrupt\"}} live override");
        }
        println!("  (watch live: mpcnn top --remote http://{})", edge.local_addr());
    }
    match run_for {
        0 => {
            println!("serving until killed (pass --for SECS for a timed run)");
            loop {
                std::thread::sleep(Duration::from_secs(3600));
            }
        }
        secs => {
            println!("serving for {secs}s, then draining");
            std::thread::sleep(Duration::from_secs(secs));
        }
    }

    println!("\ndraining edge (stop admitting -> flush in-flight -> stop threads)...");
    let s = edge.shutdown();
    println!(
        "edge: {} requests ({} ok, {} client-err, {} server-err), p50 {:.0}us p99 {:.0}us",
        s.requests, s.ok, s.client_errors, s.server_errors, s.p50_us, s.p99_us
    );
    println!(
        "  shed: {} rate-limited (429), {} admission (503), {} at the socket queue",
        s.rate_limited, s.admission_shed, s.queue_shed
    );
    println!(
        "  cache: {} hits / {} misses, {} inserted, {} evicted, {} uncacheable",
        s.cache_hits, s.cache_misses, s.cache_insertions, s.cache_evictions, s.cache_uncacheable
    );
    println!(
        "  coalescing: {} led, {} rode an in-flight duplicate",
        s.coalesce_leaders, s.coalesce_joined
    );

    let server = Arc::try_unwrap(server)
        .map_err(|_| anyhow!("edge threads still hold the gateway after shutdown"))?;
    print!("{}", server.summary_table().render());
    let r = server.robustness_report();
    println!(
        "robustness: shed={} (expired-at-dequeue {}) panics={} worker-restarts={} \
         retried={} hedged={} hedge-wins={} fallbacks={}",
        r.shed, r.shed_expired, r.panics, r.worker_restarts, r.retried, r.hedged,
        r.hedge_wins, r.fallbacks
    );
    if let Some(f) = fault {
        let c = &f.controls;
        println!(
            "fault '{}': {} backend calls seen, {} faults injected \
             (errors {}, panics {}, latency spikes {}, corruptions {})",
            f.scenario,
            c.calls(),
            c.injected_total(),
            c.injected_errors(),
            c.injected_panics(),
            c.injected_latency_spikes(),
            c.injected_corruptions(),
        );
    }
    server.shutdown();
    Ok(())
}

/// `classify --remote http://ADDR`: drive a running `serve --listen` edge
/// over HTTP instead of booting a local gateway. Connection errors retry
/// under the same exponential-backoff policy the gateway uses internally.
fn classify_remote(args: &Args, remote: &str) -> Result<()> {
    let retry = RetryPolicy::attempts(args.get_u64("retry", 3).min(16) as u32);
    let client = RemoteClient::new(remote, retry);
    let image_len = args.get_usize("image-len", 3072);
    let classes = args.get_usize("classes", 10);
    let index = args.get_usize("index", 0);
    // The synthetic-image rule every hosted backend agrees on: a constant
    // image of value c classifies as c.
    let class = index % classes;
    let img = vec![class as f32; image_len];
    let deadline = args.get_u64("deadline", 0);
    let route = args.get("route");
    let a = client.classify(
        &img,
        route,
        (deadline > 0).then_some(deadline),
        args.get("client"),
    )?;
    println!(
        "remote {}: image {index} predicted class {} via variant '{}'{}{} (label {class})",
        client.addr(),
        a.class,
        a.variant,
        if a.cached { " [cached]" } else { "" },
        if a.coalesced { " [coalesced]" } else { "" },
    );
    Ok(())
}

fn cmd_classify(args: &Args) -> Result<()> {
    if let Some(remote) = args.get("remote") {
        return classify_remote(args, remote);
    }
    let dir = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    let wq = args.get_u64("wq", 4) as u32;
    let index = args.get_usize("index", 0);
    let wqs = args.get_list_u32("variants", &[wq]);
    let sel = match args.get("route") {
        Some(r) => VariantSelector::parse(r).map_err(|e| anyhow!("{e}"))?,
        // Pin to --wq only when it was given; `classify --variants 2,8`
        // without --wq must route to the hosted default, not Exact(4).
        None if args.get("wq").is_some() => VariantSelector::Exact(wq),
        None => VariantSelector::Default,
    };
    let kind = BackendKind::parse(&args.get_or("backend", "auto"))?;
    let aq = args.get_u64("aq", 8) as u32;
    let gw = build_gateway(&dir, &wqs, aq, 1, kind, RetryPolicy::default(), None)?;
    let (img, label) = match &gw.testset {
        Some(ts) => {
            if index >= ts.n {
                bail!("index {index} out of range (testset has {} images)", ts.n);
            }
            (ts.image(index).to_vec(), ts.labels[index] as usize)
        }
        None => {
            let class = index % gw.classes;
            (vec![class as f32; gw.image_len], class)
        }
    };
    let trace = if args.has_flag("trace") {
        mpcnn::obs::TraceHandle::start()
    } else {
        mpcnn::obs::TraceHandle::off()
    };
    let resp = gw
        .server
        .infer(
            InferRequest::new(img.clone())
                .with_variant(sel.clone())
                .with_trace(trace.clone()),
        )
        .map_err(|e| anyhow!("{e}"))?;
    println!(
        "image {index}: predicted class {} via variant '{}' (route {sel}, label {label}) \
         [{} backend]",
        resp.class,
        resp.variant,
        gw.backend.label(),
    );
    if let Some(probe) = gw.xmp_refs.get(&resp.variant) {
        // The served class must be the sliced-digit kernels' own answer:
        // re-run the image through an independently built copy.
        let want = probe.classify_one(&img)?;
        if want != resp.class {
            bail!("served class {} disagrees with the xmp reference ({want})", resp.class);
        }
        println!("xmp reference check: independent model copy agrees (class {want})");
    }
    if let Some(done) = trace.finish(std::time::Instant::now()) {
        print!("{}", span_table(&done).render());
    }
    Ok(())
}

/// Render a locally completed trace's spans as a console table.
fn span_table(done: &mpcnn::obs::CompletedTrace) -> mpcnn::util::table::Table {
    let mut t = mpcnn::util::table::Table::new(format!(
        "trace {} — {:.0}us end to end, {:.0}% span coverage",
        done.id,
        done.total_us,
        100.0 * done.coverage()
    ))
    .headers(&["span", "start us", "dur us", "tags"]);
    for s in &done.spans {
        let tags: Vec<String> = s.tags.iter().map(|(k, v)| format!("{k}={v}")).collect();
        t.row(vec![
            s.name.to_string(),
            format!("{:.0}", s.start_us),
            format!("{:.0}", s.dur_us),
            tags.join(" "),
        ]);
    }
    t
}

/// `trace --remote http://ADDR`: inspect a running `serve --listen --trace`
/// edge's flight recorder over HTTP.
fn cmd_trace(args: &Args) -> Result<()> {
    let Some(remote) = args.get("remote") else {
        bail!("trace needs --remote http://ADDR (a `serve --listen --trace` edge)");
    };
    let retry = RetryPolicy::attempts(args.get_u64("retry", 3).min(16) as u32);
    let client = RemoteClient::new(&remote, retry);

    if let Some(out) = args.get("out") {
        let (status, body) = client.get("/v1/trace/export")?;
        if status != 200 {
            bail!("GET /v1/trace/export -> {status}: {}", body.trim());
        }
        let events = mpcnn::util::json::parse(&body)
            .ok()
            .and_then(|j| j.get("traceEvents").and_then(|v| v.as_arr()).map(<[_]>::len))
            .unwrap_or(0);
        std::fs::write(&out, &body)?;
        println!(
            "wrote {out}: {events} trace events from {} (load in Perfetto or chrome://tracing)",
            client.addr()
        );
        return Ok(());
    }

    if let Some(id) = args.get("id") {
        let (status, body) = client.get(&format!("/v1/trace/{id}"))?;
        if status != 200 {
            bail!("GET /v1/trace/{id} -> {status}: {}", body.trim());
        }
        let j = mpcnn::util::json::parse(&body).map_err(|e| anyhow!("bad trace JSON: {e}"))?;
        let total = j.get("total_us").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let coverage = j.get("coverage").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let mut t = mpcnn::util::table::Table::new(format!(
            "trace {id} — {total:.0}us end to end, {:.0}% span coverage",
            100.0 * coverage
        ))
        .headers(&["span", "start us", "dur us", "tags"]);
        for s in j.get("spans").and_then(|v| v.as_arr()).unwrap_or(&[]) {
            let tags = match s.get("tags") {
                Some(mpcnn::util::json::Json::Obj(m)) => m
                    .iter()
                    .map(|(k, v)| format!("{k}={}", v.as_str().unwrap_or("?")))
                    .collect::<Vec<String>>()
                    .join(" "),
                _ => String::new(),
            };
            t.row(vec![
                s.get("name").and_then(|v| v.as_str()).unwrap_or("?").to_string(),
                format!("{:.0}", s.get("start_us").and_then(|v| v.as_f64()).unwrap_or(0.0)),
                format!("{:.0}", s.get("dur_us").and_then(|v| v.as_f64()).unwrap_or(0.0)),
                tags,
            ]);
        }
        print!("{}", t.render());
        return Ok(());
    }

    let (status, body) = client.get("/v1/trace")?;
    if status != 200 {
        bail!("GET /v1/trace -> {status}: {}", body.trim());
    }
    let j = mpcnn::util::json::parse(&body).map_err(|e| anyhow!("bad trace index: {e}"))?;
    let recorded = j.get("recorded").and_then(|v| v.as_u64()).unwrap_or(0);
    let pinned = j.get("slow_pinned").and_then(|v| v.as_u64()).unwrap_or(0);
    println!(
        "flight recorder at {}: {recorded} traces recorded, {pinned} slow exemplars pinned",
        client.addr()
    );
    let mut t = mpcnn::util::table::Table::new("recent traces (newest first)").headers(&[
        "id", "total us", "spans", "slow",
    ]);
    for r in j.get("recent").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        t.row(vec![
            r.get("id").and_then(|v| v.as_u64()).unwrap_or(0).to_string(),
            format!("{:.0}", r.get("total_us").and_then(|v| v.as_f64()).unwrap_or(0.0)),
            r.get("spans").and_then(|v| v.as_u64()).unwrap_or(0).to_string(),
            if r.get("slow").and_then(|v| v.as_bool()).unwrap_or(false) {
                "yes".to_string()
            } else {
                String::new()
            },
        ]);
    }
    print!("{}", t.render());
    println!("fetch one with `mpcnn trace --remote http://{} --id N`", client.addr());
    Ok(())
}

/// `top --remote http://ADDR`: live operational console over a
/// `serve --listen --slo` edge. The edge does the math (windowed counter
/// deltas over its time-series ring, burn-rate evaluation); this client
/// only polls `/v1/stats` + `/v1/alerts` and redraws the tables.
fn cmd_top(args: &Args) -> Result<()> {
    let Some(remote) = args.get("remote") else {
        bail!("top needs --remote http://ADDR (a `serve --listen --slo` edge)");
    };
    let retry = RetryPolicy::attempts(args.get_u64("retry", 3).min(16) as u32);
    let client = RemoteClient::new(&remote, retry);
    let window = args.get_or("window", "30s");
    let interval = Duration::from_millis(args.get_u64("interval", 2000).max(100));
    let once = args.has_flag("once");
    loop {
        let frame = top_frame(&client, &window)?;
        if once {
            print!("{frame}");
            return Ok(());
        }
        // Cursor-home + clear-to-end: redraw in place without scrollback
        // spam; the frame always ends shorter than a terminal screen.
        print!("\x1b[H\x1b[J{frame}");
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        std::thread::sleep(interval);
    }
}

/// Render one `top` frame (header, per-variant table, alert board).
fn top_frame(client: &RemoteClient, window: &str) -> Result<String> {
    use mpcnn::util::json::Json;
    use std::fmt::Write as _;

    let (status, body) = client.get(&format!("/v1/stats?window={window}"))?;
    if status != 200 {
        bail!("GET /v1/stats -> {status}: {}", body.trim());
    }
    let j = mpcnn::util::json::parse(&body).map_err(|e| anyhow!("bad stats JSON: {e}"))?;
    let num = |o: Option<&Json>, k: &str| -> f64 {
        o.and_then(|v| v.get(k)).and_then(|v| v.as_f64()).unwrap_or(0.0)
    };

    let mut out = String::new();
    let samples = j.get("samples").and_then(|v| v.as_u64()).unwrap_or(0);
    if !j.get("ready").and_then(|v| v.as_bool()).unwrap_or(false) {
        let _ = writeln!(
            out,
            "mpcnn top — {} — warming up ({samples} samples retained, need 2)",
            client.addr()
        );
        return Ok(out);
    }
    let win_s = j.get("window_us").and_then(|v| v.as_f64()).unwrap_or(0.0) / 1e6;
    let edge = j.get("edge");
    let gw = j.get("gateway");
    let _ = writeln!(
        out,
        "mpcnn top — {} — last {win_s:.0}s ({samples} samples retained)",
        client.addr()
    );
    let _ = writeln!(
        out,
        "edge: {:.1} req/s | ok {:.0} | 4xx {:.0} | 5xx {:.0} | 429 {:.0} | shed {:.0} | \
         cache hits {:.0} | negative hits {:.0} | agreement {:.0}/{:.0} failed",
        num(edge, "rps"),
        num(edge, "ok"),
        num(edge, "client_errors"),
        num(edge, "server_errors"),
        num(edge, "rate_limited"),
        num(edge, "admission_shed"),
        num(edge, "cache_hits"),
        num(edge, "negative_hits"),
        num(edge, "agreement_failures"),
        num(edge, "agreement_checks"),
    );
    let _ = writeln!(
        out,
        "gateway: shed {:.0} | panics {:.0} | worker restarts {:.0} | retried {:.0} | \
         hedged {:.0} | fallbacks {:.0}",
        num(gw, "shed"),
        num(gw, "panics"),
        num(gw, "worker_restarts"),
        num(gw, "retried"),
        num(gw, "hedged"),
        num(gw, "fallbacks"),
    );

    let mut t = mpcnn::util::table::Table::new(format!("variants over the last {win_s:.0}s"))
        .headers(&[
            "variant", "req/s", "resp", "err", "shed", "restarts", "p50 us", "p99 us",
            "q p99 us", "ewma us", "fps", "breaker", "health",
        ]);
    for v in j.get("variants").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        let v = Some(v);
        t.row(vec![
            v.and_then(|x| x.get("name")).and_then(|x| x.as_str()).unwrap_or("?").to_string(),
            format!("{:.1}", num(v, "rps")),
            format!("{:.0}", num(v, "responses")),
            format!("{:.0}", num(v, "errors")),
            format!("{:.0}", num(v, "shed")),
            format!("{:.0}", num(v, "worker_restarts")),
            format!("{:.0}", num(v, "p50_us")),
            format!("{:.0}", num(v, "p99_us")),
            format!("{:.0}", num(v, "queue_p99_us")),
            format!("{:.0}", num(v, "ewma_us")),
            format!("{:.1}", num(v, "fpga_fps")),
            v.and_then(|x| x.get("breaker")).and_then(|x| x.as_str()).unwrap_or("?").to_string(),
            v.and_then(|x| x.get("health")).and_then(|x| x.as_str()).unwrap_or("?").to_string(),
        ]);
    }
    out.push_str(&t.render());

    let (status, body) = client.get("/v1/alerts")?;
    if status != 200 {
        bail!("GET /v1/alerts -> {status}: {}", body.trim());
    }
    let a = mpcnn::util::json::parse(&body).map_err(|e| anyhow!("bad alerts JSON: {e}"))?;
    let firing: Vec<&str> = a
        .get("firing")
        .and_then(|v| v.as_arr())
        .map(|arr| arr.iter().filter_map(|v| v.as_str()).collect())
        .unwrap_or_default();
    let title = if firing.is_empty() {
        "SLO alerts — all quiet".to_string()
    } else {
        format!("SLO alerts — {} FIRING: {}", firing.len(), firing.join(", "))
    };
    let mut t = mpcnn::util::table::Table::new(title).headers(&[
        "alert", "kind", "variant", "state", "fast burn", "slow burn", "flips", "detail",
    ]);
    for al in a.get("alerts").and_then(|v| v.as_arr()).unwrap_or(&[]) {
        let al = Some(al);
        t.row(vec![
            al.and_then(|x| x.get("name")).and_then(|x| x.as_str()).unwrap_or("?").to_string(),
            al.and_then(|x| x.get("kind")).and_then(|x| x.as_str()).unwrap_or("?").to_string(),
            al.and_then(|x| x.get("variant")).and_then(|x| x.as_str()).unwrap_or("-").to_string(),
            al.and_then(|x| x.get("state")).and_then(|x| x.as_str()).unwrap_or("?").to_string(),
            format!("{:.2}", num(al, "fast_burn")),
            format!("{:.2}", num(al, "slow_burn")),
            format!("{:.0}", num(al, "transitions")),
            al.and_then(|x| x.get("detail")).and_then(|x| x.as_str()).unwrap_or("").to_string(),
        ]);
    }
    out.push_str(&t.render());
    Ok(out)
}

/// `profile`: measured-host vs virtual-FPGA per-layer attribution. One
/// image runs through the xmp kernels with the stage-timing sink on, then
/// the accelerator simulator models the same planned network so every conv
/// layer shows both its measured host microseconds and its modeled cycles.
fn cmd_profile(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let name = args.get_or("cnn", "resnet18");
    let base = resnet::by_name(&name).ok_or_else(|| anyhow!("unknown CNN '{name}'"))?;
    let wq = args.get_u64("wq", 4) as u32;
    let aq = args.get_u64("aq", 8) as u32;
    let k = args.get_u64("k", 2) as u32;
    let spec = VariantSpec::uniform_joint(wq, aq);
    let backend = XmpBackend::from_spec(&base, &spec, XmpConfig::default())?;
    let image_len = (base.input_hw * base.input_hw * base.input_channels) as usize;
    let (_logits, mut prof) = backend.profile_forward(&vec![0.5f32; image_len])?;

    // Modeled side: the DSE's chosen array for this slice width, simulated
    // on the same uniformly planned network (first/last layers pin to 8
    // bits in both the xmp spec and the plan, so layer wq tags line up).
    let planned = base.with_uniform_wq(wq);
    let out = dse::explore_k(&planned, &cfg, k);
    let design = sim::AcceleratorDesign::new(
        mpcnn::pe::PeDesign::bp_st_1d(k),
        out.array.dims,
        &planned,
        &cfg,
    );
    let matched = prof.attach_sim(&sim::simulate(&planned, &design));

    if args.has_flag("json") {
        println!("{}", prof.to_json().to_string_pretty());
    } else {
        print!("{}", prof.table().render());
        println!(
            "\n{matched}/{} layers matched a modeled schedule; host total {:.0}us vs \
             modeled FPGA total {:.0}us (BP-ST-1D k={k} @ {})",
            prof.layers.len(),
            prof.total_host_us(),
            prof.total_fpga_us(),
            out.array.dims,
        );
        if !prof.conv_layers_attributed() {
            bail!("attribution incomplete: a conv layer is missing host time or modeled cycles");
        }
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    let mut t = mpcnn::util::table::Table::new("built-in CNNs").headers(&[
        "name", "input", "layers", "GMACs (conv)", "params (M)", "peak act Mb",
    ]);
    for name in ["resnet8", "resnet20", "resnet18", "resnet34", "resnet50", "resnet101", "resnet152"] {
        let c = resnet::by_name(name).unwrap();
        t.row(vec![
            c.name.clone(),
            format!("{0}x{0}x{1}", c.input_hw, c.input_channels),
            c.layers.len().to_string(),
            format!("{:.2}", c.conv_macs() as f64 / 1e9),
            format!("{:.2}", c.total_params() as f64 / 1e6),
            format!("{:.2}", c.peak_activation_bits() as f64 / 1e6),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
