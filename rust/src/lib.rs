//! `mpcnn` — Mixed-Precision CNN Accelerator DSE, Simulator & Serving Stack.
//!
//! Reproduction of Latotzke, Ciesielski & Gemmeke, *"Design of
//! High-Throughput Mixed-Precision CNN Accelerators on FPGA"*, FPL 2022.
//!
//! # Architecture (three layers)
//!
//! - **L1** (`python/compile/kernels/`): the bit-sliced BP-ST-1D MAC datapath
//!   as a Pallas kernel, AOT-lowered to HLO.
//! - **L2** (`python/compile/model.py`): quantized ResNets in JAX, trained
//!   with LSQ QAT, exported to `artifacts/*.hlo.txt`.
//! - **L3** (this crate): the paper's design-space exploration
//!   ([`pe`], [`array`], [`dataflow`], [`dse`]), the FPGA accelerator
//!   simulator ([`sim`], [`energy`]), the precision [`planner`] that
//!   searches layer/channel-wise word-length assignments and emits the
//!   Pareto variant family, the [`xmp`] truly-mixed-precision execution
//!   engine (a software PE array whose inner MAC is the sliced-digit
//!   datapath of Fig 1b), and a multi-variant serving gateway
//!   ([`serving`]) that batches requests and routes them across
//!   mixed-precision model variants — executing AOT artifacts via PJRT
//!   ([`runtime`]) when available, the xmp engine otherwise — and a
//!   network [`edge`]: an HTTP front-end adding admission control,
//!   identical-request coalescing, a content-addressed response cache,
//!   and a Prometheus metrics endpoint over the gateway.
//!
//! Start at [`dse`] for the headline methodology, [`sim`] for the
//! system-level model behind Table IV / Fig 9, [`planner`] for the
//! automated precision assignment, [`xmp`] for the executable sliced-digit
//! kernels, or [`serving`] for the trade-off curve deployed as a request
//! router.

pub mod array;
pub mod baselines;
pub mod cnn;
pub mod config;
pub mod dataflow;
pub mod dse;
pub mod edge;
pub mod energy;
pub mod obs;
pub mod pe;
pub mod planner;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod util;
pub mod xmp;
