//! Reproduction reporting: paper reference constants ([`paper`]) and
//! table/figure renderers ([`tables`]) that print paper-vs-ours side by side
//! with automated shape checks.

pub mod paper;
pub mod tables;

/// One qualitative reproduction check ("who wins / by roughly what factor /
/// where the crossover falls").
#[derive(Clone, Debug)]
pub struct ShapeCheck {
    pub name: String,
    pub pass: bool,
    pub detail: String,
}

impl ShapeCheck {
    pub fn new(name: impl Into<String>, pass: bool, detail: impl Into<String>) -> Self {
        ShapeCheck {
            name: name.into(),
            pass,
            detail: detail.into(),
        }
    }
}

/// Shared driver for the paper-table benches (`rust/benches/*`, all
/// `harness = false`): render the table once, print the shape checks, then
/// time the generator with the mini-bench harness.
pub fn run_table_bench<F>(name: &str, mut f: F)
where
    F: FnMut() -> (crate::util::table::Table, Vec<ShapeCheck>),
{
    let (table, checks) = f();
    println!("{}", table.render());
    print!("{}", render_checks(&checks));
    let mut b = crate::util::bench::Bencher::new();
    b.run(&format!("{name}::generate"), || f());
    b.finish(name);
    let failed = checks.iter().filter(|c| !c.pass).count();
    if failed > 0 {
        eprintln!("WARNING: {failed} shape checks failed in {name}");
        std::process::exit(1);
    }
}

/// Render shape checks as a compact pass/fail block.
pub fn render_checks(checks: &[ShapeCheck]) -> String {
    let mut out = String::new();
    for c in checks {
        out.push_str(&format!(
            "  [{}] {} — {}\n",
            if c.pass { "PASS" } else { "FAIL" },
            c.name,
            c.detail
        ));
    }
    let passed = checks.iter().filter(|c| c.pass).count();
    out.push_str(&format!("  {}/{} shape checks passed\n", passed, checks.len()));
    out
}
