//! Renderers for every table and figure of the paper's evaluation, printing
//! the paper's values next to ours and running automated shape checks.
//!
//! Each `figN`/`tableN` function is the single source of truth consumed by
//! both the corresponding bench (`rust/benches/`) and `mpcnn tables`.

use super::paper;
use super::ShapeCheck;
use crate::array::{bram_npa, Dims};
use crate::cnn::{resnet, workload};
use crate::config::RunConfig;
use crate::dse;
use crate::energy::{dsp_scaling_factor, ideal_scaling_factor};
use crate::pe::dse::{evaluate_all, fig3_series, fig7_series};
use crate::pe::PeDesign;
use crate::sim::{simulate, AcceleratorDesign, SimResult};
use crate::util::table::{fnum, Table};

/// Fig 3: DSP multiply energy vs weight word-length.
pub fn fig3() -> (Table, Vec<ShapeCheck>) {
    let mut t = Table::new("Fig 3 — DSP multiply energy vs weight word-length (acts 8 bit)")
        .headers(&["w_Q (bit)", "actual (norm.)", "linear scaling", "gap"]);
    for (w, actual, ideal) in fig3_series() {
        t.row(vec![
            w.to_string(),
            fnum(actual, 3),
            fnum(ideal, 3),
            fnum(actual / ideal, 2),
        ]);
    }
    t.note("paper: 8->1 bit gives only 0.58x energy instead of ideal 0.125x");
    let checks = vec![
        ShapeCheck::new(
            "fig3.saturation",
            (dsp_scaling_factor(1) - 0.58).abs() < 0.01,
            format!("E(1)/E(8) = {:.3} (paper 0.58)", dsp_scaling_factor(1)),
        ),
        ShapeCheck::new(
            "fig3.above-linear",
            (1..8).all(|w| dsp_scaling_factor(w) > ideal_scaling_factor(w)),
            "actual curve above the linear-scaling line everywhere",
        ),
    ];
    (t, checks)
}

/// Fig 6: the PE DSE scatter — bits/s/LUT for every design point.
pub fn fig6(cfg: &RunConfig) -> (Table, Vec<ShapeCheck>) {
    let mut t = Table::new("Fig 6 — PE efficiency (processed bits/s/LUT), acts 8 bit")
        .headers(&["design", "LUTs", "fmax MHz", "wq=1", "wq=2", "wq=4", "wq=8"]);
    let evals = evaluate_all(&cfg.slices, &cfg.weight_bits);
    let mut designs: Vec<PeDesign> = Vec::new();
    for e in &evals {
        if !designs.contains(&e.design) {
            designs.push(e.design);
        }
    }
    for d in &designs {
        let per_wq: Vec<String> = cfg
            .weight_bits
            .iter()
            .map(|wq| {
                let e = evals
                    .iter()
                    .find(|e| e.design == *d && e.wq == *wq)
                    .unwrap();
                fnum(e.bits_per_s_per_lut / 1e6, 2)
            })
            .collect();
        let e0 = evals.iter().find(|e| e.design == *d).unwrap();
        let mut row = vec![d.tag(), fnum(e0.luts, 0), fnum(e0.fmax_mhz, 0)];
        row.extend(per_wq);
        t.row(row);
    }
    t.note("values in Mbit/s/LUT; paper's winner: BP-ST-1D for all asymmetric word-lengths");
    let mut checks = Vec::new();
    for wq in [1u32, 2, 4] {
        let best = crate::pe::dse::best_for(&cfg.slices, wq);
        checks.push(ShapeCheck::new(
            format!("fig6.winner.wq{wq}"),
            best.design == PeDesign::bp_st_1d(best.design.k),
            format!("best at wq={wq}: {}", best.design),
        ));
        checks.push(ShapeCheck::new(
            format!("fig6.k-tracks-wq{wq}"),
            if wq == 1 { best.design.k <= 2 } else { best.design.k == wq },
            format!("best k = {} for wq={wq} (k=2 near-tie accepted at wq=1, cf. §IV-C)", best.design.k),
        ));
    }
    (t, checks)
}

/// Fig 7: energy efficiency of BP-ST-1D per operand slice, vs DSP.
pub fn fig7(cfg: &RunConfig) -> (Table, Vec<ShapeCheck>) {
    let mut t = Table::new("Fig 7 — energy efficiency normalized to 8x8 (per solution and per bit)")
        .headers(&["point", "solution-norm.", "bit-norm."]);
    let rows = fig7_series(&cfg.slices);
    for r in &rows {
        t.row(vec![
            r.label.clone(),
            fnum(r.solution_normalized, 2),
            fnum(r.bit_normalized, 2),
        ]);
    }
    let r22 = rows.iter().find(|r| !r.is_dsp && r.k == 2 && r.wq == 2).unwrap();
    let checks = vec![
        ShapeCheck::new(
            "fig7.8x2-vs-8x8",
            (1.8..2.3).contains(&r22.solution_normalized),
            format!("8x2 gain {:.2}x (paper 2.1x)", r22.solution_normalized),
        ),
        ShapeCheck::new(
            "fig7.dsp-advantage",
            (crate::energy::e_lut_mac8_pj() / crate::energy::e_dsp_mac8_pj() - 1.7).abs() < 0.01,
            "DSP 1.7x more efficient at equal word-length",
        ),
    ];
    (t, checks)
}

/// Fig 8: BRAM_NPA vs PE-array dimensions at k=4, all inputs 8 bit.
pub fn fig8() -> (Table, Vec<ShapeCheck>) {
    let mut t = Table::new("Fig 8 — parallel BRAM accesses vs PE array dimensions (k=4, 8-bit)")
        .headers(&["N_PE", "dims (sym)", "BRAM sym", "dims (asym)", "BRAM asym", "Eq4 bound"]);
    let mut all_ok = true;
    for s in [4u32, 6, 8, 10, 12] {
        let n_pe = (s * s * s) as u64;
        let sym = Dims::new(s, s, s);
        // a representative asymmetric split of the same N_PE
        let asym = Dims::new(s * s, s, 1);
        let b_sym = bram_npa(sym, 8, 8);
        let b_asym = bram_npa(asym, 8, 8);
        all_ok &= b_sym <= b_asym;
        t.row(vec![
            n_pe.to_string(),
            sym.to_string(),
            b_sym.to_string(),
            asym.to_string(),
            b_asym.to_string(),
            fnum(crate::array::min_bram_npa_symmetric(n_pe), 0),
        ]);
    }
    let checks = vec![ShapeCheck::new(
        "fig8.symmetric-minimizes",
        all_ok,
        "symmetric dims always need fewer parallel BRAMs (Eq 4)",
    )];
    (t, checks)
}

/// Table II: chosen PE array dimensions from our array DSE vs the paper's.
pub fn table2(cfg: &RunConfig) -> (Table, Vec<ShapeCheck>) {
    let mut t = Table::new("Table II — chosen PE array dimensions")
        .headers(&["CNN", "k", "paper HxWxD", "paper N_PE", "ours HxWxD", "ours N_PE", "ours fps"]);
    let mut checks = Vec::new();
    for (cnn_name, build) in [
        ("ResNet-18", resnet::resnet18 as fn() -> crate::cnn::Cnn),
        ("ResNet-50/152", resnet::resnet50),
    ] {
        for &k in &cfg.slices {
            let cnn = build().with_uniform_wq(8);
            let out = dse::explore_k_cached(&cnn, cfg, k, dse::DseCache::global());
            let p = paper::TABLE2
                .iter()
                .find(|r| r.cnn == cnn_name && r.k == k)
                .unwrap();
            t.row(vec![
                cnn_name.to_string(),
                k.to_string(),
                format!("{}x{}x{}", p.h, p.w, p.d),
                p.n_pe.to_string(),
                out.array.dims.to_string(),
                out.array.n_pe.to_string(),
                fnum(out.sim.fps, 1),
            ]);
            // Our exhaustive search saturates the LUT budget; the paper's
            // k=2/k=4 arrays stopped short of it (243.9-327.7 kLUT of a
            // ~400 kLUT budget), so we accept up to +50 % N_PE while still
            // requiring the same regime and ordering (see EXPERIMENTS.md
            // §Deviations).
            let rel = (out.array.n_pe as f64 - p.n_pe as f64).abs() / p.n_pe as f64;
            checks.push(ShapeCheck::new(
                format!("table2.{cnn_name}.k{k}.npe"),
                rel < 0.50,
                format!("N_PE {} vs paper {} ({:+.0}%)", out.array.n_pe, p.n_pe, rel * 100.0),
            ));
            // H must tile the dominant 56-px stage exactly (7, 8, 14, 28 …
            // all qualify; the paper picked 7).
            checks.push(ShapeCheck::new(
                format!("table2.{cnn_name}.k{k}.h-tiles"),
                56 % out.array.dims.h == 0 || out.array.dims.h % 7 == 0,
                format!("H={} tiles the 56-px ResNet stages", out.array.dims.h),
            ));
        }
    }
    (t, checks)
}

/// Table III: accuracy vs memory footprint (our first-principles footprint
/// next to the paper's reported values).
pub fn table3() -> (Table, Vec<ShapeCheck>) {
    let mut t = Table::new("Table III — accuracy vs memory footprint")
        .headers(&[
            "CNN", "wq", "paper MB", "paper comp.", "ours wt MB", "ours comp.", "Top-1*", "Top-5*",
        ]);
    let mut checks = Vec::new();
    for (name, build) in [
        ("ResNet-18", resnet::resnet18 as fn() -> crate::cnn::Cnn),
        ("ResNet-50", resnet::resnet50),
        ("ResNet-152", resnet::resnet152),
    ] {
        let mut comps = Vec::new();
        for wq in [0u32, 1, 2, 4] {
            let p = paper::TABLE3
                .iter()
                .find(|r| r.cnn == name && r.wq == wq)
                .unwrap();
            let (wt_mb, comp) = if wq == 0 {
                let net = build();
                (workload::footprint_fp32(&net).weight_mb(), 1.0)
            } else {
                let net = build().with_uniform_wq(wq);
                (
                    workload::footprint(&net).weight_mb(),
                    workload::weight_compression_factor(&net),
                )
            };
            comps.push((wq, comp));
            t.row(vec![
                name.to_string(),
                if wq == 0 { "FP".into() } else { wq.to_string() },
                fnum(p.footprint_mb, 0),
                fnum(p.compression, 1),
                fnum(wt_mb, 1),
                fnum(comp, 1),
                fnum(p.top1, 2),
                fnum(p.top5, 2),
            ]);
        }
        t.sep();
        // shape: compression monotone decreasing in wq
        let mono = comps.windows(2).skip(1).all(|w| w[0].1 >= w[1].1);
        checks.push(ShapeCheck::new(
            format!("table3.{name}.monotone"),
            mono,
            "compression decreases with wq",
        ));
    }
    // depth effect at wq=2
    let c50 = workload::weight_compression_factor(&resnet::resnet50().with_uniform_wq(2));
    let c152 = workload::weight_compression_factor(&resnet::resnet152().with_uniform_wq(2));
    checks.push(ShapeCheck::new(
        "table3.depth-compresses-more",
        c152 > c50,
        format!("w2: ResNet-152 {c152:.1}x > ResNet-50 {c50:.1}x (paper: 9.4 > 5.6)"),
    ));
    t.note("* accuracies are the paper's ImageNet QAT results; our small-scale QAT ordering check lives in EXPERIMENTS.md");
    t.note("paper's absolute MB column uses a different (unstated) accounting — see DESIGN.md §8");
    (t, checks)
}

/// The paper's Table II array geometries, used to make Table IV directly
/// comparable.
fn paper_dims_resnet18(k: u32) -> Dims {
    match k {
        1 => Dims::new(7, 3, 32),
        2 => Dims::new(7, 5, 37),
        4 => Dims::new(7, 4, 66),
        _ => panic!("paper has no ResNet-18 design for k={k}"),
    }
}

/// Simulate a Table IV column (ResNet-18 on the paper's k-design).
pub fn table4_column(k: u32, wq: u32, cfg: &RunConfig) -> SimResult {
    let cnn = resnet::resnet18().with_uniform_wq(wq);
    let design = AcceleratorDesign::new(PeDesign::bp_st_1d(k), paper_dims_resnet18(k), &cnn, cfg);
    simulate(&cnn, &design)
}

/// Table IV: impact of operand slices processing ResNet-18.
pub fn table4(cfg: &RunConfig) -> (Table, Vec<ShapeCheck>) {
    let mut t = Table::new("Table IV — impact of operand slices, ResNet-18 (paper / ours)")
        .headers(&[
            "metric", "k=1 w8", "k=2 w8", "k=4 w8", "k=1 w1", "k=2 w2", "k=4 w4",
        ]);
    let cols: Vec<(paper::Table4Col, SimResult)> = paper::TABLE4
        .iter()
        .map(|p| (*p, table4_column(p.k, p.wq, cfg)))
        .collect();
    let row = |label: &str, f: &dyn Fn(&(paper::Table4Col, SimResult)) -> String| {
        let mut r = vec![label.to_string()];
        r.extend(cols.iter().map(f));
        r
    };
    t.row(row("kLUT (paper)", &|(p, _)| fnum(p.kluts, 1)));
    t.row(row("kLUT (ours)", &|(_, s)| fnum(s.kluts, 1)));
    t.row(row("BRAM (paper)", &|(p, _)| p.brams.to_string()));
    t.row(row("BRAM (ours)", &|(_, s)| s.brams.to_string()));
    t.row(row("f MHz (paper)", &|(p, _)| fnum(p.f_mhz, 0)));
    t.row(row("f MHz (ours)", &|(_, s)| fnum(s.fmhz, 0)));
    t.sep();
    t.row(row("E_comp mJ (paper)", &|(p, _)| fnum(p.e_comp_mj, 2)));
    t.row(row("E_comp mJ (ours)", &|(_, s)| fnum(s.e_comp_mj, 2)));
    t.row(row("E_bram mJ (paper)", &|(p, _)| fnum(p.e_bram_mj, 2)));
    t.row(row("E_bram mJ (ours)", &|(_, s)| fnum(s.e_bram_mj, 2)));
    t.row(row("E_ddr mJ (paper)", &|(p, _)| fnum(p.e_ddr_mj, 2)));
    t.row(row("E_ddr mJ (ours)", &|(_, s)| fnum(s.e_ddr_mj, 2)));
    t.row(row("E_total mJ (paper)", &|(p, _)| fnum(p.e_total_mj, 2)));
    t.row(row("E_total mJ (ours)", &|(_, s)| fnum(s.e_total_mj(), 2)));
    t.sep();
    t.row(row("frames/s (paper)", &|(p, _)| fnum(p.fps, 2)));
    t.row(row("frames/s (ours)", &|(_, s)| fnum(s.fps, 2)));
    t.row(row("GOps/s (paper)", &|(p, _)| fnum(p.gops, 1)));
    t.row(row("GOps/s (ours)", &|(_, s)| fnum(s.gops, 1)));

    let ours_e8: f64 = cols[0].1.e_total_mj();
    let ours_e1: f64 = cols[3].1.e_total_mj();
    let fps_ok = cols
        .iter()
        .all(|(p, s)| (s.fps - p.fps).abs() / p.fps < 0.30);
    let checks = vec![
        ShapeCheck::new(
            "table4.fps-within-30pct",
            fps_ok,
            "all six fps columns within 30% of paper",
        ),
        ShapeCheck::new(
            "table4.energy-reduction-6.36x",
            (4.5..9.0).contains(&(ours_e8 / ours_e1)),
            format!("k=1: E(w8)/E(w1) = {:.2}x (paper 6.36x)", ours_e8 / ours_e1),
        ),
        ShapeCheck::new(
            "table4.wq8-fps-order",
            cols[0].1.fps < cols[1].1.fps && cols[1].1.fps < cols[2].1.fps,
            "at wq=8: larger slices win (k=4 fastest)",
        ),
        ShapeCheck::new(
            "table4.wqk-fps-order",
            cols[3].1.fps > cols[5].1.fps,
            "at wq=k: k=1 (binary) beats k=4",
        ),
    ];
    (t, checks)
}

/// Table V: state-of-the-art comparison.
pub fn table5(cfg: &RunConfig) -> (Table, Vec<ShapeCheck>) {
    let mut t = Table::new("Table V — state-of-the-art comparison (ImageNet, CONV layers)")
        .headers(&["design", "CNN", "wq", "f MHz", "kLUT", "GOps/s", "fps", "mJ/frame", "GOps/s/W"]);
    for r in crate::baselines::table5_references() {
        t.row(vec![
            r.cite.to_string(),
            r.cnn.to_string(),
            r.wq.to_string(),
            fnum(r.f_mhz, 0),
            fnum(r.kluts, 1),
            fnum(r.gops, 1),
            r.fps.map(|f| fnum(f, 1)).unwrap_or_else(|| "-".into()),
            "-".to_string(),
            "-".to_string(),
        ]);
    }
    t.sep();
    // Paper's own three columns.
    for p in paper::TABLE5_OURS {
        t.row(vec![
            format!("paper ({})", p.cnn),
            p.cnn.to_string(),
            p.wq.to_string(),
            fnum(p.f_mhz, 0),
            fnum(p.kluts, 1),
            fnum(p.gops, 1),
            fnum(p.fps, 2),
            fnum(p.mj_per_frame, 2),
            fnum(p.gops_per_w, 1),
        ]);
    }
    t.sep();
    // Our reproduction of those three columns (k = 2 designs per paper).
    let mut ours = Vec::new();
    for (name, build, wq) in [
        ("ResNet-50", resnet::resnet50 as fn() -> crate::cnn::Cnn, 2u32),
        ("ResNet-152", resnet::resnet152, 2),
        ("ResNet-152", resnet::resnet152, 8),
    ] {
        let cnn = build().with_uniform_wq(wq);
        let out = dse::explore_k_cached(&cnn, cfg, 2, dse::DseCache::global());
        t.row(vec![
            format!("ours ({name} w{wq})"),
            name.to_string(),
            wq.to_string(),
            fnum(out.sim.fmhz, 0),
            fnum(out.sim.kluts, 1),
            fnum(out.sim.gops, 1),
            fnum(out.sim.fps, 2),
            fnum(out.sim.e_total_mj(), 2),
            fnum(out.sim.gops_per_w(), 1),
        ]);
        ours.push((name, wq, out.sim));
    }
    let r152w2 = &ours[1].2;
    let ma_gops = 276.6;
    let nguyen_gops = 726.0;
    let checks = vec![
        ShapeCheck::new(
            "table5.beats-ma-4x",
            r152w2.gops / ma_gops > 3.0,
            format!("ours/[15] = {:.2}x (paper 4.09x)", r152w2.gops / ma_gops),
        ),
        ShapeCheck::new(
            "table5.beats-nguyen",
            r152w2.gops / nguyen_gops > 1.2,
            format!("ours/[27] = {:.2}x (paper 1.56x)", r152w2.gops / nguyen_gops),
        ),
        ShapeCheck::new(
            "table5.tops-headline",
            r152w2.gops > 800.0,
            format!("ResNet-152 w2: {:.2} TOps/s (paper 1.13)", r152w2.gops / 1000.0),
        ),
    ];
    (t, checks)
}

/// Fig 9: accuracy vs throughput frontier (k = w_Q designs).
pub fn fig9(cfg: &RunConfig) -> (Table, Vec<ShapeCheck>) {
    let mut t = Table::new("Fig 9 — accuracy vs performance (operand slice k = w_Q)")
        .headers(&["CNN", "wq", "Top-5 % (paper QAT)", "ours fps", "ours GOps/s"]);
    let mut pts: Vec<(String, u32, f64, f64)> = Vec::new();
    for (name, build) in [
        ("ResNet-18", resnet::resnet18 as fn() -> crate::cnn::Cnn),
        ("ResNet-50", resnet::resnet50),
        ("ResNet-152", resnet::resnet152),
    ] {
        for wq in [1u32, 2, 4] {
            if !cfg.slices.contains(&wq) {
                continue;
            }
            let cnn = build().with_uniform_wq(wq);
            let out = dse::explore_k_cached(&cnn, cfg, wq, dse::DseCache::global());
            let top5 = paper::top5_accuracy(name, wq).unwrap();
            t.row(vec![
                name.to_string(),
                wq.to_string(),
                fnum(top5, 2),
                fnum(out.sim.fps, 1),
                fnum(out.sim.gops, 1),
            ]);
            pts.push((name.to_string(), wq, top5, out.sim.fps));
        }
        t.sep();
    }
    // Shape: within a CNN, fps decreases from wq=2 to wq=4 strictly; the
    // wq=1 vs wq=2 pair is a *near-tie that can flip*: the paper measures
    // a 1.02x gap (Table IV) and explains it by "the high efficiency of
    // the PPG with 2 bit operand slice" (§IV-C); our DSE packs the k=2
    // array to the full LUT budget (the paper's stopped at 1295 PEs) and
    // lands the pair the other way. We require wq=1 within 0.6x of wq=2
    // and strict ordering above — see EXPERIMENTS.md §Deviations.
    let fps_mono = |name: &str| {
        let v: Vec<f64> = pts
            .iter()
            .filter(|p| p.0 == name)
            .map(|p| p.3)
            .collect();
        v.len() == 3 && v[0] >= 0.6 * v[1] && v[1] > v[2]
    };
    let checks = vec![
        ShapeCheck::new(
            "fig9.fps-vs-wq",
            fps_mono("ResNet-18") && fps_mono("ResNet-152"),
            "throughput falls as word-length grows",
        ),
        ShapeCheck::new(
            "fig9.depth-tradeoff",
            {
                let f18 = pts.iter().find(|p| p.0 == "ResNet-18" && p.1 == 2).map(|p| p.3);
                let f152 = pts.iter().find(|p| p.0 == "ResNet-152" && p.1 == 2).map(|p| p.3);
                matches!((f18, f152), (Some(a), Some(b)) if a > b)
            },
            "deeper CNN trades fps for accuracy",
        ),
    ];
    (t, checks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RunConfig {
        RunConfig::default()
    }

    #[test]
    fn fig3_checks_pass() {
        let (t, checks) = fig3();
        assert!(t.n_rows() >= 8);
        assert!(checks.iter().all(|c| c.pass), "{checks:?}");
    }

    #[test]
    fn fig6_checks_pass() {
        let (_, checks) = fig6(&cfg());
        assert!(checks.iter().all(|c| c.pass), "{checks:?}");
    }

    #[test]
    fn fig7_checks_pass() {
        let (_, checks) = fig7(&cfg());
        assert!(checks.iter().all(|c| c.pass), "{checks:?}");
    }

    #[test]
    fn fig8_checks_pass() {
        let (_, checks) = fig8();
        assert!(checks.iter().all(|c| c.pass), "{checks:?}");
    }

    #[test]
    fn table3_checks_pass() {
        let (t, checks) = table3();
        assert!(t.n_rows() >= 12);
        assert!(checks.iter().all(|c| c.pass), "{checks:?}");
    }

    #[test]
    fn table4_checks_pass() {
        let (_, checks) = table4(&cfg());
        assert!(checks.iter().all(|c| c.pass), "{checks:?}");
    }
}
