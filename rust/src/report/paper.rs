//! Paper-reported reference numbers (FPL 2022), used ONLY for side-by-side
//! printing and shape checks — never fed back into the models except the
//! explicit calibration anchors listed in DESIGN.md §5.

/// Table III row: accuracy vs memory footprint.
/// `wq = 0` encodes the FP32 baseline.
#[derive(Clone, Copy, Debug)]
pub struct Table3Row {
    pub cnn: &'static str,
    pub wq: u32,
    pub footprint_mb: f64,
    pub compression: f64,
    pub top1: f64,
    pub top5: f64,
}

pub const TABLE3: [Table3Row; 12] = [
    Table3Row { cnn: "ResNet-18", wq: 0, footprint_mb: 352.0, compression: 1.0, top1: 69.69, top5: 89.07 },
    Table3Row { cnn: "ResNet-18", wq: 1, footprint_mb: 69.0, compression: 5.1, top1: 40.42, top5: 65.29 },
    Table3Row { cnn: "ResNet-18", wq: 2, footprint_mb: 72.0, compression: 4.9, top1: 67.31, top5: 87.48 },
    Table3Row { cnn: "ResNet-18", wq: 4, footprint_mb: 77.0, compression: 4.6, top1: 69.75, top5: 89.10 },
    Table3Row { cnn: "ResNet-50", wq: 0, footprint_mb: 662.0, compression: 1.0, top1: 76.00, top5: 92.93 },
    Table3Row { cnn: "ResNet-50", wq: 1, footprint_mb: 111.0, compression: 6.0, top1: 61.87, top5: 83.95 },
    Table3Row { cnn: "ResNet-50", wq: 2, footprint_mb: 118.0, compression: 5.6, top1: 74.86, top5: 92.24 },
    Table3Row { cnn: "ResNet-50", wq: 4, footprint_mb: 134.0, compression: 4.9, top1: 76.47, top5: 93.07 },
    Table3Row { cnn: "ResNet-152", wq: 0, footprint_mb: 1767.0, compression: 1.0, top1: 78.26, top5: 93.94 },
    Table3Row { cnn: "ResNet-152", wq: 1, footprint_mb: 145.0, compression: 12.2, top1: 70.77, top5: 90.02 },
    Table3Row { cnn: "ResNet-152", wq: 2, footprint_mb: 188.0, compression: 9.4, top1: 76.09, top5: 92.90 },
    Table3Row { cnn: "ResNet-152", wq: 4, footprint_mb: 272.0, compression: 6.5, top1: 78.38, top5: 94.00 },
];

/// Table II row: chosen PE array dimensions.
#[derive(Clone, Copy, Debug)]
pub struct Table2Row {
    pub cnn: &'static str,
    pub k: u32,
    pub h: u32,
    pub w: u32,
    pub d: u32,
    pub n_pe: u64,
}

pub const TABLE2: [Table2Row; 6] = [
    Table2Row { cnn: "ResNet-18", k: 1, h: 7, w: 3, d: 32, n_pe: 672 },
    Table2Row { cnn: "ResNet-18", k: 2, h: 7, w: 5, d: 37, n_pe: 1295 },
    Table2Row { cnn: "ResNet-18", k: 4, h: 7, w: 4, d: 66, n_pe: 1848 },
    Table2Row { cnn: "ResNet-50/152", k: 1, h: 7, w: 3, d: 33, n_pe: 693 },
    Table2Row { cnn: "ResNet-50/152", k: 2, h: 7, w: 5, d: 37, n_pe: 1295 },
    Table2Row { cnn: "ResNet-50/152", k: 4, h: 7, w: 4, d: 71, n_pe: 1988 },
];

/// Table IV column: ResNet-18 on the k-optimized design.
#[derive(Clone, Copy, Debug)]
pub struct Table4Col {
    pub k: u32,
    /// Inner-layer weight word-length (8 or = k).
    pub wq: u32,
    pub top1: f64,
    pub top5: f64,
    pub kluts: f64,
    pub brams: u64,
    pub f_mhz: f64,
    pub e_comp_mj: f64,
    pub e_bram_mj: f64,
    pub e_ddr_mj: f64,
    pub e_total_mj: f64,
    pub fps: f64,
    pub gops: f64,
}

pub const TABLE4: [Table4Col; 6] = [
    Table4Col { k: 1, wq: 8, top1: 70.40, top5: 89.62, kluts: 392.24, brams: 2470, f_mhz: 124.0, e_comp_mj: 100.90, e_bram_mj: 7.59, e_ddr_mj: 6.24, e_total_mj: 114.73, fps: 46.86, gops: 159.87 },
    Table4Col { k: 2, wq: 8, top1: 70.40, top5: 89.62, kluts: 327.68, brams: 2470, f_mhz: 127.0, e_comp_mj: 47.06, e_bram_mj: 5.42, e_ddr_mj: 6.24, e_total_mj: 58.72, fps: 83.81, gops: 285.94 },
    Table4Col { k: 4, wq: 8, top1: 70.40, top5: 89.62, kluts: 243.94, brams: 2470, f_mhz: 96.0, e_comp_mj: 23.40, e_bram_mj: 5.85, e_ddr_mj: 6.24, e_total_mj: 35.49, fps: 97.25, gops: 331.77 },
    Table4Col { k: 1, wq: 1, top1: 40.42, top5: 65.29, kluts: 380.35, brams: 1644, f_mhz: 124.0, e_comp_mj: 11.80, e_bram_mj: 1.35, e_ddr_mj: 4.90, e_total_mj: 18.05, fps: 271.68, gops: 926.84 },
    Table4Col { k: 2, wq: 2, top1: 67.31, top5: 87.48, kluts: 331.52, brams: 1762, f_mhz: 127.0, e_comp_mj: 11.76, e_bram_mj: 1.55, e_ddr_mj: 5.10, e_total_mj: 18.41, fps: 245.23, gops: 836.61 },
    Table4Col { k: 4, wq: 4, top1: 69.75, top5: 89.10, kluts: 243.94, brams: 1998, f_mhz: 96.0, e_comp_mj: 16.06, e_bram_mj: 3.21, e_ddr_mj: 5.48, e_total_mj: 24.75, fps: 165.63, gops: 565.05 },
];

/// Table V "this work" columns.
#[derive(Clone, Copy, Debug)]
pub struct Table5Ours {
    pub cnn: &'static str,
    pub wq: u32,
    pub top1: f64,
    pub top5: f64,
    pub f_mhz: f64,
    pub brams: u64,
    pub kluts: f64,
    pub gops: f64,
    pub fps: f64,
    pub mj_per_frame: f64,
    pub gops_per_w: f64,
}

pub const TABLE5_OURS: [Table5Ours; 3] = [
    Table5Ours { cnn: "ResNet-50", wq: 2, top1: 74.86, top5: 92.24, f_mhz: 127.0, brams: 1762, kluts: 331.5, gops: 938.33, fps: 129.38, mj_per_frame: 36.56, gops_per_w: 198.39 },
    Table5Ours { cnn: "ResNet-152", wq: 2, top1: 76.09, top5: 92.90, f_mhz: 127.0, brams: 1762, kluts: 331.5, gops: 1131.38, fps: 51.19, mj_per_frame: 97.71, gops_per_w: 226.20 },
    Table5Ours { cnn: "ResNet-152", wq: 8, top1: 78.17, top5: 93.96, f_mhz: 127.0, brams: 2470, kluts: 331.5, gops: 311.16, fps: 14.08, mj_per_frame: 367.69, gops_per_w: 60.11 },
];

/// Abstract headline numbers.
pub const HEADLINE_RESNET18_FPS: f64 = 245.0;
pub const HEADLINE_RESNET18_TOP5: f64 = 87.48;
pub const HEADLINE_RESNET152_TOPS: f64 = 1.13;
pub const HEADLINE_RESNET152_TOP5: f64 = 92.9;
pub const HEADLINE_MEM_REDUCTION_18: f64 = 4.9;
pub const HEADLINE_MEM_REDUCTION_152: f64 = 9.4;
pub const HEADLINE_ENERGY_REDUCTION: f64 = 6.36;

/// Accuracy lookup for Fig 9 / Table IV annotations (paper-trained ImageNet
/// accuracies; our small-scale QAT provides the ordering check, see
/// EXPERIMENTS.md).
pub fn top5_accuracy(cnn: &str, wq: u32) -> Option<f64> {
    accuracy(cnn, wq).map(|(_, top5)| top5)
}

/// Top-1 companion of [`top5_accuracy`] (same anchor lineage).
pub fn top1_accuracy(cnn: &str, wq: u32) -> Option<f64> {
    accuracy(cnn, wq).map(|(top1, _)| top1)
}

fn accuracy(cnn: &str, wq: u32) -> Option<(f64, f64)> {
    if let Some(r) = TABLE3.iter().find(|r| r.cnn == cnn && r.wq == wq) {
        return Some((r.top1, r.top5));
    }
    // Table III stops at wq=4; Table IV (ResNet-18 only) and Table V
    // (ResNet-152 only) add the wq=8 points, which the serving layer's
    // routing profiles and the planner's calibration need.
    if cnn == "ResNet-18" {
        return TABLE4.iter().find(|c| c.wq == wq).map(|c| (c.top1, c.top5));
    }
    if cnn == "ResNet-152" && wq == 8 {
        return TABLE5_OURS
            .iter()
            .find(|r| r.cnn == cnn && r.wq == wq)
            .map(|r| (r.top1, r.top5));
    }
    None
}

/// The paper's quantized uniform-`wq` accuracy anchors for `cnn`, as
/// `(wq, top1, top5)` sorted by ascending word-length. Single source for
/// the interpolation helpers below and `planner::sensitivity`.
pub fn accuracy_anchors(cnn: &str) -> Vec<(u32, f64, f64)> {
    let mut out = Vec::new();
    for wq in [1u32, 2, 4, 8] {
        if let Some((t1, t5)) = accuracy(cnn, wq) {
            out.push((wq, t1, t5));
        }
    }
    out
}

/// Top-5 at a (possibly fractional) word-length, piecewise-linearly
/// interpolated between the uniform anchors on a log2(w_Q) axis and clamped
/// outside the anchored range. Exact at the anchors; `None` when the paper
/// has no rows for `cnn`. This is what channel-wise routing profiles use
/// instead of the exact-anchor lookup (a `w_Q = 3` channel group previously
/// had no accuracy estimate at all).
pub fn top5_interpolated(cnn: &str, wq: f64) -> Option<f64> {
    interpolate(cnn, wq, |(_, _, t5)| t5)
}

/// Top-1 companion of [`top5_interpolated`].
pub fn top1_interpolated(cnn: &str, wq: f64) -> Option<f64> {
    interpolate(cnn, wq, |(_, t1, _)| t1)
}

fn interpolate(cnn: &str, wq: f64, pick: fn(&(u32, f64, f64)) -> f64) -> Option<f64> {
    if !wq.is_finite() || wq <= 0.0 {
        return None;
    }
    let anchors = accuracy_anchors(cnn);
    let (first, last) = (anchors.first()?, anchors.last()?);
    let x = wq.log2();
    if x <= (first.0 as f64).log2() {
        return Some(pick(first));
    }
    if x >= (last.0 as f64).log2() {
        return Some(pick(last));
    }
    for pair in anchors.windows(2) {
        let (x0, x1) = ((pair[0].0 as f64).log2(), (pair[1].0 as f64).log2());
        if x >= x0 && x <= x1 {
            let t = (x - x0) / (x1 - x0);
            return Some(pick(&pair[0]) + t * (pick(&pair[1]) - pick(&pair[0])));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_energy_columns_sum() {
        for c in TABLE4 {
            let sum = c.e_comp_mj + c.e_bram_mj + c.e_ddr_mj;
            assert!(
                (sum - c.e_total_mj).abs() < 0.02,
                "k={} wq={}: {sum} != {}",
                c.k,
                c.wq,
                c.e_total_mj
            );
        }
    }

    #[test]
    fn table2_npe_consistent() {
        for r in TABLE2 {
            assert_eq!(r.h as u64 * r.w as u64 * r.d as u64, r.n_pe);
        }
    }

    #[test]
    fn headline_consistency() {
        // 245 fps @ 87.48 Top-5 is the k=2/wq=2 ResNet-18 column.
        let c = TABLE4.iter().find(|c| c.k == 2 && c.wq == 2).unwrap();
        assert!((c.fps - HEADLINE_RESNET18_FPS).abs() < 1.0);
        assert!((c.top5 - HEADLINE_RESNET18_TOP5).abs() < 0.01);
        // 1.13 TOps/s is the ResNet-152 w2 Table V column.
        let t5 = TABLE5_OURS.iter().find(|r| r.cnn == "ResNet-152" && r.wq == 2).unwrap();
        assert!((t5.gops / 1000.0 - HEADLINE_RESNET152_TOPS).abs() < 0.01);
        // 6.36x = k=1 total energy ratio.
        let e8 = TABLE4.iter().find(|c| c.k == 1 && c.wq == 8).unwrap();
        let e1 = TABLE4.iter().find(|c| c.k == 1 && c.wq == 1).unwrap();
        assert!((e8.e_total_mj / e1.e_total_mj - HEADLINE_ENERGY_REDUCTION).abs() < 0.01);
    }

    #[test]
    fn accuracy_lookup() {
        assert_eq!(top5_accuracy("ResNet-18", 2), Some(87.48));
        assert_eq!(top5_accuracy("ResNet-18", 0), Some(89.07));
        assert_eq!(top5_accuracy("VGG", 2), None);
        // The Table IV extension point (serving profiles for wq=8).
        assert_eq!(top5_accuracy("ResNet-18", 8), Some(89.62));
        assert_eq!(top5_accuracy("ResNet-50", 8), None);
    }

    #[test]
    fn interpolation_exact_at_anchors_and_monotone_between() {
        // Exact at every anchor word-length.
        for (wq, t1, t5) in accuracy_anchors("ResNet-18") {
            assert_eq!(top5_interpolated("ResNet-18", wq as f64), Some(t5));
            assert_eq!(top1_interpolated("ResNet-18", wq as f64), Some(t1));
        }
        // A w_Q = 3 channel group now has an estimate, strictly between the
        // 2- and 4-bit anchors.
        let t3 = top5_interpolated("ResNet-18", 3.0).unwrap();
        assert!(t3 > 87.48 && t3 < 89.10, "{t3}");
        // Clamped outside the anchored range; rejects nonsense.
        assert_eq!(top5_interpolated("ResNet-18", 16.0), Some(89.62));
        assert_eq!(top5_interpolated("ResNet-18", 0.5), Some(65.29));
        assert_eq!(top5_interpolated("ResNet-18", 0.0), None);
        assert_eq!(top5_interpolated("VGG", 3.0), None);
        // ResNet-152 gets its 8-bit anchor from Table V.
        assert_eq!(top5_accuracy("ResNet-152", 8), Some(93.96));
        assert_eq!(top1_accuracy("ResNet-152", 8), Some(78.17));
        // ResNet-50 has no 8-bit row: interpolation clamps at wq=4.
        assert_eq!(top5_interpolated("ResNet-50", 8.0), Some(93.07));
    }

    #[test]
    fn table5_ours_gops_per_w_consistent() {
        // GOps/s/W must equal gops / (mJ/frame * fps / 1000) in every row —
        // this is the consistency check that exposes Table IV's column as a
        // typo (documented in EXPERIMENTS.md).
        for r in TABLE5_OURS {
            let implied = r.gops / (r.mj_per_frame * 1e-3 * r.fps);
            assert!(
                (implied - r.gops_per_w).abs() / r.gops_per_w < 0.01,
                "{}: implied {implied} vs {}",
                r.cnn,
                r.gops_per_w
            );
        }
    }
}
