//! Pareto machinery and the joint `(w_Q, a_Q)` assignment search.
//!
//! Three candidate generators feed the evaluator, all pruned by the same
//! monotone-dominance argument as `array::search` (every DP coordinate is a
//! per-layer additive sum, so a prefix that is weakly dominated on all
//! coordinates cannot complete into a non-dominated plan):
//!
//! 1. **Greedy efficiency walk** — from the all-max-bits assignment,
//!    repeatedly apply the single best per-layer demotion by
//!    Δbits/Δnoise ratio, where a step is either a *weight* demotion
//!    (saving `params · Δw` weight bits) or an *activation* demotion
//!    (saving `output_elems · Δa` Table-III activation-buffer bits).
//!    This walks the continuous-relaxation optimum of the
//!    (noise, footprint) trade-off, so the low-noise end of the frontier
//!    (where mixed plans Pareto-dominate the uniform variants) is covered
//!    densely.
//! 2. **Channel-split twists** — the first walk steps re-expressed as
//!    [`ChannelGroup`] splits, so per-channel-group points reach the
//!    evaluator too.
//! 3. **Beam DP** — layer-by-layer product with the full joint menu
//!    (uniform weight choices + splits, × the activation menu), pruned to
//!    the 4-D Pareto set over (noise, weight bits, pass cost,
//!    activation-buffer bits) and thinned to a bits-spread beam. With the
//!    default single-entry activation menu `[8]` the fourth axis is
//!    constant at every depth, so the search degenerates bit-for-bit to
//!    the weight-only planner.

use super::sensitivity::SensitivityModel;
use super::{pinned, Assignment, PlannerConfig};
use crate::cnn::{ChannelGroup, Cnn};

/// The (proxy-accuracy, throughput, footprint) coordinates dominance is
/// judged on.
#[derive(Clone, Copy, Debug)]
pub struct Triple {
    /// Proxy Top-5 percent (higher is better).
    pub top5: f64,
    /// Frames/s of the DSE-chosen design (higher is better).
    pub fps: f64,
    /// Planned memory footprint in MB (lower is better): weights at their
    /// assigned word-lengths **plus** the Table-III peak activation
    /// working set at the assigned activation word-lengths. For all-8-bit
    /// activation plans the activation term is the same constant for
    /// every point of a base CNN, so weight-only dominance decisions are
    /// unchanged; reduced-`a_Q` plans buy their frontier seat with the
    /// buffer bytes they save.
    pub footprint_mb: f64,
}

/// Pareto dominance on the triple: no worse on every coordinate, strictly
/// better on at least one.
pub fn dominates(a: &Triple, b: &Triple) -> bool {
    let ge = a.top5 >= b.top5 && a.fps >= b.fps && a.footprint_mb <= b.footprint_mb;
    let strict = a.top5 > b.top5 || a.fps > b.fps || a.footprint_mb < b.footprint_mb;
    ge && strict
}

/// Indices of the mutually non-dominated points (duplicates both survive).
pub fn pareto_indices(pts: &[Triple]) -> Vec<usize> {
    (0..pts.len())
        .filter(|&i| !pts.iter().enumerate().any(|(j, q)| j != i && dominates(q, &pts[i])))
        .collect()
}

/// One per-layer joint choice with its additive DP coordinates.
#[derive(Clone, Debug)]
struct MenuItem {
    groups: Vec<ChannelGroup>,
    /// Activation word-length of this choice.
    aq: u32,
    /// Weighted noise contribution
    /// `s_l · (Σ frac · n(wq) + (n_act(aq) − n_act(8)))`.
    noise: f64,
    /// Weight bits `params_l · Σ frac · wq`.
    bits: f64,
    /// Serial-pass cost proxy `MACs_l · Σ frac · wq` (k=1 cycle count).
    cost: f64,
    /// Table-III activation-buffer bits `output_elems_l · aq`.
    act: f64,
}

fn menu_for_layer(
    base: &Cnn,
    model: &SensitivityModel,
    li: usize,
    pcfg: &PlannerConfig,
) -> Vec<MenuItem> {
    let l = &base.layers[li];
    let (w, p, m) = (model.weight(li), l.params() as f64, l.macs() as f64);
    let out_elems = l.output_elems() as f64;
    let wqs = pcfg.bits_menu();
    let aqs = pcfg.aq_menu();
    let item = |groups: Vec<ChannelGroup>, aq: u32| {
        let avg_n: f64 = groups.iter().map(|g| g.fraction * model.noise_power(g.wq)).sum();
        let avg_b: f64 = groups.iter().map(|g| g.fraction * g.wq as f64).sum();
        MenuItem {
            groups,
            aq,
            noise: w * (avg_n + model.activation_noise_delta(aq)),
            bits: p * avg_b,
            cost: m * avg_b,
            act: out_elems * aq as f64,
        }
    };
    // aq innermost so the single-entry default menu preserves the
    // weight-only ordering exactly.
    let mut menu: Vec<MenuItem> = Vec::new();
    for &wq in &wqs {
        for &aq in &aqs {
            menu.push(item(vec![ChannelGroup { wq, fraction: 1.0 }], aq));
        }
    }
    for pair in wqs.windows(2) {
        let (lo, hi) = (pair[0], pair[1]);
        for &f in &pcfg.split_fractions {
            if f > 0.0 && f < 1.0 {
                for &aq in &aqs {
                    menu.push(item(
                        vec![
                            ChannelGroup { wq: lo, fraction: f },
                            ChannelGroup { wq: hi, fraction: 1.0 - f },
                        ],
                        aq,
                    ));
                }
            }
        }
    }
    menu
}

/// Greedy efficiency walk: from the all-max-bits joint assignment,
/// repeatedly apply the single best demotion by Δbits/Δnoise — either a
/// layer's next-lower *weight* word-length (saving `params · Δw` weight
/// bits) or its next-lower *activation* word-length (saving
/// `output_elems · Δa` activation-buffer bits). With the default
/// single-entry activation menu no activation moves exist and the walk
/// is the weight-only walk, step for step.
fn chain_candidates(base: &Cnn, model: &SensitivityModel, pcfg: &PlannerConfig) -> Vec<Assignment> {
    let wqs = pcfg.bits_menu();
    let aqs = pcfg.aq_menu();
    if wqs.len() < 2 && aqs.len() < 2 {
        return Vec::new();
    }
    let hi = *wqs.last().unwrap();
    let hi_a = *aqs.last().unwrap();
    let inner: Vec<usize> = (0..base.layers.len()).filter(|&i| !pinned(base, i)).collect();
    // Current word-length indexes per inner layer (start at max).
    let mut wlevel: Vec<usize> = vec![wqs.len() - 1; inner.len()];
    let mut alevel: Vec<usize> = vec![aqs.len() - 1; inner.len()];
    let mut cur = Assignment::uniform_joint(base, hi, hi_a);
    let mut out = Vec::new();
    enum Move {
        Weight(usize),
        Act(usize),
    }
    loop {
        // Best next single demotion by Δbits/Δnoise.
        let mut best: Option<(Move, f64)> = None;
        let mut consider = |mv: Move, eff: f64, best: &mut Option<(Move, f64)>| {
            if best.as_ref().map_or(true, |(_, be)| eff > *be) {
                *best = Some((mv, eff));
            }
        };
        for (j, &li) in inner.iter().enumerate() {
            let l = &base.layers[li];
            if wlevel[j] > 0 {
                let (from, to) = (wqs[wlevel[j]], wqs[wlevel[j] - 1]);
                let d_bits = l.params() as f64 * (from - to) as f64;
                let d_noise = model.weight(li)
                    * (model.noise_power(to) - model.noise_power(from)).max(1e-300);
                consider(Move::Weight(j), d_bits / d_noise, &mut best);
            }
            if alevel[j] > 0 {
                let (from, to) = (aqs[alevel[j]], aqs[alevel[j] - 1]);
                let d_bits = l.output_elems() as f64 * (from - to) as f64;
                let d_noise = model.weight(li)
                    * (model.activation_noise_power(to) - model.activation_noise_power(from))
                        .max(1e-300);
                consider(Move::Act(j), d_bits / d_noise, &mut best);
            }
        }
        let Some((mv, _)) = best else { break };
        match mv {
            Move::Weight(j) => {
                wlevel[j] -= 1;
                cur.groups[inner[j]] =
                    vec![ChannelGroup { wq: wqs[wlevel[j]], fraction: 1.0 }];
            }
            Move::Act(j) => {
                alevel[j] -= 1;
                cur.aq[inner[j]] = aqs[alevel[j]];
            }
        }
        out.push(cur.clone());
    }
    out
}

/// Channel-split twists of the first few walk steps: the layers the walk
/// demotes first, split `lo@f / hi@(1-f)` instead of demoted outright.
fn split_candidates(base: &Cnn, model: &SensitivityModel, pcfg: &PlannerConfig) -> Vec<Assignment> {
    let wqs = pcfg.bits_menu();
    if wqs.len() < 2 || pcfg.split_fractions.is_empty() {
        return Vec::new();
    }
    let hi = *wqs.last().unwrap();
    let lo = wqs[wqs.len() - 2];
    let hi_a = *pcfg.aq_menu().last().unwrap();
    let inner: Vec<usize> = (0..base.layers.len()).filter(|&i| !pinned(base, i)).collect();
    // Efficiency order for the hi -> lo step.
    let mut order: Vec<usize> = inner.clone();
    order.sort_by(|&a, &b| {
        let eff = |li: usize| {
            base.layers[li].params() as f64 * (hi - lo) as f64
                / (model.weight(li) * (model.noise_power(lo) - model.noise_power(hi))).max(1e-300)
        };
        eff(b).total_cmp(&eff(a))
    });
    let mut out = Vec::new();
    for &li in order.iter().take(3) {
        for &f in &pcfg.split_fractions {
            if f <= 0.0 || f >= 1.0 {
                continue;
            }
            let mut a = Assignment::uniform_joint(base, hi, hi_a);
            a.groups[li] = vec![
                ChannelGroup { wq: lo, fraction: f },
                ChannelGroup { wq: hi, fraction: 1.0 - f },
            ];
            out.push(a);
        }
    }
    out
}

#[derive(Clone, Debug)]
struct BeamState {
    noise: f64,
    bits: f64,
    cost: f64,
    /// Table-III activation-buffer bits — the axis the joint search adds.
    act: f64,
    choices: Vec<u16>,
}

/// Keep only states no other state weakly dominates (≤ on all four
/// coordinates; equal states collapse to the first). With a single-entry
/// activation menu the `act` coordinate is identical across all states at
/// a given depth, so the pruning degenerates to the 3-D weight-only one.
fn prune_weakly_dominated(mut states: Vec<BeamState>) -> Vec<BeamState> {
    states.sort_by(|a, b| {
        a.noise
            .total_cmp(&b.noise)
            .then(a.bits.total_cmp(&b.bits))
            .then(a.cost.total_cmp(&b.cost))
            .then(a.act.total_cmp(&b.act))
    });
    let mut kept: Vec<BeamState> = Vec::new();
    'outer: for s in states {
        for k in &kept {
            if k.noise <= s.noise && k.bits <= s.bits && k.cost <= s.cost && k.act <= s.act {
                continue 'outer;
            }
        }
        kept.push(s);
    }
    kept
}

/// Beam DP over the inner layers' joint `(wq groups, aq)` menus.
fn beam_candidates(base: &Cnn, model: &SensitivityModel, pcfg: &PlannerConfig) -> Vec<Assignment> {
    let inner: Vec<usize> = (0..base.layers.len()).filter(|&i| !pinned(base, i)).collect();
    let menus: Vec<Vec<MenuItem>> =
        inner.iter().map(|&li| menu_for_layer(base, model, li, pcfg)).collect();
    let beam = pcfg.beam_width.max(2);
    let mut states = vec![BeamState {
        noise: 0.0,
        bits: 0.0,
        cost: 0.0,
        act: 0.0,
        choices: Vec::new(),
    }];
    for menu in &menus {
        let mut next = Vec::with_capacity(states.len() * menu.len());
        for s in &states {
            for (mi, m) in menu.iter().enumerate() {
                let mut choices = s.choices.clone();
                choices.push(mi as u16);
                next.push(BeamState {
                    noise: s.noise + m.noise,
                    bits: s.bits + m.bits,
                    cost: s.cost + m.cost,
                    act: s.act + m.act,
                    choices,
                });
            }
        }
        let mut pruned = prune_weakly_dominated(next);
        if pruned.len() > beam {
            // Thin to an evenly bits-spaced beam, keeping both extremes.
            pruned.sort_by(|a, b| a.bits.total_cmp(&b.bits));
            let last = pruned.len() - 1;
            let mut take: Vec<usize> = (0..beam).map(|j| j * last / (beam - 1)).collect();
            take.dedup();
            pruned = take.into_iter().map(|i| pruned[i].clone()).collect();
        }
        states = pruned;
    }
    states
        .into_iter()
        .map(|s| {
            let mut a = Assignment::uniform(base, 8);
            for (j, &li) in inner.iter().enumerate() {
                let item = &menus[j][s.choices[j] as usize];
                a.groups[li] = item.groups.clone();
                a.aq[li] = item.aq;
            }
            a
        })
        .collect()
}

/// All candidate assignments worth evaluating, deduplicated.
pub fn enumerate_assignments(
    base: &Cnn,
    model: &SensitivityModel,
    pcfg: &PlannerConfig,
) -> Vec<Assignment> {
    let mut out = chain_candidates(base, model, pcfg);
    out.extend(split_candidates(base, model, pcfg));
    out.extend(beam_candidates(base, model, pcfg));
    let mut seen: Vec<Assignment> = Vec::with_capacity(out.len());
    for a in out {
        if !seen.contains(&a) {
            seen.push(a);
        }
    }
    seen
}

/// Pick at most `max_evals` candidates, evenly spaced over the log of their
/// aggregate noise (the accuracy proxy is log-sensitive near the quiet
/// anchors, so linear spacing would starve the high-accuracy end where the
/// dominating plans live).
pub fn thin_candidates(
    mut cands: Vec<Assignment>,
    model: &SensitivityModel,
    max_evals: usize,
) -> Vec<Assignment> {
    if cands.len() <= max_evals {
        return cands;
    }
    cands.sort_by(|a, b| model.aggregate_noise(a).total_cmp(&model.aggregate_noise(b)));
    let ln: Vec<f64> =
        cands.iter().map(|a| (model.aggregate_noise(a) + 1e-12).ln()).collect();
    let (lo, hi) = (ln[0], ln[ln.len() - 1]);
    let mut picked: Vec<usize> = Vec::with_capacity(max_evals);
    for t in 0..max_evals {
        let target = lo + (hi - lo) * t as f64 / (max_evals - 1).max(1) as f64;
        let i = ln
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| (*a - target).abs().total_cmp(&(*b - target).abs()))
            .map(|(i, _)| i)
            .unwrap();
        if !picked.contains(&i) {
            picked.push(i);
        }
    }
    picked.sort_unstable();
    picked.into_iter().map(|i| cands[i].clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;

    fn t(top5: f64, fps: f64, mb: f64) -> Triple {
        Triple { top5, fps, footprint_mb: mb }
    }

    #[test]
    fn dominance_definition() {
        assert!(dominates(&t(89.0, 100.0, 5.0), &t(89.0, 90.0, 5.0)));
        assert!(dominates(&t(89.0, 100.0, 4.0), &t(89.0, 100.0, 5.0)));
        // Equal points do not dominate each other.
        assert!(!dominates(&t(89.0, 100.0, 5.0), &t(89.0, 100.0, 5.0)));
        // A trade-off is incomparable.
        assert!(!dominates(&t(89.5, 90.0, 5.0), &t(89.0, 100.0, 5.0)));
        assert!(!dominates(&t(89.0, 90.0, 5.0), &t(89.5, 100.0, 4.0)));
    }

    #[test]
    fn pareto_keeps_only_nondominated() {
        let pts = vec![
            t(89.6, 130.0, 11.7), // dominated by the next point
            t(89.6, 140.0, 9.3),
            t(87.5, 320.0, 3.3),
            t(65.3, 320.0, 1.9),
        ];
        let keep = pareto_indices(&pts);
        assert_eq!(keep, vec![1, 2, 3]);
    }

    #[test]
    fn enumeration_covers_the_quiet_end_and_dedupes() {
        let base = resnet::resnet18();
        let pcfg = PlannerConfig::default();
        let model = SensitivityModel::build(
            &base,
            "ResNet-18",
            pcfg.alpha,
            &pcfg.wq_choices,
            &pcfg.aq_choices,
        )
        .unwrap();
        let cands = enumerate_assignments(&base, &model, &pcfg);
        assert!(cands.len() > 20, "{}", cands.len());
        for (i, a) in cands.iter().enumerate() {
            assert!(!cands[..i].contains(a), "duplicate candidate at {i}");
            assert_eq!(a.groups.len(), base.layers.len());
        }
        // The first greedy step (one fat layer one notch down, rest at max)
        // must be among the candidates — it is the flagship low-noise plan.
        let n8 = model.aggregate_noise(&Assignment::uniform(&base, 8));
        let quiet = cands
            .iter()
            .filter(|a| a.uniform_wq(&base).is_none())
            .map(|a| model.aggregate_noise(a))
            .fold(f64::INFINITY, f64::min);
        assert!(quiet > n8 && quiet < n8 + 1e-3, "quietest mixed plan {quiet} vs n8 {n8}");
        // Some candidate carries a channel split.
        assert!(cands
            .iter()
            .any(|a| a.groups.iter().any(|g| g.len() > 1)));
    }

    #[test]
    fn joint_aq_menu_reaches_the_candidate_pool() {
        // Opening the activation menu must produce candidates that narrow
        // activations — via the beam's joint menu AND the greedy walk's
        // activation moves — while an aq-8-only menu never does.
        let base = resnet::resnet18();
        let mut pcfg = PlannerConfig { aq_choices: vec![4, 8], ..PlannerConfig::default() };
        let model = SensitivityModel::build(
            &base,
            "ResNet-18",
            pcfg.alpha,
            &pcfg.wq_choices,
            &pcfg.aq_choices,
        )
        .unwrap();
        let cands = enumerate_assignments(&base, &model, &pcfg);
        assert!(
            cands.iter().any(|a| a.aq.iter().any(|&q| q == 4)),
            "joint menu must surface reduced-activation candidates"
        );
        // Pinned layers never narrow.
        for a in &cands {
            assert_eq!(a.aq[0], 8, "conv1 activations pinned");
            assert_eq!(*a.aq.last().unwrap(), 8, "fc activations pinned");
            assert_eq!(a.aq.len(), base.layers.len());
            for &q in &a.aq {
                assert!(q == 4 || q == 8, "aq {q} outside the menu");
            }
        }
        // No reduced-aq candidate is classed as a uniform paper baseline.
        for a in cands.iter().filter(|a| a.aq.iter().any(|&q| q != 8)) {
            assert_eq!(a.uniform_wq(&base), None);
        }
        // The default single-entry menu stays all-8.
        pcfg.aq_choices = vec![8];
        let model8 = SensitivityModel::build(
            &base,
            "ResNet-18",
            pcfg.alpha,
            &pcfg.wq_choices,
            &pcfg.aq_choices,
        )
        .unwrap();
        let cands8 = enumerate_assignments(&base, &model8, &pcfg);
        assert!(cands8.iter().all(|a| a.aq.iter().all(|&q| q == 8)));
    }

    #[test]
    fn thinning_respects_cap_and_keeps_extremes() {
        let base = resnet::resnet18();
        let pcfg = PlannerConfig::default();
        let model = SensitivityModel::build(
            &base,
            "ResNet-18",
            pcfg.alpha,
            &pcfg.wq_choices,
            &pcfg.aq_choices,
        )
        .unwrap();
        let cands = enumerate_assignments(&base, &model, &pcfg);
        let noises: Vec<f64> = cands.iter().map(|a| model.aggregate_noise(a)).collect();
        let (lo, hi) = noises.iter().fold((f64::INFINITY, 0.0f64), |(l, h), &n| {
            (l.min(n), h.max(n))
        });
        let thin = thin_candidates(cands, &model, 8);
        assert!(thin.len() <= 8 && thin.len() >= 2);
        let tn: Vec<f64> = thin.iter().map(|a| model.aggregate_noise(a)).collect();
        assert!(tn.iter().any(|&n| n == lo), "quiet extreme kept");
        assert!(tn.iter().any(|&n| n == hi), "loud extreme kept");
    }
}
