//! Pareto machinery and the assignment search.
//!
//! Three candidate generators feed the evaluator, all pruned by the same
//! monotone-dominance argument as `array::search` (every DP coordinate is a
//! per-layer additive sum, so a prefix that is weakly dominated on all
//! coordinates cannot complete into a non-dominated plan):
//!
//! 1. **Greedy efficiency walk** — from the all-max-bits assignment,
//!    repeatedly apply the single per-layer demotion with the best
//!    Δbits/Δnoise ratio. This walks the continuous-relaxation optimum of
//!    the (noise, footprint) trade-off, so the low-noise end of the
//!    frontier (where mixed plans Pareto-dominate the uniform variants) is
//!    covered densely.
//! 2. **Channel-split twists** — the first walk steps re-expressed as
//!    [`ChannelGroup`] splits, so per-channel-group points reach the
//!    evaluator too.
//! 3. **Beam DP** — layer-by-layer product with the full menu (uniform
//!    choices + splits), pruned to the 3-D Pareto set over
//!    (noise, weight bits, pass cost) and thinned to a bits-spread beam.

use super::sensitivity::SensitivityModel;
use super::{pinned, Assignment, PlannerConfig};
use crate::cnn::{ChannelGroup, Cnn};

/// The (proxy-accuracy, throughput, footprint) coordinates dominance is
/// judged on.
#[derive(Clone, Copy, Debug)]
pub struct Triple {
    /// Proxy Top-5 percent (higher is better).
    pub top5: f64,
    /// Frames/s of the DSE-chosen design (higher is better).
    pub fps: f64,
    /// Weight footprint in MB (lower is better).
    pub footprint_mb: f64,
}

/// Pareto dominance on the triple: no worse on every coordinate, strictly
/// better on at least one.
pub fn dominates(a: &Triple, b: &Triple) -> bool {
    let ge = a.top5 >= b.top5 && a.fps >= b.fps && a.footprint_mb <= b.footprint_mb;
    let strict = a.top5 > b.top5 || a.fps > b.fps || a.footprint_mb < b.footprint_mb;
    ge && strict
}

/// Indices of the mutually non-dominated points (duplicates both survive).
pub fn pareto_indices(pts: &[Triple]) -> Vec<usize> {
    (0..pts.len())
        .filter(|&i| !pts.iter().enumerate().any(|(j, q)| j != i && dominates(q, &pts[i])))
        .collect()
}

/// One per-layer choice with its additive DP coordinates.
#[derive(Clone, Debug)]
struct MenuItem {
    groups: Vec<ChannelGroup>,
    /// Weighted noise contribution `s_l · Σ frac · n(wq)`.
    noise: f64,
    /// Weight bits `params_l · Σ frac · wq`.
    bits: f64,
    /// Serial-pass cost proxy `MACs_l · Σ frac · wq` (k=1 cycle count).
    cost: f64,
}

fn menu_for_layer(
    base: &Cnn,
    model: &SensitivityModel,
    li: usize,
    pcfg: &PlannerConfig,
) -> Vec<MenuItem> {
    let l = &base.layers[li];
    let (w, p, m) = (model.weight(li), l.params() as f64, l.macs() as f64);
    let wqs = pcfg.bits_menu();
    let item = |groups: Vec<ChannelGroup>| {
        let avg_n: f64 = groups.iter().map(|g| g.fraction * model.noise_power(g.wq)).sum();
        let avg_b: f64 = groups.iter().map(|g| g.fraction * g.wq as f64).sum();
        MenuItem {
            groups,
            noise: w * avg_n,
            bits: p * avg_b,
            cost: m * avg_b,
        }
    };
    let mut menu: Vec<MenuItem> =
        wqs.iter().map(|&wq| item(vec![ChannelGroup { wq, fraction: 1.0 }])).collect();
    for pair in wqs.windows(2) {
        let (lo, hi) = (pair[0], pair[1]);
        for &f in &pcfg.split_fractions {
            if f > 0.0 && f < 1.0 {
                menu.push(item(vec![
                    ChannelGroup { wq: lo, fraction: f },
                    ChannelGroup { wq: hi, fraction: 1.0 - f },
                ]));
            }
        }
    }
    menu
}

/// Greedy efficiency walk: from all-max-bits, repeatedly demote the single
/// layer whose next-lower uniform word-length saves the most weight bits
/// per unit of added aggregate noise.
fn chain_candidates(base: &Cnn, model: &SensitivityModel, pcfg: &PlannerConfig) -> Vec<Assignment> {
    let wqs = pcfg.bits_menu();
    if wqs.len() < 2 {
        return Vec::new();
    }
    let hi = *wqs.last().unwrap();
    let inner: Vec<usize> = (0..base.layers.len()).filter(|&i| !pinned(base, i)).collect();
    // Current uniform word-length index per inner layer (start at max).
    let mut level: Vec<usize> = vec![wqs.len() - 1; inner.len()];
    let mut cur = Assignment::uniform(base, hi);
    let mut out = Vec::new();
    loop {
        // Best next single-layer demotion by Δbits/Δnoise.
        let mut best: Option<(usize, f64)> = None;
        for (j, &li) in inner.iter().enumerate() {
            if level[j] == 0 {
                continue;
            }
            let l = &base.layers[li];
            let (from, to) = (wqs[level[j]], wqs[level[j] - 1]);
            let d_bits = l.params() as f64 * (from - to) as f64;
            let d_noise =
                model.weight(li) * (model.noise_power(to) - model.noise_power(from)).max(1e-300);
            let eff = d_bits / d_noise;
            if best.map_or(true, |(_, be)| eff > be) {
                best = Some((j, eff));
            }
        }
        let Some((j, _)) = best else { break };
        level[j] -= 1;
        cur.groups[inner[j]] = vec![ChannelGroup { wq: wqs[level[j]], fraction: 1.0 }];
        out.push(cur.clone());
    }
    out
}

/// Channel-split twists of the first few walk steps: the layers the walk
/// demotes first, split `lo@f / hi@(1-f)` instead of demoted outright.
fn split_candidates(base: &Cnn, model: &SensitivityModel, pcfg: &PlannerConfig) -> Vec<Assignment> {
    let wqs = pcfg.bits_menu();
    if wqs.len() < 2 || pcfg.split_fractions.is_empty() {
        return Vec::new();
    }
    let hi = *wqs.last().unwrap();
    let lo = wqs[wqs.len() - 2];
    let inner: Vec<usize> = (0..base.layers.len()).filter(|&i| !pinned(base, i)).collect();
    // Efficiency order for the hi -> lo step.
    let mut order: Vec<usize> = inner.clone();
    order.sort_by(|&a, &b| {
        let eff = |li: usize| {
            base.layers[li].params() as f64 * (hi - lo) as f64
                / (model.weight(li) * (model.noise_power(lo) - model.noise_power(hi))).max(1e-300)
        };
        eff(b).total_cmp(&eff(a))
    });
    let mut out = Vec::new();
    for &li in order.iter().take(3) {
        for &f in &pcfg.split_fractions {
            if f <= 0.0 || f >= 1.0 {
                continue;
            }
            let mut a = Assignment::uniform(base, hi);
            a.groups[li] = vec![
                ChannelGroup { wq: lo, fraction: f },
                ChannelGroup { wq: hi, fraction: 1.0 - f },
            ];
            out.push(a);
        }
    }
    out
}

#[derive(Clone, Debug)]
struct BeamState {
    noise: f64,
    bits: f64,
    cost: f64,
    choices: Vec<u16>,
}

/// Keep only states no other state weakly dominates (≤ on all three
/// coordinates; equal states collapse to the first).
fn prune_weakly_dominated(mut states: Vec<BeamState>) -> Vec<BeamState> {
    states.sort_by(|a, b| {
        a.noise
            .total_cmp(&b.noise)
            .then(a.bits.total_cmp(&b.bits))
            .then(a.cost.total_cmp(&b.cost))
    });
    let mut kept: Vec<BeamState> = Vec::new();
    'outer: for s in states {
        for k in &kept {
            if k.noise <= s.noise && k.bits <= s.bits && k.cost <= s.cost {
                continue 'outer;
            }
        }
        kept.push(s);
    }
    kept
}

/// Beam DP over the inner layers.
fn beam_candidates(base: &Cnn, model: &SensitivityModel, pcfg: &PlannerConfig) -> Vec<Assignment> {
    let inner: Vec<usize> = (0..base.layers.len()).filter(|&i| !pinned(base, i)).collect();
    let menus: Vec<Vec<MenuItem>> =
        inner.iter().map(|&li| menu_for_layer(base, model, li, pcfg)).collect();
    let beam = pcfg.beam_width.max(2);
    let mut states = vec![BeamState { noise: 0.0, bits: 0.0, cost: 0.0, choices: Vec::new() }];
    for menu in &menus {
        let mut next = Vec::with_capacity(states.len() * menu.len());
        for s in &states {
            for (mi, m) in menu.iter().enumerate() {
                let mut choices = s.choices.clone();
                choices.push(mi as u16);
                next.push(BeamState {
                    noise: s.noise + m.noise,
                    bits: s.bits + m.bits,
                    cost: s.cost + m.cost,
                    choices,
                });
            }
        }
        let mut pruned = prune_weakly_dominated(next);
        if pruned.len() > beam {
            // Thin to an evenly bits-spaced beam, keeping both extremes.
            pruned.sort_by(|a, b| a.bits.total_cmp(&b.bits));
            let last = pruned.len() - 1;
            let mut take: Vec<usize> = (0..beam).map(|j| j * last / (beam - 1)).collect();
            take.dedup();
            pruned = take.into_iter().map(|i| pruned[i].clone()).collect();
        }
        states = pruned;
    }
    states
        .into_iter()
        .map(|s| {
            let mut a = Assignment::uniform(base, 8);
            for (j, &li) in inner.iter().enumerate() {
                a.groups[li] = menus[j][s.choices[j] as usize].groups.clone();
            }
            a
        })
        .collect()
}

/// All candidate assignments worth evaluating, deduplicated.
pub fn enumerate_assignments(
    base: &Cnn,
    model: &SensitivityModel,
    pcfg: &PlannerConfig,
) -> Vec<Assignment> {
    let mut out = chain_candidates(base, model, pcfg);
    out.extend(split_candidates(base, model, pcfg));
    out.extend(beam_candidates(base, model, pcfg));
    let mut seen: Vec<Assignment> = Vec::with_capacity(out.len());
    for a in out {
        if !seen.contains(&a) {
            seen.push(a);
        }
    }
    seen
}

/// Pick at most `max_evals` candidates, evenly spaced over the log of their
/// aggregate noise (the accuracy proxy is log-sensitive near the quiet
/// anchors, so linear spacing would starve the high-accuracy end where the
/// dominating plans live).
pub fn thin_candidates(
    mut cands: Vec<Assignment>,
    model: &SensitivityModel,
    max_evals: usize,
) -> Vec<Assignment> {
    if cands.len() <= max_evals {
        return cands;
    }
    cands.sort_by(|a, b| model.aggregate_noise(a).total_cmp(&model.aggregate_noise(b)));
    let ln: Vec<f64> =
        cands.iter().map(|a| (model.aggregate_noise(a) + 1e-12).ln()).collect();
    let (lo, hi) = (ln[0], ln[ln.len() - 1]);
    let mut picked: Vec<usize> = Vec::with_capacity(max_evals);
    for t in 0..max_evals {
        let target = lo + (hi - lo) * t as f64 / (max_evals - 1).max(1) as f64;
        let i = ln
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| (*a - target).abs().total_cmp(&(*b - target).abs()))
            .map(|(i, _)| i)
            .unwrap();
        if !picked.contains(&i) {
            picked.push(i);
        }
    }
    picked.sort_unstable();
    picked.into_iter().map(|i| cands[i].clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;

    fn t(top5: f64, fps: f64, mb: f64) -> Triple {
        Triple { top5, fps, footprint_mb: mb }
    }

    #[test]
    fn dominance_definition() {
        assert!(dominates(&t(89.0, 100.0, 5.0), &t(89.0, 90.0, 5.0)));
        assert!(dominates(&t(89.0, 100.0, 4.0), &t(89.0, 100.0, 5.0)));
        // Equal points do not dominate each other.
        assert!(!dominates(&t(89.0, 100.0, 5.0), &t(89.0, 100.0, 5.0)));
        // A trade-off is incomparable.
        assert!(!dominates(&t(89.5, 90.0, 5.0), &t(89.0, 100.0, 5.0)));
        assert!(!dominates(&t(89.0, 90.0, 5.0), &t(89.5, 100.0, 4.0)));
    }

    #[test]
    fn pareto_keeps_only_nondominated() {
        let pts = vec![
            t(89.6, 130.0, 11.7), // dominated by the next point
            t(89.6, 140.0, 9.3),
            t(87.5, 320.0, 3.3),
            t(65.3, 320.0, 1.9),
        ];
        let keep = pareto_indices(&pts);
        assert_eq!(keep, vec![1, 2, 3]);
    }

    #[test]
    fn enumeration_covers_the_quiet_end_and_dedupes() {
        let base = resnet::resnet18();
        let pcfg = PlannerConfig::default();
        let model = SensitivityModel::build(&base, "ResNet-18", pcfg.alpha, &pcfg.wq_choices)
            .unwrap();
        let cands = enumerate_assignments(&base, &model, &pcfg);
        assert!(cands.len() > 20, "{}", cands.len());
        for (i, a) in cands.iter().enumerate() {
            assert!(!cands[..i].contains(a), "duplicate candidate at {i}");
            assert_eq!(a.groups.len(), base.layers.len());
        }
        // The first greedy step (one fat layer one notch down, rest at max)
        // must be among the candidates — it is the flagship low-noise plan.
        let n8 = model.aggregate_noise(&Assignment::uniform(&base, 8));
        let quiet = cands
            .iter()
            .filter(|a| a.uniform_wq(&base).is_none())
            .map(|a| model.aggregate_noise(a))
            .fold(f64::INFINITY, f64::min);
        assert!(quiet > n8 && quiet < n8 + 1e-3, "quietest mixed plan {quiet} vs n8 {n8}");
        // Some candidate carries a channel split.
        assert!(cands
            .iter()
            .any(|a| a.groups.iter().any(|g| g.len() > 1)));
    }

    #[test]
    fn thinning_respects_cap_and_keeps_extremes() {
        let base = resnet::resnet18();
        let pcfg = PlannerConfig::default();
        let model = SensitivityModel::build(&base, "ResNet-18", pcfg.alpha, &pcfg.wq_choices)
            .unwrap();
        let cands = enumerate_assignments(&base, &model, &pcfg);
        let noises: Vec<f64> = cands.iter().map(|a| model.aggregate_noise(a)).collect();
        let (lo, hi) = noises.iter().fold((f64::INFINITY, 0.0f64), |(l, h), &n| {
            (l.min(n), h.max(n))
        });
        let thin = thin_candidates(cands, &model, 8);
        assert!(thin.len() <= 8 && thin.len() >= 2);
        let tn: Vec<f64> = thin.iter().map(|a| model.aggregate_noise(a)).collect();
        assert!(tn.iter().any(|&n| n == lo), "quiet extreme kept");
        assert!(tn.iter().any(|&n| n == hi), "loud extreme kept");
    }
}
