//! Lower frontier points into the serving gateway's vocabulary.
//!
//! Each [`PlannedPoint`](super::PlannedPoint) becomes a
//! [`VariantSpec`] (plain `w<q>` for uniform baselines; a layerwise
//! [`VariantSpec::planned`] carrying the per-layer [`ChannelGroup`] lists
//! otherwise) plus a [`VariantProfile`] routing prior — proxy Top-5 from
//! the calibrated sensitivity model, fps and energy from the DSE-chosen
//! design — and a [`BatcherConfig`] whose virtual-FPGA clock runs at that
//! design's simulated frame rate. [`mock_family_server`] registers the
//! whole family on a [`ServerBuilder`] with deterministic mock backends so
//! the planned family can be booted (and routed against) without PJRT
//! artifacts; [`xmp_family_server`] does the same with real sliced-digit
//! execution ([`crate::xmp`], synthetic LSQ weights) so routed requests
//! return classes the kernels actually computed; production callers
//! register the same specs/profiles with `EngineBackend` factories
//! instead.

use super::PlanReport;
use crate::cnn::Cnn;
use crate::serving::{
    BatcherConfig, InferenceBackend, MockBackend, Server, ServerBuilder, VariantProfile,
    VariantSpec,
};
use crate::util::error::Result;
use crate::xmp::{XmpBackend, XmpConfig};

/// One servable variant emitted from the frontier.
#[derive(Clone, Debug)]
pub struct PlannedVariant {
    pub spec: VariantSpec,
    pub profile: VariantProfile,
    pub batcher: BatcherConfig,
}

/// Convert every frontier point of `report` into a servable variant, in
/// frontier order (descending proxy Top-5). Joint plans lower their
/// per-layer activation word-lengths into the spec
/// ([`VariantSpec::with_layerwise_aq`]), so the xmp backends slice
/// activations exactly as planned.
pub fn emit_variants(report: &PlanReport) -> Vec<PlannedVariant> {
    report
        .frontier
        .iter()
        .map(|p| {
            let spec = match p.uniform_wq {
                Some(wq) => VariantSpec::uniform(wq),
                None => {
                    let s = VariantSpec::planned(p.name.clone(), p.assignment.groups.clone());
                    if p.assignment.aq.iter().any(|&a| a != 8) {
                        s.with_layerwise_aq(p.assignment.aq.clone())
                    } else {
                        s
                    }
                }
            };
            let profile = VariantProfile {
                top5_accuracy: Some(p.proxy_top5),
                fpga_fps: p.fps,
                fpga_mj_per_frame: p.mj_per_frame,
            };
            let batcher = BatcherConfig { fpga_fps_sim: p.fps, ..BatcherConfig::default() };
            PlannedVariant { spec, profile, batcher }
        })
        .collect()
}

/// Register `variants` on `builder` with deterministic [`MockBackend`]s
/// whose service time tracks each design's simulated frame time.
pub fn register_mock_family(
    mut builder: ServerBuilder,
    variants: Vec<PlannedVariant>,
    image_len: usize,
    classes: usize,
) -> ServerBuilder {
    for v in variants {
        let latency_us = (1e6 / v.profile.fpga_fps.max(1.0)).clamp(100.0, 20_000.0) as u64;
        let max_batch = v.batcher.max_batch.max(1);
        builder = builder.variant_with_profile(v.spec, v.profile, v.batcher, move || {
            Ok(Box::new(MockBackend::new(image_len, classes, vec![1, max_batch], latency_us))
                as Box<dyn InferenceBackend>)
        });
    }
    builder
}

/// Boot the emitted family end to end on mock backends: the round-trip the
/// planner integration tests (and `mpcnn plan`) exercise.
pub fn mock_family_server(report: &PlanReport, image_len: usize, classes: usize) -> Result<Server> {
    let variants = emit_variants(report);
    if variants.is_empty() {
        return Err(crate::anyhow!("plan frontier is empty — nothing to serve"));
    }
    register_mock_family(Server::builder(), variants, image_len, classes).build()
}

/// Register `variants` on `builder` with REAL sliced-digit execution: one
/// [`XmpBackend`] per variant, synthetic LSQ weights honoring each spec's
/// per-layer plan on `base`. The executable counterpart of
/// [`register_mock_family`] — same specs, profiles, and batcher configs,
/// but routed requests come back with argmax classes the xmp kernels
/// actually computed.
pub fn register_xmp_family(
    mut builder: ServerBuilder,
    variants: Vec<PlannedVariant>,
    base: &Cnn,
    xcfg: XmpConfig,
) -> ServerBuilder {
    for v in variants {
        let spec = v.spec.clone();
        let base = base.clone();
        builder = builder.variant_with_profile(v.spec, v.profile, v.batcher, move || {
            Ok(Box::new(XmpBackend::from_spec(&base, &spec, xcfg)?)
                as Box<dyn InferenceBackend>)
        });
    }
    builder
}

/// Boot the emitted family on xmp backends: every planned variant —
/// layerwise and channelwise plans included — executes real mixed-precision
/// integer arithmetic end to end.
pub fn xmp_family_server(report: &PlanReport, base: &Cnn, xcfg: XmpConfig) -> Result<Server> {
    let variants = emit_variants(report);
    if variants.is_empty() {
        return Err(crate::anyhow!("plan frontier is empty — nothing to serve"));
    }
    register_xmp_family(Server::builder(), variants, base, xcfg).build()
}

#[cfg(test)]
mod tests {
    use super::super::{plan, PlannerConfig};
    use super::*;
    use crate::cnn::resnet;
    use crate::config::RunConfig;
    use crate::serving::{InferRequest, VariantSelector};

    fn small_report() -> super::super::PlanReport {
        // Tiny budget on the exported ResNet-8 topology: fast and
        // deterministic.
        let base = resnet::resnet_small(1, 10);
        let cfg = RunConfig { slices: vec![2], ..RunConfig::default() };
        let pcfg = PlannerConfig {
            wq_choices: vec![2, 8],
            beam_width: 8,
            max_evals: 4,
            ..PlannerConfig::default()
        };
        plan(&base, &cfg, &pcfg).unwrap()
    }

    #[test]
    fn emitted_family_boots_on_xmp_backends() {
        // The planned family on REAL sliced-digit backends: every variant
        // (layerwise plans included) answers with a class its own xmp
        // kernels computed — verified against an independently built copy
        // of the same deterministic model.
        let base = resnet::resnet_small(1, 10);
        let report = small_report();
        let xcfg = crate::xmp::XmpConfig::default();
        let server = xmp_family_server(&report, &base, xcfg).unwrap();
        assert_eq!(server.n_variants(), report.frontier.len());
        let img = vec![0.8f32; 3072];
        for v in emit_variants(&report) {
            let probe = crate::xmp::XmpBackend::from_spec(&base, &v.spec, xcfg).unwrap();
            let want = probe.classify_one(&img).unwrap();
            let resp = server
                .infer(
                    InferRequest::new(img.clone())
                        .with_variant(VariantSelector::Named(v.spec.name.clone())),
                )
                .unwrap();
            assert_eq!(resp.variant, v.spec.name);
            assert_eq!(resp.class, want, "variant {} diverged from probe", v.spec.name);
        }
        server.shutdown();
    }

    #[test]
    fn joint_planned_family_boots_on_xmp_backends() {
        // A joint (wq, aq) plan run end to end: the emitted layerwise
        // specs carry per-layer activation word-lengths, and every
        // variant still answers with its own kernels' class.
        let base = resnet::resnet_small(1, 10);
        let cfg = RunConfig { slices: vec![2], ..RunConfig::default() };
        let pcfg = PlannerConfig {
            wq_choices: vec![2, 8],
            aq_choices: vec![4, 8],
            beam_width: 8,
            max_evals: 4,
            ..PlannerConfig::default()
        };
        let report = plan(&base, &cfg, &pcfg).unwrap();
        let variants = emit_variants(&report);
        // At least one emitted mixed variant narrows an activation.
        let narrowed: Vec<&PlannedVariant> = variants
            .iter()
            .filter(|v| v.spec.layerwise_aq.iter().any(|&a| a != 8))
            .collect();
        assert!(
            !narrowed.is_empty(),
            "a [2,8]x[4,8] joint search should emit a reduced-aq plan; frontier: {:?}",
            report.frontier.iter().map(|p| p.assignment.describe(&base)).collect::<Vec<_>>()
        );
        let xcfg = crate::xmp::XmpConfig::default();
        let server = xmp_family_server(&report, &base, xcfg).unwrap();
        let img = vec![0.6f32; 3072];
        for v in &variants {
            let probe = crate::xmp::XmpBackend::from_spec(&base, &v.spec, xcfg).unwrap();
            let want = probe.classify_one(&img).unwrap();
            let resp = server
                .infer(
                    InferRequest::new(img.clone())
                        .with_variant(VariantSelector::Named(v.spec.name.clone())),
                )
                .unwrap();
            assert_eq!(resp.class, want, "variant {} diverged from probe", v.spec.name);
        }
        server.shutdown();
    }

    #[test]
    fn emitted_family_boots_and_routes() {
        let report = small_report();
        let variants = emit_variants(&report);
        assert_eq!(variants.len(), report.frontier.len());
        assert!(!variants.is_empty());
        for v in &variants {
            assert!(v.profile.fpga_fps > 0.0);
            assert!((v.batcher.fpga_fps_sim - v.profile.fpga_fps).abs() < 1e-9);
            assert!(v.profile.top5_accuracy.is_some());
        }
        let server = mock_family_server(&report, 12, 10).unwrap();
        assert_eq!(server.n_variants(), report.frontier.len());
        // Every planned variant is routable by name.
        for p in &report.frontier {
            let resp = server
                .infer(
                    InferRequest::new(vec![0.5; 12])
                        .with_variant(VariantSelector::Named(p.name.clone())),
                )
                .unwrap();
            assert_eq!(resp.variant, p.name);
        }
        server.shutdown();
    }
}
