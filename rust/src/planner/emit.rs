//! Lower frontier points into the serving gateway's vocabulary.
//!
//! Each [`PlannedPoint`](super::PlannedPoint) becomes a
//! [`VariantSpec`] (plain `w<q>` for uniform baselines; a layerwise
//! [`VariantSpec::planned`] carrying the per-layer [`ChannelGroup`] lists
//! otherwise) plus a [`VariantProfile`] routing prior — proxy Top-5 from
//! the calibrated sensitivity model, fps and energy from the DSE-chosen
//! design — and a [`BatcherConfig`] whose virtual-FPGA clock runs at that
//! design's simulated frame rate. [`mock_family_server`] registers the
//! whole family on a [`ServerBuilder`] with deterministic mock backends so
//! the planned family can be booted (and routed against) without PJRT
//! artifacts; production callers register the same specs/profiles with
//! `EngineBackend` factories instead.

use super::PlanReport;
use crate::serving::{
    BatcherConfig, InferenceBackend, MockBackend, Server, ServerBuilder, VariantProfile,
    VariantSpec,
};
use crate::util::error::Result;

/// One servable variant emitted from the frontier.
#[derive(Clone, Debug)]
pub struct PlannedVariant {
    pub spec: VariantSpec,
    pub profile: VariantProfile,
    pub batcher: BatcherConfig,
}

/// Convert every frontier point of `report` into a servable variant, in
/// frontier order (descending proxy Top-5).
pub fn emit_variants(report: &PlanReport) -> Vec<PlannedVariant> {
    report
        .frontier
        .iter()
        .map(|p| {
            let spec = match p.uniform_wq {
                Some(wq) => VariantSpec::uniform(wq),
                None => VariantSpec::planned(p.name.clone(), p.assignment.groups.clone()),
            };
            let profile = VariantProfile {
                top5_accuracy: Some(p.proxy_top5),
                fpga_fps: p.fps,
                fpga_mj_per_frame: p.mj_per_frame,
            };
            let batcher = BatcherConfig { fpga_fps_sim: p.fps, ..BatcherConfig::default() };
            PlannedVariant { spec, profile, batcher }
        })
        .collect()
}

/// Register `variants` on `builder` with deterministic [`MockBackend`]s
/// whose service time tracks each design's simulated frame time.
pub fn register_mock_family(
    mut builder: ServerBuilder,
    variants: Vec<PlannedVariant>,
    image_len: usize,
    classes: usize,
) -> ServerBuilder {
    for v in variants {
        let latency_us = (1e6 / v.profile.fpga_fps.max(1.0)).clamp(100.0, 20_000.0) as u64;
        let max_batch = v.batcher.max_batch.max(1);
        builder = builder.variant_with_profile(v.spec, v.profile, v.batcher, move || {
            Ok(Box::new(MockBackend::new(image_len, classes, vec![1, max_batch], latency_us))
                as Box<dyn InferenceBackend>)
        });
    }
    builder
}

/// Boot the emitted family end to end on mock backends: the round-trip the
/// planner integration tests (and `mpcnn plan`) exercise.
pub fn mock_family_server(report: &PlanReport, image_len: usize, classes: usize) -> Result<Server> {
    let variants = emit_variants(report);
    if variants.is_empty() {
        return Err(crate::anyhow!("plan frontier is empty — nothing to serve"));
    }
    register_mock_family(Server::builder(), variants, image_len, classes).build()
}

#[cfg(test)]
mod tests {
    use super::super::{plan, PlannerConfig};
    use super::*;
    use crate::cnn::resnet;
    use crate::config::RunConfig;
    use crate::serving::{InferRequest, VariantSelector};

    fn small_report() -> super::super::PlanReport {
        // Tiny budget on the exported ResNet-8 topology: fast and
        // deterministic.
        let base = resnet::resnet_small(1, 10);
        let cfg = RunConfig { slices: vec![2], ..RunConfig::default() };
        let pcfg = PlannerConfig {
            wq_choices: vec![2, 8],
            beam_width: 8,
            max_evals: 4,
            ..PlannerConfig::default()
        };
        plan(&base, &cfg, &pcfg).unwrap()
    }

    #[test]
    fn emitted_family_boots_and_routes() {
        let report = small_report();
        let variants = emit_variants(&report);
        assert_eq!(variants.len(), report.frontier.len());
        assert!(!variants.is_empty());
        for v in &variants {
            assert!(v.profile.fpga_fps > 0.0);
            assert!((v.batcher.fpga_fps_sim - v.profile.fpga_fps).abs() < 1e-9);
            assert!(v.profile.top5_accuracy.is_some());
        }
        let server = mock_family_server(&report, 12, 10).unwrap();
        assert_eq!(server.n_variants(), report.frontier.len());
        // Every planned variant is routable by name.
        for p in &report.frontier {
            let resp = server
                .infer(
                    InferRequest::new(vec![0.5; 12])
                        .with_variant(VariantSelector::Named(p.name.clone())),
                )
                .unwrap();
            assert_eq!(resp.variant, p.name);
        }
        server.shutdown();
    }
}
