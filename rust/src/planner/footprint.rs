//! Footprint side of the planner triple — a thin aggregation over the
//! existing Table III models in [`crate::cnn::workload`], evaluated on the
//! *planned* (layer/channel-wise quantized) CNN.

use crate::cnn::{workload, Cnn};

/// Memory footprint summary of one planned CNN.
#[derive(Clone, Copy, Debug)]
pub struct PlanFootprint {
    /// Weight storage at the assigned word-lengths, MB.
    pub weight_mb: f64,
    /// Peak activation working set at the assigned activation
    /// word-lengths (Table III's activation-buffer bytes; the planned
    /// CNN's `act_bits` carry the per-layer `a_Q`), MB.
    pub act_mb: f64,
    /// Weights + BN/bias + peak activation working set, MB.
    pub total_mb: f64,
    /// Weight compression vs the FP32 baseline (the abstract's 4.9x/9.4x
    /// metric).
    pub compression: f64,
    /// Parameter-weighted average weight word-length in bits.
    pub avg_bits: f64,
}

impl PlanFootprint {
    pub fn of(cnn: &Cnn) -> PlanFootprint {
        let f = workload::footprint(cnn);
        let params: u64 = cnn.total_params();
        PlanFootprint {
            weight_mb: f.weight_mb(),
            act_mb: f.peak_activation_bits as f64 / 8.0 / 1e6,
            total_mb: f.total_mb(),
            compression: workload::weight_compression_factor(cnn),
            avg_bits: f.weight_bits as f64 / (params as f64).max(1.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;

    #[test]
    fn tracks_workload_models_and_orders_by_wq() {
        let w2 = PlanFootprint::of(&resnet::resnet18().with_uniform_wq(2));
        let w8 = PlanFootprint::of(&resnet::resnet18().with_uniform_wq(8));
        assert!(w2.weight_mb < w8.weight_mb);
        assert!(w2.compression > w8.compression);
        assert!(w2.avg_bits > 2.0 && w2.avg_bits < 3.0, "{}", w2.avg_bits);
        assert!((w8.avg_bits - 8.0).abs() < 1e-9);
        assert!(w8.total_mb > w8.weight_mb);
        assert!(w8.act_mb > 0.0);
    }

    #[test]
    fn act_mb_tracks_activation_word_lengths() {
        use crate::cnn::channelwise::{apply_joint_plan, apply_plan};
        use crate::cnn::ChannelGroup;
        let base = resnet::resnet18();
        let plan: Vec<Vec<ChannelGroup>> = base
            .layers
            .iter()
            .map(|_| vec![ChannelGroup { wq: 8, fraction: 1.0 }])
            .collect();
        let a8 = PlanFootprint::of(&apply_plan(&base, &plan));
        let aq: Vec<u32> = vec![4; base.layers.len()];
        let a4 = PlanFootprint::of(&apply_joint_plan(&base, &plan, &aq));
        assert!(a4.act_mb < a8.act_mb, "{} vs {}", a4.act_mb, a8.act_mb);
        assert_eq!(a4.weight_mb, a8.weight_mb, "weights untouched by aq");
        assert!((a4.act_mb - a8.act_mb / 2.0).abs() < 1e-9, "4 bit = half of 8");
    }
}
