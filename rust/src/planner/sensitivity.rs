//! Quantization-sensitivity accuracy proxy.
//!
//! Per-layer model: quantizing layer `l` to word-length `w` injects noise
//! with power `n(w)` per weight (LSQ MSE on a fixed reference distribution,
//! [`crate::quant::lsq::reference_noise_power`]); the layer's impact weight
//! is `s_l ∝ MACs_l · (p̄ / params_l)^α` — each weight's error is counted
//! once per MAC it feeds, attenuated by over-parameterization (layers with
//! many parameters average out more independent noise terms; `α` is the
//! redundancy exponent, default 1.0 — see EXPERIMENTS.md §Planner). The
//! aggregate noise of an assignment is the `s`-weighted mean of its
//! per-layer (fraction-weighted, for channel groups) noise powers.
//!
//! Aggregate noise maps to Top-1/Top-5 percent by piecewise-linear
//! interpolation through the paper's uniform-`w_Q` anchors
//! ([`crate::report::paper::accuracy_anchors`], Table III + the Table IV/V
//! 8-bit rows): a uniform assignment's aggregate noise is exactly `n(w_Q)`,
//! so the proxy reproduces every anchor bit-for-bit by construction, and
//! mixed assignments interpolate between them. Proxies are quoted at the
//! anchors' own resolution (0.01%); differences below that are not
//! meaningful under this calibration.
//!
//! **Activation word-lengths.** Reducing a layer's `a_Q` injects its own
//! quantization noise ([`crate::quant::lsq::reference_activation_noise_power`],
//! an LSQ-initialized unsigned quantizer over a half-normal post-ReLU
//! reference). The paper's anchors all sit at the fixed 8-bit activation
//! point, so the activation term enters as a **delta against that
//! reference**: layer `l` contributes `s_l · (n_act(a_l) − n_act(8))` on
//! top of its weight term. At `a_Q = 8` the delta is exactly `0.0` (the
//! same f64 subtracted from itself), so every weight-only anchor — and
//! every pre-activation-planning proxy value — is reproduced bit-for-bit;
//! narrower activations push the aggregate toward the noisy anchors the
//! same way narrower weights do.

use super::Assignment;
use crate::cnn::Cnn;
use crate::quant::lsq::{reference_activation_noise_power, reference_noise_power};
use crate::report::paper;
use crate::util::error::Result;

/// The calibrated proxy for one (base CNN, accuracy family) pair.
#[derive(Clone, Debug)]
pub struct SensitivityModel {
    /// Per-layer sensitivity weights over the base CNN, normalized to sum 1
    /// (0 for the pinned first/last/FC layers).
    weights: Vec<f64>,
    /// `(bits, noise power)` menu for weights, ascending bits.
    noise: Vec<(u32, f64)>,
    /// `(bits, activation noise power)` menu, ascending bits.
    act_noise: Vec<(u32, f64)>,
    /// The 8-bit activation reference `n_act(8)` the deltas are taken
    /// against.
    act_noise_ref: f64,
    /// `(aggregate noise, top1, top5)` anchors, ascending noise.
    anchors: Vec<(f64, f64, f64)>,
}

impl SensitivityModel {
    /// Build and calibrate the model. `family` names the paper's accuracy
    /// tables (e.g. `"ResNet-18"`); `wq_menu` / `aq_menu` list every
    /// weight / activation word-length the search may assign. Fails when
    /// the paper has no anchors for `family`.
    pub fn build(
        base: &Cnn,
        family: &str,
        alpha: f64,
        wq_menu: &[u32],
        aq_menu: &[u32],
    ) -> Result<SensitivityModel> {
        assert!(alpha >= 0.0, "redundancy exponent must be non-negative");
        let n_layers = base.layers.len();
        let inner: Vec<usize> = (0..n_layers).filter(|&i| !super::pinned(base, i)).collect();
        if inner.is_empty() {
            return Err(crate::anyhow!("CNN '{}' has no inner layers to plan", base.name));
        }
        let p_bar = inner
            .iter()
            .map(|&i| base.layers[i].params() as f64)
            .sum::<f64>()
            / inner.len() as f64;
        let mut weights = vec![0.0; n_layers];
        for &i in &inner {
            let l = &base.layers[i];
            weights[i] = l.macs() as f64 * (p_bar / (l.params() as f64).max(1.0)).powf(alpha);
        }
        let total: f64 = weights.iter().sum();
        for w in &mut weights {
            *w /= total;
        }

        if let Some(bad) = wq_menu.iter().find(|b| !(1..=8).contains(*b)) {
            return Err(crate::anyhow!(
                "word-length menu entry {bad} is outside the supported 1..=8 bit range"
            ));
        }
        if let Some(bad) = aq_menu.iter().find(|b| !(1..=8).contains(*b)) {
            return Err(crate::anyhow!(
                "activation word-length menu entry {bad} is outside the supported 1..=8 bit range"
            ));
        }
        let mut bits: Vec<u32> = wq_menu.to_vec();
        bits.extend([1, 2, 4, 8]);
        bits.sort_unstable();
        bits.dedup();
        let noise: Vec<(u32, f64)> = bits.iter().map(|&b| (b, reference_noise_power(b))).collect();
        let np = |b: u32| noise.iter().find(|(bb, _)| *bb == b).unwrap().1;
        let mut abits: Vec<u32> = aq_menu.to_vec();
        abits.push(8);
        abits.sort_unstable();
        abits.dedup();
        let act_noise: Vec<(u32, f64)> = abits
            .iter()
            .map(|&b| (b, reference_activation_noise_power(b)))
            .collect();
        let act_noise_ref = reference_activation_noise_power(8);

        // Anchors: a uniform-wq assignment aggregates to exactly n(wq).
        let mut anchors: Vec<(f64, f64, f64)> = paper::accuracy_anchors(family)
            .into_iter()
            .filter(|(wq, _, _)| (1..=8).contains(wq))
            .map(|(wq, t1, t5)| (np(wq), t1, t5))
            .collect();
        // Families without an 8-bit row (ResNet-50) get their low-noise
        // anchor from the FP32 baseline at zero noise.
        if !anchors.iter().any(|(x, _, _)| *x <= np(8)) {
            if let (Some(t1), Some(t5)) =
                (paper::top1_accuracy(family, 0), paper::top5_accuracy(family, 0))
            {
                anchors.push((0.0, t1, t5));
            }
        }
        anchors.sort_by(|a, b| a.0.total_cmp(&b.0));
        if anchors.len() < 2 {
            return Err(crate::anyhow!(
                "no paper accuracy anchors for family '{family}' (try ResNet-18/50/152)"
            ));
        }
        Ok(SensitivityModel {
            weights,
            noise,
            act_noise,
            act_noise_ref,
            anchors,
        })
    }

    /// Noise power of one weight word-length from the model's menu
    /// (computes on the fly for bits outside it).
    pub fn noise_power(&self, bits: u32) -> f64 {
        self.noise
            .iter()
            .find(|(b, _)| *b == bits)
            .map(|(_, n)| *n)
            .unwrap_or_else(|| reference_noise_power(bits))
    }

    /// Noise power of one activation word-length from the model's menu
    /// (computes on the fly for bits outside it).
    pub fn activation_noise_power(&self, bits: u32) -> f64 {
        self.act_noise
            .iter()
            .find(|(b, _)| *b == bits)
            .map(|(_, n)| *n)
            .unwrap_or_else(|| reference_activation_noise_power(bits))
    }

    /// The per-layer activation-noise **delta** against the paper's fixed
    /// 8-bit activation point: `n_act(bits) − n_act(8)`. Exactly `0.0` at
    /// 8 bit — the calibration that keeps the weight-only anchors
    /// bit-for-bit.
    pub fn activation_noise_delta(&self, bits: u32) -> f64 {
        self.activation_noise_power(bits) - self.act_noise_ref
    }

    /// Normalized sensitivity weight of layer `i` of the base CNN.
    pub fn weight(&self, i: usize) -> f64 {
        self.weights[i]
    }

    /// Sensitivity-weighted mean noise power of an assignment: per layer,
    /// the fraction-weighted weight-quantization noise of its channel
    /// groups plus the activation-noise delta of its `a_Q`.
    pub fn aggregate_noise(&self, a: &Assignment) -> f64 {
        assert_eq!(a.groups.len(), self.weights.len(), "assignment/base mismatch");
        assert_eq!(a.aq.len(), self.weights.len(), "activation plan/base mismatch");
        let mut acc = 0.0;
        for ((groups, &aq), &w) in a.groups.iter().zip(&a.aq).zip(&self.weights) {
            if w == 0.0 {
                continue;
            }
            let layer_noise: f64 = groups
                .iter()
                .map(|g| g.fraction * self.noise_power(g.wq))
                .sum();
            acc += w * (layer_noise + self.activation_noise_delta(aq));
        }
        acc
    }

    /// Proxy Top-5 percent, at the anchors' 0.01 resolution.
    pub fn proxy_top5(&self, a: &Assignment) -> f64 {
        round2(self.interp(self.aggregate_noise(a), |(_, _, t5)| *t5))
    }

    /// Proxy Top-1 percent, at the anchors' 0.01 resolution.
    pub fn proxy_top1(&self, a: &Assignment) -> f64 {
        round2(self.interp(self.aggregate_noise(a), |(_, t1, _)| *t1))
    }

    fn interp(&self, x: f64, pick: fn(&(f64, f64, f64)) -> f64) -> f64 {
        let first = &self.anchors[0];
        let last = &self.anchors[self.anchors.len() - 1];
        if x <= first.0 {
            return pick(first);
        }
        if x >= last.0 {
            return pick(last);
        }
        for pair in self.anchors.windows(2) {
            let (x0, x1) = (pair[0].0, pair[1].0);
            if x >= x0 && x <= x1 {
                let t = if x1 > x0 { (x - x0) / (x1 - x0) } else { 0.0 };
                return pick(&pair[0]) + t * (pick(&pair[1]) - pick(&pair[0]));
            }
        }
        pick(last)
    }
}

fn round2(x: f64) -> f64 {
    (x * 100.0).round() / 100.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;

    fn model() -> SensitivityModel {
        SensitivityModel::build(&resnet::resnet18(), "ResNet-18", 1.0, &[1, 2, 4, 8], &[4, 8])
            .unwrap()
    }

    #[test]
    fn uniform_assignments_reproduce_the_paper_anchors() {
        let base = resnet::resnet18();
        let m = model();
        for (wq, want) in [(1u32, 65.29), (2, 87.48), (4, 89.10), (8, 89.62)] {
            let a = Assignment::uniform(&base, wq);
            assert_eq!(m.proxy_top5(&a), want, "w{wq}");
        }
        let a4 = Assignment::uniform(&base, 4);
        assert_eq!(m.proxy_top1(&a4), 69.75);
    }

    #[test]
    fn aggregate_noise_monotone_in_assignment_bits() {
        let base = resnet::resnet18();
        let m = model();
        let n8 = m.aggregate_noise(&Assignment::uniform(&base, 8));
        let n4 = m.aggregate_noise(&Assignment::uniform(&base, 4));
        let n1 = m.aggregate_noise(&Assignment::uniform(&base, 1));
        assert!(n8 < n4 && n4 < n1, "{n8} {n4} {n1}");
        // A mixed plan lands strictly between its bracketing uniforms.
        let mut mixed = Assignment::uniform(&base, 4);
        let fat = (0..base.layers.len())
            .filter(|&i| !super::super::pinned(&base, i))
            .max_by_key(|&i| base.layers[i].params())
            .unwrap();
        mixed.groups[fat] = vec![crate::cnn::ChannelGroup { wq: 2, fraction: 1.0 }];
        let nm = m.aggregate_noise(&mixed);
        let n2 = m.aggregate_noise(&Assignment::uniform(&base, 2));
        assert!(nm > n4 && nm < n2, "{n4} {nm} {n2}");
    }

    #[test]
    fn fat_layer_demotion_costs_less_than_thin_layer_demotion() {
        // The redundancy discount: demoting the biggest-parameter inner
        // layer adds less aggregate noise than demoting an early thin one
        // with comparable MACs — the asymmetry the planner exploits.
        let base = resnet::resnet18();
        let m = model();
        let inner: Vec<usize> =
            (0..base.layers.len()).filter(|&i| !super::super::pinned(&base, i)).collect();
        let fat = *inner.iter().max_by_key(|&&i| base.layers[i].params()).unwrap();
        let thin = *inner.iter().min_by_key(|&&i| base.layers[i].params()).unwrap();
        let demote = |i: usize| {
            let mut a = Assignment::uniform(&base, 8);
            a.groups[i] = vec![crate::cnn::ChannelGroup { wq: 4, fraction: 1.0 }];
            m.aggregate_noise(&a)
        };
        assert!(demote(fat) < demote(thin));
    }

    #[test]
    fn activation_term_is_zero_at_8_bit_and_monotone_below() {
        let base = resnet::resnet18();
        let m = model();
        // The calibration contract: at aq = 8 the delta is EXACTLY zero,
        // so the aggregate (and hence every proxy value) is bit-for-bit
        // the weight-only number.
        assert_eq!(m.activation_noise_delta(8).to_bits(), 0.0f64.to_bits());
        let w4 = Assignment::uniform(&base, 4);
        let mut w4a8 = w4.clone();
        w4a8.aq = vec![8; base.layers.len()];
        assert_eq!(
            m.aggregate_noise(&w4).to_bits(),
            m.aggregate_noise(&w4a8).to_bits(),
            "explicit aq=8 must not move the aggregate by a single bit"
        );
        // Narrower activations add noise, monotonically.
        let mut prev = m.aggregate_noise(&w4);
        for aq in [6u32, 4, 2, 1] {
            let a = Assignment::uniform_joint(&base, 4, aq);
            let n = m.aggregate_noise(&a);
            assert!(n > prev, "aq={aq}: {n} should exceed {prev}");
            prev = n;
        }
        // And the proxy accuracy falls accordingly.
        let t_w4 = m.proxy_top5(&Assignment::uniform(&base, 4));
        let t_w4a2 = m.proxy_top5(&Assignment::uniform_joint(&base, 4, 2));
        assert!(t_w4a2 < t_w4, "{t_w4a2} vs {t_w4}");
    }

    #[test]
    fn resnet50_calibrates_via_fp32_anchor() {
        let base = resnet::resnet50();
        let m = SensitivityModel::build(&base, "ResNet-50", 1.0, &[1, 2, 4, 8], &[8]).unwrap();
        assert_eq!(m.proxy_top5(&Assignment::uniform(&base, 2)), 92.24);
        // Quieter than the 4-bit anchor interpolates toward the FP32 row.
        let t8 = m.proxy_top5(&Assignment::uniform(&base, 8));
        assert!(t8 >= 92.93 && t8 <= 93.07, "{t8}");
    }

    #[test]
    fn unknown_family_is_an_error() {
        assert!(SensitivityModel::build(&resnet::resnet18(), "VGG-16", 1.0, &[2], &[8]).is_err());
    }

    #[test]
    fn out_of_range_menu_is_an_error_not_a_panic() {
        // `plan --bits 2,4,16` must surface as a clean error.
        let r = SensitivityModel::build(&resnet::resnet18(), "ResNet-18", 1.0, &[2, 4, 16], &[8]);
        assert!(r.unwrap_err().to_string().contains("1..=8"));
        let m = SensitivityModel::build(&resnet::resnet18(), "ResNet-18", 1.0, &[0], &[8]);
        assert!(m.is_err());
        let m = SensitivityModel::build(&resnet::resnet18(), "ResNet-18", 1.0, &[2], &[0]);
        assert!(m.is_err());
        let m = SensitivityModel::build(&resnet::resnet18(), "ResNet-18", 1.0, &[2], &[9]);
        assert!(m.is_err());
    }
}
