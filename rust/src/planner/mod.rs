//! Precision planner: layer/channel-wise word-length search emitting the
//! Pareto variant family.
//!
//! The paper *chooses* its mixed-precision assignments by hand (uniform
//! inner `w_Q` per variant, Table III/IV); this subsystem automates the
//! choice, in the spirit of DeepBurning-MixQ and Zhao et al. (PAPERS.md):
//! it searches per-layer — and per-channel-group, via
//! [`crate::cnn::channelwise`] — weight word-length assignments for a CNN
//! under the FPGA budgets, and extracts the
//! (proxy-accuracy, throughput, footprint) Pareto frontier.
//!
//! Pipeline (one [`plan`] call):
//!
//! 1. [`sensitivity`] — calibrate the MAC-weighted quantization-noise
//!    accuracy proxy against the paper's Table III anchors (via
//!    [`crate::quant::lsq`]).
//! 2. [`frontier`] — enumerate candidate assignments (greedy efficiency
//!    walk + channel-split twists + beam DP with monotone-dominance
//!    pruning) and thin them to an evaluation budget.
//! 3. Evaluate every candidate and every uniform baseline through the
//!    PR-1 cached holistic DSE ([`crate::dse::explore_cached`]) and the
//!    Table III footprint models ([`footprint`]).
//! 4. Pareto-filter the union and record which uniform variants the mixed
//!    plans dominate.
//! 5. [`emit`] — lower frontier points to [`crate::serving::VariantSpec`]s
//!    plus routing profiles, so a [`crate::serving::ServerBuilder`] can
//!    host the *planned* family end to end.
//!
//! CLI: `mpcnn plan --cnn resnet18`; benchmark: `cargo bench --bench
//! planner`; knobs and reproduction notes: EXPERIMENTS.md §Planner.

pub mod emit;
pub mod footprint;
pub mod frontier;
pub mod sensitivity;

pub use emit::{
    emit_variants, mock_family_server, register_xmp_family, xmp_family_server, PlannedVariant,
};
pub use footprint::PlanFootprint;
pub use frontier::{dominates, pareto_indices, Triple};
pub use sensitivity::SensitivityModel;

use crate::array::Dims;
use crate::cnn::{ChannelGroup, Cnn, LayerKind};
use crate::config::RunConfig;
use crate::dse::{self, DseCache};
use crate::util::error::Result;
use crate::util::table::{fnum, Table};

/// Layers the paper pins to 8 bit (first, last, FC) — excluded from the
/// search, exactly as in [`crate::cnn::channelwise::apply_channelwise`] and
/// [`Cnn::with_uniform_wq`].
pub(crate) fn pinned(base: &Cnn, i: usize) -> bool {
    i == 0 || i + 1 == base.layers.len() || base.layers[i].kind == LayerKind::Fc
}

/// A per-layer **joint** precision assignment over a base CNN: one
/// [`ChannelGroup`] list (weights; single entry = uniform layer, multiple
/// entries = channel-wise split) and one activation word-length per
/// layer. Pinned layers always carry `[w8 @ 1.0]` and `a8`.
#[derive(Clone, Debug, PartialEq)]
pub struct Assignment {
    pub groups: Vec<Vec<ChannelGroup>>,
    /// Per-layer activation word-lengths (the paper fixes 8; the joint
    /// search may narrow inner layers).
    pub aq: Vec<u32>,
}

impl Assignment {
    /// Every inner layer at `wq`, pinned layers at 8 bit, every
    /// activation at the paper's fixed 8 bit.
    pub fn uniform(base: &Cnn, wq: u32) -> Assignment {
        Assignment::uniform_joint(base, wq, 8)
    }

    /// Every inner layer at `(wq, aq)`, pinned layers at `(8, 8)`.
    pub fn uniform_joint(base: &Cnn, wq: u32, aq: u32) -> Assignment {
        let n = base.layers.len();
        let groups = (0..n)
            .map(|i| {
                let w = if pinned(base, i) { 8 } else { wq };
                vec![ChannelGroup { wq: w, fraction: 1.0 }]
            })
            .collect();
        let aq = (0..n)
            .map(|i| if pinned(base, i) { 8 } else { aq })
            .collect();
        Assignment { groups, aq }
    }

    /// `Some(wq)` when every inner layer is a single group at the same
    /// word-length **with activations at the paper's fixed 8 bit** (the
    /// assignment is expressible as one of the uniform paper baselines —
    /// a reduced-activation uniform plan is not, and must survive as a
    /// mixed candidate).
    pub fn uniform_wq(&self, base: &Cnn) -> Option<u32> {
        let mut seen: Option<u32> = None;
        for (i, (g, &a)) in self.groups.iter().zip(&self.aq).enumerate() {
            if pinned(base, i) {
                continue;
            }
            if g.len() != 1 || a != 8 {
                return None;
            }
            match seen {
                None => seen = Some(g[0].wq),
                Some(w) if w == g[0].wq => {}
                Some(_) => return None,
            }
        }
        seen
    }

    /// Lower onto the base CNN (see
    /// [`crate::cnn::channelwise::apply_plan`] /
    /// [`crate::cnn::channelwise::apply_joint_plan`]): the all-8-bit
    /// activation case takes the weights-only path and is bit-identical
    /// to the pre-activation-planning lowering.
    pub fn apply(&self, base: &Cnn) -> Cnn {
        if self.aq.iter().any(|&a| a != 8) {
            crate::cnn::channelwise::apply_joint_plan(base, &self.groups, &self.aq)
        } else {
            crate::cnn::channelwise::apply_plan(base, &self.groups)
        }
    }

    /// Weight footprint in MB straight from the assignment (fraction-exact;
    /// the lowered CNN's channel rounding can differ by a few KB). Cheap
    /// enough to gate candidates before any DSE evaluation.
    pub fn weight_mb(&self, base: &Cnn) -> f64 {
        let bits: f64 = base
            .layers
            .iter()
            .zip(&self.groups)
            .map(|(l, groups)| {
                let avg_bits: f64 = groups.iter().map(|g| g.fraction * g.wq as f64).sum();
                l.params() as f64 * avg_bits
            })
            .sum();
        bits / 8.0 / 1e6
    }

    /// Peak activation working set in MB at the assigned per-layer
    /// activation word-lengths — the Table III activation-buffer bytes
    /// the joint footprint adds. Computable from the assignment alone
    /// (no lowering), like [`weight_mb`](Self::weight_mb). Inputs are
    /// priced at the *structural* producer's `a_Q` (mirroring the xmp
    /// forward's rules): the previous layer when shapes chain (incl.
    /// through the elided stride-2 pool), the most recent shape-matching
    /// earlier layer for residual `downsample` projections, and the
    /// conservative 8-bit image width otherwise — so a narrow projection
    /// layer fed by a wide stage boundary is priced at the wide width,
    /// not its own.
    pub fn act_buffer_mb(&self, base: &Cnn) -> f64 {
        let peak_bits = base
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                l.input_elems() * self.producer_aq(base, i) as u64
                    + l.output_elems() * self.aq[i] as u64
            })
            .max()
            .unwrap_or(0);
        peak_bits as f64 / 8.0 / 1e6
    }

    /// Word-length of the activations feeding base layer `i` under this
    /// assignment (see [`act_buffer_mb`](Self::act_buffer_mb)). Falls
    /// back to the 8-bit maximum when no structural producer matches
    /// (the image, or a merge whose wider branch re-widened the buffer).
    fn producer_aq(&self, base: &Cnn, i: usize) -> u32 {
        if i == 0 {
            return 8;
        }
        let l = &base.layers[i];
        let prev = &base.layers[i - 1];
        let chains = (prev.oh(), prev.od) == (l.ih, l.iw)
            || (prev.od == l.iw && prev.oh().div_ceil(2) == l.ih);
        if chains {
            return self.aq[i - 1];
        }
        for j in (0..i.saturating_sub(1)).rev() {
            let p = &base.layers[j];
            if (p.oh(), p.od) == (l.ih, l.iw) {
                return self.aq[j];
            }
        }
        8
    }

    /// Human-readable summary: the majority word-length plus the
    /// exceptions, e.g. `w8; layer4.0.conv2→w4a6 (+2 more)` (the `aN`
    /// suffix appears only when a layer's activations are narrowed below
    /// the paper's fixed 8 bit).
    pub fn describe(&self, base: &Cnn) -> String {
        let key = |g: &[ChannelGroup], aq: u32| -> String {
            let w = if g.len() == 1 {
                format!("w{}", g[0].wq)
            } else {
                g.iter()
                    .map(|c| format!("w{}:{:.2}", c.wq, c.fraction))
                    .collect::<Vec<_>>()
                    .join("+")
            };
            if aq == 8 {
                w
            } else {
                format!("{w}a{aq}")
            }
        };
        let inner: Vec<usize> =
            (0..base.layers.len()).filter(|&i| !pinned(base, i)).collect();
        // Majority key among inner layers.
        let mut counts: Vec<(String, usize)> = Vec::new();
        for &i in &inner {
            let k = key(&self.groups[i], self.aq[i]);
            match counts.iter_mut().find(|(kk, _)| *kk == k) {
                Some((_, c)) => *c += 1,
                None => counts.push((k, 1)),
            }
        }
        let majority = counts
            .iter()
            .max_by_key(|(_, c)| *c)
            .map(|(k, _)| k.clone())
            .unwrap_or_else(|| "w8".into());
        let exceptions: Vec<String> = inner
            .iter()
            .filter(|&&i| key(&self.groups[i], self.aq[i]) != majority)
            .map(|&i| format!("{}→{}", base.layers[i].name, key(&self.groups[i], self.aq[i])))
            .collect();
        match exceptions.len() {
            0 => majority,
            n if n <= 3 => format!("{majority}; {}", exceptions.join(", ")),
            n => format!(
                "{majority}; {} (+{} more)",
                exceptions[..2].join(", "),
                n - 2
            ),
        }
    }
}

/// Search-budget knobs (EXPERIMENTS.md §Planner documents each).
#[derive(Clone, Debug)]
pub struct PlannerConfig {
    /// Accuracy family for the paper anchors (`ResNet-18/50/152`).
    pub family: String,
    /// Weight word-lengths the search may assign per layer.
    pub wq_choices: Vec<u32>,
    /// Activation word-lengths the search may assign per layer. The
    /// default `[8]` (the paper's fixed point) keeps the search — and
    /// every result — identical to the weight-only planner; widening the
    /// menu (CLI `--aq 4,6,8`) opens the joint `(w_Q, a_Q)` space.
    pub aq_choices: Vec<u32>,
    /// Channel-split fractions for two-group menu entries (low-wq share).
    pub split_fractions: Vec<f64>,
    /// Redundancy exponent of the sensitivity model.
    pub alpha: f64,
    /// Beam width of the DP enumeration.
    pub beam_width: usize,
    /// Max candidate assignments evaluated through the full DSE.
    pub max_evals: usize,
    /// Drop candidates whose proxy Top-5 falls below this, if set.
    pub min_top5: Option<f64>,
    /// Drop candidates whose planned footprint — weights at their
    /// assigned word-lengths **plus** the Table-III peak activation
    /// buffer at the assigned `a_Q` (the same wt+act MB the frontier
    /// ranks on) — exceeds this (MB), if set.
    pub max_footprint_mb: Option<f64>,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            family: "ResNet-18".to_string(),
            wq_choices: vec![1, 2, 4, 8],
            aq_choices: vec![8],
            split_fractions: vec![0.5],
            alpha: 1.0,
            beam_width: 48,
            max_evals: 16,
            min_top5: None,
            max_footprint_mb: None,
        }
    }
}

impl PlannerConfig {
    /// Defaults with the word-length menu taken from `cfg.weight_bits`.
    pub fn for_config(cfg: &RunConfig) -> PlannerConfig {
        PlannerConfig {
            wq_choices: cfg.weight_bits.clone(),
            ..PlannerConfig::default()
        }
    }

    /// The weight word-length menu, sorted ascending and deduplicated —
    /// the one normalization every candidate generator shares.
    pub fn bits_menu(&self) -> Vec<u32> {
        let mut wqs = self.wq_choices.clone();
        wqs.sort_unstable();
        wqs.dedup();
        wqs
    }

    /// The activation word-length menu, sorted ascending and
    /// deduplicated; `[8]` when unset.
    pub fn aq_menu(&self) -> Vec<u32> {
        let mut aqs = self.aq_choices.clone();
        if aqs.is_empty() {
            aqs.push(8);
        }
        aqs.sort_unstable();
        aqs.dedup();
        aqs
    }
}

/// One fully evaluated point (mixed plan or uniform baseline).
#[derive(Clone, Debug)]
pub struct PlannedPoint {
    /// Registry name (`w<q>` for uniforms, `mp<i>` for mixed plans).
    pub name: String,
    pub assignment: Assignment,
    /// `Some(wq)` for the uniform baselines.
    pub uniform_wq: Option<u32>,
    pub proxy_top1: f64,
    pub proxy_top5: f64,
    /// Frames/s of the fps-best slice's DSE-chosen design.
    pub fps: f64,
    pub k: u32,
    pub dims: Dims,
    pub mj_per_frame: f64,
    pub footprint: PlanFootprint,
    /// Uniform baselines this point Pareto-dominates (filled by [`plan`]).
    pub dominates: Vec<u32>,
}

impl PlannedPoint {
    pub fn triple(&self) -> Triple {
        Triple {
            top5: self.proxy_top5,
            fps: self.fps,
            footprint_mb: self.footprint.weight_mb + self.footprint.act_mb,
        }
    }
}

/// Outcome of one [`plan`] run.
#[derive(Clone, Debug)]
pub struct PlanReport {
    pub cnn_name: String,
    pub family: String,
    /// The Pareto frontier over mixed plans ∪ uniform baselines, sorted by
    /// descending proxy Top-5 (ties: descending fps).
    pub frontier: Vec<PlannedPoint>,
    /// Every uniform baseline, whether on the frontier or not.
    pub uniforms: Vec<PlannedPoint>,
    /// Candidates enumerated / evaluated through the DSE.
    pub enumerated: usize,
    pub evaluated: usize,
}

impl PlanReport {
    /// Mixed frontier points that Pareto-dominate at least one uniform
    /// baseline.
    pub fn dominating_points(&self) -> Vec<&PlannedPoint> {
        self.frontier
            .iter()
            .filter(|p| p.uniform_wq.is_none() && !p.dominates.is_empty())
            .collect()
    }

    /// Render the frontier (with the off-frontier uniform baselines
    /// appended) as a table.
    pub fn table(&self, base: &Cnn) -> Table {
        let mut t = Table::new(format!(
            "Precision plan frontier — {} ({} anchors)",
            self.cnn_name, self.family
        ))
        .headers(&[
            "name", "assignment", "Top-1*", "Top-5*", "fps", "k", "HxWxD", "wt MB", "act MB",
            "comp", "mJ/f", "dominates",
        ]);
        fn cells(p: &PlannedPoint, base: &Cnn, on_frontier: bool) -> Vec<String> {
            let doms = if p.dominates.is_empty() {
                if on_frontier { String::new() } else { "(off frontier)".into() }
            } else {
                p.dominates
                    .iter()
                    .map(|w| format!("≻w{w}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            vec![
                p.name.clone(),
                p.assignment.describe(base),
                fnum(p.proxy_top1, 2),
                fnum(p.proxy_top5, 2),
                fnum(p.fps, 1),
                p.k.to_string(),
                p.dims.to_string(),
                fnum(p.footprint.weight_mb, 2),
                fnum(p.footprint.act_mb, 2),
                format!("{:.1}x", p.footprint.compression),
                fnum(p.mj_per_frame, 2),
                doms,
            ]
        }
        for p in &self.frontier {
            t.row(cells(p, base, true));
        }
        let off: Vec<&PlannedPoint> = self
            .uniforms
            .iter()
            .filter(|u| !self.frontier.iter().any(|p| p.name == u.name))
            .collect();
        if !off.is_empty() {
            t.sep();
            for u in off {
                t.row(cells(u, base, false));
            }
        }
        t.note("* proxy accuracy: MAC-weighted LSQ-noise model calibrated on the paper's \
                Table III/IV anchors, quoted at their 0.01% resolution");
        t.note("≻wN = Pareto-dominates the uniform wN baseline on (Top-5*, fps, wt+act MB)");
        t.note("act MB = Table III peak activation working set at the assigned a_Q \
                (aN suffixes in the assignment column mark layers below the paper's fixed 8 bit)");
        t
    }
}

fn evaluate(
    name: String,
    assignment: Assignment,
    uniform_wq: Option<u32>,
    base: &Cnn,
    cfg: &RunConfig,
    model: &SensitivityModel,
    cache: &DseCache,
) -> PlannedPoint {
    let cnn = assignment.apply(base);
    let report = dse::explore_cached(&cnn, cfg, cache);
    let best = report.best_outcome();
    let mut footprint = PlanFootprint::of(&cnn);
    // The lowered CNN's peak is the *schedule* view, where a channel split
    // artificially halves a layer's output working set (sub-layers are
    // scheduled separately, but at execution time all groups' outputs are
    // live together to form the next input). Use the assignment-level
    // base-layer peak — input at the structural producer's a_Q, output at
    // the layer's own — which is also what the xmp engine actually
    // buffers, and keep total_mb consistent with the substitution.
    let schedule_act_mb = footprint.act_mb;
    footprint.act_mb = assignment.act_buffer_mb(base);
    footprint.total_mb += footprint.act_mb - schedule_act_mb;
    PlannedPoint {
        name,
        proxy_top1: model.proxy_top1(&assignment),
        proxy_top5: model.proxy_top5(&assignment),
        fps: best.sim.fps,
        k: best.k,
        dims: best.array.dims,
        mj_per_frame: best.sim.e_total_mj(),
        footprint,
        assignment,
        uniform_wq,
        dominates: Vec::new(),
    }
}

/// Run the full planner: search the assignment space, evaluate through the
/// cached DSE, and return the Pareto frontier plus the uniform baselines.
pub fn plan(base: &Cnn, cfg: &RunConfig, pcfg: &PlannerConfig) -> Result<PlanReport> {
    let model = SensitivityModel::build(
        base,
        &pcfg.family,
        pcfg.alpha,
        &pcfg.wq_choices,
        &pcfg.aq_choices,
    )?;
    let mut candidates = frontier::enumerate_assignments(base, &model, pcfg);
    let enumerated = candidates.len();
    candidates.retain(|a| a.uniform_wq(base).is_none());
    if let Some(min) = pcfg.min_top5 {
        candidates.retain(|a| model.proxy_top5(a) >= min);
    }
    // Footprint is computable from the assignment alone, so gate here —
    // before thinning — rather than waste DSE evaluations on over-budget
    // candidates (a final exact retain below catches channel-rounding
    // stragglers). The budget bounds the same wt+act quantity the
    // frontier ranks and prints.
    if let Some(limit) = pcfg.max_footprint_mb {
        candidates.retain(|a| a.weight_mb(base) + a.act_buffer_mb(base) <= limit);
    }
    let candidates = frontier::thin_candidates(candidates, &model, pcfg.max_evals);

    // Planner-local DSE cache: candidate CNNs are one-shot, so keep them
    // from churning the process-global serving cache.
    let cache = DseCache::new();
    let mut mixed: Vec<PlannedPoint> = candidates
        .into_iter()
        .enumerate()
        .map(|(i, a)| evaluate(format!("mp{i}"), a, None, base, cfg, &model, &cache))
        .collect();
    let evaluated = mixed.len();
    if let Some(limit) = pcfg.max_footprint_mb {
        mixed.retain(|p| p.footprint.weight_mb + p.footprint.act_mb <= limit);
    }

    let uniforms: Vec<PlannedPoint> = pcfg
        .bits_menu()
        .into_iter()
        .map(|wq| {
            evaluate(
                format!("w{wq}"),
                Assignment::uniform(base, wq),
                Some(wq),
                base,
                cfg,
                &model,
                &cache,
            )
        })
        .collect();

    // Dominance bookkeeping: which uniform baselines does each mixed plan
    // Pareto-dominate?
    for p in &mut mixed {
        p.dominates = uniforms
            .iter()
            .filter(|u| dominates(&p.triple(), &u.triple()))
            .filter_map(|u| u.uniform_wq)
            .collect();
    }

    // Frontier over the union.
    let mut all: Vec<PlannedPoint> = mixed;
    all.extend(uniforms.iter().cloned());
    let triples: Vec<Triple> = all.iter().map(|p| p.triple()).collect();
    let keep = pareto_indices(&triples);
    let mut frontier: Vec<PlannedPoint> = keep.into_iter().map(|i| all[i].clone()).collect();
    frontier.sort_by(|a, b| {
        b.proxy_top5
            .total_cmp(&a.proxy_top5)
            .then(b.fps.total_cmp(&a.fps))
    });

    Ok(PlanReport {
        cnn_name: base.name.clone(),
        family: pcfg.family.clone(),
        frontier,
        uniforms,
        enumerated,
        evaluated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;

    #[test]
    fn assignment_uniform_and_describe() {
        let base = resnet::resnet18();
        let a = Assignment::uniform(&base, 2);
        assert_eq!(a.uniform_wq(&base), Some(2));
        assert_eq!(a.describe(&base), "w2");
        assert_eq!(a.groups[0][0].wq, 8, "conv1 pinned");
        assert_eq!(a.groups.last().unwrap()[0].wq, 8, "fc pinned");

        let mut b = a.clone();
        let fat = (0..base.layers.len())
            .filter(|&i| !pinned(&base, i))
            .max_by_key(|&i| base.layers[i].params())
            .unwrap();
        b.groups[fat] = vec![ChannelGroup { wq: 1, fraction: 1.0 }];
        assert_eq!(b.uniform_wq(&base), None);
        let d = b.describe(&base);
        assert!(d.starts_with("w2; ") && d.contains("→w1"), "{d}");
    }

    #[test]
    fn joint_assignment_uniform_wq_describe_and_footprint() {
        let base = resnet::resnet18();
        // A reduced-activation uniform plan is NOT a paper baseline.
        let j = Assignment::uniform_joint(&base, 4, 6);
        assert_eq!(j.uniform_wq(&base), None);
        assert_eq!(j.describe(&base), "w4a6");
        assert_eq!(j.groups, Assignment::uniform(&base, 4).groups);
        // Pinned layers stay at a8.
        assert_eq!(j.aq[0], 8);
        assert_eq!(*j.aq.last().unwrap(), 8);
        let w4 = Assignment::uniform(&base, 4);
        // On ResNet-18 the peak activation working set is conv1's — a
        // pinned layer — so narrowing inner activations cannot move the
        // Table III buffer: the joint plan's act footprint is honest
        // about that (equal, not smaller).
        assert_eq!(j.act_buffer_mb(&base), w4.act_buffer_mb(&base));
        assert_eq!(j.weight_mb(&base), w4.weight_mb(&base));
        // On the small 32x32 topology the peak is an inner layer, and the
        // buffer genuinely shrinks with aq.
        let small = resnet::resnet_small(1, 10);
        let js = Assignment::uniform_joint(&small, 4, 6);
        let ws = Assignment::uniform(&small, 4);
        assert!(
            js.act_buffer_mb(&small) < ws.act_buffer_mb(&small),
            "{} vs {}",
            js.act_buffer_mb(&small),
            ws.act_buffer_mb(&small)
        );
        // Lowering writes act_bits; the all-8 case is the weights-only CNN.
        assert_eq!(
            w4.apply(&base).fingerprint(),
            base.clone().with_uniform_wq(4).fingerprint()
        );
        assert_ne!(j.apply(&base).fingerprint(), w4.apply(&base).fingerprint());
        // A single narrowed layer shows up as an aN exception.
        let mut one = Assignment::uniform(&base, 4);
        let fat = (0..base.layers.len())
            .filter(|&i| !pinned(&base, i))
            .max_by_key(|&i| base.layers[i].params())
            .unwrap();
        one.aq[fat] = 5;
        let d = one.describe(&base);
        assert!(d.starts_with("w4; ") && d.contains("→w4a5"), "{d}");
    }

    #[test]
    fn assignment_apply_matches_with_uniform_wq() {
        let base = resnet::resnet_small(1, 10);
        let a = Assignment::uniform(&base, 4);
        assert_eq!(
            a.apply(&base).fingerprint(),
            base.clone().with_uniform_wq(4).fingerprint()
        );
    }

    #[test]
    fn planner_config_tracks_run_config_bits() {
        let cfg = RunConfig { weight_bits: vec![2, 4], ..RunConfig::default() };
        let p = PlannerConfig::for_config(&cfg);
        assert_eq!(p.wq_choices, vec![2, 4]);
    }
}
