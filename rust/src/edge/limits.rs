//! Edge admission: per-client token buckets and the global inflight gate.
//!
//! Both sit *ahead of* the gateway's bounded variant queues — a client that
//! would be silently absorbed into queueing delay is instead told to back
//! off (429/503 with `Retry-After`), which keeps the queues short enough
//! that the worker-side deadline shedding still has headroom to act.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Bound on distinct tracked clients; beyond it, fully-refilled (idle)
/// buckets are evicted before a new client is admitted.
const MAX_TRACKED_CLIENTS: usize = 4096;

struct Bucket {
    tokens: f64,
    last: Instant,
}

fn refill(b: &mut Bucket, now: Instant, rate: f64, burst: f64) {
    let dt = now.saturating_duration_since(b.last).as_secs_f64();
    b.tokens = (b.tokens + dt * rate).min(burst);
    b.last = now;
}

/// Classic token bucket per client id (`X-Client-Id` header, else peer
/// IP): `burst` tokens capacity, refilled at `rate_per_sec`. A rate of 0
/// disables limiting entirely.
pub struct RateLimiter {
    rate_per_sec: f64,
    burst: f64,
    buckets: Mutex<HashMap<String, Bucket>>,
    limited: AtomicU64,
}

impl RateLimiter {
    pub fn new(rate_per_sec: f64, burst: f64) -> RateLimiter {
        RateLimiter {
            rate_per_sec,
            burst: burst.max(1.0),
            buckets: Mutex::new(HashMap::new()),
            limited: AtomicU64::new(0),
        }
    }

    /// Take one token for `client`. `Err(d)` means limited: retry after
    /// roughly `d` (the time for one token to refill).
    pub fn acquire(&self, client: &str) -> std::result::Result<(), Duration> {
        if self.rate_per_sec <= 0.0 {
            return Ok(());
        }
        let now = Instant::now();
        let mut buckets = self.buckets.lock().unwrap_or_else(|e| e.into_inner());
        if buckets.len() >= MAX_TRACKED_CLIENTS && !buckets.contains_key(client) {
            let (rate, burst) = (self.rate_per_sec, self.burst);
            // Idle clients are exactly the refilled-to-burst buckets.
            buckets.retain(|_, b| {
                refill(b, now, rate, burst);
                b.tokens < burst - 0.5
            });
        }
        let b = buckets.entry(client.to_string()).or_insert(Bucket {
            tokens: self.burst,
            last: now,
        });
        refill(b, now, self.rate_per_sec, self.burst);
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            Ok(())
        } else {
            self.limited.fetch_add(1, Ordering::Relaxed);
            Err(Duration::from_secs_f64(
                (1.0 - b.tokens) / self.rate_per_sec,
            ))
        }
    }

    /// Total acquisitions refused since construction.
    pub fn limited(&self) -> u64 {
        self.limited.load(Ordering::Relaxed)
    }

    /// Distinct clients currently tracked.
    pub fn tracked_clients(&self) -> usize {
        self.buckets.lock().unwrap_or_else(|e| e.into_inner()).len()
    }
}

/// Global concurrent-request ceiling across every variant queue. RAII:
/// the permit returns its slot on drop, so error paths can't leak
/// capacity.
pub struct AdmissionGate {
    inflight: AtomicU64,
    max: u64,
    shed: AtomicU64,
}

pub struct AdmissionPermit<'a> {
    gate: &'a AdmissionGate,
}

impl AdmissionGate {
    /// `max == 0` means unlimited.
    pub fn new(max: u64) -> AdmissionGate {
        AdmissionGate {
            inflight: AtomicU64::new(0),
            max,
            shed: AtomicU64::new(0),
        }
    }

    pub fn try_enter(&self) -> Option<AdmissionPermit<'_>> {
        let prev = self.inflight.fetch_add(1, Ordering::SeqCst);
        if self.max > 0 && prev >= self.max {
            self.inflight.fetch_sub(1, Ordering::SeqCst);
            self.shed.fetch_add(1, Ordering::Relaxed);
            None
        } else {
            Some(AdmissionPermit { gate: self })
        }
    }

    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::SeqCst)
    }

    /// Requests refused at the gate since construction.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }
}

impl Drop for AdmissionPermit<'_> {
    fn drop(&mut self) {
        self.gate.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_admits_burst_then_limits() {
        let rl = RateLimiter::new(1.0, 3.0);
        assert!(rl.acquire("a").is_ok());
        assert!(rl.acquire("a").is_ok());
        assert!(rl.acquire("a").is_ok());
        let retry = rl.acquire("a").unwrap_err();
        assert!(retry > Duration::ZERO && retry <= Duration::from_secs(2));
        assert_eq!(rl.limited(), 1);
        // Other clients have their own bucket.
        assert!(rl.acquire("b").is_ok());
    }

    #[test]
    fn zero_rate_means_unlimited() {
        let rl = RateLimiter::new(0.0, 1.0);
        for _ in 0..1000 {
            assert!(rl.acquire("x").is_ok());
        }
        assert_eq!(rl.limited(), 0);
    }

    #[test]
    fn gate_caps_inflight_and_permits_return_slots() {
        let g = AdmissionGate::new(2);
        let p1 = g.try_enter().unwrap();
        let _p2 = g.try_enter().unwrap();
        assert!(g.try_enter().is_none());
        assert_eq!(g.inflight(), 2);
        assert_eq!(g.shed(), 1);
        drop(p1);
        assert_eq!(g.inflight(), 1);
        assert!(g.try_enter().is_some());
        assert_eq!(g.inflight(), 1, "dropped permit returned its slot");
    }

    #[test]
    fn gate_zero_is_unlimited() {
        let g = AdmissionGate::new(0);
        let permits: Vec<_> = (0..64).map(|_| g.try_enter().unwrap()).collect();
        assert_eq!(g.inflight(), 64);
        drop(permits);
        assert_eq!(g.inflight(), 0);
    }
}
