//! Bounded, content-addressed response cache.
//!
//! Classification is deterministic per `(variant, image)` — two identical
//! images routed to the same variant produce bit-identical logits — so the
//! edge can answer repeats without touching a backend. Keys are
//! `sha256(variant || 0x00 || image-bytes)`; entries are the full
//! [`Answer`] (class + logits), evicted LRU once `capacity` is exceeded.
//!
//! Only *successful* responses that pass the configured response check are
//! inserted (see `handlers`): a `FaultyBackend` corrupt-logits response is
//! counted under `uncacheable` and never stored, so a transient fault can
//! never be amplified into a sticky wrong answer.

use super::{Answer, Key};
use crate::util::sha256::sha256;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Content address for one `(variant, image)` request.
pub fn cache_key(variant: &str, image: &[f32]) -> Key {
    let mut bytes = Vec::with_capacity(variant.len() + 1 + image.len() * 4);
    bytes.extend_from_slice(variant.as_bytes());
    bytes.push(0);
    for v in image {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    sha256(&bytes)
}

struct Inner {
    map: HashMap<Key, Answer>,
    /// LRU order, least-recent at the front. Touched on hit.
    order: VecDeque<Key>,
}

pub struct ResponseCache {
    capacity: usize,
    inner: Mutex<Inner>,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
    uncacheable: AtomicU64,
}

impl ResponseCache {
    /// `capacity == 0` disables the cache (every lookup is a miss).
    pub fn new(capacity: usize) -> ResponseCache {
        ResponseCache {
            capacity,
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                order: VecDeque::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            uncacheable: AtomicU64::new(0),
        }
    }

    pub fn get(&self, key: &Key) -> Option<Answer> {
        if self.capacity == 0 {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        match inner.map.get(key).cloned() {
            Some(answer) => {
                if let Some(pos) = inner.order.iter().position(|k| k == key) {
                    inner.order.remove(pos);
                    inner.order.push_back(*key);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(answer)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, key: Key, answer: Answer) {
        if self.capacity == 0 {
            return;
        }
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if inner.map.insert(key, answer).is_none() {
            inner.order.push_back(key);
        }
        self.insertions.fetch_add(1, Ordering::Relaxed);
        while inner.map.len() > self.capacity {
            match inner.order.pop_front() {
                Some(oldest) => {
                    inner.map.remove(&oldest);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
        }
    }

    /// Record a successful response that failed the cacheability check
    /// (e.g. disagreed with the reference model) and was NOT stored.
    pub fn note_uncacheable(&self) {
        self.uncacheable.fetch_add(1, Ordering::Relaxed);
    }

    pub fn len(&self) -> usize {
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .map
            .len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn insertions(&self) -> u64 {
        self.insertions.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    pub fn uncacheable(&self) -> u64 {
        self.uncacheable.load(Ordering::Relaxed)
    }
}

/// One remembered deterministic rejection: the status and body the edge
/// would compute again for the same request.
#[derive(Clone, Debug, PartialEq)]
pub struct NegativeEntry {
    pub status: u16,
    pub message: String,
}

/// Bounded LRU cache for *deterministic* 4xx refusals.
///
/// Some rejections are pure functions of the request: an unknown variant
/// name stays unknown until the registry changes, and a pinned-route
/// image-shape mismatch stays wrong for that `(selector, image_len)`
/// forever. Re-deriving those through route resolution (and, for shape
/// errors, through the whole gateway queue) on every repeat is wasted
/// work; a misbehaving client retrying a bad request in a loop would get
/// amplified into backend load. This cache short-circuits them.
///
/// Deliberately separate from [`ResponseCache`]: different key shape
/// (selector + image length, not content hash), different capacity, and
/// 4xx entries must never compete with real answers for cache space.
/// Non-deterministic refusals (429 rate limits, 503 shed, load-dependent
/// anything) must NOT be inserted — policy enforced at the call site in
/// `handlers`.
pub struct NegativeCache {
    capacity: usize,
    inner: Mutex<NegativeInner>,
    hits: AtomicU64,
    misses: AtomicU64,
    insertions: AtomicU64,
    evictions: AtomicU64,
}

struct NegativeInner {
    map: HashMap<Key, NegativeEntry>,
    order: VecDeque<Key>,
}

/// Key for a negative entry: the selector string and the image *length*
/// (shape errors depend only on length, never on pixel values).
pub fn negative_key(selector: &str, image_len: usize) -> Key {
    let mut bytes = Vec::with_capacity(selector.len() + 9);
    bytes.extend_from_slice(selector.as_bytes());
    bytes.push(0);
    bytes.extend_from_slice(&(image_len as u64).to_le_bytes());
    sha256(&bytes)
}

impl NegativeCache {
    /// `capacity == 0` disables negative caching entirely.
    pub fn new(capacity: usize) -> NegativeCache {
        NegativeCache {
            capacity,
            inner: Mutex::new(NegativeInner {
                map: HashMap::new(),
                order: VecDeque::new(),
            }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            insertions: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn get(&self, key: &Key) -> Option<NegativeEntry> {
        if self.capacity == 0 {
            return None;
        }
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        match inner.map.get(key).cloned() {
            Some(entry) => {
                if let Some(pos) = inner.order.iter().position(|k| k == key) {
                    inner.order.remove(pos);
                    inner.order.push_back(*key);
                }
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, key: Key, status: u16, message: impl Into<String>) {
        if self.capacity == 0 {
            return;
        }
        let entry = NegativeEntry {
            status,
            message: message.into(),
        };
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if inner.map.insert(key, entry).is_none() {
            inner.order.push_back(key);
        }
        self.insertions.fetch_add(1, Ordering::Relaxed);
        while inner.map.len() > self.capacity {
            match inner.order.pop_front() {
                Some(oldest) => {
                    inner.map.remove(&oldest);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
        }
    }

    pub fn len(&self) -> usize {
        self.inner
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .map
            .len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn insertions(&self) -> u64 {
        self.insertions.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn answer(class: usize) -> Answer {
        Answer {
            class,
            variant: "w2".to_string(),
            logits: vec![class as f32],
        }
    }

    #[test]
    fn key_separates_variant_and_image() {
        let img = vec![1.0f32, 2.0, 3.0];
        assert_ne!(cache_key("w2", &img), cache_key("w4", &img));
        assert_ne!(cache_key("w2", &img), cache_key("w2", &[1.0, 2.0]));
        assert_eq!(cache_key("w2", &img), cache_key("w2", &img));
    }

    #[test]
    fn hit_miss_and_counters() {
        let c = ResponseCache::new(8);
        let k = cache_key("w2", &[1.0]);
        assert!(c.get(&k).is_none());
        c.insert(k, answer(5));
        assert_eq!(c.get(&k).unwrap().class, 5);
        assert_eq!((c.hits(), c.misses(), c.insertions()), (1, 1, 1));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let c = ResponseCache::new(2);
        let k1 = cache_key("w2", &[1.0]);
        let k2 = cache_key("w2", &[2.0]);
        let k3 = cache_key("w2", &[3.0]);
        c.insert(k1, answer(1));
        c.insert(k2, answer(2));
        // Touch k1 so k2 is the least-recently-used.
        assert!(c.get(&k1).is_some());
        c.insert(k3, answer(3));
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        assert!(c.get(&k1).is_some(), "recently-used entry survived");
        assert!(c.get(&k2).is_none(), "LRU entry was evicted");
        assert!(c.get(&k3).is_some());
    }

    #[test]
    fn zero_capacity_disables() {
        let c = ResponseCache::new(0);
        let k = cache_key("w2", &[1.0]);
        c.insert(k, answer(1));
        assert!(c.get(&k).is_none());
        assert_eq!(c.insertions(), 0);
        assert!(c.is_empty());
    }

    #[test]
    fn reinsert_same_key_does_not_duplicate_order() {
        let c = ResponseCache::new(2);
        let k = cache_key("w2", &[1.0]);
        c.insert(k, answer(1));
        c.insert(k, answer(2));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&k).unwrap().class, 2);
        assert_eq!(c.evictions(), 0);
    }

    #[test]
    fn negative_key_separates_selector_and_length() {
        assert_ne!(negative_key("exact:2", 3072), negative_key("exact:4", 3072));
        assert_ne!(negative_key("exact:2", 3072), negative_key("exact:2", 3073));
        assert_eq!(negative_key("exact:2", 3072), negative_key("exact:2", 3072));
    }

    #[test]
    fn negative_cache_hits_and_evicts_lru() {
        let c = NegativeCache::new(2);
        let k1 = negative_key("exact:9", 10);
        let k2 = negative_key("name:ghost", 10);
        let k3 = negative_key("exact:9", 11);
        assert!(c.get(&k1).is_none());
        c.insert(k1, 404, "no such variant: exact:9\n");
        c.insert(k2, 404, "no such variant: ghost\n");
        let hit = c.get(&k1).unwrap();
        assert_eq!(hit.status, 404);
        assert!(hit.message.contains("exact:9"));
        // k2 is now LRU; inserting k3 must evict it.
        c.insert(k3, 400, "bad input: image length 11\n");
        assert_eq!(c.len(), 2);
        assert_eq!(c.evictions(), 1);
        assert!(c.get(&k2).is_none());
        assert!(c.get(&k1).is_some());
        assert_eq!((c.hits(), c.insertions()), (2, 3));
    }

    #[test]
    fn negative_cache_zero_capacity_disables() {
        let c = NegativeCache::new(0);
        let k = negative_key("exact:9", 10);
        c.insert(k, 404, "x");
        assert!(c.get(&k).is_none());
        assert_eq!(c.insertions(), 0);
        assert_eq!(c.misses(), 0, "disabled cache does not count misses");
        assert!(c.is_empty());
    }
}
