//! Edge-side counters and the Prometheus text exposition.
//!
//! Two layers are exposed on `GET /metrics`: the edge's own HTTP-level
//! counters (`mpcnn_edge_*`, `mpcnn_cache_*`, `mpcnn_coalesce_*`) and the
//! gateway's per-variant serving signals (`mpcnn_variant_*`, labeled by
//! variant) drawn from the same [`MetricsSummary`] /
//! [`RobustnessReport`] structs the CLI report consumes — one export
//! surface, two renderings.

use super::{Coalescer, EdgeState, NegativeCache, ResponseCache};
use crate::serving::BackendHealth;
use crate::util::stats::LatencyHistogram;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// HTTP-level counters, all lock-free except the latency histogram.
pub struct EdgeMetrics {
    requests: AtomicU64,
    ok: AtomicU64,
    client_errors: AtomicU64,
    server_errors: AtomicU64,
    rate_limited: AtomicU64,
    admission_shed: AtomicU64,
    queue_shed: AtomicU64,
    bad_requests: AtomicU64,
    classify_requests: AtomicU64,
    agreement_checks: AtomicU64,
    agreement_failures: AtomicU64,
    latency: Mutex<LatencyHistogram>,
}

impl Default for EdgeMetrics {
    fn default() -> EdgeMetrics {
        EdgeMetrics::new()
    }
}

impl EdgeMetrics {
    pub fn new() -> EdgeMetrics {
        EdgeMetrics {
            requests: AtomicU64::new(0),
            ok: AtomicU64::new(0),
            client_errors: AtomicU64::new(0),
            server_errors: AtomicU64::new(0),
            rate_limited: AtomicU64::new(0),
            admission_shed: AtomicU64::new(0),
            queue_shed: AtomicU64::new(0),
            bad_requests: AtomicU64::new(0),
            classify_requests: AtomicU64::new(0),
            agreement_checks: AtomicU64::new(0),
            agreement_failures: AtomicU64::new(0),
            latency: Mutex::new(LatencyHistogram::default()),
        }
    }

    /// Fold one finished request into the counters.
    pub fn observe(&self, status: u16, latency: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        match status {
            200..=299 => self.ok.fetch_add(1, Ordering::Relaxed),
            400..=499 => self.client_errors.fetch_add(1, Ordering::Relaxed),
            _ => self.server_errors.fetch_add(1, Ordering::Relaxed),
        };
        self.latency
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .record_us(latency.as_micros() as f64);
    }

    pub fn note_classify(&self) {
        self.classify_requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_rate_limited(&self) {
        self.rate_limited.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_admission_shed(&self) {
        self.admission_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Connections refused because the acceptor's hand-off queue was full.
    pub fn note_queue_shed(&self) {
        self.queue_shed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_bad_request(&self) {
        self.bad_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// One response compared against the xmp reference model. The
    /// agreement *rate* (1 - failures/checks) is the accuracy-drift
    /// watchdog's and the agreement SLO's raw signal.
    pub fn note_agreement(&self, agreed: bool) {
        self.agreement_checks.fetch_add(1, Ordering::Relaxed);
        if !agreed {
            self.agreement_failures.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Copy of the request-latency histogram for the Prometheus
    /// `_bucket`/`_sum`/`_count` exposition.
    pub fn latency_histogram(&self) -> LatencyHistogram {
        self.latency.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Flatten every edge counter (cache, negative-cache, and coalescing
    /// ledgers included) into a plain-number snapshot.
    pub fn snapshot(
        &self,
        cache: &ResponseCache,
        negative: &NegativeCache,
        coalescer: &Coalescer,
    ) -> EdgeSnapshot {
        let (p50_us, p99_us) = {
            let h = self.latency.lock().unwrap_or_else(|e| e.into_inner());
            (h.percentile_us(50.0), h.percentile_us(99.0))
        };
        EdgeSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            ok: self.ok.load(Ordering::Relaxed),
            client_errors: self.client_errors.load(Ordering::Relaxed),
            server_errors: self.server_errors.load(Ordering::Relaxed),
            rate_limited: self.rate_limited.load(Ordering::Relaxed),
            admission_shed: self.admission_shed.load(Ordering::Relaxed),
            queue_shed: self.queue_shed.load(Ordering::Relaxed),
            bad_requests: self.bad_requests.load(Ordering::Relaxed),
            classify_requests: self.classify_requests.load(Ordering::Relaxed),
            agreement_checks: self.agreement_checks.load(Ordering::Relaxed),
            agreement_failures: self.agreement_failures.load(Ordering::Relaxed),
            coalesce_leaders: coalescer.leaders(),
            coalesce_joined: coalescer.joined(),
            cache_hits: cache.hits(),
            cache_misses: cache.misses(),
            cache_insertions: cache.insertions(),
            cache_evictions: cache.evictions(),
            cache_uncacheable: cache.uncacheable(),
            negative_hits: negative.hits(),
            negative_misses: negative.misses(),
            negative_insertions: negative.insertions(),
            negative_evictions: negative.evictions(),
            p50_us,
            p99_us,
        }
    }
}

/// Point-in-time copy of every edge counter — what the tests, the drain
/// report, and the exposition below consume.
#[derive(Clone, Copy, Debug, Default)]
pub struct EdgeSnapshot {
    pub requests: u64,
    pub ok: u64,
    pub client_errors: u64,
    pub server_errors: u64,
    pub rate_limited: u64,
    pub admission_shed: u64,
    pub queue_shed: u64,
    pub bad_requests: u64,
    pub classify_requests: u64,
    pub agreement_checks: u64,
    pub agreement_failures: u64,
    pub coalesce_leaders: u64,
    pub coalesce_joined: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_insertions: u64,
    pub cache_evictions: u64,
    pub cache_uncacheable: u64,
    pub negative_hits: u64,
    pub negative_misses: u64,
    pub negative_insertions: u64,
    pub negative_evictions: u64,
    pub p50_us: f64,
    pub p99_us: f64,
}

fn metric(out: &mut String, name: &str, kind: &str, help: &str, value: f64) {
    out.push_str(&format!(
        "# HELP {name} {help}\n# TYPE {name} {kind}\n{name} {value}\n"
    ));
}

fn family_header(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

fn labeled(out: &mut String, name: &str, variant: &str, value: f64) {
    out.push_str(&format!("{name}{{variant=\"{variant}\"}} {value}\n"));
}

/// Append one histogram's cumulative `_bucket` / `_sum` / `_count` series.
/// `label` is an optional `variant="x"` selector shared by every line.
/// Buckets are the log2 [`LatencyHistogram`] buckets: `le="2^(i+1)"` counts
/// samples below that bound, and `+Inf` equals `_count` (samples past the
/// last bucket clamp into it).
fn histogram_series(out: &mut String, name: &str, label: Option<&str>, h: &LatencyHistogram) {
    let with_le = |le: &str| match label {
        Some(l) => format!("{{{l},le=\"{le}\"}}"),
        None => format!("{{le=\"{le}\"}}"),
    };
    let plain = match label {
        Some(l) => format!("{{{l}}}"),
        None => String::new(),
    };
    let mut cum = 0u64;
    for (i, &c) in h.buckets().iter().enumerate() {
        cum += c;
        let le = LatencyHistogram::bound(i);
        out.push_str(&format!("{name}_bucket{} {cum}\n", with_le(&le.to_string())));
    }
    out.push_str(&format!("{name}_bucket{} {}\n", with_le("+Inf"), h.count()));
    out.push_str(&format!("{name}_sum{plain} {}\n", h.sum_us()));
    out.push_str(&format!("{name}_count{plain} {}\n", h.count()));
}

fn health_code(h: BackendHealth) -> f64 {
    match h {
        BackendHealth::Healthy => 0.0,
        BackendHealth::Degraded => 1.0,
        BackendHealth::Unavailable => 2.0,
    }
}

/// Render the full exposition (Prometheus text format 0.0.4).
pub fn prometheus(state: &EdgeState) -> String {
    let mut out = String::with_capacity(8192);
    let snap = state
        .metrics
        .snapshot(&state.cache, &state.negative, &state.coalescer);

    let up = if state.draining() { 0.0 } else { 1.0 };
    let edge_metrics: [(&str, &str, &str, f64); 28] = [
        (
            "mpcnn_edge_up",
            "gauge",
            "edge accepting requests (0 while draining)",
            up,
        ),
        (
            "mpcnn_edge_requests_total",
            "counter",
            "HTTP requests handled",
            snap.requests as f64,
        ),
        (
            "mpcnn_edge_responses_ok_total",
            "counter",
            "2xx responses",
            snap.ok as f64,
        ),
        (
            "mpcnn_edge_responses_client_error_total",
            "counter",
            "4xx responses",
            snap.client_errors as f64,
        ),
        (
            "mpcnn_edge_responses_server_error_total",
            "counter",
            "5xx responses",
            snap.server_errors as f64,
        ),
        (
            "mpcnn_edge_classify_requests_total",
            "counter",
            "POST /v1/classify requests",
            snap.classify_requests as f64,
        ),
        (
            "mpcnn_edge_rate_limited_total",
            "counter",
            "requests refused by the per-client token bucket (429)",
            snap.rate_limited as f64,
        ),
        (
            "mpcnn_edge_admission_shed_total",
            "counter",
            "requests refused by the global inflight gate (503)",
            snap.admission_shed as f64,
        ),
        (
            "mpcnn_edge_queue_shed_total",
            "counter",
            "connections refused: acceptor hand-off queue full",
            snap.queue_shed as f64,
        ),
        (
            "mpcnn_edge_bad_requests_total",
            "counter",
            "malformed requests (400)",
            snap.bad_requests as f64,
        ),
        (
            "mpcnn_edge_inflight",
            "gauge",
            "requests currently inside the admission gate",
            state.gate.inflight() as f64,
        ),
        (
            "mpcnn_edge_latency_p50_us",
            "gauge",
            "median edge-observed request latency (us)",
            snap.p50_us,
        ),
        (
            "mpcnn_edge_latency_p99_us",
            "gauge",
            "p99 edge-observed request latency (us)",
            snap.p99_us,
        ),
        (
            "mpcnn_cache_hits_total",
            "counter",
            "classify responses served from the content-addressed cache",
            snap.cache_hits as f64,
        ),
        (
            "mpcnn_cache_misses_total",
            "counter",
            "cache lookups that missed",
            snap.cache_misses as f64,
        ),
        (
            "mpcnn_cache_insertions_total",
            "counter",
            "responses inserted into the cache",
            snap.cache_insertions as f64,
        ),
        (
            "mpcnn_cache_evictions_total",
            "counter",
            "LRU evictions",
            snap.cache_evictions as f64,
        ),
        (
            "mpcnn_cache_uncacheable_total",
            "counter",
            "successful responses refused by the cacheability check",
            snap.cache_uncacheable as f64,
        ),
        (
            "mpcnn_cache_entries",
            "gauge",
            "entries currently cached",
            state.cache.len() as f64,
        ),
        (
            "mpcnn_cache_negative_hits_total",
            "counter",
            "deterministic 4xx refusals served from the negative cache",
            snap.negative_hits as f64,
        ),
        (
            "mpcnn_cache_negative_misses_total",
            "counter",
            "negative-cache lookups that missed",
            snap.negative_misses as f64,
        ),
        (
            "mpcnn_cache_negative_insertions_total",
            "counter",
            "deterministic 4xx refusals remembered",
            snap.negative_insertions as f64,
        ),
        (
            "mpcnn_cache_negative_evictions_total",
            "counter",
            "negative-cache LRU evictions",
            snap.negative_evictions as f64,
        ),
        (
            "mpcnn_cache_negative_entries",
            "gauge",
            "refusals currently remembered",
            state.negative.len() as f64,
        ),
        (
            "mpcnn_edge_agreement_checks_total",
            "counter",
            "responses compared against the reference model",
            snap.agreement_checks as f64,
        ),
        (
            "mpcnn_edge_agreement_failures_total",
            "counter",
            "responses that disagreed with the reference model",
            snap.agreement_failures as f64,
        ),
        (
            "mpcnn_coalesce_leaders_total",
            "counter",
            "inferences that led a coalescing group",
            snap.coalesce_leaders as f64,
        ),
        (
            "mpcnn_coalesce_joined_total",
            "counter",
            "requests that joined an in-flight duplicate",
            snap.coalesce_joined as f64,
        ),
    ];
    for (name, kind, help, value) in edge_metrics {
        metric(&mut out, name, kind, help, value);
    }

    // Full latency distribution, not just the p50/p99 gauges above.
    family_header(
        &mut out,
        "mpcnn_edge_latency_us",
        "histogram",
        "edge-observed request latency (us)",
    );
    histogram_series(
        &mut out,
        "mpcnn_edge_latency_us",
        None,
        &state.metrics.latency_histogram(),
    );

    // Per-variant gateway signals: live router view (EWMA latency,
    // inflight, health) plus the cumulative MetricsSummary counters.
    let statuses = state.server.statuses();
    type StatusProj = fn(&crate::serving::VariantStatus) -> f64;
    let status_families: [(&str, &str, StatusProj); 4] = [
        (
            "mpcnn_variant_ewma_latency_us",
            "router-facing EWMA end-to-end latency (us)",
            |s| s.ewma_latency_us,
        ),
        (
            "mpcnn_variant_inflight",
            "requests queued or executing on the variant",
            |s| s.inflight as f64,
        ),
        (
            "mpcnn_variant_health",
            "backend health (0 healthy, 1 degraded, 2 unavailable)",
            |s| health_code(s.health),
        ),
        (
            "mpcnn_variant_fpga_fps",
            "simulated FPGA frames/s from the DSE profile",
            |s| s.fpga_fps,
        ),
    ];
    for (name, help, project) in status_families {
        family_header(&mut out, name, "gauge", help);
        for s in &statuses {
            labeled(&mut out, name, &s.name, project(s));
        }
    }

    // Cumulative per-variant counters: rendered straight from the shared
    // SUMMARY_FIELDS table, so the exposition and the CLI report cannot
    // drift apart (the exposition tests assert against the same table).
    let variant_metrics = state.server.metrics_all();
    let summaries: Vec<(String, crate::serving::MetricsSummary)> = variant_metrics
        .iter()
        .map(|(name, m)| (name.clone(), m.summarize()))
        .collect();
    for (name, help, project) in crate::serving::SUMMARY_FIELDS {
        let kind = if name.ends_with("_total") {
            "counter"
        } else {
            "gauge"
        };
        family_header(&mut out, name, kind, help);
        for (variant, s) in &summaries {
            labeled(&mut out, name, variant, project(s));
        }
    }

    // Per-variant distributions: latency, queue wait, and batch size (same
    // log2 histogram type; the batch-size "le" bounds are item counts, not
    // microseconds).
    type HistProj = fn(&crate::serving::Metrics) -> &LatencyHistogram;
    let hist_families: [(&str, &str, HistProj); 3] = [
        (
            "mpcnn_variant_latency_us",
            "end-to-end request latency (us)",
            |m| &m.latency,
        ),
        (
            "mpcnn_variant_queue_wait_us",
            "time queued before batch assembly (us)",
            |m| &m.queue_wait,
        ),
        (
            "mpcnn_variant_batch_size",
            "executed batch sizes (items per batch, before padding)",
            |m| &m.batch_sizes,
        ),
    ];
    for (name, help, project) in hist_families {
        family_header(&mut out, name, "histogram", help);
        for (variant, m) in &variant_metrics {
            let label = format!("variant=\"{variant}\"");
            histogram_series(&mut out, name, Some(&label), project(m));
        }
    }

    // Server-level robustness ledger (retry/hedge/breaker effects).
    let r = state.server.robustness_report();
    let robust_metrics: [(&str, &str, f64); 7] = [
        (
            "mpcnn_robust_shed_total",
            "requests shed across all variants (admission + dequeue)",
            r.shed as f64,
        ),
        (
            "mpcnn_robust_panics_total",
            "backend panics across all variants",
            r.panics as f64,
        ),
        (
            "mpcnn_robust_worker_restarts_total",
            "worker restarts across all variants",
            r.worker_restarts as f64,
        ),
        (
            "mpcnn_robust_retried_total",
            "requests that consumed at least one retry attempt",
            r.retried as f64,
        ),
        (
            "mpcnn_robust_hedged_total",
            "requests that launched a hedge attempt",
            r.hedged as f64,
        ),
        (
            "mpcnn_robust_hedge_wins_total",
            "hedge attempts that answered first",
            r.hedge_wins as f64,
        ),
        (
            "mpcnn_robust_fallbacks_total",
            "retries that re-routed onto a different variant",
            r.fallbacks as f64,
        ),
    ];
    for (name, help, value) in robust_metrics {
        metric(&mut out, name, "counter", help, value);
    }

    // SLO engine: per-alert state and burn rates (labeled by alert name,
    // not variant — one SLO may fan out to one alert per variant and the
    // alert name already embeds the variant). Absent when the sampler is
    // off (`serve --listen` without `--slo`).
    if let Some(obs) = &state.obs {
        let views = obs.engine.snapshot();
        type AlertProj = fn(&crate::obs::AlertView) -> f64;
        let slo_families: [(&str, &str, AlertProj); 3] = [
            (
                "mpcnn_slo_alert_state",
                "alert state (0 inactive, 1 pending, 2 firing, 3 resolved)",
                |v| v.state.code() as f64,
            ),
            (
                "mpcnn_slo_fast_burn",
                "error-budget burn rate over the alert's fast window",
                |v| v.fast_burn,
            ),
            (
                "mpcnn_slo_slow_burn",
                "error-budget burn rate over the alert's slow window",
                |v| v.slow_burn,
            ),
        ];
        for (name, help, project) in slo_families {
            family_header(&mut out, name, "gauge", help);
            for v in &views {
                out.push_str(&format!("{name}{{alert=\"{}\"}} {}\n", v.name, project(v)));
            }
        }
        metric(
            &mut out,
            "mpcnn_slo_alerts_firing",
            "gauge",
            "alerts currently in the firing state",
            obs.engine.firing().len() as f64,
        );
        metric(
            &mut out,
            "mpcnn_slo_events_total",
            "counter",
            "events appended to the journal (ring may have evicted old ones)",
            obs.journal.appended() as f64,
        );
        metric(
            &mut out,
            "mpcnn_slo_samples",
            "gauge",
            "snapshots currently retained in the time-series ring",
            obs.tsdb.len() as f64,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_series_is_cumulative_and_coherent() {
        let mut h = LatencyHistogram::default();
        for us in [1.0, 3.0, 3.0, 100.0, 1e12] {
            h.record_us(us);
        }
        let mut out = String::new();
        histogram_series(&mut out, "x_us", Some("variant=\"w4\""), &h);
        let bucket = |le: &str| -> u64 {
            out.lines()
                .find(|l| l.starts_with(&format!("x_us_bucket{{variant=\"w4\",le=\"{le}\"}}")))
                .and_then(|l| l.rsplit(' ').next())
                .unwrap()
                .parse()
                .unwrap()
        };
        assert_eq!(bucket("2"), 1, "1us lands in [1,2)");
        assert_eq!(bucket("4"), 3, "3us samples land in [2,4)");
        assert_eq!(bucket("128"), 4, "100us lands in [64,128)");
        assert_eq!(bucket("+Inf"), 5, "overflow sample only reaches +Inf via clamp");
        assert!(out.contains("x_us_count{variant=\"w4\"} 5"), "{out}");
        let mut prev = 0u64;
        for l in out.lines().filter(|l| l.contains("_bucket")) {
            let v: u64 = l.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "buckets must be cumulative: {l}");
            prev = v;
        }
    }

    #[test]
    fn observe_classifies_status_bands() {
        let m = EdgeMetrics::new();
        m.observe(200, Duration::from_micros(100));
        m.observe(404, Duration::from_micros(100));
        m.observe(503, Duration::from_micros(100));
        let snap = m.snapshot(&ResponseCache::new(4), &NegativeCache::new(4), &Coalescer::new());
        assert_eq!(snap.requests, 3);
        assert_eq!(snap.ok, 1);
        assert_eq!(snap.client_errors, 1);
        assert_eq!(snap.server_errors, 1);
        assert!(snap.p50_us > 0.0);
    }

    #[test]
    fn agreement_counters_track_failures() {
        let m = EdgeMetrics::new();
        m.note_agreement(true);
        m.note_agreement(true);
        m.note_agreement(false);
        let snap = m.snapshot(&ResponseCache::new(4), &NegativeCache::new(4), &Coalescer::new());
        assert_eq!(snap.agreement_checks, 3);
        assert_eq!(snap.agreement_failures, 1);
    }
}
