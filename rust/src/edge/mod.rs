//! Network edge: a dependency-free HTTP/1.1 front-end over the serving
//! gateway ([`crate::serving::Server`]).
//!
//! The edge owns everything between the TCP socket and the gateway's
//! bounded variant queues:
//!
//! - **Routes** — `POST /v1/classify` (image + route selector + deadline),
//!   `GET /healthz`, `GET /metrics` (Prometheus text format), and with
//!   `--trace`: `GET /v1/trace` (recent trace index), `GET /v1/trace/<id>`
//!   (one trace's spans), `GET /v1/trace/export` (Chrome trace-event JSON).
//! - **Admission** — a per-client token bucket ([`RateLimiter`], 429) and
//!   a global inflight ceiling ([`AdmissionGate`], 503), both answering
//!   with `Retry-After` *before* a request can bloat the variant queues.
//! - **Coalescing** — concurrent duplicates of one `(variant, image)` key
//!   share a single backend inference ([`Coalescer`]).
//! - **Caching** — a bounded, sha256 content-addressed [`ResponseCache`];
//!   classification is deterministic per `(variant, image)`, so repeats
//!   are answered with bit-identical logits without touching a backend.
//! - **Observability** — every shed/hit/panic/restart signal the gateway
//!   and the edge track, rendered by [`metrics::prometheus`].
//! - **SLOs** — with `--slo`, a background [`Sampler`] thread snapshots
//!   every layer into a fixed-memory time-series ring ([`Tsdb`]), the SLO
//!   engine evaluates multi-window burn rates and drift watchdogs over
//!   it, and the results are served at `GET /v1/alerts` (alert states),
//!   `GET /v1/events` (JSONL transition journal), and
//!   `GET /v1/stats?window=30s` (windowed per-variant rates) — plus
//!   `mpcnn_slo_*` series in `/metrics` and the live `mpcnn top` view.
//!
//! Threading: one acceptor thread hands sockets to a fixed pool of
//! handler threads over a bounded channel (overflow is answered 503, not
//! queued). [`EdgeServer::shutdown`] drains gracefully: stop the sampler,
//! stop admitting, flush in-flight requests, then stop the threads.

pub mod cache;
pub mod client;
pub mod coalescing;
pub mod handlers;
pub mod http;
pub mod limits;
pub mod metrics;

pub use cache::{cache_key, negative_key, NegativeCache, NegativeEntry, ResponseCache};
pub use client::{RemoteAnswer, RemoteClient};
pub use coalescing::Coalescer;
pub use http::{HttpRequest, HttpResponse};
pub use limits::{AdmissionGate, RateLimiter};
pub use metrics::{EdgeMetrics, EdgeSnapshot};

use crate::obs::{
    AlertEngine, DriftConfig, DriftDetector, EdgeCounters, EventJournal, FlightRecorder,
    GatewayCounters, RecorderConfig, Sample, Sampler, SloSpec, Tsdb, VariantSample,
};
use crate::serving::{BackendHealth, BreakerState, FaultControls, Server};
use crate::util::error::Result;
use crate::util::json::Json;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

/// Content address of one `(variant, image)` request: a sha256 digest.
pub type Key = [u8; 32];

/// One classification result as the edge caches and serves it.
#[derive(Clone, Debug, PartialEq)]
pub struct Answer {
    pub class: usize,
    /// Variant that actually answered (retries may re-route).
    pub variant: String,
    pub logits: Vec<f32>,
}

/// Cacheability check: `(image, answer) -> ok`. Wired to the xmp reference
/// models by `mpcnn serve` so a corrupt response is never cached.
pub type ResponseCheck = Arc<dyn Fn(&[f32], &Answer) -> bool + Send + Sync>;

/// Tuning knobs for the edge. The defaults suit a loopback benchmark;
/// `mpcnn serve --listen` exposes the interesting ones as flags.
#[derive(Clone, Debug)]
pub struct EdgeConfig {
    /// Handler pool size (concurrent connections being served).
    pub handler_threads: usize,
    /// Accepted-but-unclaimed socket queue; overflow is answered 503.
    pub pending_connections: usize,
    /// Global concurrent-request ceiling (0 = unlimited).
    pub max_inflight: u64,
    /// Per-client token refill rate (0 = rate limiting off).
    pub rate_per_sec: f64,
    /// Per-client token bucket capacity.
    pub burst: f64,
    /// Response cache entries (0 = cache off).
    pub cache_capacity: usize,
    /// Largest request body accepted.
    pub max_body_bytes: usize,
    /// Socket read/write timeout.
    pub io_timeout: Duration,
    /// Enable end-to-end request tracing: every classify request gets a
    /// [`crate::obs::TraceHandle`] and lands in the flight recorder,
    /// served at `GET /v1/trace`.
    pub trace: bool,
    /// Flight-recorder ring capacity (recent completed traces).
    pub trace_capacity: usize,
    /// Traces at or above this end-to-end latency are pinned as slow
    /// exemplars until fetched by id.
    pub slow_trace_us: f64,
    /// Negative-cache entries: deterministic 4xx refusals remembered so a
    /// retry loop of a bad request never reaches route resolution twice
    /// (0 = negative caching off).
    pub negative_capacity: usize,
    /// SLO spec evaluated over the time-series ring; `None` disables the
    /// whole SLO layer (no sampler thread, 404 on `/v1/alerts` etc.).
    pub slo: Option<SloSpec>,
    /// Sampler tick interval (`serve --listen --sample-ms`).
    pub sample_interval: Duration,
    /// Time-series ring capacity in samples. The default keeps one hour
    /// at the default 1 s interval in fixed memory.
    pub tsdb_capacity: usize,
    /// Event-journal ring capacity (alert transitions, restarts, breaker
    /// flips, health changes).
    pub event_capacity: usize,
    /// Drift-watchdog tuning; the default suits second-scale sampling.
    pub drift: DriftConfig,
}

impl Default for EdgeConfig {
    fn default() -> EdgeConfig {
        EdgeConfig {
            handler_threads: 8,
            pending_connections: 64,
            max_inflight: 256,
            rate_per_sec: 1000.0,
            burst: 256.0,
            cache_capacity: 1024,
            max_body_bytes: 16 << 20,
            io_timeout: Duration::from_secs(30),
            trace: false,
            trace_capacity: 256,
            slow_trace_us: 50_000.0,
            negative_capacity: 256,
            slo: None,
            sample_interval: Duration::from_secs(1),
            tsdb_capacity: 3600,
            event_capacity: 1024,
            drift: DriftConfig::default(),
        }
    }
}

/// The SLO layer's shared state: the time-series ring the sampler fills,
/// the alert engine and journal the handlers serve, and the declarative
/// spec + drift detector evaluated every tick. Lives on [`EdgeState`] as
/// `Some` only when the edge was configured with an SLO spec.
pub struct ObsRuntime {
    pub tsdb: Tsdb,
    pub engine: AlertEngine,
    pub journal: EventJournal,
    pub drift: DriftDetector,
    pub spec: SloSpec,
}

/// Everything a handler thread needs, shared behind one `Arc`.
pub struct EdgeState {
    pub server: Arc<Server>,
    pub cfg: EdgeConfig,
    pub limiter: RateLimiter,
    pub gate: AdmissionGate,
    pub coalescer: Coalescer,
    pub cache: ResponseCache,
    /// Remembered deterministic 4xx refusals (unknown variant, pinned
    /// shape mismatch); see [`NegativeCache`].
    pub negative: NegativeCache,
    pub metrics: EdgeMetrics,
    pub check: Option<ResponseCheck>,
    /// Flight recorder behind `/v1/trace`; `None` when tracing is off
    /// (requests then carry an inert [`crate::obs::TraceHandle`]).
    pub recorder: Option<Arc<FlightRecorder>>,
    /// SLO layer (tsdb + alert engine + journal + drift); `None` without
    /// `--slo`.
    pub obs: Option<ObsRuntime>,
    /// Live fault-injection override handle, wired by `mpcnn serve
    /// --listen --fault` so `POST /v1/fault` can lift or force faults
    /// without a restart. `None` when serving real backends.
    fault: Mutex<Option<Arc<FaultControls>>>,
    draining: AtomicBool,
}

impl EdgeState {
    pub fn new(server: Arc<Server>, cfg: EdgeConfig, check: Option<ResponseCheck>) -> EdgeState {
        EdgeState {
            limiter: RateLimiter::new(cfg.rate_per_sec, cfg.burst),
            gate: AdmissionGate::new(cfg.max_inflight),
            coalescer: Coalescer::new(),
            cache: ResponseCache::new(cfg.cache_capacity),
            negative: NegativeCache::new(cfg.negative_capacity),
            metrics: EdgeMetrics::new(),
            recorder: cfg.trace.then(|| {
                Arc::new(FlightRecorder::new(RecorderConfig {
                    capacity: cfg.trace_capacity,
                    slow_threshold_us: cfg.slow_trace_us,
                    ..RecorderConfig::default()
                }))
            }),
            obs: cfg.slo.clone().map(|spec| ObsRuntime {
                tsdb: Tsdb::new(cfg.tsdb_capacity),
                engine: AlertEngine::new(),
                journal: EventJournal::new(cfg.event_capacity),
                drift: DriftDetector::new(cfg.drift.clone()),
                spec,
            }),
            fault: Mutex::new(None),
            server,
            cfg,
            check,
            draining: AtomicBool::new(false),
        }
    }

    /// True once shutdown has begun: classify refuses (503) and keep-alive
    /// connections close after the in-flight response.
    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Expose a fault-injection handle on `POST /v1/fault`. Called once
    /// after bind by `mpcnn serve --listen --fault`.
    pub fn set_fault_controls(&self, controls: Arc<FaultControls>) {
        *self.fault.lock().unwrap_or_else(|e| e.into_inner()) = Some(controls);
    }

    pub fn fault_controls(&self) -> Option<Arc<FaultControls>> {
        self.fault.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }
}

/// Bound on how long [`EdgeServer::shutdown`] waits for in-flight
/// requests to flush before stopping the threads anyway.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(10);

/// The running front-end: an acceptor, a handler pool, shared state.
pub struct EdgeServer {
    state: Arc<EdgeState>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: JoinHandle<()>,
    handlers: Vec<JoinHandle<()>>,
    /// Background SLO sampler; `None` without `--slo`.
    sampler: Option<Sampler>,
}

impl EdgeServer {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and start serving immediately.
    pub fn bind(
        server: Arc<Server>,
        addr: &str,
        cfg: EdgeConfig,
        check: Option<ResponseCheck>,
    ) -> Result<EdgeServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let state = Arc::new(EdgeState::new(server, cfg, check));
        let stop = Arc::new(AtomicBool::new(false));

        let (conn_tx, conn_rx) = sync_channel::<TcpStream>(
            state.cfg.pending_connections.max(1),
        );
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        // The acceptor is the sole owner of `conn_tx`: when it exits, the
        // channel disconnects and the handler pool drains out.
        let acceptor = {
            let state = state.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("edge-acceptor".to_string())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let stream = match conn {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        match conn_tx.try_send(stream) {
                            Ok(()) => {}
                            Err(TrySendError::Full(mut stream)) => {
                                // Shed at the socket: the hand-off queue is
                                // the last bound before unbounded memory.
                                state.metrics.note_queue_shed();
                                let _ = HttpResponse::text(503, "connection queue full\n")
                                    .retry_after_secs(1)
                                    .with_header("Connection", "close")
                                    .write(&mut stream);
                            }
                            Err(TrySendError::Disconnected(_)) => break,
                        }
                    }
                })?
        };

        let mut handlers = Vec::with_capacity(state.cfg.handler_threads.max(1));
        for i in 0..state.cfg.handler_threads.max(1) {
            let state = state.clone();
            let conn_rx = conn_rx.clone();
            handlers.push(
                std::thread::Builder::new()
                    .name(format!("edge-handler-{i}"))
                    .spawn(move || loop {
                        let next = {
                            let rx = conn_rx.lock().unwrap_or_else(|e| e.into_inner());
                            rx.recv()
                        };
                        match next {
                            Ok(stream) => serve_connection(&state, stream),
                            Err(_) => break, // acceptor gone, queue drained
                        }
                    })?,
            );
        }

        // The sampler holds its own Arc to the state and a `prev` sample
        // for event derivation; ticks are cheap (counter loads + histogram
        // clones) so they share no locks with the request path beyond the
        // metrics the handlers already touch.
        let sampler = state.obs.is_some().then(|| {
            let state = state.clone();
            let mut prev: Option<Sample> = None;
            Sampler::spawn(state.cfg.sample_interval, move || {
                sample_tick(&state, &mut prev);
            })
        });

        Ok(EdgeServer {
            state,
            addr: local,
            stop,
            acceptor,
            handlers,
            sampler,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn state(&self) -> &Arc<EdgeState> {
        &self.state
    }

    /// Point-in-time copy of every edge counter.
    pub fn snapshot(&self) -> EdgeSnapshot {
        self.state.metrics.snapshot(
            &self.state.cache,
            &self.state.negative,
            &self.state.coalescer,
        )
    }

    /// Graceful drain: stop admitting new classify work, flush what is
    /// in flight (bounded by [`DRAIN_TIMEOUT`]), then stop the acceptor
    /// and the handler pool. Returns the final counter snapshot.
    pub fn shutdown(self) -> EdgeSnapshot {
        // Stop the sampler first: a tick mid-drain would race the counter
        // flush and journal a spurious final delta.
        if let Some(sampler) = &self.sampler {
            sampler.stop();
        }
        self.state.draining.store(true, Ordering::SeqCst);
        let deadline = Instant::now() + DRAIN_TIMEOUT;
        while self.state.gate.inflight() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }

        self.stop.store(true, Ordering::SeqCst);
        // accept() is blocking; a throwaway local connection wakes the
        // acceptor so it can observe the stop flag and exit.
        let _ = TcpStream::connect(self.addr);
        let _ = self.acceptor.join();
        for h in self.handlers {
            let _ = h.join();
        }
        self.state.metrics.snapshot(
            &self.state.cache,
            &self.state.negative,
            &self.state.coalescer,
        )
    }
}

/// Serve one connection: parse requests in a keep-alive loop, dispatch,
/// record latency per response. Closes on parse error, io error, client
/// `Connection: close`, or drain.
fn serve_connection(state: &EdgeState, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(state.cfg.io_timeout));
    let _ = stream.set_write_timeout(Some(state.cfg.io_timeout));
    let _ = stream.set_nodelay(true);
    let peer = stream
        .peer_addr()
        .map(|a| a.ip().to_string())
        .unwrap_or_else(|_| "unknown".to_string());
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);

    loop {
        let req = match http::read_request(&mut reader, state.cfg.max_body_bytes) {
            Ok(Some(req)) => req,
            Ok(None) => break, // clean close between requests
            Err(e) => {
                // Parse errors get a 400; io errors (timeout, reset) just
                // close — there is no one listening to apologize to.
                if !e.starts_with("io") {
                    let resp = HttpResponse::text(400, format!("{e}\n"))
                        .with_header("Connection", "close");
                    let _ = resp.write(&mut stream);
                    state.metrics.observe(400, Duration::ZERO);
                }
                break;
            }
        };
        let started = Instant::now();
        let mut resp = handlers::handle(state, &req, &peer);
        let keep = req.keep_alive() && !state.draining();
        if !keep {
            resp = resp.with_header("Connection", "close");
        }
        let status = resp.status;
        let write_ok = resp.write(&mut stream).is_ok();
        state.metrics.observe(status, started.elapsed());
        if !keep || !write_ok {
            break;
        }
    }
}

/// Wall-clock microseconds since the Unix epoch — the tsdb's and the
/// event journal's shared timebase.
fn now_unix_us() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

fn health_byte(h: BackendHealth) -> u8 {
    match h {
        BackendHealth::Healthy => 0,
        BackendHealth::Degraded => 1,
        BackendHealth::Unavailable => 2,
    }
}

fn breaker_byte(b: BreakerState) -> u8 {
    match b {
        BreakerState::Closed => 0,
        BreakerState::Open => 1,
        BreakerState::HalfOpen => 2,
    }
}

/// Snapshot every layer — edge counters, gateway robustness ledger, and
/// each variant's cumulative metrics + live router/breaker view — into
/// one [`Sample`].
fn collect_sample(state: &EdgeState, at_us: u64) -> Sample {
    let snap = state
        .metrics
        .snapshot(&state.cache, &state.negative, &state.coalescer);
    let edge = EdgeCounters {
        requests: snap.requests,
        ok: snap.ok,
        client_errors: snap.client_errors,
        server_errors: snap.server_errors,
        rate_limited: snap.rate_limited,
        admission_shed: snap.admission_shed,
        queue_shed: snap.queue_shed,
        bad_requests: snap.bad_requests,
        classify_requests: snap.classify_requests,
        cache_hits: snap.cache_hits,
        cache_misses: snap.cache_misses,
        negative_hits: snap.negative_hits,
        negative_insertions: snap.negative_insertions,
        agreement_checks: snap.agreement_checks,
        agreement_failures: snap.agreement_failures,
    };
    let r = state.server.robustness_report();
    let gateway = GatewayCounters {
        shed: r.shed,
        shed_admission: r.shed_admission,
        shed_expired: r.shed_expired,
        panics: r.panics,
        worker_restarts: r.worker_restarts,
        retried: r.retried,
        hedged: r.hedged,
        hedge_wins: r.hedge_wins,
        fallbacks: r.fallbacks,
    };
    let statuses = state.server.statuses();
    let breakers = state.server.breaker_states();
    let variants = state
        .server
        .metrics_all()
        .into_iter()
        .map(|(name, m)| {
            let status = statuses.iter().find(|s| s.name.as_ref() == name.as_str());
            let breaker = breakers
                .iter()
                .find(|(b, _)| b == &name)
                .map(|(_, s)| *s)
                .unwrap_or(BreakerState::Closed);
            VariantSample {
                requests: m.requests,
                responses: m.responses,
                errors: m.errors,
                shed_admission: m.shed_admission,
                shed_expired: m.shed_expired,
                panics: m.panics,
                worker_restarts: m.worker_restarts,
                batches: m.batches,
                latency_buckets: *m.latency.buckets(),
                latency_sum_us: m.latency.sum_us(),
                latency_max_us: m.latency.max_us(),
                queue_buckets: *m.queue_wait.buckets(),
                queue_sum_us: m.queue_wait.sum_us(),
                queue_max_us: m.queue_wait.max_us(),
                ewma_us: status.map_or(m.ewma_latency_us, |s| s.ewma_latency_us),
                fpga_fps: status.map_or(0.0, |s| s.fpga_fps),
                health: status.map_or(0, |s| health_byte(s.health)),
                breaker: breaker_byte(breaker),
                name,
            }
        })
        .collect();
    Sample {
        at_us,
        edge,
        gateway,
        variants,
    }
}

/// Journal the discrete state changes between two consecutive samples:
/// worker restarts, circuit-breaker flips, health transitions (degraded-
/// mode entry/exit). Derived from sampler deltas, not hot-path hooks, so
/// the request path pays nothing for the journal.
fn derive_events(obs: &ObsRuntime, prev: Option<&Sample>, cur: &Sample) {
    use crate::obs::tsdb::{breaker_name, health_name};
    let Some(prev) = prev else { return };
    for v in &cur.variants {
        let old = prev.variants.iter().find(|p| p.name == v.name);
        let (old_restarts, old_breaker, old_health) = match old {
            Some(p) => (p.worker_restarts, p.breaker, p.health),
            // A variant that appeared mid-flight has no history to diff.
            None => (v.worker_restarts, v.breaker, v.health),
        };
        if v.worker_restarts > old_restarts {
            obs.journal.record(
                cur.at_us,
                "worker_restart",
                vec![
                    ("variant", Json::str(v.name.clone())),
                    (
                        "restarts",
                        Json::num((v.worker_restarts - old_restarts) as f64),
                    ),
                    ("total", Json::num(v.worker_restarts as f64)),
                ],
            );
        }
        if v.breaker != old_breaker {
            obs.journal.record(
                cur.at_us,
                "breaker",
                vec![
                    ("variant", Json::str(v.name.clone())),
                    ("from", Json::str(breaker_name(old_breaker))),
                    ("to", Json::str(breaker_name(v.breaker))),
                ],
            );
        }
        if v.health != old_health {
            obs.journal.record(
                cur.at_us,
                "health",
                vec![
                    ("variant", Json::str(v.name.clone())),
                    ("from", Json::str(health_name(old_health))),
                    ("to", Json::str(health_name(v.health))),
                ],
            );
        }
    }
}

/// One sampler tick: journal delta events, push the sample, evaluate the
/// SLO spec and the drift watchdogs over the ring, and step the alert
/// state machines (which journal their own transitions).
fn sample_tick(state: &EdgeState, prev: &mut Option<Sample>) {
    let Some(obs) = &state.obs else { return };
    let now = now_unix_us();
    let sample = collect_sample(state, now);
    derive_events(obs, prev.as_ref(), &sample);
    obs.tsdb.push(sample.clone());
    let mut signals = crate::obs::slo::evaluate(&obs.spec, &obs.tsdb);
    signals.extend(obs.drift.evaluate(&obs.tsdb));
    obs.engine.observe(now, &signals, &obs.journal);
    *prev = Some(sample);
}
