//! Network edge: a dependency-free HTTP/1.1 front-end over the serving
//! gateway ([`crate::serving::Server`]).
//!
//! The edge owns everything between the TCP socket and the gateway's
//! bounded variant queues:
//!
//! - **Routes** — `POST /v1/classify` (image + route selector + deadline),
//!   `GET /healthz`, `GET /metrics` (Prometheus text format), and with
//!   `--trace`: `GET /v1/trace` (recent trace index), `GET /v1/trace/<id>`
//!   (one trace's spans), `GET /v1/trace/export` (Chrome trace-event JSON).
//! - **Admission** — a per-client token bucket ([`RateLimiter`], 429) and
//!   a global inflight ceiling ([`AdmissionGate`], 503), both answering
//!   with `Retry-After` *before* a request can bloat the variant queues.
//! - **Coalescing** — concurrent duplicates of one `(variant, image)` key
//!   share a single backend inference ([`Coalescer`]).
//! - **Caching** — a bounded, sha256 content-addressed [`ResponseCache`];
//!   classification is deterministic per `(variant, image)`, so repeats
//!   are answered with bit-identical logits without touching a backend.
//! - **Observability** — every shed/hit/panic/restart signal the gateway
//!   and the edge track, rendered by [`metrics::prometheus`].
//!
//! Threading: one acceptor thread hands sockets to a fixed pool of
//! handler threads over a bounded channel (overflow is answered 503, not
//! queued). [`EdgeServer::shutdown`] drains gracefully: stop admitting,
//! flush in-flight requests, then stop the threads.

pub mod cache;
pub mod client;
pub mod coalescing;
pub mod handlers;
pub mod http;
pub mod limits;
pub mod metrics;

pub use cache::{cache_key, ResponseCache};
pub use client::{RemoteAnswer, RemoteClient};
pub use coalescing::Coalescer;
pub use http::{HttpRequest, HttpResponse};
pub use limits::{AdmissionGate, RateLimiter};
pub use metrics::{EdgeMetrics, EdgeSnapshot};

use crate::obs::{FlightRecorder, RecorderConfig};
use crate::serving::Server;
use crate::util::error::Result;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Content address of one `(variant, image)` request: a sha256 digest.
pub type Key = [u8; 32];

/// One classification result as the edge caches and serves it.
#[derive(Clone, Debug, PartialEq)]
pub struct Answer {
    pub class: usize,
    /// Variant that actually answered (retries may re-route).
    pub variant: String,
    pub logits: Vec<f32>,
}

/// Cacheability check: `(image, answer) -> ok`. Wired to the xmp reference
/// models by `mpcnn serve` so a corrupt response is never cached.
pub type ResponseCheck = Arc<dyn Fn(&[f32], &Answer) -> bool + Send + Sync>;

/// Tuning knobs for the edge. The defaults suit a loopback benchmark;
/// `mpcnn serve --listen` exposes the interesting ones as flags.
#[derive(Clone, Debug)]
pub struct EdgeConfig {
    /// Handler pool size (concurrent connections being served).
    pub handler_threads: usize,
    /// Accepted-but-unclaimed socket queue; overflow is answered 503.
    pub pending_connections: usize,
    /// Global concurrent-request ceiling (0 = unlimited).
    pub max_inflight: u64,
    /// Per-client token refill rate (0 = rate limiting off).
    pub rate_per_sec: f64,
    /// Per-client token bucket capacity.
    pub burst: f64,
    /// Response cache entries (0 = cache off).
    pub cache_capacity: usize,
    /// Largest request body accepted.
    pub max_body_bytes: usize,
    /// Socket read/write timeout.
    pub io_timeout: Duration,
    /// Enable end-to-end request tracing: every classify request gets a
    /// [`crate::obs::TraceHandle`] and lands in the flight recorder,
    /// served at `GET /v1/trace`.
    pub trace: bool,
    /// Flight-recorder ring capacity (recent completed traces).
    pub trace_capacity: usize,
    /// Traces at or above this end-to-end latency are pinned as slow
    /// exemplars until fetched by id.
    pub slow_trace_us: f64,
}

impl Default for EdgeConfig {
    fn default() -> EdgeConfig {
        EdgeConfig {
            handler_threads: 8,
            pending_connections: 64,
            max_inflight: 256,
            rate_per_sec: 1000.0,
            burst: 256.0,
            cache_capacity: 1024,
            max_body_bytes: 16 << 20,
            io_timeout: Duration::from_secs(30),
            trace: false,
            trace_capacity: 256,
            slow_trace_us: 50_000.0,
        }
    }
}

/// Everything a handler thread needs, shared behind one `Arc`.
pub struct EdgeState {
    pub server: Arc<Server>,
    pub cfg: EdgeConfig,
    pub limiter: RateLimiter,
    pub gate: AdmissionGate,
    pub coalescer: Coalescer,
    pub cache: ResponseCache,
    pub metrics: EdgeMetrics,
    pub check: Option<ResponseCheck>,
    /// Flight recorder behind `/v1/trace`; `None` when tracing is off
    /// (requests then carry an inert [`crate::obs::TraceHandle`]).
    pub recorder: Option<Arc<FlightRecorder>>,
    draining: AtomicBool,
}

impl EdgeState {
    pub fn new(server: Arc<Server>, cfg: EdgeConfig, check: Option<ResponseCheck>) -> EdgeState {
        EdgeState {
            limiter: RateLimiter::new(cfg.rate_per_sec, cfg.burst),
            gate: AdmissionGate::new(cfg.max_inflight),
            coalescer: Coalescer::new(),
            cache: ResponseCache::new(cfg.cache_capacity),
            metrics: EdgeMetrics::new(),
            recorder: cfg.trace.then(|| {
                Arc::new(FlightRecorder::new(RecorderConfig {
                    capacity: cfg.trace_capacity,
                    slow_threshold_us: cfg.slow_trace_us,
                    ..RecorderConfig::default()
                }))
            }),
            server,
            cfg,
            check,
            draining: AtomicBool::new(false),
        }
    }

    /// True once shutdown has begun: classify refuses (503) and keep-alive
    /// connections close after the in-flight response.
    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }
}

/// Bound on how long [`EdgeServer::shutdown`] waits for in-flight
/// requests to flush before stopping the threads anyway.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(10);

/// The running front-end: an acceptor, a handler pool, shared state.
pub struct EdgeServer {
    state: Arc<EdgeState>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: JoinHandle<()>,
    handlers: Vec<JoinHandle<()>>,
}

impl EdgeServer {
    /// Bind `addr` (e.g. `127.0.0.1:0`) and start serving immediately.
    pub fn bind(
        server: Arc<Server>,
        addr: &str,
        cfg: EdgeConfig,
        check: Option<ResponseCheck>,
    ) -> Result<EdgeServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let state = Arc::new(EdgeState::new(server, cfg, check));
        let stop = Arc::new(AtomicBool::new(false));

        let (conn_tx, conn_rx) = sync_channel::<TcpStream>(
            state.cfg.pending_connections.max(1),
        );
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        // The acceptor is the sole owner of `conn_tx`: when it exits, the
        // channel disconnects and the handler pool drains out.
        let acceptor = {
            let state = state.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("edge-acceptor".to_string())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let stream = match conn {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        match conn_tx.try_send(stream) {
                            Ok(()) => {}
                            Err(TrySendError::Full(mut stream)) => {
                                // Shed at the socket: the hand-off queue is
                                // the last bound before unbounded memory.
                                state.metrics.note_queue_shed();
                                let _ = HttpResponse::text(503, "connection queue full\n")
                                    .retry_after_secs(1)
                                    .with_header("Connection", "close")
                                    .write(&mut stream);
                            }
                            Err(TrySendError::Disconnected(_)) => break,
                        }
                    }
                })?
        };

        let mut handlers = Vec::with_capacity(state.cfg.handler_threads.max(1));
        for i in 0..state.cfg.handler_threads.max(1) {
            let state = state.clone();
            let conn_rx = conn_rx.clone();
            handlers.push(
                std::thread::Builder::new()
                    .name(format!("edge-handler-{i}"))
                    .spawn(move || loop {
                        let next = {
                            let rx = conn_rx.lock().unwrap_or_else(|e| e.into_inner());
                            rx.recv()
                        };
                        match next {
                            Ok(stream) => serve_connection(&state, stream),
                            Err(_) => break, // acceptor gone, queue drained
                        }
                    })?,
            );
        }

        Ok(EdgeServer {
            state,
            addr: local,
            stop,
            acceptor,
            handlers,
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn state(&self) -> &Arc<EdgeState> {
        &self.state
    }

    /// Point-in-time copy of every edge counter.
    pub fn snapshot(&self) -> EdgeSnapshot {
        self.state
            .metrics
            .snapshot(&self.state.cache, &self.state.coalescer)
    }

    /// Graceful drain: stop admitting new classify work, flush what is
    /// in flight (bounded by [`DRAIN_TIMEOUT`]), then stop the acceptor
    /// and the handler pool. Returns the final counter snapshot.
    pub fn shutdown(self) -> EdgeSnapshot {
        self.state.draining.store(true, Ordering::SeqCst);
        let deadline = Instant::now() + DRAIN_TIMEOUT;
        while self.state.gate.inflight() > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }

        self.stop.store(true, Ordering::SeqCst);
        // accept() is blocking; a throwaway local connection wakes the
        // acceptor so it can observe the stop flag and exit.
        let _ = TcpStream::connect(self.addr);
        let _ = self.acceptor.join();
        for h in self.handlers {
            let _ = h.join();
        }
        self.state
            .metrics
            .snapshot(&self.state.cache, &self.state.coalescer)
    }
}

/// Serve one connection: parse requests in a keep-alive loop, dispatch,
/// record latency per response. Closes on parse error, io error, client
/// `Connection: close`, or drain.
fn serve_connection(state: &EdgeState, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(state.cfg.io_timeout));
    let _ = stream.set_write_timeout(Some(state.cfg.io_timeout));
    let _ = stream.set_nodelay(true);
    let peer = stream
        .peer_addr()
        .map(|a| a.ip().to_string())
        .unwrap_or_else(|_| "unknown".to_string());
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);

    loop {
        let req = match http::read_request(&mut reader, state.cfg.max_body_bytes) {
            Ok(Some(req)) => req,
            Ok(None) => break, // clean close between requests
            Err(e) => {
                // Parse errors get a 400; io errors (timeout, reset) just
                // close — there is no one listening to apologize to.
                if !e.starts_with("io") {
                    let resp = HttpResponse::text(400, format!("{e}\n"))
                        .with_header("Connection", "close");
                    let _ = resp.write(&mut stream);
                    state.metrics.observe(400, Duration::ZERO);
                }
                break;
            }
        };
        let started = Instant::now();
        let mut resp = handlers::handle(state, &req, &peer);
        let keep = req.keep_alive() && !state.draining();
        if !keep {
            resp = resp.with_header("Connection", "close");
        }
        let status = resp.status;
        let write_ok = resp.write(&mut stream).is_ok();
        state.metrics.observe(status, started.elapsed());
        if !keep || !write_ok {
            break;
        }
    }
}
