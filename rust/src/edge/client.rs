//! `RemoteClient` — the std-only HTTP/1.1 client behind
//! `mpcnn classify --remote` and `mpcnn top`, also used by the
//! integration tests and the edge bench.
//!
//! **Keep-alive:** the client holds one pooled connection and reuses it
//! across requests (classify loops, `top`'s poll cycle). A stale pooled
//! socket — the server idled it out between polls — is detected by the
//! failed exchange and replaced with a fresh connect *within the same
//! attempt*, so connection reuse never costs an attempt from the retry
//! budget. A connection goes back in the pool only when the response was
//! `Content-Length`-framed and the server didn't say `Connection: close`.
//!
//! Connection-level failures (refused, reset, timed out socket) are
//! retried under the serving [`RetryPolicy`]'s attempt budget and
//! exponential backoff — the same policy shape PR 6 gave the gateway.
//! HTTP error *statuses* are never retried here: the server already ran
//! its own retry/hedge machinery before answering, and a deterministic
//! classify is idempotent, so only transport loss is worth a resend.

use super::http;
use crate::anyhow;
use crate::serving::RetryPolicy;
use crate::util::error::Result;
use crate::util::json::Json;
use std::io::BufReader;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::Duration;

/// A parsed successful `/v1/classify` response.
#[derive(Clone, Debug)]
pub struct RemoteAnswer {
    pub class: usize,
    pub variant: String,
    pub logits: Vec<f32>,
    /// Served from the content-addressed cache (no inference ran).
    pub cached: bool,
    /// Rode an in-flight duplicate's inference.
    pub coalesced: bool,
}

pub struct RemoteClient {
    addr: String,
    pub retry: RetryPolicy,
    pub timeout: Duration,
    /// One idle keep-alive connection, reused by the next request.
    pool: Mutex<Option<BufReader<TcpStream>>>,
}

impl RemoteClient {
    /// Accepts `http://HOST:PORT` or bare `HOST:PORT`.
    pub fn new(addr: &str, retry: RetryPolicy) -> RemoteClient {
        let addr = addr.strip_prefix("http://").unwrap_or(addr);
        RemoteClient {
            addr: addr.trim_end_matches('/').to_string(),
            retry,
            timeout: Duration::from_secs(30),
            pool: Mutex::new(None),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// POST one image to `/v1/classify`.
    pub fn classify(
        &self,
        image: &[f32],
        route: Option<&str>,
        deadline_ms: Option<u64>,
        client_id: Option<&str>,
    ) -> Result<RemoteAnswer> {
        let mut pairs: Vec<(&str, Json)> = vec![(
            "image",
            Json::Arr(image.iter().map(|&v| Json::num(v as f64)).collect()),
        )];
        if let Some(r) = route {
            pairs.push(("route", Json::str(r)));
        }
        if let Some(d) = deadline_ms {
            pairs.push(("deadline_ms", Json::num(d as f64)));
        }
        if let Some(c) = client_id {
            pairs.push(("client", Json::str(c)));
        }
        let body = Json::obj(pairs).to_string_compact();
        let resp = self.send_with_retry("POST", "/v1/classify", body.as_bytes())?;
        if resp.status != 200 {
            return Err(anyhow!(
                "HTTP {} from {}: {}",
                resp.status,
                self.addr,
                resp.body_text().trim()
            ));
        }
        parse_answer(&resp.body)
    }

    /// GET a path (healthz, metrics); returns (status, body).
    pub fn get(&self, path: &str) -> Result<(u16, String)> {
        let resp = self.send_with_retry("GET", path, &[])?;
        Ok((resp.status, resp.body_text()))
    }

    fn take_pooled(&self) -> Option<BufReader<TcpStream>> {
        self.pool.lock().unwrap_or_else(|e| e.into_inner()).take()
    }

    fn put_pooled(&self, conn: BufReader<TcpStream>) {
        *self.pool.lock().unwrap_or_else(|e| e.into_inner()) = Some(conn);
    }

    fn send_with_retry(
        &self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> Result<http::ClientResponse> {
        let attempts = self.retry.max_attempts.max(1);
        let mut last: Option<std::io::Error> = None;
        let headers = [("Content-Type", "application/json")];
        for attempt in 0..attempts {
            if attempt > 0 {
                let backoff = self.retry.backoff_before(attempt);
                if !backoff.is_zero() {
                    std::thread::sleep(backoff);
                }
            }
            // Reuse the pooled keep-alive connection first. A stale pool
            // (the server closed the idle socket) falls through to a fresh
            // connect below WITHOUT consuming this attempt: the request
            // never reached a live server, and idling out is the normal
            // fate of a pooled connection, not a server failure.
            if let Some(mut conn) = self.take_pooled() {
                if let Ok((resp, reusable)) =
                    http::exchange(&mut conn, &self.addr, method, path, &headers, body, true)
                {
                    if reusable {
                        self.put_pooled(conn);
                    }
                    return Ok(resp);
                }
            }
            match http::connect(&self.addr, self.timeout) {
                Ok(mut conn) => {
                    match http::exchange(
                        &mut conn,
                        &self.addr,
                        method,
                        path,
                        &headers,
                        body,
                        true,
                    ) {
                        Ok((resp, reusable)) => {
                            if reusable {
                                self.put_pooled(conn);
                            }
                            return Ok(resp);
                        }
                        Err(e) => last = Some(e),
                    }
                }
                Err(e) => last = Some(e),
            }
        }
        Err(anyhow!(
            "connection to {} failed after {attempts} attempt(s): {}",
            self.addr,
            last.map(|e| e.to_string()).unwrap_or_default()
        ))
    }
}

fn parse_answer(body: &[u8]) -> Result<RemoteAnswer> {
    let text = std::str::from_utf8(body).map_err(|e| anyhow!("response is not UTF-8: {e}"))?;
    let j = crate::util::json::parse(text).map_err(|e| anyhow!("bad response JSON: {e}"))?;
    let class = j
        .get("class")
        .and_then(|v| v.as_u64())
        .ok_or_else(|| anyhow!("response is missing \"class\""))? as usize;
    let variant = j
        .get("variant")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("response is missing \"variant\""))?
        .to_string();
    let logits = j
        .get("logits")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|f| f as f32).collect())
        .unwrap_or_default();
    Ok(RemoteAnswer {
        class,
        variant,
        logits,
        cached: j.get("cached").and_then(|v| v.as_bool()).unwrap_or(false),
        coalesced: j.get("coalesced").and_then(|v| v.as_bool()).unwrap_or(false),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn url_forms_normalize() {
        let retry = RetryPolicy::default();
        assert_eq!(
            RemoteClient::new("http://127.0.0.1:8080/", retry).addr(),
            "127.0.0.1:8080"
        );
        let retry = RetryPolicy::default();
        assert_eq!(
            RemoteClient::new("127.0.0.1:8080", retry).addr(),
            "127.0.0.1:8080"
        );
    }

    #[test]
    fn parse_answer_round_trips() {
        let body = br#"{"class":7,"variant":"w8","cached":true,"coalesced":false,"logits":[0.5,-1.25]}"#;
        let a = parse_answer(body).unwrap();
        assert_eq!(a.class, 7);
        assert_eq!(a.variant, "w8");
        assert!(a.cached);
        assert!(!a.coalesced);
        assert_eq!(a.logits, vec![0.5, -1.25]);
        assert!(parse_answer(b"{}").is_err());
    }

    #[test]
    fn unreachable_server_fails_after_retries() {
        // Reserved-but-closed port: connect must fail fast, and the error
        // must mention the attempt budget.
        let client = RemoteClient::new("127.0.0.1:1", RetryPolicy::attempts(2));
        let e = client.get("/healthz").unwrap_err().to_string();
        assert!(e.contains("2 attempt"), "{e}");
    }

    /// Read one request head (requests here carry no body) off a raw
    /// socket; panics if the peer closes first.
    fn read_head(s: &mut std::net::TcpStream) {
        use std::io::Read;
        let mut seen = Vec::new();
        let mut buf = [0u8; 1024];
        loop {
            let n = s.read(&mut buf).expect("server read");
            assert!(n > 0, "client closed before sending a full request");
            seen.extend_from_slice(&buf[..n]);
            if seen.windows(4).any(|w| w == b"\r\n\r\n") {
                return;
            }
        }
    }

    #[test]
    fn keep_alive_reuses_one_connection() {
        use std::io::Write;
        use std::net::TcpListener;
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let conns = Arc::new(AtomicUsize::new(0));
        let server_conns = conns.clone();
        let server = std::thread::spawn(move || {
            // One accepted connection must carry both requests.
            let (mut s, _) = listener.accept().unwrap();
            server_conns.fetch_add(1, Ordering::SeqCst);
            for _ in 0..2 {
                read_head(&mut s);
                s.write_all(b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\nok")
                    .unwrap();
            }
        });

        let client = RemoteClient::new(&addr, RetryPolicy::attempts(1));
        let (s1, b1) = client.get("/healthz").unwrap();
        let (s2, b2) = client.get("/healthz").unwrap();
        server.join().unwrap();
        assert_eq!((s1, s2), (200, 200));
        assert_eq!((b1.as_str(), b2.as_str()), ("ok", "ok"));
        assert_eq!(
            conns.load(Ordering::SeqCst),
            1,
            "second request must ride the pooled connection"
        );
    }

    #[test]
    fn stale_pooled_connection_recovers_without_spending_an_attempt() {
        use std::io::Write;
        use std::net::TcpListener;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            // Two connections: each answers one framed (poolable) response
            // and then closes, so the pooled socket is stale by the time
            // the client's next request tries it.
            for _ in 0..2 {
                let (mut s, _) = listener.accept().unwrap();
                read_head(&mut s);
                s.write_all(b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\nok")
                    .unwrap();
            }
        });

        // attempts(1): the stale-pool failure must fall through to a fresh
        // connect within the SAME attempt, or this second get would error.
        let client = RemoteClient::new(&addr, RetryPolicy::attempts(1));
        assert_eq!(client.get("/a").unwrap().0, 200);
        assert_eq!(client.get("/b").unwrap().0, 200);
        server.join().unwrap();
    }

    #[test]
    fn eof_framed_response_is_not_pooled() {
        use std::io::Write;
        use std::net::TcpListener;

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            // First response has no Content-Length: EOF-framed, so the
            // client must NOT pool the connection; the second request gets
            // a fresh one.
            let (mut s, _) = listener.accept().unwrap();
            read_head(&mut s);
            s.write_all(b"HTTP/1.1 200 OK\r\n\r\nok").unwrap();
            drop(s);
            let (mut s, _) = listener.accept().unwrap();
            read_head(&mut s);
            s.write_all(b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\n\r\nok")
                .unwrap();
        });

        let client = RemoteClient::new(&addr, RetryPolicy::attempts(1));
        assert_eq!(client.get("/a").unwrap().1.as_str(), "ok");
        assert_eq!(client.get("/b").unwrap().0, 200);
        server.join().unwrap();
    }
}
