//! `RemoteClient` — the std-only HTTP/1.1 client behind
//! `mpcnn classify --remote`, also used by the integration tests and the
//! edge bench.
//!
//! Connection-level failures (refused, reset, timed out socket) are
//! retried under the serving [`RetryPolicy`]'s attempt budget and
//! exponential backoff — the same policy shape PR 6 gave the gateway.
//! HTTP error *statuses* are never retried here: the server already ran
//! its own retry/hedge machinery before answering, and a deterministic
//! classify is idempotent, so only transport loss is worth a resend.

use super::http;
use crate::anyhow;
use crate::serving::RetryPolicy;
use crate::util::error::Result;
use crate::util::json::Json;
use std::time::Duration;

/// A parsed successful `/v1/classify` response.
#[derive(Clone, Debug)]
pub struct RemoteAnswer {
    pub class: usize,
    pub variant: String,
    pub logits: Vec<f32>,
    /// Served from the content-addressed cache (no inference ran).
    pub cached: bool,
    /// Rode an in-flight duplicate's inference.
    pub coalesced: bool,
}

pub struct RemoteClient {
    addr: String,
    pub retry: RetryPolicy,
    pub timeout: Duration,
}

impl RemoteClient {
    /// Accepts `http://HOST:PORT` or bare `HOST:PORT`.
    pub fn new(addr: &str, retry: RetryPolicy) -> RemoteClient {
        let addr = addr.strip_prefix("http://").unwrap_or(addr);
        RemoteClient {
            addr: addr.trim_end_matches('/').to_string(),
            retry,
            timeout: Duration::from_secs(30),
        }
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// POST one image to `/v1/classify`.
    pub fn classify(
        &self,
        image: &[f32],
        route: Option<&str>,
        deadline_ms: Option<u64>,
        client_id: Option<&str>,
    ) -> Result<RemoteAnswer> {
        let mut pairs: Vec<(&str, Json)> = vec![(
            "image",
            Json::Arr(image.iter().map(|&v| Json::num(v as f64)).collect()),
        )];
        if let Some(r) = route {
            pairs.push(("route", Json::str(r)));
        }
        if let Some(d) = deadline_ms {
            pairs.push(("deadline_ms", Json::num(d as f64)));
        }
        if let Some(c) = client_id {
            pairs.push(("client", Json::str(c)));
        }
        let body = Json::obj(pairs).to_string_compact();
        let resp = self.send_with_retry("POST", "/v1/classify", body.as_bytes())?;
        if resp.status != 200 {
            return Err(anyhow!(
                "HTTP {} from {}: {}",
                resp.status,
                self.addr,
                resp.body_text().trim()
            ));
        }
        parse_answer(&resp.body)
    }

    /// GET a path (healthz, metrics); returns (status, body).
    pub fn get(&self, path: &str) -> Result<(u16, String)> {
        let resp = self.send_with_retry("GET", path, &[])?;
        Ok((resp.status, resp.body_text()))
    }

    fn send_with_retry(
        &self,
        method: &str,
        path: &str,
        body: &[u8],
    ) -> Result<http::ClientResponse> {
        let attempts = self.retry.max_attempts.max(1);
        let mut last: Option<std::io::Error> = None;
        for attempt in 0..attempts {
            if attempt > 0 {
                let backoff = self.retry.backoff_before(attempt);
                if !backoff.is_zero() {
                    std::thread::sleep(backoff);
                }
            }
            let headers = [("Content-Type", "application/json")];
            match http::request(&self.addr, method, path, &headers, body, self.timeout) {
                Ok(r) => return Ok(r),
                Err(e) => last = Some(e),
            }
        }
        Err(anyhow!(
            "connection to {} failed after {attempts} attempt(s): {}",
            self.addr,
            last.map(|e| e.to_string()).unwrap_or_default()
        ))
    }
}

fn parse_answer(body: &[u8]) -> Result<RemoteAnswer> {
    let text = std::str::from_utf8(body).map_err(|e| anyhow!("response is not UTF-8: {e}"))?;
    let j = crate::util::json::parse(text).map_err(|e| anyhow!("bad response JSON: {e}"))?;
    let class = j
        .get("class")
        .and_then(|v| v.as_u64())
        .ok_or_else(|| anyhow!("response is missing \"class\""))? as usize;
    let variant = j
        .get("variant")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("response is missing \"variant\""))?
        .to_string();
    let logits = j
        .get("logits")
        .and_then(|v| v.as_arr())
        .map(|a| a.iter().filter_map(|v| v.as_f64()).map(|f| f as f32).collect())
        .unwrap_or_default();
    Ok(RemoteAnswer {
        class,
        variant,
        logits,
        cached: j.get("cached").and_then(|v| v.as_bool()).unwrap_or(false),
        coalesced: j.get("coalesced").and_then(|v| v.as_bool()).unwrap_or(false),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn url_forms_normalize() {
        let retry = RetryPolicy::default();
        assert_eq!(
            RemoteClient::new("http://127.0.0.1:8080/", retry).addr(),
            "127.0.0.1:8080"
        );
        let retry = RetryPolicy::default();
        assert_eq!(
            RemoteClient::new("127.0.0.1:8080", retry).addr(),
            "127.0.0.1:8080"
        );
    }

    #[test]
    fn parse_answer_round_trips() {
        let body = br#"{"class":7,"variant":"w8","cached":true,"coalesced":false,"logits":[0.5,-1.25]}"#;
        let a = parse_answer(body).unwrap();
        assert_eq!(a.class, 7);
        assert_eq!(a.variant, "w8");
        assert!(a.cached);
        assert!(!a.coalesced);
        assert_eq!(a.logits, vec![0.5, -1.25]);
        assert!(parse_answer(b"{}").is_err());
    }

    #[test]
    fn unreachable_server_fails_after_retries() {
        // Reserved-but-closed port: connect must fail fast, and the error
        // must mention the attempt budget.
        let client = RemoteClient::new("127.0.0.1:1", RetryPolicy::attempts(2));
        let e = client.get("/healthz").unwrap_err().to_string();
        assert!(e.contains("2 attempt"), "{e}");
    }
}
