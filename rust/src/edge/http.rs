//! Minimal HTTP/1.1 wire protocol, std-only: enough request parsing for
//! the edge's three routes, a response writer, and a blocking client used
//! by `mpcnn classify --remote` and the tests.
//!
//! Deliberately small: `Content-Length` bodies only (no chunked encoding,
//! no TLS), headers capped, bodies bounded by the caller. Anything the
//! parser rejects becomes a 400 at the connection layer — malformed input
//! must never reach the inference path.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Parse errors are plain strings; the connection layer folds them into
/// the 400 body.
type ParseResult<T> = std::result::Result<T, String>;

/// Upper bound on header count per request (defense against header floods).
const MAX_HEADERS: usize = 100;
/// Upper bound on a single line (request line or header).
const MAX_LINE_BYTES: usize = 8192;

/// One parsed request: method, path, headers (order preserved), body.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header matching `name`, case-insensitively.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// HTTP/1.1 default is keep-alive unless the client says `close`.
    pub fn keep_alive(&self) -> bool {
        !matches!(self.header("connection"), Some(v) if v.eq_ignore_ascii_case("close"))
    }
}

/// Read one request off the stream. `Ok(None)` means the peer closed the
/// connection cleanly before sending anything (normal keep-alive end).
pub fn read_request<R: BufRead>(r: &mut R, max_body: usize) -> ParseResult<Option<HttpRequest>> {
    let mut line = String::new();
    match r.read_line(&mut line) {
        Ok(0) => return Ok(None),
        Ok(n) if n > MAX_LINE_BYTES => return Err("request line too long".to_string()),
        Ok(_) => {}
        Err(e) => return Err(format!("io: {e}")),
    }
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| "empty request line".to_string())?
        .to_string();
    let path = parts
        .next()
        .ok_or_else(|| "request line is missing the path".to_string())?
        .to_string();
    let version = parts.next().unwrap_or("HTTP/1.0");
    if !version.starts_with("HTTP/1.") {
        return Err(format!("unsupported version {version:?}"));
    }

    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        match r.read_line(&mut h) {
            Ok(0) => return Err("eof inside headers".to_string()),
            Ok(n) if n > MAX_LINE_BYTES => return Err("header line too long".to_string()),
            Ok(_) => {}
            Err(e) => return Err(format!("io: {e}")),
        }
        let h = h.trim_end_matches(|c| c == '\r' || c == '\n');
        if h.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err("too many headers".to_string());
        }
        match h.split_once(':') {
            Some((k, v)) => headers.push((k.trim().to_string(), v.trim().to_string())),
            None => return Err(format!("malformed header line {h:?}")),
        }
    }

    let content_length = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .map(|(_, v)| {
            v.parse::<usize>()
                .map_err(|_| format!("bad content-length {v:?}"))
        })
        .transpose()?
        .unwrap_or(0);
    if content_length > max_body {
        return Err(format!("body too large ({content_length} > {max_body} bytes)"));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        r.read_exact(&mut body)
            .map_err(|e| format!("io reading body: {e}"))?;
    }
    Ok(Some(HttpRequest {
        method,
        path,
        headers,
        body,
    }))
}

/// One response to serialize: status, extra headers, body.
/// `Content-Length` is always emitted from the body.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn new(status: u16, content_type: &str, body: Vec<u8>) -> HttpResponse {
        HttpResponse {
            status,
            headers: vec![("Content-Type".to_string(), content_type.to_string())],
            body,
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> HttpResponse {
        HttpResponse::new(status, "text/plain; charset=utf-8", body.into().into_bytes())
    }

    pub fn json(status: u16, body: &crate::util::json::Json) -> HttpResponse {
        HttpResponse::new(
            status,
            "application/json",
            body.to_string_compact().into_bytes(),
        )
    }

    pub fn with_header(mut self, name: &str, value: impl Into<String>) -> HttpResponse {
        self.headers.push((name.to_string(), value.into()));
        self
    }

    /// Standard back-pressure hint on 429/503.
    pub fn retry_after_secs(self, secs: u64) -> HttpResponse {
        self.with_header("Retry-After", secs.to_string())
    }

    pub fn write<W: Write>(&self, w: &mut W) -> std::io::Result<()> {
        write!(w, "HTTP/1.1 {} {}\r\n", self.status, reason(self.status))?;
        write!(w, "Content-Length: {}\r\n", self.body.len())?;
        for (k, v) in &self.headers {
            write!(w, "{k}: {v}\r\n")?;
        }
        w.write_all(b"\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Reason phrase for the statuses the edge emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// A response as seen by the client side.
#[derive(Clone, Debug)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Open one client connection with timeouts applied, ready for
/// [`exchange`]. Returned buffered so pipelined keep-alive responses
/// that arrive together are not lost between exchanges.
pub fn connect(addr: &str, timeout: Duration) -> std::io::Result<BufReader<TcpStream>> {
    let stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    let _ = stream.set_nodelay(true);
    Ok(BufReader::new(stream))
}

/// One blocking HTTP/1.1 exchange over an established connection: send
/// the request, read the full response. With `keep_alive` the connection
/// is reusable for another exchange afterwards — but only if the returned
/// flag says so: a response without `Content-Length` is framed by EOF,
/// and a server `Connection: close` means the peer is done either way.
///
/// Connection-level failures surface as `io::Error` so callers can
/// distinguish "server unreachable / stale socket" (retryable) from an
/// HTTP error status (not retryable here — the server already ran its own
/// retry/hedge policy).
pub fn exchange(
    conn: &mut BufReader<TcpStream>,
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<(ClientResponse, bool)> {
    let mut head = String::with_capacity(256);
    head.push_str(&format!("{method} {path} HTTP/1.1\r\n"));
    head.push_str(&format!("Host: {addr}\r\n"));
    if !keep_alive {
        head.push_str("Connection: close\r\n");
    }
    head.push_str(&format!("Content-Length: {}\r\n", body.len()));
    for (k, v) in headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    {
        let stream = conn.get_mut();
        stream.write_all(head.as_bytes())?;
        stream.write_all(body)?;
        stream.flush()?;
    }

    let mut status_line = String::new();
    conn.read_line(&mut status_line)?;
    let status = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad status line {status_line:?}"),
            )
        })?;

    let mut resp_headers = Vec::new();
    loop {
        let mut h = String::new();
        if conn.read_line(&mut h)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "eof inside response headers",
            ));
        }
        let h = h.trim_end_matches(|c| c == '\r' || c == '\n');
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            resp_headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }

    let content_length = resp_headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse::<usize>().ok());
    let mut resp_body = Vec::new();
    match content_length {
        Some(n) => {
            resp_body.resize(n, 0);
            conn.read_exact(&mut resp_body)?;
        }
        // No Content-Length: the body is framed by EOF, so the connection
        // is spent regardless of what anyone asked for.
        None => {
            conn.read_to_end(&mut resp_body)?;
        }
    }
    let server_close = resp_headers
        .iter()
        .any(|(k, v)| k.eq_ignore_ascii_case("connection") && v.eq_ignore_ascii_case("close"));
    let reusable = keep_alive && content_length.is_some() && !server_close;
    Ok((
        ClientResponse {
            status,
            headers: resp_headers,
            body: resp_body,
        },
        reusable,
    ))
}

/// One-shot convenience: connect, exchange with `Connection: close`,
/// drop the socket. The keep-alive pooling lives in
/// [`super::client::RemoteClient`].
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
    timeout: Duration,
) -> std::io::Result<ClientResponse> {
    let mut conn = connect(addr, timeout)?;
    let (resp, _reusable) = exchange(&mut conn, addr, method, path, headers, body, false)?;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/classify HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\nX-Client-Id: a\r\n\r\nhello";
        let req = read_request(&mut Cursor::new(&raw[..]), 1024)
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/classify");
        assert_eq!(req.header("x-client-id"), Some("a"));
        assert_eq!(req.body, b"hello");
        assert!(req.keep_alive());
    }

    #[test]
    fn clean_eof_is_none_and_errors_are_errors() {
        assert!(read_request(&mut Cursor::new(&b""[..]), 1024)
            .unwrap()
            .is_none());
        assert!(read_request(&mut Cursor::new(&b"GARBAGE\r\n\r\n"[..]), 1024).is_err());
        let oversized = b"POST / HTTP/1.1\r\nContent-Length: 9999\r\n\r\n";
        let e = read_request(&mut Cursor::new(&oversized[..]), 16).unwrap_err();
        assert!(e.contains("too large"), "{e}");
        let bad_len = b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n";
        assert!(read_request(&mut Cursor::new(&bad_len[..]), 16).is_err());
    }

    #[test]
    fn connection_close_disables_keep_alive() {
        let raw = b"GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..]), 1024)
            .unwrap()
            .unwrap();
        assert!(!req.keep_alive());
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        HttpResponse::text(429, "slow down")
            .retry_after_secs(2)
            .write(&mut out)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{s}");
        assert!(s.contains("Retry-After: 2\r\n"), "{s}");
        assert!(s.contains("Content-Length: 9\r\n"), "{s}");
        assert!(s.ends_with("\r\n\r\nslow down"), "{s}");
    }
}
