//! Identical-request coalescing: concurrent duplicates of one
//! `(variant, image)` key share a single backend inference.
//!
//! The first arrival becomes the *leader* and runs the inference; later
//! arrivals become *followers* and block on a channel. The leader's
//! [`LeaderGuard`] broadcasts the outcome (success or error) to every
//! follower on [`complete`](LeaderGuard::complete) — and its `Drop` impl
//! broadcasts an error if the leader unwinds without completing, so a
//! panicking handler can never strand its followers.

use super::{Answer, Key};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Mutex;

/// What followers receive: the leader's verbatim outcome.
pub type Outcome = std::result::Result<Answer, String>;

pub struct Coalescer {
    /// key -> followers waiting on the in-flight leader.
    inflight: Mutex<HashMap<Key, Vec<SyncSender<Outcome>>>>,
    leaders: AtomicU64,
    joined: AtomicU64,
}

/// Result of [`Coalescer::join`]: run the inference, or wait for whoever is.
pub enum Join<'a> {
    Leader(LeaderGuard<'a>),
    Follower(Receiver<Outcome>),
}

/// Held by the thread that owns the in-flight inference for a key.
pub struct LeaderGuard<'a> {
    coalescer: &'a Coalescer,
    key: Key,
    done: bool,
}

impl Default for Coalescer {
    fn default() -> Coalescer {
        Coalescer::new()
    }
}

impl Coalescer {
    pub fn new() -> Coalescer {
        Coalescer {
            inflight: Mutex::new(HashMap::new()),
            leaders: AtomicU64::new(0),
            joined: AtomicU64::new(0),
        }
    }

    /// Join the in-flight inference for `key`, or claim leadership of it.
    pub fn join(&self, key: Key) -> Join<'_> {
        let mut map = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(waiters) = map.get_mut(&key) {
            // Buffer 1 so the leader's broadcast never blocks on a
            // follower that timed out and dropped its receiver.
            let (tx, rx) = sync_channel(1);
            waiters.push(tx);
            self.joined.fetch_add(1, Ordering::Relaxed);
            Join::Follower(rx)
        } else {
            map.insert(key, Vec::new());
            self.leaders.fetch_add(1, Ordering::Relaxed);
            Join::Leader(LeaderGuard {
                coalescer: self,
                key,
                done: false,
            })
        }
    }

    /// Inferences led (== unique keys that reached a backend).
    pub fn leaders(&self) -> u64 {
        self.leaders.load(Ordering::Relaxed)
    }

    /// Requests that rode an in-flight duplicate instead of inferring.
    pub fn joined(&self) -> u64 {
        self.joined.load(Ordering::Relaxed)
    }

    fn finish(&self, key: &Key, outcome: &Outcome) {
        let waiters = {
            let mut map = self.inflight.lock().unwrap_or_else(|e| e.into_inner());
            map.remove(key).unwrap_or_default()
        };
        for w in waiters {
            // A follower that gave up dropped its receiver; ignore.
            let _ = w.send(outcome.clone());
        }
    }
}

impl LeaderGuard<'_> {
    /// Publish the outcome to every follower and release the key.
    pub fn complete(mut self, outcome: &Outcome) {
        self.done = true;
        self.coalescer.finish(&self.key, outcome);
    }
}

impl Drop for LeaderGuard<'_> {
    fn drop(&mut self) {
        if !self.done {
            self.coalescer.finish(
                &self.key,
                &Err("coalescing leader aborted before completing".to_string()),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn key(b: u8) -> Key {
        [b; 32]
    }

    fn answer() -> Answer {
        Answer {
            class: 3,
            variant: "w2".to_string(),
            logits: vec![0.0, 1.0],
        }
    }

    #[test]
    fn leader_broadcasts_to_followers() {
        let c = Coalescer::new();
        let leader = match c.join(key(1)) {
            Join::Leader(g) => g,
            Join::Follower(_) => panic!("first join must lead"),
        };
        let rx1 = match c.join(key(1)) {
            Join::Follower(rx) => rx,
            Join::Leader(_) => panic!("duplicate must follow"),
        };
        let rx2 = match c.join(key(1)) {
            Join::Follower(rx) => rx,
            Join::Leader(_) => panic!("duplicate must follow"),
        };
        leader.complete(&Ok(answer()));
        assert_eq!(rx1.recv().unwrap().unwrap().class, 3);
        assert_eq!(rx2.recv().unwrap().unwrap().class, 3);
        assert_eq!(c.leaders(), 1);
        assert_eq!(c.joined(), 2);
        // Key released: next join leads again.
        assert!(matches!(c.join(key(1)), Join::Leader(_)));
    }

    #[test]
    fn distinct_keys_do_not_coalesce() {
        let c = Coalescer::new();
        assert!(matches!(c.join(key(1)), Join::Leader(_)));
        assert!(matches!(c.join(key(2)), Join::Leader(_)));
    }

    #[test]
    fn dropped_leader_errors_followers_instead_of_hanging() {
        let c = Coalescer::new();
        let leader = match c.join(key(9)) {
            Join::Leader(g) => g,
            Join::Follower(_) => panic!(),
        };
        let rx = match c.join(key(9)) {
            Join::Follower(rx) => rx,
            Join::Leader(_) => panic!(),
        };
        drop(leader); // simulates a panicking handler
        let outcome = rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert!(outcome.unwrap_err().contains("aborted"));
    }

    #[test]
    fn gone_follower_does_not_block_the_broadcast() {
        let c = Coalescer::new();
        let leader = match c.join(key(4)) {
            Join::Leader(g) => g,
            Join::Follower(_) => panic!(),
        };
        match c.join(key(4)) {
            Join::Follower(rx) => drop(rx), // follower gave up
            Join::Leader(_) => panic!(),
        }
        leader.complete(&Ok(answer())); // must not block or panic
    }
}
