//! Route dispatch and the classify pipeline.
//!
//! The classify path is the edge's back-pressure spine, in order:
//! parse → per-client rate limit (429) → global admission gate (503) →
//! route resolution → content-addressed cache → coalescer → the gateway's
//! own bounded queue + deadline shedding + retry/hedge machinery. Every
//! refusal carries `Retry-After` and a counter; nothing is silently
//! queued and nothing is silently dropped.

use super::coalescing::Join;
use super::http::{HttpRequest, HttpResponse};
use super::{cache, metrics, Answer, EdgeState, ObsRuntime};
use crate::obs::tsdb::{breaker_name, health_name};
use crate::obs::{chrome_export, TraceHandle};
use crate::serving::{BackendHealth, Forced, InferRequest, RouteError, VariantSelector};
use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Fallback bound on a coalescing follower's wait when the request
/// carries no deadline; generous because the leader's own inference is
/// already bounded by the gateway's machinery.
const FOLLOWER_WAIT_DEFAULT: Duration = Duration::from_secs(60);
/// Extra margin a follower waits past the request deadline (the leader
/// may have started slightly earlier with a slightly different budget).
const FOLLOWER_WAIT_MARGIN: Duration = Duration::from_secs(5);

/// Dispatch one parsed request.
pub fn handle(state: &EdgeState, req: &HttpRequest, peer: &str) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/classify") => classify(state, req, peer),
        ("GET", "/healthz") => healthz(state),
        ("GET", "/metrics") => HttpResponse::new(
            200,
            "text/plain; version=0.0.4; charset=utf-8",
            metrics::prometheus(state).into_bytes(),
        ),
        ("GET", "/v1/trace") => trace_index(state),
        ("GET", "/v1/trace/export") => trace_export(state),
        ("GET", p) if p.starts_with("/v1/trace/") => trace_get(state, &p["/v1/trace/".len()..]),
        ("GET", "/v1/alerts") => alerts(state),
        ("GET", "/v1/events") => events(state),
        ("GET", p) if p == "/v1/stats" || p.starts_with("/v1/stats?") => stats(state, p),
        ("POST", "/v1/fault") => fault_override(state, req),
        ("GET", "/v1/classify") | ("GET", "/v1/fault") | ("POST", "/healthz")
        | ("POST", "/metrics") | ("POST", "/v1/trace") | ("POST", "/v1/alerts")
        | ("POST", "/v1/events") | ("POST", "/v1/stats") => {
            HttpResponse::text(405, "method not allowed\n")
        }
        (m, p) => HttpResponse::text(404, format!("no route for {m} {p}\n")),
    }
}

fn health_str(h: BackendHealth) -> &'static str {
    match h {
        BackendHealth::Healthy => "healthy",
        BackendHealth::Degraded => "degraded",
        BackendHealth::Unavailable => "unavailable",
    }
}

/// 200 while any variant can serve; 503 once every backend is gone.
fn healthz(state: &EdgeState) -> HttpResponse {
    let statuses = state.server.statuses();
    let serving = statuses
        .iter()
        .any(|s| s.health != BackendHealth::Unavailable);
    let body = Json::obj(vec![
        (
            "status",
            Json::str(if serving { "ok" } else { "unavailable" }),
        ),
        ("draining", Json::Bool(state.draining())),
        (
            "variants",
            Json::Arr(
                statuses
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("name", Json::str(s.name.to_string())),
                            ("health", Json::str(health_str(s.health))),
                            ("ewma_latency_us", Json::num(s.ewma_latency_us)),
                            ("inflight", Json::num(s.inflight as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    HttpResponse::json(if serving { 200 } else { 503 }, &body)
}

struct ClassifyBody {
    image: Vec<f32>,
    selector: VariantSelector,
    /// The selector as the client wrote it (`"default"` when omitted) —
    /// the negative cache's key alongside the image length.
    route_raw: String,
    deadline: Option<Duration>,
    client: Option<String>,
}

impl ClassifyBody {
    /// Pinned selectors never re-route, so a shape mismatch against them
    /// is deterministic and safe to negative-cache. Policy selectors may
    /// resolve differently under load and must be re-derived every time.
    fn pinned(&self) -> bool {
        matches!(
            self.selector,
            VariantSelector::Exact(_) | VariantSelector::Named(_)
        )
    }
}

fn parse_body(raw: &[u8]) -> std::result::Result<ClassifyBody, String> {
    let text = std::str::from_utf8(raw).map_err(|_| "body is not UTF-8".to_string())?;
    let j = crate::util::json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let image = j
        .get("image")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| "missing \"image\" (array of numbers)".to_string())?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| "\"image\" must contain only numbers".to_string())?;
    if image.is_empty() {
        return Err("\"image\" must not be empty".to_string());
    }
    let (selector, route_raw) = match j.get("route").and_then(|v| v.as_str()) {
        Some(s) => (
            VariantSelector::parse(s).map_err(|e| format!("bad \"route\": {e}"))?,
            s.to_string(),
        ),
        None => (VariantSelector::Default, "default".to_string()),
    };
    let deadline = j
        .get("deadline_ms")
        .and_then(|v| v.as_f64())
        .filter(|d| d.is_finite() && *d > 0.0)
        .map(|d| Duration::from_secs_f64(d / 1e3));
    let client = j
        .get("client")
        .and_then(|v| v.as_str())
        .map(str::to_string);
    Ok(ClassifyBody {
        image,
        selector,
        route_raw,
        deadline,
        client,
    })
}

/// Map a gateway error string onto an HTTP status. The gateway's error
/// surface is strings (its own public contract), so this is a keyword
/// map; anything unrecognized is a 502 from the backend.
fn error_response(e: &str) -> HttpResponse {
    let lower = e.to_ascii_lowercase();
    if lower.contains("timeout") {
        HttpResponse::text(504, format!("{e}\n"))
    } else if lower.contains("bad input") || lower.contains("image length") {
        HttpResponse::text(400, format!("{e}\n"))
    } else if lower.contains("shed")
        || lower.contains("deadline")
        || lower.contains("backpressure")
        || lower.contains("queue full")
        || lower.contains("restarting")
        || lower.contains("breaker")
    {
        HttpResponse::text(503, format!("{e}\n")).retry_after_secs(1)
    } else {
        HttpResponse::text(502, format!("backend error: {e}\n"))
    }
}

fn trace_unavailable() -> HttpResponse {
    HttpResponse::text(404, "tracing is off (start the edge with --trace)\n")
}

/// `GET /v1/trace`: recent trace ids with headline latency.
fn trace_index(state: &EdgeState) -> HttpResponse {
    match &state.recorder {
        Some(r) => HttpResponse::json(200, &r.index_json()),
        None => trace_unavailable(),
    }
}

/// `GET /v1/trace/export`: every retained trace as one Chrome trace-event
/// JSON document (load in `chrome://tracing` or Perfetto).
fn trace_export(state: &EdgeState) -> HttpResponse {
    match &state.recorder {
        Some(r) => HttpResponse::json(200, &chrome_export(&r.recent())),
        None => trace_unavailable(),
    }
}

/// `GET /v1/trace/<id>`: one trace's spans. Fetching a pinned slow
/// exemplar unpins it.
fn trace_get(state: &EdgeState, id: &str) -> HttpResponse {
    let Some(r) = &state.recorder else {
        return trace_unavailable();
    };
    let Ok(id) = id.parse::<u64>() else {
        return HttpResponse::text(400, "trace id must be an integer\n");
    };
    match r.get(id) {
        Some(t) => HttpResponse::json(200, &t.to_json()),
        None => HttpResponse::text(404, format!("no trace {id} (ring may have lapped it)\n")),
    }
}

fn slo_unavailable() -> HttpResponse {
    HttpResponse::text(404, "the SLO layer is off (start the edge with --slo)\n")
}

/// `GET /v1/alerts`: every alert's state machine + burn rates, plus the
/// currently-firing set.
fn alerts(state: &EdgeState) -> HttpResponse {
    match &state.obs {
        Some(obs) => HttpResponse::json(200, &obs.engine.alerts_json()),
        None => slo_unavailable(),
    }
}

/// `GET /v1/events`: the structured event journal as JSONL, oldest first —
/// alert transitions, worker restarts, breaker flips, health changes,
/// fault overrides. Every line carries `ts_us`, `seq`, and `kind`.
fn events(state: &EdgeState) -> HttpResponse {
    match &state.obs {
        Some(obs) => HttpResponse::new(
            200,
            "application/x-ndjson; charset=utf-8",
            obs.journal.jsonl().into_bytes(),
        ),
        None => slo_unavailable(),
    }
}

/// Parse the `window=` query parameter: `1500ms`, `30s`, `5m`, `1h`, or
/// bare seconds. Defaults to 30 s when absent.
fn parse_window_us(path: &str) -> std::result::Result<u64, String> {
    const DEFAULT_US: u64 = 30_000_000;
    let Some(query) = path.splitn(2, '?').nth(1) else {
        return Ok(DEFAULT_US);
    };
    for pair in query.split('&') {
        let Some((k, v)) = pair.split_once('=') else {
            continue;
        };
        if k != "window" {
            continue;
        }
        let (digits, scale) = if let Some(d) = v.strip_suffix("ms") {
            (d, 1_000u64)
        } else if let Some(d) = v.strip_suffix('s') {
            (d, 1_000_000)
        } else if let Some(d) = v.strip_suffix('m') {
            (d, 60_000_000)
        } else if let Some(d) = v.strip_suffix('h') {
            (d, 3_600_000_000)
        } else {
            (v, 1_000_000)
        };
        return match digits.parse::<u64>() {
            Ok(n) if n > 0 => Ok(n.saturating_mul(scale)),
            _ => Err(format!("bad window {v:?} (use e.g. 30s, 5m, 1500ms)")),
        };
    }
    Ok(DEFAULT_US)
}

/// `GET /v1/stats?window=30s`: per-variant rates and quantiles over the
/// requested lookback, derived from the time-series ring — the payload
/// `mpcnn top` renders.
fn stats(state: &EdgeState, path: &str) -> HttpResponse {
    let Some(obs) = &state.obs else {
        return slo_unavailable();
    };
    match parse_window_us(path) {
        Ok(lookback_us) => HttpResponse::json(200, &stats_json(obs, lookback_us)),
        Err(e) => HttpResponse::text(400, format!("{e}\n")),
    }
}

fn stats_json(obs: &ObsRuntime, lookback_us: u64) -> Json {
    let firing = obs.engine.firing();
    let mut pairs = vec![
        ("requested_window_us", Json::num(lookback_us as f64)),
        ("retained_span_us", Json::num(obs.tsdb.span_us() as f64)),
        ("samples", Json::num(obs.tsdb.len() as f64)),
        (
            "firing",
            Json::Arr(firing.into_iter().map(Json::str).collect()),
        ),
    ];
    let Some(w) = obs.tsdb.window(lookback_us) else {
        // Fewer than two samples retained: nothing to delta yet.
        pairs.push(("ready", Json::Bool(false)));
        return Json::obj(pairs);
    };
    let secs = (w.span_us as f64 / 1e6).max(1e-9);
    pairs.push(("ready", Json::Bool(true)));
    pairs.push(("window_us", Json::num(w.span_us as f64)));
    pairs.push(("at_us", Json::num(w.at_us as f64)));
    pairs.push((
        "edge",
        Json::obj(vec![
            ("requests", Json::num(w.edge.requests as f64)),
            ("rps", Json::num(w.edge.requests as f64 / secs)),
            ("ok", Json::num(w.edge.ok as f64)),
            ("client_errors", Json::num(w.edge.client_errors as f64)),
            ("server_errors", Json::num(w.edge.server_errors as f64)),
            ("rate_limited", Json::num(w.edge.rate_limited as f64)),
            ("admission_shed", Json::num(w.edge.admission_shed as f64)),
            ("cache_hits", Json::num(w.edge.cache_hits as f64)),
            ("negative_hits", Json::num(w.edge.negative_hits as f64)),
            ("agreement_checks", Json::num(w.edge.agreement_checks as f64)),
            (
                "agreement_failures",
                Json::num(w.edge.agreement_failures as f64),
            ),
        ]),
    ));
    pairs.push((
        "gateway",
        Json::obj(vec![
            ("shed", Json::num(w.gateway.shed as f64)),
            ("panics", Json::num(w.gateway.panics as f64)),
            (
                "worker_restarts",
                Json::num(w.gateway.worker_restarts as f64),
            ),
            ("retried", Json::num(w.gateway.retried as f64)),
            ("hedged", Json::num(w.gateway.hedged as f64)),
            ("fallbacks", Json::num(w.gateway.fallbacks as f64)),
        ]),
    ));
    pairs.push((
        "variants",
        Json::Arr(
            w.variants
                .iter()
                .map(|v| {
                    Json::obj(vec![
                        ("name", Json::str(v.name.clone())),
                        ("rps", Json::num(v.rps)),
                        ("responses", Json::num(v.responses as f64)),
                        ("errors", Json::num(v.errors as f64)),
                        (
                            "shed",
                            Json::num((v.shed_admission + v.shed_expired) as f64),
                        ),
                        ("worker_restarts", Json::num(v.worker_restarts as f64)),
                        ("p50_us", Json::num(v.latency.percentile_us(50.0))),
                        ("p99_us", Json::num(v.latency.percentile_us(99.0))),
                        ("queue_p50_us", Json::num(v.queue_wait.percentile_us(50.0))),
                        ("queue_p99_us", Json::num(v.queue_wait.percentile_us(99.0))),
                        ("ewma_us", Json::num(v.ewma_us)),
                        ("fpga_fps", Json::num(v.fpga_fps)),
                        ("health", Json::str(health_name(v.health))),
                        ("breaker", Json::str(breaker_name(v.breaker))),
                    ])
                })
                .collect(),
        ),
    ));
    Json::obj(pairs)
}

/// `POST /v1/fault` with `{"force":"none"|"error"|"panic"|"corrupt"}`:
/// flip the live fault-injection override. Exists so the CI smoke test
/// (and an operator) can lift a seeded fault and watch the alerts resolve
/// *without a restart*. 404 unless the edge was started with `--fault`.
fn fault_override(state: &EdgeState, req: &HttpRequest) -> HttpResponse {
    let Some(controls) = state.fault_controls() else {
        return HttpResponse::text(404, "no fault injection active (start with --fault)\n");
    };
    let force = std::str::from_utf8(&req.body)
        .ok()
        .and_then(|t| crate::util::json::parse(t).ok())
        .and_then(|j| j.get("force").and_then(|v| v.as_str()).map(str::to_string));
    let forced = match force.as_deref() {
        Some("none") => Forced::None,
        Some("error") => Forced::Error,
        Some("panic") => Forced::Panic,
        Some("corrupt") => Forced::Corrupt,
        _ => {
            return HttpResponse::text(
                400,
                "body must be {\"force\":\"none|error|panic|corrupt\"}\n",
            )
        }
    };
    let name = force.unwrap_or_default();
    controls.force(forced);
    if let Some(obs) = &state.obs {
        obs.journal.record(
            super::now_unix_us(),
            "fault_override",
            vec![("force", Json::str(name.clone()))],
        );
    }
    HttpResponse::json(
        200,
        &Json::obj(vec![
            ("force", Json::str(name)),
            ("injected_total", Json::num(controls.injected_total() as f64)),
        ]),
    )
}

fn answer_response(a: &Answer, cached: bool, coalesced: bool) -> HttpResponse {
    let body = Json::obj(vec![
        ("class", Json::num(a.class as f64)),
        ("variant", Json::str(a.variant.clone())),
        ("cached", Json::Bool(cached)),
        ("coalesced", Json::Bool(coalesced)),
        (
            "logits",
            Json::Arr(a.logits.iter().map(|&l| Json::num(l as f64)).collect()),
        ),
    ]);
    HttpResponse::json(200, &body)
}

/// Classify entry point: allocates a trace when the flight recorder is on,
/// runs the pipeline, then seals and records the trace on *every* exit
/// path (refusals included) and stamps the response with `X-Trace-Id`.
fn classify(state: &EdgeState, req: &HttpRequest, peer: &str) -> HttpResponse {
    let trace = if state.recorder.is_some() {
        TraceHandle::start()
    } else {
        TraceHandle::off()
    };
    let resp = classify_traced(state, req, peer, &trace);
    match (&state.recorder, trace.id()) {
        (Some(rec), Some(id)) => {
            if let Some(done) = trace.finish(Instant::now()) {
                rec.record(done);
            }
            resp.with_header("X-Trace-Id", id.to_string())
        }
        _ => resp,
    }
}

fn classify_traced(
    state: &EdgeState,
    req: &HttpRequest,
    peer: &str,
    trace: &TraceHandle,
) -> HttpResponse {
    state.metrics.note_classify();
    let t_parse = Instant::now();
    let body = match parse_body(&req.body) {
        Ok(b) => b,
        Err(e) => {
            state.metrics.note_bad_request();
            return HttpResponse::text(400, format!("{e}\n"));
        }
    };
    trace.add_span(
        "edge.parse",
        t_parse,
        Instant::now(),
        vec![("bytes", req.body.len().to_string())],
    );
    if state.draining() {
        return HttpResponse::text(503, "draining\n").retry_after_secs(1);
    }

    // Client identity for the token bucket: JSON `client` field, else the
    // X-Client-Id header, else the peer IP.
    let client = body
        .client
        .clone()
        .or_else(|| req.header("x-client-id").map(str::to_string))
        .unwrap_or_else(|| peer.to_string());
    let t_adm = Instant::now();
    if let Err(retry_after) = state.limiter.acquire(&client) {
        state.metrics.note_rate_limited();
        trace.add_span(
            "admission",
            t_adm,
            Instant::now(),
            vec![("outcome", "rate_limited".to_string())],
        );
        let secs = retry_after.as_secs_f64().ceil().max(1.0) as u64;
        return HttpResponse::text(429, "rate limited\n").retry_after_secs(secs);
    }

    // Global admission ahead of the variant queues; RAII permit spans the
    // whole inference (coalesced waits included).
    let Some(_permit) = state.gate.try_enter() else {
        state.metrics.note_admission_shed();
        trace.add_span(
            "admission",
            t_adm,
            Instant::now(),
            vec![("outcome", "shed".to_string())],
        );
        return HttpResponse::text(503, "server at capacity\n").retry_after_secs(1);
    };
    trace.add_span(
        "admission",
        t_adm,
        Instant::now(),
        vec![("outcome", "admitted".to_string())],
    );

    // Deterministic-refusal fast path: a remembered unknown-variant or
    // pinned shape-mismatch 4xx answers here, before route resolution and
    // the gateway ever see the repeat.
    let neg_key = cache::negative_key(&body.route_raw, body.image.len());
    if let Some(neg) = state.negative.get(&neg_key) {
        trace.add_event("negative.hit", Instant::now(), vec![]);
        return HttpResponse::text(neg.status, neg.message);
    }

    // Resolve the route once so the cache/coalescing key names the
    // concrete variant this request would land on.
    let t_route = Instant::now();
    let variant = match state.server.route(&body.selector) {
        Ok(v) => v,
        Err(RouteError::NoSuchVariant(what)) => {
            // Unknown variants are deterministic for *any* selector form:
            // the registry is fixed at boot.
            let msg = format!("no such variant: {what}\n");
            state.negative.insert(neg_key, 404, msg.clone());
            return HttpResponse::text(404, msg);
        }
        Err(e) => return HttpResponse::text(503, format!("unroutable: {e}\n")).retry_after_secs(1),
    };
    trace.add_span(
        "route.decide",
        t_route,
        Instant::now(),
        vec![("variant", variant.clone())],
    );
    let t_cache = Instant::now();
    let key = cache::cache_key(&variant, &body.image);
    let hit = state.cache.get(&key);
    trace.add_span(
        "cache.lookup",
        t_cache,
        Instant::now(),
        vec![("hit", hit.is_some().to_string())],
    );
    if let Some(hit) = hit {
        let t_resp = Instant::now();
        let resp = answer_response(&hit, true, false);
        trace.add_span("respond", t_resp, Instant::now(), vec![]);
        return resp;
    }

    match state.coalescer.join(key) {
        Join::Follower(rx) => {
            let wait = body
                .deadline
                .map(|d| d + FOLLOWER_WAIT_MARGIN)
                .unwrap_or(FOLLOWER_WAIT_DEFAULT);
            let t_wait = Instant::now();
            let out = rx.recv_timeout(wait);
            trace.add_span(
                "coalesce.follower",
                t_wait,
                Instant::now(),
                vec![("ok", matches!(out, Ok(Ok(_))).to_string())],
            );
            let t_resp = Instant::now();
            let resp = match out {
                Ok(Ok(a)) => answer_response(&a, false, true),
                Ok(Err(e)) => error_response(&e),
                Err(_) => HttpResponse::text(504, "coalesced wait timed out\n"),
            };
            trace.add_span("respond", t_resp, Instant::now(), vec![]);
            resp
        }
        Join::Leader(guard) => {
            trace.add_event("coalesce.leader", Instant::now(), vec![]);
            let mut infer = InferRequest::new(body.image.clone())
                .with_variant(body.selector)
                .with_trace(trace.clone());
            if let Some(d) = body.deadline {
                infer = infer.with_deadline(d);
            }
            // The client-observed gateway time; the worker's own
            // queue.wait / batch.assemble / infer spans nest inside it.
            let t_infer = Instant::now();
            let outcome = state.server.infer(infer).map(|resp| Answer {
                class: resp.class,
                variant: resp.variant,
                logits: resp.logits,
            });
            trace.add_span(
                "infer.wait",
                t_infer,
                Instant::now(),
                vec![("ok", outcome.is_ok().to_string())],
            );
            if let Ok(a) = &outcome {
                // Cache only reference-agreeing successes; a corrupt
                // response must never become a sticky wrong answer. Keyed
                // under the variant that actually answered (retries may
                // have re-routed past the resolved one). Every comparison
                // also feeds the agreement-rate SLI the accuracy-drift
                // watchdog consumes.
                let cacheable = match &state.check {
                    Some(c) => {
                        let agreed = c(&body.image, a);
                        state.metrics.note_agreement(agreed);
                        agreed
                    }
                    None => true,
                };
                if cacheable {
                    state
                        .cache
                        .insert(cache::cache_key(&a.variant, &body.image), a.clone());
                } else {
                    state.cache.note_uncacheable();
                }
            }
            guard.complete(&outcome);
            let t_resp = Instant::now();
            let resp = match outcome {
                Ok(a) => answer_response(&a, false, false),
                Err(e) => {
                    let resp = error_response(&e);
                    // A 400 against a pinned selector is a deterministic
                    // shape mismatch — remember it so the retry loop stops
                    // reaching the gateway.
                    if resp.status == 400 && body.pinned() {
                        state.negative.insert(
                            neg_key,
                            400,
                            String::from_utf8_lossy(&resp.body).into_owned(),
                        );
                    }
                    resp
                }
            };
            trace.add_span("respond", t_resp, Instant::now(), vec![]);
            resp
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_body_accepts_full_request() {
        let raw = br#"{"image":[1.0,2.5,3.0],"route":"exact:2","deadline_ms":50,"client":"c1"}"#;
        let b = parse_body(raw).unwrap();
        assert_eq!(b.image, vec![1.0, 2.5, 3.0]);
        assert!(matches!(b.selector, VariantSelector::Exact(2)));
        assert_eq!(b.deadline, Some(Duration::from_millis(50)));
        assert_eq!(b.client.as_deref(), Some("c1"));
    }

    #[test]
    fn parse_body_defaults_and_rejects() {
        let b = parse_body(br#"{"image":[0.5]}"#).unwrap();
        assert!(matches!(b.selector, VariantSelector::Default));
        assert!(b.deadline.is_none());
        assert!(parse_body(b"not json").is_err());
        assert!(parse_body(br#"{"image":[]}"#).is_err());
        assert!(parse_body(br#"{"image":["x"]}"#).is_err());
        assert!(parse_body(br#"{"route":"exact:2"}"#).is_err());
        assert!(parse_body(br#"{"image":[1],"route":"exact:nope"}"#).is_err());
    }

    #[test]
    fn window_parsing_units_and_default() {
        assert_eq!(parse_window_us("/v1/stats").unwrap(), 30_000_000);
        assert_eq!(parse_window_us("/v1/stats?window=30s").unwrap(), 30_000_000);
        assert_eq!(parse_window_us("/v1/stats?window=5m").unwrap(), 300_000_000);
        assert_eq!(parse_window_us("/v1/stats?window=1500ms").unwrap(), 1_500_000);
        assert_eq!(
            parse_window_us("/v1/stats?window=1h").unwrap(),
            3_600_000_000
        );
        assert_eq!(parse_window_us("/v1/stats?window=45").unwrap(), 45_000_000);
        assert_eq!(parse_window_us("/v1/stats?other=1&window=2s").unwrap(), 2_000_000);
        assert!(parse_window_us("/v1/stats?window=0s").is_err());
        assert!(parse_window_us("/v1/stats?window=soon").is_err());
    }

    #[test]
    fn pinned_selectors_only() {
        let pinned = parse_body(br#"{"image":[1],"route":"exact:2"}"#).unwrap();
        assert!(pinned.pinned());
        assert_eq!(pinned.route_raw, "exact:2");
        let policy = parse_body(br#"{"image":[1],"route":"min_accuracy:90"}"#);
        if let Ok(policy) = policy {
            assert!(!policy.pinned());
        }
        let default = parse_body(br#"{"image":[1]}"#).unwrap();
        assert!(!default.pinned());
        assert_eq!(default.route_raw, "default");
    }

    #[test]
    fn error_mapping_statuses() {
        assert_eq!(error_response("timeout").status, 504);
        assert_eq!(error_response("bad input: image length 5").status, 400);
        assert_eq!(error_response("shed: deadline expired in queue").status, 503);
        assert_eq!(error_response("queue full").status, 503);
        assert_eq!(error_response("mock backend exploded").status, 502);
    }

    #[test]
    fn answer_logits_round_trip_bit_identically() {
        let a = Answer {
            class: 2,
            variant: "w2".to_string(),
            logits: vec![0.1f32, -3.25, 1e-7, 42.0],
        };
        let resp = answer_response(&a, false, false);
        let j = crate::util::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let back: Vec<f32> = j
            .get("logits")
            .and_then(|v| v.as_arr())
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(back, a.logits, "f32 -> JSON -> f32 must be lossless");
        assert_eq!(j.get("class").and_then(|v| v.as_u64()), Some(2));
    }
}
