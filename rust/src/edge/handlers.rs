//! Route dispatch and the classify pipeline.
//!
//! The classify path is the edge's back-pressure spine, in order:
//! parse → per-client rate limit (429) → global admission gate (503) →
//! route resolution → content-addressed cache → coalescer → the gateway's
//! own bounded queue + deadline shedding + retry/hedge machinery. Every
//! refusal carries `Retry-After` and a counter; nothing is silently
//! queued and nothing is silently dropped.

use super::coalescing::Join;
use super::http::{HttpRequest, HttpResponse};
use super::{cache, metrics, Answer, EdgeState};
use crate::obs::{chrome_export, TraceHandle};
use crate::serving::{BackendHealth, InferRequest, RouteError, VariantSelector};
use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Fallback bound on a coalescing follower's wait when the request
/// carries no deadline; generous because the leader's own inference is
/// already bounded by the gateway's machinery.
const FOLLOWER_WAIT_DEFAULT: Duration = Duration::from_secs(60);
/// Extra margin a follower waits past the request deadline (the leader
/// may have started slightly earlier with a slightly different budget).
const FOLLOWER_WAIT_MARGIN: Duration = Duration::from_secs(5);

/// Dispatch one parsed request.
pub fn handle(state: &EdgeState, req: &HttpRequest, peer: &str) -> HttpResponse {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/classify") => classify(state, req, peer),
        ("GET", "/healthz") => healthz(state),
        ("GET", "/metrics") => HttpResponse::new(
            200,
            "text/plain; version=0.0.4; charset=utf-8",
            metrics::prometheus(state).into_bytes(),
        ),
        ("GET", "/v1/trace") => trace_index(state),
        ("GET", "/v1/trace/export") => trace_export(state),
        ("GET", p) if p.starts_with("/v1/trace/") => trace_get(state, &p["/v1/trace/".len()..]),
        ("GET", "/v1/classify") | ("POST", "/healthz") | ("POST", "/metrics")
        | ("POST", "/v1/trace") => HttpResponse::text(405, "method not allowed\n"),
        (m, p) => HttpResponse::text(404, format!("no route for {m} {p}\n")),
    }
}

fn health_str(h: BackendHealth) -> &'static str {
    match h {
        BackendHealth::Healthy => "healthy",
        BackendHealth::Degraded => "degraded",
        BackendHealth::Unavailable => "unavailable",
    }
}

/// 200 while any variant can serve; 503 once every backend is gone.
fn healthz(state: &EdgeState) -> HttpResponse {
    let statuses = state.server.statuses();
    let serving = statuses
        .iter()
        .any(|s| s.health != BackendHealth::Unavailable);
    let body = Json::obj(vec![
        (
            "status",
            Json::str(if serving { "ok" } else { "unavailable" }),
        ),
        ("draining", Json::Bool(state.draining())),
        (
            "variants",
            Json::Arr(
                statuses
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("name", Json::str(s.name.to_string())),
                            ("health", Json::str(health_str(s.health))),
                            ("ewma_latency_us", Json::num(s.ewma_latency_us)),
                            ("inflight", Json::num(s.inflight as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    HttpResponse::json(if serving { 200 } else { 503 }, &body)
}

struct ClassifyBody {
    image: Vec<f32>,
    selector: VariantSelector,
    deadline: Option<Duration>,
    client: Option<String>,
}

fn parse_body(raw: &[u8]) -> std::result::Result<ClassifyBody, String> {
    let text = std::str::from_utf8(raw).map_err(|_| "body is not UTF-8".to_string())?;
    let j = crate::util::json::parse(text).map_err(|e| format!("bad JSON: {e}"))?;
    let image = j
        .get("image")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| "missing \"image\" (array of numbers)".to_string())?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Option<Vec<f32>>>()
        .ok_or_else(|| "\"image\" must contain only numbers".to_string())?;
    if image.is_empty() {
        return Err("\"image\" must not be empty".to_string());
    }
    let selector = match j.get("route").and_then(|v| v.as_str()) {
        Some(s) => VariantSelector::parse(s).map_err(|e| format!("bad \"route\": {e}"))?,
        None => VariantSelector::Default,
    };
    let deadline = j
        .get("deadline_ms")
        .and_then(|v| v.as_f64())
        .filter(|d| d.is_finite() && *d > 0.0)
        .map(|d| Duration::from_secs_f64(d / 1e3));
    let client = j
        .get("client")
        .and_then(|v| v.as_str())
        .map(str::to_string);
    Ok(ClassifyBody {
        image,
        selector,
        deadline,
        client,
    })
}

/// Map a gateway error string onto an HTTP status. The gateway's error
/// surface is strings (its own public contract), so this is a keyword
/// map; anything unrecognized is a 502 from the backend.
fn error_response(e: &str) -> HttpResponse {
    let lower = e.to_ascii_lowercase();
    if lower.contains("timeout") {
        HttpResponse::text(504, format!("{e}\n"))
    } else if lower.contains("bad input") || lower.contains("image length") {
        HttpResponse::text(400, format!("{e}\n"))
    } else if lower.contains("shed")
        || lower.contains("deadline")
        || lower.contains("backpressure")
        || lower.contains("queue full")
        || lower.contains("restarting")
        || lower.contains("breaker")
    {
        HttpResponse::text(503, format!("{e}\n")).retry_after_secs(1)
    } else {
        HttpResponse::text(502, format!("backend error: {e}\n"))
    }
}

fn trace_unavailable() -> HttpResponse {
    HttpResponse::text(404, "tracing is off (start the edge with --trace)\n")
}

/// `GET /v1/trace`: recent trace ids with headline latency.
fn trace_index(state: &EdgeState) -> HttpResponse {
    match &state.recorder {
        Some(r) => HttpResponse::json(200, &r.index_json()),
        None => trace_unavailable(),
    }
}

/// `GET /v1/trace/export`: every retained trace as one Chrome trace-event
/// JSON document (load in `chrome://tracing` or Perfetto).
fn trace_export(state: &EdgeState) -> HttpResponse {
    match &state.recorder {
        Some(r) => HttpResponse::json(200, &chrome_export(&r.recent())),
        None => trace_unavailable(),
    }
}

/// `GET /v1/trace/<id>`: one trace's spans. Fetching a pinned slow
/// exemplar unpins it.
fn trace_get(state: &EdgeState, id: &str) -> HttpResponse {
    let Some(r) = &state.recorder else {
        return trace_unavailable();
    };
    let Ok(id) = id.parse::<u64>() else {
        return HttpResponse::text(400, "trace id must be an integer\n");
    };
    match r.get(id) {
        Some(t) => HttpResponse::json(200, &t.to_json()),
        None => HttpResponse::text(404, format!("no trace {id} (ring may have lapped it)\n")),
    }
}

fn answer_response(a: &Answer, cached: bool, coalesced: bool) -> HttpResponse {
    let body = Json::obj(vec![
        ("class", Json::num(a.class as f64)),
        ("variant", Json::str(a.variant.clone())),
        ("cached", Json::Bool(cached)),
        ("coalesced", Json::Bool(coalesced)),
        (
            "logits",
            Json::Arr(a.logits.iter().map(|&l| Json::num(l as f64)).collect()),
        ),
    ]);
    HttpResponse::json(200, &body)
}

/// Classify entry point: allocates a trace when the flight recorder is on,
/// runs the pipeline, then seals and records the trace on *every* exit
/// path (refusals included) and stamps the response with `X-Trace-Id`.
fn classify(state: &EdgeState, req: &HttpRequest, peer: &str) -> HttpResponse {
    let trace = if state.recorder.is_some() {
        TraceHandle::start()
    } else {
        TraceHandle::off()
    };
    let resp = classify_traced(state, req, peer, &trace);
    match (&state.recorder, trace.id()) {
        (Some(rec), Some(id)) => {
            if let Some(done) = trace.finish(Instant::now()) {
                rec.record(done);
            }
            resp.with_header("X-Trace-Id", id.to_string())
        }
        _ => resp,
    }
}

fn classify_traced(
    state: &EdgeState,
    req: &HttpRequest,
    peer: &str,
    trace: &TraceHandle,
) -> HttpResponse {
    state.metrics.note_classify();
    let t_parse = Instant::now();
    let body = match parse_body(&req.body) {
        Ok(b) => b,
        Err(e) => {
            state.metrics.note_bad_request();
            return HttpResponse::text(400, format!("{e}\n"));
        }
    };
    trace.add_span(
        "edge.parse",
        t_parse,
        Instant::now(),
        vec![("bytes", req.body.len().to_string())],
    );
    if state.draining() {
        return HttpResponse::text(503, "draining\n").retry_after_secs(1);
    }

    // Client identity for the token bucket: JSON `client` field, else the
    // X-Client-Id header, else the peer IP.
    let client = body
        .client
        .clone()
        .or_else(|| req.header("x-client-id").map(str::to_string))
        .unwrap_or_else(|| peer.to_string());
    let t_adm = Instant::now();
    if let Err(retry_after) = state.limiter.acquire(&client) {
        state.metrics.note_rate_limited();
        trace.add_span(
            "admission",
            t_adm,
            Instant::now(),
            vec![("outcome", "rate_limited".to_string())],
        );
        let secs = retry_after.as_secs_f64().ceil().max(1.0) as u64;
        return HttpResponse::text(429, "rate limited\n").retry_after_secs(secs);
    }

    // Global admission ahead of the variant queues; RAII permit spans the
    // whole inference (coalesced waits included).
    let Some(_permit) = state.gate.try_enter() else {
        state.metrics.note_admission_shed();
        trace.add_span(
            "admission",
            t_adm,
            Instant::now(),
            vec![("outcome", "shed".to_string())],
        );
        return HttpResponse::text(503, "server at capacity\n").retry_after_secs(1);
    };
    trace.add_span(
        "admission",
        t_adm,
        Instant::now(),
        vec![("outcome", "admitted".to_string())],
    );

    // Resolve the route once so the cache/coalescing key names the
    // concrete variant this request would land on.
    let t_route = Instant::now();
    let variant = match state.server.route(&body.selector) {
        Ok(v) => v,
        Err(RouteError::NoSuchVariant(what)) => {
            return HttpResponse::text(404, format!("no such variant: {what}\n"));
        }
        Err(e) => return HttpResponse::text(503, format!("unroutable: {e}\n")).retry_after_secs(1),
    };
    trace.add_span(
        "route.decide",
        t_route,
        Instant::now(),
        vec![("variant", variant.clone())],
    );
    let t_cache = Instant::now();
    let key = cache::cache_key(&variant, &body.image);
    let hit = state.cache.get(&key);
    trace.add_span(
        "cache.lookup",
        t_cache,
        Instant::now(),
        vec![("hit", hit.is_some().to_string())],
    );
    if let Some(hit) = hit {
        let t_resp = Instant::now();
        let resp = answer_response(&hit, true, false);
        trace.add_span("respond", t_resp, Instant::now(), vec![]);
        return resp;
    }

    match state.coalescer.join(key) {
        Join::Follower(rx) => {
            let wait = body
                .deadline
                .map(|d| d + FOLLOWER_WAIT_MARGIN)
                .unwrap_or(FOLLOWER_WAIT_DEFAULT);
            let t_wait = Instant::now();
            let out = rx.recv_timeout(wait);
            trace.add_span(
                "coalesce.follower",
                t_wait,
                Instant::now(),
                vec![("ok", matches!(out, Ok(Ok(_))).to_string())],
            );
            let t_resp = Instant::now();
            let resp = match out {
                Ok(Ok(a)) => answer_response(&a, false, true),
                Ok(Err(e)) => error_response(&e),
                Err(_) => HttpResponse::text(504, "coalesced wait timed out\n"),
            };
            trace.add_span("respond", t_resp, Instant::now(), vec![]);
            resp
        }
        Join::Leader(guard) => {
            trace.add_event("coalesce.leader", Instant::now(), vec![]);
            let mut infer = InferRequest::new(body.image.clone())
                .with_variant(body.selector)
                .with_trace(trace.clone());
            if let Some(d) = body.deadline {
                infer = infer.with_deadline(d);
            }
            // The client-observed gateway time; the worker's own
            // queue.wait / batch.assemble / infer spans nest inside it.
            let t_infer = Instant::now();
            let outcome = state.server.infer(infer).map(|resp| Answer {
                class: resp.class,
                variant: resp.variant,
                logits: resp.logits,
            });
            trace.add_span(
                "infer.wait",
                t_infer,
                Instant::now(),
                vec![("ok", outcome.is_ok().to_string())],
            );
            if let Ok(a) = &outcome {
                // Cache only reference-agreeing successes; a corrupt
                // response must never become a sticky wrong answer. Keyed
                // under the variant that actually answered (retries may
                // have re-routed past the resolved one).
                let cacheable = state.check.as_ref().map_or(true, |c| c(&body.image, a));
                if cacheable {
                    state
                        .cache
                        .insert(cache::cache_key(&a.variant, &body.image), a.clone());
                } else {
                    state.cache.note_uncacheable();
                }
            }
            guard.complete(&outcome);
            let t_resp = Instant::now();
            let resp = match outcome {
                Ok(a) => answer_response(&a, false, false),
                Err(e) => error_response(&e),
            };
            trace.add_span("respond", t_resp, Instant::now(), vec![]);
            resp
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_body_accepts_full_request() {
        let raw = br#"{"image":[1.0,2.5,3.0],"route":"exact:2","deadline_ms":50,"client":"c1"}"#;
        let b = parse_body(raw).unwrap();
        assert_eq!(b.image, vec![1.0, 2.5, 3.0]);
        assert!(matches!(b.selector, VariantSelector::Exact(2)));
        assert_eq!(b.deadline, Some(Duration::from_millis(50)));
        assert_eq!(b.client.as_deref(), Some("c1"));
    }

    #[test]
    fn parse_body_defaults_and_rejects() {
        let b = parse_body(br#"{"image":[0.5]}"#).unwrap();
        assert!(matches!(b.selector, VariantSelector::Default));
        assert!(b.deadline.is_none());
        assert!(parse_body(b"not json").is_err());
        assert!(parse_body(br#"{"image":[]}"#).is_err());
        assert!(parse_body(br#"{"image":["x"]}"#).is_err());
        assert!(parse_body(br#"{"route":"exact:2"}"#).is_err());
        assert!(parse_body(br#"{"image":[1],"route":"exact:nope"}"#).is_err());
    }

    #[test]
    fn error_mapping_statuses() {
        assert_eq!(error_response("timeout").status, 504);
        assert_eq!(error_response("bad input: image length 5").status, 400);
        assert_eq!(error_response("shed: deadline expired in queue").status, 503);
        assert_eq!(error_response("queue full").status, 503);
        assert_eq!(error_response("mock backend exploded").status, 502);
    }

    #[test]
    fn answer_logits_round_trip_bit_identically() {
        let a = Answer {
            class: 2,
            variant: "w2".to_string(),
            logits: vec![0.1f32, -3.25, 1e-7, 42.0],
        };
        let resp = answer_response(&a, false, false);
        let j = crate::util::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let back: Vec<f32> = j
            .get("logits")
            .and_then(|v| v.as_arr())
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(back, a.logits, "f32 -> JSON -> f32 must be lossless");
        assert_eq!(j.get("class").and_then(|v| v.as_u64()), Some(2));
    }
}
