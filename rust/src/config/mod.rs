//! Hardware constraints (HWC) and run configuration.
//!
//! The paper's DSE is parameterized by the target FPGA's resources
//! (§III: "the available logic, memory, and bandwidth"). We model the paper's
//! device (Stratix V GXA7) plus a couple of alternates to demonstrate that the
//! methodology generalizes ("the presented DSE methodology can generically be
//! applied to any FPGA architecture").

mod parse;

pub use parse::{parse_kv, KvError};

/// Resources of a target FPGA.
#[derive(Clone, Debug, PartialEq)]
pub struct FpgaSpec {
    pub name: String,
    /// Total logic LUTs (ALUTs). Stratix V GXA7: 234,720 ALMs = 469,440 ALUTs.
    pub luts: u64,
    /// Number of block RAMs (M20K on Stratix V).
    pub brams: u64,
    /// Capacity of one BRAM block in bits (M20K = 20 kbit).
    pub bram_bits: u64,
    /// Number of DSP hardmacro blocks.
    pub dsps: u64,
    /// Off-chip (DDR3) bandwidth in bytes/second.
    pub ddr_bw_bytes_per_s: f64,
    /// Technology node in nm (affects nothing but reporting).
    pub node_nm: u32,
}

impl FpgaSpec {
    /// The paper's device: Intel/Altera Stratix V GXA7 (5SGXA7), 28 nm.
    ///
    /// 234,720 ALMs ≈ 469,440 ALUTs; 2,560 M20K blocks; 256 variable-precision
    /// DSP blocks ("it features 256 DSPs", §IV-A); DDR3-1600 x64 ≈ 12.8 GB/s.
    pub fn stratix_v_gxa7() -> FpgaSpec {
        FpgaSpec {
            name: "Stratix V GXA7".to_string(),
            luts: 469_440,
            brams: 2_560,
            bram_bits: 20 * 1024,
            dsps: 256,
            ddr_bw_bytes_per_s: 12.8e9,
            node_nm: 28,
        }
    }

    /// A smaller sibling, used in the ablation "what if the fabric shrinks".
    pub fn stratix_v_gxa5() -> FpgaSpec {
        FpgaSpec {
            name: "Stratix V GXA5".to_string(),
            luts: 345_200,
            brams: 2_304,
            bram_bits: 20 * 1024,
            dsps: 256,
            ddr_bw_bytes_per_s: 12.8e9,
            node_nm: 28,
        }
    }

    /// A Zynq-class edge device (ZCU102-ish), for the generality ablation.
    pub fn zcu102() -> FpgaSpec {
        FpgaSpec {
            name: "ZCU102 (XCZU9EG)".to_string(),
            luts: 274_080,
            brams: 1_824,
            bram_bits: 18 * 1024,
            dsps: 2_520,
            ddr_bw_bytes_per_s: 19.2e9,
            node_nm: 16,
        }
    }

    pub fn by_name(name: &str) -> Option<FpgaSpec> {
        match name.to_ascii_lowercase().replace(['-', '_', ' '], "").as_str() {
            "stratixvgxa7" | "stratixv" | "gxa7" => Some(Self::stratix_v_gxa7()),
            "stratixvgxa5" | "gxa5" => Some(Self::stratix_v_gxa5()),
            "zcu102" => Some(Self::zcu102()),
            _ => None,
        }
    }

    /// Total on-chip BRAM capacity in bits.
    pub fn bram_capacity_bits(&self) -> u64 {
        self.brams * self.bram_bits
    }
}

/// Fraction of device LUTs the DSE may allocate to the PE array + buffers.
/// The paper reports 71 % LUT utilization on its largest design (Table V);
/// Quartus routing practically caps usable logic well below 100 %.
pub const DEFAULT_LUT_BUDGET_FRACTION: f64 = 0.85;

/// Fraction of BRAM blocks available to the global buffers.
pub const DEFAULT_BRAM_BUDGET_FRACTION: f64 = 0.97;

/// A full DSE/simulation configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub fpga: FpgaSpec,
    /// Activation word-length in bits (the paper fixes N = 8).
    pub act_bits: u32,
    /// Candidate operand slices `k` explored by the PE DSE.
    pub slices: Vec<u32>,
    /// Inner-layer weight word-lengths to evaluate.
    pub weight_bits: Vec<u32>,
    pub lut_budget_fraction: f64,
    pub bram_budget_fraction: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            fpga: FpgaSpec::stratix_v_gxa7(),
            act_bits: 8,
            slices: vec![1, 2, 4],
            weight_bits: vec![1, 2, 4, 8],
            lut_budget_fraction: DEFAULT_LUT_BUDGET_FRACTION,
            bram_budget_fraction: DEFAULT_BRAM_BUDGET_FRACTION,
        }
    }
}

impl RunConfig {
    /// Load overrides from a `key = value` config file (see [`parse_kv`]).
    pub fn from_kv(text: &str) -> Result<RunConfig, KvError> {
        let kv = parse_kv(text)?;
        let mut cfg = RunConfig::default();
        if let Some(name) = kv.get("fpga") {
            cfg.fpga = FpgaSpec::by_name(name).ok_or_else(|| KvError {
                line: 0,
                message: format!("unknown fpga '{name}'"),
            })?;
        }
        if let Some(v) = kv.get("act_bits") {
            cfg.act_bits = v.parse().map_err(|_| KvError {
                line: 0,
                message: format!("bad act_bits '{v}'"),
            })?;
        }
        if let Some(v) = kv.get("slices") {
            cfg.slices = parse_u32_list(v);
        }
        if let Some(v) = kv.get("weight_bits") {
            cfg.weight_bits = parse_u32_list(v);
        }
        if let Some(v) = kv.get("lut_budget_fraction") {
            cfg.lut_budget_fraction = v.parse().unwrap_or(cfg.lut_budget_fraction);
        }
        if let Some(v) = kv.get("bram_budget_fraction") {
            cfg.bram_budget_fraction = v.parse().unwrap_or(cfg.bram_budget_fraction);
        }
        Ok(cfg)
    }

    /// LUTs available to the accelerator after the budget haircut.
    pub fn lut_budget(&self) -> u64 {
        (self.fpga.luts as f64 * self.lut_budget_fraction) as u64
    }

    pub fn bram_budget(&self) -> u64 {
        (self.fpga.brams as f64 * self.bram_budget_fraction) as u64
    }
}

fn parse_u32_list(v: &str) -> Vec<u32> {
    v.split(',').filter_map(|p| p.trim().parse().ok()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gxa7_matches_paper_constants() {
        let f = FpgaSpec::stratix_v_gxa7();
        assert_eq!(f.dsps, 256, "paper: 'it features 256 DSPs'");
        assert_eq!(f.brams, 2560);
        // Table IV uses up to 2470 BRAMs and 392 kLUT; both must fit.
        assert!(f.brams >= 2470);
        assert!(f.luts >= 392_240);
        // Table V: 331.5 kLUT reported as 71 % utilization -> total ≈ 467k.
        let implied_total = 331_500.0 / 0.71;
        assert!((f.luts as f64 - implied_total).abs() / implied_total < 0.02);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(
            FpgaSpec::by_name("stratix-v-gxa7").unwrap().name,
            "Stratix V GXA7"
        );
        assert!(FpgaSpec::by_name("ZCU102").is_some());
        assert!(FpgaSpec::by_name("nope").is_none());
    }

    #[test]
    fn default_config_budget() {
        let c = RunConfig::default();
        assert!(c.lut_budget() < c.fpga.luts);
        assert!(c.bram_budget() <= c.fpga.brams);
        assert_eq!(c.slices, vec![1, 2, 4]);
    }

    #[test]
    fn config_from_kv() {
        let text = "
# comment
fpga = gxa5
act_bits = 8
slices = 1, 2
weight_bits = 2,4
lut_budget_fraction = 0.8
";
        let c = RunConfig::from_kv(text).unwrap();
        assert_eq!(c.fpga.name, "Stratix V GXA5");
        assert_eq!(c.slices, vec![1, 2]);
        assert_eq!(c.weight_bits, vec![2, 4]);
        assert!((c.lut_budget_fraction - 0.8).abs() < 1e-12);
    }

    #[test]
    fn config_rejects_unknown_fpga() {
        assert!(RunConfig::from_kv("fpga = virtex9000").is_err());
    }
}
