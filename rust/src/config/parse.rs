//! `key = value` config-file parser (TOML-subset; no `toml`/`serde` offline).
//!
//! Supported: comments (`#`), blank lines, `key = value` pairs, optional
//! `[section]` headers which prefix keys as `section.key`. Values are kept as
//! raw strings; typed access happens at the consumer.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub struct KvError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for KvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for KvError {}

/// Parse `text` into a flat `key -> value` map.
pub fn parse_kv(text: &str) -> Result<BTreeMap<String, String>, KvError> {
    let mut map = BTreeMap::new();
    let mut section = String::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(sec) = line.strip_prefix('[') {
            let sec = sec.strip_suffix(']').ok_or(KvError {
                line: i + 1,
                message: "unterminated section header".to_string(),
            })?;
            section = sec.trim().to_string();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or(KvError {
            line: i + 1,
            message: format!("expected 'key = value', got '{line}'"),
        })?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{}.{}", section, k.trim())
        };
        // Strip optional quotes and trailing comments.
        let mut val = v.trim();
        if let Some(hash) = val.find(" #") {
            val = val[..hash].trim();
        }
        let val = val.trim_matches('"').to_string();
        if key.is_empty() {
            return Err(KvError {
                line: i + 1,
                message: "empty key".to_string(),
            });
        }
        map.insert(key, val);
    }
    Ok(map)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_pairs() {
        let m = parse_kv("a = 1\nb = hello\n").unwrap();
        assert_eq!(m["a"], "1");
        assert_eq!(m["b"], "hello");
    }

    #[test]
    fn sections_prefix() {
        let m = parse_kv("[fpga]\nname = gxa7\n[dse]\nk = 2").unwrap();
        assert_eq!(m["fpga.name"], "gxa7");
        assert_eq!(m["dse.k"], "2");
    }

    #[test]
    fn comments_and_quotes() {
        let m = parse_kv("# top\nname = \"quoted\"  # trailing\n").unwrap();
        assert_eq!(m["name"], "quoted");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_kv("ok = 1\nnot a pair\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse_kv("[oops\n").unwrap_err();
        assert_eq!(e.line, 1);
    }
}
