//! Channel-wise mixed precision (paper Table V: "supports channel-wise
//! mixed-precision CNNs"; [8][34]).
//!
//! On the BP-ST-1D array, output channels with different weight
//! word-lengths are processed as separate channel groups along the D
//! dimension: the PE's on-the-fly word-length switch (pe::golden) makes
//! this free of reconfiguration; the *schedule* sees each group as a
//! sub-layer with its own `N/w_Q` unrolling factor. This module performs
//! that layer splitting so the whole DSE/simulator stack handles
//! channel-wise CNNs unchanged.

use super::layer::{Cnn, Layer, LayerKind};

/// A channel-group specification: fraction of output channels at a given
/// weight word-length. Fractions must sum to ~1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChannelGroup {
    pub wq: u32,
    pub fraction: f64,
}

/// Channel count per group for an `od`-channel layer: rounded shares with
/// the last group absorbing the remainder, so the counts sum to `od`
/// exactly (individual entries may round to 0 for vanishing fractions).
/// Shared by [`split_layer`] and the xmp weight packer
/// ([`crate::xmp`]) so the schedule-side split and the executed split are
/// derived by the same arithmetic.
pub fn group_channel_counts(od: u32, groups: &[ChannelGroup]) -> Vec<u32> {
    assert!(!groups.is_empty());
    // Each fraction must be a positive, finite share on its own: the sum
    // check alone accepted e.g. [1.5, -0.5] (sums to 1) and silently
    // assigned *all* channels to the 1.5 group — found by
    // `prop_split_rounding_invariants` below.
    for g in groups {
        assert!(
            g.fraction.is_finite() && g.fraction > 0.0,
            "channel fractions must be positive and finite (got {} for w{})",
            g.fraction,
            g.wq
        );
    }
    let total: f64 = groups.iter().map(|g| g.fraction).sum();
    assert!(
        (total - 1.0).abs() < 1e-6,
        "channel fractions must sum to 1 (got {total})"
    );
    let mut out = Vec::with_capacity(groups.len());
    let mut assigned = 0u32;
    for (i, g) in groups.iter().enumerate() {
        let n = if i + 1 == groups.len() {
            od - assigned
        } else {
            ((od as f64 * g.fraction).round() as u32).min(od - assigned)
        };
        assigned += n;
        out.push(n);
    }
    out
}

/// Split one CONV layer's output channels into word-length groups.
/// Channel counts are rounded, with the last group absorbing the
/// remainder so `sum(od_i) == od` exactly.
pub fn split_layer(layer: &Layer, groups: &[ChannelGroup]) -> Vec<Layer> {
    let counts = group_channel_counts(layer.od, groups);
    let mut out = Vec::with_capacity(groups.len());
    for (g, &od) in groups.iter().zip(&counts) {
        if od == 0 {
            continue;
        }
        let mut l = layer.clone();
        l.od = od;
        l.wq = g.wq;
        l.name = format!("{}[w{}]", layer.name, g.wq);
        out.push(l);
    }
    out
}

/// Apply an explicit per-layer precision plan: one group list per layer of
/// `cnn` (same order). A single-group entry assigns that word-length to the
/// whole layer; multi-group entries split the layer's output channels as in
/// [`split_layer`]. This is the lowering used by `planner::emit` /
/// `serving::VariantSpec` for planned (layer- and channel-wise) variants —
/// the resulting [`Cnn`] flows through the DSE/simulator stack unchanged.
pub fn apply_plan(cnn: &Cnn, per_layer: &[Vec<ChannelGroup>]) -> Cnn {
    assert_eq!(
        per_layer.len(),
        cnn.layers.len(),
        "one group list per layer required"
    );
    let mut layers = Vec::with_capacity(cnn.layers.len());
    for (l, groups) in cnn.layers.iter().zip(per_layer) {
        assert!(!groups.is_empty(), "layer '{}' has no groups", l.name);
        if groups.len() == 1 {
            // Uniform layer: the single group must cover all channels —
            // accepting e.g. fraction 0.25 here would silently quantize a
            // different network than the caller specified.
            assert!(
                (groups[0].fraction - 1.0).abs() < 1e-6,
                "single-group fraction for layer '{}' must be 1 (got {})",
                l.name,
                groups[0].fraction
            );
            let mut u = l.clone();
            u.wq = groups[0].wq;
            layers.push(u);
        } else {
            // FC layers are host-side and never split: refuse rather than
            // silently collapsing the extra groups.
            assert!(
                l.kind != LayerKind::Fc,
                "FC layer '{}' cannot be channel-split",
                l.name
            );
            layers.extend(split_layer(l, groups));
        }
    }
    Cnn {
        layers,
        ..cnn.clone()
    }
}

/// Apply a **joint** per-layer precision plan: the weight lowering of
/// [`apply_plan`] plus one activation word-length per base layer, written
/// into every produced (possibly split) layer's `act_bits`. This is how
/// `(wq, aq)` plans reach the Table III footprint models and the DSE's
/// activation-traffic accounting: `Cnn::peak_activation_bits` (which
/// prices each layer's input at the *producer's* `act_bits`) /
/// `total_activation_bits` read `act_bits`, and the structural
/// fingerprint hashes it, so joint variants cache and cost distinctly.
/// An all-8 `aq` produces exactly [`apply_plan`]'s CNN.
///
/// Caveat of the schedule (sub-layer) view: the later sub-layers of a
/// channel-split layer see their *sibling* as predecessor, so their
/// input is priced at the layer's own `a_Q` rather than the true
/// producer's — the per-layer `dataflow` spill heuristic shares the same
/// single-knob approximation. Exact execution-view buffer bytes come
/// from `planner::Assignment::act_buffer_mb`, which is what the planner
/// uses for Pareto dominance.
pub fn apply_joint_plan(cnn: &Cnn, per_layer: &[Vec<ChannelGroup>], aq: &[u32]) -> Cnn {
    assert_eq!(
        aq.len(),
        cnn.layers.len(),
        "one activation word-length per layer required"
    );
    for a in aq {
        assert!(
            (1..=8).contains(a),
            "activation word-length {a} outside the supported 1..=8 bit range"
        );
    }
    let mut lowered = apply_plan(cnn, per_layer);
    // Walk the split structure: base layer i produced 1 lowered layer when
    // uniform, else one per non-zero channel group.
    let mut pos = 0usize;
    for ((l, groups), &a) in cnn.layers.iter().zip(per_layer).zip(aq) {
        let produced = if groups.len() == 1 {
            1
        } else {
            group_channel_counts(l.od, groups)
                .iter()
                .filter(|&&c| c > 0)
                .count()
        };
        for out in &mut lowered.layers[pos..pos + produced] {
            out.act_bits = a;
        }
        pos += produced;
    }
    debug_assert_eq!(pos, lowered.layers.len());
    lowered
}

/// Apply a channel-wise scheme to every inner CONV layer of a CNN
/// (first/last layers stay at 8 bit, as in the paper).
pub fn apply_channelwise(cnn: &Cnn, groups: &[ChannelGroup]) -> Cnn {
    let n = cnn.layers.len();
    let mut layers = Vec::new();
    for (i, l) in cnn.layers.iter().enumerate() {
        let is_edge = i == 0 || i == n - 1 || l.kind == LayerKind::Fc;
        if is_edge {
            let mut e = l.clone();
            e.wq = 8;
            layers.push(e);
        } else {
            layers.extend(split_layer(l, groups));
        }
    }
    Cnn {
        name: format!("{} (channel-wise)", cnn.name),
        layers,
        ..cnn.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;
    use crate::config::RunConfig;
    use crate::util::prop::{check, check_eq, forall};
    use crate::util::rng::Rng;

    fn groups_80_20() -> Vec<ChannelGroup> {
        vec![
            ChannelGroup { wq: 1, fraction: 0.8 },
            ChannelGroup { wq: 8, fraction: 0.2 },
        ]
    }

    #[test]
    fn split_preserves_channels_and_macs() {
        let l = Layer::conv("c", 28, 128, 256, 3, 1);
        let parts = split_layer(&l, &groups_80_20());
        assert_eq!(parts.iter().map(|p| p.od).sum::<u32>(), 256);
        assert_eq!(parts.iter().map(|p| p.macs()).sum::<u64>(), l.macs());
        assert_eq!(parts[0].wq, 1);
        assert_eq!(parts[1].wq, 8);
    }

    #[test]
    fn prop_split_conserves_work() {
        forall(500, |rng: &mut Rng| {
            let l = Layer::conv(
                "p",
                [14u32, 28, 56][rng.range(0, 3)],
                1 << rng.range(3, 9),
                1 << rng.range(3, 10),
                3,
                1,
            );
            let f = rng.uniform(0.05, 0.95);
            let groups = vec![
                ChannelGroup { wq: *rng.choose(&[1u32, 2]), fraction: f },
                ChannelGroup { wq: 8, fraction: 1.0 - f },
            ];
            let parts = split_layer(&l, &groups);
            check_eq(
                parts.iter().map(|p| p.od).sum::<u32>(),
                l.od,
                "channels conserved",
            )?;
            check_eq(
                parts.iter().map(|p| p.params()).sum::<u64>(),
                l.params(),
                "params conserved",
            )
        });
    }

    #[test]
    fn nguyen_style_scheme_beats_uniform_8bit() {
        // The [27]-style scheme (most weights binarized, a few at 8 bit)
        // must land between all-1-bit and all-8-bit in both throughput and
        // footprint — the motivation for channel-wise support.
        let cfg = RunConfig::default();
        let base = resnet::resnet18();
        let cw = apply_channelwise(&base, &groups_80_20());
        let u1 = base.clone().with_uniform_wq(1);
        let u8b = base.clone().with_uniform_wq(8);
        let fps = |cnn: &crate::cnn::Cnn| crate::dse::explore_k(cnn, &cfg, 1).sim.fps;
        let (f_cw, f_1, f_8) = (fps(&cw), fps(&u1), fps(&u8b));
        assert!(
            f_1 >= f_cw && f_cw > f_8,
            "fps ordering: w1 {f_1} >= cw {f_cw} > w8 {f_8}"
        );
        let wb = |cnn: &crate::cnn::Cnn| {
            cnn.layers.iter().map(|l| l.weight_bits_total()).sum::<u64>()
        };
        assert!(wb(&u1) <= wb(&cw) && wb(&cw) < wb(&u8b));
    }

    #[test]
    fn edge_layers_stay_8bit() {
        let cw = apply_channelwise(&resnet::resnet18(), &groups_80_20());
        assert_eq!(cw.layers.first().unwrap().wq, 8);
        assert_eq!(cw.layers.last().unwrap().wq, 8);
        // inner layers got split into two groups each
        assert!(cw.layers.len() > resnet::resnet18().layers.len() + 10);
    }

    #[test]
    fn prop_split_rounding_invariants() {
        // Satellite invariants: group `od`s sum exactly to `layer.od`, no
        // zero-channel sub-layer survives, and fractions arbitrarily close
        // to 0 or 1 neither panic nor leave channels behind.
        forall(1000, |rng: &mut Rng| {
            let l = Layer::conv(
                "inv",
                [7u32, 14, 28][rng.range(0, 3)],
                1 << rng.range(0, 8),
                1 + rng.range(0, 700) as u32,
                *rng.choose(&[1u32, 3]),
                1,
            );
            let n_groups = rng.range(2, 5);
            // Raw positive shares, occasionally extreme, normalized to 1.
            let mut shares: Vec<f64> = (0..n_groups)
                .map(|_| {
                    if rng.chance(0.3) {
                        rng.uniform(1e-9, 1e-3)
                    } else {
                        rng.uniform(0.05, 1.0)
                    }
                })
                .collect();
            let total: f64 = shares.iter().sum();
            for s in &mut shares {
                *s /= total;
            }
            let wqs = [1u32, 2, 3, 4, 8];
            let groups: Vec<ChannelGroup> = shares
                .iter()
                .enumerate()
                .map(|(i, &fraction)| ChannelGroup {
                    wq: wqs[i % wqs.len()],
                    fraction,
                })
                .collect();
            let parts = split_layer(&l, &groups);
            check(!parts.is_empty(), "at least one group must survive")?;
            check_eq(
                parts.iter().map(|p| p.od).sum::<u32>(),
                l.od,
                "group ods must sum exactly to layer.od",
            )?;
            check(
                parts.iter().all(|p| p.od > 0),
                "no zero-channel sub-layer may survive",
            )?;
            check_eq(
                parts.iter().map(|p| p.params()).sum::<u64>(),
                l.params(),
                "params conserved",
            )
        });
    }

    #[test]
    fn counts_match_split_layer() {
        // The packer-facing counts and the schedule-facing split must be the
        // same arithmetic: non-zero counts line up with the split sub-layers.
        let l = Layer::conv("c", 28, 16, 37, 3, 1);
        let groups = vec![
            ChannelGroup { wq: 2, fraction: 0.61 },
            ChannelGroup { wq: 4, fraction: 0.38 },
            ChannelGroup { wq: 8, fraction: 0.01 },
        ];
        let counts = group_channel_counts(l.od, &groups);
        assert_eq!(counts.iter().sum::<u32>(), l.od);
        let split_ods: Vec<u32> = split_layer(&l, &groups).iter().map(|p| p.od).collect();
        let nonzero: Vec<u32> = counts.iter().copied().filter(|&c| c > 0).collect();
        assert_eq!(split_ods, nonzero);
    }

    #[test]
    #[should_panic(expected = "must be positive")]
    fn rejects_negative_fraction_even_when_sum_is_one() {
        // The violation the property hunt surfaced: [1.5, -0.5] sums to 1
        // and previously passed validation, assigning every channel to the
        // 1.5 group.
        split_layer(
            &Layer::conv("neg", 14, 8, 8, 3, 1),
            &[
                ChannelGroup { wq: 2, fraction: 1.5 },
                ChannelGroup { wq: 8, fraction: -0.5 },
            ],
        );
    }

    #[test]
    fn apply_plan_mixes_uniform_and_split_layers() {
        let base = resnet::resnet_small(1, 10);
        let n = base.layers.len();
        let per_layer: Vec<Vec<ChannelGroup>> = (0..n)
            .map(|i| {
                if i == 0 || i == n - 1 {
                    vec![ChannelGroup { wq: 8, fraction: 1.0 }]
                } else if i == 1 {
                    vec![
                        ChannelGroup { wq: 2, fraction: 0.5 },
                        ChannelGroup { wq: 8, fraction: 0.5 },
                    ]
                } else {
                    vec![ChannelGroup { wq: 4, fraction: 1.0 }]
                }
            })
            .collect();
        let planned = apply_plan(&base, &per_layer);
        // One extra layer from the single split; totals conserved.
        assert_eq!(planned.layers.len(), n + 1);
        assert_eq!(
            planned.layers.iter().map(|l| l.macs()).sum::<u64>(),
            base.layers.iter().map(|l| l.macs()).sum::<u64>()
        );
        assert_eq!(planned.layers[0].wq, 8);
        assert_eq!(planned.layers.last().unwrap().wq, 8);
        assert_eq!(planned.layers[1].wq, 2);
        assert_eq!(planned.layers[2].wq, 8);
        // Uniform entries keep their layer name (stable fingerprints).
        assert_eq!(planned.layers[3].name, base.layers[2].name);
        assert_eq!(planned.layers[3].wq, 4);
    }

    #[test]
    fn apply_joint_plan_sets_act_bits_per_split_structure() {
        let base = resnet::resnet_small(1, 10);
        let n = base.layers.len();
        let per_layer: Vec<Vec<ChannelGroup>> = (0..n)
            .map(|i| {
                if i == 1 {
                    vec![
                        ChannelGroup { wq: 2, fraction: 0.5 },
                        ChannelGroup { wq: 8, fraction: 0.5 },
                    ]
                } else {
                    vec![ChannelGroup { wq: 8, fraction: 1.0 }]
                }
            })
            .collect();
        let aq: Vec<u32> = (0..n).map(|i| if i == 1 { 4 } else { 8 }).collect();
        let joint = apply_joint_plan(&base, &per_layer, &aq);
        // Both split halves of base layer 1 carry its aq; neighbors keep 8.
        assert_eq!(joint.layers[0].act_bits, 8);
        assert_eq!(joint.layers[1].act_bits, 4);
        assert_eq!(joint.layers[2].act_bits, 4);
        assert_eq!(joint.layers[3].act_bits, 8);
        // All-8 aq reproduces apply_plan exactly (same fingerprint).
        let all8 = vec![8u32; n];
        assert_eq!(
            apply_joint_plan(&base, &per_layer, &all8).fingerprint(),
            apply_plan(&base, &per_layer).fingerprint()
        );
        // A narrowed aq moves the fingerprint and shrinks the activation
        // working set (Table III accounting sees the reduction).
        let weights_only = apply_plan(&base, &per_layer);
        assert_ne!(joint.fingerprint(), weights_only.fingerprint());
        assert!(joint.total_activation_bits() < weights_only.total_activation_bits());
    }

    #[test]
    #[should_panic(expected = "1..=8")]
    fn apply_joint_plan_rejects_bad_aq() {
        let base = resnet::resnet_small(1, 10);
        let per_layer: Vec<Vec<ChannelGroup>> = base
            .layers
            .iter()
            .map(|_| vec![ChannelGroup { wq: 8, fraction: 1.0 }])
            .collect();
        let mut aq = vec![8u32; base.layers.len()];
        aq[1] = 0;
        apply_joint_plan(&base, &per_layer, &aq);
    }

    #[test]
    #[should_panic(expected = "cannot be channel-split")]
    fn apply_plan_refuses_to_split_fc_layers() {
        let base = resnet::resnet_small(1, 10);
        let mut per_layer: Vec<Vec<ChannelGroup>> = base
            .layers
            .iter()
            .map(|_| vec![ChannelGroup { wq: 8, fraction: 1.0 }])
            .collect();
        *per_layer.last_mut().unwrap() = vec![
            ChannelGroup { wq: 2, fraction: 0.5 },
            ChannelGroup { wq: 8, fraction: 0.5 },
        ];
        apply_plan(&base, &per_layer);
    }

    #[test]
    #[should_panic(expected = "must be 1")]
    fn apply_plan_rejects_partial_single_group() {
        let base = resnet::resnet_small(1, 10);
        let mut per_layer: Vec<Vec<ChannelGroup>> = base
            .layers
            .iter()
            .map(|_| vec![ChannelGroup { wq: 8, fraction: 1.0 }])
            .collect();
        per_layer[1] = vec![ChannelGroup { wq: 2, fraction: 0.25 }];
        apply_plan(&base, &per_layer);
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn rejects_bad_fractions() {
        split_layer(
            &Layer::conv("x", 14, 8, 8, 3, 1),
            &[ChannelGroup { wq: 2, fraction: 0.5 }],
        );
    }
}
