//! Channel-wise mixed precision (paper Table V: "supports channel-wise
//! mixed-precision CNNs"; [8][34]).
//!
//! On the BP-ST-1D array, output channels with different weight
//! word-lengths are processed as separate channel groups along the D
//! dimension: the PE's on-the-fly word-length switch (pe::golden) makes
//! this free of reconfiguration; the *schedule* sees each group as a
//! sub-layer with its own `N/w_Q` unrolling factor. This module performs
//! that layer splitting so the whole DSE/simulator stack handles
//! channel-wise CNNs unchanged.

use super::layer::{Cnn, Layer, LayerKind};

/// A channel-group specification: fraction of output channels at a given
/// weight word-length. Fractions must sum to ~1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChannelGroup {
    pub wq: u32,
    pub fraction: f64,
}

/// Split one CONV layer's output channels into word-length groups.
/// Channel counts are rounded, with the last group absorbing the
/// remainder so `sum(od_i) == od` exactly.
pub fn split_layer(layer: &Layer, groups: &[ChannelGroup]) -> Vec<Layer> {
    assert!(!groups.is_empty());
    let total: f64 = groups.iter().map(|g| g.fraction).sum();
    assert!(
        (total - 1.0).abs() < 1e-6,
        "channel fractions must sum to 1 (got {total})"
    );
    let mut out = Vec::with_capacity(groups.len());
    let mut assigned = 0u32;
    for (i, g) in groups.iter().enumerate() {
        let od = if i + 1 == groups.len() {
            layer.od - assigned
        } else {
            ((layer.od as f64 * g.fraction).round() as u32).min(layer.od - assigned)
        };
        if od == 0 {
            continue;
        }
        assigned += od;
        let mut l = layer.clone();
        l.od = od;
        l.wq = g.wq;
        l.name = format!("{}[w{}]", layer.name, g.wq);
        out.push(l);
    }
    out
}

/// Apply a channel-wise scheme to every inner CONV layer of a CNN
/// (first/last layers stay at 8 bit, as in the paper).
pub fn apply_channelwise(cnn: &Cnn, groups: &[ChannelGroup]) -> Cnn {
    let n = cnn.layers.len();
    let mut layers = Vec::new();
    for (i, l) in cnn.layers.iter().enumerate() {
        let is_edge = i == 0 || i == n - 1 || l.kind == LayerKind::Fc;
        if is_edge {
            let mut e = l.clone();
            e.wq = 8;
            layers.push(e);
        } else {
            layers.extend(split_layer(l, groups));
        }
    }
    Cnn {
        name: format!("{} (channel-wise)", cnn.name),
        layers,
        ..cnn.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;
    use crate::config::RunConfig;
    use crate::util::prop::{check, check_eq, forall};
    use crate::util::rng::Rng;

    fn groups_80_20() -> Vec<ChannelGroup> {
        vec![
            ChannelGroup { wq: 1, fraction: 0.8 },
            ChannelGroup { wq: 8, fraction: 0.2 },
        ]
    }

    #[test]
    fn split_preserves_channels_and_macs() {
        let l = Layer::conv("c", 28, 128, 256, 3, 1);
        let parts = split_layer(&l, &groups_80_20());
        assert_eq!(parts.iter().map(|p| p.od).sum::<u32>(), 256);
        assert_eq!(parts.iter().map(|p| p.macs()).sum::<u64>(), l.macs());
        assert_eq!(parts[0].wq, 1);
        assert_eq!(parts[1].wq, 8);
    }

    #[test]
    fn prop_split_conserves_work() {
        forall(500, |rng: &mut Rng| {
            let l = Layer::conv(
                "p",
                [14u32, 28, 56][rng.range(0, 3)],
                1 << rng.range(3, 9),
                1 << rng.range(3, 10),
                3,
                1,
            );
            let f = rng.uniform(0.05, 0.95);
            let groups = vec![
                ChannelGroup { wq: *rng.choose(&[1u32, 2]), fraction: f },
                ChannelGroup { wq: 8, fraction: 1.0 - f },
            ];
            let parts = split_layer(&l, &groups);
            check_eq(
                parts.iter().map(|p| p.od).sum::<u32>(),
                l.od,
                "channels conserved",
            )?;
            check_eq(
                parts.iter().map(|p| p.params()).sum::<u64>(),
                l.params(),
                "params conserved",
            )
        });
    }

    #[test]
    fn nguyen_style_scheme_beats_uniform_8bit() {
        // The [27]-style scheme (most weights binarized, a few at 8 bit)
        // must land between all-1-bit and all-8-bit in both throughput and
        // footprint — the motivation for channel-wise support.
        let cfg = RunConfig::default();
        let base = resnet::resnet18();
        let cw = apply_channelwise(&base, &groups_80_20());
        let u1 = base.clone().with_uniform_wq(1);
        let u8b = base.clone().with_uniform_wq(8);
        let fps = |cnn: &crate::cnn::Cnn| crate::dse::explore_k(cnn, &cfg, 1).sim.fps;
        let (f_cw, f_1, f_8) = (fps(&cw), fps(&u1), fps(&u8b));
        assert!(
            f_1 >= f_cw && f_cw > f_8,
            "fps ordering: w1 {f_1} >= cw {f_cw} > w8 {f_8}"
        );
        let wb = |cnn: &crate::cnn::Cnn| {
            cnn.layers.iter().map(|l| l.weight_bits_total()).sum::<u64>()
        };
        assert!(wb(&u1) <= wb(&cw) && wb(&cw) < wb(&u8b));
    }

    #[test]
    fn edge_layers_stay_8bit() {
        let cw = apply_channelwise(&resnet::resnet18(), &groups_80_20());
        assert_eq!(cw.layers.first().unwrap().wq, 8);
        assert_eq!(cw.layers.last().unwrap().wq, 8);
        // inner layers got split into two groups each
        assert!(cw.layers.len() > resnet::resnet18().layers.len() + 10);
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn rejects_bad_fractions() {
        split_layer(
            &Layer::conv("x", 14, 8, 8, 3, 1),
            &[ChannelGroup { wq: 2, fraction: 0.5 }],
        );
    }
}
