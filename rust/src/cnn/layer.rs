//! CNN layer intermediate representation.
//!
//! Notation follows the paper (§III-B): a convolutional layer is described by
//! the input feature-map height `I_H` (spatial, square maps), the input
//! channel count `I_W`, the output channel count `O_D`, filter kernel `K` and
//! stride `S`. MAC count per layer is `I_H² · I_W · O_D · (K/S)²`
//! (numerator of Eq 3).

/// Layer type. The accelerator processes CONV layers (paper: "we focus ... on
/// the processing of CONV layers"); FC layers are carried for footprint and
/// host-side accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Fc,
}

/// One layer of a CNN, annotated with its assigned weight word-length.
#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    /// Input feature-map height/width in pixels (square), `I_H`. 1 for FC.
    pub ih: u32,
    /// Input channels, `I_W`.
    pub iw: u32,
    /// Output channels, `O_D`.
    pub od: u32,
    /// Kernel size `K` (square). 1 for FC.
    pub k: u32,
    /// Stride `S`.
    pub s: u32,
    /// Assigned weight word-length in bits (`w_Q`).
    pub wq: u32,
    /// Activation word-length in bits (paper fixes 8).
    pub act_bits: u32,
}

impl Layer {
    pub fn conv(name: &str, ih: u32, iw: u32, od: u32, k: u32, s: u32) -> Layer {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Conv,
            ih,
            iw,
            od,
            k,
            s,
            wq: 8,
            act_bits: 8,
        }
    }

    pub fn fc(name: &str, iw: u32, od: u32) -> Layer {
        Layer {
            name: name.to_string(),
            kind: LayerKind::Fc,
            ih: 1,
            iw,
            od,
            k: 1,
            s: 1,
            wq: 8,
            act_bits: 8,
        }
    }

    /// Output spatial size (`ceil(I_H / S)` — SAME padding, as in ResNet).
    pub fn oh(&self) -> u32 {
        self.ih.div_ceil(self.s)
    }

    /// Multiply-accumulate operations for one input frame.
    pub fn macs(&self) -> u64 {
        match self.kind {
            LayerKind::Conv => {
                (self.oh() as u64).pow(2)
                    * (self.k as u64).pow(2)
                    * self.iw as u64
                    * self.od as u64
            }
            LayerKind::Fc => self.iw as u64 * self.od as u64,
        }
    }

    /// Ops for one frame under the paper's convention (1 MAC = 2 Ops).
    pub fn ops(&self) -> u64 {
        2 * self.macs()
    }

    /// Weight parameter count (biases folded into BN, counted separately).
    pub fn params(&self) -> u64 {
        (self.k as u64).pow(2) * self.iw as u64 * self.od as u64
    }

    /// Weight storage in bits at the assigned word-length.
    pub fn weight_bits_total(&self) -> u64 {
        self.params() * self.wq as u64
    }

    /// Input activation count for one frame.
    pub fn input_elems(&self) -> u64 {
        (self.ih as u64).pow(2) * self.iw as u64
    }

    /// Output activation count for one frame.
    pub fn output_elems(&self) -> u64 {
        match self.kind {
            LayerKind::Conv => (self.oh() as u64).pow(2) * self.od as u64,
            LayerKind::Fc => self.od as u64,
        }
    }
}

/// A CNN: named sequence of layers plus input geometry.
#[derive(Clone, Debug, PartialEq)]
pub struct Cnn {
    pub name: String,
    pub input_hw: u32,
    pub input_channels: u32,
    pub classes: u32,
    pub layers: Vec<Layer>,
}

impl Cnn {
    /// All CONV layers (the accelerated set).
    pub fn conv_layers(&self) -> impl Iterator<Item = &Layer> {
        self.layers.iter().filter(|l| l.kind == LayerKind::Conv)
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// CONV-only MACs — what the accelerator executes (paper Table V
    /// footnote: "CONV only: yes").
    pub fn conv_macs(&self) -> u64 {
        self.conv_layers().map(|l| l.macs()).sum()
    }

    pub fn conv_ops(&self) -> u64 {
        2 * self.conv_macs()
    }

    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Assign the paper's quantization scheme: inner layers at `inner_bits`,
    /// first and last layer fixed to 8 bit ("we fix activations as well as
    /// first and last layer weights to 8 bit", §IV-C).
    pub fn with_uniform_wq(mut self, inner_bits: u32) -> Cnn {
        let n = self.layers.len();
        for (i, l) in self.layers.iter_mut().enumerate() {
            l.wq = if i == 0 || i == n - 1 { 8 } else { inner_bits };
        }
        self
    }

    /// Assign explicit per-layer word-lengths (layer-wise mixed precision).
    /// `bits.len()` must equal the layer count.
    pub fn with_layerwise_wq(mut self, bits: &[u32]) -> Cnn {
        assert_eq!(
            bits.len(),
            self.layers.len(),
            "one word-length per layer required"
        );
        for (l, b) in self.layers.iter_mut().zip(bits) {
            l.wq = *b;
        }
        self
    }

    /// Largest single-layer activation working set in bits (input + output of
    /// the worst layer) — drives the on-chip activation buffer size.
    ///
    /// The input side is priced at the *producer's* word-length: a layer
    /// assigned `a_Q = 4` whose producer emits 8-bit activations still
    /// buffers an 8-bit input map. The producer is resolved structurally
    /// (see [`input_act_bits`](Self::input_act_bits)), so residual
    /// projection layers price their input at the saved earlier
    /// activation's width, not the list predecessor's. For
    /// uniform-`act_bits` CNNs — every CNN outside joint `(w_Q, a_Q)`
    /// lowering — this is exactly the old `(input + output) · act_bits`
    /// accounting.
    pub fn peak_activation_bits(&self) -> u64 {
        self.layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                l.input_elems() * self.input_act_bits(i) as u64
                    + l.output_elems() * l.act_bits as u64
            })
            .max()
            .unwrap_or(0)
    }

    /// Word-length of the activations feeding layer `i`, mirroring the
    /// structural rules of the xmp forward pass: the previous layer's
    /// `act_bits` when shapes chain (including through an elided stride-2
    /// pool, which preserves its input's width); otherwise the most
    /// recent earlier layer whose output shape matches the wanted input
    /// (the residual `downsample` projections); otherwise — layer 0's
    /// image input, unmatched branches, split sub-layers whose producer
    /// is itself split — the widest `act_bits` seen so far, a
    /// conservative bound that is exact for uniform-`act_bits` CNNs.
    pub fn input_act_bits(&self, i: usize) -> u32 {
        let l = &self.layers[i];
        let widest = self.layers[..=i]
            .iter()
            .map(|p| p.act_bits)
            .max()
            .unwrap_or(8);
        if i == 0 {
            return widest;
        }
        let prev = &self.layers[i - 1];
        let chains = (prev.oh(), prev.od) == (l.ih, l.iw)
            || (prev.od == l.iw && prev.oh().div_ceil(2) == l.ih);
        if chains {
            return self.layers[i - 1].act_bits;
        }
        for p in self.layers[..i.saturating_sub(1)].iter().rev() {
            if (p.oh(), p.od) == (l.ih, l.iw) {
                return p.act_bits;
            }
        }
        widest
    }

    /// Total activation traffic (all layer outputs, written once + read once)
    /// in bits — used by the DDR-spill model when activations exceed on-chip
    /// capacity.
    pub fn total_activation_bits(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.output_elems() * l.act_bits as u64)
            .sum()
    }

    /// Order-sensitive structural hash (FNV-1a, process-stable) over
    /// everything the DSE and simulator read from this CNN — names, input
    /// geometry, and every layer field. Used as the
    /// [`crate::dse::DseCache`] key component.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::fnv::Fnv1a::new();
        h.write_delimited(self.name.as_bytes());
        h.write_u32(self.input_hw);
        h.write_u32(self.input_channels);
        h.write_u32(self.classes);
        for l in &self.layers {
            h.write_delimited(l.name.as_bytes());
            let kind = match l.kind {
                LayerKind::Conv => 0u32,
                LayerKind::Fc => 1,
            };
            for v in [kind, l.ih, l.iw, l.od, l.k, l.s, l.wq, l.act_bits] {
                h.write_u32(v);
            }
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv1_resnet_macs() {
        // ResNet conv1: 224x224x3 -> 7x7/2 -> 64 channels = 118.0 MMACs.
        let l = Layer::conv("conv1", 224, 3, 64, 7, 2);
        assert_eq!(l.oh(), 112);
        assert_eq!(l.macs(), 112u64 * 112 * 49 * 3 * 64);
        assert!((l.macs() as f64 - 118.0e6).abs() / 118.0e6 < 0.01);
    }

    #[test]
    fn fc_macs_and_params() {
        let l = Layer::fc("fc", 512, 1000);
        assert_eq!(l.macs(), 512_000);
        assert_eq!(l.params(), 512_000);
        assert_eq!(l.ops(), 1_024_000);
    }

    #[test]
    fn uniform_wq_pins_first_last() {
        let cnn = Cnn {
            name: "t".into(),
            input_hw: 32,
            input_channels: 3,
            classes: 10,
            layers: vec![
                Layer::conv("a", 32, 3, 16, 3, 1),
                Layer::conv("b", 32, 16, 16, 3, 1),
                Layer::fc("fc", 16, 10),
            ],
        }
        .with_uniform_wq(2);
        assert_eq!(cnn.layers[0].wq, 8);
        assert_eq!(cnn.layers[1].wq, 2);
        assert_eq!(cnn.layers[2].wq, 8);
    }

    #[test]
    fn peak_activation_prices_inputs_at_the_producers_word_length() {
        let mut cnn = Cnn {
            name: "t".into(),
            input_hw: 32,
            input_channels: 3,
            classes: 10,
            layers: vec![
                Layer::conv("a", 32, 3, 16, 3, 1),
                Layer::conv("b", 32, 16, 16, 3, 1),
            ],
        };
        // Uniform act_bits: exactly the old (in + out) · act_bits rule.
        let uniform: u64 = cnn
            .layers
            .iter()
            .map(|l| (l.input_elems() + l.output_elems()) * 8)
            .max()
            .unwrap();
        assert_eq!(cnn.peak_activation_bits(), uniform);
        // Narrow layer b's OUTPUT to 4 bits: its input buffer still holds
        // layer a's 8-bit map — the joint-plan case that used to be
        // undercounted as (in + out) · 4.
        cnn.layers[1].act_bits = 4;
        let a = &cnn.layers[0];
        let b = &cnn.layers[1];
        let want = (a.input_elems() * 8 + a.output_elems() * 8)
            .max(b.input_elems() * 8 + b.output_elems() * 4);
        assert_eq!(cnn.peak_activation_bits(), want);
        assert!(cnn.peak_activation_bits() > b.input_elems() * 4 + b.output_elems() * 4);
    }

    #[test]
    fn stride_two_quarters_macs() {
        let a = Layer::conv("s1", 56, 64, 128, 3, 1);
        let b = Layer::conv("s2", 56, 64, 128, 3, 2);
        assert_eq!(a.macs(), 4 * b.macs());
    }

    #[test]
    fn odd_spatial_ceil() {
        let l = Layer::conv("odd", 7, 8, 8, 3, 2);
        assert_eq!(l.oh(), 4);
    }

    #[test]
    fn fingerprint_sensitive_to_structure() {
        let base = Cnn {
            name: "t".into(),
            input_hw: 32,
            input_channels: 3,
            classes: 10,
            layers: vec![
                Layer::conv("a", 32, 3, 16, 3, 1),
                Layer::conv("b", 32, 16, 16, 3, 1),
            ],
        };
        let same = base.clone();
        assert_eq!(base.fingerprint(), same.fingerprint());
        // Any DSE-relevant change must move the fingerprint.
        let mut requantized = base.clone();
        requantized.layers[1].wq = 2;
        assert_ne!(base.fingerprint(), requantized.fingerprint());
        let mut widened = base.clone();
        widened.layers[1].od = 32;
        assert_ne!(base.fingerprint(), widened.fingerprint());
        let mut renamed = base.clone();
        renamed.name = "u".into();
        assert_ne!(base.fingerprint(), renamed.fingerprint());
    }
}
