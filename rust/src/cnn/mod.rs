//! CNN model descriptions: layer IR, ResNet builders, and workload statistics.
//!
//! These are the *shapes* the DSE and simulator operate on. The runnable
//! (PJRT-executed) models live in `python/compile/` and are exported as HLO;
//! `resnet::resnet_small` mirrors the exported topology exactly so the
//! simulator can be cross-checked against real execution.

pub mod channelwise;
pub mod layer;
pub mod resnet;
pub mod workload;

pub use channelwise::{apply_channelwise, ChannelGroup};
pub use layer::{Cnn, Layer, LayerKind};
