//! ResNet model builders (He et al. [14]).
//!
//! The paper evaluates ResNet-18 (basic blocks), ResNet-50 and ResNet-152
//! (bottleneck blocks) on ImageNet (224×224×3, 1000 classes). We reconstruct
//! the exact layer tables (including identity-shortcut downsample convs) so
//! the DSE and simulator operate on the true shapes, and provide small
//! 32×32 variants matching `python/compile/model.py` for the runnable
//! serving path.

use super::layer::{Cnn, Layer};

/// Basic residual block: two 3×3 convs (+ 1×1 downsample when the shape
/// changes). `ih` is the block's input spatial size.
fn basic_block(layers: &mut Vec<Layer>, tag: &str, ih: u32, in_ch: u32, out_ch: u32, stride: u32) {
    layers.push(Layer::conv(
        &format!("{tag}.conv1"),
        ih,
        in_ch,
        out_ch,
        3,
        stride,
    ));
    let oh = ih.div_ceil(stride);
    layers.push(Layer::conv(&format!("{tag}.conv2"), oh, out_ch, out_ch, 3, 1));
    if stride != 1 || in_ch != out_ch {
        layers.push(Layer::conv(
            &format!("{tag}.downsample"),
            ih,
            in_ch,
            out_ch,
            1,
            stride,
        ));
    }
}

/// Bottleneck residual block: 1×1 reduce, 3×3, 1×1 expand (expansion 4).
fn bottleneck_block(
    layers: &mut Vec<Layer>,
    tag: &str,
    ih: u32,
    in_ch: u32,
    mid_ch: u32,
    stride: u32,
) {
    let out_ch = mid_ch * 4;
    layers.push(Layer::conv(&format!("{tag}.conv1"), ih, in_ch, mid_ch, 1, 1));
    layers.push(Layer::conv(
        &format!("{tag}.conv2"),
        ih,
        mid_ch,
        mid_ch,
        3,
        stride,
    ));
    let oh = ih.div_ceil(stride);
    layers.push(Layer::conv(
        &format!("{tag}.conv3"),
        oh,
        mid_ch,
        out_ch,
        1,
        1,
    ));
    if stride != 1 || in_ch != out_ch {
        layers.push(Layer::conv(
            &format!("{tag}.downsample"),
            ih,
            in_ch,
            out_ch,
            1,
            stride,
        ));
    }
}

/// Build an ImageNet ResNet with basic blocks (18/34-style).
fn resnet_basic(name: &str, blocks_per_stage: [u32; 4]) -> Cnn {
    let mut layers = vec![Layer::conv("conv1", 224, 3, 64, 7, 2)];
    // maxpool 3x3/2: 112 -> 56 (no MACs; shapes only)
    let mut ih = 56;
    let mut in_ch = 64;
    for (stage, &nblocks) in blocks_per_stage.iter().enumerate() {
        let out_ch = 64 << stage;
        for b in 0..nblocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            basic_block(
                &mut layers,
                &format!("layer{}.{}", stage + 1, b),
                ih,
                in_ch,
                out_ch,
                stride,
            );
            ih = ih.div_ceil(stride);
            in_ch = out_ch;
        }
    }
    layers.push(Layer::fc("fc", in_ch, 1000));
    Cnn {
        name: name.to_string(),
        input_hw: 224,
        input_channels: 3,
        classes: 1000,
        layers,
    }
}

/// Build an ImageNet ResNet with bottleneck blocks (50/101/152-style).
fn resnet_bottleneck(name: &str, blocks_per_stage: [u32; 4]) -> Cnn {
    let mut layers = vec![Layer::conv("conv1", 224, 3, 64, 7, 2)];
    let mut ih = 56;
    let mut in_ch = 64;
    for (stage, &nblocks) in blocks_per_stage.iter().enumerate() {
        let mid_ch = 64 << stage;
        for b in 0..nblocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            bottleneck_block(
                &mut layers,
                &format!("layer{}.{}", stage + 1, b),
                ih,
                in_ch,
                mid_ch,
                stride,
            );
            ih = ih.div_ceil(stride);
            in_ch = mid_ch * 4;
        }
    }
    layers.push(Layer::fc("fc", in_ch, 1000));
    Cnn {
        name: name.to_string(),
        input_hw: 224,
        input_channels: 3,
        classes: 1000,
        layers,
    }
}

/// ResNet-18 for ImageNet: 1.81 GMACs, 11.7 M parameters.
pub fn resnet18() -> Cnn {
    resnet_basic("ResNet-18", [2, 2, 2, 2])
}

/// ResNet-34 for ImageNet (extension beyond the paper's set).
pub fn resnet34() -> Cnn {
    resnet_basic("ResNet-34", [3, 4, 6, 3])
}

/// ResNet-50 for ImageNet: 4.09 GMACs, 25.5 M parameters.
pub fn resnet50() -> Cnn {
    resnet_bottleneck("ResNet-50", [3, 4, 6, 3])
}

/// ResNet-101 for ImageNet (extension beyond the paper's set).
pub fn resnet101() -> Cnn {
    resnet_bottleneck("ResNet-101", [3, 4, 23, 3])
}

/// ResNet-152 for ImageNet: 11.5 GMACs, 60.2 M parameters.
pub fn resnet152() -> Cnn {
    resnet_bottleneck("ResNet-152", [3, 8, 36, 3])
}

/// Small 32×32 ResNet (CIFAR-style, He et al. §4.2): conv3×3(16) then three
/// stages of `n` basic blocks at 16/32/64 channels, then FC. `resnet_small(1)`
/// = ResNet-8 — this exact net is what `python/compile/model.py` builds, QAT
/// trains, and `aot.py` exports for the rust serving path.
pub fn resnet_small(n_per_stage: u32, classes: u32) -> Cnn {
    let mut layers = vec![Layer::conv("conv1", 32, 3, 16, 3, 1)];
    let mut ih = 32;
    let mut in_ch = 16;
    for (stage, mult) in [1u32, 2, 4].iter().enumerate() {
        let out_ch = 16 * mult;
        for b in 0..n_per_stage {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            basic_block(
                &mut layers,
                &format!("layer{}.{}", stage + 1, b),
                ih,
                in_ch,
                out_ch,
                stride,
            );
            ih = ih.div_ceil(stride);
            in_ch = out_ch;
        }
    }
    layers.push(Layer::fc("fc", in_ch, classes));
    Cnn {
        name: format!("ResNet-{}", 6 * n_per_stage + 2),
        input_hw: 32,
        input_channels: 3,
        classes,
        layers,
    }
}

/// Look up a CNN by name (CLI entry point).
pub fn by_name(name: &str) -> Option<Cnn> {
    match name
        .to_ascii_lowercase()
        .replace(['-', '_', ' '], "")
        .as_str()
    {
        "resnet18" => Some(resnet18()),
        "resnet34" => Some(resnet34()),
        "resnet50" => Some(resnet50()),
        "resnet101" => Some(resnet101()),
        "resnet152" => Some(resnet152()),
        "resnet8" => Some(resnet_small(1, 10)),
        "resnet14" => Some(resnet_small(2, 10)),
        "resnet20" => Some(resnet_small(3, 10)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel_err(got: f64, want: f64) -> f64 {
        (got - want).abs() / want
    }

    #[test]
    fn resnet18_totals_match_literature() {
        let net = resnet18();
        // 1.81-1.82 GMACs, 11.68 M params (torchvision: 11,689,512 incl. BN).
        assert!(
            rel_err(net.total_macs() as f64, 1.82e9) < 0.02,
            "macs={}",
            net.total_macs()
        );
        assert!(
            rel_err(net.total_params() as f64, 11.68e6) < 0.03,
            "params={}",
            net.total_params()
        );
        // 20 convs + 1 fc: conv1 + 16 block convs + 3 downsamples.
        assert_eq!(net.layers.len(), 21);
    }

    #[test]
    fn resnet50_totals_match_literature() {
        let net = resnet50();
        assert!(
            rel_err(net.total_macs() as f64, 4.09e9) < 0.03,
            "macs={}",
            net.total_macs()
        );
        assert!(
            rel_err(net.total_params() as f64, 25.5e6) < 0.03,
            "params={}",
            net.total_params()
        );
        // conv1 + 48 block convs + 4 downsamples + fc = 54 layers.
        assert_eq!(net.layers.len(), 54);
    }

    #[test]
    fn resnet152_totals_match_literature() {
        let net = resnet152();
        assert!(
            rel_err(net.total_macs() as f64, 11.5e9) < 0.03,
            "macs={}",
            net.total_macs()
        );
        assert!(
            rel_err(net.total_params() as f64, 60.19e6) < 0.03,
            "params={}",
            net.total_params()
        );
    }

    #[test]
    fn paper_gops_per_frame_consistency() {
        // Table V: ResNet-152 at 1131.38 GOps/s and 51.19 frames/s implies
        // ~22.1 GOps/frame of CONV work; our conv_ops must be within 5 %.
        let net = resnet152();
        let gops_per_frame = net.conv_ops() as f64 / 1e9;
        assert!(
            rel_err(gops_per_frame, 1131.38 / 51.19) < 0.05,
            "gops/frame={gops_per_frame}"
        );
    }

    #[test]
    fn small_resnets() {
        let r8 = resnet_small(1, 10);
        assert_eq!(r8.name, "ResNet-8");
        assert_eq!(r8.input_hw, 32);
        // conv1 + 3 stages x (2 convs + maybe ds) + fc:
        // stage1: 2, stage2: 3, stage3: 3 -> 1+8+1 = 10 layers.
        assert_eq!(r8.layers.len(), 10);
        let r20 = resnet_small(3, 10);
        assert_eq!(r20.name, "ResNet-20");
        assert!(r20.total_macs() > r8.total_macs());
    }

    #[test]
    fn by_name_lookup() {
        assert_eq!(by_name("ResNet-18").unwrap().name, "ResNet-18");
        assert_eq!(by_name("resnet_152").unwrap().name, "ResNet-152");
        assert!(by_name("vgg16").is_none());
    }

    #[test]
    fn spatial_sizes_telescope() {
        // Every layer's input spatial size must match the previous layer's
        // output (within the residual-block structure: downsample layers
        // re-read the block input).
        let net = resnet18();
        for l in net.conv_layers() {
            assert!(l.ih >= 7, "layer {} too small: {}", l.name, l.ih);
            assert_eq!(l.ih % l.s, 0, "stride must divide spatial: {}", l.name);
        }
        // Final stage runs at 7x7.
        let last_conv = net
            .layers
            .iter()
            .rev()
            .find(|l| l.kind == super::super::layer::LayerKind::Conv)
            .unwrap();
        assert_eq!(last_conv.oh(), 7);
    }

    #[test]
    fn downsample_layers_present() {
        let net = resnet18();
        let ds: Vec<&Layer> = net
            .layers
            .iter()
            .filter(|l| l.name.contains("downsample"))
            .collect();
        assert_eq!(ds.len(), 3, "stages 2-4 each have one downsample conv");
        assert!(ds.iter().all(|l| l.k == 1 && l.s == 2));
    }
}
