//! Workload statistics: memory footprint, compression factor, op counts
//! (backs Table III and feeds the DDR-traffic model of Table IV).
//!
//! Footprint accounting (first-principles; see DESIGN.md §8 for why we do not
//! copy the paper's absolute MB column): weights at their per-layer
//! word-length + BN scale/shift and biases at 32 bit + the peak activation
//! working set at the activation word-length.

use super::layer::{Cnn, LayerKind};

/// Memory footprint breakdown for one quantized CNN.
#[derive(Clone, Debug, PartialEq)]
pub struct Footprint {
    pub weight_bits: u64,
    /// BN gamma/beta + biases kept at 32-bit as in the paper's FP baseline.
    pub bn_bias_bits: u64,
    pub peak_activation_bits: u64,
}

impl Footprint {
    pub fn total_bits(&self) -> u64 {
        self.weight_bits + self.bn_bias_bits + self.peak_activation_bits
    }

    pub fn total_mb(&self) -> f64 {
        self.total_bits() as f64 / 8.0 / 1e6
    }

    pub fn weight_mb(&self) -> f64 {
        self.weight_bits as f64 / 8.0 / 1e6
    }
}

/// Compute the footprint of `cnn` with its current per-layer `wq`.
pub fn footprint(cnn: &Cnn) -> Footprint {
    let weight_bits = cnn.layers.iter().map(|l| l.weight_bits_total()).sum();
    // Each conv layer is followed by BN (2 params per output channel); the FC
    // layer has a bias per class. All at 32 bit.
    let bn_bias_bits = cnn
        .layers
        .iter()
        .map(|l| match l.kind {
            LayerKind::Conv => 2 * l.od as u64 * 32,
            LayerKind::Fc => l.od as u64 * 32,
        })
        .sum();
    Footprint {
        weight_bits,
        bn_bias_bits,
        peak_activation_bits: cnn.peak_activation_bits(),
    }
}

/// Footprint of the 32-bit floating-point baseline of the same topology.
pub fn footprint_fp32(cnn: &Cnn) -> Footprint {
    let mut fp = cnn.clone();
    for l in fp.layers.iter_mut() {
        l.wq = 32;
        l.act_bits = 32;
    }
    footprint(&fp)
}

/// Compression factor vs the FP32 baseline (paper Table III column).
pub fn compression_factor(cnn: &Cnn) -> f64 {
    footprint_fp32(cnn).total_bits() as f64 / footprint(cnn).total_bits() as f64
}

/// Weight-only compression (the abstract's 4.9x / 9.4x numbers are
/// parameter-memory reductions).
pub fn weight_compression_factor(cnn: &Cnn) -> f64 {
    let fp_bits: u64 = cnn.layers.iter().map(|l| l.params() * 32).sum();
    let q_bits: u64 = cnn.layers.iter().map(|l| l.weight_bits_total()).sum();
    fp_bits as f64 / q_bits as f64
}

/// Average weight word-length over CONV layers, weighted by MAC count — the
/// quantity the paper says drives the optimal operand slice k ("the final
/// choice of the operand slice k depends on the average word-length used in
/// the adopted CNN").
pub fn mac_weighted_avg_wq(cnn: &Cnn) -> f64 {
    let (num, den) = cnn.conv_layers().fold((0.0, 0.0), |(n, d), l| {
        (n + (l.macs() as f64) * l.wq as f64, d + l.macs() as f64)
    });
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::resnet;

    #[test]
    fn fp32_footprint_matches_param_count() {
        let net = resnet::resnet18();
        let fp = footprint_fp32(&net);
        // 11.68M params * 4 bytes ≈ 46.7 MB of weights.
        assert!((fp.weight_mb() - 46.7).abs() < 1.5, "{}", fp.weight_mb());
    }

    #[test]
    fn compression_at_wq2_substantial_and_depth_helps_over_50() {
        // Paper Table III reports 4.9x/5.6x/9.4x at w_Q=2 under its own
        // (unstated) accounting; our first-principles parameter accounting
        // gives larger factors (~13-15x) because we count only real weight
        // bits. The robust *shape*: ResNet-152 compresses better than
        // ResNet-50 (its 8-bit FC layer amortizes away), and every factor is
        // far above the w_Q=4 ones.
        let c18 = weight_compression_factor(&resnet::resnet18().with_uniform_wq(2));
        let c50 = weight_compression_factor(&resnet::resnet50().with_uniform_wq(2));
        let c152 = weight_compression_factor(&resnet::resnet152().with_uniform_wq(2));
        assert!(c152 > c50, "c50={c50} c152={c152}");
        for c in [c18, c50, c152] {
            assert!((10.0..17.0).contains(&c), "c={c}");
        }
    }

    #[test]
    fn compression_monotone_in_wq() {
        let c4 = weight_compression_factor(&resnet::resnet18().with_uniform_wq(4));
        let c2 = weight_compression_factor(&resnet::resnet18().with_uniform_wq(2));
        let c1 = weight_compression_factor(&resnet::resnet18().with_uniform_wq(1));
        assert!(c1 > c2 && c2 > c4);
    }

    #[test]
    fn avg_wq_between_bounds() {
        let net = resnet::resnet18().with_uniform_wq(2);
        let avg = mac_weighted_avg_wq(&net);
        assert!(avg > 2.0 && avg < 8.0, "avg={avg}");
        // conv1 is a small fraction of MACs, so avg is near 2.
        assert!(avg < 2.6, "avg={avg}");
    }

    #[test]
    fn footprint_total_includes_activations() {
        let net = resnet::resnet18().with_uniform_wq(4);
        let f = footprint(&net);
        assert!(f.peak_activation_bits > 0);
        assert!(f.total_bits() > f.weight_bits);
    }
}
