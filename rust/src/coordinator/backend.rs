//! Inference backends for the coordinator: the production PJRT engine and a
//! deterministic mock for tests/benches.

use crate::anyhow;
use crate::runtime::Engine;
use crate::util::error::Result;

/// Anything that can run a batch of images to logits.
///
/// Not `Send`: the PJRT client types are thread-affine, so the coordinator
/// constructs the backend *inside* the batcher thread via a factory closure
/// (see [`crate::coordinator::Coordinator::start`]).
pub trait InferenceBackend {
    /// Batch sizes the backend has compiled executables for (sorted not
    /// required).
    fn batch_sizes(&self) -> Vec<usize>;
    /// Flattened image length (h*w*c).
    fn image_len(&self) -> usize;
    fn classes(&self) -> usize;
    /// Run `batch` images (flattened, padded by the caller) and return
    /// `batch * classes` logits.
    fn infer_batch(&self, images: &[f32], batch: usize) -> Result<Vec<f32>>;
}

/// PJRT-backed production backend for one word-length variant.
pub struct EngineBackend {
    engine: Engine,
    wq: u32,
    batch_sizes: Vec<usize>,
    image_len: usize,
    classes: usize,
}

impl EngineBackend {
    /// Wrap an engine, serving the `wq` variant.
    pub fn new(engine: Engine, wq: u32) -> Result<EngineBackend> {
        let entries: Vec<_> = engine
            .manifest
            .models
            .iter()
            .filter(|m| m.wq == wq)
            .cloned()
            .collect();
        if entries.is_empty() {
            return Err(anyhow!("no exported models for wq={wq}"));
        }
        let image_len = entries[0].input_len() / entries[0].batch;
        let classes = entries[0].classes;
        let batch_sizes = entries.iter().map(|e| e.batch).collect();
        Ok(EngineBackend {
            engine,
            wq,
            batch_sizes,
            image_len,
            classes,
        })
    }
}

impl InferenceBackend for EngineBackend {
    fn batch_sizes(&self) -> Vec<usize> {
        self.batch_sizes.clone()
    }

    fn image_len(&self) -> usize {
        self.image_len
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn infer_batch(&self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        let model = self
            .engine
            .model_for(self.wq, batch)
            .ok_or_else(|| anyhow!("no compiled model for wq={} batch={batch}", self.wq))?;
        model.infer(images)
    }
}

/// Deterministic mock backend: logits are a fixed function of the input so
/// tests can assert classification results; optional artificial latency and
/// failure injection.
pub struct MockBackend {
    image_len: usize,
    classes: usize,
    batch_sizes: Vec<usize>,
    latency_us: u64,
    /// Fail every call after the Nth (failure injection).
    pub fail_after: Option<u64>,
    calls: std::sync::atomic::AtomicU64,
}

impl MockBackend {
    pub fn new(image_len: usize, classes: usize, batch_sizes: Vec<usize>, latency_us: u64) -> Self {
        MockBackend {
            image_len,
            classes,
            batch_sizes,
            latency_us,
            fail_after: None,
            calls: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// The mock's ground-truth rule: class = floor(mean(image)) mod classes.
    pub fn expected_class(&self, image: &[f32]) -> usize {
        let mean = image.iter().sum::<f32>() / image.len() as f32;
        (mean.max(0.0) as usize) % self.classes
    }
}

impl InferenceBackend for MockBackend {
    fn batch_sizes(&self) -> Vec<usize> {
        self.batch_sizes.clone()
    }

    fn image_len(&self) -> usize {
        self.image_len
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn infer_batch(&self, images: &[f32], batch: usize) -> Result<Vec<f32>> {
        let n = self
            .calls
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if let Some(limit) = self.fail_after {
            if n >= limit {
                return Err(anyhow!("injected failure on call {n}"));
            }
        }
        if self.latency_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.latency_us));
        }
        if images.len() != batch * self.image_len {
            return Err(anyhow!(
                "mock: bad input length {} for batch {batch}",
                images.len()
            ));
        }
        let mut logits = vec![0.0f32; batch * self.classes];
        for b in 0..batch {
            let img = &images[b * self.image_len..(b + 1) * self.image_len];
            let class = self.expected_class(img);
            logits[b * self.classes + class] = 1.0;
        }
        Ok(logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mock_is_deterministic() {
        let m = MockBackend::new(4, 3, vec![1], 0);
        let img = vec![2.0, 2.0, 2.0, 2.0]; // mean 2 -> class 2
        let logits = m.infer_batch(&img, 1).unwrap();
        assert_eq!(logits, vec![0.0, 0.0, 1.0]);
        assert_eq!(m.expected_class(&img), 2);
    }

    #[test]
    fn mock_batch_layout() {
        let m = MockBackend::new(2, 2, vec![2], 0);
        let imgs = vec![0.0, 0.0, 1.0, 1.0]; // classes 0 and 1
        let logits = m.infer_batch(&imgs, 2).unwrap();
        assert_eq!(logits, vec![1.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn mock_failure_injection() {
        let mut m = MockBackend::new(2, 2, vec![1], 0);
        m.fail_after = Some(1);
        assert!(m.infer_batch(&[0.0, 0.0], 1).is_ok());
        assert!(m.infer_batch(&[0.0, 0.0], 1).is_err());
    }

    #[test]
    fn mock_validates_length() {
        let m = MockBackend::new(3, 2, vec![1], 0);
        assert!(m.infer_batch(&[0.0; 2], 1).is_err());
    }
}
