//! DEPRECATED single-variant shim — the serving stack lives in
//! [`crate::serving`]; start there.
//!
//! Everything in this module is either a re-export of `serving` types or the
//! thin [`Coordinator`] wrapper around a one-variant
//! [`Server`](crate::serving::Server), kept only so pre-gateway callers keep
//! compiling. All remaining pass-through APIs are marked `#[deprecated]`;
//! new code should register variants on a
//! [`ServerBuilder`](crate::serving::ServerBuilder) (see the module docs of
//! [`crate::serving`] for the full routing/batching documentation, which is
//! deliberately not duplicated here).

pub use crate::serving::backend;
pub use crate::serving::metrics;

pub use crate::serving::{
    BackendHealth, BatcherConfig, Client, EngineBackend, InferenceBackend, Metrics, MockBackend,
    PendingResponse, Response, SubmitError,
};

use crate::serving::{Server, VariantProfile, VariantSpec};
use crate::util::error::Result;

/// Name the shim registers its single variant under.
const SHIM_VARIANT: &str = "default";

/// The old single-variant coordinator: one queue, one batcher worker, one
/// backend. New code should register variants on a
/// [`ServerBuilder`](crate::serving::ServerBuilder) instead.
pub struct Coordinator {
    server: Server,
}

impl Coordinator {
    /// Start the batcher thread. `factory` runs *inside* the worker thread
    /// and builds the backend there — required because the PJRT client types
    /// are not `Send`. Fails if the factory fails.
    #[deprecated(
        since = "0.3.0",
        note = "use serving::Server::builder() and register variants explicitly"
    )]
    pub fn start<F>(factory: F, cfg: BatcherConfig) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        let spec = VariantSpec {
            name: SHIM_VARIANT.to_string(),
            wq: None,
            channelwise: Vec::new(),
            layerwise: Vec::new(),
        };
        let server = Server::builder()
            .variant_with_profile(spec, VariantProfile::default(), cfg, factory)
            .build()?;
        Ok(Coordinator { server })
    }

    #[deprecated(
        since = "0.3.0",
        note = "use serving::Server::client(name) on a multi-variant server"
    )]
    pub fn client(&self) -> Client {
        self.server
            .client(SHIM_VARIANT)
            .expect("shim server has exactly one variant")
    }

    /// Snapshot of the metrics (wall window = since start).
    #[deprecated(since = "0.3.0", note = "use serving::Server::metrics(name)")]
    pub fn metrics(&self) -> Metrics {
        self.server
            .metrics(SHIM_VARIANT)
            .expect("shim server has exactly one variant")
    }

    /// Graceful shutdown: signals the worker, joins it, returns the final
    /// metrics. In-flight requests complete; queued-but-unbatched requests
    /// are still drained before exit.
    #[deprecated(since = "0.3.0", note = "use serving::Server::shutdown")]
    pub fn shutdown(self) -> Metrics {
        let mut all = self.server.shutdown();
        all.remove(0).1
    }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)]
    use super::*;
    use std::time::Duration;

    fn mock(
        latency_us: u64,
    ) -> impl FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static {
        move || {
            Ok(Box::new(MockBackend::new(12, 4, vec![1, 4, 8], latency_us))
                as Box<dyn InferenceBackend>)
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let c = Coordinator::start(mock(0), BatcherConfig::default()).unwrap();
        let resp = c.client().classify(vec![0.5; 12]).unwrap();
        assert_eq!(resp.logits.len(), 4);
        assert_eq!(resp.batch_size, 1);
        let m = c.metrics();
        assert_eq!(m.responses, 1);
        assert_eq!(m.errors, 0);
    }

    #[test]
    fn batching_assembles_multiple() {
        let cfg = BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(50),
            ..Default::default()
        };
        let c = Coordinator::start(mock(1000), cfg).unwrap();
        let client = c.client();
        let pending: Vec<_> = (0..6)
            .map(|i| client.submit(vec![i as f32; 12]).unwrap())
            .collect();
        let responses: Vec<_> = pending.into_iter().map(|p| p.wait().unwrap()).collect();
        assert_eq!(responses.len(), 6);
        // At least one response should have ridden in a batch > 1.
        assert!(responses.iter().any(|r| r.batch_size > 1));
        let m = c.metrics();
        assert!(m.batches < 6, "batching must coalesce: {} batches", m.batches);
        assert!(m.padded_items > 0, "6 requests pad to 8");
    }

    #[test]
    fn bad_input_rejected_up_front() {
        let c = Coordinator::start(mock(0), BatcherConfig::default()).unwrap();
        match c.client().try_submit(vec![1.0; 5]) {
            Err(SubmitError::BadInput { expected, got }) => {
                assert_eq!(expected, 12);
                assert_eq!(got, 5);
            }
            other => panic!("expected BadInput, got {other:?}"),
        }
    }

    #[test]
    fn backpressure_sheds_load() {
        // Slow backend + tiny queue: try_submit must eventually refuse.
        let cfg = BatcherConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(0),
            queue_capacity: 2,
            fpga_fps_sim: 0.0,
        };
        let c = Coordinator::start(mock(50_000), cfg).unwrap();
        let client = c.client();
        let mut pending = Vec::new();
        let mut shed = 0;
        for _ in 0..20 {
            match client.try_submit(vec![0.0; 12]) {
                Ok(p) => pending.push(p),
                Err(SubmitError::Backpressure) => shed += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(shed > 0, "queue of 2 cannot absorb 20 instant submissions");
        for p in pending {
            p.wait().unwrap();
        }
    }

    #[test]
    fn backend_failure_propagates() {
        let c = Coordinator::start(
            || {
                let mut b = MockBackend::new(12, 4, vec![1, 8], 0);
                b.fail_after = Some(2);
                Ok(Box::new(b) as Box<dyn InferenceBackend>)
            },
            BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(0),
                ..Default::default()
            },
        )
        .unwrap();
        let client = c.client();
        let mut errors = 0;
        for _ in 0..5 {
            if client.classify(vec![0.0; 12]).is_err() {
                errors += 1;
            }
        }
        assert!(errors >= 3, "failures after the 2nd call must surface");
        assert!(c.metrics().errors >= 3);
    }

    #[test]
    fn virtual_fpga_clock_advances() {
        let cfg = BatcherConfig {
            fpga_fps_sim: 100.0,
            ..Default::default()
        };
        let c = Coordinator::start(mock(0), cfg).unwrap();
        for _ in 0..10 {
            c.client().classify(vec![0.0; 12]).unwrap();
        }
        let m = c.metrics();
        // 10 frames at 100 fps = 0.1 s of virtual time.
        assert!((m.fpga_virtual_us - 100_000.0).abs() < 1.0);
        assert!((m.fpga_fps() - 100.0).abs() < 1.0);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let c = Coordinator::start(mock(0), BatcherConfig::default()).unwrap();
        c.client().classify(vec![0.0; 12]).unwrap();
        let m = c.shutdown();
        assert_eq!(m.responses, 1);
    }

    #[test]
    fn concurrent_clients() {
        let c = Coordinator::start(mock(100), BatcherConfig::default()).unwrap();
        let mut handles = Vec::new();
        for t in 0..4 {
            let client = c.client();
            handles.push(std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..25 {
                    let img = vec![(t * 100 + i) as f32; 12];
                    if client.classify(img).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 100);
        assert_eq!(c.metrics().responses, 100);
    }
}
