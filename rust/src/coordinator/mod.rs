//! L3 serving coordinator: bounded admission queue with backpressure, a
//! dynamic batcher, a worker executing batches on an [`InferenceBackend`]
//! (the PJRT engine in production, mocks in tests), and serving metrics
//! including a virtual-FPGA clock tied to the simulated accelerator design.
//!
//! No tokio offline — plain threads + `std::sync::mpsc`, which is entirely
//! adequate for a single-device inference queue: one batcher thread owns
//! the backend, clients block on per-request channels.

pub mod backend;
pub mod metrics;

pub use backend::{EngineBackend, InferenceBackend, MockBackend};
pub use metrics::Metrics;

use crate::util::error::Result;
use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Assemble at most this many requests per batch (must be a supported
    /// backend batch size or smaller).
    pub max_batch: usize,
    /// Wait at most this long for the batch to fill.
    pub max_wait: Duration,
    /// Admission queue depth; beyond this, `try_submit` sheds load.
    pub queue_capacity: usize,
    /// Frames/s of the simulated FPGA design (drives the virtual clock);
    /// 0 disables the virtual clock.
    pub fpga_fps_sim: f64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            queue_capacity: 128,
            fpga_fps_sim: 0.0,
        }
    }
}

/// One inference request.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    reply: SyncSender<Result<Response, String>>,
}

/// One inference response.
#[derive(Clone, Debug)]
pub struct Response {
    /// Logits for this request's image.
    pub logits: Vec<f32>,
    /// Predicted class (argmax).
    pub class: usize,
    /// End-to-end latency.
    pub latency: Duration,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
}

/// Submission error.
#[derive(Debug)]
pub enum SubmitError {
    Backpressure,
    Closed,
    BadInput { expected: usize, got: usize },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Backpressure => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "coordinator is shut down"),
            SubmitError::BadInput { expected, got } => {
                write!(f, "bad input: expected {expected} elements, got {got}")
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Handle for submitting requests; cheap to clone across client threads.
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Request>,
    image_len: usize,
}

impl Client {
    /// Non-blocking submit; sheds load when the queue is full.
    pub fn try_submit(&self, image: Vec<f32>) -> Result<PendingResponse, SubmitError> {
        if image.len() != self.image_len {
            return Err(SubmitError::BadInput {
                expected: self.image_len,
                got: image.len(),
            });
        }
        let (reply_tx, reply_rx) = sync_channel(1);
        let req = Request {
            image,
            enqueued: Instant::now(),
            reply: reply_tx,
        };
        match self.tx.try_send(req) {
            Ok(()) => Ok(PendingResponse { rx: reply_rx }),
            Err(TrySendError::Full(_)) => Err(SubmitError::Backpressure),
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Blocking submit (applies backpressure to the caller).
    pub fn submit(&self, image: Vec<f32>) -> Result<PendingResponse, SubmitError> {
        if image.len() != self.image_len {
            return Err(SubmitError::BadInput {
                expected: self.image_len,
                got: image.len(),
            });
        }
        let (reply_tx, reply_rx) = sync_channel(1);
        let req = Request {
            image,
            enqueued: Instant::now(),
            reply: reply_tx,
        };
        self.tx
            .send(req)
            .map_err(|_| SubmitError::Closed)?;
        Ok(PendingResponse { rx: reply_rx })
    }

    /// Convenience: submit and wait.
    pub fn classify(&self, image: Vec<f32>) -> Result<Response, String> {
        self.submit(image)
            .map_err(|e| e.to_string())?
            .wait()
    }
}

/// Future-like handle for an in-flight request.
#[derive(Debug)]
pub struct PendingResponse {
    rx: Receiver<Result<Response, String>>,
}

impl PendingResponse {
    pub fn wait(self) -> Result<Response, String> {
        self.rx
            .recv()
            .map_err(|_| "coordinator dropped request".to_string())?
    }

    pub fn wait_timeout(self, d: Duration) -> Result<Response, String> {
        match self.rx.recv_timeout(d) {
            Ok(r) => r,
            Err(_) => Err("timeout".to_string()),
        }
    }
}

/// The running coordinator.
pub struct Coordinator {
    client: Client,
    metrics: Arc<Mutex<Metrics>>,
    worker: Option<JoinHandle<()>>,
    started: Instant,
    /// Set on shutdown/drop; the worker polls it while idle so stray
    /// `Client` clones cannot keep the thread alive.
    stop: Arc<AtomicBool>,
}

impl Coordinator {
    /// Start the batcher thread. `factory` runs *inside* the worker thread
    /// and builds the backend there — required because the PJRT client types
    /// are not `Send`. Fails if the factory fails.
    pub fn start<F>(factory: F, cfg: BatcherConfig) -> Result<Coordinator>
    where
        F: FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static,
    {
        assert!(cfg.max_batch >= 1);
        let (tx, rx) = sync_channel::<Request>(cfg.queue_capacity);
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let m2 = metrics.clone();
        // The worker reports readiness (and the image length) or the
        // factory's error back over a rendezvous channel.
        let (ready_tx, ready_rx) = sync_channel::<Result<usize, String>>(1);
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let worker = std::thread::Builder::new()
            .name("mpcnn-batcher".to_string())
            .spawn(move || {
                let backend = match factory() {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(b.image_len()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                batcher_loop(backend, rx, cfg, m2, stop2)
            })
            .expect("spawn batcher");
        let image_len = ready_rx
            .recv()
            .map_err(|_| crate::anyhow!("batcher thread died during startup"))?
            .map_err(|e| crate::anyhow!("backend factory failed: {e}"))?;
        Ok(Coordinator {
            client: Client { tx, image_len },
            metrics,
            worker: Some(worker),
            started: Instant::now(),
            stop,
        })
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    /// Snapshot of the metrics (wall window = since start).
    pub fn metrics(&self) -> Metrics {
        let mut m = self.metrics.lock().unwrap().clone();
        m.wall_us = self.started.elapsed().as_micros() as f64;
        m
    }

    /// Graceful shutdown: signals the worker, joins it, returns the final
    /// metrics. In-flight requests complete; queued-but-unbatched requests
    /// are still drained before exit (the stop flag is only honoured while
    /// idle).
    pub fn shutdown(mut self) -> Metrics {
        let final_metrics = self.metrics();
        self.stop_and_join();
        final_metrics
    }

    fn stop_and_join(&mut self) {
        if let Some(h) = self.worker.take() {
            self.stop.store(true, Ordering::SeqCst);
            // Also drop our own sender so an idle worker wakes immediately
            // when no other Client clones exist.
            let dummy = Client {
                tx: sync_channel(1).0,
                image_len: 0,
            };
            let old = std::mem::replace(&mut self.client, dummy);
            drop(old);
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// The batcher loop: collect up to `max_batch` requests within `max_wait`
/// of the first, pad to a supported backend batch size, execute, fan out.
fn batcher_loop(
    backend: Box<dyn InferenceBackend>,
    rx: Receiver<Request>,
    cfg: BatcherConfig,
    metrics: Arc<Mutex<Metrics>>,
    stop: Arc<AtomicBool>,
) {
    let supported = {
        let mut s = backend.batch_sizes();
        s.sort_unstable();
        s
    };
    let image_len = backend.image_len();
    let classes = backend.classes();
    loop {
        // Block for the first request of the batch, polling the stop flag
        // so shutdown works even while stray Client clones are alive.
        let first = loop {
            if stop.load(Ordering::SeqCst) {
                // Drain whatever is already queued, then exit.
                match rx.try_recv() {
                    Ok(r) => break r,
                    Err(_) => return,
                }
            }
            match rx.recv_timeout(Duration::from_millis(25)) {
                Ok(r) => break r,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return, // all clients dropped
            }
        };
        let deadline = Instant::now() + cfg.max_wait;
        let mut batch = vec![first];
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        // Pick the smallest supported batch size >= len (pad), else the
        // largest supported (split would be needed; max_batch should be a
        // supported size so this doesn't happen).
        let n = batch.len();
        let exec_size = supported
            .iter()
            .copied()
            .find(|&s| s >= n)
            .unwrap_or_else(|| *supported.last().unwrap());
        let mut flat = Vec::with_capacity(exec_size * image_len);
        for r in &batch {
            flat.extend_from_slice(&r.image);
        }
        flat.resize(exec_size * image_len, 0.0); // zero padding

        {
            let mut m = metrics.lock().unwrap();
            m.requests += n as u64;
            m.batches += 1;
            m.batched_items += n as u64;
            m.padded_items += (exec_size - n) as u64;
            for r in &batch {
                m.queue_wait
                    .record_us(r.enqueued.elapsed().as_micros() as f64);
            }
        }

        let result = backend.infer_batch(&flat, exec_size);
        let mut m = metrics.lock().unwrap();
        if cfg.fpga_fps_sim > 0.0 {
            m.fpga_virtual_us += n as f64 / cfg.fpga_fps_sim * 1e6;
        }
        match result {
            Ok(logits) => {
                for (i, r) in batch.into_iter().enumerate() {
                    let row = logits[i * classes..(i + 1) * classes].to_vec();
                    let class = crate::runtime::argmax_rows(&row, classes)[0];
                    let latency = r.enqueued.elapsed();
                    m.latency.record_us(latency.as_micros() as f64);
                    m.responses += 1;
                    let _ = r.reply.send(Ok(Response {
                        logits: row,
                        class,
                        latency,
                        batch_size: n,
                    }));
                }
            }
            Err(e) => {
                let msg = format!("backend error: {e}");
                for r in batch {
                    m.errors += 1;
                    let _ = r.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mock(latency_us: u64) -> impl FnOnce() -> Result<Box<dyn InferenceBackend>> + Send + 'static {
        move || Ok(Box::new(MockBackend::new(12, 4, vec![1, 4, 8], latency_us)) as Box<dyn InferenceBackend>)
    }

    #[test]
    fn single_request_roundtrip() {
        let c = Coordinator::start(mock(0), BatcherConfig::default()).unwrap();
        let resp = c.client().classify(vec![0.5; 12]).unwrap();
        assert_eq!(resp.logits.len(), 4);
        assert_eq!(resp.batch_size, 1);
        let m = c.metrics();
        assert_eq!(m.responses, 1);
        assert_eq!(m.errors, 0);
    }

    #[test]
    fn batching_assembles_multiple() {
        let cfg = BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(50),
            ..Default::default()
        };
        let c = Coordinator::start(mock(1000), cfg).unwrap();
        let client = c.client();
        let pending: Vec<_> = (0..6)
            .map(|i| client.submit(vec![i as f32; 12]).unwrap())
            .collect();
        let responses: Vec<_> = pending.into_iter().map(|p| p.wait().unwrap()).collect();
        assert_eq!(responses.len(), 6);
        // At least one response should have ridden in a batch > 1.
        assert!(responses.iter().any(|r| r.batch_size > 1));
        let m = c.metrics();
        assert!(m.batches < 6, "batching must coalesce: {} batches", m.batches);
        assert!(m.padded_items > 0, "6 requests pad to 8");
    }

    #[test]
    fn bad_input_rejected_up_front() {
        let c = Coordinator::start(mock(0), BatcherConfig::default()).unwrap();
        match c.client().try_submit(vec![1.0; 5]) {
            Err(SubmitError::BadInput { expected, got }) => {
                assert_eq!(expected, 12);
                assert_eq!(got, 5);
            }
            other => panic!("expected BadInput, got {other:?}"),
        }
    }

    #[test]
    fn backpressure_sheds_load() {
        // Slow backend + tiny queue: try_submit must eventually refuse.
        let cfg = BatcherConfig {
            max_batch: 1,
            max_wait: Duration::from_millis(0),
            queue_capacity: 2,
            fpga_fps_sim: 0.0,
        };
        let c = Coordinator::start(mock(50_000), cfg).unwrap();
        let client = c.client();
        let mut pending = Vec::new();
        let mut shed = 0;
        for _ in 0..20 {
            match client.try_submit(vec![0.0; 12]) {
                Ok(p) => pending.push(p),
                Err(SubmitError::Backpressure) => shed += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(shed > 0, "queue of 2 cannot absorb 20 instant submissions");
        for p in pending {
            p.wait().unwrap();
        }
    }

    #[test]
    fn backend_failure_propagates() {
        let c = Coordinator::start(
            || {
                let mut b = MockBackend::new(12, 4, vec![1, 8], 0);
                b.fail_after = Some(2);
                Ok(Box::new(b) as Box<dyn InferenceBackend>)
            },
            BatcherConfig {
                max_batch: 1,
                max_wait: Duration::from_millis(0),
                ..Default::default()
            },
        )
        .unwrap();
        let client = c.client();
        let mut errors = 0;
        for _ in 0..5 {
            if client.classify(vec![0.0; 12]).is_err() {
                errors += 1;
            }
        }
        assert!(errors >= 3, "failures after the 2nd call must surface");
        assert!(c.metrics().errors >= 3);
    }

    #[test]
    fn virtual_fpga_clock_advances() {
        let cfg = BatcherConfig {
            fpga_fps_sim: 100.0,
            ..Default::default()
        };
        let c = Coordinator::start(mock(0), cfg).unwrap();
        for _ in 0..10 {
            c.client().classify(vec![0.0; 12]).unwrap();
        }
        let m = c.metrics();
        // 10 frames at 100 fps = 0.1 s of virtual time.
        assert!((m.fpga_virtual_us - 100_000.0).abs() < 1.0);
        assert!((m.fpga_fps() - 100.0).abs() < 1.0);
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let c = Coordinator::start(mock(0), BatcherConfig::default()).unwrap();
        c.client().classify(vec![0.0; 12]).unwrap();
        let m = c.shutdown();
        assert_eq!(m.responses, 1);
    }

    #[test]
    fn concurrent_clients() {
        let c = Coordinator::start(mock(100), BatcherConfig::default()).unwrap();
        let mut handles = Vec::new();
        for t in 0..4 {
            let client = c.client();
            handles.push(std::thread::spawn(move || {
                let mut ok = 0;
                for i in 0..25 {
                    let img = vec![(t * 100 + i) as f32; 12];
                    if client.classify(img).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 100);
        assert_eq!(c.metrics().responses, 100);
    }
}
